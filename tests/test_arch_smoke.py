"""Per-architecture smoke tests: reduced config, one loss/grad step + a
prefill+decode step on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ARCHS, get_smoke

jax.config.update("jax_enable_x64", False)

B, S = 2, 64
DEC_LEN = 16


def _batch(cfg, rng):
    k1, k2 = jax.random.split(rng)
    n_prefix = 0
    batch = {}
    if cfg.frontend == "vision_patches":
        n_prefix = cfg.n_patches
        batch["patches"] = jax.random.normal(k2, (B, n_prefix, 1024), jnp.float32)
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(k2, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    s_tok = S - n_prefix
    batch["tokens"] = jax.random.randint(k1, (B, s_tok), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(k1, (B, s_tok), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_smoke(arch)
    rng = jax.random.PRNGKey(0)
    params = models.init_params(cfg, rng)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    loss, metrics = models.loss_fn(params, cfg, batch, remat=False)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grad_step(arch):
    cfg = get_smoke(arch)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    def lf(p):
        loss, _ = models.loss_fn(p, cfg, batch, remat=True)
        return loss

    loss, grads = jax.value_and_grad(lf)(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, dtype=np.float32))) for g in flat)
    # at least one grad is nonzero
    assert any(float(jnp.max(jnp.abs(g.astype(jnp.float32)))) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode(arch):
    cfg = get_smoke(arch)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    max_seq = S + DEC_LEN

    logits, caches = models.prefill(params, cfg, batch, max_seq)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))

    tok = jnp.argmax(logits, axis=-1)[:, None]
    prompt_len = batch["tokens"].shape[1] + (cfg.n_patches if cfg.frontend == "vision_patches" else 0)
    lg, caches = models.decode_step(params, cfg, tok, caches, prompt_len)
    assert lg.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(lg))), arch


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "xlstm-125m"])
def test_decode_matches_forward_ssm(arch):
    """Recurrent decode must match the chunked-parallel forward numerics:
    run T tokens via prefill+decode and via one forward; compare hiddens."""
    cfg = get_smoke(arch)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    T = 12
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, T), 0, cfg.vocab_size)

    # parallel forward over the full sequence
    hidden_par, _, _ = models.forward_hidden(params, cfg, {"tokens": tokens})

    # prefill on the first T-1, then decode token T-1
    caches = models.init_cache(cfg, 1, T + 4)
    _, caches2, _ = models.forward_hidden(
        params, cfg, {"tokens": tokens[:, : T - 1]}, caches=caches, cache_index=0
    )
    hid_dec, _, _ = models.forward_hidden(
        params, cfg, {"tokens": tokens[:, T - 1 :]}, caches=caches2, cache_index=T - 1
    )
    np.testing.assert_allclose(
        np.asarray(hid_dec[0, 0], np.float32),
        np.asarray(hidden_par[0, -1], np.float32),
        rtol=0.05, atol=0.05,
    )


def test_param_counts_sane():
    """Full configs should be within ~35% of the published param counts."""
    targets = {
        "minitron-8b": 8.0e9,
        "olmo-1b": 1.2e9,
        "olmoe-1b-7b": 6.9e9,
        "nemotron-4-340b": 340e9,
        "deepseek-v3-671b": 671e9,
        "zamba2-1.2b": 1.2e9,
        "xlstm-125m": 0.125e9,
    }
    from repro.configs import get_config

    for name, target in targets.items():
        cfg = get_config(name)
        defs = models.build_def(cfg)
        n = sum(
            int(np.prod(d.shape))
            for d in jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, models.ParamDef))
        )
        assert 0.6 * target < n < 1.5 * target, (name, n, target)
