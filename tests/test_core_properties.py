"""Property-based tests (hypothesis) on the quantization system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property sweep skipped")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import formats as F
from repro.core.quantize import fake_quantize_act, fake_quantize_weight, quantize_weight
from repro.core.scales import constrain_scales_m1, constrain_scales_m2

FP_FMTS = ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1", "fp4_e3m0"]


def finite_floats(max_mag=1e4):
    return hnp.arrays(
        np.float32,
        st.integers(1, 64),
        elements=st.floats(
            -max_mag, max_mag, allow_nan=False, allow_infinity=False, width=32
        ),
    )


@settings(max_examples=50, deadline=None)
@given(x=finite_floats(), name=st.sampled_from(FP_FMTS))
def test_quantize_idempotent(x, name):
    """Q(Q(x)) == Q(x): the grid is a fixed-point set."""
    fmt = F.FORMATS[name]
    q1 = F.quantize_to_grid(jnp.asarray(x), fmt)
    q2 = F.quantize_to_grid(q1, fmt)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@settings(max_examples=50, deadline=None)
@given(x=finite_floats(), name=st.sampled_from(FP_FMTS))
def test_quantize_error_bounded_by_half_step(x, name):
    """|x - Q(x)| <= max(half local grid step, saturation overflow)."""
    fmt = F.FORMATS[name]
    xs = np.clip(x, -fmt.max_value, fmt.max_value)  # ignore saturation region
    q = np.asarray(F.quantize_to_grid(jnp.asarray(xs), fmt))
    absx = np.abs(xs)
    e = np.clip(np.floor(np.log2(np.maximum(absx, 1e-38))), fmt.min_exp, fmt.max_exp)
    half_step = 0.5 * 2.0 ** (e - fmt.man_bits)
    assert np.all(np.abs(xs - q) <= half_step * (1 + 1e-6) + 1e-30)


@settings(max_examples=50, deadline=None)
@given(x=finite_floats(), name=st.sampled_from(FP_FMTS))
def test_quantize_odd_symmetry(x, name):
    """Q(-x) == -Q(x): symmetric grids, RNE is sign-symmetric."""
    fmt = F.FORMATS[name]
    q_pos = np.asarray(F.quantize_to_grid(jnp.asarray(x), fmt))
    q_neg = np.asarray(F.quantize_to_grid(jnp.asarray(-x), fmt))
    np.testing.assert_array_equal(q_pos, -q_neg)


@settings(max_examples=50, deadline=None)
@given(x=finite_floats(), name=st.sampled_from(FP_FMTS))
def test_encode_decode_identity_on_grid(x, name):
    fmt = F.FORMATS[name]
    q = F.quantize_to_grid(jnp.asarray(x), fmt)
    back = F.fp_decode(F.fp_encode(q, fmt), fmt)
    # -0.0 decodes to -0.0; compare with equality that treats 0 == -0
    np.testing.assert_allclose(np.asarray(back), np.asarray(q), rtol=0, atol=0)


@settings(max_examples=30, deadline=None)
@given(
    w=hnp.arrays(
        np.float32,
        st.tuples(st.sampled_from([4, 8, 16]), st.sampled_from([32, 64])),
        elements=st.floats(-10, 10, allow_nan=False, width=32),
    ),
    fmt=st.sampled_from(["fp4_e2m1", "int4", "fp8_e4m3", "int8"]),
)
def test_weight_quant_scaling_invariance(w, fmt):
    """FGQ with symmetric scales: quantizing c*W (c = power of two) gives
    c * (quantized W) — scale covariance, the property pow-2 kernels rely on."""
    w = jnp.asarray(w)
    a = np.asarray(fake_quantize_weight(w, fmt, group_size=w.shape[1]))
    b = np.asarray(fake_quantize_weight(w * 4.0, fmt, group_size=w.shape[1]))
    np.testing.assert_allclose(4.0 * a, b, rtol=1e-5, atol=1e-7)


@settings(max_examples=30, deadline=None)
@given(
    s=hnp.arrays(
        np.float32,
        st.tuples(st.integers(1, 8), st.integers(1, 16)),
        elements=st.floats(
            np.float32(1e-4), np.float32(1e4), allow_nan=False, width=32
        ),
    )
)
def test_m2_structural_invariants(s):
    """M2 theorem-level invariants: for every scale, S/2 < S_hat <= S
    (one-sided, at most one binade of shrink), and the group max is exact.
    (The paper's 'M2 beats M1' claim is empirical on weight-scale
    distributions — covered by the fixed-seed test in test_core_quantize.)"""
    s = jnp.asarray(s)
    m2 = constrain_scales_m2(s)
    s_np = np.asarray(s)
    hat = np.asarray(m2.scales)
    assert np.all(hat <= s_np * (1 + 1e-6))
    assert np.all(hat > s_np / 2 * (1 - 1e-6))
    np.testing.assert_allclose(hat.max(axis=-1), s_np.max(axis=-1), rtol=1e-6)
    # M1 invariant: S <= S_hat < 2S (pure pow2, one binade of growth)
    m1 = np.asarray(constrain_scales_m1(s))
    assert np.all(m1 >= s_np * (1 - 1e-6)) and np.all(m1 < 2 * s_np * (1 + 1e-6))


@settings(max_examples=30, deadline=None)
@given(
    x=hnp.arrays(
        np.float32,
        st.tuples(st.integers(1, 8), st.sampled_from([16, 32])),
        elements=st.floats(-100, 100, allow_nan=False, width=32),
    ),
    fmt=st.sampled_from(["fp8_e4m3", "int8"]),
)
def test_act_quant_tokenwise_is_per_row(x, fmt):
    """Quantizing rows independently == quantizing the batch (token-wise)."""
    x = jnp.asarray(x)
    full = np.asarray(fake_quantize_act(x, fmt))
    rows = np.stack([np.asarray(fake_quantize_act(x[i], fmt)) for i in range(x.shape[0])])
    np.testing.assert_allclose(full, rows, rtol=1e-6, atol=1e-8)


@settings(max_examples=20, deadline=None)
@given(
    w=hnp.arrays(
        np.float32,
        st.tuples(st.just(8), st.just(64)),
        elements=st.floats(-5, 5, allow_nan=False, width=32),
    ),
    gs=st.sampled_from([16, 32, 64]),
)
def test_quantized_tensor_roundtrip_shape(w, gs):
    qt = quantize_weight(jnp.asarray(w), "fp4_e2m1", group_size=gs)
    deq = qt.dequantize()
    assert deq.shape == w.shape
    assert qt.scale.shape == (8, 64 // gs)
    assert np.all(np.isfinite(np.asarray(deq)))
