"""Fault-tolerant serving: request-level failure isolation, deterministic
fault injection, spill integrity checksums, and the runtime pool auditor.

Covers: submit() input validation fail-fasts; the in-graph isfinite
sentinel quarantining exactly the poisoned row (decode and prefill) with
pages/slabs retired through the normal accounting path; spill CRC
verification falling back to the tail re-prefill on corrupted/dropped
payloads (token-identical recovery); transient allocator-exhaustion
injection absorbed by the steal/defer machinery; Server.audit() returning
a clean summary vs raising structured PoolCorruptionError on seeded
corruption (ad hoc and via audit_every); strict-mode ServingError carrying
partial results + pending diagnostics and non-strict per-request
starvation failure; deadline/failed interplay in victim selection; and
the capstone seeded chaos test (NaN + corrupted spill + alloc fault on a
steal-happy pool, bf16 + fp8): survivors token-identical to the fault-free
run, exactly the injected requests fail, audit clean at drain."""
import numpy as np
import pytest

import jax

from conftest import tiny_lm_cfg

from repro import models
from repro.runtime import kv_cache as kvc
from repro.runtime.faults import FaultPlan
from repro.runtime.serve import (PoolCorruptionError, Request,
                                 SchedulerConfig, Server, ServerConfig,
                                 ServingError)


def _tiny_server(params, cfg, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_seq", 32)
    kw.setdefault("kv_fmt", "fp8_e4m3")
    kw.setdefault("page_size", 4)
    kw.setdefault("a_fmt", None)
    return Server(params, cfg, **kw)


def _solo_out(params, cfg, prompt, max_new, **kw):
    kw.setdefault("max_seq", 32)
    kw.setdefault("kv_fmt", "fp8_e4m3")
    kw.setdefault("page_size", 4)
    srv = Server(params, cfg, slots=1, a_fmt=None, **kw)
    ref = Request(rid=99, prompt=list(prompt), max_new=max_new)
    srv.submit(ref)
    srv.run_until_drained()
    return ref.out


class TestSubmitValidation:
    @pytest.fixture(scope="class")
    def srv(self):
        cfg = tiny_lm_cfg()
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        return _tiny_server(params, cfg)

    def test_empty_prompt_rejected(self, srv):
        with pytest.raises(ValueError, match="empty prompt"):
            srv.submit(Request(rid=0, prompt=[], max_new=4))

    def test_nonpositive_max_new_rejected(self, srv):
        with pytest.raises(ValueError, match="max_new"):
            srv.submit(Request(rid=0, prompt=[1, 2], max_new=0))
        with pytest.raises(ValueError, match="max_new"):
            srv.submit(Request(rid=0, prompt=[1, 2], max_new=-3))

    def test_out_of_vocab_ids_rejected(self, srv):
        v = srv.cfg.vocab_size
        with pytest.raises(ValueError, match="vocab"):
            srv.submit(Request(rid=0, prompt=[1, v], max_new=4))
        with pytest.raises(ValueError, match="vocab"):
            srv.submit(Request(rid=0, prompt=[-1, 2], max_new=4))

    def test_rejected_request_leaves_no_state(self, srv):
        before = (list(srv.queue), srv._submit_seq)
        with pytest.raises(ValueError):
            srv.submit(Request(rid=0, prompt=[], max_new=4))
        assert (list(srv.queue), srv._submit_seq) == before


class TestNaNQuarantine:
    @pytest.mark.parametrize("kv_fmt", [None, "fp8_e4m3"])
    def test_decode_nan_quarantines_only_offending_row(self, trained_tiny,
                                                       kv_fmt):
        """A NaN logits row (injected in-graph, upstream of the sentinel)
        fails exactly that request; batchmates finish token-identical to
        solo runs and the drained pool is whole."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(11)
        plan = FaultPlan(nan_logits=((2, 1),))
        srv = _tiny_server(params, cfg, slots=3, kv_fmt=kv_fmt, faults=plan)
        reqs = [Request(rid=i, prompt=rng.integers(1, 64, 5).tolist(),
                        max_new=8) for i in range(3)]
        for r in reqs:
            srv.submit(r)
        srv.run_until_drained()
        victim = reqs[1]  # slot i holds rid i: all admitted in one round
        assert victim.done and victim.status == "failed"
        assert "non-finite" in victim.error
        assert plan.nan_hits == [(2, 1, victim.rid)]
        assert srv.stats["failed"] == 1
        for r in reqs:
            if r is victim:
                continue
            assert r.status == "ok" and r.error is None
            assert r.out == _solo_out(params, cfg, r.prompt, 8, kv_fmt=kv_fmt)
        assert srv.audit()["violations"] == 0
        assert sorted(srv.free_pages + srv.reusable_pages) == \
            list(range(srv._n_pages))

    def test_prefill_nonfinite_fails_request_not_process(self, trained_tiny):
        """Non-finite logits during a prefill stream fail that request
        without registering its pages in the prefix index (frozen garbage
        must never become a future hit) and without a process error. The
        quarantine scrubs every page the failing prefill wrote — including
        the shared null page its bucketed overhang hit: NaN K/V codes
        survive attention's zero-weight masking (0 * NaN = NaN), so
        unscubbed bytes would fail healthy batchmates and successors."""
        cfg, params = trained_tiny
        bad = dict(params)
        # poison one learned position embedding: only a context that
        # reaches position 5 goes non-finite, through the real forward
        # pass (token embeddings are tied to the head, so poisoning those
        # would NaN one logit column for every request)
        pos = np.array(bad["pos_embed"])  # host copy, original dtype
        pos[5] = np.nan
        bad["pos_embed"] = pos
        srv = _tiny_server(bad, cfg)
        ok_req = Request(rid=0, prompt=[13, 14, 15], max_new=2)
        bad_req = Request(rid=1, prompt=[3, 4, 5, 6, 8, 9, 10, 11, 12],
                          max_new=4)
        srv.submit(ok_req)
        srv.submit(bad_req)
        srv.run_until_drained()
        assert bad_req.done and bad_req.status == "failed"
        assert "prefill" in bad_req.error
        assert bad_req.out == []  # no seed token from garbage logits
        assert ok_req.status == "ok" and len(ok_req.out) == 2
        # the failed prefill's pages (incl. the null page) were scrubbed:
        # a successor recycling them from the free list decodes clean
        after = Request(rid=2, prompt=[16, 17, 18], max_new=2)
        srv.submit(after)
        srv.run_until_drained()
        assert after.status == "ok" and len(after.out) == 2
        assert srv.audit()["violations"] == 0
        assert sorted(srv.free_pages + srv.reusable_pages) == \
            list(range(srv._n_pages))

    def test_failed_recurrent_request_frees_slab(self):
        """Slab accounting for a quarantined recurrent request: the slab
        returns to the free pool and a later request reuses it."""
        from repro.configs import get_smoke

        cfg = get_smoke("xlstm-125m")
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(3)
        plan = FaultPlan(nan_logits=((2, 0),))
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=32, a_fmt=None,
                                  pool_slabs=2, page_size=4,
                                  scheduler=SchedulerConfig(prefill_chunk_pages=1)),
                     faults=plan)
        a = Request(rid=0, prompt=rng.integers(1, cfg.vocab_size, 5).tolist(),
                    max_new=8)
        b = Request(rid=1, prompt=rng.integers(1, cfg.vocab_size, 5).tolist(),
                    max_new=6)
        srv.submit(a)
        srv.submit(b)
        srv.run_until_drained()
        assert a.status == "failed" and plan.nan_hits[0][2] == 0
        assert b.status == "ok"
        assert sorted(srv.free_slabs) == list(range(srv._n_slabs))
        assert srv.audit()["violations"] == 0
        solo = Server(params, cfg,
                      ServerConfig(slots=1, max_seq=32, a_fmt=None,
                                   page_size=4,
                                   scheduler=SchedulerConfig(prefill_chunk_pages=1)))
        ref = Request(rid=99, prompt=list(b.prompt), max_new=6)
        solo.submit(ref)
        solo.run_until_drained()
        assert b.out == ref.out


class TestSpillIntegrity:
    @pytest.mark.parametrize("mode", ["corrupt", "drop"])
    def test_tampered_spill_reprefills_token_identical(self, trained_tiny,
                                                       mode):
        """A corrupted (one byte flipped) or dropped (zeroed) host spill
        fails the CRC verify at resume; the engine falls back to the tail
        re-prefill and the request still finishes token-identically — a
        rotted spill costs a prefill, never correctness."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(11)
        plan = (FaultPlan(corrupt_spills=(0,)) if mode == "corrupt"
                else FaultPlan(drop_spills=(0,)))
        srv = _tiny_server(params, cfg, pool_pages=6, faults=plan)
        reqs = [Request(rid=i, prompt=rng.integers(1, 64, 5).tolist(),
                        max_new=10) for i in range(2)]
        for r in reqs:
            srv.submit(r)
        srv.run_until_drained()
        assert srv.stats["preemptions"] >= 1
        assert srv.stats["spill_integrity_failures"] == 1
        assert srv.stats["spill_evictions"] >= 1
        tampered = (plan.corrupted_rids if mode == "corrupt"
                    else plan.dropped_rids)
        assert len(tampered) == 1
        for r in reqs:
            assert r.status == "ok" and len(r.out) == 10
            assert r.out == _solo_out(params, cfg, r.prompt, 10)
        assert srv.audit()["violations"] == 0

    def test_checksum_detects_any_single_byte_flip(self, trained_tiny):
        """payload_checksum changes under every single-byte XOR the
        corruptor can apply (CRC32 is linear: flipped bits always move
        the checksum)."""
        cfg, params = trained_tiny
        srv = _tiny_server(params, cfg, pool_pages=6)
        r = Request(rid=0, prompt=[3, 4, 5, 6, 7], max_new=8)
        srv.submit(r)
        srv.step()
        srv._preempt(0)
        sp = srv.preempted[0]
        clean = kvc.payload_checksum(sp.payload)
        assert clean == sp.crc
        for seed in range(5):
            plan = FaultPlan(seed=seed, corrupt_spills=(0,))
            tampered = plan.spill_payload(r.rid, sp.payload)
            assert kvc.payload_checksum(tampered) != clean
        # the original payload was not mutated in place
        assert kvc.payload_checksum(sp.payload) == clean


class TestAllocFaults:
    def test_transient_exhaustion_recovers_token_identical(self,
                                                           trained_tiny):
        """A blanked-allocator tick defers admission and routes growth
        through the steal path; once the tick passes, everything resumes
        and finishes token-identically — transient exhaustion is absorbed,
        not fatal."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(7)
        plan = FaultPlan(alloc_fail_ticks=(3, 4))
        srv = _tiny_server(params, cfg, pool_pages=6, faults=plan)
        reqs = [Request(rid=i, prompt=rng.integers(1, 64, 5).tolist(),
                        max_new=10) for i in range(2)]
        for r in reqs:
            srv.submit(r)
        srv.run_until_drained()
        assert plan.blocked_ticks == [3, 4]
        for r in reqs:
            assert r.status == "ok"
            assert r.out == _solo_out(params, cfg, r.prompt, 10)
        assert srv.audit()["violations"] == 0

    def test_blocked_idle_tick_is_not_starvation(self, trained_tiny):
        """run_until_drained must not call a step blocked only by an
        injected allocator fault 'starved' — capacity returns next tick."""
        cfg, params = trained_tiny
        plan = FaultPlan(alloc_fail_ticks=(1,))
        srv = _tiny_server(params, cfg, faults=plan)
        r = Request(rid=0, prompt=[3, 4, 5], max_new=4)
        srv.submit(r)
        srv.run_until_drained()  # tick 1 admits nothing; tick 2 proceeds
        assert r.status == "ok" and len(r.out) == 4
        assert plan.blocked_ticks == [1]


class TestAuditor:
    def test_clean_audit_returns_summary(self, trained_tiny):
        cfg, params = trained_tiny
        srv = _tiny_server(params, cfg)
        r = Request(rid=0, prompt=[3, 4, 5, 6, 7], max_new=6)
        srv.submit(r)
        srv.step()
        mid = srv.audit()
        assert mid["violations"] == 0 and mid["active"] == 1
        assert mid["pages_mapped"] == len(srv.slot_pages[0])
        srv.run_until_drained()
        end = srv.audit()
        assert end["violations"] == 0 and end["active"] == 0

    def test_refcount_corruption_raises_structured(self, trained_tiny):
        cfg, params = trained_tiny
        srv = _tiny_server(params, cfg)
        srv.submit(Request(rid=0, prompt=[3, 4, 5, 6, 7], max_new=6))
        srv.step()
        srv.page_refs[srv.slot_pages[0][0]] += 1  # seeded corruption
        with pytest.raises(PoolCorruptionError, match="refcount") as ei:
            srv.audit()
        assert any("refcount" in v for v in ei.value.violations)
        assert ei.value.dump["slot_pages"][0] == srv.slot_pages[0]
        assert "page_refs" in ei.value.dump

    def test_double_free_and_leak_detected(self, trained_tiny):
        cfg, params = trained_tiny
        srv = _tiny_server(params, cfg)
        srv.submit(Request(rid=0, prompt=[3, 4, 5, 6, 7], max_new=6))
        srv.step()
        srv.free_pages.append(srv.slot_pages[0][0])  # mapped AND free
        with pytest.raises(PoolCorruptionError) as ei:
            srv.audit()
        assert any("mapped and free" in v for v in ei.value.violations)

    def test_audit_every_runs_inside_step(self, trained_tiny):
        cfg, params = trained_tiny
        srv = _tiny_server(params, cfg, audit_every=1)
        srv.submit(Request(rid=0, prompt=[3, 4, 5, 6, 7], max_new=8))
        srv.step()  # clean: audit passes silently
        srv.page_refs[srv.slot_pages[0][0]] += 1
        with pytest.raises(PoolCorruptionError):
            srv.step()


class TestStrictness:
    def _starve(self, params, cfg, strict):
        """A finishes while B sits spilled against a pool that never
        recovers: strict raises with partial results, non-strict fails
        exactly B."""
        rng = np.random.default_rng(5)
        srv = _tiny_server(params, cfg, pool_pages=8, strict=strict,
                           prefix_cache=False)
        a = Request(rid=0, prompt=rng.integers(1, 64, 3).tolist(), max_new=6)
        # B's resume will need pages(9 ctx) + headroom = 4 pages; after the
        # free list is dropped, A's retirement returns only 2 — B starves
        b = Request(rid=1, prompt=rng.integers(1, 64, 9).tolist(), max_new=6)
        srv.submit(a)
        srv.submit(b)
        srv.step()  # both admitted
        srv._preempt(srv.active.index(b))
        srv.free_pages.clear()  # the pool never recovers for B
        return srv, a, b

    def test_strict_starvation_attaches_partial_results(self, trained_tiny):
        cfg, params = trained_tiny
        srv, a, b = self._starve(params, cfg, strict=True)
        with pytest.raises(ServingError, match="starved") as ei:
            srv.run_until_drained()
        # A finished during the failing call and is recoverable from the
        # exception; B's pending diagnostics say what it was waiting for
        # finished now carries immutable RequestResult snapshots
        assert [r.rid for r in ei.value.finished] == [a.rid]
        assert ei.value.finished[0].ok and a.status == "ok"
        assert len(a.out) == 6
        (diag,) = ei.value.pending
        assert diag["rid"] == b.rid and diag["state"] == "spilled"
        assert diag["pages_needed"] > 0

    def test_non_strict_fails_pending_per_request(self, trained_tiny):
        cfg, params = trained_tiny
        srv, a, b = self._starve(params, cfg, strict=False)
        done = srv.run_until_drained()  # completes: degrade per request
        assert {a.rid, b.rid} == {r.rid for r in done}
        assert a.status == "ok" and len(a.out) == 6
        assert b.status == "failed" and "starved" in b.error
        assert srv.stats["failed"] == 1
        assert not srv.preempted and srv._spill_bytes == 0

    def test_max_steps_attaches_diagnostics(self, trained_tiny):
        cfg, params = trained_tiny
        srv = _tiny_server(params, cfg)
        r = Request(rid=0, prompt=[3, 4, 5], max_new=20)
        srv.submit(r)
        with pytest.raises(ServingError, match="max_steps") as ei:
            srv.run_until_drained(max_steps=3)
        (diag,) = ei.value.pending
        assert diag["rid"] == 0 and diag["state"] == "active"
        assert diag["out_tokens"] == len(r.out) > 0

    def test_legacy_starvation_match_still_works(self, trained_tiny):
        """ServingError subclasses RuntimeError and keeps the 'starved'
        message — existing callers catching RuntimeError keep working."""
        cfg, params = trained_tiny
        srv, a, b = self._starve(params, cfg, strict=True)
        with pytest.raises(RuntimeError, match="starved"):
            srv.run_until_drained()


class TestDeadlineFailedInterplay:
    def test_failed_row_stops_shielding(self, trained_tiny):
        """Satellite: a tight-deadline request that fails is retired out
        of the active set immediately — victim selection must never see
        (and shield) the dead row; the surviving no-deadline request is
        the only candidate and finishes token-identically."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(13)
        plan = FaultPlan(nan_logits=((2, 1),))
        srv = _tiny_server(params, cfg, pool_pages=6, steal_cooldown=0,
                           faults=plan)
        loose = Request(rid=0, prompt=rng.integers(1, 64, 5).tolist(),
                        max_new=10)
        tight = Request(rid=1, prompt=rng.integers(1, 64, 5).tolist(),
                        max_new=10, deadline_step=14)  # would be shielded
        srv.submit(loose)
        srv.submit(tight)
        srv.step()
        srv.step()  # step 2: tight (slot 1) is poisoned and quarantined
        assert tight.status == "failed" and tight.done
        assert srv.active[1] is None
        assert srv.active[srv._pick_victim()] is loose
        srv.run_until_drained()
        assert loose.status == "ok"
        assert loose.out == _solo_out(params, cfg, loose.prompt, 10)

    def test_truncated_status_and_failed_are_distinct(self, trained_tiny):
        cfg, params = trained_tiny
        rng = np.random.default_rng(4)
        srv = Server(params, cfg,
                     ServerConfig(slots=1, max_seq=16, kv_fmt=None,
                                  page_size=4, a_fmt=None))
        r = Request(rid=0, prompt=rng.integers(1, 64, 5).tolist(),
                    max_new=50)
        srv.submit(r)
        srv.run_until_drained()
        assert r.truncated and r.status == "truncated" and r.error is None
        assert srv.stats["failed"] == 0


class TestChaos:
    @pytest.mark.parametrize("kv_fmt", [None, "fp8_e4m3"])
    def test_chaos_survivors_token_identical(self, trained_tiny, kv_fmt):
        """Capstone: a steal-happy mixed workload under a seeded fault
        schedule (NaN rows + a corrupted spill + transient allocator
        exhaustion, audited every 2 steps). Exactly the NaN-hit requests
        fail; every survivor — including preempted/resumed and
        re-prefilled ones — finishes token-identical to the fault-free
        run; the auditor is clean at drain and the pool is whole."""
        cfg, params = trained_tiny

        def workload():
            rng = np.random.default_rng(17)
            return [Request(rid=i,
                            prompt=rng.integers(1, 64,
                                                rng.choice([3, 5, 9])).tolist(),
                            max_new=int(rng.choice([4, 8, 14])),
                            priority=int(rng.choice([0, 1])))
                    for i in range(10)]

        def serve(faults=None, audit_every=0):
            srv = Server(params, cfg,
                         ServerConfig(slots=3, max_seq=32, kv_fmt=kv_fmt,
                                      page_size=4, pool_pages=9, a_fmt=None,
                                      audit_every=audit_every,
                                      scheduler=SchedulerConfig(headroom_pages=1,
                                                                steal_cooldown=1)),
                         faults=faults)
            reqs = workload()
            for r in reqs:
                srv.submit(r)
            srv.run_until_drained(max_steps=800)
            return srv, reqs

        clean_srv, clean_reqs = serve()
        clean = {r.rid: list(r.out) for r in clean_reqs}
        assert clean_srv.stats["preemptions"] >= 1, \
            "chaos workload must exercise steals"
        assert all(r.status == "ok" for r in clean_reqs)

        plan = FaultPlan(seed=23, nan_logits=((10, 0), (15, 2)),
                         corrupt_spills=(0,), alloc_fail_ticks=(20,))
        srv, reqs = serve(faults=plan, audit_every=2)

        failed = {r.rid for r in reqs if r.status == "failed"}
        assert failed == {rid for (_, _, rid) in plan.nan_hits}
        assert len(failed) >= 1, "the NaN schedule must land"
        assert srv.stats["failed"] == len(failed)
        assert srv.stats["spill_integrity_failures"] >= 1
        assert plan.corrupted_rids and plan.blocked_ticks == [20]
        # unaffected requests: token-identical to the fault-free run
        for r in reqs:
            assert r.done
            if r.rid not in failed:
                assert r.status == "ok"
                assert list(r.out) == clean[r.rid], (r.rid, r.out)
        # drained engine: auditor clean, pool whole
        assert srv.audit()["violations"] == 0
        assert sorted(srv.free_pages + srv.reusable_pages) == \
            list(range(srv._n_pages))
        assert (srv.page_refs == 0).all()
