"""Direct unit tests for launch/sharding.py's serving-facing spec
builders: serve_rules expert placement, _cache_leaf_spec heuristics
(1-tuple batch axis, model-only mesh, kv-head and sequence dims),
serve_pool_pspecs / _pool_leaf_spec per paged-pool-leaf layouts, and
serve_param_shardings on a real 1-device mesh.

The spec builders read only ``mesh.shape``, so stub meshes stand in for
2- and 8-device topologies without simulated devices.
"""
import numpy as np
import pytest

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from conftest import tiny_lm_cfg

from repro import models
from repro.configs import get_smoke
from repro.launch.mesh import make_mesh
from repro.launch.sharding import (_cache_leaf_spec, _pool_leaf_spec,
                                   cache_pspecs, serve_param_shardings,
                                   serve_pool_pspecs, serve_rules)
from repro.models.params import DEFAULT_RULES


class _StubMesh:
    """Only ``.shape`` (an ordered axis->size dict) is read by the spec
    builders under test."""

    def __init__(self, **shape):
        self.shape = dict(shape)


MESH_1 = _StubMesh(data=1, model=1)
MESH_2 = _StubMesh(data=1, model=2)
MESH_8 = _StubMesh(data=4, model=2)
MESH_MODEL_ONLY = _StubMesh(model=2)


class TestServeRules:
    def test_dense_config_keeps_default_rules(self):
        for mesh in (MESH_1, MESH_2, MESH_8):
            assert serve_rules(tiny_lm_cfg(), mesh) == DEFAULT_RULES

    def test_moe_experts_ep_whole_mesh_when_divisible(self):
        cfg = get_smoke("olmoe-1b-7b")  # 8 experts
        assert serve_rules(cfg, MESH_2)["expert"] == ("data", "model")
        assert serve_rules(cfg, MESH_8)["expert"] == ("data", "model")
        pod = _StubMesh(pod=2, data=2, model=2)
        assert serve_rules(cfg, pod)["expert"] == ("pod", "data", "model")

    def test_moe_experts_fall_back_to_data_model_subset(self):
        cfg = get_smoke("olmoe-1b-7b")  # 8 % 16 != 0, 8 % (2*4) == 0
        mesh = _StubMesh(pod=2, data=2, model=4)
        assert serve_rules(cfg, mesh)["expert"] == ("data", "model")

    def test_moe_experts_replicate_when_indivisible(self):
        cfg = get_smoke("olmoe-1b-7b")  # 8 % 3 != 0
        mesh = _StubMesh(data=1, model=3)
        assert serve_rules(cfg, mesh)["expert"] == DEFAULT_RULES["expert"]


class TestCacheLeafSpec:
    def test_batch_dim_single_axis_is_bare(self):
        # one dp axis goes in bare ("data"), not as a 1-tuple (("data",)):
        # downstream introspection compares entries to axis names
        spec = _cache_leaf_spec((2, 4, 128, 2, 16), MESH_8)
        assert spec[1] == "data"
        assert not isinstance(spec[1], tuple)

    def test_kv_head_dim_5d_shards_model(self):
        spec = _cache_leaf_spec((2, 4, 128, 2, 16), MESH_8)
        assert spec == P(None, "data", None, "model", None)

    def test_model_only_mesh_leaves_batch_replicated(self):
        spec = _cache_leaf_spec((2, 4, 128, 2, 16), MESH_MODEL_ONLY)
        assert spec == P(None, None, None, "model", None)

    def test_indivisible_dims_replicate(self):
        # batch 3 % 4 != 0, kv-heads 3 % 2 != 0, dim2 127 % 2 != 0
        spec = _cache_leaf_spec((2, 3, 127, 3, 16), MESH_8)
        assert spec == P(None, None, None, None, None)

    def test_ssm_state_heads_heuristic(self):
        # 5D with an indivisible dim3: small-ish dim2 (<= 1024) is treated
        # as the ssm head dim and shards over model
        spec = _cache_leaf_spec((2, 4, 128, 3, 16), MESH_8)
        assert spec == P(None, "data", "model", None, None)

    def test_long_sequence_takes_remaining_axes(self):
        # 3D (L, B, S): batch takes data, seq >= 4096 takes model
        spec = _cache_leaf_spec((2, 4, 8192), MESH_8)
        assert spec == P(None, "data", "model")

    def test_cache_pspecs_maps_tree(self):
        class _S:  # shape-only stand-in (jax.ShapeDtypeStruct-alike)
            def __init__(self, shape):
                self.shape = shape

        tree = {"kv": _S((2, 4, 128, 2, 16)), "x": _S((2, 3, 7))}
        specs = cache_pspecs(tree, MESH_8)
        assert specs["kv"] == P(None, "data", None, "model", None)
        assert specs["x"] == P(None, None, None)


class TestPoolLeafSpec:
    """Paged-pool leaves (runtime/kv_cache.py layouts): GQA codes shard
    the KV-head dim, *_shift scales co-shard, everything else replicates.
    """

    GQA_POOL = {  # (L, P+1, page, KV, hd) + scale/marker leaves
        "k": np.zeros((2, 9, 8, 2, 16), np.uint8),
        "v": np.zeros((2, 9, 8, 2, 16), np.uint8),
        "k_shift": np.zeros((2, 9, 2), np.int32),
        "v_shift": np.zeros((2, 9, 2), np.int32),
        "k_smax": np.zeros((2, 9), np.float32),
        "v_smax": np.zeros((2, 9), np.float32),
    }
    MLA_POOL = {  # latent (L, P+1, page, r): no head axis
        "ckv": np.zeros((2, 9, 8, 16), np.uint8),
        "krope": np.zeros((2, 9, 8, 8), np.uint8),
        "ckv_shift": np.zeros((2, 9, 1), np.int32),
        "ckv_smax": np.zeros((2, 9), np.float32),
    }

    def test_gqa_codes_and_scales_co_shard(self):
        specs = serve_pool_pspecs(self.GQA_POOL, MESH_2)
        assert specs["k"] == P(None, None, None, "model", None)
        assert specs["v"] == P(None, None, None, "model", None)
        assert specs["k_shift"] == P(None, None, "model")
        assert specs["v_shift"] == P(None, None, "model")
        # one scalar per page, shared by every head shard: replicated
        assert not any(a is not None for a in specs["k_smax"])
        assert not any(a is not None for a in specs["v_smax"])

    def test_mla_latents_replicate(self):
        for mesh in (MESH_2, MESH_8):
            specs = serve_pool_pspecs(self.MLA_POOL, mesh)
            assert all(not any(a is not None for a in s)
                       for s in specs.values())

    def test_mesh_1_replicates_everything(self):
        specs = serve_pool_pspecs(self.GQA_POOL, MESH_1)
        assert all(not any(a is not None for a in s)
                   for s in specs.values())

    def test_indivisible_kv_heads_replicate(self):
        mesh = _StubMesh(data=1, model=4)  # 2 kv heads % 4 != 0
        specs = serve_pool_pspecs(self.GQA_POOL, mesh)
        assert not any(a is not None for a in specs["k"])
        assert not any(a is not None for a in specs["k_shift"])

    def test_zero_size_markers_replicate(self):
        pool = dict(self.GQA_POOL,
                    k_fz=np.zeros((2, 0, 8, 2, 16), np.uint8),
                    _fp4=np.zeros((0,), np.uint8))
        specs = serve_pool_pspecs(pool, MESH_2)
        assert not any(a is not None for a in specs["k_fz"])
        assert not any(a is not None for a in specs["_fp4"])

    def test_frozen_region_mirrors_active_layout(self):
        pool = {"k_fz": np.zeros((2, 4, 8, 2, 16), np.uint8),
                "k_fz_shift": np.zeros((2, 4, 2), np.int32)}
        specs = serve_pool_pspecs(pool, MESH_2)
        assert specs["k_fz"] == P(None, None, None, "model", None)
        assert specs["k_fz_shift"] == P(None, None, "model")


class TestServeParamShardings:
    def test_one_device_mesh_full_tree(self):
        """On a real 1-device mesh every leaf gets a NamedSharding and
        device_put round-trips the whole tree (the divisibility fallback
        can never fire at size 1)."""
        cfg = tiny_lm_cfg()
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        mesh = make_mesh((1, 1), ("data", "model"))
        sh = serve_param_shardings(cfg, params, mesh)
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(
            sh, is_leaf=lambda x: isinstance(x, NamedSharding))
        assert len(flat_p) == len(flat_s)
        assert all(isinstance(s, NamedSharding) for s in flat_s)
        placed = jax.device_put(params, sh)
        np.testing.assert_array_equal(
            np.asarray(jax.tree.leaves(placed)[0]), np.asarray(flat_p[0]))

    def test_moe_expert_stack_spec(self):
        """MoE expert stacks carry the serve_rules EP axes on dim0 (the
        spec is mesh-shape-arithmetic, so a 1-device mesh would replicate;
        assert on the generated pspec via a stub-shaped real mesh)."""
        cfg = get_smoke("olmoe-1b-7b")
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        mesh = make_mesh((1, 1), ("data", "model"))
        sh = serve_param_shardings(cfg, params, mesh)
        flat, _ = jax.tree_util.tree_flatten_with_path(
            sh, is_leaf=lambda x: isinstance(x, NamedSharding))
        hits = [s for path, s in flat if any("wu" in str(k) for k in path)]
        assert hits, "no MoE wu leaf found in the sharding tree"
        for s in hits:
            # def leaves stack layers at dim0: (L, E, ff, d) — the expert
            # dim (1) carries the serve_rules EP axes, layers replicate
            assert s.spec[0] is None
            assert s.spec[1] == ("data", "model")


def test_pool_leaf_spec_matches_engine_pools():
    """End-to-end: specs generated for a REAL Server pool (tiny GQA,
    fp8) pick the head dim the engine actually lays out."""
    from repro.runtime.serve import Request, Server, ServerConfig

    cfg = tiny_lm_cfg()
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    srv = Server(params, cfg,
                 ServerConfig(slots=1, max_seq=32, kv_fmt="fp8_e4m3",
                              page_size=8, a_fmt=None))
    pool = srv._unit((0, "kv"))
    specs = serve_pool_pspecs(pool, MESH_2)
    for name, leaf in pool.items():
        spec = specs[name]
        if leaf.ndim == 5 and leaf.size:
            assert leaf.shape[3] == cfg.n_kv_heads
            assert spec == P(None, None, None, "model", None), name
        sharded = [a for a in spec if a is not None]
        assert sharded in ([], ["model"]), name
