"""Sharded paged serving (ServerConfig.mesh = MeshPlan).

Single-device process: MeshPlan validation fail-fasts and the total == 1
bit-identity guarantee (no Mesh is ever built — the engine installs the
same module-level jitted step as mesh=None, so the path is identical by
construction, and we assert it).

Subprocess (XLA_FLAGS=--xla_force_host_platform_device_count=8, set
before the jax import — the reason these run out-of-process): greedy
decode token-identity of the sharded engine vs the single-device engine
for GQA (bf16 + fp8 pages), MLA and MoE tiny configs on simulated 2- and
8-device meshes, plus the chaos capstone (NaN quarantine + corrupted
spill CRC + transient alloc faults + steal/spill/resume + prefix cache +
audit_every) on a mesh.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from conftest import tiny_lm_cfg

from repro import models
from repro.runtime.serve import MeshPlan, Request, Server, ServerConfig
from repro.runtime import serve as serve_mod


def _run_script(tmp_path, name, body):
    script = tmp_path / name
    script.write_text(body)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, timeout=900, env=env, cwd="/root/repo")
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


class TestMeshPlan:
    def test_validation(self):
        with pytest.raises(ValueError):
            MeshPlan(data=0)
        with pytest.raises(ValueError):
            MeshPlan(model=-2)
        assert MeshPlan().total == 1
        assert MeshPlan(data=2, model=4).total == 8

    def test_build_needs_devices(self):
        # the test process runs on 1 CPU device
        if len(jax.devices()) > 1:
            pytest.skip("single-device assertion")
        with pytest.raises(ValueError, match="devices"):
            MeshPlan(model=2).build()

    def test_rejects_non_page_families(self):
        from repro.configs import get_smoke

        cfg = get_smoke("whisper-tiny")
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="page families"):
            Server(params, cfg,
                   ServerConfig(slots=1, max_seq=32, kv_fmt=None,
                                page_size=8, a_fmt=None,
                                mesh=MeshPlan(model=2)))

    def test_rejects_indivisible_heads(self):
        cfg = tiny_lm_cfg()  # 4 heads, 2 kv heads
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="n_heads"):
            Server(params, cfg,
                   ServerConfig(slots=1, max_seq=32, kv_fmt=None,
                                page_size=8, a_fmt=None,
                                mesh=MeshPlan(model=3)))
        with pytest.raises(ValueError, match="n_kv_heads"):
            Server(params, cfg,
                   ServerConfig(slots=1, max_seq=32, kv_fmt=None,
                                page_size=8, a_fmt=None,
                                mesh=MeshPlan(model=4)))

    def test_total_one_is_bit_identical_single_device_engine(
            self, trained_tiny):
        """MeshPlan with total == 1 must never build a Mesh: the server
        installs the shared module-level jitted step — the same executable
        object the mesh=None engine uses — so output is bit-identical by
        construction (asserted on the wiring AND the tokens)."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(0)
        prompts = [rng.integers(1, cfg.vocab_size, 7).tolist()
                   for _ in range(2)]

        def serve(mesh):
            srv = Server(params, cfg,
                         ServerConfig(slots=2, max_seq=64, kv_fmt="fp8_e4m3",
                                      page_size=8, a_fmt=None, mesh=mesh))
            assert srv._mesh is None
            assert srv._decode.func is serve_mod._decode_step_jit
            for i, p in enumerate(prompts):
                srv.submit(Request(rid=i, prompt=p, max_new=6))
            return {r.rid: list(r.tokens) for r in srv.run_until_drained()}

        assert serve(None) == serve(MeshPlan(data=1, model=1))


_COMMON = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax

    from repro.runtime.serve import MeshPlan, Request, Server, ServerConfig

    def serve_tokens(params, cfg, prompts, kv_fmt, mesh, max_new=6, **kw):
        kw.setdefault("slots", len(prompts))
        kw.setdefault("max_seq", 64)
        kw.setdefault("page_size", 8)
        srv = Server(params, cfg,
                     ServerConfig(kv_fmt=kv_fmt, a_fmt=None, mesh=mesh, **kw))
        for i, p in enumerate(prompts):
            srv.submit(Request(rid=i, prompt=p, max_new=max_new))
        done = srv.run_until_drained()
        return {int(r.rid): list(r.tokens) for r in done}, srv
""")


def _train_tiny_block():
    return textwrap.dedent("""
        import sys
        sys.path.insert(0, "tests")
        from conftest import tiny_lm_cfg
        from repro.data.pipeline import DataConfig
        from repro.optimizer import AdamWConfig
        from repro.runtime.train import TrainLoopConfig, train_loop

        cfg = tiny_lm_cfg()
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                        global_batch=8, seed=3)
        oc = AdamWConfig(lr=8e-3, warmup=20, total_steps=150)
        state, _ = train_loop(cfg, dc, oc,
                              TrainLoopConfig(steps=150, log_every=150))
        params = state.params
    """)


class TestShardedTokenIdentity:
    def test_gqa_bf16_and_fp8(self, tmp_path):
        """GQA pages (codes + co-sharded scales) on 2- and 8-device meshes:
        greedy decode must be token-identical to the single-device engine,
        and KV bytes must actually land on every model shard."""
        body = _COMMON + _train_tiny_block() + textwrap.dedent("""
            rng = np.random.default_rng(0)
            prompts = [rng.integers(1, cfg.vocab_size, n).tolist()
                       for n in (5, 9, 13)]
            ok = True
            residency_devices = 0
            for kv_fmt in (None, "fp8_e4m3"):
                ref, _ = serve_tokens(params, cfg, prompts, kv_fmt, None)
                for plan in (MeshPlan(data=1, model=2),
                             MeshPlan(data=4, model=2)):
                    got, srv = serve_tokens(params, cfg, prompts, kv_fmt, plan)
                    ok = ok and (got == ref)
                    per = srv.shard_residency()
                    residency_devices = max(residency_devices, len(per))
            print(json.dumps({"ok": ok,
                              "residency_devices": residency_devices}))
        """)
        rec = _run_script(tmp_path, "gqa_mesh.py", body)
        assert rec["ok"]
        assert rec["residency_devices"] >= 8

    def test_mla_latent_pages(self, tmp_path):
        """MLA latent pages replicate; absorbed q heads shard. Token
        identity vs single-device on 2- and 4-way model meshes."""
        body = _COMMON + textwrap.dedent("""
            from repro.configs import get_smoke
            from repro.data.pipeline import DataConfig
            from repro.optimizer import AdamWConfig
            from repro.runtime.train import TrainLoopConfig, train_loop

            cfg = get_smoke("minicpm3-4b")
            dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                            global_batch=8, seed=5)
            oc = AdamWConfig(lr=6e-3, warmup=20, total_steps=150)
            state, _ = train_loop(cfg, dc, oc,
                                  TrainLoopConfig(steps=150, log_every=150))
            params = state.params

            rng = np.random.default_rng(1)
            prompts = [rng.integers(1, cfg.vocab_size, n).tolist()
                       for n in (5, 11)]
            ok = True
            for kv_fmt in (None, "fp8_e4m3"):
                ref, _ = serve_tokens(params, cfg, prompts, kv_fmt, None)
                for plan in (MeshPlan(data=1, model=2),
                             MeshPlan(data=2, model=4)):
                    got, _ = serve_tokens(params, cfg, prompts, kv_fmt, plan)
                    ok = ok and (got == ref)
            print(json.dumps({"ok": ok}))
        """)
        assert _run_script(tmp_path, "mla_mesh.py", body)["ok"]

    def test_moe_expert_parallel_decode(self, tmp_path):
        """MoE decode routes expert-parallel (replicated einsum dispatch,
        shard_map'ed expert FFNs): token-identical to the single-device
        einsum path on 2- and 8-way EP."""
        body = _COMMON + textwrap.dedent("""
            from repro.configs import get_smoke
            from repro.data.pipeline import DataConfig
            from repro.optimizer import AdamWConfig
            from repro.runtime.train import TrainLoopConfig, train_loop

            cfg = get_smoke("olmoe-1b-7b")  # 8 experts, 4 heads / 4 kv
            dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                            global_batch=8, seed=9)
            oc = AdamWConfig(lr=6e-3, warmup=20, total_steps=150)
            state, _ = train_loop(cfg, dc, oc,
                                  TrainLoopConfig(steps=150, log_every=150))
            params = state.params

            rng = np.random.default_rng(2)
            prompts = [rng.integers(1, cfg.vocab_size, n).tolist()
                       for n in (5, 9)]
            ref, _ = serve_tokens(params, cfg, prompts, "fp8_e4m3", None)
            ok = True
            for plan in (MeshPlan(data=1, model=2),
                         MeshPlan(data=4, model=2)):
                got, _ = serve_tokens(params, cfg, prompts, "fp8_e4m3", plan)
                ok = ok and (got == ref)
            print(json.dumps({"ok": ok}))
        """)
        assert _run_script(tmp_path, "moe_mesh.py", body)["ok"]

    def test_chaos_suite_on_mesh(self, tmp_path):
        """The PR 6 chaos machinery runs unchanged on a mesh: NaN rows
        quarantined + scrubbed, a tampered spill fails its CRC (computed
        over the host-gathered payload) and re-prefills token-identically,
        transient alloc faults absorbed, audit_every clean throughout, on
        a steal-happy 2-way model mesh with the prefix cache on."""
        body = _COMMON + _train_tiny_block() + textwrap.dedent("""
            from repro.runtime.faults import FaultPlan

            rng = np.random.default_rng(11)
            prompts = [rng.integers(1, cfg.vocab_size, 5).tolist()
                       for _ in range(2)]
            plan = MeshPlan(data=1, model=2)
            # steal-happy pool (mirrors tests/test_faults.py): two 15-token
            # requests through 6 pages of 4 forces preempt + spill + resume
            kw = dict(max_new=10, max_seq=32, page_size=4, pool_pages=6,
                      audit_every=2)
            ref, _ = serve_tokens(params, cfg, prompts, "fp8_e4m3", plan,
                                  **kw)

            faults = FaultPlan(corrupt_spills=(0,), alloc_fail_ticks=(4,))
            srv = Server(params, cfg,
                         ServerConfig(slots=2, max_seq=32, kv_fmt="fp8_e4m3",
                                      page_size=4, a_fmt=None, mesh=plan,
                                      pool_pages=6, audit_every=2),
                         faults=faults)
            reqs = [Request(rid=i, prompt=p, max_new=10)
                    for i, p in enumerate(prompts)]
            for r in reqs:
                srv.submit(r)
            srv.run_until_drained()
            audit = srv.audit()
            print(json.dumps({
                "violations": audit["violations"],
                "all_ok": all(r.status == "ok" and len(r.out) == 10
                              for r in reqs),
                "token_identical": all(list(r.out) == ref[r.rid]
                                       for r in reqs),
                "preemptions": srv.stats["preemptions"],
                "crc_failures": srv.stats["spill_integrity_failures"],
                "blocked": list(faults.blocked_ticks),
            }))
        """)
        rec = _run_script(tmp_path, "chaos_mesh.py", body)
        assert rec["violations"] == 0
        assert rec["all_ok"] and rec["token_identical"]
        assert rec["preemptions"] >= 1
        assert rec["crc_failures"] == 1

    def test_nan_quarantine_and_scrub_on_mesh(self, tmp_path):
        """An injected NaN row on a mesh fails exactly that request; the
        scrub path re-pins the pools and batchmates finish
        token-identically."""
        body = _COMMON + _train_tiny_block() + textwrap.dedent("""
            from repro.runtime.faults import FaultPlan

            rng = np.random.default_rng(13)
            prompts = [rng.integers(1, cfg.vocab_size, 5).tolist()
                       for _ in range(2)]
            plan = MeshPlan(data=1, model=2)
            ref, _ = serve_tokens(params, cfg, prompts, "fp8_e4m3", plan,
                                  max_new=8)

            faults = FaultPlan(nan_logits=((2, 1),))
            srv = Server(params, cfg,
                         ServerConfig(slots=2, max_seq=64, kv_fmt="fp8_e4m3",
                                      page_size=8, a_fmt=None, mesh=plan),
                         faults=faults)
            reqs = [Request(rid=i, prompt=p, max_new=8)
                    for i, p in enumerate(prompts)]
            for r in reqs:
                srv.submit(r)
            srv.run_until_drained()
            nan_rids = {rid for _, _, rid in faults.nan_hits}
            print(json.dumps({
                "violations": srv.audit()["violations"],
                "injected": len(nan_rids),
                "failed_match": sorted(r.rid for r in reqs
                                       if r.status == "failed")
                                == sorted(nan_rids),
                "survivors_ok": all(list(r.out) == ref[r.rid] for r in reqs
                                    if r.rid not in nan_rids),
            }))
        """)
        rec = _run_script(tmp_path, "nan_mesh.py", body)
        assert rec["violations"] == 0
        assert rec["injected"] == 1
        assert rec["failed_match"] and rec["survivors_ok"]
