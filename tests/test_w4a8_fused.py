"""Fused single-pass W4A8 pipeline validation.

Three layers of assertions, mirroring tests/test_kernels.py:
  * kernel parity: the fused kernel (in-kernel FP8 act-quant + LoRC
    epilogue) must match the split path (act_quant_pallas +
    w4a8_matmul_pallas + jnp LoRC matmuls) and the jnp oracles, swept over
    shapes (incl. M/N not divisible by the block sizes), both FP4 formats,
    M2 pow-2 scales, and LoRC rank in {0, 4, 16};
  * batched variant parity (both orientations) vs the batched oracle;
  * integration: MoE and MLA forward passes with packed weights never
    densify via dequant_packed under the pallas backend, and agree with the
    ref backend.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import QuantPolicy
from repro.core.ptq import _pack_batched, pack_linear, quantize_tree
from repro.kernels import ops, ref
from repro.kernels.act_quant import act_quant_pallas
from repro.kernels.common import unpack_nibbles
from repro.kernels.w4a8_fused import (clamp_block, w4a8_fused_batched_pallas,
                                      w4a8_fused_matmul_pallas)
from repro.kernels.w4a8_matmul import w4a8_matmul_pallas
from repro.models.config import ArchConfig, MLASpec, MoESpec


@pytest.fixture(autouse=True)
def _ref_backend_after():
    yield
    ops.set_backend("ref")


def _pack(rng, n, k, group, w_fmt="fp4_e2m1", scale_mode="none", lorc_rank=0):
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32) * 0.05)
    policy = QuantPolicy(w_fmt=w_fmt, a_fmt="fp8_e4m3", group_size=group,
                         scale_mode=scale_mode, lorc_rank=lorc_rank)
    return pack_linear(w, policy)


def _split_path(x, w):
    """The pre-fusion three-pass pipeline, verbatim."""
    qv, sc = act_quant_pallas(x, w.a_fmt, interpret=True)
    xq = (qv * sc).astype(jnp.bfloat16)
    y = w4a8_matmul_pallas(xq, w.codes, w.scale, s_max=w.s_max, shifts=w.shifts,
                           w_fmt=w.w_fmt, group_size=w.group_size, interpret=True)
    if w.lorc_a is not None:
        y = y + (xq @ w.lorc_b.T.astype(jnp.bfloat16)).astype(jnp.bfloat16) @ \
            w.lorc_a.T.astype(jnp.bfloat16)
    return y


def _fused(x, w, bm=128, bn=128):
    return w4a8_fused_matmul_pallas(
        x, w.codes, w.scale, w.s_max, w.shifts, w.lorc_a, w.lorc_b,
        w_fmt=w.w_fmt, a_fmt=w.a_fmt, group_size=w.group_size,
        bm=bm, bn=bn, interpret=True)


# ---------------------------------------------------------------------------
# shared nibble unpack (copy-free bitwise construction)
# ---------------------------------------------------------------------------
def test_unpack_nibbles_matches_core():
    from repro.core.formats import unpack_nibbles as core_unpack

    rng = np.random.default_rng(0)
    packed = jnp.asarray(rng.integers(0, 256, size=(5, 16), dtype=np.uint8))
    np.testing.assert_array_equal(np.asarray(unpack_nibbles(packed)),
                                  np.asarray(core_unpack(packed)))
    # low nibble first
    np.testing.assert_array_equal(
        np.asarray(unpack_nibbles(jnp.asarray([[0xBA]], jnp.uint8))),
        np.asarray([[0x0A, 0x0B]], np.uint8))


# ---------------------------------------------------------------------------
# fused vs split parity sweep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mnk", [(8, 128, 256), (16, 256, 512), (128, 384, 256),
                                 (5, 96, 256), (3, 100, 512), (64, 128, 768)])
@pytest.mark.parametrize("scale_mode", ["none", "m2"])
def test_fused_matches_split_path(mnk, scale_mode):
    m, n, k = mnk
    rng = np.random.default_rng(m * n + k)
    w = _pack(rng, n, k, min(256, k), scale_mode=scale_mode)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(jnp.bfloat16)
    y_fused = _fused(x, w)
    y_split = _split_path(x, w)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_split),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("w_fmt", ["fp4_e2m1", "fp4_e3m0"])
@pytest.mark.parametrize("lorc_rank", [0, 4, 16])
def test_fused_formats_and_lorc_vs_oracle(w_fmt, lorc_rank):
    m, n, k, group = 16, 256, 512, 128
    rng = np.random.default_rng(lorc_rank + 29)
    w = _pack(rng, n, k, group, w_fmt=w_fmt, lorc_rank=lorc_rank)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(jnp.bfloat16)
    y_fused = _fused(x, w)
    y_ref = ref.w4a8_matmul_ref(x.astype(jnp.float32), w.codes, w.scale,
                                w.lorc_a, w.lorc_b, w_fmt=w_fmt,
                                a_fmt="fp8_e4m3", group_size=group)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_ref, np.float32),
                               rtol=2e-2, atol=2e-2)
    y_split = _split_path(x, w)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_split),
                               rtol=2e-2, atol=2e-2)


def test_fused_m2_lorc_odd_blocks():
    """Everything at once: M2 shifts + rank-16 LoRC + block sizes that do not
    divide M or N (the kernel clamps to divisors)."""
    m, n, k, group = 12, 160, 512, 256
    rng = np.random.default_rng(7)
    w = _pack(rng, n, k, group, scale_mode="m2", lorc_rank=16)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(jnp.bfloat16)
    y_ref = ref.w4a8_matmul_ref(x.astype(jnp.float32), w.codes, w.scale,
                                w.lorc_a, w.lorc_b, a_fmt="fp8_e4m3",
                                group_size=group)
    for bm, bn in [(128, 128), (8, 32), (3, 160)]:
        y = _fused(x, w, bm=bm, bn=bn)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref, np.float32),
                                   rtol=2e-2, atol=2e-2)


def test_clamp_block():
    assert clamp_block(384, 128) == 128
    assert clamp_block(100, 128) == 100
    assert clamp_block(96, 64) == 48
    assert clamp_block(5, 128) == 5
    assert clamp_block(7, 2) == 1


# ---------------------------------------------------------------------------
# batched variant: expert stacks + transposed orientation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scale_mode,lorc_rank", [("none", 0), ("m2", 8)])
@pytest.mark.parametrize("transpose", [False, True])
def test_batched_fused_matches_oracle(scale_mode, lorc_rank, transpose):
    e, n, k, m, group = 4, 128, 256, 24, 128
    rng = np.random.default_rng(e * n + lorc_rank)
    w = jnp.asarray(rng.normal(size=(e, n, k)).astype(np.float32) * 0.05)
    policy = QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", group_size=group,
                         scale_mode=scale_mode, lorc_rank=lorc_rank)
    pw = _pack_batched(w, policy)
    d = n if transpose else k
    x = jnp.asarray(rng.normal(size=(e, m, d)).astype(np.float32)).astype(jnp.bfloat16)
    for a_fmt in (None, "fp8_e4m3"):
        y = w4a8_fused_batched_pallas(
            x, pw.codes, pw.scale, pw.s_max, pw.shifts, pw.lorc_a, pw.lorc_b,
            w_fmt="fp4_e2m1", a_fmt=a_fmt, group_size=group,
            transpose_w=transpose, interpret=True)
        y_ref = ref.w4a8_batched_matmul_ref(
            x, pw.codes, pw.scale, pw.lorc_a, pw.lorc_b, w_fmt="fp4_e2m1",
            a_fmt=a_fmt, group_size=group, transpose_w=transpose)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# integration: MoE / MLA forward without dequant_packed on pallas backend
# ---------------------------------------------------------------------------
class _NoDequant:
    """Context that makes ops.dequant_packed explode if the hot path calls it."""

    def __enter__(self):
        self._orig = ops.dequant_packed

        def boom(w):  # pragma: no cover - only fires on regression
            raise AssertionError("dequant_packed called on the pallas hot path")

        ops.dequant_packed = boom
        return self

    def __exit__(self, *exc):
        ops.dequant_packed = self._orig
        return False


def _moe_cfg():
    return ArchConfig(name="moe-test", family="moe", n_layers=1, d_model=128,
                      n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=256,
                      mlp_gated=True, moe=MoESpec(n_experts=4, top_k=2, d_ff=128))


def test_moe_packed_pallas_no_dequant_matches_ref():
    from repro.models.moe import moe_layer, moe_params
    from repro.models.params import init_tree

    cfg = _moe_cfg()
    defs = moe_params(cfg)
    p = init_tree(defs, jax.random.PRNGKey(0))
    policy = QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", group_size=64,
                         scale_mode="m2", lorc_rank=4)
    pq = quantize_tree(p, defs, policy)
    assert pq["wu"].codes.ndim == 3  # expert stack stayed packed
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.bfloat16)

    ops.set_backend("ref")
    y_ref, _ = moe_layer(pq, x, cfg, group_size=32)
    ops.set_backend("pallas")
    with _NoDequant():
        y_pl, _ = moe_layer(pq, x, cfg, group_size=32)
    np.testing.assert_allclose(np.asarray(y_ref, np.float32),
                               np.asarray(y_pl, np.float32), rtol=5e-2, atol=5e-2)


def _mla_cfg():
    return ArchConfig(name="mla-test", family="dense", n_layers=1, d_model=128,
                      n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=256,
                      attn_kind="mla",
                      mla=MLASpec(q_lora_rank=0, kv_lora_rank=64, qk_nope_dim=32,
                                  qk_rope_dim=16, v_head_dim=32))


def test_mla_decode_packed_pallas_no_dequant_matches_ref():
    from repro.models.mla import init_mla_cache, mla_attention, mla_params
    from repro.models.params import init_tree

    cfg = _mla_cfg()
    defs = mla_params(cfg)
    p = init_tree(defs, jax.random.PRNGKey(0))
    policy = QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", group_size=64,
                         scale_mode="none", lorc_rank=4)
    pq = quantize_tree(p, defs, policy)
    assert isinstance(pq["wk_b"], type(pq["wv_b"]))  # both packed
    assert pq["wk_b"].codes is not None

    cache = init_mla_cache(cfg, 2, 32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, cfg.d_model), jnp.bfloat16)
    pos = jnp.full((2, 1), 5, jnp.int32)

    ops.set_backend("ref")
    y_ref, _ = mla_attention(pq, x, cfg, pos, kv_cache=cache, cache_index=5)
    ops.set_backend("pallas")
    with _NoDequant():
        y_pl, _ = mla_attention(pq, x, cfg, pos, kv_cache=cache, cache_index=5)
    np.testing.assert_allclose(np.asarray(y_ref, np.float32),
                               np.asarray(y_pl, np.float32), rtol=5e-2, atol=5e-2)


def test_mla_prefill_packed_pallas_no_dequant():
    """Materialized (prefill) form routes wk_b/wv_b through linear() ->
    fused 2-D kernel; nothing densifies either."""
    from repro.models.mla import mla_attention, mla_params
    from repro.models.params import init_tree

    cfg = _mla_cfg()
    defs = mla_params(cfg)
    p = init_tree(defs, jax.random.PRNGKey(0))
    policy = QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", group_size=64)
    pq = quantize_tree(p, defs, policy)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model), jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))

    ops.set_backend("ref")
    y_ref, _ = mla_attention(pq, x, cfg, pos)
    ops.set_backend("pallas")
    with _NoDequant():
        y_pl, _ = mla_attention(pq, x, cfg, pos)
    np.testing.assert_allclose(np.asarray(y_ref, np.float32),
                               np.asarray(y_pl, np.float32), rtol=5e-2, atol=5e-2)


def test_packed_head_view_roundtrip():
    from repro.models.layers import packed_head_view

    rng = np.random.default_rng(11)
    w = _pack(rng, 128, 64, 64, lorc_rank=4)  # e.g. (H*out, in) = (4*32, 64)
    v = packed_head_view(w, 4)
    assert v.codes.shape == (4, 32, 32)
    assert v.scale.shape == (4, 32, 1)
    assert v.lorc_a.shape == (4, 32, 4) and v.lorc_b.shape == (4, 4, 64)
    np.testing.assert_array_equal(np.asarray(v.codes.reshape(128, 32)),
                                  np.asarray(w.codes))


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------
def test_autotune_sweep_and_cache(tmp_path, monkeypatch):
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    monkeypatch.setattr(autotune, "_MEM", None)

    rng = np.random.default_rng(5)
    w = _pack(rng, 128, 256, 128)
    x = jnp.asarray(rng.normal(size=(16, 256)).astype(np.float32)).astype(jnp.bfloat16)

    sig = dict(batch=1, m=16, n=128, k=256, w_fmt="fp4_e2m1", a_fmt="fp8_e4m3",
               group_size=128, m2=False, lorc_rank=0)
    key = autotune.cache_key("fused", **sig)

    def build(bm, bn):
        return lambda: _fused(x, w, bm=bm, bn=bn)

    best = autotune.autotune_gemm(build, key, candidates=((8, 128), (16, 128)))
    assert best in ((8, 128), (16, 128))
    # persisted: a fresh in-process cache reloads the winner from disk
    monkeypatch.setattr(autotune, "_MEM", None)
    assert autotune.best_block_sizes("fused", **sig) == best
    # a different signature misses and falls back to the legal heuristic
    bm, bn = autotune.best_block_sizes("fused", **{**sig, "m": 999})
    assert bm >= 1 and bn >= 1


def test_ops_batched_backend_switch():
    """ops.w4a8_matmul_batched agrees between ref and pallas backends."""
    e, n, k, m, group = 3, 128, 256, 8, 128
    rng = np.random.default_rng(23)
    w = jnp.asarray(rng.normal(size=(e, n, k)).astype(np.float32) * 0.05)
    policy = QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", group_size=group,
                         scale_mode="m2", lorc_rank=4)
    pw = _pack_batched(w, policy)
    x = jnp.asarray(rng.normal(size=(e, m, k)).astype(np.float32)).astype(jnp.bfloat16)

    ops.set_backend("ref")
    y_ref = ops.w4a8_matmul_batched(x, pw)
    ops.set_backend("pallas")
    y_pl = ops.w4a8_matmul_batched(x, pw)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pl),
                               rtol=5e-2, atol=5e-2)
