"""Async streaming front-end: per-request token streams match the sync
engine, concurrent submissions batch in the running scheduler, terminal
events carry the outcome, and the stdlib SSE endpoint speaks the
OpenAI-completions shape. Tests drive asyncio via asyncio.run inside
sync defs (no pytest-asyncio in the container).
"""
import asyncio
import json

import numpy as np
import pytest

from repro.runtime.frontend import AsyncServer, serve_http
from repro.runtime.serve import (Request, SamplingParams, Server,
                                 ServerConfig, TokenEvent)


def _mk(params, cfg, **over):
    base = dict(slots=3, max_seq=64, page_size=8, a_fmt=None)
    base.update(over)
    return Server(params, cfg, ServerConfig(**base))


def _sync_reference(params, cfg, specs):
    srv = _mk(params, cfg)
    for rid, (prompt, max_new, sp) in enumerate(specs):
        srv.submit(Request(rid=rid, prompt=list(prompt), max_new=max_new,
                           sampling=sp))
    return {r.rid: r.tokens for r in srv.run_until_drained()}


async def _collect(front, rid, prompt, max_new, sp):
    toks, events = [], []
    async for ev in front.generate(list(prompt), max_new=max_new,
                                   sampling=sp, rid=rid):
        events.append(ev)
        if not ev.finished:
            toks.append(ev.token)
    return tuple(toks), events


class TestAsyncServer:
    def _specs(self, cfg):
        rng = np.random.default_rng(0)
        return [
            (rng.integers(1, cfg.vocab_size, 5).tolist(), 6,
             SamplingParams()),
            (rng.integers(1, cfg.vocab_size, 9).tolist(), 4,
             SamplingParams(temperature=0.8, top_k=12, seed=3)),
            (rng.integers(1, cfg.vocab_size, 3).tolist(), 5,
             SamplingParams(temperature=1.1, top_p=0.9, seed=9)),
        ]

    def test_concurrent_streams_match_sync_engine(self, trained_tiny):
        """Three concurrent generates (greedy + two sampled) stream the
        same tokens the batch run produces — the front-end only changes
        delivery, never the schedule's determinism."""
        cfg, params = trained_tiny
        specs = self._specs(cfg)
        want = _sync_reference(params, cfg, specs)

        async def main():
            front = AsyncServer(_mk(params, cfg))
            try:
                return await asyncio.gather(*[
                    _collect(front, rid, p, m, sp)
                    for rid, (p, m, sp) in enumerate(specs)])
            finally:
                await front.close()

        got = asyncio.run(main())
        for rid, (toks, events) in enumerate(got):
            assert toks == want[rid], rid
            assert all(isinstance(e, TokenEvent) for e in events)
            assert [e.index for e in events[:-1]] == list(range(len(toks)))
            term = events[-1]
            assert term.finished and term.token is None
            assert term.status == "ok"
            ts = [e.t for e in events]
            assert ts == sorted(ts)

    def test_late_submission_joins_running_batch(self, trained_tiny):
        """A generate() issued while the engine is mid-decode streams from
        the same pump: continuous batching, not run-to-completion."""
        cfg, params = trained_tiny
        specs = self._specs(cfg)[:2]
        want = _sync_reference(params, cfg, specs)

        async def main():
            front = AsyncServer(_mk(params, cfg))
            try:
                first = asyncio.ensure_future(
                    _collect(front, 0, specs[0][0], specs[0][1],
                             specs[0][2]))
                # let the pump take a few engine steps before joining
                for _ in range(8):
                    await asyncio.sleep(0)
                second = asyncio.ensure_future(
                    _collect(front, 1, specs[1][0], specs[1][1],
                             specs[1][2]))
                return await asyncio.gather(first, second)
            finally:
                await front.close()

        (toks0, _), (toks1, _) = asyncio.run(main())
        # determinism holds regardless of when each stream was opened
        assert toks0 == want[0] and toks1 == want[1]

    def test_result_available_after_stream(self, trained_tiny):
        cfg, params = trained_tiny

        async def main():
            front = AsyncServer(_mk(params, cfg))
            try:
                toks, _ = await _collect(front, 0, [1, 2, 3], 4,
                                         SamplingParams())
                return toks, front.result(0)
            finally:
                await front.close()

        toks, res = asyncio.run(main())
        assert res is not None and res.tokens == toks and res.ok
        assert res.ttft is not None and len(res.itl) == 3

    def test_submit_validation_raises_before_streaming(self, trained_tiny):
        cfg, params = trained_tiny

        async def main():
            front = AsyncServer(_mk(params, cfg))
            try:
                gen = front.generate([1, 2], max_new=2,
                                     sampling=SamplingParams(top_p=0.0))
                with pytest.raises(ValueError, match="top_p"):
                    await gen.__anext__()
            finally:
                await front.close()

        asyncio.run(main())


class TestHTTPEndpoint:
    async def _post(self, port, body):
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        data = json.dumps(body).encode()
        writer.write(b"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                     b"Content-Type: application/json\r\n"
                     + f"Content-Length: {len(data)}\r\n\r\n".encode()
                     + data)
        await writer.drain()
        raw = await reader.read()
        writer.close()
        return raw.decode()

    def test_sse_streams_two_concurrent_prefix_sharing_requests(
            self, trained_tiny):
        """Acceptance: two concurrent SSE requests sharing a prompt prefix
        stream token chunks from one engine; the shared prefix pages hit
        the content cache (prefix_hit_tokens > 0)."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(5)
        shared = rng.integers(1, cfg.vocab_size, 16).tolist()
        p1 = shared + [3, 4]
        p2 = shared + [9]

        async def main():
            engine = _mk(params, cfg, slots=2, page_size=8)
            front = AsyncServer(engine)
            srv = await serve_http(front, port=0)
            port = srv.sockets[0].getsockname()[1]
            try:
                r1, r2 = await asyncio.gather(
                    self._post(port, {"prompt": p1, "max_tokens": 5,
                                      "stream": True}),
                    self._post(port, {"prompt": p2, "max_tokens": 5,
                                      "temperature": 0.7, "seed": 4,
                                      "stream": True}))
                return r1, r2, engine.stats["prefix_hit_tokens"]
            finally:
                srv.close()
                await srv.wait_closed()
                await front.close()

        r1, r2, hit_tokens = asyncio.run(main())
        for raw in (r1, r2):
            assert "text/event-stream" in raw
            chunks = [json.loads(ln[6:]) for ln in raw.splitlines()
                      if ln.startswith("data: {")]
            toks = [c["choices"][0]["token"] for c in chunks
                    if c["choices"][0].get("token") is not None]
            assert len(toks) == 5
            assert chunks[-1]["choices"][0]["finish_reason"] == "stop"
            assert raw.rstrip().endswith("data: [DONE]")
        assert hit_tokens > 0  # the second prompt reused the shared pages

    def test_non_stream_matches_sync_tokens(self, trained_tiny):
        cfg, params = trained_tiny
        prompt = [1, 2, 3, 4]
        want = _sync_reference(
            params, cfg, [(prompt, 5, SamplingParams(temperature=0.9,
                                                     seed=2))])[0]

        async def main():
            front = AsyncServer(_mk(params, cfg))
            srv = await serve_http(front, port=0)
            port = srv.sockets[0].getsockname()[1]
            try:
                return await self._post(port, {
                    "prompt": prompt, "max_tokens": 5,
                    "temperature": 0.9, "seed": 2})
            finally:
                srv.close()
                await srv.wait_closed()
                await front.close()

        raw = asyncio.run(main())
        assert raw.startswith("HTTP/1.1 200")
        body = json.loads(raw.split("\r\n\r\n", 1)[1])
        assert tuple(body["choices"][0]["tokens"]) == want
        assert body["choices"][0]["finish_reason"] == "stop"
        assert body["usage"]["completion_tokens"] == 5

    def test_bad_request_is_400(self, trained_tiny):
        cfg, params = trained_tiny

        async def main():
            front = AsyncServer(_mk(params, cfg))
            srv = await serve_http(front, port=0)
            port = srv.sockets[0].getsockname()[1]
            try:
                bad_prompt = await self._post(port, {"prompt": "text"})
                bad_param = await self._post(
                    port, {"prompt": [1, 2], "top_p": 0.0})
                return bad_prompt, bad_param
            finally:
                srv.close()
                await srv.wait_closed()
                await front.close()

        bad_prompt, bad_param = asyncio.run(main())
        assert bad_prompt.startswith("HTTP/1.1 400")
        assert "token ids" in bad_prompt
        assert bad_param.startswith("HTTP/1.1 400")
        assert "top_p" in bad_param
