"""Token-budget continuous-batching scheduler: page-steal preemption,
streaming paged prefill, pool-accounting invariants.

Covers: preempted-then-resumed requests generate token-identical greedy
output vs an uncontended solo run (bf16 + fp8 pages — spills restore page
payloads bit-exactly); a seeded fuzz of admit/steal/resume sequences
asserting the pool never leaks or double-owns a page; streaming chunked
prefill parity against the monolithic-prefill + one-shot-splice path (GQA
and MLA); watermark admission hysteresis; the run_until_drained starvation
guard; and token-budget vs reserve-on-admit utilization under a long-tail
max_new workload."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import tiny_lm_cfg

from repro import models
from repro.runtime import kv_cache as kvc
from repro.runtime.serve import (Request, SchedulerConfig, Server,
                                 ServerConfig)


def _assert_pool_invariants(srv):
    """Refcounted pool accounting invariants, checked by the *production*
    auditor (``Server.audit()`` — promoted from this file's PR 5 fuzz
    helper): every page is exactly one of mapped / parked / free and the
    three sets partition the pool, the page table mirrors ownership, a
    slot's pages split into a leading shared-frozen run followed by
    exclusively-owned private pages, and slabs are exclusively owned.
    Running it here means every scheduler fuzz also exercises the auditor
    itself (a clean audit returns a summary instead of raising)."""
    summary = srv.audit()
    assert summary["violations"] == 0


def _drain_checked(srv, max_steps=500):
    """Step to drain, asserting pool invariants after every engine step."""
    done_before = len(srv.finished)
    for _ in range(max_steps):
        went = srv.step()
        _assert_pool_invariants(srv)
        if not went and not srv.queue and not srv.preempted:
            break
    else:
        raise AssertionError("drain did not converge")
    return srv.finished[done_before:]


class TestPreemptResume:
    @pytest.mark.parametrize("kv_fmt", [None, "fp8_e4m3"])
    def test_resume_token_identical(self, trained_tiny, kv_fmt):
        """A preempted-then-resumed request produces token-identical greedy
        output vs an uncontended run: the steal spills the page payload
        bit-exactly and the restored pages are logically identical."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(11)
        prompts = [rng.integers(1, cfg.vocab_size, size=5).tolist()
                   for _ in range(2)]
        # pool of 6 x 4-token pages; both requests charge 2 prompt pages + 1
        # headroom, then both grow past 12 tokens -> the later-admitted
        # request (rid 1) is the steal victim and must resume afterwards
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=32, kv_fmt=kv_fmt,
                                  page_size=4, pool_pages=6, a_fmt=None))
        reqs = [Request(rid=i, prompt=p, max_new=10)
                for i, p in enumerate(prompts)]
        for r in reqs:
            srv.submit(r)
        _drain_checked(srv)
        assert reqs[1].preemptions >= 1, "scenario must actually preempt"
        assert srv.stats["resumes"] >= 1
        for r in reqs:
            solo = Server(params, cfg,
                          ServerConfig(slots=1, max_seq=32, kv_fmt=kv_fmt,
                                       page_size=4, a_fmt=None))
            ref = Request(rid=99, prompt=list(r.prompt), max_new=10)
            solo.submit(ref)
            solo.run_until_drained()
            assert r.out == ref.out, (r.rid, r.out, ref.out)

    def test_priority_protects_high(self, trained_tiny):
        """Steal victims are picked lowest-priority-first, not by slot
        order: the high-priority request is never preempted."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(3)
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=32, kv_fmt="fp8_e4m3",
                                  page_size=4, pool_pages=6, a_fmt=None))
        lo = Request(rid=0, prompt=rng.integers(1, 64, 5).tolist(),
                     max_new=10, priority=0)
        hi = Request(rid=1, prompt=rng.integers(1, 64, 5).tolist(),
                     max_new=10, priority=1)
        srv.submit(lo)
        srv.submit(hi)  # admitted later -> default tie-break victim, but
        _drain_checked(srv)  # priority=1 shields it
        assert srv.stats["preemptions"] >= 1
        assert hi.preemptions == 0
        assert lo.preemptions >= 1


class TestFuzzAccounting:
    def test_admit_steal_resume_fuzz(self):
        """Seeded fuzz over staggered submissions on a tight pool: every
        step preserves pool-accounting invariants, every request finishes
        fully, and the drained pool is whole again."""
        cfg = tiny_lm_cfg()
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(7)
        srv = Server(params, cfg,
                     ServerConfig(slots=3, max_seq=32, kv_fmt="fp8_e4m3",
                                  page_size=4, pool_pages=9, a_fmt=None,
                                  scheduler=SchedulerConfig(headroom_pages=1, steal_cooldown=1)))
        # prompt lengths restricted to a few values: each distinct length is
        # a fresh prefill-chunk jit trace on CPU
        reqs = [Request(rid=i, prompt=rng.integers(1, 64, rng.choice([3, 5, 9])).tolist(),
                        max_new=int(rng.choice([2, 6, 14])),
                        priority=int(rng.choice([0, 1])))
                for i in range(12)]
        pending = list(reqs)
        for _ in range(4):  # staggered arrivals fuzz the admit sequence
            srv.submit(pending.pop(0))
        for step in range(600):
            went = srv.step()
            _assert_pool_invariants(srv)
            if pending and step % 3 == 0:
                srv.submit(pending.pop(0))
            if not went and not pending and not srv.queue and not srv.preempted:
                break
        assert len(srv.finished) == len(reqs)
        assert all(len(r.out) == r.max_new for r in reqs)
        assert not any(r.truncated for r in reqs)
        assert sorted(srv.free_pages + srv.reusable_pages) == \
            list(range(srv._n_pages))
        assert (srv.page_refs == 0).all()
        assert srv.stats["preemptions"] >= 1, "fuzz should exercise steals"
        assert srv.stats["preemptions"] == (srv.stats["resumes"]
                                            + srv.stats["resume_fallbacks"])


class TestStreamingPrefill:
    def test_gqa_stream_matches_splice(self):
        """Chunked in-graph prefill writes bit-identical pages to the
        monolithic prefill + one-shot splice, and the final-chunk logits
        match the full prefill's last-token logits."""
        cfg = tiny_lm_cfg()
        params = models.init_params(cfg, jax.random.PRNGKey(1))
        rng = np.random.default_rng(5)
        prompt = rng.integers(1, cfg.vocab_size, size=11).tolist()
        page, n = 4, 11
        for fmt in (None, "fp8_e4m3"):
            logits_ref, caches = models.prefill(
                params, cfg, {"tokens": jnp.asarray([prompt], jnp.int32)}, 12)
            pool_ref = kvc.init_gqa_pool(cfg.n_layers, 6, page, cfg.n_kv_heads,
                                         cfg.resolved_head_dim, fmt)
            pool_ref = kvc.splice_prefill(pool_ref, caches[0]["kv"],
                                          np.array([0, 1, 2]), n)
            pools = [{"kv": kvc.init_gqa_pool(cfg.n_layers, 6, page,
                                              cfg.n_kv_heads,
                                              cfg.resolved_head_dim, fmt)}]
            pos, ids = 0, [0, 1, 2]
            while pos < n:
                take = min(2 * page, n - pos)
                w = kvc.pages_needed(pos + take, page)
                table = np.zeros((1, w), np.int32)
                table[0] = ids[:w]
                st = kvc.PagedState(jnp.asarray(table),
                                    jnp.asarray([pos], jnp.int32))
                logits, pools = models.decode_step(
                    params, cfg, jnp.asarray([prompt[pos: pos + take]], jnp.int32),
                    pools, st)
                pos += take
            st = kvc.PagedState(jnp.asarray([[0, 1, 2]], jnp.int32),
                                jnp.asarray([n], jnp.int32))
            for name in ("k", "v"):
                a = kvc.gather_pages({k: v[0] for k, v in pool_ref.items()},
                                     name, st)
                b = kvc.gather_pages(
                    {k: v[0] for k, v in pools[0]["kv"].items()}, name, st)
                np.testing.assert_allclose(np.asarray(b)[0, :n],
                                           np.asarray(a)[0, :n],
                                           rtol=5e-2, atol=5e-2)
            lr, ls = np.asarray(logits_ref[0]), np.asarray(logits[0])
            tol = 0.08 if fmt else 1e-3
            assert np.abs(lr - ls).max() / (np.abs(lr).max() + 1e-9) < tol

    def test_mla_stream_matches_splice(self):
        """The MLA absorbed chunk path: streamed latent pages match the
        materialized-prefill splice, and final-chunk logits agree."""
        from repro.configs import get_smoke

        cfg = get_smoke("minicpm3-4b")
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(2)
        prompt = rng.integers(1, cfg.vocab_size, size=13).tolist()
        page, n = 8, 13
        for fmt in (None, "fp8_e4m3"):
            logits_ref, caches = models.prefill(
                params, cfg, {"tokens": jnp.asarray([prompt], jnp.int32)}, 16)
            pool_ref = kvc.init_mla_pool(cfg.n_layers, 4, page,
                                         cfg.mla.kv_lora_rank,
                                         cfg.mla.qk_rope_dim, fmt)
            pool_ref = kvc.splice_prefill(pool_ref, caches[0]["kv"],
                                          np.array([0, 1]), n)
            pools = [{"kv": kvc.init_mla_pool(cfg.n_layers, 4, page,
                                              cfg.mla.kv_lora_rank,
                                              cfg.mla.qk_rope_dim, fmt)}]
            pos, ids = 0, [0, 1]
            while pos < n:
                take = min(page, n - pos)
                w = kvc.pages_needed(pos + take, page)
                table = np.zeros((1, w), np.int32)
                table[0] = ids[:w]
                st = kvc.PagedState(jnp.asarray(table),
                                    jnp.asarray([pos], jnp.int32))
                logits, pools = models.decode_step(
                    params, cfg, jnp.asarray([prompt[pos: pos + take]], jnp.int32),
                    pools, st)
                pos += take
            st = kvc.PagedState(jnp.asarray([[0, 1]], jnp.int32),
                                jnp.asarray([n], jnp.int32))
            for name in ("ckv", "krope"):
                a = kvc.gather_pages({k: v[0] for k, v in pool_ref.items()},
                                     name, st)
                b = kvc.gather_pages({k: v[0] for k, v in pools[0]["kv"].items()},
                                     name, st)
                np.testing.assert_allclose(np.asarray(b)[0, :n],
                                           np.asarray(a)[0, :n],
                                           rtol=8e-2, atol=8e-2)
            lr, ls = np.asarray(logits_ref[0]), np.asarray(logits[0])
            tol = 0.12 if fmt else 3e-2  # absorbed-vs-materialized reorder
            assert np.abs(lr - ls).max() / (np.abs(lr).max() + 1e-9) < tol


class TestSpillBudget:
    @pytest.mark.parametrize("kv_fmt", [None, "fp8_e4m3"])
    def test_eviction_requeues_and_finishes_token_identical(self, trained_tiny,
                                                            kv_fmt):
        """ROADMAP (b): with a zero spill budget every preemption evicts —
        the spilled bytes are dropped and the request re-queues for a full
        context re-prefill — yet every request still finishes with the same
        greedy tokens as an uncontended solo run (no host OOM path left)."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(11)
        prompts = [rng.integers(1, cfg.vocab_size, size=5).tolist()
                   for _ in range(2)]
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=32, kv_fmt=kv_fmt,
                                  page_size=4, pool_pages=6, a_fmt=None,
                                  scheduler=SchedulerConfig(spill_budget_bytes=0)))
        reqs = [Request(rid=i, prompt=list(p), max_new=10)
                for i, p in enumerate(prompts)]
        for r in reqs:
            srv.submit(r)
        _drain_checked(srv)
        assert srv.stats["preemptions"] >= 1
        assert srv.stats["spill_evictions"] >= 1
        assert srv._spill_bytes == 0 and not srv.preempted
        assert any(r.evictions >= 1 for r in reqs)
        for r in reqs:
            solo = Server(params, cfg,
                          ServerConfig(slots=1, max_seq=32, kv_fmt=kv_fmt,
                                       page_size=4, a_fmt=None))
            ref = Request(rid=99, prompt=list(r.prompt), max_new=10)
            solo.submit(ref)
            solo.run_until_drained()
            assert r.out == ref.out, (r.rid, r.out, ref.out)

    def test_budget_keeps_newest_spills_resident(self, trained_tiny):
        """A budget large enough for one spill keeps the newest resident
        (oldest-first eviction) instead of dropping everything."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(3)
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=32, kv_fmt="fp8_e4m3",
                                  page_size=4, pool_pages=6, a_fmt=None,
                                  scheduler=SchedulerConfig(spill_budget_bytes=1 << 30)))
        reqs = [Request(rid=i, prompt=rng.integers(1, 64, 5).tolist(),
                        max_new=10) for i in range(2)]
        for r in reqs:
            srv.submit(r)
        _drain_checked(srv)
        assert srv.stats["preemptions"] >= 1
        assert srv.stats["spill_evictions"] == 0  # generous budget: no evicts
        assert srv.stats["resumes"] == srv.stats["preemptions"]


class TestPrefillBucketing:
    def test_trace_count_logarithmic(self, trained_tiny):
        """ROADMAP (a): a high-entropy prompt-length workload must compile
        O(log max_seq) prefill programs, not one per distinct length. The
        engine records each distinct (padded_chunk, table_width) signature
        it feeds the jitted step — with a fixed config that set IS the
        trace-cache key set."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(0)
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=64, kv_fmt="fp8_e4m3",
                                  page_size=4, a_fmt=None,
                                  scheduler=SchedulerConfig(prefill_chunk_pages=4)))
        lengths = list(range(3, 28))  # 25 distinct prompt lengths
        rng.shuffle(lengths)
        for i, n in enumerate(lengths):
            srv.submit(Request(rid=i, prompt=rng.integers(1, 64, n).tolist(),
                               max_new=2))
        done = srv.run_until_drained()
        assert len(done) == len(lengths)
        assert srv._bucket_prefill
        # pow2 chunk lengths x pow2 table widths: far below the 25 distinct
        # (chunk_len, width) pairs the unbucketed engine would compile
        assert len(srv.prefill_traces) <= 8, sorted(srv.prefill_traces)
        for padded, w in srv.prefill_traces:
            assert padded & (padded - 1) == 0, (padded, w)
            assert w & (w - 1) == 0, (padded, w)

    def test_bucketed_prefill_token_identical(self, trained_tiny):
        """Pad+mask must not change numerics: bucketed streaming prefill
        reproduces the legacy contiguous-cache greedy output exactly on
        bf16 pages for lengths exercising every pad path."""
        from test_kv_cache import _greedy_legacy

        cfg, params = trained_tiny
        rng = np.random.default_rng(9)
        for n in (1, 3, 8, 13, 17, 30):
            prompt = rng.integers(1, cfg.vocab_size, size=n).tolist()
            srv = Server(params, cfg,
                         ServerConfig(slots=1, max_seq=64, kv_fmt=None,
                                      page_size=4, a_fmt=None,
                                      scheduler=SchedulerConfig(prefill_chunk_pages=2)))
            r = Request(rid=0, prompt=list(prompt), max_new=5)
            srv.submit(r)
            srv.run_until_drained()
            assert r.out == _greedy_legacy(params, cfg, prompt, 5), n


class TestStateSlabs:
    def test_slab_fuzz_steal_resume_bit_identity(self):
        """Seeded fuzz on a slab-starved xLSTM pool (3 slots, 2 slabs):
        priority arrivals force slab steals; every spill/resume restores
        the recurrent state bit-exactly, so each request's output equals
        its uncontended solo run — even at random init, where any
        numerical drift would flip tokens."""
        from repro.configs import get_smoke

        cfg = get_smoke("xlstm-125m")
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(13)
        srv = Server(params, cfg,
                     ServerConfig(slots=3, max_seq=32, a_fmt=None,
                                  pool_slabs=2, page_size=4,
                                  scheduler=SchedulerConfig(prefill_chunk_pages=1,
                                                            steal_cooldown=1)))
        # recurrent state cannot skip prefill chunks: no prefix cache
        assert srv._prefix is None
        reqs = [Request(rid=i,
                        prompt=rng.integers(1, cfg.vocab_size,
                                            rng.choice([3, 5, 9])).tolist(),
                        max_new=int(rng.choice([2, 5, 8])),
                        priority=int(i % 3))
                for i in range(9)]
        pending = list(reqs)
        for _ in range(2):
            srv.submit(pending.pop(0))
        for step in range(500):
            went = srv.step()
            # slab accounting invariants: owned + free partition the pool
            owned = [s for s in srv.slot_slab if s >= 0]
            assert len(owned) == len(set(owned))
            assert sorted(owned + srv.free_slabs) == list(range(srv._n_slabs))
            if pending and step % 2 == 0:
                srv.submit(pending.pop(0))
            if (not went and not pending and not srv.queue
                    and not srv.preempted):
                break
        assert len(srv.finished) == len(reqs)
        assert srv.stats["preemptions"] >= 1, "fuzz should exercise steals"
        assert sorted(srv.free_slabs) == list(range(srv._n_slabs))
        for r in reqs:
            solo = Server(params, cfg,
                          ServerConfig(slots=1, max_seq=32, a_fmt=None,
                                       page_size=4,
                                       scheduler=SchedulerConfig(prefill_chunk_pages=1)))
            ref = Request(rid=99, prompt=list(r.prompt), max_new=r.max_new)
            solo.submit(ref)
            solo.run_until_drained()
            assert r.out == ref.out, (r.rid, r.out, ref.out)

    def test_priority_slab_steal_under_zero_budget_loses_nothing(self):
        """Regression: a slab steal fires *mid-admission* (the arriving
        request outbids the runner), and with a zero spill budget the
        victim is immediately evicted into the queue. Budget enforcement
        must not run inside the preempt (it would mutate the queue under
        _admit_one's feet and pop the wrong request) — both requests must
        finish, token-identical to solo runs."""
        from repro.configs import get_smoke

        cfg = get_smoke("xlstm-125m")
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=32, a_fmt=None,
                                  pool_slabs=1, page_size=4,
                                  scheduler=SchedulerConfig(prefill_chunk_pages=1,
                                                            spill_budget_bytes=0,
                                                            steal_cooldown=0)))
        lo = Request(rid=0, prompt=rng.integers(1, 64, 5).tolist(),
                     max_new=8, priority=0)
        hi = Request(rid=1, prompt=rng.integers(1, 64, 5).tolist(),
                     max_new=4, priority=1)
        srv.submit(lo)
        srv.step()  # lo running on the only slab
        srv.submit(hi)  # outbids lo -> slab steal mid-admission + eviction
        srv.run_until_drained()
        assert lo.done and hi.done
        assert srv.stats["preemptions"] >= 1
        assert srv.stats["spill_evictions"] >= 1 and lo.evictions >= 1
        for r in (lo, hi):
            solo = Server(params, cfg,
                          ServerConfig(slots=1, max_seq=32, a_fmt=None,
                                       page_size=4,
                                       scheduler=SchedulerConfig(prefill_chunk_pages=1)))
            ref = Request(rid=99, prompt=list(r.prompt), max_new=r.max_new)
            solo.submit(ref)
            solo.run_until_drained()
            assert r.out == ref.out, (r.rid, r.out, ref.out)

    def test_reserve_scheduler_never_slab_steals(self):
        """Regression: reserve-on-admit's contract is that admitted work is
        never preempted — a slab-starved high-priority arrival must wait
        for retirement, not steal (the stolen victim could never resume:
        spill readmission is a token-budget mechanism)."""
        from repro.configs import get_smoke

        cfg = get_smoke("xlstm-125m")
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(5)
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=32, a_fmt=None,
                                  pool_slabs=1, page_size=4,
                                  scheduler=SchedulerConfig(prefill_chunk_pages=1,
                                                            policy="reserve",
                                                            steal_cooldown=0)))
        lo = Request(rid=0, prompt=rng.integers(1, 64, 5).tolist(),
                     max_new=6, priority=0)
        hi = Request(rid=1, prompt=rng.integers(1, 64, 5).tolist(),
                     max_new=4, priority=1)
        srv.submit(lo)
        srv.step()
        srv.submit(hi)  # must wait for lo's slab, not steal it
        srv.run_until_drained()
        assert lo.done and hi.done
        assert srv.stats["preemptions"] == 0

    def test_xlstm_stream_matches_full_prefill(self):
        """Chunked streaming prefill carries the (c, n, m) + conv state
        across chunks exactly: the final-chunk logits argmax matches the
        one-shot legacy prefill (this is what the _mlstm_chunked carry fix
        makes true for T > chunk)."""
        from repro.configs import get_smoke

        cfg = get_smoke("xlstm-125m")
        params = models.init_params(cfg, jax.random.PRNGKey(1))
        rng = np.random.default_rng(2)
        prompt = rng.integers(1, cfg.vocab_size, size=13).tolist()
        logits_ref, _ = models.prefill(
            params, cfg, {"tokens": jnp.asarray([prompt], jnp.int32)}, 32)
        srv = Server(params, cfg,
                     ServerConfig(slots=1, max_seq=32, a_fmt=None, page_size=4,
                                  scheduler=SchedulerConfig(prefill_chunk_pages=1)))
        r = Request(rid=0, prompt=list(prompt), max_new=1)
        srv.submit(r)
        srv.run_until_drained()
        assert r.out[0] == int(jnp.argmax(logits_ref[0]))


class TestPrefixCacheServing:
    """Refcounted pages + the content-addressed shared-prefix cache: the
    acceptance scenario (shared system prompt -> zero prefill compute for
    the shared pages, token-identical output), refcount/parking lifecycle,
    and the resume fallback when cached pages were reclaimed."""

    @pytest.mark.parametrize("kv_fmt", [None, "fp8_e4m3"])
    def test_shared_prefix_token_identical_and_saves_prefill(
            self, trained_tiny, kv_fmt):
        """Acceptance: 8 requests sharing a 64-token system prompt produce
        greedy outputs token-identical to the cold-cache engine, while
        ``stats['prefill_tokens']`` drops by exactly the shared pages'
        token count (every request after the first maps all 8 pages)."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(21)
        page = 8
        shared = rng.integers(1, cfg.vocab_size, size=64).tolist()
        prompts = [shared + rng.integers(1, cfg.vocab_size,
                                         size=int(t)).tolist()
                   for t in rng.integers(3, 7, size=8)]
        total = sum(len(p) for p in prompts)
        outs = {}
        for warm in (False, True):
            srv = Server(params, cfg,
                         ServerConfig(slots=4, max_seq=96, kv_fmt=kv_fmt,
                                      page_size=page, a_fmt=None,
                                      prefix_cache=warm))
            reqs = [Request(rid=i, prompt=list(p), max_new=6)
                    for i, p in enumerate(prompts)]
            for r in reqs:
                srv.submit(r)
            done = _drain_checked(srv)
            assert len(done) == len(reqs)
            outs[warm] = {r.rid: r.out for r in reqs}
            if warm:
                saved = 7 * 64  # everyone but the first hits all 8 pages
                assert srv.stats["prefix_hit_tokens"] == saved
                assert srv.stats["prefix_hit_pages"] == 7 * 8
                assert srv.stats["prefill_tokens"] == total - saved
                assert srv.prefix_hit_rate() > 0.7
            else:
                assert srv.stats["prefix_hit_tokens"] == 0
                assert srv.stats["prefill_tokens"] == total
        assert outs[False] == outs[True]

    def test_refcounts_and_parking_lifecycle(self, trained_tiny):
        """Two concurrent requests map the same physical prefix pages
        (refcount 2); retirement parks them at refcount 0 in the reusable
        LRU instead of the free list; a third request re-acquires them.

        Pinned to the alternating engine: the step-1 assertions require
        admission to prefill+register the first request before the second
        walks the prefix index; the mixed engine streams that prefill
        across steps (its refcount/parking coverage is the steal-happy
        identity fuzz in tests/test_mixed_engine.py)."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(3)
        page = 8
        shared = rng.integers(1, cfg.vocab_size, size=2 * page).tolist()
        tail = rng.integers(1, cfg.vocab_size, size=3).tolist()
        mk = lambda rid: Request(rid=rid, prompt=shared + tail, max_new=3)
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=64, kv_fmt="fp8_e4m3",
                                  page_size=page, a_fmt=None,
                                  scheduler=SchedulerConfig(
                                      engine="alternating")))
        a, b = mk(0), mk(1)
        srv.submit(a)
        srv.submit(b)
        srv.step()  # admits both: a prefills + registers, b maps the hits
        assert srv.slot_shared == [2, 2]
        assert srv.slot_pages[0][:2] == srv.slot_pages[1][:2]
        assert (srv.page_refs[srv.slot_pages[0][:2]] == 2).all()
        _assert_pool_invariants(srv)
        _drain_checked(srv)
        # retired: the prefix pages parked, not freed — still reusable
        assert len(srv.reusable_pages) == 2
        assert (srv.page_refs == 0).all()
        c = mk(2)
        srv.submit(c)
        _drain_checked(srv)
        assert srv.stats["prefix_hit_tokens"] == 2 * (2 * page)
        assert a.out == b.out == c.out

    def test_preempt_keeps_prefix_resident_and_resumes(self, trained_tiny):
        """Preemption spills only the private tail: the shared prefix
        pages stay in the index (parked if nobody else maps them) and are
        re-resolved on resume — the spill's host bytes exclude them."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(5)
        prompt = rng.integers(1, cfg.vocab_size, size=9).tolist()
        srv = Server(params, cfg,
                     ServerConfig(slots=1, max_seq=32, kv_fmt="fp8_e4m3",
                                  page_size=4, a_fmt=None))
        r = Request(rid=0, prompt=list(prompt), max_new=8)
        srv.submit(r)
        srv.step()
        assert srv.slot_shared[0] == 2  # 8 of 9 prompt tokens registered
        srv._preempt(0)
        sp = srv.preempted[0]
        assert sp.shared_pages == 2
        assert len(srv.reusable_pages) == 2  # prefix parked, not spilled
        _assert_pool_invariants(srv)
        srv.run_until_drained()
        assert srv.stats["resumes"] == 1 and r.done
        solo = Server(params, cfg,
                      ServerConfig(slots=1, max_seq=32, kv_fmt="fp8_e4m3",
                                   page_size=4, a_fmt=None))
        ref = Request(rid=99, prompt=list(prompt), max_new=8)
        solo.submit(ref)
        solo.run_until_drained()
        assert r.out == ref.out

    def test_resume_falls_back_to_reprefill_after_reclaim(self, trained_tiny):
        """If a spill's shared prefix pages were reclaimed while it sat on
        host, resume cannot restore behind them: the engine falls back to
        an eviction-style tail re-prefill and still finishes
        token-identically."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(7)
        prompt = rng.integers(1, cfg.vocab_size, size=9).tolist()
        srv = Server(params, cfg,
                     ServerConfig(slots=1, max_seq=32, kv_fmt="fp8_e4m3",
                                  page_size=4, a_fmt=None))
        r = Request(rid=0, prompt=list(prompt), max_new=8)
        srv.submit(r)
        srv.step()
        srv._preempt(0)
        # simulate pool pressure reclaiming the parked prefix while spilled
        while srv._prefix.n_reusable:
            srv.free_pages.append(srv._prefix.reclaim())
        _assert_pool_invariants(srv)
        srv.run_until_drained()
        assert srv.stats["resume_fallbacks"] == 1
        assert srv.stats["spill_evictions"] == 1 and r.evictions == 1
        assert r.done and len(r.out) == 8
        solo = Server(params, cfg,
                      ServerConfig(slots=1, max_seq=32, kv_fmt="fp8_e4m3",
                                   page_size=4, a_fmt=None))
        ref = Request(rid=99, prompt=list(prompt), max_new=8)
        solo.submit(ref)
        solo.run_until_drained()
        assert r.out == ref.out

    def test_admission_charges_parked_hits_against_free_pool(self,
                                                             trained_tiny):
        """Regression: a prefix hit sitting parked in the reusable LRU
        counts in ``_free_capacity()`` but is consumed by the very
        admission that maps it — the feasibility check must charge parked
        hits against the free pool, or ``_alloc`` runs the allocator dry
        mid-admission (assert crash) instead of deferring the request."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(19)
        page = 4
        prompt_a = rng.integers(1, cfg.vocab_size, size=13).tolist()
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=32, kv_fmt="fp8_e4m3",
                                  page_size=page, pool_pages=8, a_fmt=None))
        a = Request(rid=0, prompt=list(prompt_a), max_new=2)
        srv.submit(a)
        _drain_checked(srv)
        assert len(srv.reusable_pages) == 3  # A's full prompt pages parked
        # D fills the entire free list with private pages and keeps running
        d = Request(rid=1, prompt=rng.integers(1, 64, 13).tolist(),
                    max_new=18)
        srv.submit(d)
        srv.step()
        assert len(srv.free_pages) == 0 and len(srv.reusable_pages) == 3
        # E hits all 3 parked pages, but its private tail pages cannot be
        # allocated with free = 0: it must wait for D, not crash
        e = Request(rid=2, prompt=list(prompt_a), max_new=8)
        srv.submit(e)
        done = _drain_checked(srv)
        assert e in done and len(e.out) == 8 and d in done
        solo = Server(params, cfg,
                      ServerConfig(slots=1, max_seq=32, kv_fmt="fp8_e4m3",
                                   page_size=page, a_fmt=None))
        ref = Request(rid=99, prompt=list(prompt_a), max_new=8)
        solo.submit(ref)
        solo.run_until_drained()
        assert e.out == ref.out

    def test_mla_shared_prefix_token_identical(self, trained_tiny_mla):
        """The prefix cache is payload-agnostic: MLA latent pages (ckv +
        krope leaves under one page id) share across requests exactly like
        GQA K/V pages."""
        cfg, params = trained_tiny_mla
        rng = np.random.default_rng(8)
        page = 8
        shared = rng.integers(1, cfg.vocab_size, size=2 * page).tolist()
        prompts = [shared + rng.integers(1, cfg.vocab_size,
                                         size=t).tolist()
                   for t in (3, 5, 4)]
        outs = {}
        for warm in (False, True):
            srv = Server(params, cfg,
                         ServerConfig(slots=3, max_seq=64, kv_fmt="fp8_e4m3",
                                      page_size=page, a_fmt=None,
                                      prefix_cache=warm,
                                      scheduler=SchedulerConfig(prefill_chunk_pages=1)))
            reqs = [Request(rid=i, prompt=list(p), max_new=5)
                    for i, p in enumerate(prompts)]
            for r in reqs:
                srv.submit(r)
            done = _drain_checked(srv)
            assert len(done) == len(reqs)
            outs[warm] = {r.rid: r.out for r in reqs}
            if warm:
                assert srv.stats["prefix_hit_tokens"] == 2 * (2 * page)
        assert outs[False] == outs[True]

    @pytest.mark.parametrize("kv_fmt", [None, "fp8_e4m3"])
    def test_shared_prefix_fuzz_refcounted(self, trained_tiny, kv_fmt):
        """Satellite fuzz: staggered shared-prefix arrivals on a tight,
        steal-happy pool — every step preserves the refcount invariants
        (no leaked pages, no double-free, refcounts == table occupancy),
        every request finishes, and each output is token-identical to a
        cold-cache solo run."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(17)
        page = 4
        shared = rng.integers(1, cfg.vocab_size, size=2 * page).tolist()
        srv = Server(params, cfg,
                     ServerConfig(slots=3, max_seq=32, kv_fmt=kv_fmt,
                                  page_size=page, pool_pages=8, a_fmt=None,
                                  scheduler=SchedulerConfig(prefill_chunk_pages=1,
                                                            headroom_pages=1,
                                                            steal_cooldown=1)))
        reqs = [Request(rid=i,
                        prompt=shared + rng.integers(
                            1, cfg.vocab_size, int(rng.choice([1, 3, 6]))
                        ).tolist(),
                        max_new=int(rng.choice([5, 9, 12])),
                        priority=int(rng.choice([0, 1])))
                for i in range(10)]
        pending = list(reqs)
        for _ in range(3):
            srv.submit(pending.pop(0))
        for step in range(600):
            went = srv.step()
            _assert_pool_invariants(srv)
            if pending and step % 3 == 0:
                srv.submit(pending.pop(0))
            if (not went and not pending and not srv.queue
                    and not srv.preempted):
                break
        assert len(srv.finished) == len(reqs)
        assert srv.stats["preemptions"] >= 1, "fuzz should exercise steals"
        assert srv.stats["prefix_hit_tokens"] > 0, "fuzz should share pages"
        assert sorted(srv.free_pages + srv.reusable_pages) == \
            list(range(srv._n_pages))
        assert (srv.page_refs == 0).all()
        for r in reqs:
            solo = Server(params, cfg,
                          ServerConfig(slots=1, max_seq=32, kv_fmt=kv_fmt,
                                       page_size=page, a_fmt=None,
                                       prefix_cache=False,
                                       scheduler=SchedulerConfig(prefill_chunk_pages=1)))
            ref = Request(rid=99, prompt=list(r.prompt), max_new=r.max_new)
            solo.submit(ref)
            solo.run_until_drained()
            assert r.out == ref.out, (r.rid, r.out, ref.out)


class TestWaitLineFairness:
    def test_evicted_spill_keeps_global_wait_order(self, trained_tiny):
        """Regression (satellite 1): budget eviction must not push the
        *oldest* waiter behind every younger spill. A is preempted before
        B; the budget evicts A (oldest-first) into the queue; readmission
        must still pick A first — one global (since, seq) wait line, not
        'preempted strictly before fresh'."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(11)
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=32, kv_fmt="fp8_e4m3",
                                  page_size=4, pool_pages=12, a_fmt=None))
        a = Request(rid=0, prompt=rng.integers(1, 64, 5).tolist(), max_new=10)
        b = Request(rid=1, prompt=rng.integers(1, 64, 5).tolist(), max_new=10)
        srv.submit(a)
        srv.submit(b)
        srv.step()  # both active
        srv._preempt(srv.active.index(a))  # A spilled first (older key)
        srv._step_no += 1  # a step passes without readmitting A ...
        srv._preempt(srv.active.index(b))  # ... then B is spilled too
        assert a.since < b.since
        # budget fits exactly one spill: the oldest (A) is evicted
        srv.spill_budget_bytes = max(sp.nbytes for sp in srv.preempted)
        srv._enforce_spill_budget()
        assert a.evictions == 1 and a in srv.queue
        assert [sp.req for sp in srv.preempted] == [b]
        # readmission picks A (evicted but oldest), not the younger spill
        assert srv._admit_one(0)
        assert srv.active[0] is a
        srv.run_until_drained()
        for r in (a, b):
            solo = Server(params, cfg,
                          ServerConfig(slots=1, max_seq=32, kv_fmt="fp8_e4m3",
                                       page_size=4, a_fmt=None))
            ref = Request(rid=99, prompt=list(r.prompt), max_new=10)
            solo.submit(ref)
            solo.run_until_drained()
            assert r.out == ref.out, (r.rid, r.out, ref.out)


class TestDeadlineVictim:
    def test_deadline_shields_tight_slo(self, trained_tiny):
        """ROADMAP (c): within a priority class the victim is the request
        with the *most* deadline slack. The older no-deadline request —
        which the old newest-first tie-break would have protected — yields
        to the newer request racing a tight deadline."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(13)
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=32, kv_fmt="fp8_e4m3",
                                  page_size=4, pool_pages=6, a_fmt=None,
                                  scheduler=SchedulerConfig(steal_cooldown=0)))
        loose = Request(rid=0, prompt=rng.integers(1, 64, 5).tolist(),
                        max_new=10)  # no deadline: infinite slack
        tight = Request(rid=1, prompt=rng.integers(1, 64, 5).tolist(),
                        max_new=10, deadline_step=14)
        srv.submit(loose)
        srv.submit(tight)
        _drain_checked(srv)
        assert srv.stats["preemptions"] >= 1
        assert tight.preemptions == 0, "tight-SLO request must be shielded"
        assert loose.preemptions >= 1

    def test_pick_victim_orders_by_slack_then_age(self, trained_tiny):
        cfg, params = trained_tiny
        rng = np.random.default_rng(2)
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=32, kv_fmt="fp8_e4m3",
                                  page_size=4, a_fmt=None,
                                  scheduler=SchedulerConfig(steal_cooldown=0)))
        r0 = Request(rid=0, prompt=rng.integers(1, 64, 3).tolist(),
                     max_new=8, deadline_step=100)  # plenty of slack
        r1 = Request(rid=1, prompt=rng.integers(1, 64, 3).tolist(),
                     max_new=8, deadline_step=10)  # about to miss
        srv.submit(r0)
        srv.submit(r1)
        srv.step()
        victim = srv._pick_victim()
        assert srv.active[victim] is r0
        # priority stays the primary key: a lower-priority tight request
        # still yields before a higher-priority slack-rich one
        r0.priority, r1.priority = 1, 0
        assert srv.active[srv._pick_victim()] is r1
        # a deadline already missed stops shielding: the dead-SLO request
        # yields before a peer whose deadline is still meetable
        r0.priority = 0
        r0.deadline_step, r1.deadline_step = 100, 1  # r1's SLO is lost
        assert srv._slack(r1) == float("inf")
        assert srv.active[srv._pick_victim()] is r1


class TestTruncation:
    def test_max_seq_boundary_sets_truncated(self, trained_tiny):
        """Satellite: a request cut off at the max_seq - 1 context bound
        retires with fewer than max_new tokens and must say so."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(4)
        srv = Server(params, cfg,
                     ServerConfig(slots=1, max_seq=16, kv_fmt=None,
                                  page_size=4, a_fmt=None))
        r = Request(rid=0, prompt=rng.integers(1, 64, 5).tolist(), max_new=50)
        srv.submit(r)
        srv.run_until_drained()
        assert r.done and r.truncated
        assert len(r.out) == (16 - 1) - 5 + 1  # context bound, not budget
        assert srv.stats["truncated"] == 1
        ok = Request(rid=1, prompt=rng.integers(1, 64, 3).tolist(), max_new=4)
        srv.submit(ok)
        srv.run_until_drained()
        assert ok.done and not ok.truncated and len(ok.out) == 4
        assert srv.stats["truncated"] == 1


class TestPrefillTableContract:
    def test_overhang_pages_nulled(self, trained_tiny):
        """Satellite: a bucketed chunk's zeroed pad writes overhang the
        last data page; ``append_prefill_chunk``'s contract is that those
        table positions point at the *null page* — never at allocated
        headroom (a correctness hazard once pages are shared read-only).

        Pinned to the alternating engine: the spy reads the serial chunk
        loop's ``state.page_table``; the mixed step nests the same
        _chunk_plan table under ``state.prefill`` (covered by
        tests/test_mixed_engine.py)."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(6)
        srv = Server(params, cfg,
                     ServerConfig(slots=1, max_seq=64, kv_fmt="fp8_e4m3",
                                  page_size=4, a_fmt=None,
                                  scheduler=SchedulerConfig(
                                      prefill_chunk_pages=4,
                                      engine="alternating")))
        tables = []
        orig = srv._decode

        def spy(params, pools, toks, state, poison, samp):
            tables.append(np.asarray(state.page_table))
            return orig(params, pools, toks, state, poison, samp)

        srv._decode = spy
        r = Request(rid=0, prompt=rng.integers(1, 64, 9).tolist(), max_new=2)
        srv.submit(r)
        srv.run_until_drained()
        # chunk: take=9 padded to 16 -> table width 4, but only 3 pages
        # hold data; the pad-overhang fourth slot must be the null page
        # (the old table mapped the allocated headroom page there)
        pre = tables[0]
        assert pre.shape[1] == 4
        assert pre[0, 3] == srv._null_page
        assert (pre[0, :3] != srv._null_page).all()
        assert len(srv.slot_pages[0]) == 0 and r.done  # sanity: retired


class TestSchedulerPolicy:
    def test_low_watermark_defers_fresh_admission(self):
        """With active work running, fresh admission must leave
        ``low_watermark`` pages free (growth slack) — the second request
        waits even though its charge would physically fit."""
        cfg = tiny_lm_cfg()
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=32, kv_fmt="fp8_e4m3",
                                  page_size=4, pool_pages=4, a_fmt=None,
                                  scheduler=SchedulerConfig(headroom_pages=1, low_watermark=2)))
        a = Request(rid=0, prompt=rng.integers(1, 64, 3).tolist(), max_new=3)
        b = Request(rid=1, prompt=rng.integers(1, 64, 3).tolist(), max_new=3)
        srv.submit(a)
        srv.submit(b)
        srv.step()  # admits a (pool idle: watermark bypassed), defers b
        assert srv.active.count(None) == 1 and b in srv.queue
        _drain_checked(srv)
        assert a.done and b.done

    def test_overlong_prompt_fails_fast(self):
        """A prompt with no decode room left must be rejected at submit,
        not crash mid-prefill after pages were already allocated."""
        cfg = tiny_lm_cfg()
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        srv = Server(params, cfg,
                     ServerConfig(slots=1, max_seq=32, kv_fmt="fp8_e4m3",
                                  page_size=4, a_fmt=None))
        with pytest.raises(ValueError, match="max_seq"):
            srv.submit(Request(rid=0, prompt=list(range(1, 41)), max_new=4))

    def test_starvation_guard_raises(self):
        """If the pool is fully stolen and nothing can ever be readmitted,
        run_until_drained raises a clear error instead of spinning (or
        silently dropping preempted-but-never-resumed requests)."""
        cfg = tiny_lm_cfg()
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        srv = Server(params, cfg,
                     ServerConfig(slots=1, max_seq=32, kv_fmt="fp8_e4m3",
                                  page_size=4, pool_pages=4, a_fmt=None))
        r = Request(rid=0, prompt=rng.integers(1, 64, 5).tolist(), max_new=8)
        srv.submit(r)
        srv.step()
        srv._preempt(0)  # steal the only runner's pages ...
        srv.free_pages.clear()  # ... and simulate the pool never recovering
        with pytest.raises(RuntimeError, match="starved"):
            srv.run_until_drained()

    def test_token_budget_beats_reserve_under_long_tail(self, trained_tiny):
        """The acceptance claim at test scale: under a long-tail max_new
        workload on a tight pool, token-budget admission achieves strictly
        higher slot utilization (and fewer engine steps for the same
        tokens) than reserve-on-admit, with identical greedy outputs."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(17)
        prompts = [rng.integers(1, cfg.vocab_size, size=int(m)).tolist()
                   for m in rng.integers(3, 8, size=8)]
        outs, stats = {}, {}
        for sched in ("reserve", "token_budget"):
            srv = Server(params, cfg,
                         ServerConfig(slots=4, max_seq=48, kv_fmt="fp8_e4m3",
                                      page_size=4, pool_pages=12, a_fmt=None,
                                      scheduler=SchedulerConfig(policy=sched)))
            reqs = [Request(rid=i, prompt=list(p),
                            max_new=24 if i % 4 == 0 else 4)
                    for i, p in enumerate(prompts)]
            for r in reqs:
                srv.submit(r)
            done = srv.run_until_drained()
            assert len(done) == len(reqs)
            outs[sched] = {r.rid: r.out for r in reqs}
            stats[sched] = (srv.utilization(), srv.stats["steps"])
        assert outs["reserve"] == outs["token_budget"]
        (u_rv, steps_rv), (u_tb, steps_tb) = stats["reserve"], stats["token_budget"]
        assert u_tb > u_rv, (u_tb, u_rv)
        assert steps_tb <= steps_rv, (steps_tb, steps_rv)
