"""Mixed-precision KV cache: packed FP4 frozen pages behind a CachePolicy.

Covers: the CachePolicy validation surface and the ``kv_fmt`` deprecation
shim (warn on legacy, TypeError on both, token-identity of the shim path),
freeze-point transcode roundtrips (FP8 page -> packed FP4 frozen row ->
dual-region gather), the FP4 tolerance tier of the decode kernels
(kernel == oracle bit-parity in interpret mode, both vs the exact
unquantized softmax across a (heads, head_dim, page, seq) sweep, GQA and
MLA), the no-write-path-targets-FP4 invariants (append assert, pool
constructor validation, ``assert_unfrozen`` frozen-base extension), and
the served end-to-end path: a warm shared-prefix workload under
``frozen_fmt='fp4_e2m1'`` stays within bounded greedy-token divergence of
the all-FP8 run while frozen residency lands at about half the
bytes-per-token, and a steal-happy policy-transition fuzz
(freeze -> transcode -> park -> reclaim -> steal) holds ``Server.audit()``
clean at every step with spill/resume of mixed-format tables
token-identical to uncontended runs."""
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.common import PAGE_FORMAT_NAMES, page_format
from repro.runtime import kv_cache as kvc
from repro.runtime.serve import (CachePolicy, Request, SchedulerConfig,
                                 Server, ServerConfig)

# FP4 E2M1 has 1 mantissa bit on a 8-point positive grid: per-page M2
# scales leave ~2-4x the FP8 quantization error through a softmax.
FP4_TOL = 0.35
FP8_TOL = 0.12


class TestCachePolicy:
    def test_defaults_are_homogeneous_bf16(self):
        p = CachePolicy()
        assert not p.mixed
        assert p.active.fmt is None and p.frozen.fmt is None

    def test_frozen_inherits_active(self):
        p = CachePolicy(active_fmt="fp8_e4m3")
        assert not p.mixed
        assert p.frozen.name == "fp8_e4m3" and p.cross.name == "fp8_e4m3"

    def test_mixed_pair(self):
        p = CachePolicy(active_fmt="fp8_e4m3", frozen_fmt="fp4_e2m1")
        assert p.mixed
        assert p.frozen.packed and p.frozen.bytes_per_code == 0.5

    def test_active_must_be_writable(self):
        with pytest.raises(ValueError, match="writable"):
            CachePolicy(active_fmt="fp4_e2m1")

    def test_only_supported_transcode_pair(self):
        with pytest.raises(ValueError, match="transcode"):
            CachePolicy(active_fmt=None, frozen_fmt="fp8_e4m3")

    def test_cross_fp4_needs_quantized_engine(self):
        with pytest.raises(ValueError, match="cross_fmt"):
            CachePolicy(cross_fmt="fp4_e2m1")

    def test_unknown_format_fails_fast_with_allowed_set(self):
        with pytest.raises(ValueError) as ei:
            CachePolicy(active_fmt="fp8_e4m3", frozen_fmt="fp3_e1m1")
        msg = str(ei.value)
        for name in PAGE_FORMAT_NAMES:
            assert name in msg, msg

    def test_frozen_pages_floor(self):
        with pytest.raises(ValueError, match="frozen_pages"):
            CachePolicy(active_fmt="fp8_e4m3", frozen_fmt="fp4_e2m1",
                        frozen_pages=0)


class TestKvFmtShim:
    def test_legacy_kv_fmt_warns_and_normalizes(self):
        with pytest.warns(DeprecationWarning, match="kv_fmt"):
            legacy = ServerConfig(kv_fmt="fp8_e4m3")
        assert legacy == ServerConfig(cache=CachePolicy(active_fmt="fp8_e4m3"))
        assert legacy.kv_fmt is None  # normalized into the policy

    def test_both_is_a_type_error(self):
        with pytest.raises(TypeError, match="not both"):
            ServerConfig(kv_fmt="fp8_e4m3",
                         cache=CachePolicy(active_fmt="fp8_e4m3"))

    def test_cache_alone_does_not_warn(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            ServerConfig(cache=CachePolicy(active_fmt="fp8_e4m3"))

    def test_shim_token_identical_to_policy(self, trained_tiny):
        cfg, params = trained_tiny
        prompts = [[3, 7, 11, 2, 9], [5, 5, 1]]

        def serve(sc):
            srv = Server(params, cfg, sc)
            for i, p in enumerate(prompts):
                srv.submit(Request(rid=i, prompt=list(p), max_new=6))
            return [list(r.tokens) for r in srv.run_until_drained()]

        with pytest.warns(DeprecationWarning):
            legacy = serve(ServerConfig(slots=2, max_seq=64, page_size=8,
                                        kv_fmt="fp8_e4m3", a_fmt=None))
        modern = serve(ServerConfig(
            slots=2, max_seq=64, page_size=8, a_fmt=None,
            cache=CachePolicy(active_fmt="fp8_e4m3")))
        assert legacy == modern

    def test_legacy_flat_kwargs_route_through_policy(self, trained_tiny):
        cfg, params = trained_tiny
        with pytest.warns(DeprecationWarning):
            srv = Server(params, cfg, slots=2, max_seq=64,
                         kv_fmt="fp8_e4m3", a_fmt=None)
        assert srv.policy == CachePolicy(active_fmt="fp8_e4m3")
        assert srv.kv_fmt == "fp8_e4m3"  # read-side alias survives


def _mixed_gqa_layer(rng, kv, hd, page, pp, lens, freeze):
    """A 1-layer mixed pool: FP8 splice, then the first ``freeze`` pages of
    each row transcoded into the packed FP4 frozen region with the table
    rewritten to frozen logical ids (base = P+1)."""
    b = len(lens)
    n_pages = b * pp
    pool = kvc.init_gqa_pool(1, n_pages, page, kv, hd, "fp8_e4m3",
                             frozen_fmt="fp4_e2m1", n_frozen=n_pages)
    pt = np.zeros((b, pp), np.int32)
    kc = rng.normal(size=(b, 1, 1, pp * page, kv, hd)).astype(np.float32)
    vc = rng.normal(size=(b, 1, 1, pp * page, kv, hd)).astype(np.float32)
    base = n_pages + 1
    fidx = 0
    for r in range(b):
        npg = kvc.pages_needed(int(lens[r]), page)
        ids = np.arange(r * pp, r * pp + npg, dtype=np.int32)
        pt[r, :npg] = ids
        pool = kvc.splice_prefill(
            pool, {"k": jnp.asarray(kc[r]), "v": jnp.asarray(vc[r])}, ids,
            int(lens[r]))
        for i in range(min(freeze, npg)):
            pool = kvc.transcode_page(pool, int(ids[i]), fidx)
            pt[r, i] = base + fidx
            fidx += 1
    layer = {k: v[0] for k, v in pool.items()}
    return layer, pt, kc[:, 0, 0], vc[:, 0, 0]


def _attn_exact(q, k, v, kv_len, g):
    h, hd = q.shape
    o = np.zeros((h, v.shape[-1]), np.float32)
    for hi in range(h):
        sc = q[hi] @ k[:kv_len, hi // g].T / np.sqrt(hd)
        p = np.exp(sc - sc.max())
        p /= p.sum()
        o[hi] = p @ v[:kv_len, hi // g]
    return o


class TestTranscode:
    def test_roundtrip_within_fp4_grid_error(self):
        rng = np.random.default_rng(0)
        lens = np.array([24, 9], np.int32)
        layer, pt, kc, _ = _mixed_gqa_layer(rng, 2, 16, 8, 3, lens, freeze=2)
        state = kvc.PagedState(jnp.asarray(pt), jnp.asarray(lens))
        got = np.asarray(kvc.gather_pages(layer, "k", state))
        for r, n in enumerate(lens):
            ref = kc[r, :n]
            err = np.abs(got[r, :n] - ref).max() / np.abs(ref).max()
            assert err < FP4_TOL, (r, err)

    def test_frozen_store_is_half_width(self):
        pool = kvc.init_gqa_pool(2, 8, 8, 2, 16, "fp8_e4m3",
                                 frozen_fmt="fp4_e2m1", n_frozen=4)
        assert pool["k"].shape[-1] == 16
        assert pool["k_fz"].shape[-1] == 8
        assert pool["k_fz"].shape[1] == 5  # n_frozen + clamped-gather dummy

    def test_odd_head_dim_packs_with_pad_nibble(self):
        rng = np.random.default_rng(1)
        lens = np.array([10], np.int32)
        layer, pt, kc, _ = _mixed_gqa_layer(rng, 2, 9, 8, 2, lens, freeze=1)
        assert layer["k_fz"].shape[-1] == 5  # ceil(9 / 2)
        state = kvc.PagedState(jnp.asarray(pt), jnp.asarray(lens))
        got = np.asarray(kvc.gather_pages(layer, "k", state))[0, :10]
        err = np.abs(got - kc[0, :10]).max() / np.abs(kc[0, :10]).max()
        assert err < FP4_TOL, err

    def test_mixed_pool_page_bytes_ratio(self):
        # the bench-gated density ratio: a frozen page must cost <= 0.55x
        # an active FP8 page across the stacked layers
        pool = kvc.init_gqa_pool(4, 32, 8, 2, 64, "fp8_e4m3",
                                 frozen_fmt="fp4_e2m1", n_frozen=16)
        ratio = kvc.page_bytes(pool, frozen=True) / kvc.page_bytes(pool)
        assert ratio <= 0.55, ratio
        # active-class accounting must not be polluted by the frozen store
        plain = kvc.init_gqa_pool(4, 32, 8, 2, 64, "fp8_e4m3")
        assert kvc.pool_bytes_per_token(pool) == \
            kvc.pool_bytes_per_token(plain)


class TestNoWritePathTargetsFP4:
    def test_append_asserts_on_packed_pages(self):
        pool = kvc.init_gqa_pool(1, 4, 8, 2, 16, "fp4_e2m1")
        layer = {k: v[0] for k, v in pool.items()}
        state = kvc.PagedState(jnp.asarray([[0, 1]], jnp.int32),
                               jnp.asarray([3], jnp.int32))
        new = {"k": jnp.ones((1, 1, 2, 16)), "v": jnp.ones((1, 1, 2, 16))}
        with pytest.raises(AssertionError, match="packed FP4"):
            kvc.append_paged(layer, new, state)

    def test_mixed_pool_requires_fp8_active(self):
        with pytest.raises(ValueError, match="fp4_e2m1"):
            kvc.init_gqa_pool(1, 4, 8, 2, 16, None,
                              frozen_fmt="fp4_e2m1", n_frozen=2)

    def test_assert_unfrozen_rejects_frozen_region_ids(self):
        c = kvc.PrefixCache(page_size=8)
        c.insert([1] * 8, [3])
        c.assert_unfrozen([0, 1, 2])  # private active pages pass
        with pytest.raises(AssertionError):
            c.assert_unfrozen([3])  # registered
        with pytest.raises(AssertionError, match="frozen"):
            # any id at/above the frozen base is read-only by construction,
            # registered or not — a write plan holding one is corruption
            c.assert_unfrozen([17], frozen_base=17)
        c.assert_unfrozen([16], frozen_base=17)


class TestFP4DecodeParity:
    @pytest.mark.parametrize("kv,g,hd,page,pp", [
        (2, 2, 16, 8, 3),   # GQA smoke shape
        (1, 4, 32, 16, 2),  # MQA-ish, bigger head
        (4, 1, 8, 4, 4),    # MHA, many small pages
        (2, 3, 64, 32, 2),  # odd group size (padding path)
    ])
    def test_gqa_kernel_matches_oracle_mixed(self, kv, g, hd, page, pp):
        """Mixed-format tables (frozen FP4 prefix + FP8 tail): the pallas
        kernel (interpret mode) bit-matches the jnp oracle, and both stay
        within the FP4 tolerance tier of the exact unquantized softmax."""
        rng = np.random.default_rng(hash((kv, g, hd, page)) % 2**31)
        h = kv * g
        lens = np.array([page * pp - 3, max(1, page // 2)], np.int32)
        q = jnp.asarray(rng.normal(size=(2, h, hd)).astype(np.float32))
        layer, pt, kc, vc = _mixed_gqa_layer(rng, kv, hd, page, pp, lens,
                                             freeze=pp - 1)
        assert (pt >= pt.shape[0] * pp + 1).any()  # frozen ids in play
        prev = ops.get_backend()
        try:
            ops.set_backend("ref")
            o_ref = ops.paged_decode_attn(q, layer, jnp.asarray(pt),
                                          jnp.asarray(lens))
            ops.set_backend("pallas")
            o_pal = ops.paged_decode_attn(q, layer, jnp.asarray(pt),
                                          jnp.asarray(lens))
        finally:
            ops.set_backend(prev)
        np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                                   rtol=2e-5, atol=2e-5)
        for r in range(2):
            exact = _attn_exact(np.asarray(q[r]), kc[r], vc[r],
                                int(lens[r]), g)
            err = np.abs(np.asarray(o_ref[r]) - exact).max()
            assert err / (np.abs(exact).max() + 1e-9) < FP4_TOL, (r, err)

    @pytest.mark.parametrize("h,r,dr,page,pp", [
        (4, 16, 8, 8, 3),
        (8, 32, 16, 16, 2),
        (3, 16, 8, 4, 4),   # odd head count (bq padding path)
    ])
    def test_mla_kernel_matches_oracle_mixed(self, h, r, dr, page, pp):
        rng = np.random.default_rng(hash((h, r, dr, page)) % 2**31)
        b = 2
        lens = np.array([page * pp - 3, max(1, page // 2)], np.int32)
        pool = kvc.init_mla_pool(1, b * pp, page, r, dr, "fp8_e4m3",
                                 frozen_fmt="fp4_e2m1", n_frozen=b * pp)
        pt = np.zeros((b, pp), np.int32)
        ck = rng.normal(size=(b, 1, 1, pp * page, r)).astype(np.float32)
        kr = rng.normal(size=(b, 1, 1, pp * page, dr)).astype(np.float32)
        base, fidx = b * pp + 1, 0
        for row in range(b):
            npg = kvc.pages_needed(int(lens[row]), page)
            ids = np.arange(row * pp, row * pp + npg, dtype=np.int32)
            pt[row, :npg] = ids
            pool = kvc.splice_prefill(
                pool, {"ckv": jnp.asarray(ck[row]),
                       "krope": jnp.asarray(kr[row])}, ids, int(lens[row]))
            for i in range(min(pp - 1, npg)):
                pool = kvc.transcode_page(pool, int(ids[i]), fidx)
                pt[row, i] = base + fidx
                fidx += 1
        layer = {k: v[0] for k, v in pool.items()}
        ql = jnp.asarray(rng.normal(size=(b, h, r)).astype(np.float32))
        qr = jnp.asarray(rng.normal(size=(b, h, dr)).astype(np.float32))
        scale = 1.0 / float(r + dr) ** 0.5
        prev = ops.get_backend()
        try:
            ops.set_backend("ref")
            o_ref = ops.paged_mla_decode_attn(
                ql, qr, layer, jnp.asarray(pt), jnp.asarray(lens), scale)
            ops.set_backend("pallas")
            o_pal = ops.paged_mla_decode_attn(
                ql, qr, layer, jnp.asarray(pt), jnp.asarray(lens), scale)
        finally:
            ops.set_backend(prev)
        np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                                   rtol=2e-5, atol=2e-5)
        for row in range(b):
            n = int(lens[row])
            s = (np.asarray(ql[row]) @ ck[row, 0, 0, :n].T
                 + np.asarray(qr[row]) @ kr[row, 0, 0, :n].T) * scale
            p = np.exp(s - s.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            exact = p @ ck[row, 0, 0, :n]
            err = np.abs(np.asarray(o_ref[row]) - exact).max()
            assert err / (np.abs(exact).max() + 1e-9) < FP4_TOL, (row, err)


MIXED = CachePolicy(active_fmt="fp8_e4m3", frozen_fmt="fp4_e2m1")


def _shared_prompts(cfg, n=4, prefix_tokens=24, seed=0):
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, cfg.vocab_size, size=prefix_tokens).tolist()
    return [shared + rng.integers(1, cfg.vocab_size,
                                  size=3 + i).tolist() for i in range(n)]


def _serve_policy(params, cfg, policy, prompts, max_new=8, **kw):
    srv = Server(params, cfg,
                 ServerConfig(slots=3, max_seq=64, page_size=8, a_fmt=None,
                              cache=policy, audit_every=1, **kw))
    for i, p in enumerate(prompts):
        srv.submit(Request(rid=i, prompt=list(p), max_new=max_new))
    done = srv.run_until_drained()
    srv.audit()
    return {r.rid: list(r.tokens) for r in done}, srv


class TestMixedServer:
    def test_warm_prefix_fp4_bounded_divergence(self, trained_tiny):
        """The acceptance workload: a warm shared-prefix batch under
        frozen_fmt='fp4_e2m1' vs the same batch all-FP8. Only the frozen
        prefix pages differ in precision, so greedy streams must stay
        within a bounded divergence — and the frozen residency must land
        at about half the bytes-per-token."""
        cfg, params = trained_tiny
        prompts = _shared_prompts(cfg)
        out8, _ = _serve_policy(params, cfg,
                                CachePolicy(active_fmt="fp8_e4m3"), prompts)
        out4, srv = _serve_policy(params, cfg, MIXED, prompts)
        assert srv.stats["fp4_frozen_pages"] >= 3
        assert srv.stats["prefix_hit_pages"] > 0
        total = agree = 0
        for rid in out8:
            for a, b in zip(out8[rid], out4[rid]):
                total += 1
                agree += a == b
        # bounded divergence: FP4 prefix attention may flip a near-tie,
        # but the bulk of both greedy streams must match position-wise
        assert agree / total >= 0.5, (agree, total, out8, out4)
        resid = srv.cache_residency()
        assert resid["n_frozen_live"] >= 3
        ratio = (resid["frozen_bytes_per_token"]
                 / resid["active_bytes_per_token"])
        assert ratio <= 0.55, ratio

    def test_audit_summary_reports_frozen_classes(self, trained_tiny):
        cfg, params = trained_tiny
        _, srv = _serve_policy(params, cfg, MIXED, _shared_prompts(cfg))
        summary = srv.audit()
        assert summary["frozen_mapped"] + summary["frozen_free"] + \
            summary["pages_parked"] == srv._n_frozen

    def test_fuzz_policy_transitions_steal_happy(self, trained_tiny):
        """freeze -> transcode -> park -> reclaim -> steal under a pool too
        small for the workload, auditing every decode step. Three waves
        with two distinct prefixes force parks (wave drain), unparks
        (warm wave), reclaims (prefix rotation on a full frozen region)
        and page-steal preempt/resume of slots holding mixed tables.

        Pinned to the alternating engine: the reclaim assertion depends on
        its wave timing (the first prefix's pages must hit refcount 0
        before the second registers, so registration rotates the full
        frozen region). The mixed engine overlaps those lifecycles — its
        fp4 transition coverage lives in test_steal_resume_token_identity_
        mixed and tests/test_mixed_engine.py."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(11)
        prefixes = [rng.integers(1, cfg.vocab_size, size=24).tolist()
                    for _ in range(2)]
        srv = Server(params, cfg, ServerConfig(
            slots=3, max_seq=64, page_size=8, a_fmt=None, pool_pages=7,
            cache=CachePolicy(active_fmt="fp8_e4m3", frozen_fmt="fp4_e2m1",
                              frozen_pages=4),
            audit_every=1,
            scheduler=SchedulerConfig(headroom_pages=1, steal_cooldown=1,
                                      engine="alternating")))
        reqs = []
        for wave in range(3):
            for i in range(6):
                rid = wave * 10 + i
                tail = rng.integers(1, cfg.vocab_size,
                                    size=2 + (i + wave) % 4).tolist()
                r = Request(rid=rid, prompt=prefixes[(wave + i) % 2] + tail,
                            max_new=16)
                reqs.append(r)
                srv.submit(r)
            srv.run_until_drained()  # audits every step via audit_every=1
            srv.audit()
        assert all(r.status == "ok" for r in reqs)
        assert srv.stats["fp4_frozen_pages"] >= 3
        assert srv.stats["prefix_reclaims"] >= 1  # frozen-region rotation
        assert srv.stats["preemptions"] >= 1 and srv.stats["resumes"] >= 1

    def test_steal_resume_token_identity_mixed(self, trained_tiny):
        """Spill/resume of mixed-format tables is bit-exact per format:
        the same single-prefix workload served through a pool tight enough
        to force page steals produces token streams identical to an ample
        pool where nothing is ever preempted. (Solo-run comparison would
        be wrong here: a warm-admitted request prefills against the FP4
        frozen prefix, a cold solo run against its own FP8 pages.)"""
        cfg, params = trained_tiny
        rng = np.random.default_rng(5)
        prefix = rng.integers(1, cfg.vocab_size, size=24).tolist()
        prompts = [prefix + rng.integers(1, cfg.vocab_size,
                                         size=2 + i % 5).tolist()
                   for i in range(8)]

        def run(pool_pages):
            srv = Server(params, cfg, ServerConfig(
                slots=3, max_seq=64, page_size=8, a_fmt=None,
                pool_pages=pool_pages, cache=MIXED, audit_every=1,
                scheduler=SchedulerConfig(headroom_pages=1,
                                          steal_cooldown=1)))
            reqs = [Request(rid=i, prompt=list(p), max_new=16)
                    for i, p in enumerate(prompts)]
            for r in reqs:
                srv.submit(r)
            srv.run_until_drained()
            srv.audit()
            return {r.rid: list(r.out) for r in reqs}, srv

        tight, srv_t = run(7)
        ample, srv_a = run(None)
        assert srv_t.stats["preemptions"] >= 1
        assert srv_a.stats["preemptions"] == 0
        assert tight == ample

    def test_mixed_policy_requires_prefix_cache(self, trained_tiny):
        cfg, params = trained_tiny
        with pytest.raises(ValueError, match="prefix cache"):
            Server(params, cfg,
                   ServerConfig(slots=2, max_seq=64, page_size=8,
                                a_fmt=None, prefix_cache=False, cache=MIXED))
