"""In-graph sampling: mask parity vs a numpy oracle, greedy identity to
the pre-sampling argmax engine, seed reproducibility across batch
compositions and across a preempt/spill/resume cycle, submit-time
validation, and the ServerConfig/RequestResult API redesign contracts.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import sampling as smp
from repro.runtime.serve import (Request, RequestResult, SamplingParams,
                                 SchedulerConfig, Server, ServerConfig)


# -- mask parity vs numpy oracle ----------------------------------------------

def _oracle_mask(scaled, top_k, top_p):
    """Numpy mirror of sampling_mask's documented semantics: top-k keeps
    everything >= the k-th largest (ties kept); top-p keeps the smallest
    descending prefix whose exclusive cumulative probability is < p."""
    keep = np.ones_like(scaled, dtype=bool)
    for r in range(scaled.shape[0]):
        row = scaled[r]
        if top_k[r] > 0:
            kth = np.sort(row)[::-1][min(top_k[r], row.size) - 1]
            keep[r] &= row >= kth
        srt = np.sort(row)[::-1]
        probs = np.exp(srt - srt.max())
        probs /= probs.sum()
        exclusive = np.cumsum(probs) - probs
        n_keep = int((exclusive < top_p[r]).sum())  # >= 1 always
        cut = srt[n_keep - 1]
        keep[r] &= row >= cut
    return keep


class TestMaskOracle:
    def test_mask_matches_numpy_oracle(self):
        rng = np.random.default_rng(0)
        scaled = rng.normal(scale=3.0, size=(16, 37)).astype(np.float32)
        top_k = rng.integers(0, 40, size=16).astype(np.int32)
        top_p = rng.uniform(0.05, 1.0, size=16).astype(np.float32)
        top_p[3] = 1.0  # exact no-op nucleus
        top_k[5] = 0  # top-k off
        got = np.asarray(smp.sampling_mask(
            jnp.asarray(scaled), jnp.asarray(top_k), jnp.asarray(top_p)))
        want = _oracle_mask(scaled, top_k, top_p)
        assert (got == want).all()

    def test_mask_keeps_boundary_ties(self):
        # three tokens tied at the k=2 boundary: all three survive (the
        # fixed-shape threshold compare cannot break ties; keeping them
        # is the documented conservative side)
        scaled = jnp.asarray([[5.0, 2.0, 2.0, 2.0, 1.0]])
        got = np.asarray(smp.sampling_mask(
            scaled, jnp.asarray([2], jnp.int32), jnp.asarray([1.0])))
        assert got.tolist() == [[True, True, True, True, False]]

    def test_top_token_always_survives_tiny_p(self):
        scaled = jnp.asarray(np.random.default_rng(1)
                             .normal(size=(4, 11)).astype(np.float32))
        got = np.asarray(smp.sampling_mask(
            scaled, jnp.zeros(4, jnp.int32), jnp.full(4, 1e-6, jnp.float32)))
        assert (got.sum(-1) >= 1).all()
        top = np.asarray(scaled).argmax(-1)
        assert got[np.arange(4), top].all()

    def test_sampled_tokens_respect_mask(self):
        rng = np.random.default_rng(2)
        logits = rng.normal(scale=2.0, size=(8, 23)).astype(np.float32)
        temps = np.full(8, 0.7, np.float32)
        top_k = np.full(8, 4, np.int32)
        top_p = np.full(8, 0.8, np.float32)
        allowed = _oracle_mask(logits / 0.7, top_k, top_p)
        for trial in range(5):
            toks = np.asarray(smp.sample_tokens(
                jnp.asarray(logits), jnp.asarray(temps), jnp.asarray(top_k),
                jnp.asarray(top_p), jnp.asarray(np.full(8, trial, np.uint32)),
                jnp.asarray(np.arange(8), jnp.int32)))
            assert allowed[np.arange(8), toks].all()

    def test_temperature_zero_rows_are_argmax(self):
        rng = np.random.default_rng(3)
        logits = rng.normal(size=(6, 19)).astype(np.float32)
        temps = np.asarray([0, 0.9, 0, 1.5, 0, 0.1], np.float32)
        toks = np.asarray(smp.sample_tokens(
            jnp.asarray(logits), jnp.asarray(temps),
            jnp.zeros(6, jnp.int32), jnp.ones(6, jnp.float32),
            jnp.asarray(np.full(6, 7, np.uint32)),
            jnp.zeros(6, jnp.int32)))
        greedy = logits.argmax(-1)
        assert (toks[temps == 0] == greedy[temps == 0]).all()

    def test_draw_depends_on_index_not_batch_row(self):
        """The key is fold_in(seed, emitted-index): the same (seed, index)
        draws the same token whatever row of the batch it occupies."""
        rng = np.random.default_rng(4)
        row = rng.normal(scale=2.0, size=23).astype(np.float32)
        for slot in range(3):
            logits = rng.normal(size=(4, 23)).astype(np.float32)
            logits[slot] = row
            toks = np.asarray(smp.sample_tokens(
                jnp.asarray(logits),
                jnp.full(4, 0.8, jnp.float32), jnp.zeros(4, jnp.int32),
                jnp.ones(4, jnp.float32),
                jnp.asarray(np.full(4, 11, np.uint32)),
                jnp.full(4, 5, jnp.int32)))
            if slot == 0:
                want = toks[0]
            assert toks[slot] == want


# -- validation ---------------------------------------------------------------

class TestValidation:
    @pytest.mark.parametrize("bad, match", [
        (dict(temperature=-0.1), "temperature"),
        (dict(temperature=float("nan")), "temperature"),
        (dict(top_p=0.0), "top_p"),
        (dict(top_p=-0.5), "top_p"),
        (dict(top_p=1.5), "top_p"),
        (dict(top_k=-1), "top_k"),
    ])
    def test_bad_params_raise(self, bad, match):
        with pytest.raises(ValueError, match=match):
            SamplingParams(**bad).validate()

    def test_bounds_are_inclusive_where_documented(self):
        SamplingParams(temperature=0.0, top_p=1.0, top_k=0).validate()
        SamplingParams(temperature=2.0, top_p=0.01, top_k=1).validate()

    def test_submit_validates_with_rid(self, trained_tiny):
        cfg, params = trained_tiny
        srv = Server(params, cfg,
                     ServerConfig(slots=1, max_seq=32, page_size=8,
                                  a_fmt=None))
        with pytest.raises(ValueError, match="request 7.*top_p"):
            srv.submit(Request(rid=7, prompt=[1, 2], max_new=2,
                               sampling=SamplingParams(top_p=0.0)))
        assert srv.queue == []  # fail-fast: nothing was enqueued


# -- engine-level sampling ----------------------------------------------------

def _drain_tokens(srv, reqs):
    for r in reqs:
        srv.submit(r)
    return {r.rid: r.tokens for r in srv.run_until_drained()}


def _mk(params, cfg, **over):
    base = dict(slots=3, max_seq=64, page_size=8, a_fmt=None)
    base.update(over)
    return Server(params, cfg, ServerConfig(**base))


class TestServerSampling:
    def _prompts(self, cfg, n=3):
        rng = np.random.default_rng(0)
        return [rng.integers(1, cfg.vocab_size, size=m).tolist()
                for m in (5, 9, 3)[:n]]

    @pytest.mark.parametrize("kv_fmt", [None, "fp8_e4m3"])
    def test_greedy_token_identical_to_argmax_engine(self, trained_tiny,
                                                     kv_fmt):
        """temperature=0 (the default) must reproduce the pre-sampling
        engine bit-exactly — the sampling epilogue ends in
        where(temp > 0, sampled, argmax), so greedy rows never see the
        masks. Reference: argmax over the model's own decode logits."""
        from repro import models

        cfg, params = trained_tiny
        prompts = self._prompts(cfg)
        outs = _drain_tokens(
            _mk(params, cfg, kv_fmt=kv_fmt),
            [Request(rid=i, prompt=p, max_new=6)
             for i, p in enumerate(prompts)])
        for i, p in enumerate(prompts):
            batch = {"tokens": jnp.asarray([p], jnp.int32)}
            logits, caches = models.prefill(params, cfg, batch, 64)
            ref = [int(jnp.argmax(logits[0]))]
            idx = len(p)
            while len(ref) < 6:
                logits, caches = models.decode_step(
                    params, cfg, jnp.asarray([[ref[-1]]], jnp.int32),
                    caches, idx)
                ref.append(int(jnp.argmax(logits[0])))
                idx += 1
            assert list(outs[i]) == ref, (kv_fmt, i)

    def test_seeded_stream_independent_of_batch_composition(self,
                                                            trained_tiny):
        """The same (prompt, SamplingParams) produces the same tokens
        solo, batched with different neighbours, and in a different
        slot — the key depends only on (seed, emitted-index)."""
        cfg, params = trained_tiny
        prompts = self._prompts(cfg)
        sp = SamplingParams(temperature=0.8, top_k=12, top_p=0.95, seed=21)
        solo = _drain_tokens(
            _mk(params, cfg, slots=1),
            [Request(rid=0, prompt=list(prompts[0]), max_new=8, sampling=sp)])
        batched = _drain_tokens(
            _mk(params, cfg, slots=3),
            [Request(rid=0, prompt=list(prompts[0]), max_new=8, sampling=sp),
             Request(rid=1, prompt=list(prompts[1]), max_new=4,
                     sampling=SamplingParams(temperature=1.2, seed=5)),
             Request(rid=2, prompt=list(prompts[2]), max_new=6)])
        assert solo[0] == batched[0]
        # and in a different admission order (different slot)
        reordered = _drain_tokens(
            _mk(params, cfg, slots=3),
            [Request(rid=2, prompt=list(prompts[2]), max_new=6),
             Request(rid=1, prompt=list(prompts[1]), max_new=4,
                     sampling=SamplingParams(temperature=1.2, seed=5)),
             Request(rid=0, prompt=list(prompts[0]), max_new=8, sampling=sp)])
        assert reordered[0] == solo[0] and reordered[1] == batched[1]

    def test_different_seeds_diverge(self, trained_tiny):
        cfg, params = trained_tiny
        p = self._prompts(cfg)[0]
        outs = _drain_tokens(
            _mk(params, cfg),
            [Request(rid=i, prompt=list(p), max_new=8,
                     sampling=SamplingParams(temperature=1.0, seed=i))
             for i in range(3)])
        assert len({outs[i] for i in range(3)}) > 1

    def test_seeded_stream_survives_preempt_spill_resume(self, trained_tiny):
        """A sampled request stolen mid-stream and resumed continues its
        token stream exactly: the spill carries (rng_seed, emitted) and
        the KV restore is bit-exact, so draw i's key and logits are both
        unchanged. Pool sized to force >= 1 steal (same shape as the
        scheduler suite's preempt tests)."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(11)
        prompts = [rng.integers(1, cfg.vocab_size, size=5).tolist()
                   for _ in range(2)]
        sps = [SamplingParams(temperature=0.9, top_k=16, top_p=0.9, seed=31),
               SamplingParams(temperature=0.7, seed=32)]
        # pool of 6 x 4-token pages: both charge 2 prompt pages + 1
        # headroom, then growth past 12 tokens forces a steal + resume
        # (same contention shape as the scheduler suite's preempt tests)
        srv = _mk(params, cfg, slots=2, max_seq=32, page_size=4,
                  pool_pages=6, kv_fmt="fp8_e4m3",
                  scheduler=SchedulerConfig(steal_cooldown=0))
        reqs = [Request(rid=i, prompt=list(p), max_new=10, sampling=sp)
                for i, (p, sp) in enumerate(zip(prompts, sps))]
        contended = _drain_tokens(srv, reqs)
        assert srv.stats["preemptions"] >= 1 and srv.stats["resumes"] >= 1
        for i in range(2):
            solo = _mk(params, cfg, slots=1, max_seq=32, page_size=4,
                       kv_fmt="fp8_e4m3")
            ref = _drain_tokens(solo, [Request(
                rid=9, prompt=list(prompts[i]), max_new=10, sampling=sps[i])])
            assert contended[i] == ref[9], i


# -- API redesign contracts ---------------------------------------------------

class TestServerConfigAPI:
    def test_legacy_kwargs_warn_and_map(self, trained_tiny):
        cfg, params = trained_tiny
        with pytest.warns(DeprecationWarning, match="ServerConfig"):
            srv = Server(params, cfg, slots=2, max_seq=32, page_size=8,
                         a_fmt=None, headroom_pages=3, scheduler="reserve")
        assert srv.config.slots == 2
        assert srv.config.scheduler.policy == "reserve"
        assert srv.config.scheduler.headroom_pages == 3

    def test_legacy_unknown_kwarg_raises(self, trained_tiny):
        cfg, params = trained_tiny
        with pytest.raises(TypeError, match="bogus"):
            Server(params, cfg, bogus=1)

    def test_config_and_legacy_mutually_exclusive(self, trained_tiny):
        cfg, params = trained_tiny
        with pytest.raises(TypeError, match="not both"):
            Server(params, cfg, ServerConfig(), slots=2)

    def test_config_is_frozen(self):
        with pytest.raises(dataclasses.FrozenInstanceError):
            ServerConfig().slots = 8

    def test_new_form_emits_no_warning(self, trained_tiny):
        cfg, params = trained_tiny
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            Server(params, cfg, ServerConfig(slots=1, max_seq=32,
                                             page_size=8, a_fmt=None))


def _shim_kv_fmt(mode):
    from repro.runtime.kv_cache import CachePolicy

    if mode == "legacy":
        return ServerConfig(kv_fmt="fp8_e4m3")
    if mode == "conflict":
        return ServerConfig(kv_fmt="fp8_e4m3",
                            cache=CachePolicy(active_fmt="fp8_e4m3"))
    return ServerConfig(cache=CachePolicy(active_fmt="fp8_e4m3"))


def _shim_flat_kwargs(mode, params, cfg):
    if mode == "legacy":
        return Server(params, cfg, slots=1, max_seq=32, page_size=8,
                      a_fmt=None)
    if mode == "conflict":
        return Server(params, cfg, ServerConfig(), slots=2)
    return Server(params, cfg, ServerConfig(slots=1, max_seq=32,
                                            page_size=8, a_fmt=None))


class TestLegacyShimMatrix:
    """Both deprecation shims (kv_fmt -> CachePolicy, flat Server kwargs
    -> ServerConfig) route through the one _migrate_legacy_kwarg helper;
    this matrix pins the shared contract: legacy spelling warns (and maps),
    legacy + modern together is a TypeError naming 'not both', the modern
    spelling alone is silent."""

    @pytest.fixture(scope="class")
    def ctx(self):
        from conftest import tiny_lm_cfg
        from repro import models

        cfg = tiny_lm_cfg()
        return models.init_params(cfg, jax.random.PRNGKey(0)), cfg

    def _call(self, shim, mode, ctx):
        if shim == "kv_fmt":
            return _shim_kv_fmt(mode)
        return _shim_flat_kwargs(mode, *ctx)

    @pytest.mark.parametrize("shim,match", [("kv_fmt", "kv_fmt"),
                                            ("flat", "ServerConfig")])
    def test_legacy_spelling_warns(self, ctx, shim, match):
        with pytest.warns(DeprecationWarning, match=match):
            self._call(shim, "legacy", ctx)

    @pytest.mark.parametrize("shim", ["kv_fmt", "flat"])
    def test_legacy_plus_modern_is_type_error(self, ctx, shim):
        with pytest.raises(TypeError, match="not both"):
            self._call(shim, "conflict", ctx)

    @pytest.mark.parametrize("shim", ["kv_fmt", "flat"])
    def test_modern_spelling_is_silent(self, ctx, shim):
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            self._call(shim, "modern", ctx)

    def test_conflict_leaves_no_partial_state(self, ctx):
        # the conflict raises from inside _migrate_legacy_kwarg before any
        # engine state exists; a retry with the modern spelling succeeds
        with pytest.raises(TypeError):
            self._call("flat", "conflict", ctx)
        srv = self._call("flat", "modern", ctx)
        assert srv.config.slots == 1


class TestRequestResultAPI:
    def test_drained_results_are_frozen_snapshots(self, trained_tiny):
        cfg, params = trained_tiny
        srv = _mk(params, cfg, slots=2)
        rng = np.random.default_rng(1)
        srv.submit(Request(rid=0, prompt=rng.integers(1, 64, 4).tolist(),
                           max_new=3))
        (res,) = srv.run_until_drained()
        assert isinstance(res, RequestResult)
        assert res.ok and res.status == "ok" and res.error is None
        assert isinstance(res.tokens, tuple) and len(res.tokens) == 3
        assert res.prompt_len == 4
        with pytest.raises(dataclasses.FrozenInstanceError):
            res.status = "failed"

    def test_result_timing_fields(self, trained_tiny):
        cfg, params = trained_tiny
        srv = _mk(params, cfg, slots=1)
        srv.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4))
        (res,) = srv.run_until_drained()
        assert len(res.token_times) == 4
        assert res.ttft is not None and res.ttft > 0
        assert len(res.itl) == 3 and all(g >= 0 for g in res.itl)
        assert list(res.token_times) == sorted(res.token_times)

    def test_truncated_folds_into_status(self, trained_tiny):
        """Request.truncated is now derived: status == 'truncated' is the
        one source of truth, on both the Request and its result."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(4)
        srv = _mk(params, cfg, slots=1, max_seq=16, page_size=4)
        req = Request(rid=0, prompt=rng.integers(1, 64, 5).tolist(),
                      max_new=50)
        srv.submit(req)
        (res,) = srv.run_until_drained()
        assert req.status == "truncated" and req.truncated
        assert res.truncated and not res.ok
        assert len(res.tokens) < 50
        with pytest.raises(AttributeError):
            req.truncated = False  # read-only property
