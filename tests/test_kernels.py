"""Pallas kernel validation: interpret-mode execution swept over shapes,
dtypes and scale modes, assert_allclose against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import FORMATS, fp_encode, pack_nibbles, quantize_to_grid, value_grid
from repro.core.policy import QuantPolicy
from repro.core.ptq import pack_linear
from repro.kernels import ops, ref
from repro.kernels.act_quant import act_quant_pallas
from repro.kernels.w4a8_matmul import decode_e2m1, decode_e3m0, w4a8_matmul_pallas


# ---------------------------------------------------------------------------
# decode closed forms vs core.formats
# ---------------------------------------------------------------------------
def test_decode_e2m1_matches_fp_decode():
    codes = jnp.arange(16, dtype=jnp.uint8)
    from repro.core.formats import fp_decode

    np.testing.assert_array_equal(
        np.asarray(decode_e2m1(codes)), np.asarray(fp_decode(codes, FORMATS["fp4_e2m1"]))
    )


def test_decode_e3m0_matches_fp_decode():
    codes = jnp.arange(16, dtype=jnp.uint8)
    from repro.core.formats import fp_decode

    np.testing.assert_array_equal(
        np.asarray(decode_e3m0(codes)), np.asarray(fp_decode(codes, FORMATS["fp4_e3m0"]))
    )


# ---------------------------------------------------------------------------
# act_quant kernel sweep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(8, 128), (16, 256), (3, 384), (32, 1024), (5, 96)])
@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp8_e5m2"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_act_quant_kernel_matches_ref(shape, fmt, dtype):
    rng = np.random.default_rng(hash((shape, fmt, str(dtype))) % 2**31)
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32) * 13.0).astype(dtype)
    qk, sk = act_quant_pallas(x, fmt, interpret=True)
    qr, sr = ref.act_quant_ref(x, fmt)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))


def test_act_quant_kernel_outlier_row():
    x = jnp.asarray(np.r_[np.full(127, 0.01), [100.0]].astype(np.float32))[None]
    qk, sk = act_quant_pallas(x, "fp8_e4m3", interpret=True)
    qr, sr = ref.act_quant_ref(x, "fp8_e4m3")
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    # the outlier maps to the max grid value
    assert float(qk[0, -1]) == FORMATS["fp8_e4m3"].max_value


# ---------------------------------------------------------------------------
# w4a8 matmul kernel sweep
# ---------------------------------------------------------------------------
def _pack_weight(rng, n, k, group, w_fmt="fp4_e2m1", scale_mode="none"):
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32) * 0.05)
    policy = QuantPolicy(w_fmt=w_fmt, a_fmt="fp8_e4m3", group_size=group,
                        scale_mode=scale_mode)
    return w, pack_linear(w, policy)


@pytest.mark.parametrize("mnk", [(8, 128, 256), (16, 256, 512), (128, 384, 256),
                                 (4, 512, 1024), (64, 128, 768)])
@pytest.mark.parametrize("group", [128, 256])
def test_w4a8_kernel_matches_ref(mnk, group):
    m, n, k = mnk
    if k % group:
        pytest.skip("group must divide k")
    rng = np.random.default_rng(m * n + k)
    _, pl_w = _pack_weight(rng, n, k, group)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(jnp.bfloat16)

    # activations quantized identically on both sides, so the only diff is
    # blocked vs monolithic f32 accumulation order
    qv, sc = ref.act_quant_ref(x, "fp8_e4m3")
    xq = (qv * sc).astype(jnp.bfloat16)

    y_kernel = w4a8_matmul_pallas(xq, pl_w.codes, pl_w.scale, group_size=group,
                                  interpret=True)
    w_deq = ref.dequant_packed_ref(pl_w.codes, pl_w.scale, "fp4_e2m1", group)
    y_ref = jax.lax.dot_general(xq.astype(jnp.float32), w_deq.astype(jnp.float32),
                                (((1,), (1,)), ((), ())))
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("w_fmt", ["fp4_e2m1", "fp4_e3m0"])
def test_w4a8_kernel_formats(w_fmt):
    rng = np.random.default_rng(7)
    n, k, m, group = 128, 512, 16, 256
    _, pl_w = _pack_weight(rng, n, k, group, w_fmt=w_fmt)
    xq = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(jnp.bfloat16)
    y_kernel = w4a8_matmul_pallas(xq, pl_w.codes, pl_w.scale, w_fmt=w_fmt,
                                  group_size=group, interpret=True)
    w_deq = ref.dequant_packed_ref(pl_w.codes, pl_w.scale, w_fmt, group)
    y_ref = jax.lax.dot_general(xq.astype(jnp.float32), w_deq.astype(jnp.float32),
                                (((1,), (1,)), ((), ())))
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               rtol=2e-2, atol=2e-2)


def test_w4a8_kernel_m2_shift_path():
    """The M2 exponent-shift path must equal the plain-scale path bit-for-bit
    (scales are exactly s_max * 2^-k)."""
    rng = np.random.default_rng(11)
    n, k, m, group = 128, 1024, 8, 256
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32) * 0.05)
    policy = QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", group_size=group,
                        scale_mode="m2")
    pl_w = pack_linear(w, policy)
    assert pl_w.shifts is not None and pl_w.s_max is not None
    xq = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(jnp.bfloat16)

    y_scale = w4a8_matmul_pallas(xq, pl_w.codes, pl_w.scale, group_size=group,
                                 interpret=True)
    y_shift = w4a8_matmul_pallas(xq, pl_w.codes, pl_w.scale, s_max=pl_w.s_max,
                                 shifts=pl_w.shifts, group_size=group,
                                 interpret=True)
    # shift path applies 2^-k exactly (pow2 scaling is lossless in bf16) and
    # s_max once in f32; the scale path rounds s_max*2^-k*w to bf16 — the
    # shift path is the MORE precise one (the paper's efficiency cast loses
    # nothing). Tolerance = bf16 quantum.
    np.testing.assert_allclose(np.asarray(y_shift), np.asarray(y_scale),
                               rtol=1e-2, atol=1e-2)


def test_ops_backend_switch_end_to_end():
    """linear() with a PackedLinear must agree between ref and pallas
    backends (same quantization, different execution)."""
    from repro.models.layers import PackedLinear, linear

    rng = np.random.default_rng(13)
    n, k, m, group = 256, 512, 8, 256
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32) * 0.05)
    policy = QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", group_size=group,
                        scale_mode="m2", lorc_rank=4)
    fac_w = pack_linear(w, policy)
    x = jnp.asarray(rng.normal(size=(2, m // 2, k)).astype(np.float32)).astype(jnp.bfloat16)

    ops.set_backend("ref")
    y_ref = linear(fac_w, x)
    ops.set_backend("pallas_interpret")
    try:
        y_pl = linear(fac_w, x)
    finally:
        ops.set_backend("ref")
    np.testing.assert_allclose(
        np.asarray(y_ref, dtype=np.float32), np.asarray(y_pl, dtype=np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_packed_codes_roundtrip_grid():
    """Every packed code decodes to a grid value (property over random w)."""
    from repro.core.formats import fp_decode, unpack_nibbles

    rng = np.random.default_rng(17)
    w = jnp.asarray(rng.normal(size=(32, 256)).astype(np.float32))
    policy = QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", group_size=64)
    pl_w = pack_linear(w, policy)
    vals = np.unique(np.asarray(fp_decode(unpack_nibbles(pl_w.codes), FORMATS["fp4_e2m1"])))
    grid = set(value_grid("fp4_e2m1").tolist())
    assert set(vals.tolist()) <= grid


# ---------------------------------------------------------------------------
# flash attention kernel sweep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(1, 128, 2, 64), (2, 256, 4, 32), (1, 384, 1, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(shape, causal):
    from repro.kernels.flash_attn import flash_attention_pallas, flash_attention_ref

    b, s, h, hd = shape
    rng = np.random.default_rng(b * s + h)
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32)).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32)).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32)).astype(jnp.bfloat16)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=128, block_k=128,
                                 interpret=True)
    ref_out = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref_out, np.float32),
        rtol=3e-2, atol=3e-2,
    )


def test_flash_attention_distinct_v_dim():
    """MLA-style: v head dim differs from qk head dim."""
    from repro.kernels.flash_attn import flash_attention_pallas, flash_attention_ref

    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(1, 256, 2, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 32)).astype(np.float32))
    out = flash_attention_pallas(q, k, v, interpret=True)
    ref_out = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out), rtol=1e-4, atol=1e-4)
