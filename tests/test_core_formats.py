"""Unit + property tests for repro.core.formats."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats as F

jax.config.update("jax_enable_x64", False)


E2M1_GRID = np.array(
    [-6, -4, -3, -2, -1.5, -1, -0.5, 0, 0.5, 1, 1.5, 2, 3, 4, 6], dtype=np.float32
)


def test_e2m1_grid_matches_paper():
    grid = F.value_grid("fp4_e2m1")
    np.testing.assert_array_equal(grid, E2M1_GRID)


def test_e4m3_extremes():
    # qtorch-style saturating grid (paper footnote 3): all codes are values,
    # so max = 2^8 * 1.875 = 480 (NVIDIA's NaN-reserving variant caps at 448).
    fmt = F.FORMATS["fp8_e4m3"]
    assert fmt.max_value == 480.0
    assert fmt.min_subnormal == 2.0 ** (-6 - 3)


def test_e5m2_extremes():
    # saturating grid: all-ones exponent is a value (IEEE inf/NaN variant
    # would cap at 57344); max = 2^16 * 1.75
    fmt = F.FORMATS["fp8_e5m2"]
    assert fmt.max_value == 114688.0


def test_e3m0_grid():
    # bias 3, saturating: exponent fields 1..7 -> 2^-2 .. 2^4; no mantissa,
    # no subnormals (m=0 only) -> pure powers of two.
    grid = F.value_grid("fp4_e3m0")
    pos = grid[grid > 0]
    np.testing.assert_allclose(pos, [0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0])


@pytest.mark.parametrize("name", ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1", "fp4_e3m0"])
def test_quantize_is_nearest_grid_point(name):
    """quantize_to_grid must equal explicit nearest-neighbour on the grid
    (ties handled RNE, so we only check non-tie points)."""
    fmt = F.FORMATS[name]
    grid = F.value_grid(name)
    rng = np.random.default_rng(0)
    x = rng.normal(size=4096).astype(np.float32) * fmt.max_value * 0.4
    q = np.asarray(F.quantize_to_grid(jnp.asarray(x), fmt))
    # brute-force nearest
    d = np.abs(x[:, None] - grid[None, :])
    nearest = grid[np.argmin(d, axis=1)]
    best = np.min(d, axis=1)
    second = np.partition(d, 1, axis=1)[:, 1]
    not_tie = (second - best) > 1e-6 * np.maximum(np.abs(x), 1e-3)
    np.testing.assert_array_equal(q[not_tie], nearest[not_tie])


@pytest.mark.parametrize("name", ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1", "fp4_e3m0"])
def test_grid_points_are_fixed_points(name):
    fmt = F.FORMATS[name]
    grid = jnp.asarray(F.value_grid(name))
    q = F.quantize_to_grid(grid, fmt)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(grid))


@pytest.mark.parametrize("name", ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1", "fp4_e3m0"])
def test_saturation(name):
    fmt = F.FORMATS[name]
    x = jnp.asarray([1e9, -1e9, np.float32(fmt.max_value) * 1.5])
    q = F.quantize_to_grid(x, fmt)
    np.testing.assert_allclose(
        np.asarray(q), [fmt.max_value, -fmt.max_value, fmt.max_value]
    )


def test_rne_tie_behavior_e2m1():
    fmt = F.FORMATS["fp4_e2m1"]
    # 1.25 is halfway between 1.0 and 1.5 -> step 0.5 at exponent 0;
    # 1.25/0.5 = 2.5 -> RNE to 2 -> 1.0 (even mantissa)
    q = F.quantize_to_grid(jnp.asarray([1.25, 1.75]), fmt)
    np.testing.assert_allclose(np.asarray(q), [1.0, 2.0])


@pytest.mark.parametrize("name", ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1", "fp4_e3m0"])
def test_encode_decode_roundtrip(name):
    fmt = F.FORMATS[name]
    grid = jnp.asarray(F.value_grid(name))
    codes = F.fp_encode(grid, fmt)
    back = F.fp_decode(codes, fmt)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(grid))
    assert int(jnp.max(codes)) < 2**fmt.bits


def test_codes_are_unique_e2m1():
    fmt = F.FORMATS["fp4_e2m1"]
    grid = jnp.asarray(F.value_grid("fp4_e2m1"))
    codes = np.asarray(F.fp_encode(grid, fmt))
    # -0 and +0 share the value but we only feed one zero
    assert len(set(codes.tolist())) == len(grid)


def test_pack_unpack_nibbles():
    rng = np.random.default_rng(1)
    codes = rng.integers(0, 16, size=(8, 64), dtype=np.uint8)
    packed = F.pack_nibbles(jnp.asarray(codes))
    assert packed.shape == (8, 32)
    out = F.unpack_nibbles(packed)
    np.testing.assert_array_equal(np.asarray(out), codes)


def test_quantize_preserves_dtype():
    fmt = F.FORMATS["fp8_e4m3"]
    x = jnp.ones((4,), jnp.bfloat16)
    assert F.quantize_to_grid(x, fmt).dtype == jnp.bfloat16


def test_zero_maps_to_zero():
    for name in ["fp8_e4m3", "fp4_e2m1", "fp4_e3m0"]:
        fmt = F.FORMATS[name]
        q = F.quantize_to_grid(jnp.zeros((3,)), fmt)
        np.testing.assert_array_equal(np.asarray(q), np.zeros(3, np.float32))
