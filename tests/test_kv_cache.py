"""Paged FP8 KV cache + decode attention.

Covers: page quantization roundtrips (splice + in-graph append), the
decode-attention kernel vs its jnp oracle (bit-level parity in interpret
mode) and both vs the unquantized bf16 reference at FP8-appropriate
tolerance across a (heads, head_dim, page_size, seq) sweep, the MLA
absorbed paged path vs the contiguous legacy decode, pool bytes-per-token
accounting, and the served end-to-end path (paged bf16 == legacy greedy;
paged FP8 == paged bf16 greedy on a trained tiny config)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import models
from repro.kernels import ops
from repro.runtime import kv_cache as kvc
from repro.runtime.serve import Request, Server, ServerConfig


def _attn_exact(q, k, v, kv_len, g):
    """Unquantized single-token attention oracle. q: (H, hd); k/v: (T, KV, hd)."""
    h, hd = q.shape
    o = np.zeros((h, v.shape[-1]), np.float32)
    for hi in range(h):
        sc = q[hi] @ k[:kv_len, hi // g].T / np.sqrt(hd)
        p = np.exp(sc - sc.max())
        p /= p.sum()
        o[hi] = p @ v[:kv_len, hi // g]
    return o


def _filled_pool(rng, kv, hd, page, pp, lens, fmt):
    """A 1-layer GQA pool spliced with per-row random prompts."""
    b = len(lens)
    n_pages = b * pp
    pool = kvc.init_gqa_pool(1, n_pages, page, kv, hd, fmt)
    pt = np.zeros((b, pp), np.int32)
    kc = rng.normal(size=(b, 1, 1, pp * page, kv, hd)).astype(np.float32)
    vc = rng.normal(size=(b, 1, 1, pp * page, kv, hd)).astype(np.float32)
    for r in range(b):
        npg = kvc.pages_needed(int(lens[r]), page)
        ids = np.arange(r * pp, r * pp + npg, dtype=np.int32)
        pt[r, :npg] = ids
        pool = kvc.splice_prefill(
            pool, {"k": jnp.asarray(kc[r]), "v": jnp.asarray(vc[r])}, ids,
            int(lens[r]))
    layer = {k: v[0] for k, v in pool.items()}
    return layer, pt, kc[:, 0, 0], vc[:, 0, 0]


class TestPagedPool:
    def test_splice_gather_roundtrip_fp8(self):
        rng = np.random.default_rng(0)
        lens = np.array([13, 5], np.int32)
        layer, pt, kc, _ = _filled_pool(rng, 2, 16, 8, 3, lens, "fp8_e4m3")
        state = kvc.PagedState(jnp.asarray(pt), jnp.asarray(lens))
        got = np.asarray(kvc.gather_pages(layer, "k", state))
        for r, n in enumerate(lens):
            ref = kc[r, :n]
            err = np.abs(got[r, :n] - ref).max() / np.abs(ref).max()
            assert err < 0.07, err  # E4M3 grid with floor-rounded M2 scales

    def test_append_matches_splice(self):
        """Tokens appended one-by-one in-graph decode to (nearly) the same
        values as a one-shot splice of the full sequence."""
        rng = np.random.default_rng(1)
        kv, hd, page = 2, 8, 4
        seq = 11
        stream = rng.normal(size=(seq, kv, hd)).astype(np.float32)
        pool = kvc.init_gqa_pool(1, 4, page, kv, hd, "fp8_e4m3")
        # token 0 arrives as a (1-token) prefill splice — rows with length 0
        # are by convention inactive and never receive decode appends
        pool = kvc.splice_prefill(
            pool, {"k": jnp.asarray(stream[None, None, None, :1]),
                   "v": jnp.asarray(stream[None, None, None, :1])},
            np.array([0]), 1)
        layer = {k: v[0] for k, v in pool.items()}
        pt = jnp.asarray([[0, 1, 2]], jnp.int32)
        app = jax.jit(kvc.append_paged)
        for t in range(1, seq):
            state = kvc.PagedState(pt, jnp.asarray([t], jnp.int32))
            tok = jnp.asarray(stream[t][None, None])
            layer = app(layer, {"k": tok, "v": tok}, state)
        state = kvc.PagedState(pt, jnp.asarray([seq], jnp.int32))
        got = np.asarray(kvc.gather_pages(layer, "k", state))[0, :seq]
        err = np.abs(got - stream).max() / np.abs(stream).max()
        # appends requantize the touched page; with unchanged scales the
        # decode->encode is exact, so error stays at one-quantization level
        assert err < 0.08, err

    def test_append_empty_rows_hit_null_page(self):
        """Inactive rows (lengths == 0) must not corrupt live pages."""
        rng = np.random.default_rng(2)
        lens = np.array([9, 0], np.int32)
        layer, pt, kc, _ = _filled_pool(rng, 2, 8, 8, 2, lens, "fp8_e4m3")
        state = kvc.PagedState(jnp.asarray(pt), jnp.asarray(lens))
        before = np.asarray(kvc.gather_pages(layer, "k", state))[0, :9]
        new = {"k": jnp.ones((2, 1, 2, 8)), "v": jnp.ones((2, 1, 2, 8))}
        layer = jax.jit(kvc.append_paged)(layer, new, state)
        after = np.asarray(kvc.gather_pages(layer, "k", state))[0, :9]
        np.testing.assert_allclose(after, before, rtol=1e-6, atol=1e-6)

    def test_splice_overhangs_prefill_cache(self):
        """Reserved pages may overhang the prefill cache's max_seq when
        max_seq is not a page multiple — the tail pads with zeros instead
        of crashing."""
        rng = np.random.default_rng(3)
        kv, hd, page, max_seq, n = 2, 8, 8, 20, 18  # 3 pages = 24 > 20
        pool = kvc.init_gqa_pool(1, 4, page, kv, hd, "fp8_e4m3")
        cache = {
            "k": jnp.asarray(rng.normal(size=(1, 1, max_seq, kv, hd)).astype(np.float32)),
            "v": jnp.asarray(rng.normal(size=(1, 1, max_seq, kv, hd)).astype(np.float32)),
        }
        pool = kvc.splice_prefill(pool, cache, np.array([0, 1, 2]), n)
        state = kvc.PagedState(jnp.asarray([[0, 1, 2]], jnp.int32),
                               jnp.asarray([n], jnp.int32))
        layer = {k: v[0] for k, v in pool.items()}
        got = np.asarray(kvc.gather_pages(layer, "k", state))[0]
        ref = np.asarray(cache["k"][0, 0, :n])
        assert np.abs(got[:n] - ref).max() / np.abs(ref).max() < 0.07
        np.testing.assert_array_equal(got[n:], 0)

    def test_bytes_per_token_halved(self):
        pool = kvc.init_gqa_pool(4, 32, 64, 4, 64, "fp8_e4m3")
        ratio = kvc.pool_bytes_per_token(pool) / kvc.bf16_bytes_per_token(pool)
        assert ratio <= 0.55, ratio
        bf16 = kvc.init_gqa_pool(4, 32, 64, 4, 64, None)
        assert kvc.pool_bytes_per_token(bf16) == kvc.bf16_bytes_per_token(bf16)


class TestPagedDecodeAttn:
    @pytest.mark.parametrize("kv,g,hd,page,pp", [
        (2, 2, 16, 8, 3),   # GQA
        (1, 4, 32, 16, 2),  # MQA-ish, bigger head
        (4, 1, 8, 4, 4),    # MHA, many small pages
        (2, 3, 64, 32, 2),  # odd group size (padding path)
    ])
    def test_fp8_matches_bf16_oracle(self, kv, g, hd, page, pp):
        """The quantized paged decode matches full-precision attention to
        FP8-appropriate tolerance, and the pallas kernel (interpret mode)
        matches the jnp oracle tightly."""
        rng = np.random.default_rng(hash((kv, g, hd, page)) % 2**31)
        h = kv * g
        lens = np.array([page * pp - 3, max(1, page // 2)], np.int32)
        q = jnp.asarray(rng.normal(size=(2, h, hd)).astype(np.float32))
        prev = ops.get_backend()
        try:
            outs = {}
            for fmt in ("fp8_e4m3", None):
                layer, pt, kc, vc = _filled_pool(rng, kv, hd, page, pp, lens, fmt)
                ops.set_backend("ref")
                o_ref = ops.paged_decode_attn(q, layer, jnp.asarray(pt),
                                              jnp.asarray(lens))
                ops.set_backend("pallas")
                o_pal = ops.paged_decode_attn(q, layer, jnp.asarray(pt),
                                              jnp.asarray(lens))
                np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                                           rtol=2e-5, atol=2e-5)
                for r in range(2):
                    exact = _attn_exact(np.asarray(q[r]), kc[r], vc[r],
                                        int(lens[r]), g)
                    err = np.abs(np.asarray(o_ref[r]) - exact).max()
                    scale = np.abs(exact).max() + 1e-9
                    tol = 0.12 if fmt else 0.01
                    assert err / scale < tol, (fmt, err / scale)
                outs[fmt] = o_ref
        finally:
            ops.set_backend(prev)


    def test_sliding_window(self):
        """window > 0 masks history beyond the window in both backends (the
        query for a decode step sits at position kv_len - 1)."""
        rng = np.random.default_rng(5)
        kv, g, hd, page, pp = 2, 2, 16, 8, 3
        window = 6
        lens = np.array([20, 4], np.int32)  # row 1 shorter than the window
        q = jnp.asarray(rng.normal(size=(2, kv * g, hd)).astype(np.float32))
        layer, pt, kc, vc = _filled_pool(rng, kv, hd, page, pp, lens, None)
        prev = ops.get_backend()
        try:
            ops.set_backend("ref")
            o_ref = ops.paged_decode_attn(q, layer, jnp.asarray(pt),
                                          jnp.asarray(lens), window=window)
            ops.set_backend("pallas")
            o_pal = ops.paged_decode_attn(q, layer, jnp.asarray(pt),
                                          jnp.asarray(lens), window=window)
        finally:
            ops.set_backend(prev)
        np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                                   rtol=2e-5, atol=2e-5)
        for r in range(2):
            lo = max(0, int(lens[r]) - window)
            exact = _attn_exact(np.asarray(q[r]), kc[r, lo:], vc[r, lo:],
                                int(lens[r]) - lo, g)
            err = np.abs(np.asarray(o_ref[r]) - exact).max()
            assert err / (np.abs(exact).max() + 1e-9) < 0.01, err


class TestPrefixCacheIndex:
    """The host-side content-addressed index over frozen pages: chained
    page keys, longest-prefix walk, dedup on insert, park/unpark/reclaim
    LRU semantics, and the frozen-page write guard."""

    def test_walk_insert_roundtrip(self):
        c = kvc.PrefixCache(4)
        toks = list(range(12))
        assert c.walk(toks) == []
        assert c.insert(toks[:8], [10, 11]) == [10, 11]
        assert c.walk(toks) == [10, 11]  # third page never registered
        assert c.walk(toks, max_pages=1) == [10]
        assert c.walk(toks[:6]) == [10]  # partial second page: no hit
        assert c.walk([9, 9, 9, 9]) == []  # different content

    def test_chained_keys_do_not_collide_across_depths(self):
        """The same token window under a different history is a different
        page: keys chain on the parent, so depth-1 [4..7] != root [4..7]."""
        c = kvc.PrefixCache(4)
        toks = list(range(8))
        c.insert(toks, [0, 1])
        c.insert(toks[4:8], [2])  # same window at the *root*
        assert c.walk(toks) == [0, 1]
        assert c.walk(toks[4:8]) == [2]

    def test_insert_dedups_to_canonical(self):
        """A second registration of the same chain returns the existing
        pages — the duplicate pid is never registered (the caller adopts
        the canonical page and frees its copy)."""
        c = kvc.PrefixCache(4)
        toks = list(range(8))
        assert c.insert(toks, [0, 1]) == [0, 1]
        assert c.insert(toks, [5, 6]) == [0, 1]
        assert not c.registered(5) and not c.registered(6)

    def test_park_unpark_reclaim_lru(self):
        c = kvc.PrefixCache(4)
        toks = list(range(12))
        c.insert(toks, [0, 1, 2])
        for pid in (0, 1, 2):
            c.park(pid)
        assert c.n_reusable == 3
        c.unpark(1)  # re-acquired: no longer reclaimable
        assert c.reclaim() == 0  # oldest parked first
        assert c.reclaims == 1
        # the chain is broken at depth 1: deeper entries are unreachable
        assert c.walk(toks) == []
        assert c.registered(1) and c.registered(2)
        assert c.reclaim() == 2 and c.reclaim() is None

    def test_assert_unfrozen_guards_registered_pages(self):
        c = kvc.PrefixCache(4)
        c.insert(list(range(4)), [3])
        c.assert_unfrozen([0, 1, 2])  # private pages pass
        with pytest.raises(AssertionError, match="frozen"):
            c.assert_unfrozen([3])


def _mla_smoke_cfg():
    from repro.configs import get_smoke

    return get_smoke("minicpm3-4b")


def _filled_mla_pool(rng, r, dr, page, pp, lens, fmt):
    """A 1-layer MLA latent pool spliced with per-row random prompts."""
    b = len(lens)
    pool = kvc.init_mla_pool(1, b * pp, page, r, dr, fmt)
    pt = np.zeros((b, pp), np.int32)
    ck = rng.normal(size=(b, 1, 1, pp * page, r)).astype(np.float32)
    kr = rng.normal(size=(b, 1, 1, pp * page, dr)).astype(np.float32)
    for row in range(b):
        npg = kvc.pages_needed(int(lens[row]), page)
        ids = np.arange(row * pp, row * pp + npg, dtype=np.int32)
        pt[row, :npg] = ids
        pool = kvc.splice_prefill(
            pool, {"ckv": jnp.asarray(ck[row]), "krope": jnp.asarray(kr[row])},
            ids, int(lens[row]))
    layer = {k: v[0] for k, v in pool.items()}
    return layer, pt, ck[:, 0, 0], kr[:, 0, 0]


class TestPagedMLAKernel:
    """The latent flash-decoding kernel (KV = 1 head, k = concat(ckv,
    krope), v = ckv view) vs the jnp oracle and the exact numpy softmax."""

    @pytest.mark.parametrize("h,r,dr,page,pp", [
        (4, 16, 8, 8, 3),    # minicpm3-ish smoke
        (8, 32, 16, 16, 2),  # wider latent
        (3, 16, 8, 4, 4),    # odd head count (bq padding path)
        (16, 64, 32, 8, 2),  # many heads, deepseek-ish ratio
    ])
    def test_kernel_matches_oracle(self, h, r, dr, page, pp):
        rng = np.random.default_rng(hash((h, r, dr, page)) % 2**31)
        lens = np.array([page * pp - 3, max(1, page // 2)], np.int32)
        ql = jnp.asarray(rng.normal(size=(2, h, r)).astype(np.float32))
        qr = jnp.asarray(rng.normal(size=(2, h, dr)).astype(np.float32))
        scale = 1.0 / float(r + dr) ** 0.5
        prev = ops.get_backend()
        try:
            for fmt in ("fp8_e4m3", None):
                layer, pt, ck, kr = _filled_mla_pool(rng, r, dr, page, pp,
                                                     lens, fmt)
                ops.set_backend("ref")
                o_ref = ops.paged_mla_decode_attn(
                    ql, qr, layer, jnp.asarray(pt), jnp.asarray(lens), scale)
                ops.set_backend("pallas")
                o_pal = ops.paged_mla_decode_attn(
                    ql, qr, layer, jnp.asarray(pt), jnp.asarray(lens), scale)
                np.testing.assert_allclose(np.asarray(o_pal),
                                           np.asarray(o_ref),
                                           rtol=2e-5, atol=2e-5)
                # vs the exact (unquantized, unpaged) softmax
                for row in range(2):
                    n = int(lens[row])
                    s = (np.asarray(ql[row]) @ ck[row, :n].T
                         + np.asarray(qr[row]) @ kr[row, :n].T) * scale
                    p = np.exp(s - s.max(-1, keepdims=True))
                    p /= p.sum(-1, keepdims=True)
                    exact = p @ ck[row, :n]
                    err = np.abs(np.asarray(o_ref[row]) - exact).max()
                    tol = 0.12 if fmt else 0.01
                    assert err / (np.abs(exact).max() + 1e-9) < tol, (fmt, err)
        finally:
            ops.set_backend(prev)


class TestPagedMLA:
    @pytest.mark.parametrize("kv_fmt", [None, "fp8_e4m3"])
    def test_paged_decode_matches_legacy(self, kv_fmt):
        """MLA absorbed decode over latent pages vs the contiguous cache."""
        cfg = _mla_smoke_cfg()
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        prompt = np.random.default_rng(0).integers(1, cfg.vocab_size, 7).tolist()
        toks = jnp.asarray([prompt], jnp.int32)
        max_seq, page = 32, 8
        logits, caches = models.prefill(params, cfg, {"tokens": toks}, max_seq)
        t0 = int(jnp.argmax(logits[0]))

        # legacy contiguous decode
        lg_legacy, _ = models.decode_step(
            params, cfg, jnp.asarray([[t0]], jnp.int32), caches, len(prompt))

        # paged decode from a spliced pool
        pools = []
        from repro.models.transformer import segments_for

        for i, seg in enumerate(segments_for(cfg)):
            pool = kvc.init_mla_pool(seg.count, 4, page, cfg.mla.kv_lora_rank,
                                     cfg.mla.qk_rope_dim, kv_fmt)
            pools.append({"kv": kvc.splice_prefill(
                pool, caches[i]["kv"], np.array([0, 1]), len(prompt))})
        state = kvc.PagedState(jnp.asarray([[0, 1, 2, 3]], jnp.int32),
                               jnp.asarray([len(prompt)], jnp.int32))
        lg_paged, _ = models.decode_step(
            params, cfg, jnp.asarray([[t0]], jnp.int32), pools, state)

        a, b = np.asarray(lg_legacy[0]), np.asarray(lg_paged[0])
        scale = np.abs(a).max() + 1e-9
        tol = 0.1 if kv_fmt else 2e-2
        assert np.abs(a - b).max() / scale < tol


def _greedy_legacy(params, cfg, prompt, max_new, max_seq=64, frames=None):
    """Reference greedy loop over the contiguous (non-paged) cache — the
    pre-paged-engine decode path kept by the model layer."""
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    if frames is not None:
        batch["frames"] = jnp.asarray(frames[None])
    logits, caches = models.prefill(params, cfg, batch, max_seq)
    out = [int(jnp.argmax(logits[0]))]
    idx = len(prompt)
    while len(out) < max_new:
        logits, caches = models.decode_step(
            params, cfg, jnp.asarray([[out[-1]]], jnp.int32), caches, idx)
        out.append(int(jnp.argmax(logits[0])))
        idx += 1
    return out


class TestServerPaged:
    def _prompts(self, cfg):
        rng = np.random.default_rng(0)
        return [rng.integers(1, cfg.vocab_size, size=n).tolist()
                for n in (5, 9, 3)]

    def _serve(self, params, cfg, kv_fmt, prompts, max_new=6):
        srv = Server(params, cfg,
                     ServerConfig(slots=len(prompts), max_seq=64,
                                  kv_fmt=kv_fmt, page_size=8, a_fmt=None))
        for i, p in enumerate(prompts):
            srv.submit(Request(rid=i, prompt=p, max_new=max_new))
        done = srv.run_until_drained()
        return {r.rid: list(r.tokens) for r in done}, srv

    def test_bf16_paged_matches_legacy_greedy(self, trained_tiny):
        """Per-slot true lengths: a mixed-length batch reproduces each
        request's solo contiguous-cache generation exactly (the old
        synchronized max-length engine could not)."""
        cfg, params = trained_tiny
        prompts = self._prompts(cfg)
        batch, _ = self._serve(params, cfg, None, prompts)
        for i, p in enumerate(prompts):
            assert batch[i] == _greedy_legacy(params, cfg, p, 6), i

    def test_fp8_token_identical_to_bf16(self, trained_tiny):
        cfg, params = trained_tiny
        prompts = self._prompts(cfg)
        out_bf16, _ = self._serve(params, cfg, None, prompts)
        out_fp8, srv = self._serve(params, cfg, "fp8_e4m3", prompts)
        assert out_bf16 == out_fp8
        ratio = srv.kv_bytes_per_token() / srv.kv_bf16_bytes_per_token()
        assert ratio <= 0.55, ratio

    def test_run_until_drained_returns_finished(self, trained_tiny):
        cfg, params = trained_tiny
        prompts = self._prompts(cfg)
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=64, kv_fmt="fp8_e4m3",
                                  page_size=8, a_fmt=None))
        for i, p in enumerate(prompts):
            srv.submit(Request(rid=i, prompt=p, max_new=4))
        done = srv.run_until_drained()
        assert sorted(r.rid for r in done) == [0, 1, 2]
        assert all(r.ok and len(r.tokens) == 4 for r in done)
        assert srv.queue == [] and not any(srv.active)
        # pages recycled: 3 requests served through a 2-slot pool (full
        # prompt pages stay parked in the prefix cache's reusable LRU —
        # still allocatable, so the pool is whole)
        assert (len(srv.free_pages) + len(srv.reusable_pages)
                == len(srv.page_table.flatten()))
        assert (srv.page_refs == 0).all()

    def test_page_recycling_under_pressure(self, trained_tiny):
        """More requests than the pool can hold at once: admission waits for
        retirements, every request still completes correctly."""
        cfg, params = trained_tiny
        prompts = self._prompts(cfg) * 2
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=64, kv_fmt="fp8_e4m3",
                                  page_size=8, pool_pages=4, a_fmt=None))
        for i, p in enumerate(prompts):
            srv.submit(Request(rid=i, prompt=p, max_new=4))
        done = srv.run_until_drained()
        assert len(done) == len(prompts)
        by_rid = {r.rid: r.tokens for r in done}
        assert by_rid[0] == by_rid[3] and by_rid[2] == by_rid[5]

    def test_sliding_window_config_matches_legacy(self, trained_tiny):
        """A window > 0 config must thread its sliding-window mask through
        the paged decode path, not silently attend full history."""
        import dataclasses

        cfg, params = trained_tiny
        wcfg = dataclasses.replace(cfg, window=4)
        prompts = self._prompts(cfg)
        batch, _ = self._serve(params, wcfg, None, prompts)
        for i, p in enumerate(prompts):
            assert batch[i] == _greedy_legacy(params, wcfg, p, 6), i

    def test_infeasible_request_fails_fast(self, trained_tiny):
        """A request that can never fit the pool raises at submit instead of
        head-of-line blocking the queue forever."""
        cfg, params = trained_tiny
        srv = Server(params, cfg,
                     ServerConfig(slots=1, max_seq=64, kv_fmt="fp8_e4m3",
                                  page_size=8, pool_pages=2, a_fmt=None))
        with pytest.raises(ValueError, match="pages"):
            srv.submit(Request(rid=0, prompt=list(range(1, 20)), max_new=10))

    def test_mla_served_greedy_matches_legacy(self, trained_tiny_mla):
        """The acceptance claim for MLA: the paged engine (latent decode
        kernel path) reproduces the legacy contiguous-cache greedy output,
        bf16 and fp8, on a trained model with decisive logits."""
        cfg, params = trained_tiny_mla
        rng = np.random.default_rng(2)
        prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
                   for n in (5, 11, 3)]
        for kv_fmt in (None, "fp8_e4m3"):
            batch, _ = self._serve(params, cfg, kv_fmt, prompts)
            for i, p in enumerate(prompts):
                assert batch[i] == _greedy_legacy(params, cfg, p, 6), (kv_fmt, i)


class TestServerEncDec:
    """Whisper-style enc-dec on the paged engine: write-once cross pages +
    paged decoder self-attention, admission charging prompt + encoder
    pages — the family that used to keep the legacy monolithic engine."""

    def _reqs(self, cfg, rng, n=3):
        prompts = [rng.integers(1, cfg.vocab_size, size=m).tolist()
                   for m in (5, 9, 3)[:n]]
        frames = [rng.normal(size=(cfg.encoder_seq, cfg.d_model))
                  .astype(np.float32) for _ in prompts]
        return prompts, frames

    def _serve(self, params, cfg, kv_fmt, prompts, frames, max_new=6):
        srv = Server(params, cfg,
                     ServerConfig(slots=len(prompts), max_seq=64,
                                  kv_fmt=kv_fmt, page_size=8, a_fmt=None))
        for i, (p, f) in enumerate(zip(prompts, frames)):
            srv.submit(Request(rid=i, prompt=list(p), max_new=max_new,
                               frames=f))
        done = srv.run_until_drained()
        return {r.rid: list(r.tokens) for r in done}, srv

    @pytest.mark.parametrize("kv_fmt", [None, "fp8_e4m3"])
    def test_paged_matches_legacy_greedy(self, trained_tiny_encdec, kv_fmt):
        """Acceptance: enc-dec greedy through the paged engine (bf16 and
        fp8 pages) is token-identical to the pre-paged legacy engine."""
        cfg, params = trained_tiny_encdec
        rng = np.random.default_rng(0)
        prompts, frames = self._reqs(cfg, rng)
        batch, srv = self._serve(params, cfg, kv_fmt, prompts, frames)
        for i, (p, f) in enumerate(zip(prompts, frames)):
            assert batch[i] == _greedy_legacy(params, cfg, p, 6, frames=f), i
        if kv_fmt:  # FP8 cross+self pages still halve the KV bytes
            ratio = srv.kv_bytes_per_token() / srv.kv_bf16_bytes_per_token()
            assert ratio <= 0.55, ratio

    def test_admission_charges_encoder_pages(self, trained_tiny_encdec):
        """Admission must charge pages(prompt) + pages(encoder_seq): a pool
        that fits the prompt but not the cross pages cannot admit."""
        cfg, params = trained_tiny_encdec
        rng = np.random.default_rng(1)
        prompts, frames = self._reqs(cfg, rng, n=1)
        cross_pp = kvc.pages_needed(cfg.encoder_seq, 8)
        srv = Server(params, cfg,
                     ServerConfig(slots=1, max_seq=64, kv_fmt="fp8_e4m3",
                                  page_size=8, pool_pages=cross_pp, a_fmt=None))
        with pytest.raises(ValueError, match="pages"):
            srv.submit(Request(rid=0, prompt=prompts[0], max_new=4,
                               frames=frames[0]))

    def test_missing_frames_fails_fast(self, trained_tiny_encdec):
        cfg, params = trained_tiny_encdec
        srv = Server(params, cfg,
                     ServerConfig(slots=1, max_seq=32, kv_fmt="fp8_e4m3",
                                  page_size=8, a_fmt=None))
        # decoder K/V depends on the encoder frames, not just the token
        # prefix — the prefix cache stays ON (radix chains hang off a
        # per-frames-digest root, see test_encdec_prefix_cache), but a
        # request without frames still fails fast at submit
        assert srv._prefix is not None
        with pytest.raises(ValueError, match="frames"):
            srv.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4))

    def test_encdec_prefix_cache_parity_and_collision_safety(
            self, trained_tiny_encdec):
        """Enc-dec prefix sharing keys pages on (frames digest, token
        prefix): two requests with the same prompt and the SAME frames hit
        the cache (second serve pays no prefill for the shared pages) and
        stay token-identical to a cold run; the same prompt under
        DIFFERENT frames must never share pages — decoder K/V depends on
        the frames through cross-attention — and each still decodes
        exactly its own cold-run tokens."""
        cfg, params = trained_tiny_encdec
        rng = np.random.default_rng(9)
        prompt = rng.integers(1, cfg.vocab_size, size=17).tolist()
        f_a = rng.normal(size=(cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        f_b = rng.normal(size=(cfg.encoder_seq, cfg.d_model)).astype(np.float32)

        def cold(frames):
            out, _ = self._serve(params, cfg, "fp8_e4m3", [prompt], [frames])
            return out[0]

        ref_a, ref_b = cold(f_a), cold(f_b)
        # digests are bit-exact content hashes: distinct frames -> distinct
        # radix roots (collision safety does not depend on output deltas)
        assert (Request(rid=98, prompt=[1], frames=f_a).frames_digest()
                != Request(rid=97, prompt=[1], frames=f_b).frames_digest())

        # same frames twice, then the same prompt under different frames
        srv = Server(params, cfg,
                     ServerConfig(slots=1, max_seq=64, kv_fmt="fp8_e4m3",
                                  page_size=8, a_fmt=None))
        outs = {}
        for rid, frames in ((0, f_a), (1, f_a), (2, f_b)):
            r = Request(rid=rid, prompt=list(prompt), max_new=6, frames=frames)
            srv.submit(r)
            srv.run_until_drained()
            outs[rid] = list(r.out)
        assert outs[0] == ref_a and outs[1] == ref_a  # parity + hit path
        assert outs[2] == ref_b  # no cross-frames aliasing
        # the repeat under identical frames mapped the frozen pages
        # ((17 - 1) // 8 = 2 full pages); the f_b request walked a disjoint
        # radix chain and hit nothing
        assert srv.stats["prefix_hit_pages"] == 2
        assert srv.audit()["violations"] == 0

    def test_cross_pages_survive_steal_resume(self, trained_tiny_encdec):
        """Preemption spills cross pages with the rest of the payload:
        a stolen-and-resumed enc-dec request is token-identical to an
        uncontended solo run."""
        cfg, params = trained_tiny_encdec
        rng = np.random.default_rng(4)
        prompts, frames = self._reqs(cfg, rng, n=2)
        # prompts (5, 9) charge 2+1 and 3+1 pages + cross_pp each; both fit
        # at admission, but growth to 15 and 19 tokens (4 + 5 pages) wants
        # one page more than the pool holds -> exactly one steal + resume
        cross_pp = kvc.pages_needed(cfg.encoder_seq, 4)
        srv = Server(params, cfg,
                     ServerConfig(slots=2, max_seq=32, kv_fmt="fp8_e4m3",
                                  page_size=4, pool_pages=8 + 2 * cross_pp,
                                  a_fmt=None))
        reqs = [Request(rid=i, prompt=list(p), max_new=10, frames=f)
                for i, (p, f) in enumerate(zip(prompts, frames))]
        for r in reqs:
            srv.submit(r)
        srv.run_until_drained()
        assert srv.stats["preemptions"] >= 1 and srv.stats["resumes"] >= 1
        for r in reqs:
            solo = Server(params, cfg,
                          ServerConfig(slots=1, max_seq=32, kv_fmt="fp8_e4m3",
                                       page_size=4, a_fmt=None))
            ref = Request(rid=99, prompt=list(r.prompt), max_new=10,
                          frames=r.frames)
            solo.submit(ref)
            solo.run_until_drained()
            assert r.out == ref.out, (r.rid, r.out, ref.out)
