"""Distribution-layer tests. The heavyweight (arch x shape) sweep lives in
the dry-run (repro.launch.dryrun); here we cover the machinery itself:
sharding rules, cache specs, roofline analyzer, and a subprocess mini
dry-run on an 8-host-device mesh (device count must be set before jax
initializes, hence the subprocess)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_mesh
from repro.launch.sharding import _cache_leaf_spec, serve_rules, train_rules
from repro.models.params import DEFAULT_RULES, ParamDef, pspec_leaf


class TestShardingRules:
    class _Mesh:  # duck-typed mesh: only .shape is consulted
        shape = {"data": 16, "model": 16}

    def test_kv_head_fallback(self):
        # flattened kv*hd dim (1024) divides the 16-way axis -> shards
        d = ParamDef((8 * 128, 4096), ("kv", "embed"))
        assert pspec_leaf(d, DEFAULT_RULES, self._Mesh()) == P("model", None)
        # a bare 8-kv-head dim does NOT divide 16 -> replicated fallback
        d2 = ParamDef((8, 128, 4096), ("kv", None, "embed"))
        assert pspec_leaf(d2, DEFAULT_RULES, self._Mesh()) == P(None, None, None)

    def test_heads_shard(self):
        d = ParamDef((4096, 4096), ("heads", "embed"))
        assert pspec_leaf(d, DEFAULT_RULES, self._Mesh()) == P("model", None)

    def test_tuple_axis_no_duplicates(self):
        rules = dict(DEFAULT_RULES, expert=("data", "model"), ffn="model")
        d = ParamDef((256, 2048, 7168), ("expert", "ffn", "embed"))
        spec = pspec_leaf(d, rules, self._Mesh())
        assert spec == P(("data", "model"), None, None)

    def test_zero3_rules(self):
        cfg = get_config("nemotron-4-340b")

        class M:
            shape = {"data": 16, "model": 16}

        prules, mrules = train_rules(cfg, M(), zero3=True)
        assert prules["embed"] == ("data",) or prules["embed"] == "data"
        assert mrules["embed"] is not None

    def test_serve_rules_moe_ep(self):
        cfg = get_config("deepseek-v3-671b")

        class M:
            shape = {"data": 16, "model": 16}

        rules = serve_rules(cfg, M())
        assert rules["expert"] == ("data", "model")  # 256 experts = 16x16


class TestCacheSpecs:
    class _Mesh:
        shape = {"data": 16, "model": 16}

    def test_kv_cache_batch_and_heads(self):
        # (L, B, S, KV, hd): batch over data, kv over model
        spec = _cache_leaf_spec((32, 128, 32768, 16, 128), self._Mesh())
        assert spec[1] == "data" and spec[3] == "model"

    def test_long_context_batch1_seq_sharded(self):
        # (L, B=1, S=500k, KV, hd): seq takes both axes
        spec = _cache_leaf_spec((38, 1, 524288, 32, 64), self._Mesh())
        assert spec[3] == "model"
        assert spec[2] == "data"

    def test_mla_latent_cache(self):
        # (L, B, S, r) — no head dim; seq gets model
        spec = _cache_leaf_spec((61, 128, 32768, 512), self._Mesh())
        assert spec[1] == "data" and spec[2] == "model"


class TestHloCost:
    def test_scan_trip_multiplication(self):
        def body(x, w):
            return jnp.tanh(x @ w), None

        def fn(x, ws):
            return jax.lax.scan(body, x, ws)[0]

        x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        ws = jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)
        txt = jax.jit(fn).lower(x, ws).compile().as_text()
        c = analyze_hlo(txt, 1, bf16_model=False)
        expect = 12 * 2 * 256**3
        assert abs(c.flops - expect) / expect < 0.05

    def test_collective_traffic_model(self):
        from repro.launch.hlo_cost import _coll_traffic

        assert _coll_traffic("all-reduce", 100, 4) == 150.0
        assert _coll_traffic("all-gather", 100, 4) == 75.0
        assert _coll_traffic("collective-permute", 100, 4) == 100.0


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    from repro.configs import get_smoke
    from repro.launch.mesh import make_mesh
    from repro.launch.shapes import ShapeSpec
    from repro.launch.steps import lower_cell

    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = get_smoke(sys.argv[1])
    shape = ShapeSpec("mini", sys.argv[2], seq=64, batch=4)
    lowered, meta = lower_cell(cfg, shape, mesh)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    print(json.dumps({"ok": True, "mode": meta["mode"],
                      "temp": mem.temp_size_in_bytes}))
""")


@pytest.mark.parametrize("arch,kind", [
    ("olmo-1b", "train"), ("olmoe-1b-7b", "train"), ("minicpm3-4b", "decode"),
    ("zamba2-1.2b", "decode"), ("whisper-tiny", "prefill"),
])
def test_mini_dryrun_subprocess(arch, kind, tmp_path):
    """lower+compile a smoke config on an 8-device 2x4 mesh end to end."""
    script = tmp_path / "mini.py"
    script.write_text(MINI_DRYRUN)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, str(script), arch, kind],
        capture_output=True, text=True, timeout=300, env=env, cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["mode"] == kind
