"""Unified mixed prefill+decode engine step (chunked-prefill piggyback).

Covers: greedy token-identity of the mixed engine vs the alternating
baseline on GQA / MLA / MoE (bf16 + fp8 pages) under a steal-happy pool;
mid-prefill NaN quarantine hitting only the streaming request; every
decode row emitting a token on every engine step while a 4-page prompt
streams in; the O(log max_seq) trace bound under a high-entropy workload
of random prompt lengths; the family fallback matrix (recurrent-slab and
enc-dec servers run the alternating engine even when mixed is requested);
the ``prefill_token_budget`` knob's page rounding; and the mixed engine's
whole-engine utilization beating the alternating baseline on a
long-prompt / short-decode mix.
"""
import numpy as np
import pytest

import jax

from conftest import tiny_lm_cfg

from repro import models
from repro.configs import get_smoke
from repro.runtime.faults import FaultPlan
from repro.runtime.serve import (Request, SchedulerConfig, Server,
                                 ServerConfig)


def _run_engine(params, cfg, prompts, engine, *, kv_fmt="fp8_e4m3",
                slots=3, max_seq=48, page_size=4, pool_pages=None,
                max_new=8, budget=None):
    srv = Server(params, cfg, ServerConfig(
        slots=slots, max_seq=max_seq, page_size=page_size, a_fmt=None,
        pool_pages=pool_pages, kv_fmt=kv_fmt,
        scheduler=SchedulerConfig(engine=engine,
                                  prefill_token_budget=budget)))
    assert srv.engine == engine
    reqs = [Request(rid=i, prompt=list(p), max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    assert srv.audit()["violations"] == 0
    return srv, reqs


class TestTokenIdentity:
    """Greedy token streams must be bit-identical between the mixed and
    alternating engines: the mixed step's per-row numerics (decode lanes
    and the piggybacked chunk) match the dedicated programs exactly."""

    @pytest.mark.parametrize("kv_fmt", [None, "fp8_e4m3"])
    def test_gqa_steal_happy(self, trained_tiny, kv_fmt):
        """A pool tight enough to force steals + resumes mid-run: both
        engines still produce identical outputs for every request."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(17)
        prompts = [rng.integers(1, cfg.vocab_size, size=int(t)).tolist()
                   for t in rng.integers(3, 18, size=6)]
        outs = {}
        for engine in ("alternating", "mixed"):
            srv, reqs = _run_engine(params, cfg, prompts, engine,
                                    kv_fmt=kv_fmt, pool_pages=12,
                                    max_new=12)
            assert srv.stats["preemptions"] >= 1, "scenario must steal"
            outs[engine] = [r.out for r in reqs]
        assert outs["mixed"] == outs["alternating"]

    @pytest.mark.parametrize("kv_fmt", [None, "fp8_e4m3"])
    def test_mla(self, trained_tiny_mla, kv_fmt):
        cfg, params = trained_tiny_mla
        rng = np.random.default_rng(23)
        prompts = [rng.integers(1, cfg.vocab_size, size=int(t)).tolist()
                   for t in rng.integers(3, 14, size=4)]
        outs = {}
        for engine in ("alternating", "mixed"):
            _, reqs = _run_engine(params, cfg, prompts, engine,
                                  kv_fmt=kv_fmt, slots=2, max_new=6)
            outs[engine] = [r.out for r in reqs]
        assert outs["mixed"] == outs["alternating"]

    @pytest.mark.parametrize("kv_fmt", [None, "fp8_e4m3"])
    def test_moe(self, kv_fmt):
        """Expert routing is per-token, so the fused row must route each
        token identically to the dedicated programs (engine-vs-engine
        identity needs no training — both runs share the weights)."""
        cfg = get_smoke("olmoe-1b-7b")
        params = models.init_params(cfg, jax.random.PRNGKey(2))
        rng = np.random.default_rng(29)
        prompts = [rng.integers(1, cfg.vocab_size, size=int(t)).tolist()
                   for t in rng.integers(3, 14, size=4)]
        outs = {}
        for engine in ("alternating", "mixed"):
            _, reqs = _run_engine(params, cfg, prompts, engine,
                                  kv_fmt=kv_fmt, slots=2, max_new=6)
            outs[engine] = [r.out for r in reqs]
        assert outs["mixed"] == outs["alternating"]


class TestMidPrefillQuarantine:
    def test_nan_mid_prefill_quarantines_streaming_request(
            self, trained_tiny):
        """A NaN injected while a request's prompt is still streaming
        through the fused step fails exactly that request — its chunk-row
        sentinel trips, its pages are scrubbed and never registered, and
        every batchmate keeps decoding token-identically."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(31)
        short = rng.integers(1, cfg.vocab_size, size=5).tolist()
        long = rng.integers(1, cfg.vocab_size, size=16).tolist()
        # step 1-2: rid 0 streams (4+1 tokens); steps 3..6: rid 1 streams
        # 4 chunks of 4 while rid 0 decodes — step 4 poisons rid 1's slot
        # mid-stream (8 of 16 prompt tokens written)
        plan = FaultPlan(nan_logits=((4, 1),))
        srv = Server(params, cfg, ServerConfig(
            slots=2, max_seq=48, page_size=4, pool_pages=16, a_fmt=None,
            kv_fmt="fp8_e4m3",
            scheduler=SchedulerConfig(engine="mixed",
                                      prefill_token_budget=4)),
            faults=plan)
        r0 = Request(rid=0, prompt=list(short), max_new=8)
        r1 = Request(rid=1, prompt=list(long), max_new=8)
        srv.submit(r0)
        srv.submit(r1)
        srv.run_until_drained()
        assert r1.done and r1.status == "failed"
        assert "during prefill" in r1.error
        assert plan.nan_hits == [(4, 1, 1)]
        assert srv.stats["failed"] == 1
        assert r0.status == "ok" and r0.error is None
        solo, ref = _run_engine(params, cfg, [short], "mixed", slots=1,
                                budget=4)
        assert r0.out == ref[0].out
        assert srv.audit()["violations"] == 0
        assert sorted(srv.free_pages + srv.reusable_pages) == \
            list(range(srv._n_pages))


class TestDecodeNeverStalls:
    def test_every_decode_row_emits_while_prompt_streams(self,
                                                         trained_tiny):
        """The regression the mixed engine exists to fix: while a 4-page
        prompt streams in, every already-decoding row emits one token on
        every engine step — decode never waits for the prefill."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(37)
        srv = Server(params, cfg, ServerConfig(
            slots=3, max_seq=64, page_size=4, pool_pages=24, a_fmt=None,
            kv_fmt="fp8_e4m3",
            scheduler=SchedulerConfig(engine="mixed",
                                      prefill_token_budget=4)))
        early = [Request(rid=i,
                         prompt=rng.integers(1, 64, size=3).tolist(),
                         max_new=30) for i in range(2)]
        for r in early:
            srv.submit(r)
        while not all(r.out for r in early):
            srv.step()
        late = Request(rid=9, prompt=rng.integers(1, 64, 16).tolist(),
                       max_new=4)
        srv.submit(late)
        stream_steps = 0
        while not late.out:  # late's prompt (16 tokens, 4 pages) streams
            before = [len(r.out) for r in early]
            assert srv.step()
            stream_steps += 1
            after = [len(r.out) for r in early]
            assert after == [b + 1 for b in before], \
                "a decode row stalled behind the streaming prompt"
        assert stream_steps >= 4  # 16 tokens at 4/step, then the seed
        assert srv.audit()["violations"] == 0


class TestTraceBudget:
    def test_trace_count_logarithmic_high_entropy(self, trained_tiny):
        """Random prompt lengths across the whole context range compile
        only the power-of-two bucketed family of fused chunk programs:
        O(log max_seq), not one per distinct length."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(41)
        lengths = rng.integers(1, 44, size=24)
        prompts = [rng.integers(1, cfg.vocab_size, size=int(t)).tolist()
                   for t in lengths]
        srv, _ = _run_engine(params, cfg, prompts, "mixed", max_seq=64,
                             pool_pages=48, max_new=3, budget=8)
        page = srv.page_size
        chunk_buckets = (8).bit_length()           # padded in {1,2,4,8}
        table_buckets = (64 // page).bit_length()  # w in {1,2,...,16}
        assert len({int(t) for t in lengths}) > chunk_buckets * 2
        for padded, w in srv.prefill_traces:
            assert padded & (padded - 1) == 0 and padded <= 8
            assert w & (w - 1) == 0 and w <= 64 // page
        assert len(srv.prefill_traces) <= chunk_buckets * table_buckets


class TestFamilyFallback:
    def test_encdec_falls_back_to_alternating(self):
        cfg = get_smoke("whisper-tiny")
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        srv = Server(params, cfg, ServerConfig(
            slots=2, max_seq=32, page_size=4, a_fmt=None,
            scheduler=SchedulerConfig(engine="mixed")))
        assert srv.engine == "alternating"

    def test_recurrent_slabs_fall_back_to_alternating(self):
        cfg = get_smoke("xlstm-125m")
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        srv = Server(params, cfg, ServerConfig(
            slots=2, max_seq=32, page_size=4, a_fmt=None,
            scheduler=SchedulerConfig(engine="mixed")))
        assert srv.engine == "alternating"

    def test_dense_paged_runs_mixed_by_default(self, trained_tiny):
        cfg, params = trained_tiny
        srv = Server(params, cfg, ServerConfig(
            slots=2, max_seq=32, page_size=4, a_fmt=None))
        assert srv.engine == "mixed"
        alt = Server(params, cfg, ServerConfig(
            slots=2, max_seq=32, page_size=4, a_fmt=None,
            scheduler=SchedulerConfig(engine="alternating")))
        assert alt.engine == "alternating"

    def test_unknown_engine_rejected(self, trained_tiny):
        cfg, params = trained_tiny
        with pytest.raises(ValueError, match="engine"):
            Server(params, cfg, ServerConfig(
                slots=2, max_seq=32, page_size=4, a_fmt=None,
                scheduler=SchedulerConfig(engine="fused")))


class TestBudgetKnob:
    def test_budget_rounds_down_to_page_multiple(self, trained_tiny):
        cfg, params = trained_tiny
        srv = Server(params, cfg, ServerConfig(
            slots=1, max_seq=32, page_size=4, a_fmt=None,
            scheduler=SchedulerConfig(prefill_token_budget=6)))
        assert srv.prefill_token_budget == 4
        tiny = Server(params, cfg, ServerConfig(
            slots=1, max_seq=32, page_size=4, a_fmt=None,
            scheduler=SchedulerConfig(prefill_token_budget=1)))
        assert tiny.prefill_token_budget == 4  # min one page
        dflt = Server(params, cfg, ServerConfig(
            slots=1, max_seq=32, page_size=4, a_fmt=None))
        assert dflt.prefill_token_budget == \
            dflt.prefill_chunk_pages * dflt.page_size


class TestEngineUtilization:
    def test_mixed_beats_alternating_on_prefill_heavy_mix(self,
                                                          trained_tiny):
        """Long prompts + short decodes: the alternating engine burns
        whole programs on chunks that decode nothing, so the mixed
        engine's decoded-tokens-per-launch is strictly higher."""
        cfg, params = trained_tiny
        rng = np.random.default_rng(43)
        prompts = [rng.integers(1, cfg.vocab_size, size=20).tolist()
                   for _ in range(6)]
        util = {}
        for engine in ("alternating", "mixed"):
            srv, _ = _run_engine(params, cfg, prompts, engine,
                                 max_seq=48, pool_pages=48, max_new=4,
                                 budget=8)
            util[engine] = srv.engine_utilization()
            assert srv.stats["programs"] > 0
        assert util["mixed"] > util["alternating"]
