"""All-to-all EP MoE must match the einsum-dispatch MoE (same capacity
semantics) on a single device, and lower/compile multi-device."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.launch.mesh import make_mesh
from repro.models.moe import moe_layer, moe_params
from repro.models.moe_a2a import moe_layer_a2a
from repro.models.params import init_tree


def test_a2a_matches_einsum_single_device():
    cfg = get_smoke("olmoe-1b-7b")
    p = init_tree(moe_params(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.bfloat16)
    mesh = make_mesh((1, 1), ("data", "model"))
    y_ein, aux_e = moe_layer(p, x, cfg, group_size=32)
    y_a2a, aux_a = moe_layer_a2a(p, x, cfg, mesh)
    np.testing.assert_allclose(
        np.asarray(y_ein, np.float32), np.asarray(y_a2a, np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_a2a_grads_finite():
    cfg = get_smoke("olmoe-1b-7b")
    p = init_tree(moe_params(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    mesh = make_mesh((1, 1), ("data", "model"))

    def loss(p):
        y, aux = moe_layer_a2a(p, x, cfg, mesh)
        return jnp.sum(jnp.square(y.astype(jnp.float32))) + 0.01 * aux

    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))
    assert float(jnp.max(jnp.abs(g["wu"].astype(jnp.float32)))) > 0


MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_smoke
    from repro.launch.mesh import make_mesh
    from repro.models.moe import moe_layer, moe_params
    from repro.models.moe_a2a import moe_layer_a2a
    from repro.models.params import init_tree

    cfg = get_smoke("olmoe-1b-7b")  # 8 experts
    p = init_tree(moe_params(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
    mesh = make_mesh((2, 4), ("data", "model"))
    y_ref, _ = moe_layer(p, x, cfg, group_size=32)
    fn = jax.jit(lambda p, x: moe_layer_a2a(p, x, cfg, mesh)[0])
    y = fn(p, x)
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32) - y_ref.astype(jnp.float32))))
    # capacity partitioning differs across ranks (per-rank vs per-group), so
    # drops can differ; demand broad agreement instead of exactness
    rel = err / (float(jnp.max(jnp.abs(y_ref.astype(jnp.float32)))) + 1e-9)
    print(json.dumps({"ok": bool(np.isfinite(err)), "rel": rel}))
""")


def test_a2a_multidevice_subprocess(tmp_path):
    script = tmp_path / "a2a.py"
    script.write_text(MULTIDEV)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, timeout=300, env=env, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]
    assert rec["rel"] < 1.0  # same scale; routing/drops may differ slightly
