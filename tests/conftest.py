"""Shared fixtures: the trained tiny LM used by every serving-path suite.

Training once per session keeps the paged-KV and scheduler suites cheap;
the brief training makes greedy logit gaps decisive, so token-identity
assertions are robust to FP8 KV noise.
"""
import pytest

from repro.models.config import ArchConfig


def tiny_lm_cfg():
    return ArchConfig(
        name="kvtest", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=64, attn_kind="gqa",
        norm_kind="layernorm", act_kind="relu", mlp_gated=False,
        use_bias=True, pos_embedding="learned", tie_embeddings=True,
        max_position=128, attn_chunk=128,
    )


@pytest.fixture(scope="session")
def trained_tiny():
    """A briefly-trained tiny LM: greedy logit gaps are decisive, so
    token-identity assertions are robust to FP8 KV noise."""
    from repro.data.pipeline import DataConfig
    from repro.optimizer import AdamWConfig
    from repro.runtime.train import TrainLoopConfig, train_loop

    cfg = tiny_lm_cfg()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=3)
    oc = AdamWConfig(lr=8e-3, warmup=20, total_steps=150)
    state, _ = train_loop(cfg, dc, oc, TrainLoopConfig(steps=150, log_every=150))
    return cfg, state.params
