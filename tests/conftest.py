"""Shared fixtures: the trained tiny LM used by every serving-path suite.

Training once per session keeps the paged-KV and scheduler suites cheap;
the brief training makes greedy logit gaps decisive, so token-identity
assertions are robust to FP8 KV noise.
"""
import pytest

from repro.models.config import ArchConfig


def tiny_lm_cfg():
    return ArchConfig(
        name="kvtest", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=64, attn_kind="gqa",
        norm_kind="layernorm", act_kind="relu", mlp_gated=False,
        use_bias=True, pos_embedding="learned", tie_embeddings=True,
        max_position=128, attn_chunk=128,
    )


@pytest.fixture(scope="session")
def trained_tiny():
    """A briefly-trained tiny LM: greedy logit gaps are decisive, so
    token-identity assertions are robust to FP8 KV noise."""
    from repro.data.pipeline import DataConfig
    from repro.optimizer import AdamWConfig
    from repro.runtime.train import TrainLoopConfig, train_loop

    cfg = tiny_lm_cfg()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=3)
    oc = AdamWConfig(lr=8e-3, warmup=20, total_steps=150)
    state, _ = train_loop(cfg, dc, oc, TrainLoopConfig(steps=150, log_every=150))
    return cfg, state.params


@pytest.fixture(scope="session")
def trained_tiny_mla():
    """A briefly-trained MLA smoke config (minicpm3 shape) for paged-vs-
    legacy greedy parity through the latent decode kernel."""
    from repro.configs import get_smoke
    from repro.data.pipeline import DataConfig
    from repro.optimizer import AdamWConfig
    from repro.runtime.train import TrainLoopConfig, train_loop

    cfg = get_smoke("minicpm3-4b")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=5)
    oc = AdamWConfig(lr=6e-3, warmup=20, total_steps=150)
    state, _ = train_loop(cfg, dc, oc, TrainLoopConfig(steps=150, log_every=150))
    return cfg, state.params


@pytest.fixture(scope="session")
def trained_tiny_encdec():
    """A briefly-trained whisper smoke config. The synthetic corpus drives
    the decoder; frames are random per step, so the learned logit gaps come
    from token structure and stay decisive under any request's frames."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_smoke
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.launch.steps import TrainState, make_train_step
    from repro.optimizer import AdamWConfig, adamw_init
    from repro import models

    cfg = get_smoke("whisper-tiny")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=24, global_batch=8, seed=7)
    oc = AdamWConfig(lr=6e-3, warmup=20, total_steps=150)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    state = TrainState(params=params, opt=adamw_init(params, oc))
    step_fn = jax.jit(make_train_step(cfg, oc), donate_argnums=(0,))
    data = SyntheticLM(dc)
    frng = np.random.default_rng(11)
    for step in range(150):
        b = dict(data.batch(step))
        b["frames"] = jnp.asarray(frng.normal(
            size=(dc.global_batch, cfg.encoder_seq, cfg.d_model)
        ).astype(np.float32))
        state, _ = step_fn(state, b)
    return cfg, state.params
