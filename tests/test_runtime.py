"""Runtime subsystems: data determinism, checkpoint atomicity + elastic
restore, straggler policy, gradient compression, overlap kernel, train loop
smoke + resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, latest_step, restore, save
from repro.configs import get_smoke
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optimizer import AdamWConfig
from repro.runtime.compress import compress_tree, decompress_tree, make_fp8_compressor
from repro.runtime.straggler import StragglerPolicy
from repro.runtime.train import TrainLoopConfig, train_loop


class TestData:
    def test_deterministic_and_stateless(self):
        dc = DataConfig(vocab_size=512, seq_len=32, global_batch=4, seed=7)
        a = SyntheticLM(dc).batch(13)
        b = SyntheticLM(dc).batch(13)  # fresh instance, same (seed, step)
        np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))

    def test_host_sharding_disjoint_seeds(self):
        k = dict(vocab_size=512, seq_len=16, global_batch=8, seed=7, n_hosts=2)
        h0 = SyntheticLM(DataConfig(host_index=0, **k)).batch(3)
        h1 = SyntheticLM(DataConfig(host_index=1, **k)).batch(3)
        assert h0["tokens"].shape == (4, 16)
        assert not np.array_equal(np.asarray(h0["tokens"]), np.asarray(h1["tokens"]))

    def test_labels_shifted(self):
        dc = DataConfig(vocab_size=128, seq_len=16, global_batch=2)
        b = SyntheticLM(dc).batch(0)
        assert b["tokens"].shape == b["labels"].shape

    def test_learnable_structure(self):
        """Grammar tokens should make bigram statistics non-uniform."""
        dc = DataConfig(vocab_size=64, seq_len=256, global_batch=8)
        b = np.asarray(SyntheticLM(dc).batch(0)["tokens"])
        _, counts = np.unique(b, return_counts=True)
        assert counts.max() > 3 * counts.mean()


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        save(str(tmp_path), 5, tree)
        out = restore(str(tmp_path), tree)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
        assert out["b"]["c"].dtype == jnp.bfloat16

    def test_atomicity_ignores_torn_writes(self, tmp_path):
        tree = {"a": jnp.zeros((2,))}
        save(str(tmp_path), 1, tree)
        # simulate a torn write: tmp dir without manifest
        os.makedirs(tmp_path / "step_00000002.tmp0")
        assert latest_step(str(tmp_path)) == 1

    def test_elastic_restore_new_sharding(self, tmp_path):
        """Save unsharded, restore onto an explicit (1-device) sharding —
        the topology-independence path."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.launch.mesh import make_mesh

        tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
        save(str(tmp_path), 3, tree)
        mesh = make_mesh((1,), ("model",))
        sh = {"w": NamedSharding(mesh, P(None, None))}
        out = restore(str(tmp_path), tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))

    def test_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last=2, every=1)
        tree = {"a": jnp.zeros((2,))}
        for s in range(1, 6):
            mgr.maybe_save(s, tree)
        steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
        assert len(steps) == 2 and steps[-1].endswith("00000005")


class TestStraggler:
    def test_detection_and_reassignment(self):
        p = StragglerPolicy(n_hosts=4, threshold=1.5, patience=2)
        for step in range(4):
            for h in range(4):
                p.record(h, 1.0 if h != 2 else 3.0, now=100.0 + step)
            slow = p.stragglers()
        assert slow == [2]
        backup = p.reassign_shard(2)
        assert backup != 2

    def test_dead_host_eviction(self):
        p = StragglerPolicy(n_hosts=3, heartbeat_timeout_s=10)
        for h in range(3):
            p.record(h, 1.0, now=100.0)
        p.record(0, 1.0, now=200.0)
        p.record(1, 1.0, now=200.0)
        dead = p.dead_hosts(now=200.0)
        assert dead == [2]
        p.evict(2)
        assert p.live_count() == 2


class TestCompression:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        grads = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32) * 1e-3)}
        c = compress_tree(grads)
        out = decompress_tree(c, grads)
        rel = float(jnp.linalg.norm(grads["w"] - out["w"]) / jnp.linalg.norm(grads["w"]))
        assert rel < 0.05  # E4M3 relative quantization error

    def test_pow2_scale(self):
        grads = {"w": jnp.ones((8, 8)) * 0.37}
        (q, scale), = jax.tree.leaves(compress_tree(grads),
                                      is_leaf=lambda x: isinstance(x, tuple))
        log = np.log2(float(scale))
        assert abs(log - round(log)) < 1e-6

    def test_compressor_in_train_step(self):
        """A train step with fp8 grad compression still reduces the loss
        direction (sanity: params move, no NaNs)."""
        cfg = get_smoke("olmo-1b")
        from repro import models
        from repro.launch.steps import TrainState, make_train_step
        from repro.optimizer import adamw_init

        params = models.init_params(cfg, jax.random.PRNGKey(0))
        opt_cfg = AdamWConfig(lr=1e-3)
        state = TrainState(params, adamw_init(params, opt_cfg))
        step = make_train_step(cfg, opt_cfg, grad_compress=make_fp8_compressor())
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab_size),
        }
        new_state, metrics = jax.jit(step)(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        moved = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
            state.params, new_state.params)
        assert max(jax.tree.leaves(moved)) > 0


class TestOverlap:
    def test_ring_ag_matmul_matches_dense(self):
        from repro.launch.mesh import make_mesh
        from repro.runtime.overlap import ring_ag_matmul

        mesh = make_mesh((1, 1), ("data", "model"))
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(2, 8, 16)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(12, 16)).astype(np.float32))
        y = ring_ag_matmul(x, w, mesh)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w.T), rtol=1e-5)


class TestTrainLoop:
    def test_loss_decreases_and_resumes(self, tmp_path):
        cfg = get_smoke("opt-125m")
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4, seed=1)
        oc = AdamWConfig(lr=3e-3, warmup=5, total_steps=40)
        lc = TrainLoopConfig(steps=30, ckpt_dir=str(tmp_path), ckpt_every=10,
                             log_every=5)
        state, hist = train_loop(cfg, dc, oc, lc)
        first, last = hist[0]["nll"], hist[-1]["nll"]
        assert last < first, (first, last)
        assert latest_step(str(tmp_path)) == 30

        # resume continues from the checkpoint, not from scratch
        lc2 = TrainLoopConfig(steps=35, ckpt_dir=str(tmp_path), ckpt_every=10,
                              log_every=5)
        state2, hist2 = train_loop(cfg, dc, oc, lc2)
        assert hist2[0]["step"] >= 30
