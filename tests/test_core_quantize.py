"""Tests for FGQ weight quantization, token-wise activation quantization,
GPTQ, LoRC and the M1/M2 scale constraints — including the paper's
directional claims at the mechanism level."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    constrain_scales_m1,
    constrain_scales_m2,
    fake_quantize_act,
    fake_quantize_weight,
    gptq_quantize,
    hessian_init,
    hessian_update,
    lorc_apply,
    lorc_compensate,
    quantize_act_tokenwise,
    quantize_weight,
)


def _rand_w(rng, out=64, inp=128, outlier=0.0):
    w = rng.normal(size=(out, inp)).astype(np.float32) * 0.02
    if outlier:
        idx = rng.integers(0, inp, size=out)
        w[np.arange(out), idx] += outlier * np.sign(rng.normal(size=out))
    return jnp.asarray(w)


class TestWeightQuant:
    def test_group_shapes(self):
        rng = np.random.default_rng(0)
        w = _rand_w(rng, 32, 256)
        qt = quantize_weight(w, "fp4_e2m1", group_size=64)
        assert qt.scale.shape == (32, 4)
        assert qt.values.shape == (32, 256)

    @pytest.mark.parametrize("fmt", ["fp4_e2m1", "fp4_e3m0", "int4", "int8", "fp8_e4m3"])
    def test_quant_dequant_error_bounded(self, fmt):
        rng = np.random.default_rng(1)
        w = _rand_w(rng, 32, 128)
        w_hat = fake_quantize_weight(w, fmt, group_size=32)
        rel = float(jnp.linalg.norm(w - w_hat) / jnp.linalg.norm(w))
        # 4-bit ~< 20% relative error on gaussians, 8-bit ~< 3%
        assert rel < (0.25 if "4" in fmt else 0.04), (fmt, rel)

    def test_finer_groups_reduce_error(self):
        rng = np.random.default_rng(2)
        w = _rand_w(rng, 32, 256, outlier=1.0)
        errs = []
        for g in (256, 64, 16):
            w_hat = fake_quantize_weight(w, "int4", group_size=g)
            errs.append(float(jnp.linalg.norm(w - w_hat)))
        assert errs[0] > errs[1] > errs[2]

    def test_paper_claim_fp4_beats_int4_with_outliers(self):
        """Fig 2 mechanism: on outlier-heavy rows the FP grid wins."""
        rng = np.random.default_rng(3)
        w = _rand_w(rng, 64, 256, outlier=1.5)
        e_fp = float(jnp.linalg.norm(w - fake_quantize_weight(w, "fp4_e2m1", 256)))
        e_int = float(jnp.linalg.norm(w - fake_quantize_weight(w, "int4", 256)))
        assert e_fp < e_int

    def test_paper_claim_e2m1_beats_e3m0(self):
        """Table A.1 mechanism: E2M1 > E3M0 for weight quantization."""
        rng = np.random.default_rng(4)
        w = _rand_w(rng, 64, 256)
        e_21 = float(jnp.linalg.norm(w - fake_quantize_weight(w, "fp4_e2m1", 64)))
        e_30 = float(jnp.linalg.norm(w - fake_quantize_weight(w, "fp4_e3m0", 64)))
        assert e_21 < e_30


class TestActQuant:
    def test_tokenwise_scale_shape(self):
        x = jnp.ones((4, 7, 16))
        q, s = quantize_act_tokenwise(x, "fp8_e4m3")
        assert s.shape == (4, 7, 1)
        assert q.shape == x.shape

    def test_paper_claim_fp8_beats_int8_on_skewed_acts(self):
        """Fig 1/2: ReLU-style skewed activations with outliers — FP8 wins."""
        rng = np.random.default_rng(5)
        x = np.abs(rng.normal(size=(64, 512)).astype(np.float32)) ** 3  # heavy right skew
        x[:, 0] += 100.0  # outlier feature
        x = jnp.asarray(x)
        e_fp = float(jnp.linalg.norm(x - fake_quantize_act(x, "fp8_e4m3")))
        e_int = float(jnp.linalg.norm(x - fake_quantize_act(x, "int8")))
        assert e_fp < e_int

    def test_identity_for_none(self):
        x = jnp.ones((3, 5))
        assert fake_quantize_act(x, "none") is x


class TestScaleConstraints:
    def test_m1_powers_of_two(self):
        s = jnp.asarray([[0.3, 1.0, 0.11, 2.5]])
        s1 = constrain_scales_m1(s)
        logs = np.log2(np.asarray(s1))
        np.testing.assert_allclose(logs, np.round(logs))
        # ceil: constrained >= original
        assert bool(jnp.all(s1 >= s))

    def test_m2_structure(self):
        rng = np.random.default_rng(6)
        s = jnp.asarray(np.abs(rng.normal(size=(8, 16))).astype(np.float32) + 0.01)
        m2 = constrain_scales_m2(s)
        # every constrained scale is s_max * 2^-k
        recon = m2.s_max * jnp.exp2(-m2.shifts.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(m2.scales), np.asarray(recon), rtol=1e-6)
        assert bool(jnp.all(m2.shifts >= 0))
        # the max scale itself is preserved exactly
        np.testing.assert_allclose(
            np.asarray(jnp.max(m2.scales, axis=-1)), np.asarray(m2.s_max[:, 0])
        )

    def test_paper_claim_m2_better_than_m1(self):
        """Table 3: M2 approximates the original scales far better."""
        rng = np.random.default_rng(7)
        s = jnp.asarray(np.abs(rng.normal(size=(32, 16))).astype(np.float32) + 0.01)
        e1 = float(jnp.linalg.norm(s - constrain_scales_m1(s)))
        e2 = float(jnp.linalg.norm(s - constrain_scales_m2(s).scales))
        assert e2 < e1

    def test_m2_shift_bounds(self):
        """k is clipped to [0, max_shift] even for pathological ratios."""
        s = jnp.asarray([[1.0, 1e-12, 1e-30, 0.5]])
        for max_shift in (4, 31):
            m2 = constrain_scales_m2(s, max_shift=max_shift)
            k = np.asarray(m2.shifts)
            assert k.min() >= 0 and k.max() <= max_shift, (max_shift, k)
            # the clipped entries still reconstruct as s_max * 2^-k
            recon = np.asarray(m2.s_max) * 2.0 ** (-k.astype(np.float64))
            np.testing.assert_allclose(np.asarray(m2.scales), recon, rtol=1e-6)

    def test_pow2_scales_idempotent(self):
        """Scales that already sit on the pow-2 lattice pass through both
        constraints exactly (M1 bit-for-bit; M2 under either rounding)."""
        s = jnp.asarray([[2.0**-7, 2.0**-3, 2.0**0, 2.0**5]])
        np.testing.assert_array_equal(np.asarray(constrain_scales_m1(s)),
                                      np.asarray(s))
        for rounding in ("ceil", "floor"):
            m2 = constrain_scales_m2(s, rounding=rounding)
            np.testing.assert_array_equal(np.asarray(m2.scales), np.asarray(s))
            # exact integer shifts: log2 ratios are integers already
            assert np.array_equal(np.asarray(m2.shifts), [[12, 8, 5, 0]])

    def test_dequant_roundtrip_vs_unconstrained(self):
        """Constrained-scale dequantization stays close to the unconstrained
        FGQ roundtrip: M2 within ~1/3 extra error, M1 (coarse pow-2 snap)
        bounded by 2x, and the error ordering unconstrained <= m2 <= m1."""
        rng = np.random.default_rng(8)
        w = _rand_w(rng, out=32, inp=128, outlier=0.3)

        def rt_err(scale):
            qt = quantize_weight(w, "fp4_e2m1", group_size=32, scale=scale)
            return float(jnp.linalg.norm(w - qt.dequantize()))

        base_scale = quantize_weight(w, "fp4_e2m1", group_size=32).scale
        e_raw = rt_err(None)
        e_m2 = rt_err(constrain_scales_m2(base_scale).scales)
        e_m1 = rt_err(constrain_scales_m1(base_scale))
        assert e_raw <= e_m2 * (1 + 1e-6) <= e_m1 * (1 + 1e-6), (e_raw, e_m2, e_m1)
        assert e_m2 < 1.35 * e_raw, (e_raw, e_m2)
        assert e_m1 < 2.0 * e_raw, (e_raw, e_m1)

    def test_m2_floor_rounding_never_saturates(self):
        """rounding='floor' keeps every constrained scale >= the raw scale,
        so content quantized with it cannot clip (the KV-cache contract);
        'ceil' (the paper's weight path) snaps at-or-below."""
        rng = np.random.default_rng(9)
        s = jnp.asarray(np.abs(rng.normal(size=(16, 8))).astype(np.float32) + 1e-3)
        lo = constrain_scales_m2(s, rounding="floor").scales
        hi = constrain_scales_m2(s, rounding="ceil").scales
        assert bool(jnp.all(lo >= s * (1 - 1e-6)))
        assert bool(jnp.all(lo < 2 * s))
        assert bool(jnp.all(hi <= s * (1 + 1e-6)))


class TestGPTQ:
    def _calib(self, rng, n=512, d=64, correlated=True):
        x = rng.normal(size=(n, d)).astype(np.float32)
        if correlated:
            mix = rng.normal(size=(d, d)).astype(np.float32) * 0.3 + np.eye(d, dtype=np.float32)
            x = x @ mix
        return jnp.asarray(x)

    def test_hessian_accumulation(self):
        rng = np.random.default_rng(8)
        x = self._calib(rng, n=256, d=16)
        st = hessian_init(16)
        st = hessian_update(st, x[:128])
        st = hessian_update(st, x[128:])
        expect = 2.0 * (np.asarray(x).T @ np.asarray(x)) / 256
        np.testing.assert_allclose(np.asarray(st.h), expect, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("fmt", ["int4", "fp4_e2m1"])
    def test_gptq_beats_rtn(self, fmt):
        """The point of GPTQ: lower layer-output error than round-to-nearest."""
        rng = np.random.default_rng(9)
        d, out = 128, 64
        w = _rand_w(rng, out, d)
        x = self._calib(rng, n=2048, d=d)
        st = hessian_update(hessian_init(d), x)
        w_gptq, _ = gptq_quantize(w, st.h, fmt, group_size=64, block=32)
        w_rtn = fake_quantize_weight(w, fmt, group_size=64)
        y = x @ w.T
        e_gptq = float(jnp.linalg.norm(y - x @ w_gptq.T))
        e_rtn = float(jnp.linalg.norm(y - x @ w_rtn.T))
        assert e_gptq < e_rtn, (e_gptq, e_rtn)

    def test_gptq_values_on_grid(self):
        rng = np.random.default_rng(10)
        d = 64
        w = _rand_w(rng, 16, d)
        x = self._calib(rng, n=512, d=d)
        st = hessian_update(hessian_init(d), x)
        _, qt = gptq_quantize(w, st.h, "fp4_e2m1", group_size=32, block=32)
        from repro.core.formats import value_grid

        grid = value_grid("fp4_e2m1")
        vals = np.unique(np.asarray(qt.values))
        assert set(vals.tolist()) <= set(grid.tolist())

    def test_gptq_m2_scales_pow2_structure(self):
        rng = np.random.default_rng(11)
        d = 128
        w = _rand_w(rng, 16, d)
        x = self._calib(rng, n=512, d=d)
        st = hessian_update(hessian_init(d), x)
        _, qt = gptq_quantize(w, st.h, "fp4_e2m1", group_size=32, scale_mode="m2", block=32)
        s = np.asarray(qt.scale)  # (16, 4)
        smax = s.max(axis=1, keepdims=True)
        ratio = smax / s
        np.testing.assert_allclose(np.log2(ratio), np.round(np.log2(ratio)), atol=1e-5)


class TestLoRC:
    def test_lorc_reduces_error(self):
        rng = np.random.default_rng(12)
        w = _rand_w(rng, 64, 128)
        w_q = fake_quantize_weight(w, "fp4_e2m1", group_size=64)
        fac = lorc_compensate(w, w_q, rank=8)
        w_comp = lorc_apply(w_q, fac)
        assert float(jnp.linalg.norm(w - w_comp)) < float(jnp.linalg.norm(w - w_q))

    def test_lorc_rank_monotone(self):
        rng = np.random.default_rng(13)
        w = _rand_w(rng, 64, 128)
        w_q = fake_quantize_weight(w, "int4", group_size=64)
        errs = [
            float(jnp.linalg.norm(w - lorc_apply(w_q, lorc_compensate(w, w_q, rank=r))))
            for r in (2, 8, 32)
        ]
        assert errs[0] > errs[1] > errs[2]

    def test_lorc_shapes(self):
        rng = np.random.default_rng(14)
        w = _rand_w(rng, 48, 96)
        w_q = fake_quantize_weight(w, "int4", group_size=48)
        fac = lorc_compensate(w, w_q, rank=8)
        assert fac.a.shape == (48, 8) and fac.b.shape == (8, 96)
