"""Declared key schemas for the benchmark JSON snapshots.

The bench scripts emit ``BENCH_serving.json`` / ``BENCH_kernels.json``
as flat ``{key: float}`` dicts, and the CI gate steps read specific
keys back out. A renamed or silently-dropped key used to fail only at
whichever gate happened to read it (or worse, a presence-only gate kept
passing while the metric vanished). This module is the single declared
contract: every key each bench section emits, checked both ways —
missing declared keys fail, undeclared stray keys fail, and every value
must be a finite number.

    python -m benchmarks.schema BENCH_serving.json serving
    python -m benchmarks.schema BENCH_serving.json serving sharded
    python -m benchmarks.schema BENCH_kernels.json kernels

Sections name the bench entrypoints (``benchmarks.run --only <name>``)
whose keys the file is expected to hold. ``sharded`` merges into the
serving snapshot rather than owning a file, so the committed repo state
validates as ``serving sharded`` while the serving-smoke CI job (which
regenerates the file from scratch) validates as ``serving`` alone.
Stdlib-only on purpose: the bench-schema CI job runs it without jax.
"""
import json
import math
import sys

# serving_bench (benchmarks.run --only serving)
SERVING_KEYS = frozenset({
    "prefix_cache/hit_rate",
    "prefix_cache/prefill_tokens_saved",
    "serving/alternating/engine_utilization",
    "serving/alternating/programs",
    "serving/alternating/tokens_per_sec",
    "serving/degraded/failed",
    "serving/degraded/injected_faults",
    "serving/degraded/spill_integrity_failures",
    "serving/degraded/survivor_tps_ratio",
    "serving/failed/clean",
    "serving/fp4/bytes_per_token_ratio",
    "serving/fp4/frozen_pages_transcoded",
    "serving/fp4/greedy_agreement",
    "serving/fp4/resident_tokens_ratio",
    "serving/fp4/warm_tps",
    "serving/mixed/engine_utilization",
    "serving/mixed/programs",
    "serving/mixed/tokens_per_sec",
    "serving/poisson/itl_ms_p50",
    "serving/poisson/itl_ms_p95",
    "serving/poisson/tokens_per_sec",
    "serving/poisson/ttft_ms_p50",
    "serving/poisson/ttft_ms_p95",
    "serving/poisson_alternating/itl_ms_p50",
    "serving/poisson_alternating/itl_ms_p95",
    "serving/poisson_alternating/tokens_per_sec",
    "serving/poisson_alternating/ttft_ms_p50",
    "serving/poisson_alternating/ttft_ms_p95",
    "serving/preemptions/token_budget",
    "serving/resumes/token_budget",
    "serving/sampling/tps_ratio_vs_greedy",
    "serving/steps/reserve",
    "serving/steps/token_budget",
    "serving/tokens_per_sec/prefix_cold",
    "serving/tokens_per_sec/prefix_warm",
    "serving/tokens_per_sec/reserve",
    "serving/tokens_per_sec/sampled",
    "serving/tokens_per_sec/token_budget",
    "speedup/prefix_cache_tokens_per_sec",
    "speedup/serving_tokens_per_sec",
    "utilization/reserve_worst_case",
    "utilization/token_budget",
})

# sharded_serving_bench (--only sharded); merged into BENCH_serving.json
SHARDED_KEYS = frozenset({
    "serving/sharded/devices",
    "serving/sharded/greedy_agreement",
    "serving/sharded/residency_devices",
    "serving/sharded/residency_max_bytes",
    "serving/sharded/residency_min_bytes",
    "serving/sharded/tokens_per_sec",
    "serving/sharded/tokens_per_sec_single",
    "serving/sharded/tps_ratio_vs_single",
})

# kernel_microbench (--only kernels) -> BENCH_kernels.json
KERNEL_KEYS = frozenset({
    "kernel/act_quant_pallas_interp",
    "kernel/act_quant_ref",
    "kernel/mla_materialized_decode",
    "kernel/mla_paged_decode",
    "kernel/mono_decode_max_seq",
    "kernel/paged_decode_attn_pallas_interp",
    "kernel/paged_decode_attn_ref",
    "kernel/paged_decode_true_ctx",
    "kernel/w4a8_fused_decode64",
    "kernel/w4a8_fused_lorc16",
    "kernel/w4a8_fused_m256",
    "kernel/w4a8_matmul_pallas_interp",
    "kernel/w4a8_matmul_ref",
    "kernel/w4a8_split_decode64",
    "kernel/w4a8_split_lorc16",
    "kernel/w4a8_split_m256",
    "speedup/mla_paged_decode",
    "speedup/paged_decode_true_ctx",
    "speedup/w4a8_fused_decode64",
    "speedup/w4a8_fused_lorc16",
    "speedup/w4a8_fused_m256",
})

SECTIONS = {
    "serving": SERVING_KEYS,
    "sharded": SHARDED_KEYS,
    "kernels": KERNEL_KEYS,
}


def validate(payload, sections):
    """Return a list of violation strings (empty = the file conforms)."""
    unknown = [s for s in sections if s not in SECTIONS]
    if unknown:
        return [f"unknown section(s) {unknown}; declared: "
                f"{sorted(SECTIONS)}"]
    declared = frozenset().union(*(SECTIONS[s] for s in sections))
    got = set(payload)
    bad = []
    for k in sorted(declared - got):
        bad.append(f"missing declared key: {k}")
    for k in sorted(got - declared):
        bad.append(f"undeclared key (add it to benchmarks/schema.py or "
                   f"stop emitting it): {k}")
    for k in sorted(got & declared):
        v = payload[k]
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            bad.append(f"non-numeric value for {k}: {v!r}")
        elif not math.isfinite(v):
            bad.append(f"non-finite value for {k}: {v!r}")
    return bad


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) < 2:
        print(__doc__, file=sys.stderr)
        return 2
    path, sections = argv[0], argv[1:]
    with open(path) as f:
        payload = json.load(f)
    bad = validate(payload, sections)
    if bad:
        print(f"{path} violates the declared bench schema "
              f"({'+'.join(sections)}):", file=sys.stderr)
        for line in bad:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(f"{path}: {len(payload)} keys conform to the declared "
          f"{'+'.join(sections)} schema")
    return 0


if __name__ == "__main__":
    sys.exit(main())
