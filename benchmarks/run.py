"""Benchmark harness — one function per paper table/figure plus the
roofline table and kernel microbenchmarks.

Prints ``name,us_per_call,derived`` CSV at the end (harness contract).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def kernel_microbench(tiny: bool = False):
    """us/call of the quantization primitives (CPU timings — relative cost
    of ref vs pallas-interpret paths; TPU wall-time needs real hardware).

    Times the fused single-pass pipeline against the split three-pass path
    (act_quant -> HBM -> matmul -> LoRC matmuls) on every shape and emits
    BENCH_kernels.json (name -> us_per_call, plus explicit ``speedup/*``
    keys the CI benchmark-smoke job gates on) so the perf trajectory is
    tracked across PRs. Asserts the fused path is never slower than split.

    ``tiny`` (CI smoke / REPRO_BENCH_TINY=1): shrunken shapes + a reduced
    autotune candidate set so the job finishes in seconds.
    """
    import json

    from repro.core.policy import QuantPolicy
    from repro.core.ptq import pack_linear
    from repro.kernels import ref
    from repro.kernels.act_quant import act_quant_pallas
    from repro.kernels.w4a8_fused import w4a8_fused_matmul_pallas
    from repro.kernels.w4a8_matmul import w4a8_matmul_pallas
    from .common import timed

    tiny = tiny or os.environ.get("REPRO_BENCH_TINY") == "1"
    rng = np.random.default_rng(0)
    d = 256 if tiny else 1024
    x = jnp.asarray(rng.normal(size=(64 if tiny else 256, d)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(d, d)).astype(np.float32) * 0.05)
    pl_w = pack_linear(w, QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3",
                                      group_size=256, scale_mode="m2"))
    xq = jnp.asarray(rng.normal(size=(x.shape[0], d)).astype(np.float32)).astype(jnp.bfloat16)

    rows = []
    print("\n== kernel microbench (CPU) ==")
    aq_ref = jax.jit(lambda v: ref.act_quant_ref(v, "fp8_e4m3"))
    t = timed(aq_ref, x)
    rows.append(("kernel/act_quant_ref", t, 0.0))
    t2 = timed(lambda v: act_quant_pallas(v, "fp8_e4m3", interpret=True), x)
    rows.append(("kernel/act_quant_pallas_interp", t2, 0.0))
    mm_ref = jax.jit(lambda v: ref.w4a8_matmul_ref(v, pl_w.codes, pl_w.scale))
    t3 = timed(mm_ref, xq)
    rows.append(("kernel/w4a8_matmul_ref", t3, 0.0))
    t4 = timed(lambda v: w4a8_matmul_pallas(v, pl_w.codes, pl_w.scale,
                                            s_max=pl_w.s_max, shifts=pl_w.shifts,
                                            interpret=True), xq)
    rows.append(("kernel/w4a8_matmul_pallas_interp", t4, 0.0))

    # ---- fused single-pass vs split three-pass, per shape -----------------
    # The fused path runs with autotuned block sizes (the sweep also
    # populates the persistent cache the ops dispatch layer reads), the
    # split path with its production defaults — i.e. each path as deployed.
    # Shapes: prefill (256 tokens), slot-batched decode (64 concurrent
    # serving slots x 1 token), and a LoRC-heavy projection. (Single-digit-M
    # decode is omitted: CPU-interpret emulation overhead swamps the fusion
    # win there; on TPU that bandwidth-bound case is where fusion wins most,
    # and the autotune cache remains the arbiter on real hardware.)
    from repro.kernels import autotune

    if tiny:
        shapes = [("m256", 64, 256, 256, 0), ("decode64", 16, 256, 256, 0),
                  ("lorc16", 16, 256, 256, 8)]
        candidates = ((128, 128), (64, 128), (16, 128), (8, 128))
    else:
        shapes = [("m256", 256, 1024, 1024, 0), ("decode64", 64, 1024, 1024, 0),
                  ("lorc16", 64, 512, 1024, 16)]
        candidates = autotune.DEFAULT_CANDIDATES
    slower = []
    for tag, m, n, k, rank in shapes:
        pw = pack_linear(
            jnp.asarray(rng.normal(size=(n, k)).astype(np.float32) * 0.05),
            QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", group_size=256,
                        scale_mode="m2", lorc_rank=rank))
        xs = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(jnp.bfloat16)

        def split(v, pw=pw):
            qv, sc = act_quant_pallas(v, pw.a_fmt, interpret=True)
            xqv = (qv * sc).astype(jnp.bfloat16)
            y = w4a8_matmul_pallas(xqv, pw.codes, pw.scale, s_max=pw.s_max,
                                   shifts=pw.shifts, group_size=256, interpret=True)
            if pw.lorc_a is not None:
                y = y + (xqv @ pw.lorc_b.T.astype(jnp.bfloat16)).astype(jnp.bfloat16) \
                    @ pw.lorc_a.T.astype(jnp.bfloat16)
            return y

        def fused(v, bm, bn, pw=pw):
            return w4a8_fused_matmul_pallas(
                v, pw.codes, pw.scale, pw.s_max, pw.shifts, pw.lorc_a, pw.lorc_b,
                w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", group_size=256,
                bm=bm, bn=bn, interpret=True)

        sig = dict(batch=1, m=m, n=n, k=k, w_fmt="fp4_e2m1", a_fmt="fp8_e4m3",
                   group_size=256, m2=True, lorc_rank=rank)
        bm, bn = autotune.autotune_gemm(
            lambda bm, bn: (lambda: fused(xs, bm, bn)),
            autotune.cache_key("fused", **sig), candidates=candidates,
            dims=(m, n))

        # interleave the two paths so slow box-load drift hits both equally;
        # tiny mode (CI smoke) takes more reps — shapes are cheap there and
        # shared runners are noisy, and the speedup gate sits at exactly 1.0x
        jax.block_until_ready(split(xs))
        jax.block_until_ready(fused(xs, bm, bn))
        t_split, t_fused = [], []
        for _ in range(21 if tiny else 9):
            t0 = time.perf_counter()
            jax.block_until_ready(split(xs))
            t_split.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(fused(xs, bm, bn))
            t_fused.append(time.perf_counter() - t0)
        med = lambda a: sorted(a)[len(a) // 2] * 1e6
        ts, tf = med(t_split), med(t_fused)
        rows.append((f"kernel/w4a8_split_{tag}", ts, 0.0))
        rows.append((f"kernel/w4a8_fused_{tag}", tf, ts / tf))
        if tf > ts:
            slower.append((tag, tf, ts))

    # ---- paged FP8 decode attention (tracked, not gated: on CPU the
    # pallas path runs under the interpreter, so only the jnp-oracle
    # number is a meaningful trend line) -----------------------------------
    from repro.kernels import ops as kops
    from repro.runtime import kv_cache as kvc

    kv, hd, page, pp, b = (2, 32, 8, 2, 2) if tiny else (4, 64, 16, 4, 4)
    pool = kvc.init_gqa_pool(1, b * pp, page, kv, hd, "fp8_e4m3")
    kc = jnp.asarray(rng.normal(size=(1, 1, pp * page, kv, hd)).astype(np.float32))
    pt = np.zeros((b, pp), np.int32)
    for r in range(b):
        ids = np.arange(r * pp, (r + 1) * pp, dtype=np.int32)
        pt[r] = ids
        pool = kvc.splice_prefill(pool, {"k": kc, "v": kc}, ids, pp * page)
    layer = {k: v[0] for k, v in pool.items()}
    qd = jnp.asarray(rng.normal(size=(b, kv * 2, hd)).astype(np.float32))
    lens = jnp.full((b,), pp * page, jnp.int32)
    ptj = jnp.asarray(pt)
    prev = kops.get_backend()
    try:
        kops.set_backend("ref")
        t_ref = timed(jax.jit(lambda q: kops.paged_decode_attn(q, layer, ptj, lens)), qd)
        rows.append(("kernel/paged_decode_attn_ref", t_ref, 0.0))
        kops.set_backend("pallas")
        t_pal = timed(lambda q: kops.paged_decode_attn(q, layer, ptj, lens), qd)
        rows.append(("kernel/paged_decode_attn_pallas_interp", t_pal, 0.0))
    finally:
        kops.set_backend(prev)

    for name, us, _ in rows:
        print(f"{name:36s} {us:10.1f} us/call")

    payload = {name: us for name, us, _ in rows}
    # explicit speedup keys: the CI benchmark-smoke job fails the build if
    # any of these regresses below 1.0x
    for tag, _m, _n, _k, _r in shapes:
        split = payload[f"kernel/w4a8_split_{tag}"]
        fusedt = payload[f"kernel/w4a8_fused_{tag}"]
        payload[f"speedup/w4a8_fused_{tag}"] = split / fusedt
    out_path = os.path.join(os.path.dirname(__file__), "..", "BENCH_kernels.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"[wrote {os.path.normpath(out_path)}]")
    assert not slower, f"fused slower than split on: {slower}"
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter of benchmarks")
    ap.add_argument("--skip-tables", action="store_true",
                    help="skip the (slow) trained-model paper tables")
    args = ap.parse_args()

    from . import paper_tables as pt
    from .roofline_table import roofline_table

    benches = [
        ("fig2", pt.fig2_outlier_vector),
        ("fig1", pt.fig1_activation_stats),
        ("table1", pt.table1_act_quant),
        ("table2", pt.table2_quant_matrix),
        ("table3", pt.table3_scale_constraints),
        ("tableA1", pt.table_a1_fp4_formats),
        ("roofline", roofline_table),
        ("kernels", kernel_microbench),
    ]
    slow = {"fig1", "table1", "table2", "table3", "tableA1"}

    rows = []
    failures = []
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        if args.skip_tables and name in slow:
            continue
        t0 = time.time()
        try:
            rows.extend(fn() or [])
            print(f"[{name} done in {time.time() - t0:.0f}s]")
        except AssertionError as e:  # directional-claim violation
            failures.append((name, str(e)))
            print(f"[{name} CLAIM FAILED: {e}]")
        except Exception as e:  # noqa: BLE001
            failures.append((name, f"{type(e).__name__}: {e}"))
            print(f"[{name} ERROR: {e}]")

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived:.6g}")
    if failures:
        print(f"\n{len(failures)} benchmark(s) failed: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
