"""Benchmark harness — one function per paper table/figure plus the
roofline table and kernel microbenchmarks.

Prints ``name,us_per_call,derived`` CSV at the end (harness contract).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def kernel_microbench(tiny: bool = False):
    """us/call of the quantization primitives (CPU timings — relative cost
    of ref vs pallas-interpret paths; TPU wall-time needs real hardware).

    Times the fused single-pass pipeline against the split three-pass path
    (act_quant -> HBM -> matmul -> LoRC matmuls) on every shape and emits
    BENCH_kernels.json (name -> us_per_call, plus explicit ``speedup/*``
    keys the CI benchmark-smoke job gates on) so the perf trajectory is
    tracked across PRs. Asserts the fused path is never slower than split.

    ``tiny`` (CI smoke / REPRO_BENCH_TINY=1): shrunken shapes + a reduced
    autotune candidate set so the job finishes in seconds.
    """
    import json

    from repro.core.policy import QuantPolicy
    from repro.core.ptq import pack_linear
    from repro.kernels import ref
    from repro.kernels.act_quant import act_quant_pallas
    from repro.kernels.w4a8_fused import w4a8_fused_matmul_pallas
    from repro.kernels.w4a8_matmul import w4a8_matmul_pallas
    from .common import timed

    tiny = tiny or os.environ.get("REPRO_BENCH_TINY") == "1"
    rng = np.random.default_rng(0)
    d = 256 if tiny else 1024
    x = jnp.asarray(rng.normal(size=(64 if tiny else 256, d)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(d, d)).astype(np.float32) * 0.05)
    pl_w = pack_linear(w, QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3",
                                      group_size=256, scale_mode="m2"))
    xq = jnp.asarray(rng.normal(size=(x.shape[0], d)).astype(np.float32)).astype(jnp.bfloat16)

    rows = []
    print("\n== kernel microbench (CPU) ==")
    aq_ref = jax.jit(lambda v: ref.act_quant_ref(v, "fp8_e4m3"))
    t = timed(aq_ref, x)
    rows.append(("kernel/act_quant_ref", t, 0.0))
    t2 = timed(lambda v: act_quant_pallas(v, "fp8_e4m3", interpret=True), x)
    rows.append(("kernel/act_quant_pallas_interp", t2, 0.0))
    mm_ref = jax.jit(lambda v: ref.w4a8_matmul_ref(v, pl_w.codes, pl_w.scale))
    t3 = timed(mm_ref, xq)
    rows.append(("kernel/w4a8_matmul_ref", t3, 0.0))
    t4 = timed(lambda v: w4a8_matmul_pallas(v, pl_w.codes, pl_w.scale,
                                            s_max=pl_w.s_max, shifts=pl_w.shifts,
                                            interpret=True), xq)
    rows.append(("kernel/w4a8_matmul_pallas_interp", t4, 0.0))

    # ---- fused single-pass vs split three-pass, per shape -----------------
    # The fused path runs with autotuned block sizes (the sweep also
    # populates the persistent cache the ops dispatch layer reads), the
    # split path with its production defaults — i.e. each path as deployed.
    # Shapes: prefill (256 tokens), slot-batched decode (64 concurrent
    # serving slots x 1 token), and a LoRC-heavy projection. (Single-digit-M
    # decode is omitted: CPU-interpret emulation overhead swamps the fusion
    # win there; on TPU that bandwidth-bound case is where fusion wins most,
    # and the autotune cache remains the arbiter on real hardware.)
    from repro.kernels import autotune

    if tiny:
        shapes = [("m256", 64, 256, 256, 0), ("decode64", 16, 256, 256, 0),
                  ("lorc16", 16, 256, 256, 8)]
        candidates = ((128, 128), (64, 128), (16, 128), (8, 128))
    else:
        shapes = [("m256", 256, 1024, 1024, 0), ("decode64", 64, 1024, 1024, 0),
                  ("lorc16", 64, 512, 1024, 16)]
        candidates = autotune.DEFAULT_CANDIDATES
    slower = []
    for tag, m, n, k, rank in shapes:
        pw = pack_linear(
            jnp.asarray(rng.normal(size=(n, k)).astype(np.float32) * 0.05),
            QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", group_size=256,
                        scale_mode="m2", lorc_rank=rank))
        xs = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(jnp.bfloat16)

        def split(v, pw=pw):
            qv, sc = act_quant_pallas(v, pw.a_fmt, interpret=True)
            xqv = (qv * sc).astype(jnp.bfloat16)
            y = w4a8_matmul_pallas(xqv, pw.codes, pw.scale, s_max=pw.s_max,
                                   shifts=pw.shifts, group_size=256, interpret=True)
            if pw.lorc_a is not None:
                y = y + (xqv @ pw.lorc_b.T.astype(jnp.bfloat16)).astype(jnp.bfloat16) \
                    @ pw.lorc_a.T.astype(jnp.bfloat16)
            return y

        def fused(v, bm, bn, pw=pw):
            return w4a8_fused_matmul_pallas(
                v, pw.codes, pw.scale, pw.s_max, pw.shifts, pw.lorc_a, pw.lorc_b,
                w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", group_size=256,
                bm=bm, bn=bn, interpret=True)

        sig = dict(batch=1, m=m, n=n, k=k, w_fmt="fp4_e2m1", a_fmt="fp8_e4m3",
                   group_size=256, m2=True, lorc_rank=rank)
        bm, bn = autotune.autotune_gemm(
            lambda bm, bn: (lambda: fused(xs, bm, bn)),
            autotune.cache_key("fused", **sig), candidates=candidates,
            dims=(m, n))

        # interleave the two paths so slow box-load drift hits both equally;
        # tiny mode (CI smoke) takes more reps — shapes are cheap there and
        # shared runners are noisy, and the speedup gate sits at exactly 1.0x
        jax.block_until_ready(split(xs))
        jax.block_until_ready(fused(xs, bm, bn))
        t_split, t_fused = [], []
        for _ in range(21 if tiny else 9):
            t0 = time.perf_counter()
            jax.block_until_ready(split(xs))
            t_split.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(fused(xs, bm, bn))
            t_fused.append(time.perf_counter() - t0)
        med = lambda a: sorted(a)[len(a) // 2] * 1e6
        ts, tf = med(t_split), med(t_fused)
        rows.append((f"kernel/w4a8_split_{tag}", ts, 0.0))
        rows.append((f"kernel/w4a8_fused_{tag}", tf, ts / tf))
        if tf > ts:
            slower.append((tag, tf, ts))

    # ---- paged FP8 decode attention (tracked, not gated: on CPU the
    # pallas path runs under the interpreter, so only the jnp-oracle
    # number is a meaningful trend line) -----------------------------------
    from repro.kernels import ops as kops
    from repro.runtime import kv_cache as kvc

    kv, hd, page, pp, b = (2, 32, 8, 2, 2) if tiny else (4, 64, 16, 4, 4)
    pool = kvc.init_gqa_pool(1, b * pp, page, kv, hd, "fp8_e4m3")
    kc = jnp.asarray(rng.normal(size=(1, 1, pp * page, kv, hd)).astype(np.float32))
    pt = np.zeros((b, pp), np.int32)
    for r in range(b):
        ids = np.arange(r * pp, (r + 1) * pp, dtype=np.int32)
        pt[r] = ids
        pool = kvc.splice_prefill(pool, {"k": kc, "v": kc}, ids, pp * page)
    layer = {k: v[0] for k, v in pool.items()}
    qd = jnp.asarray(rng.normal(size=(b, kv * 2, hd)).astype(np.float32))
    lens = jnp.full((b,), pp * page, jnp.int32)
    ptj = jnp.asarray(pt)
    prev = kops.get_backend()
    try:
        kops.set_backend("ref")
        t_ref = timed(jax.jit(lambda q: kops.paged_decode_attn(q, layer, ptj, lens)), qd)
        rows.append(("kernel/paged_decode_attn_ref", t_ref, 0.0))
        kops.set_backend("pallas")
        t_pal = timed(lambda q: kops.paged_decode_attn(q, layer, ptj, lens), qd)
        rows.append(("kernel/paged_decode_attn_pallas_interp", t_pal, 0.0))
    finally:
        kops.set_backend(prev)

    # ---- paged decode attention vs the monolithic engine (CI-gated
    # speedup/* trend line): paged decode gathers + attends only the pages
    # a row actually owns (true context), where the legacy engine attends
    # — and masks — the full max_seq row it reserved. Sized so compute
    # dominates dispatch overhead; the work ratio (`over`x tokens) keeps
    # the >= 1.0x gate far from CPU timing noise. -------------------------
    pb, pkv, pg2, phd, ppp = (8, 2, 4, 64, 8) if tiny else (8, 4, 4, 64, 16)
    over = 8  # max_seq = over x the true context
    t_true = ppp * 16
    max_ctx = t_true * over
    pool2 = kvc.init_gqa_pool(1, pb * ppp, 16, pkv, phd, "fp8_e4m3")
    pt2 = np.zeros((pb, ppp), np.int32)
    kc2 = jnp.asarray(rng.normal(size=(1, 1, t_true, pkv, phd)).astype(np.float32))
    for r in range(pb):
        ids = np.arange(r * ppp, (r + 1) * ppp, dtype=np.int32)
        pt2[r] = ids
        pool2 = kvc.splice_prefill(pool2, {"k": kc2, "v": kc2}, ids, t_true)
    layer2 = {k: v[0] for k, v in pool2.items()}
    q2 = jnp.asarray(rng.normal(size=(pb, pkv * pg2, phd)).astype(np.float32))
    lens2 = jnp.full((pb,), t_true, jnp.int32)
    pt2j = jnp.asarray(pt2)
    kfull = jnp.asarray(rng.normal(size=(pb, max_ctx, pkv, phd))
                        .astype(np.float32)).astype(jnp.bfloat16)
    vfull = jnp.asarray(rng.normal(size=(pb, max_ctx, pkv, phd))
                        .astype(np.float32)).astype(jnp.bfloat16)

    def legacy_decode(qv):
        kf = jnp.repeat(kfull, pg2, axis=2)
        vf = jnp.repeat(vfull, pg2, axis=2)
        s = jnp.einsum("bhd,bthd->bht", qv.astype(jnp.bfloat16), kf,
                       preferred_element_type=jnp.float32)
        s = s / np.sqrt(phd)
        s = jnp.where(jnp.arange(max_ctx)[None, None] < lens2[:, None, None],
                      s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bht,bthd->bhd", p.astype(jnp.bfloat16), vf,
                          preferred_element_type=jnp.float32)

    prev = kops.get_backend()
    try:
        kops.set_backend("ref")
        t_paged = timed(jax.jit(
            lambda q: kops.paged_decode_attn(q, layer2, pt2j, lens2)), q2)
        t_mono = timed(jax.jit(legacy_decode), q2)
    finally:
        kops.set_backend(prev)
    rows.append(("kernel/paged_decode_true_ctx", t_paged, t_mono / t_paged))
    rows.append(("kernel/mono_decode_max_seq", t_mono, 0.0))

    # ---- MLA latent paged decode (CI-gated speedup/* trend line): the
    # kernel's latent dataflow — scores against k = concat(ckv, krope)
    # with v = the ckv view, so attention runs in (r + dr) dims per token
    # — vs the *materialized* gathered decode an engine without absorbed
    # MLA runs over the same pages: dequantize the latent table, expand it
    # through wk_b/wv_b into per-head K/V (h x (nope + v) dims per token),
    # standard softmax attention. Both paths are timed end to end from
    # (q_nope, q_rope) to the (B, H, v) head outputs, so the absorbed
    # path's q/out projections are charged too; the latent side is timed
    # via its jnp oracle (on CPU the pallas path runs the interpreter —
    # same convention as the paged_decode_true_ctx line).
    # sized so the head expansion dominates dispatch overhead: the
    # materialized baseline writes T x H x (nope + v) while the latent
    # path stays at T x (r + dr) — an 8x byte ratio at these dims, which
    # is what keeps the >= 1.0x gate far from CPU timing noise
    mb, mh, mr, mdr, mpage, mpp = ((2, 16, 64, 32, 16, 64) if tiny
                                   else (2, 32, 128, 64, 16, 64))
    m_nope, m_v = mr // 2, mr // 2
    mpool = kvc.init_mla_pool(1, mb * mpp, mpage, mr, mdr, "fp8_e4m3")
    mpt = np.zeros((mb, mpp), np.int32)
    mt = mpp * mpage
    ckv_src = jnp.asarray(rng.normal(size=(1, 1, mt, mr)).astype(np.float32))
    kr_src = jnp.asarray(rng.normal(size=(1, 1, mt, mdr)).astype(np.float32))
    for r in range(mb):
        ids = np.arange(r * mpp, (r + 1) * mpp, dtype=np.int32)
        mpt[r] = ids
        mpool = kvc.splice_prefill(mpool, {"ckv": ckv_src, "krope": kr_src},
                                   ids, mt)
    mlayer = {k: v[0] for k, v in mpool.items()}
    mptj = jnp.asarray(mpt)
    mlens = jnp.full((mb,), mt, jnp.int32)
    mscale = 1.0 / float(m_nope + mdr) ** 0.5
    qn = jnp.asarray(rng.normal(size=(mb, mh, m_nope)).astype(np.float32))
    qr_q = jnp.asarray(rng.normal(size=(mb, mh, mdr)).astype(np.float32))
    wk_b = jnp.asarray(rng.normal(size=(mh, m_nope, mr)).astype(np.float32)
                       * 0.1).astype(jnp.bfloat16)
    wv_b = jnp.asarray(rng.normal(size=(mh, m_v, mr)).astype(np.float32)
                       * 0.1).astype(jnp.bfloat16)
    mstate = kvc.PagedState(mptj, mlens)

    def mla_latent(qn, qr):  # absorbed: q/out fold through wk_b/wv_b
        q_lat = jnp.einsum("bhn,hnr->bhr", qn.astype(jnp.bfloat16), wk_b,
                           preferred_element_type=jnp.float32)
        ctx = kops.paged_mla_decode_attn(q_lat, qr, mlayer, mptj, mlens,
                                         mscale)
        return jnp.einsum("bhr,hvr->bhv", ctx.astype(jnp.bfloat16), wv_b,
                          preferred_element_type=jnp.float32)

    def mla_materialized(qn, qr):  # expand pages to per-head K/V, attend
        ckv = kvc.gather_pages(mlayer, "ckv", mstate).astype(jnp.bfloat16)
        krope = kvc.gather_pages(mlayer, "krope", mstate).astype(jnp.bfloat16)
        k_nope = jnp.einsum("btr,hnr->bthn", ckv, wk_b,
                            preferred_element_type=jnp.float32)
        vh = jnp.einsum("btr,hvr->bthv", ckv, wv_b,
                        preferred_element_type=jnp.float32)
        # a materialized engine holds the expanded per-head K/V as real
        # tensors (that is the thing MLA's absorbed form avoids); the
        # barrier stops XLA from algebraically re-absorbing the expansion
        # into the score contraction and un-materializing the baseline
        k_nope, vh = jax.lax.optimization_barrier((k_nope, vh))
        s = (jnp.einsum("bhn,bthn->bht", qn, k_nope)
             + jnp.einsum("bhd,btd->bht", qr, krope.astype(jnp.float32))
             ) * mscale
        msk = jnp.where(jnp.arange(mt)[None, None] < mlens[:, None, None],
                        0.0, -1e30)
        att = jax.nn.softmax(s + msk, axis=-1)
        return jnp.einsum("bht,bthv->bhv", att, vh)

    prev = kops.get_backend()
    try:
        kops.set_backend("ref")
        f_lat = jax.jit(mla_latent)
        f_mat = jax.jit(mla_materialized)
        # interleaved min-of timing (like the fused-vs-split loop): load
        # noise only ever inflates a wall time, so the per-path minimum is
        # the stable estimator for a >= 1.0x gate on shared runners
        jax.block_until_ready(f_lat(qn, qr_q))
        jax.block_until_ready(f_mat(qn, qr_q))
        ts_lat, ts_mat = [], []
        for _ in range(21 if tiny else 9):
            t0 = time.perf_counter()
            jax.block_until_ready(f_lat(qn, qr_q))
            ts_lat.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(f_mat(qn, qr_q))
            ts_mat.append(time.perf_counter() - t0)
        t_mla, t_mat = min(ts_lat) * 1e6, min(ts_mat) * 1e6
    finally:
        kops.set_backend(prev)
    rows.append(("kernel/mla_paged_decode", t_mla, t_mat / t_mla))
    rows.append(("kernel/mla_materialized_decode", t_mat, 0.0))

    for name, us, _ in rows:
        print(f"{name:36s} {us:10.1f} us/call")

    payload = {name: us for name, us, _ in rows}
    # explicit speedup keys: the CI benchmark-smoke job fails the build if
    # any of these regresses below 1.0x
    for tag, _m, _n, _k, _r in shapes:
        split = payload[f"kernel/w4a8_split_{tag}"]
        fusedt = payload[f"kernel/w4a8_fused_{tag}"]
        payload[f"speedup/w4a8_fused_{tag}"] = split / fusedt
    payload["speedup/paged_decode_true_ctx"] = (
        payload["kernel/mono_decode_max_seq"]
        / payload["kernel/paged_decode_true_ctx"])
    payload["speedup/mla_paged_decode"] = (
        payload["kernel/mla_materialized_decode"]
        / payload["kernel/mla_paged_decode"])
    out_path = os.path.join(os.path.dirname(__file__), "..", "BENCH_kernels.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"[wrote {os.path.normpath(out_path)}]")
    assert not slower, f"fused slower than split on: {slower}"
    return rows


def serving_bench(tiny: bool = False):
    """Long-tail ``max_new`` serving workload: reserve-on-admit vs the
    token-budget scheduler on the same tight FP8 page pool.

    Reserve-on-admit charges worst-case pages (prompt + max_new) up front,
    so one long-tail request blocks slots the short requests could use;
    the token-budget scheduler charges prompt + headroom, grows pages on
    demand and preempts by page steal. Same model, same requests, same
    pool — the only variable is the admission policy, and both schedulers
    produce bit-identical greedy tokens (resume is token-identical), so
    tokens/sec and slot utilization are directly comparable.

    Emits BENCH_serving.json: utilization + tokens/sec per scheduler and
    the ``speedup/serving_tokens_per_sec`` key the serving-smoke CI job
    gates >= 1.0x (plus ``utilization/token_budget >=
    utilization/reserve_worst_case``). Each scheduler is run twice and the
    second (hot jit cache) run is timed, so wall-clock compares steady
    state, not compilation.

    A second workload measures the content-addressed prefix cache: every
    request shares a 64-token system prompt (the dominant shape of heavy
    multi-user traffic), served cold (``prefix_cache=False`` — every
    request re-prefills and re-quantizes the identical K/V) vs warm (full
    scale-frozen prompt pages are shared by refcount; only the per-request
    tail streams through the prefill). Both produce token-identical greedy
    output, so ``speedup/prefix_cache_tokens_per_sec`` isolates the cache;
    the serving-smoke CI job gates it >= 1.0x and the hit rate > 0.
    """
    import json

    from repro import models
    from repro.models.config import ArchConfig
    from repro.runtime.serve import (CachePolicy, Request, SamplingParams,
                                     SchedulerConfig, Server, ServerConfig)

    tiny = tiny or os.environ.get("REPRO_BENCH_TINY") == "1"
    cfg = ArchConfig(
        name="serve-bench", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, attn_kind="gqa",
        norm_kind="layernorm", act_kind="relu", mlp_gated=False,
        use_bias=True, pos_embedding="learned", tie_embeddings=True,
        max_position=256, attn_chunk=128,
    )
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 10 if tiny else 24
    base_new, tail_new, tail_every = 4, 64, 2
    slots, page, pool_pages = 4, 8, (10 if tiny else 14)
    max_seq = 96 if tiny else 160
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=int(n)).tolist()
               for n in rng.integers(4, 9, size=n_req)]
    max_new = [tail_new if i % tail_every == 0 else base_new
               for i in range(n_req)]

    def run(sched):
        srv = Server(params, cfg,
                     ServerConfig(slots=slots, max_seq=max_seq,
                                  cache=CachePolicy(active_fmt="fp8_e4m3"), page_size=page,
                                  pool_pages=pool_pages, a_fmt=None,
                                  scheduler=SchedulerConfig(policy=sched)))
        reqs = [Request(rid=i, prompt=list(p), max_new=mn)
                for i, (p, mn) in enumerate(zip(prompts, max_new))]
        for r in reqs:
            srv.submit(r)
        t0 = time.perf_counter()
        done = srv.run_until_drained()
        dt = time.perf_counter() - t0
        assert len(done) == n_req, (sched, len(done))
        toks = sum(len(r.out) for r in reqs)
        return {"sec": dt, "tokens": toks, "tps": toks / dt,
                "util": srv.utilization(), "steps": srv.stats["steps"],
                "preemptions": srv.stats["preemptions"],
                "resumes": srv.stats["resumes"],
                "failed": srv.stats["failed"],
                "outs": {r.rid: tuple(r.out) for r in reqs}}

    print("\n== serving bench (long-tail max_new, CPU) ==")
    run("reserve")        # warmup: compile every prefill/decode shape
    run("token_budget")

    def timed_best(sched):
        # best-of-2 (min-wall-time) per scheduler: noise only ever inflates
        # wall time, so the min is the stable estimator — keeps the strict
        # in-bench tokens/sec assert from flaking on a loaded CI runner
        a, b = run(sched), run(sched)
        return a if a["tps"] >= b["tps"] else b

    rv = timed_best("reserve")
    tb = timed_best("token_budget")
    assert rv["outs"] == tb["outs"], \
        "schedulers must produce bit-identical greedy tokens"
    for name, r in (("reserve", rv), ("token_budget", tb)):
        print(f"{name:14s} {r['tokens']} tok in {r['sec']:.2f}s = "
              f"{r['tps']:7.1f} tok/s | util {r['util']:.3f} | "
              f"{r['steps']} steps | {r['preemptions']} preemptions")

    # ---- shared-system-prompt workload: cold vs prefix-cached -------------
    # 8 requests sharing a 64-token system prompt; cold re-prefills (and
    # re-quantizes) the identical K/V per request, warm maps the frozen
    # pages by refcount and streams only the tail. Greedy outputs are
    # token-identical (full pages are scale-frozen and the shared prefix
    # is chunk-aligned), so tokens/sec isolates the prefill saved.
    shared = rng.integers(1, cfg.vocab_size, size=64).tolist()
    pprompts = [shared + rng.integers(1, cfg.vocab_size, size=int(t)).tolist()
                for t in rng.integers(4, 8, size=8)]

    def run_prefix(warm):
        srv = Server(params, cfg,
                     ServerConfig(slots=slots, max_seq=96, cache=CachePolicy(active_fmt="fp8_e4m3"),
                                  page_size=8, a_fmt=None, prefix_cache=warm,
                                  scheduler=SchedulerConfig(policy="token_budget")))
        reqs = [Request(rid=i, prompt=list(p), max_new=8)
                for i, p in enumerate(pprompts)]
        for r in reqs:
            srv.submit(r)
        t0 = time.perf_counter()
        done = srv.run_until_drained()
        dt = time.perf_counter() - t0
        assert len(done) == len(reqs)
        toks = sum(len(r.out) for r in reqs)
        return {"sec": dt, "tps": toks / dt,
                "hit_rate": srv.prefix_hit_rate(),
                "hit_tokens": srv.stats["prefix_hit_tokens"],
                "prefill_tokens": srv.stats["prefill_tokens"],
                "outs": {r.rid: tuple(r.out) for r in reqs}}

    run_prefix(False)  # warmup: compile every prefill/decode shape
    run_prefix(True)

    def timed_best_prefix(warm):
        a, b = run_prefix(warm), run_prefix(warm)
        return a if a["tps"] >= b["tps"] else b

    cold = timed_best_prefix(False)
    warm = timed_best_prefix(True)
    assert cold["outs"] == warm["outs"], \
        "prefix cache must not change greedy tokens"
    assert warm["hit_tokens"] == 7 * 64, warm["hit_tokens"]  # all but req 0
    assert cold["hit_tokens"] == 0
    assert warm["prefill_tokens"] == cold["prefill_tokens"] - 7 * 64
    print(f"{'prefix_cold':14s} {cold['sec']:.2f}s = {cold['tps']:7.1f} tok/s"
          f" | hit rate {cold['hit_rate']:.3f}")
    print(f"{'prefix_warm':14s} {warm['sec']:.2f}s = {warm['tps']:7.1f} tok/s"
          f" | hit rate {warm['hit_rate']:.3f} "
          f"({warm['hit_tokens']} prefill tokens saved)")

    # ---- mixed-precision cache policy: packed FP4 frozen prefix pages -----
    # The same warm shared-prefix workload under CachePolicy(frozen_fmt=
    # 'fp4_e2m1'): shared pages are transcoded FP8 -> packed FP4 exactly
    # once, at the freeze point. Gated in-bench: the frozen page class must
    # cost <= 0.55x the active-FP8 bytes-per-token, greedy streams must
    # stay within bounded divergence of the all-FP8 warm run (only the
    # frozen prefix differs in precision), and the drain audit must hold
    # with mixed-format pages live.
    def run_fp4(policy):
        srv = Server(params, cfg,
                     ServerConfig(slots=slots, max_seq=96, cache=policy,
                                  page_size=8, a_fmt=None,
                                  scheduler=SchedulerConfig(policy="token_budget")))
        reqs = [Request(rid=i, prompt=list(p), max_new=8)
                for i, p in enumerate(pprompts)]
        for r in reqs:
            srv.submit(r)
        t0 = time.perf_counter()
        done = srv.run_until_drained()
        dt = time.perf_counter() - t0
        assert len(done) == len(reqs)
        srv.audit()  # mixed-format pages live at drain
        toks = sum(len(r.out) for r in reqs)
        return {"sec": dt, "tps": toks / dt,
                "residency": srv.cache_residency(),
                "frozen_pages": srv.stats["fp4_frozen_pages"],
                "failed": srv.stats["failed"],
                "outs": {r.rid: tuple(r.out) for r in reqs}}

    mixed = CachePolicy(active_fmt="fp8_e4m3", frozen_fmt="fp4_e2m1")
    warm8 = run_fp4(CachePolicy(active_fmt="fp8_e4m3"))
    run_fp4(mixed)  # warmup: compile the mixed-table decode shapes
    a, b = run_fp4(mixed), run_fp4(mixed)
    warm4 = a if a["tps"] >= b["tps"] else b
    assert warm8["outs"] == warm["outs"], \
        "CachePolicy(active_fmt='fp8_e4m3') must reproduce the kv_fmt run"
    r8, r4 = warm8["residency"], warm4["residency"]
    # the page-class density win: frozen FP4 vs active FP8 bytes-per-token
    fp4_density = r4["frozen_bytes_per_token"] / r4["active_bytes_per_token"]
    assert fp4_density <= 0.55, fp4_density
    assert warm4["frozen_pages"] >= len(shared) // 8, warm4["frozen_pages"]
    # blended residency win: tokens held per live byte at drain, fp4 / fp8
    resident_ratio = ((r4["resident_tokens"] / r4["live_bytes"])
                      / (r8["resident_tokens"] / r8["live_bytes"]))
    assert resident_ratio >= 1.0, resident_ratio
    # bounded greedy divergence: only the frozen prefix pages differ in
    # precision, so the bulk of both streams must agree position-wise
    fp4_total = fp4_agree = 0
    for rid in warm8["outs"]:
        for x, y in zip(warm8["outs"][rid], warm4["outs"][rid]):
            fp4_total += 1
            fp4_agree += x == y
    fp4_agreement = fp4_agree / fp4_total
    assert fp4_agreement >= 0.5, (fp4_agreement, warm8["outs"], warm4["outs"])
    print(f"{'frozen_fp4':14s} {warm4['sec']:.2f}s = {warm4['tps']:7.1f} "
          f"tok/s | frozen/active B/token {fp4_density:.3f}x | "
          f"{warm4['frozen_pages']} pages transcoded | greedy agreement "
          f"{fp4_agreement:.2f}")

    # ---- degraded mode: the token-budget workload under injected faults ----
    # Same requests, same pool, plus a deterministic fault schedule: two
    # NaN-poisoned decode rows, the first host spill bit-flipped, one
    # transient allocator-exhaustion tick, and the pool auditor running
    # every 4 decode steps. Gates graceful degradation: exactly the
    # injected requests fail (strict=False), every survivor's greedy
    # tokens match the fault-free run bit-exactly (the corrupted spill
    # recovers through the CRC-verify -> tail re-prefill path), and
    # survivor throughput stays >= 0.8x clean — fault handling must not
    # stall the batch. ``serving/degraded/survivor_tps_ratio`` is
    # deliberately NOT a ``speedup/*`` key: those are gated >= 1.0 by
    # convention, and degraded mode is allowed to cost up to 20%.
    from repro.runtime.serve import FaultPlan

    def run_degraded():
        plan = FaultPlan(seed=0, nan_logits=((6, 0), (9, 2)),
                         corrupt_spills=(0,), alloc_fail_ticks=(12,))
        srv = Server(params, cfg,
                     ServerConfig(slots=slots, max_seq=max_seq,
                                  cache=CachePolicy(active_fmt="fp8_e4m3"), page_size=page,
                                  pool_pages=pool_pages, a_fmt=None,
                                  strict=False, audit_every=4,
                                  scheduler=SchedulerConfig(policy="token_budget")),
                     faults=plan)
        reqs = [Request(rid=i, prompt=list(p), max_new=mn)
                for i, (p, mn) in enumerate(zip(prompts, max_new))]
        for r in reqs:
            srv.submit(r)
        t0 = time.perf_counter()
        srv.run_until_drained()
        dt = time.perf_counter() - t0
        failed = {r.rid for r in reqs if r.status == "failed"}
        assert failed == {rid for (_, _, rid) in plan.nan_hits}, \
            (failed, plan.nan_hits)
        assert len(failed) == len(plan.nan_logits), \
            "every scheduled NaN row must land on a live request"
        assert srv.stats["spill_integrity_failures"] >= 1
        assert plan.corrupted_rids and plan.blocked_ticks == [12]
        for r in reqs:  # survivors are token-identical to the clean run
            if r.rid not in failed:
                assert r.status == "ok" and tuple(r.out) == tb["outs"][r.rid]
        assert srv.audit()["violations"] == 0  # pool whole at drain
        toks = sum(len(r.out) for r in reqs if r.rid not in failed)
        return {"sec": dt, "tokens": toks, "tps": toks / dt,
                "failed": len(failed),
                "integrity": srv.stats["spill_integrity_failures"],
                "injected": len(plan.nan_logits)}

    run_degraded()  # warmup: the audit/fail paths add no new jit shapes
    dga, dgb = run_degraded(), run_degraded()
    dg = dga if dga["tps"] >= dgb["tps"] else dgb
    # clean-run rate over the surviving requests only (generous to clean:
    # its wall clock also produced the failed rids' tokens)
    clean_survivor_tps = dg["tokens"] / tb["sec"]
    degraded_ratio = dg["tps"] / clean_survivor_tps
    print(f"{'degraded':14s} {dg['tokens']} surviving tok in "
          f"{dg['sec']:.2f}s = {dg['tps']:7.1f} tok/s | "
          f"{dg['failed']}/{dg['injected']} injected failures | "
          f"{dg['integrity']} spill integrity event(s) | "
          f"{degraded_ratio:.2f}x clean")
    assert rv["failed"] == 0 and tb["failed"] == 0, \
        "clean path must not fail requests"
    assert degraded_ratio >= 0.8, degraded_ratio

    # ---- sampled mode: the long-tail workload with per-request sampling ----
    # Same requests, same pool, but every request samples
    # (temperature/top-k/top-p, seed = rid). The sampling epilogue is
    # compiled into every decode step (fixed trace — greedy rows pay it
    # too), so this leg measures the marginal cost of *using* it: the
    # in-graph masks + categorical draw, plus whatever schedule drift
    # different sampled tokens cause (shorter/longer page growth). Gated
    # >= 0.9x greedy in CI; deliberately NOT a ``speedup/*`` key (those
    # are gated >= 1.0 by convention, and sampling is allowed to cost up
    # to 10%). Two runs must be token-identical: per-request seeds make
    # sampled serving as reproducible as greedy.
    def run_sampled():
        srv = Server(params, cfg,
                     ServerConfig(slots=slots, max_seq=max_seq,
                                  cache=CachePolicy(active_fmt="fp8_e4m3"), page_size=page,
                                  pool_pages=pool_pages, a_fmt=None,
                                  scheduler=SchedulerConfig(
                                      policy="token_budget")))
        reqs = [Request(rid=i, prompt=list(p), max_new=mn,
                        sampling=SamplingParams(temperature=0.8, top_k=20,
                                                top_p=0.95, seed=i))
                for i, (p, mn) in enumerate(zip(prompts, max_new))]
        for r in reqs:
            srv.submit(r)
        t0 = time.perf_counter()
        done = srv.run_until_drained()
        dt = time.perf_counter() - t0
        assert len(done) == n_req
        toks = sum(len(r.tokens) for r in done)
        return {"sec": dt, "tokens": toks, "tps": toks / dt,
                "outs": {r.rid: r.tokens for r in done}}

    run_sampled()  # warmup (no new shapes; keeps timing symmetric)
    spa, spb = run_sampled(), run_sampled()
    assert spa["outs"] == spb["outs"], \
        "seeded sampling must be run-to-run deterministic"
    sp = spa if spa["tps"] >= spb["tps"] else spb
    assert any(sp["outs"][i] != tb["outs"][i] for i in sp["outs"]), \
        "sampled leg must actually sample (outputs all match greedy)"
    sampled_ratio = sp["tps"] / tb["tps"]
    print(f"{'sampled':14s} {sp['tokens']} tok in {sp['sec']:.2f}s = "
          f"{sp['tps']:7.1f} tok/s | {sampled_ratio:.2f}x greedy")
    assert sampled_ratio >= 0.9, sampled_ratio

    # ---- mixed-engine leg: long prompts, short decodes --------------------
    # The chunked-prefill piggyback's target workload: prompts several
    # pages long, a handful of decode tokens each. The alternating engine
    # burns whole programs on prefill chunks while every decode row
    # waits; the mixed engine carries the chunk on the decode step, so
    # its decoded-tokens-per-program-slot (``Server.engine_utilization``)
    # must be strictly higher — the CI-gated claim
    # (``serving/mixed/engine_utilization`` >
    # ``serving/alternating/engine_utilization``). Greedy tokens are
    # asserted bit-identical: the fused step changes scheduling, never
    # numerics.
    mprompts = [rng.integers(1, cfg.vocab_size, size=int(t)).tolist()
                for t in rng.integers(20, 33, size=(8 if tiny else 12))]

    def run_engine(engine):
        srv = Server(params, cfg,
                     ServerConfig(slots=slots, max_seq=max_seq,
                                  cache=CachePolicy(active_fmt="fp8_e4m3"),
                                  page_size=page, a_fmt=None,
                                  scheduler=SchedulerConfig(
                                      policy="token_budget", engine=engine,
                                      prefill_token_budget=2 * page)))
        assert srv.engine == engine
        reqs = [Request(rid=i, prompt=list(p), max_new=4)
                for i, p in enumerate(mprompts)]
        for r in reqs:
            srv.submit(r)
        t0 = time.perf_counter()
        done = srv.run_until_drained()
        dt = time.perf_counter() - t0
        assert len(done) == len(reqs)
        toks = sum(len(r.out) for r in reqs)
        return {"sec": dt, "tps": toks / dt,
                "eu": srv.engine_utilization(),
                "programs": srv.stats["programs"],
                "prefill_tokens": srv.stats["prefill_tokens"],
                "outs": {r.rid: tuple(r.out) for r in reqs}}

    run_engine("alternating")  # warmup: dedicated chunk + decode programs
    run_engine("mixed")        # warmup: the fused chunk+decode family
    ea, eb = run_engine("alternating"), run_engine("alternating")
    alt = ea if ea["tps"] >= eb["tps"] else eb
    ma, mb = run_engine("mixed"), run_engine("mixed")
    mx = ma if ma["tps"] >= mb["tps"] else mb
    assert mx["outs"] == alt["outs"], \
        "mixed engine must produce bit-identical greedy tokens"
    assert mx["prefill_tokens"] == alt["prefill_tokens"]
    for name, r in (("alternating", alt), ("mixed", mx)):
        print(f"{'engine_' + name:14s} {r['sec']:.2f}s = {r['tps']:7.1f} "
              f"tok/s | {r['programs']} programs | engine util "
              f"{r['eu']:.3f}")
    assert mx["eu"] > alt["eu"], (mx["eu"], alt["eu"])

    # ---- Poisson-arrival leg: TTFT / inter-token latency ------------------
    # The drained legs measure throughput with every request queued up
    # front; real traffic arrives over time and cares about time-to-first-
    # token and inter-token latency. Clients submit into the *running*
    # scheduler through the asyncio front-end with exponential
    # inter-arrival gaps (deterministic seed), and every token's host
    # timestamp comes from the engine's decode loop (RequestResult
    # token_times -> ttft/itl). p50/p95 land in BENCH_serving.json for
    # BOTH engines (``serving/poisson/*`` is the mixed default,
    # ``serving/poisson_alternating/*`` the baseline); CI gates
    # presence, not values — wall-clock latency on a shared runner is
    # not a stable regression signal, but the keys vanishing is.
    import asyncio

    from repro.runtime.frontend import AsyncServer

    def run_poisson(engine):
        starts = np.cumsum(np.random.default_rng(7).exponential(
            scale=0.01, size=n_req))

        async def client(front, rid, delay):
            await asyncio.sleep(delay)
            async for _ in front.generate(
                    list(prompts[rid]), max_new=max_new[rid],
                    sampling=SamplingParams(temperature=0.8, top_k=20,
                                            top_p=0.95, seed=rid),
                    rid=rid):
                pass
            return front.result(rid)

        async def main():
            srv = Server(params, cfg,
                         ServerConfig(slots=slots, max_seq=max_seq,
                                      cache=CachePolicy(active_fmt="fp8_e4m3"), page_size=page,
                                      pool_pages=pool_pages, a_fmt=None,
                                      scheduler=SchedulerConfig(
                                          policy="token_budget",
                                          engine=engine)))
            front = AsyncServer(srv)
            t0 = time.perf_counter()
            results = await asyncio.gather(*[
                client(front, i, float(starts[i])) for i in range(n_req)])
            dt = time.perf_counter() - t0
            await front.close()
            return results, dt

        results, dt = asyncio.run(main())
        assert all(r is not None and r.ok for r in results)
        ttft = np.asarray([r.ttft for r in results]) * 1e3
        itl = np.asarray([g for r in results for g in r.itl]) * 1e3
        toks = sum(len(r.tokens) for r in results)
        return {"sec": dt, "tps": toks / dt,
                "ttft_ms_p50": float(np.percentile(ttft, 50)),
                "ttft_ms_p95": float(np.percentile(ttft, 95)),
                "itl_ms_p50": float(np.percentile(itl, 50)),
                "itl_ms_p95": float(np.percentile(itl, 95))}

    run_poisson("mixed")  # warmup: first async run pays residual compiles
    poa, pob = run_poisson("mixed"), run_poisson("mixed")
    po = poa if poa["tps"] >= pob["tps"] else pob
    run_poisson("alternating")
    ala, alb = run_poisson("alternating"), run_poisson("alternating")
    poalt = ala if ala["tps"] >= alb["tps"] else alb
    for name, r in (("poisson", po), ("poisson_alt", poalt)):
        print(f"{name:14s} {r['sec']:.2f}s = {r['tps']:7.1f} tok/s | "
              f"TTFT p50 {r['ttft_ms_p50']:.1f}ms "
              f"p95 {r['ttft_ms_p95']:.1f}ms"
              f" | ITL p50 {r['itl_ms_p50']:.1f}ms "
              f"p95 {r['itl_ms_p95']:.1f}ms")

    payload = {
        "serving/tokens_per_sec/reserve": rv["tps"],
        "serving/tokens_per_sec/token_budget": tb["tps"],
        "utilization/reserve_worst_case": rv["util"],
        "utilization/token_budget": tb["util"],
        "serving/steps/reserve": float(rv["steps"]),
        "serving/steps/token_budget": float(tb["steps"]),
        "serving/preemptions/token_budget": float(tb["preemptions"]),
        "serving/resumes/token_budget": float(tb["resumes"]),
        "speedup/serving_tokens_per_sec": tb["tps"] / rv["tps"],
        "serving/tokens_per_sec/prefix_cold": cold["tps"],
        "serving/tokens_per_sec/prefix_warm": warm["tps"],
        "prefix_cache/hit_rate": warm["hit_rate"],
        "prefix_cache/prefill_tokens_saved": float(warm["hit_tokens"]),
        "speedup/prefix_cache_tokens_per_sec": warm["tps"] / cold["tps"],
        "serving/failed/clean": float(rv["failed"] + tb["failed"]
                                      + warm8["failed"] + warm4["failed"]),
        "serving/fp4/bytes_per_token_ratio": fp4_density,
        "serving/fp4/resident_tokens_ratio": resident_ratio,
        "serving/fp4/warm_tps": warm4["tps"],
        "serving/fp4/greedy_agreement": fp4_agreement,
        "serving/fp4/frozen_pages_transcoded": float(warm4["frozen_pages"]),
        "serving/degraded/injected_faults": float(dg["injected"]),
        "serving/degraded/failed": float(dg["failed"]),
        "serving/degraded/spill_integrity_failures": float(dg["integrity"]),
        "serving/degraded/survivor_tps_ratio": degraded_ratio,
        "serving/tokens_per_sec/sampled": sp["tps"],
        "serving/sampling/tps_ratio_vs_greedy": sampled_ratio,
        "serving/poisson/tokens_per_sec": po["tps"],
        "serving/poisson/ttft_ms_p50": po["ttft_ms_p50"],
        "serving/poisson/ttft_ms_p95": po["ttft_ms_p95"],
        "serving/poisson/itl_ms_p50": po["itl_ms_p50"],
        "serving/poisson/itl_ms_p95": po["itl_ms_p95"],
        "serving/poisson_alternating/tokens_per_sec": poalt["tps"],
        "serving/poisson_alternating/ttft_ms_p50": poalt["ttft_ms_p50"],
        "serving/poisson_alternating/ttft_ms_p95": poalt["ttft_ms_p95"],
        "serving/poisson_alternating/itl_ms_p50": poalt["itl_ms_p50"],
        "serving/poisson_alternating/itl_ms_p95": poalt["itl_ms_p95"],
        "serving/mixed/engine_utilization": mx["eu"],
        "serving/alternating/engine_utilization": alt["eu"],
        "serving/mixed/tokens_per_sec": mx["tps"],
        "serving/alternating/tokens_per_sec": alt["tps"],
        "serving/mixed/programs": float(mx["programs"]),
        "serving/alternating/programs": float(alt["programs"]),
    }
    out_path = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"[wrote {os.path.normpath(out_path)}]")

    rows = [
        ("serving/step_reserve", rv["sec"] / rv["steps"] * 1e6, rv["tps"]),
        ("serving/step_token_budget", tb["sec"] / tb["steps"] * 1e6, tb["tps"]),
        ("serving/prefix_cold", cold["sec"] * 1e6, cold["tps"]),
        ("serving/prefix_warm", warm["sec"] * 1e6, warm["tps"]),
        ("serving/prefix_warm_fp4", warm4["sec"] * 1e6, warm4["tps"]),
        ("serving/engine_mixed", mx["sec"] * 1e6, mx["tps"]),
        ("serving/engine_alternating", alt["sec"] * 1e6, alt["tps"]),
    ]
    # the paper-level claim this PR gates in CI: on-demand paging converts
    # FP8's bytes-per-token win into strictly more concurrent work
    assert tb["util"] > rv["util"], (tb["util"], rv["util"])
    assert tb["tps"] > rv["tps"], (tb["tps"], rv["tps"])
    # ... and frozen-scale pages are bit-reusable: sharing them can only
    # remove prefill work, never slow the engine down
    assert warm["hit_rate"] > 0.0
    assert warm["tps"] >= cold["tps"], (warm["tps"], cold["tps"])
    return rows


_SHARDED_SCRIPT = r'''
import json, os, sys, time

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
import jax  # noqa: E402  (flags must be set before the backend inits)
import numpy as np  # noqa: E402

from repro import models
from repro.models.config import ArchConfig
from repro.runtime.serve import (CachePolicy, MeshPlan, Request,
                                 SchedulerConfig, Server, ServerConfig)

tiny = os.environ.get("REPRO_BENCH_TINY") == "1"
cfg = ArchConfig(
    name="serve-bench", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=128, attn_kind="gqa",
    norm_kind="layernorm", act_kind="relu", mlp_gated=False,
    use_bias=True, pos_embedding="learned", tie_embeddings=True,
    max_position=256, attn_chunk=128,
)
params = models.init_params(cfg, jax.random.PRNGKey(0))
n_req = 8 if tiny else 16
rng = np.random.default_rng(0)
prompts = [rng.integers(1, cfg.vocab_size, size=int(n)).tolist()
           for n in rng.integers(4, 9, size=n_req)]


def run(plan):
    srv = Server(params, cfg,
                 ServerConfig(slots=4, max_seq=64,
                              cache=CachePolicy(active_fmt="fp8_e4m3"),
                              page_size=8, pool_pages=12, a_fmt=None,
                              mesh=plan))
    for i, p in enumerate(prompts):
        srv.submit(Request(rid=i, prompt=list(p), max_new=8))
    t0 = time.perf_counter()
    done = srv.run_until_drained()
    dt = time.perf_counter() - t0
    assert len(done) == n_req, len(done)
    assert all(r.status == "ok" for r in done), [r.status for r in done]
    toks = sum(len(r.tokens) for r in done)
    return {"sec": dt, "tps": toks / dt,
            "outs": {r.rid: list(r.tokens) for r in done},
            "residency": srv.shard_residency()}


def best(plan):
    # best-of-2 (hot jit cache): noise only inflates wall time
    a, b = run(plan), run(plan)
    return a if a["tps"] >= b["tps"] else b


run(None)                                # warmup: compile single-device
single = best(None)
run(MeshPlan(data=1, model=2))           # warmup: compile sharded
sharded = best(MeshPlan(data=1, model=2))
agree = float(single["outs"] == sharded["outs"])
print(json.dumps({
    "devices": 2.0,
    "tokens_per_sec": sharded["tps"],
    "tokens_per_sec_single": single["tps"],
    "tps_ratio_vs_single": sharded["tps"] / single["tps"],
    "greedy_agreement": agree,
    "residency_devices": float(len(sharded["residency"])),
    "residency_min_bytes": float(min(sharded["residency"].values())),
    "residency_max_bytes": float(max(sharded["residency"].values())),
}))
'''


def sharded_serving_bench(tiny: bool = False):
    """Tensor-parallel serving leg: the same tiny GQA workload served by
    the single-device engine vs a ``MeshPlan(data=1, model=2)`` mesh of
    simulated host devices (KV pages + decode attention sharded by head).

    Runs in a subprocess because ``--xla_force_host_platform_device_count``
    must be set before the JAX backend initializes — the parent process
    has already committed to one device. Merges ``serving/sharded/*``
    keys into BENCH_serving.json (read-modify-write: ``serving_bench``
    writes the file wholesale, so this leg must not clobber it) for the
    serving-sharded-smoke CI job, which gates greedy agreement == 1.0
    and per-shard residency spread across both model shards.

    On CPU the sharded leg is expected to be *slower* than single-device
    (shard_map overhead with no real parallel hardware); the tracked
    claim is token identity + balanced residency, not CPU tokens/sec.
    """
    import json
    import subprocess
    import tempfile

    tiny = tiny or os.environ.get("REPRO_BENCH_TINY") == "1"
    root = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
    with tempfile.NamedTemporaryFile("w", suffix=".py", delete=False) as f:
        f.write(_SHARDED_SCRIPT)
        script = f.name
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    if tiny:
        env["REPRO_BENCH_TINY"] = "1"
    print("\n== sharded serving bench (2 simulated devices, CPU) ==")
    proc = subprocess.run([sys.executable, script], env=env, cwd=root,
                          capture_output=True, text=True, timeout=900)
    os.unlink(script)
    if proc.returncode != 0:
        raise RuntimeError(f"sharded bench subprocess failed:\n{proc.stderr[-2000:]}")
    res = json.loads(proc.stdout.strip().splitlines()[-1])

    out_path = os.path.join(root, "BENCH_serving.json")
    payload = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            payload = json.load(f)
    payload.update({f"serving/sharded/{k}": v for k, v in res.items()})
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"[wrote {os.path.normpath(out_path)}]")
    print(f"{'sharded(1x2)':14s} {res['tokens_per_sec']:7.1f} tok/s | "
          f"single {res['tokens_per_sec_single']:7.1f} tok/s | "
          f"ratio {res['tps_ratio_vs_single']:.2f}x | "
          f"residency {int(res['residency_devices'])} devices "
          f"[{int(res['residency_min_bytes'])}, "
          f"{int(res['residency_max_bytes'])}] bytes")

    # the claims the serving-sharded-smoke CI job gates: sharded greedy
    # decode is token-identical, and pool bytes actually land on both
    # model shards (balanced within the uint8-codes asymmetry slack)
    assert res["greedy_agreement"] == 1.0, "sharded tokens diverged"
    assert res["residency_devices"] >= 2.0, res
    assert res["residency_min_bytes"] > 0.0, res
    return [("serving/sharded_tps", 0.0, res["tokens_per_sec"]),
            ("serving/sharded_ratio", 0.0, res["tps_ratio_vs_single"])]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter of benchmarks")
    ap.add_argument("--skip-tables", action="store_true",
                    help="skip the (slow) trained-model paper tables")
    args = ap.parse_args()

    from . import paper_tables as pt
    from .roofline_table import roofline_table

    benches = [
        ("fig2", pt.fig2_outlier_vector),
        ("fig1", pt.fig1_activation_stats),
        ("table1", pt.table1_act_quant),
        ("table2", pt.table2_quant_matrix),
        ("table3", pt.table3_scale_constraints),
        ("tableA1", pt.table_a1_fp4_formats),
        ("roofline", roofline_table),
        ("kernels", kernel_microbench),
        ("serving", serving_bench),
        ("sharded", sharded_serving_bench),
    ]
    slow = {"fig1", "table1", "table2", "table3", "tableA1"}

    rows = []
    failures = []
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        if args.skip_tables and name in slow:
            continue
        t0 = time.time()
        try:
            rows.extend(fn() or [])
            print(f"[{name} done in {time.time() - t0:.0f}s]")
        except AssertionError as e:  # directional-claim violation
            failures.append((name, str(e)))
            print(f"[{name} CLAIM FAILED: {e}]")
        except Exception as e:  # noqa: BLE001
            failures.append((name, f"{type(e).__name__}: {e}"))
            print(f"[{name} ERROR: {e}]")

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived:.6g}")
    if failures:
        print(f"\n{len(failures)} benchmark(s) failed: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
