"""Roofline table from the dry-run artifacts (dryrun_results.json).

Prints the per-(arch x shape x mesh) three-term roofline and emits CSV rows.
This consumes the REQUIRED multi-pod dry-run output; run
``PYTHONPATH=src python -m repro.launch.dryrun --mesh both`` first (or let
benchmarks.run use the checked-in results).
"""
from __future__ import annotations

import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "dryrun_results.json")


def roofline_table(path: str = RESULTS):
    if not os.path.exists(path):
        print(f"[roofline] {path} missing — run repro.launch.dryrun first")
        return []
    with open(path) as f:
        results = json.load(f)
    rows = []
    print("\n== Roofline (per device; v5e: 197 TF/s, 819 GB/s HBM, 50 GB/s ICI) ==")
    hdr = (f"{'arch':18s} {'shape':12s} {'mesh':6s} {'compute':>9s} {'memory':>9s} "
           f"{'coll':>9s} {'bound':>10s} {'useful':>7s} {'roofline':>9s}")
    print(hdr)
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"], r.get("mesh", ""))):
        if r["status"] == "skipped":
            print(f"{r['arch']:18s} {r['shape']:12s} {'-':6s} "
                  f"{'skipped: ' + r['reason'][:48]}")
            continue
        if r["status"] != "ok":
            print(f"{r['arch']:18s} {r['shape']:12s} ERROR {r.get('error', '')[:60]}")
            continue
        t = r["roofline"]
        print(f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:6s} "
              f"{t['compute_s']*1e3:8.1f}m {t['memory_s']*1e3:8.1f}m "
              f"{t['collective_s']*1e3:8.1f}m {t['dominant']:>10s} "
              f"{t.get('useful_ratio', 0):7.2%} {t.get('roofline_fraction', 0):8.2%}")
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            max(t["compute_s"], t["memory_s"], t["collective_s"]) * 1e6,
            t.get("roofline_fraction", 0.0),
        ))
    return rows
