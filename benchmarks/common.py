"""Shared benchmark substrate: a small trained LM + calibration/eval data +
the PTQ->perplexity pipeline every paper-table benchmark reuses.

The paper measures perplexity of HF checkpoints on WikiText-2/PTB/C4; those
are unavailable offline, so each table is reproduced on an in-framework
OPT-style model trained on the synthetic corpus (DESIGN.md §7). Directional
claims (FP8 vs INT8, FP4 vs INT4, LoRC, M1/M2) are asserted on this testbed.

The trained checkpoint is cached under .bench_cache/ so repeated benchmark
runs are fast.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.checkpoint.manager import latest_step, restore, save
from repro.core.policy import QuantPolicy
from repro.core.ptq import gptq_quantize_lm
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.config import ArchConfig
from repro.models.losses import chunked_xent
from repro.optimizer import AdamWConfig
from repro.runtime.train import TrainLoopConfig, train_loop

CACHE = os.path.join(os.path.dirname(__file__), "..", ".bench_cache")

# OPT-mini: the paper family's shape at benchmark scale
BENCH_CFG = ArchConfig(
    name="opt-mini",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=1024,
    vocab_size=4096,
    attn_kind="gqa",
    norm_kind="layernorm",
    act_kind="relu",
    mlp_gated=False,
    use_bias=True,
    pos_embedding="learned",
    tie_embeddings=True,
    max_position=512,
    attn_chunk=512,
)
SEQ = 128
TRAIN_STEPS = int(os.environ.get("BENCH_TRAIN_STEPS", "600"))


def data_cfg(seed=0):
    return DataConfig(vocab_size=BENCH_CFG.vocab_size, seq_len=SEQ,
                      global_batch=16, seed=seed)


def trained_params(refresh: bool = False):
    """Train (or load cached) the benchmark model."""
    os.makedirs(CACHE, exist_ok=True)
    ckpt_dir = os.path.join(CACHE, f"opt_mini_{TRAIN_STEPS}")
    init = models.init_params(BENCH_CFG, jax.random.PRNGKey(0))
    if not refresh and latest_step(ckpt_dir) is not None:
        return restore(ckpt_dir, init)
    oc = AdamWConfig(lr=6e-3, warmup=50, total_steps=TRAIN_STEPS)
    lc = TrainLoopConfig(steps=TRAIN_STEPS, log_every=50)
    state, hist = train_loop(BENCH_CFG, data_cfg(), oc, lc)
    save(ckpt_dir, TRAIN_STEPS, state.params)
    print(f"  [trained opt-mini: nll {hist[0]['nll']:.3f} -> {hist[-1]['nll']:.3f}]")
    return state.params


def calib_batches(n=8, seed=99):
    src = SyntheticLM(data_cfg(seed))
    return [{"tokens": src.batch(i)["tokens"]} for i in range(n)]


def eval_ppl(params, cfg=BENCH_CFG, a_fmt=None, n_batches=4, seed=1777) -> float:
    """Perplexity on held-out synthetic batches; a_fmt simulates the
    token-wise activation quantization at eval (the paper's A8)."""
    src = SyntheticLM(data_cfg(seed))
    total_nll, total_tok = 0.0, 0.0
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    for i in range(n_batches):
        b = src.batch(i)
        hidden, _, _ = models.forward_hidden(params, cfg, b, a_fmt=a_fmt)
        nll, ntok = chunked_xent(hidden, head, b["labels"])
        total_nll += float(nll) * float(ntok)
        total_tok += float(ntok)
    return float(np.exp(total_nll / total_tok))


def quantize_with_policy(params, policy: QuantPolicy, calib=None):
    """The paper's pipeline on the benchmark model (GPTQ layer-by-layer,
    optional LoRC / scale constraints), returning dense fake-quant params."""
    calib = calib if calib is not None else calib_batches()
    return gptq_quantize_lm(params, BENCH_CFG, calib, policy)


def timed(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))  # warmup/compile
    times = []
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        times.append(time.time() - t0)
    return sorted(times)[len(times) // 2] * 1e6  # median us (CPU-noise robust)
