"""One benchmark per paper table/figure (DESIGN.md §7).

Each function prints its table and returns rows of
(name, us_per_call, derived) for the CSV contract of benchmarks.run.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import fake_quantize_act, fake_quantize_weight
from repro.core.formats import FORMATS, quantize_to_grid
from repro.core.policy import QuantPolicy

from . import common


# ---------------------------------------------------------------------------
# Figure 2 — INT8 vs FP8 on the outlier vector
# ---------------------------------------------------------------------------
def fig2_outlier_vector():
    """The paper's 15-element vector with a 100.0 outlier, quantized with
    INT8-asymmetric, FP8-E5M2 and FP8-E4M3."""
    x = jnp.asarray([0.02, -0.31, 0.11, 0.05, -0.24, 0.41, -0.08, 0.37,
                     -0.45, 0.19, -0.12, 0.33, 0.27, -0.29, 100.0], jnp.float32)

    def int8_asym(v):
        lo, hi = float(v.min()), float(v.max())
        s = (hi - lo) / 255.0
        z = np.round(-lo / s)
        q = np.clip(np.round(np.asarray(v) / s + z), 0, 255)
        return (q - z) * s

    def fp8(v, name):
        scale = float(jnp.max(jnp.abs(v))) / FORMATS[name].max_value
        return np.asarray(quantize_to_grid(v / scale, FORMATS[name])) * scale

    rows = {
        "int8_asym": int8_asym(x),
        "fp8_e5m2": fp8(x, "fp8_e5m2"),
        "fp8_e4m3": fp8(x, "fp8_e4m3"),
    }
    body = np.asarray(x[:-1])
    print("\n== Figure 2: outlier-vector quantization ==")
    print(f"{'method':12s} {'body MAE':>12s} {'outlier err':>12s}")
    out = []
    errs = {}
    for name, q in rows.items():
        body_mae = float(np.mean(np.abs(q[:-1] - body)))
        out_err = float(abs(q[-1] - 100.0))
        errs[name] = body_mae
        print(f"{name:12s} {body_mae:12.5f} {out_err:12.5f}")
        out.append((f"fig2/{name}_body_mae", 0.0, body_mae))
    # paper claim: FP8 represents the clustered body far better than INT8
    assert errs["fp8_e4m3"] < errs["int8_asym"]
    assert errs["fp8_e5m2"] < errs["int8_asym"]
    return out


# ---------------------------------------------------------------------------
# Figure 1 — activation distribution statistics per module
# ---------------------------------------------------------------------------
def _moments(a):
    a = np.asarray(a, np.float64).ravel()
    mu, sd = a.mean(), a.std() + 1e-12
    skew = float(((a - mu) ** 3).mean() / sd**3)
    kurt = float(((a - mu) ** 4).mean() / sd**4 - 3)
    return float(a.min()), float(a.max()), skew, kurt


def fig1_activation_stats():
    """Skewness/kurtosis/extremes of the four captured module inputs
    (attn.q_proj, attn.out_proj, fc1, fc2) at first/mid/last layer of the
    trained model — the mechanism behind the paper's Fig. 1."""
    from repro.models import transformer as _tf
    from repro.models.attention import _repeat_kv, _sdpa_full, block_mask
    from repro.models.layers import activation as _act
    from repro.models.layers import linear as _lin
    from repro.models.layers import norm as _norm

    cfg = common.BENCH_CFG
    params = common.trained_params()
    batch = common.calib_batches(1)[0]
    x = _tf._embed_tokens(params, cfg, batch["tokens"])
    x = x + params["pos_embed"][: x.shape[1]][None].astype(x.dtype)

    stack = params["segments"][0]
    n_layers = jax.tree.leaves(stack)[0].shape[0]
    seg = _tf.segments_for(cfg)[0]
    b, s = batch["tokens"].shape
    pos = jnp.arange(s)

    rows = []
    print("\n== Figure 1: activation distribution stats (trained opt-mini) ==")
    print(f"{'layer':>5s} {'module':>9s} {'min':>9s} {'max':>9s} {'skew':>7s} {'kurt':>7s}")
    for li in range(n_layers):
        p = jax.tree.map(lambda a: a[li], stack)
        pm, pf = p["mixer"], p["ffn"]
        h_ln = _norm(pm["ln"], x, cfg.norm_kind, cfg.norm_eps)
        hd, hq, kv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
        q = _lin(pm["attn"]["wq"], h_ln, pm["attn"].get("bq")).reshape(b, s, hq, hd)
        k = _lin(pm["attn"]["wk"], h_ln).reshape(b, s, kv, hd)
        v = _lin(pm["attn"]["wv"], h_ln, pm["attn"].get("bv")).reshape(b, s, kv, hd)
        o = _sdpa_full(q, k, v, block_mask(s, s, 0, 0, True, 0)).reshape(b, s, hq * hd)
        attn_out = _lin(pm["attn"]["wo"], o, pm["attn"].get("bo"))
        x_mid = x + attn_out
        f_ln = _norm(pf["ln"], x_mid, cfg.norm_kind, cfg.norm_eps)
        up = _lin(pf["mlp"]["up"], f_ln, pf["mlp"].get("up_b"))
        h_act = _act(up, cfg.act_kind)
        x = x_mid + _lin(pf["mlp"]["down"], h_act, pf["mlp"].get("down_b"))

        if li in (0, n_layers // 2, n_layers - 1):
            for mod, val in (("q_proj", h_ln), ("out_proj", o), ("fc1", f_ln), ("fc2", h_act)):
                mn, mx, sk, ku = _moments(val)
                print(f"{li:5d} {mod:>9s} {mn:9.3f} {mx:9.3f} {sk:7.2f} {ku:7.2f}")
                rows.append((f"fig1/L{li}_{mod}_skew", 0.0, sk))
    # the paper's observation: fc2 input (post-ReLU) is the most skewed
    fc2_skew = [r[2] for r in rows if "fc2" in r[0]]
    q_skew = [abs(r[2]) for r in rows if "q_proj" in r[0]]
    assert max(fc2_skew) > max(q_skew), "ReLU'd fc2 input should be most skewed"
    return rows


# ---------------------------------------------------------------------------
# Table 1 — FP16 vs INT8 activation quantization (W16A8)
# ---------------------------------------------------------------------------
def table1_act_quant():
    params = common.trained_params()
    rows = []
    print("\n== Table 1: activation-only quantization (W16) ==")
    base = common.eval_ppl(params)
    for label, a_fmt in (("W16A16", None), ("W16A8-INT", "int8"), ("W16A8-FP", "fp8_e4m3")):
        ppl = common.eval_ppl(params, a_fmt=a_fmt)
        print(f"{label:12s} ppl {ppl:8.3f}")
        rows.append((f"table1/{label}", 0.0, ppl))
    return rows


# ---------------------------------------------------------------------------
# Table 2 — the full W/A quantization matrix
# ---------------------------------------------------------------------------
_T2_POLICIES = [
    ("W16A16", None, None),
    ("W8A8 INT-INT", QuantPolicy(w_fmt="int8", a_fmt="int8", method="gptq"), "int8"),
    ("W8A8 INT-FP", QuantPolicy(w_fmt="int8", a_fmt="fp8_e4m3", method="gptq"), "fp8_e4m3"),
    ("W8A8 FP-FP", QuantPolicy(w_fmt="fp8_e4m3", a_fmt="fp8_e4m3", method="gptq"), "fp8_e4m3"),
    ("W4A8 INT-INT", QuantPolicy(w_fmt="int4", a_fmt="int8", method="gptq"), "int8"),
    ("W4A8 INT-FP", QuantPolicy(w_fmt="int4", a_fmt="fp8_e4m3", method="gptq"), "fp8_e4m3"),
    ("W4A8 FP-FP", QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", method="gptq"), "fp8_e4m3"),
    ("W4A8+LoRC INT-INT", QuantPolicy(w_fmt="int4", a_fmt="int8", method="gptq", lorc_rank=8), "int8"),
    ("W4A8+LoRC INT-FP", QuantPolicy(w_fmt="int4", a_fmt="fp8_e4m3", method="gptq", lorc_rank=8), "fp8_e4m3"),
    ("W4A8+LoRC FP-FP", QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", method="gptq", lorc_rank=8), "fp8_e4m3"),
]


def table2_quant_matrix():
    params = common.trained_params()
    calib = common.calib_batches()
    rows = []
    ppls = {}
    print("\n== Table 2: W/A quantization matrix (GPTQ, group 256) ==")
    for label, policy, a_fmt in _T2_POLICIES:
        if policy is None:
            ppl = common.eval_ppl(params)
        else:
            qp = common.quantize_with_policy(params, policy, calib)
            ppl = common.eval_ppl(qp, a_fmt=a_fmt)
        ppls[label] = ppl
        print(f"{label:22s} ppl {ppl:8.3f}")
        rows.append((f"table2/{label.replace(' ', '_')}", 0.0, ppl))

    # paper's directional claims on this testbed
    assert ppls["W8A8 FP-FP"] <= ppls["W8A8 INT-INT"] * 1.02, "FP8 acts >= INT8"
    assert ppls["W4A8 FP-FP"] <= ppls["W4A8 INT-INT"] * 1.02, "FP4 weights >= INT4"
    assert ppls["W4A8+LoRC FP-FP"] <= ppls["W4A8 FP-FP"] * 1.01, "LoRC helps"
    return rows


# ---------------------------------------------------------------------------
# Table 3 — power-of-2 scale constraints
# ---------------------------------------------------------------------------
def table3_scale_constraints():
    params = common.trained_params()
    calib = common.calib_batches()
    rows = []
    ppls = {}
    print("\n== Table 3: scale constraints on W4A8 FP-FP ==")
    for lorc in (0, 8):
        for mode in ("none", "m1", "m2"):
            label = f"{'lorc' if lorc else 'plain'}/{mode}"
            policy = QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3", method="gptq",
                                scale_mode=mode, lorc_rank=lorc)
            qp = common.quantize_with_policy(params, policy, calib)
            ppl = common.eval_ppl(qp, a_fmt="fp8_e4m3")
            ppls[label] = ppl
            print(f"{label:12s} ppl {ppl:8.3f}")
            rows.append((f"table3/{label}", 0.0, ppl))
    # M2 approximates better than M1 (aggregate claim)
    assert ppls["plain/m2"] <= ppls["plain/m1"] * 1.02
    return rows


# ---------------------------------------------------------------------------
# Table A.1 — E2M1 vs E3M0
# ---------------------------------------------------------------------------
def table_a1_fp4_formats():
    params = common.trained_params()
    calib = common.calib_batches()
    rows = []
    ppls = {}
    print("\n== Table A.1: FP4 weight format (A = FP8 E4M3) ==")
    for fmt in ("fp4_e2m1", "fp4_e3m0"):
        policy = QuantPolicy(w_fmt=fmt, a_fmt="fp8_e4m3", method="gptq")
        qp = common.quantize_with_policy(params, policy, calib)
        ppl = common.eval_ppl(qp, a_fmt="fp8_e4m3")
        ppls[fmt] = ppl
        print(f"{fmt:10s} ppl {ppl:8.3f}")
        rows.append((f"tableA1/{fmt}", 0.0, ppl))
    assert ppls["fp4_e2m1"] <= ppls["fp4_e3m0"] * 1.02, "E2M1 beats E3M0"
    return rows
