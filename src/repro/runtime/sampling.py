"""In-graph batched sampling for the paged serving engine.

The engine decodes every active slot in one fixed-shape jitted step, so
sampling has to be *fixed-trace* too: temperature, top-k and top-p are
per-row **array inputs** (never Python branches), compiled once into the
decode step as per-row masks over the logits. A greedy row
(``temperature == 0``) and a sampled row ride the same program — the
final ``where`` selects argmax for greedy rows, so a server that only
ever serves greedy traffic pays one extra fused epilogue, not a retrace.

Reproducibility contract: a request's token stream is a pure function of
``(params, prompt, SamplingParams)`` — independent of batch composition,
page steals, spills and resumes. Two properties deliver that:

  * the KV path is already bit-deterministic (pages restore bit-exactly,
    prefix-cache hits are scale-frozen), so the logits row a request sees
    at emitted-token index ``i`` is the same in any batch; and
  * the RNG key for emitted-token index ``i`` is
    ``fold_in(PRNGKey(seed), i)`` — split per *emitted-token index*, not
    per engine step. A step-split key would tangle a request's stream
    with whatever else happened to be scheduled that step; the per-index
    split makes the draw at index ``i`` identical whether the request ran
    solo, batched, or was stolen and resumed halfway through.

Mask semantics (mirrored by the numpy oracle in tests/test_sampling.py):
top-k keeps every logit ``>=`` the k-th largest *after* temperature
scaling (ties at the boundary are all kept — the fixed-shape threshold
compare cannot break ties, and keeping ties is the conservative side);
top-p keeps the smallest prefix of the descending-sorted distribution
whose *exclusive* cumulative probability is still ``< p`` (so the top
token always survives, and ``p = 1`` keeps everything). Survivors are
renormalized implicitly by ``categorical`` over the masked logits.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SamplingParams", "sampling_mask", "sample_tokens",
           "slot_arrays", "fill_slot", "clear_slot"]

# temperature == 0 selects the argmax branch; the sampling branch still
# traces (fixed trace), so its divide needs a non-zero denominator
_MIN_TEMP = 1e-6


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling spec, carried (immutably) on the Request.

    ``temperature == 0`` (the default) is greedy argmax — bit-identical
    to the pre-sampling engine. ``top_k == 0`` disables the top-k mask,
    ``top_p == 1.0`` disables the nucleus mask. ``seed`` roots the
    request's RNG key; the stream is reproducible for a fixed seed
    regardless of batch composition or preemption (see module doc)."""

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0

    def validate(self, rid: Optional[int] = None) -> "SamplingParams":
        """Fail-fast bounds check (same style as Server.submit's prompt
        checks): temperature >= 0, 0 < top_p <= 1, top_k >= 0."""
        tag = f"request {rid}: " if rid is not None else ""
        if not self.temperature >= 0:  # NaN fails this comparison too
            raise ValueError(
                f"{tag}temperature={self.temperature} must be >= 0 "
                "(0 = greedy argmax)")
        if not 0 < self.top_p <= 1:
            raise ValueError(
                f"{tag}top_p={self.top_p} must be in (0, 1] "
                "(1 disables the nucleus mask)")
        if not self.top_k >= 0:
            raise ValueError(
                f"{tag}top_k={self.top_k} must be >= 0 "
                "(0 disables the top-k mask)")
        return self


# -- host-side slot arrays ---------------------------------------------------
# The engine threads sampling state through the jitted step as five flat
# arrays (one entry per slot). Idle rows keep the greedy defaults — their
# sampled token is discarded anyway, and temperature 0 keeps the where()
# on the cheap branch.

def slot_arrays(n: int) -> dict:
    """Greedy-default per-slot sampling arrays for an ``n``-row step."""
    return {
        "temperature": np.zeros(n, np.float32),
        "top_k": np.zeros(n, np.int32),
        "top_p": np.ones(n, np.float32),
        "seed": np.zeros(n, np.uint32),
        "count": np.zeros(n, np.int32),
    }


def fill_slot(arrs: dict, i: int, sp: SamplingParams, emitted: int):
    """Load slot ``i`` with a request's params and its emitted-token
    count (the RNG key index for the token about to be sampled)."""
    arrs["temperature"][i] = sp.temperature
    arrs["top_k"][i] = sp.top_k
    arrs["top_p"][i] = sp.top_p
    arrs["seed"][i] = np.uint32(sp.seed & 0xFFFFFFFF)
    arrs["count"][i] = emitted


def clear_slot(arrs: dict, i: int):
    """Reset slot ``i`` to the greedy defaults (idle row)."""
    arrs["temperature"][i] = 0.0
    arrs["top_k"][i] = 0
    arrs["top_p"][i] = 1.0
    arrs["seed"][i] = 0
    arrs["count"][i] = 0


def as_tuple(arrs: dict) -> tuple:
    """The positional form the jitted step takes (stable field order)."""
    return (jnp.asarray(arrs["temperature"]), jnp.asarray(arrs["top_k"]),
            jnp.asarray(arrs["top_p"]), jnp.asarray(arrs["seed"]),
            jnp.asarray(arrs["count"]))


# -- in-graph sampling -------------------------------------------------------

def sampling_mask(scaled, top_ks, top_ps):
    """Fixed-trace per-row keep mask over temperature-scaled logits.

    ``scaled``: (B, V) f32 logits / temperature. ``top_ks``: (B,) i32
    (0 = off). ``top_ps``: (B,) f32 in (0, 1]. Returns a (B, V) bool mask
    of the tokens that survive both filters. No dynamic shapes: both
    filters reduce to a per-row threshold value gathered from the
    descending sort, then one vectorized compare."""
    vocab = scaled.shape[-1]
    srt = jnp.sort(scaled, axis=-1)[:, ::-1]  # descending per row
    # top-k: the k-th largest value is the keep threshold (>=, so ties at
    # the boundary are all kept); k = 0 disables
    kth = jnp.take_along_axis(
        srt, jnp.clip(top_ks - 1, 0, vocab - 1)[:, None], axis=-1)
    keep_k = jnp.where((top_ks > 0)[:, None], scaled >= kth, True)
    # top-p: keep the smallest descending prefix whose exclusive cumsum
    # of probability is < p — the top token's exclusive mass is 0, so at
    # least one token always survives; p = 1 keeps everything
    probs = jax.nn.softmax(srt, axis=-1)
    exclusive = jnp.cumsum(probs, axis=-1) - probs
    n_keep = jnp.sum(exclusive < top_ps[:, None], axis=-1)  # >= 1
    cut = jnp.take_along_axis(srt, (n_keep - 1)[:, None], axis=-1)
    keep_p = scaled >= cut
    return keep_k & keep_p


def sample_tokens(logits, temps, top_ks, top_ps, seeds, counts):
    """One sampled (or greedy) token id per row, inside the jitted step.

    ``logits``: (B, V) f32. Per-row arrays: ``temps`` f32 (0 = greedy),
    ``top_ks`` i32, ``top_ps`` f32, ``seeds`` u32 (the request's RNG
    root) and ``counts`` i32 (the request's emitted-token index for this
    draw). Returns (B,) i32 token ids. Greedy rows take the argmax; a
    poisoned/non-finite row's draw is garbage, but the engine's row_ok
    sentinel discards it before it is ever appended."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(temps, _MIN_TEMP)[:, None]
    keep = sampling_mask(scaled, top_ks, top_ps)
    masked = jnp.where(keep, scaled, -jnp.inf)

    def draw(seed, count, row):
        # key split per emitted-token *index*, not per engine step: the
        # draw at index i is the same in any batch / after any resume
        key = jax.random.fold_in(jax.random.PRNGKey(seed), count)
        return jax.random.categorical(key, row)

    sampled = jax.vmap(draw)(seeds, counts, masked).astype(jnp.int32)
    return jnp.where(temps > 0, sampled, greedy)
