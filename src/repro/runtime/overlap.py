"""Compute/communication overlap: ring-decomposed all-gather matmul.

Megatron-SP inserts an all-gather of the seq-sharded residual before each
qkv/up projection. XLA can schedule that gather asynchronously, but the
matmul still waits for the FULL gathered tensor. This shard_map kernel
decomposes the gather into ring steps (jax.lax.ppermute) and interleaves a
partial matmul with each hop — the classic latency-hiding collective-matmul
(Wang et al.; also in MaxText). On a dry-run the win shows up structurally:
the single big all-gather disappears in favour of P-1 collective-permutes
each 1/P the size, which the TPU scheduler can overlap with the P partial
matmuls (hypothesis->measure log: EXPERIMENTS.md §Perf).

y = x @ w.T with x (B, S, d) sharded P('data', 'model', None) over seq and
w (out, d) sharded P('model', None) over out: each step computes the local
shard's contribution to every output row block while the next x shard is in
flight.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["ring_ag_matmul"]


def _ring_body(x_blk, w, axis_name: str):
    """x_blk: (B, s_loc, d) local seq shard; w: (out_loc, d) local rows.
    Returns (B, P*s_loc, out_loc): the full-seq output for local out rows."""
    from repro.launch.mesh import axis_size

    p = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)

    def step(carry, i):
        x_cur, acc = carry
        # overlap: matmul on the shard we hold while the permute moves it on
        part = jax.lax.dot_general(
            x_cur, w, (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(x_cur.dtype)
        src_pos = (idx - i) % p  # whose shard we just consumed
        acc = jax.lax.dynamic_update_slice(
            acc, part, (0, src_pos * x_blk.shape[1], 0)
        )
        x_nxt = jax.lax.ppermute(
            x_cur, axis_name, [(j, (j + 1) % p) for j in range(p)]
        )
        return (x_nxt, acc), None

    acc0 = jnp.zeros((x_blk.shape[0], p * x_blk.shape[1], w.shape[0]), x_blk.dtype)
    (_, acc), _ = jax.lax.scan(step, (x_blk, acc0), jnp.arange(p))
    return acc


def ring_ag_matmul(x, w, mesh, axis_name: str = "model", dp=("data",)):
    """Overlapped all-gather(x over seq) + matmul. x: (B, S, d) seq-sharded
    on ``axis_name``; w: (out, d) out-sharded on ``axis_name``.
    Returns (B, S, out) with out sharded on ``axis_name``."""
    fn = shard_map(
        partial(_ring_body, axis_name=axis_name),
        mesh=mesh,
        in_specs=(P(dp, axis_name, None), P(axis_name, None)),
        out_specs=P(dp, None, axis_name),
        check_rep=False,
    )
    return fn(x, w)
