"""Gradient compression for the DP all-reduce — the paper's FP machinery
reused beyond the paper.

Gradients are quantized per-tensor to E4M3 with an M1 (power-of-2) scale
before the data-parallel reduction and dequantized after. With a pow-2
scale, averaging compressed shards is exact up to the grid: the scale
factors out of the sum as an exponent shift, so compress->reduce->decompress
commutes with reduce up to E4M3 rounding. Halves (vs bf16) or quarters (vs
f32) DP all-reduce traffic.

The pair (compress, decompress) plugs into make_train_step(grad_compress=…);
under jit+GSPMD the all-reduce then moves the compressed representation
(verified in the dry-run HLO — EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import FORMATS, pow2i, quantize_to_grid
from repro.core.scales import constrain_scales_m1

__all__ = ["make_fp8_compressor", "compress_tree", "decompress_tree"]


def _compress_leaf(g, fmt):
    g32 = g.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(g32))
    scale = constrain_scales_m1(
        jnp.maximum(absmax * jnp.float32(1.0 / fmt.max_value), 1e-30)[None]
    )[0]
    q = quantize_to_grid(g32 / scale, fmt)
    return q.astype(jnp.bfloat16), scale


def _decompress_leaf(qs, dtype):
    q, scale = qs
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads, fmt_name: str = "fp8_e4m3"):
    fmt = FORMATS[fmt_name]
    return jax.tree.map(lambda g: _compress_leaf(g, fmt), grads)


def decompress_tree(cgrads, like):
    return jax.tree.map(
        lambda qs, g: _decompress_leaf(qs, g.dtype),
        cgrads, like,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2,
    )


def make_fp8_compressor(fmt_name: str = "fp8_e4m3") -> Tuple:
    """(compress, decompress) for make_train_step(grad_compress=...)."""

    def compress(grads):
        return compress_tree(grads, fmt_name), grads

    def decompress(arg):
        cgrads, like = arg
        return decompress_tree(cgrads, like)

    return compress, decompress
