"""Quantized paged KV-cache pool for the serving engine.

The KV cache is the largest *activation* tensor the server holds, and decode
attention's dominant memory term. Following the paper's finding that FP
formats beat INT for LLM activations, this module stores K/V as packed FP8
E4M3 codes in fixed-size pages with per-(page, head) scales constrained by
the M2 machinery (core.scales.constrain_scales_m2): each page keeps one
full-precision s_max plus integer pow-2 shifts per head, so the decode
kernel applies scales as an exponent add (kernels.common.decode_fp8) and
multiplies by s_max once per page. Halving KV bytes doubles the slot pool
for the same HBM.

Layout (one pool dict per model segment, leading dim = stacked layers so it
rides the per-segment lax.scan exactly like the old monolithic caches):

  GQA:  k/v        (L, P+1, page, KV, hd)  uint8 codes (fp8) | bf16 values
        k/v_smax   (L, P+1)                f32   per-page full-precision S_max
        k/v_shift  (L, P+1, KV)            int32 pow-2 ratio exponents k_i
  MLA:  ckv        (L, P+1, page, r)   + smax/shift with a single "head"
        krope      (L, P+1, page, dr)    (the latent has no head axis)

Page ids are *global across layers*: page p of every layer belongs to the
same logical page, so one host-side free list serves the whole stack. The
last page id (index P) is a reserved null page — in-graph appends from
inactive batch rows are redirected there instead of corrupting a live page.

Mixed precision (``CachePolicy(frozen_fmt="fp4_e2m1")``): the pool grows a
dedicated *frozen region* — half-width packed FP4 E2M1 stores (``k_fz`` et
al., two codes per byte, own M2 scales) of ``n_frozen`` pages. Frozen
logical page ids share the active id space above it: id ``(P+1) + fidx``
addresses frozen row ``fidx`` (row ``n_frozen`` is a dummy for clamped
gathers). A page enters the region exactly once, by ``transcode_page`` at
the moment the prefix cache freezes it, and is read-only afterwards — the
decode kernels select the per-page decode path from the id class.


Write paths:
  * prefill splice (host-side, ``splice_prefill``): quantize the prompt's
    contiguous K/V page by page and scatter into the slot's allocated pages.
    The splice walks the prompt in ``chunk_pages`` groups so the f32
    staging transient is bounded by the chunk, not the prompt.
  * streaming prefill (in-graph, ``append_prefill_chunk``): the serving
    engine's chunked prefill writes each page-aligned chunk of prompt K/V
    straight into the pool from inside the forward — no contiguous
    max_seq scratch cache ever exists, so transient HBM tracks the chunk
    size and admission cost tracks the true prompt length.
  * decode append (in-graph, ``append_paged``): the touched page is
    gathered, dequantized, the new token written at its row's true offset,
    the page's per-head scales recomputed (amax -> M2), and the page
    re-encoded. With unchanged scales decode->encode is the identity on the
    FP8 grid, so requantization only rounds (once, <= 1/2 ulp) on the few
    steps where a page's amax actually grows.

``PagedState`` (page_table + per-slot true lengths) is the per-row cache
index that replaces the old scalar ``cache_index = max(lengths)`` masking
hack in the serving engine; models treat it as an opaque pytree.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from collections import OrderedDict
from typing import Dict, Iterable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import fp_encode, pack_nibbles, quantize_to_grid
from repro.core.scales import constrain_scales_m2
from repro.kernels.common import PageFormat, page_format

__all__ = [
    "CachePolicy",
    "PagedState",
    "PrefixCache",
    "page_key",
    "init_gqa_pool",
    "init_mla_pool",
    "init_cross_pool",
    "pool_keys",
    "pool_format",
    "frozen_format",
    "n_frozen_pages",
    "quantize_pages",
    "dequantize_pages",
    "transcode_page",
    "splice_prefill",
    "append_prefill_chunk",
    "write_cross_pages",
    "append_paged",
    "gather_pages",
    "gather_history",
    "gather_slabs",
    "scatter_slabs",
    "pool_bytes_per_token",
    "bf16_bytes_per_token",
    "page_bytes",
    "payload_checksum",
]

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class CachePolicy:
    """The KV-cache precision policy — per page *class*, not one global knob.

    Replaces the flat ``kv_fmt: Optional[str]`` string (still accepted
    through a ``DeprecationWarning`` shim on ``ServerConfig``). Page classes:

    * ``active_fmt`` — pages any write path can still touch: private prompt
      pages, the decode-grown tail, the boundary page. Decode appends
      requantize these in-graph, so the format must be writable:
      ``None`` (bf16) or ``"fp8_e4m3"``.
    * ``frozen_fmt`` — pages the prefix cache has registered: shared-frozen,
      read-only for the rest of their lives. ``None`` inherits
      ``active_fmt``; ``"fp4_e2m1"`` (requires FP8 active pages) transcodes
      each page FP8 -> packed FP4 exactly once, at the freeze point —
      requantize-error accumulation never applies to a page that is never
      written again.
    * ``cross_fmt`` — enc-dec cross-attention pages, write-once at encode
      time (frozen from birth, so FP4 is safe here too). ``None`` inherits
      ``active_fmt``.

    ``frozen_pages`` sizes the dedicated frozen-page region when
    ``frozen_fmt`` differs from ``active_fmt`` (``None``: match the active
    pool size).
    """

    active_fmt: Optional[str] = None
    frozen_fmt: Optional[str] = None  # None = inherit active_fmt
    cross_fmt: Optional[str] = None  # None = inherit active_fmt
    frozen_pages: Optional[int] = None

    def __post_init__(self):
        if self.active_fmt not in (None, "fp8_e4m3"):
            raise ValueError(
                f"active_fmt={self.active_fmt!r}: active pages are "
                "requantized in-graph by decode appends, so only None (bf16) "
                "or 'fp8_e4m3' are writable")
        page_format(self.frozen_fmt)  # fail fast with the allowed set
        page_format(self.cross_fmt)
        if self.frozen_fmt is not None and self.frozen_fmt != self.active_fmt:
            if (self.active_fmt, self.frozen_fmt) != ("fp8_e4m3", "fp4_e2m1"):
                raise ValueError(
                    f"unsupported transcode {self.active_fmt!r} -> "
                    f"{self.frozen_fmt!r}: the only mixed-precision policy "
                    "is FP8 active pages with 'fp4_e2m1' frozen pages")
        if self.cross_fmt == "fp4_e2m1" and self.active_fmt != "fp8_e4m3":
            raise ValueError(
                "cross_fmt='fp4_e2m1' requires quantized (fp8_e4m3) active "
                "pages — a bf16 engine has no quantization calibration path")
        if self.frozen_pages is not None and self.frozen_pages < 1:
            raise ValueError(f"frozen_pages={self.frozen_pages}: must be >= 1")

    # -- resolved per-class formats (inheritance applied) --------------------
    @property
    def active(self) -> PageFormat:
        return page_format(self.active_fmt)

    @property
    def frozen(self) -> PageFormat:
        return page_format(self.frozen_fmt if self.frozen_fmt is not None
                           else self.active_fmt)

    @property
    def cross(self) -> PageFormat:
        return page_format(self.cross_fmt if self.cross_fmt is not None
                           else self.active_fmt)

    @property
    def mixed(self) -> bool:
        """True when frozen pages live in a separate (FP4) region."""
        return self.frozen != self.active


class PagedState(NamedTuple):
    """Per-row cache index for paged decode: which pages each slot owns and
    how many tokens it has really generated (no synchronized-length hack).

    The optional fields extend the same index to every decode family:
      * ``chunk_len`` — (1,) true token count of a *bucketed* streaming
        prefill chunk (the engine pads chunks to powers of two so jit trace
        count is O(log max_seq), not O(distinct lengths); positions >=
        chunk_len are pad and must be masked out of page writes/logits).
      * ``cross_table``/``enc_lengths`` — enc-dec decoders: page ids of the
        write-once cross-attention pages and the true encoder lengths.
      * ``slabs`` — recurrent families (SSM/xLSTM): per-row state-slab ids
        into the fixed-size slab pool (the last slab id is the reserved
        null slab, like the null page).
      * ``prefill`` — the *mixed engine step*: a nested batch-1 chunk state
        (page_table/lengths/chunk_len of one streaming-prefill chunk) that
        piggybacks on a decode step. The outer state indexes the decode
        rows; the fused token row is ``[decode tokens | chunk tokens]`` and
        the models split it at ``lengths.shape[0]``. The nested state never
        nests again (``prefill.prefill`` is always None).
    Unused fields stay ``None``; models treat the tuple as an opaque pytree.
    """

    page_table: jnp.ndarray  # (B, pages_per_slot) int32 page ids
    lengths: jnp.ndarray  # (B,) int32 true per-slot lengths
    chunk_len: Optional[jnp.ndarray] = None  # (1,) true prefill-chunk tokens
    cross_table: Optional[jnp.ndarray] = None  # (B, cross_pp) int32 page ids
    enc_lengths: Optional[jnp.ndarray] = None  # (B,) int32 encoder lengths
    slabs: Optional[jnp.ndarray] = None  # (B,) int32 state-slab ids
    prefill: Optional["PagedState"] = None  # mixed step: nested chunk state


def pool_keys(pool: Dict):
    """The value-bearing leaf names of a pool ('k'/'v' or 'ckv'/'krope')."""
    return ("k", "v") if "k" in pool else ("ckv", "krope")


def pool_format(pool: Dict) -> PageFormat:
    """The active-store PageFormat, recovered from the pool's leaves —
    jit-safe: only dtypes and leaf *names* are inspected (the zero-size
    ``_fp4`` marker leaf distinguishes packed FP4 from FP8, both uint8), so
    the answer is a trace constant."""
    first = pool[pool_keys(pool)[0]]
    if first.dtype != jnp.uint8:
        return page_format(None)
    return page_format("fp4_e2m1" if "_fp4" in pool else "fp8_e4m3")


def frozen_format(pool: Dict) -> Optional[PageFormat]:
    """The frozen-region PageFormat, or None when the pool is homogeneous
    (no dedicated ``*_fz`` store: frozen pages live in the active store)."""
    if any(name.endswith("_fz") for name in pool):
        return page_format("fp4_e2m1")
    return None


def n_frozen_pages(pool: Dict) -> int:
    """Frozen-region page count (0 when homogeneous). Works on full pools
    (leading layer dim) and per-layer slices alike — the value-leaf rank
    tells them apart (GQA k_fz: 5-D full / 4-D per-layer; MLA ckv_fz:
    4-D / 3-D)."""
    full_rank = 5 if "k" in pool else 4
    for name, leaf in pool.items():
        if name.endswith("_fz"):
            axis = 1 if leaf.ndim == full_rank else 0
            return leaf.shape[axis] - 1
    return 0


# ---------------------------------------------------------------------------
# Pool construction
# ---------------------------------------------------------------------------
def _init_store(n_layers, n_pages, page_size, n_kv, head_dim, fmt: PageFormat):
    p1 = n_pages + 1  # + reserved null page
    if not fmt.quantized:
        return {"_": jnp.zeros((n_layers, p1, page_size, n_kv, head_dim), jnp.bfloat16)}
    width = fmt.width(head_dim)  # packed FP4 stores two codes per byte
    return {
        "_": jnp.zeros((n_layers, p1, page_size, n_kv, width), jnp.uint8),
        "_smax": jnp.zeros((n_layers, p1), jnp.float32),
        "_shift": jnp.zeros((n_layers, p1, n_kv), jnp.int32),
    }


def _named(store, name):
    return {(name if k == "_" else name + k): v for k, v in store.items()}


def _frozen_suffix(suffix: str) -> str:
    # k -> k_fz, k_smax -> k_fz_smax: every frozen-store leaf name contains
    # "_fz", which is what the engine's spill/scrub leaf filters key on
    return "_fz" + suffix


def _finish_pool(pool: Dict, fmt: PageFormat, frozen_fmt, n_frozen: int,
                 mk_store) -> Dict:
    """Attach the marker/frozen leaves shared by every pool constructor."""
    if fmt.packed:
        # zero-size marker: the jit-safe static channel that tells readers
        # this uint8 store is packed FP4, not FP8 (see pool_format). Leading
        # dim matches the stacked layers so the leaf rides the per-segment
        # lax.scan (sliced to a per-layer (0,) that costs nothing).
        n_layers = pool[pool_keys(pool)[0]].shape[0]
        pool["_fp4"] = jnp.zeros((n_layers, 0), jnp.uint8)
    frozen_fmt = page_format(frozen_fmt) if frozen_fmt is not None else None
    if frozen_fmt is not None and frozen_fmt != fmt:
        if (fmt.name, frozen_fmt.name) != ("fp8_e4m3", "fp4_e2m1"):
            raise ValueError(
                f"unsupported frozen store {frozen_fmt.name!r} behind "
                f"{fmt.name!r} active pages (only fp4_e2m1 behind fp8_e4m3)")
        if n_frozen < 1:
            raise ValueError("a mixed-precision pool needs n_frozen >= 1")
        pool.update(mk_store(frozen_fmt, n_frozen))
    return pool


def init_gqa_pool(n_layers, n_pages, page_size, n_kv, head_dim,
                  fmt=page_format("fp8_e4m3"), frozen_fmt=None,
                  n_frozen: int = 0) -> Dict:
    """``fmt``/``frozen_fmt`` take a PageFormat (format-name strings and
    None are coerced through :func:`kernels.common.page_format`, which
    fails fast on unknown names). A distinct ``frozen_fmt`` adds the
    dedicated frozen-page region: half-width packed ``k_fz``/``v_fz``
    stores of ``n_frozen`` pages (+1 dummy row for clamped gathers) that
    frozen prefix pages are transcoded into (see ``transcode_page``)."""
    fmt = page_format(fmt)

    def mk(f, n):
        out = {}
        for name in ("k", "v"):
            store = _init_store(n_layers, n, page_size, n_kv, head_dim, f)
            out.update(_named({_frozen_suffix(k) if k != "_" else "_fz": v
                               for k, v in store.items()}, name))
        return out

    pool = {}
    for name in ("k", "v"):
        pool.update(_named(_init_store(n_layers, n_pages, page_size, n_kv,
                                       head_dim, fmt), name))
    return _finish_pool(pool, fmt, frozen_fmt, n_frozen, mk)


def init_mla_pool(n_layers, n_pages, page_size, kv_lora_rank, qk_rope_dim,
                  fmt=page_format("fp8_e4m3"), frozen_fmt=None,
                  n_frozen: int = 0) -> Dict:
    """Latent pages: the compressed c_kv and the shared rope key, each with a
    single scale 'head' (squeezed out of the stored value leaves)."""
    fmt = page_format(fmt)

    def build(f, n, frozen):
        out = {}
        for name, dim in (("ckv", kv_lora_rank), ("krope", qk_rope_dim)):
            store = _init_store(n_layers, n, page_size, 1, dim, f)
            store["_"] = store["_"][:, :, :, 0]  # (L, P+1, page, dim)
            if frozen:
                store = {_frozen_suffix(k) if k != "_" else "_fz": v
                         for k, v in store.items()}
            out.update(_named(store, name))
        return out

    pool = build(fmt, n_pages, frozen=False)
    return _finish_pool(pool, fmt, frozen_fmt, n_frozen,
                        lambda f, n: build(f, n, frozen=True))


def init_cross_pool(n_layers, n_pages, page_size, n_kv, head_dim,
                    fmt=page_format("fp8_e4m3")) -> Dict:
    """Immutable cross-attention pages (enc-dec decoders).

    Same storage layout as a GQA pool — k/v codes + per-(page, head) M2
    scales — but with *write-once* semantics: the encoder runs exactly once
    per request, so a slot's cross pages are written in one shot at encode
    time (``write_cross_pages``) and never touched again. There is no
    append path for them: decode only ever reads (``ops.paged_decode_attn``
    with ``kv_lens = enc_lengths``), which is what lets the per-page scales
    stay frozen at their encode-time amax for the request's whole lifetime.
    """
    return init_gqa_pool(n_layers, n_pages, page_size, n_kv, head_dim, fmt)


# ---------------------------------------------------------------------------
# Page quantization (the M2 machinery applied per (page, head))
# ---------------------------------------------------------------------------
def quantize_pages(vals, fmt="fp8_e4m3"):
    """vals: (..., page, KV, hd) f32 -> (codes uint8, s_max (...,), shifts
    (..., KV)). Scales are amax/fmt_max per (page, head), M2-constrained
    across the page's heads: S_i = s_max * 2^-k_i. For a packed format
    (fp4_e2m1) the returned codes hold two per byte on the last dim
    (odd head dims pad one zero nibble)."""
    pf = page_format(fmt)
    grid = pf.fmt
    amax = jnp.max(jnp.abs(vals), axis=(-3, -1))  # (..., KV)
    raw = jnp.maximum(amax * jnp.float32(1.0 / grid.max_value), _EPS)
    # floor-rounded ratios: S_hat >= raw scale, so page content never
    # saturates (FP grids keep the same relative step one binade up)
    m2 = constrain_scales_m2(raw, group_axis=-1, rounding="floor")
    q = quantize_to_grid(vals / m2.scales[..., None, :, None], grid)
    codes = fp_encode(q, grid)
    if pf.packed:
        if codes.shape[-1] % 2:
            codes = jnp.pad(codes, ((0, 0),) * (codes.ndim - 1) + ((0, 1),))
        codes = pack_nibbles(codes)
    return codes, m2.s_max[..., 0], m2.shifts


def dequantize_pages(codes, s_max, shifts, fmt="fp8_e4m3",
                     d: Optional[int] = None):
    """Inverse: exponent-add shift apply + one s_max multiply per page.
    codes (..., page, KV, width); s_max (...,); shifts (..., KV) -> f32.
    ``d`` recovers the logical head dim after a packed nibble unpack
    (required for packed formats when the head dim is odd)."""
    pf = page_format(fmt)
    if d is None:
        d = codes.shape[-1] * (2 if pf.packed else 1)
    v = pf.decode(codes, shifts[..., None, :, None], d)
    return v * s_max[..., None, None, None]


def transcode_page(pool: Dict, src_pid: int, dst_fidx: int) -> Dict:
    """Re-encode one active-store page into the frozen (packed FP4) store.

    Runs host-side, exactly once per page, at the moment the prefix cache
    freezes it: dequantize the FP8 page (all stacked layers at once),
    requantize onto the FP4 E2M1 grid with fresh per-(page, head) M2 scales,
    pack two codes per byte, and write frozen row ``dst_fidx``. The source
    page is untouched (the caller releases it to the free list). Frozen
    pages are read-only for the rest of their lives, so this is the only
    writer of the ``*_fz`` leaves — requantize-error accumulation never
    applies."""
    fz = frozen_format(pool)
    assert fz is not None, "transcode_page on a pool without a frozen store"
    assert pool_format(pool).name == "fp8_e4m3", "transcode source must be FP8"
    out = dict(pool)
    for name in pool_keys(pool):
        store = pool[name]
        has_heads = store.ndim == 5  # (L, P+1, page, KV, hd) vs (L, P+1, page, d)
        codes = _with_head_axis(store[:, src_pid], has_heads)  # (L, page, KV|1, hd)
        smax = pool[name + "_smax"][:, src_pid]  # (L,)
        shifts = pool[name + "_shift"][:, src_pid]  # (L, KV|1)
        vals = dequantize_pages(codes, smax, shifts)
        ncodes, nsmax, nshift = quantize_pages(vals, fz)
        if not has_heads:
            ncodes = ncodes[..., 0, :]
        out[name + "_fz"] = out[name + "_fz"].at[:, dst_fidx].set(ncodes)
        out[name + "_fz_smax"] = out[name + "_fz_smax"].at[:, dst_fidx].set(nsmax)
        out[name + "_fz_shift"] = out[name + "_fz_shift"].at[:, dst_fidx].set(nshift)
    return out


# ---------------------------------------------------------------------------
# Prefill splice (host-side: runs once per admitted request)
# ---------------------------------------------------------------------------
def _with_head_axis(arr, has_heads: bool):
    return arr if has_heads else arr[..., None, :]


def splice_prefill(pool: Dict, prefill_cache: Dict, page_ids: np.ndarray,
                   n_tokens: int, chunk_pages: int = 8) -> Dict:
    """Quantize a batch-1 prefill's contiguous K/V into this slot's pages.

    prefill_cache: the segment cache from ``models.prefill`` — leaves
    (L, 1, max_seq, KV, hd) (GQA) or (L, 1, max_seq, dim) (MLA).
    page_ids: (n_pages_used,) page ids covering ``n_tokens`` (tail zero-pad).
    chunk_pages: staging granularity — pages are quantized ``chunk_pages``
    at a time, so the f32 staging copy never exceeds one chunk (a long
    prompt no longer spikes a prompt-sized transient).
    """
    pf = pool_format(pool)
    out = dict(pool)
    n_total = len(page_ids)
    for c0 in range(0, n_total, chunk_pages):
        ids_np = np.asarray(page_ids[c0: c0 + chunk_pages], np.int32)
        npg = len(ids_np)
        for name in pool_keys(pool):
            has_heads = pool[name].ndim == 5
            page = pool[name].shape[2]
            t0 = c0 * page
            # the reserved pages may overhang the prefill cache's max_seq
            # (when max_seq is not a page multiple): take what exists, pad
            src = prefill_cache[name][:, 0, t0: t0 + npg * page].astype(jnp.float32)
            short = npg * page - src.shape[1]
            if short > 0:
                src = jnp.pad(src, ((0, 0), (0, short)) + ((0, 0),) * (src.ndim - 2))
            if t0 + npg * page > n_tokens:  # zero the tail beyond the prompt
                # so page amax stays clean
                mask = (t0 + jnp.arange(npg * page) < n_tokens).astype(jnp.float32)
                src = src * mask.reshape((1, npg * page) + (1,) * (src.ndim - 2))
            src = _with_head_axis(src, has_heads)
            nl, kv, hd = src.shape[0], src.shape[-2], src.shape[-1]
            vals = src.reshape(nl, npg, page, kv, hd)
            ids = jnp.asarray(ids_np)
            if pf.quantized:
                codes, smax, shifts = quantize_pages(vals, pf)
                if not has_heads:
                    codes = codes[..., 0, :]
                out[name] = out[name].at[:, ids].set(codes)
                out[name + "_smax"] = out[name + "_smax"].at[:, ids].set(smax)
                out[name + "_shift"] = out[name + "_shift"].at[:, ids].set(shifts)
            else:
                store = vals if has_heads else vals[..., 0, :]
                out[name] = out[name].at[:, ids].set(store.astype(pool[name].dtype))
    return out


# ---------------------------------------------------------------------------
# Decode append (in-graph: runs inside the jitted decode step, per layer)
# ---------------------------------------------------------------------------
def append_paged(pool_layer: Dict, new_vals: Dict, state: PagedState) -> Dict:
    """Write one new token per batch row at its row's true position.

    pool_layer: one layer's slice of a pool (no leading L dim).
    new_vals: {"k": (B, 1, KV, hd), "v": ...} or {"ckv": (B, 1, r), ...}.
    Rows with lengths == 0 (empty slots) are redirected to the null page.
    """
    pf = pool_format(pool_layer)
    # the no-write-to-FP4 invariant, enforced at trace time: a packed page
    # is frozen by definition (transcoded exactly once, read-only after),
    # and requantizing through the 3-bit E2M1 grid would compound error
    assert not pf.packed, \
        "decode append must never target packed FP4 pages (frozen pages are read-only)"
    b = state.lengths.shape[0]
    out = dict(pool_layer)
    rows = jnp.arange(b)
    for name in pool_keys(pool_layer):
        store = pool_layer[name]
        has_heads = store.ndim == 4  # (P+1, page, KV, hd) vs (P+1, page, dim)
        page = store.shape[1]
        null = store.shape[0] - 1
        slot = state.lengths // page
        off = state.lengths % page
        pid = jnp.take_along_axis(state.page_table, slot[:, None], axis=1)[:, 0]
        pid = jnp.where(state.lengths > 0, pid, null).astype(jnp.int32)
        # a row's tail page is always private (boundary pages never freeze),
        # so pid is always an active-store id even in a mixed-format pool;
        # clamp anyway so a violation cannot index out of bounds in-graph
        pid = jnp.minimum(pid, null)
        new = new_vals[name].astype(jnp.float32)[:, 0]  # (B, KV, hd) | (B, dim)
        new = _with_head_axis(new, has_heads)  # (B, KV|1, hd)
        if not pf.quantized:
            val = new if has_heads else new[:, 0]
            out[name] = store.at[pid, off].set(val.astype(store.dtype))
            continue
        codes = _with_head_axis(store[pid], has_heads)  # (B, page, KV|1, hd)
        smax = pool_layer[name + "_smax"][pid]  # (B,)
        shifts = pool_layer[name + "_shift"][pid]  # (B, KV|1)
        vals = dequantize_pages(codes, smax, shifts)
        vals = vals.at[rows, off].set(new)
        # zero page slots past this row's position: a recycled page may
        # carry a previous owner's stale codes, which must not leak into
        # the page amax (and so the scales) of its new owner. where(), not
        # multiply: 0 * NaN = NaN, and a stale non-finite code must not
        # survive the zeroing
        live = jnp.arange(page)[None, :] <= off[:, None]
        vals = jnp.where(live[:, :, None, None], vals, 0.0)
        ncodes, nsmax, nshift = quantize_pages(vals)
        if not has_heads:
            ncodes = ncodes[..., 0, :]
        out[name] = store.at[pid].set(ncodes)
        out[name + "_smax"] = pool_layer[name + "_smax"].at[pid].set(nsmax)
        out[name + "_shift"] = pool_layer[name + "_shift"].at[pid].set(nshift)
    return out


def append_prefill_chunk(pool_layer: Dict, new_vals: Dict,
                         state: PagedState) -> Dict:
    """Write one page-aligned chunk of a (batch-1) streaming prefill.

    pool_layer: one layer's slice of a pool (no leading L dim).
    new_vals: {"k": (1, S, KV, hd), ...} or {"ckv": (1, S, r), ...} — S
    prompt tokens starting at position ``state.lengths[0]``, which must be
    a page-size multiple (the engine feeds page-aligned chunks; only the
    final chunk of a prompt may be partial). The tail of a partial last
    page is zero-padded so the page amax stays clean; a later decode
    append at that offset requantizes the page exactly as usual.

    Unlike ``splice_prefill`` this runs *inside* the jitted chunk forward:
    the prompt's K/V never exists as a contiguous max_seq scratch cache —
    transient memory is bounded by the chunk, and the pages written here
    are immediately the attention source for the next chunk.

    When ``state.chunk_len`` is set, the chunk was padded to a power-of-two
    bucket: positions >= chunk_len carry pad-token K/V and are zeroed here
    so they cannot leak into the page amax (and so the scales). Pages the
    pad region overhangs must point at the null page in ``page_table``.
    """
    pf = pool_format(pool_layer)
    out = dict(pool_layer)
    start = state.lengths[0]
    for name in pool_keys(pool_layer):
        store = pool_layer[name]
        has_heads = store.ndim == 4  # (P+1, page, KV, hd) vs (P+1, page, dim)
        page = store.shape[1]
        new = new_vals[name].astype(jnp.float32)[0]  # (S, KV, hd) | (S, dim)
        s = new.shape[0]
        if state.chunk_len is not None:  # zero the pad tail of a bucketed chunk
            # where(), not multiply: pad-position K/V sits downstream of the
            # real chunk through attention, so a non-finite activation in
            # the chunk makes the pad values NaN — and 0 * NaN = NaN. The
            # pad tail overhangs into the shared null page, which the mixed
            # engine's decode lanes read in the same fused program; the
            # zeroing must hold even for non-finite input
            live = jnp.arange(s) < state.chunk_len[0]
            new = jnp.where(live.reshape((s,) + (1,) * (new.ndim - 1)),
                            new, 0.0)
        npg = -(-s // page)
        pad = npg * page - s
        if pad:
            new = jnp.pad(new, ((0, pad),) + ((0, 0),) * (new.ndim - 1))
        new = _with_head_axis(new, has_heads)  # (npg * page, KV|1, hd)
        vals = new.reshape(npg, page, new.shape[-2], new.shape[-1])
        pid = jax.lax.dynamic_slice_in_dim(
            state.page_table[0], start // page, npg)
        # prefill writes only ever target private (active-class) pages; in
        # a mixed pool any frozen id here would be a bug — clamp to the
        # null page so it cannot index out of bounds in-graph (the engine's
        # assert_unfrozen catches the bug host-side)
        pid = jnp.minimum(pid, store.shape[0] - 1)
        if pf.quantized:
            codes, smax, shifts = quantize_pages(vals, pf)
            if not has_heads:
                codes = codes[..., 0, :]
            out[name] = store.at[pid].set(codes)
            out[name + "_smax"] = pool_layer[name + "_smax"].at[pid].set(smax)
            out[name + "_shift"] = pool_layer[name + "_shift"].at[pid].set(shifts)
        else:
            stv = vals if has_heads else vals[..., 0, :]
            out[name] = store.at[pid].set(stv.astype(store.dtype))
    return out


def write_cross_pages(pool_layer: Dict, new_vals: Dict,
                      cross_table: jnp.ndarray) -> Dict:
    """Write one layer's encoder-derived cross K/V into its (write-once)
    cross pages, in one shot at encode time.

    pool_layer: one layer's slice of an ``init_cross_pool`` pool.
    new_vals: {"k": (1, T_enc, KV, hd), "v": ...} — the full encoder
    sequence. cross_table: (1, cross_pp) page ids covering T_enc (tail
    entries past ceil(T_enc / page) are never written).

    This is the *only* writer of cross pages: decode never appends to them,
    so the per-(page, head) M2 scales computed here are final.
    """
    state = PagedState(cross_table, jnp.zeros((1,), jnp.int32))
    return append_prefill_chunk(pool_layer, new_vals, state)


# ---------------------------------------------------------------------------
# State slabs (SSM / xLSTM recurrent state)
# ---------------------------------------------------------------------------
def gather_slabs(pool_layer, slab_ids):
    """Recurrent-state read for one layer: slab-pool leaves (S+1, ...) ->
    per-row state (B, ...). ``slab_ids``: (B,) int32; the last slab (index
    S) is the reserved null slab inactive rows point at.

    A slab is the fixed-size analogue of a page for families whose decode
    state does not grow with context (SSM state + conv tail, xLSTM
    (c, n, m) cells): one slab per running request, allocated at admission,
    steal/spill-able like pages — just never grown."""
    return jax.tree.map(lambda a: a[slab_ids], pool_layer)


def scatter_slabs(pool_layer, slab_ids, new_rows):
    """Recurrent-state write-back: scatter each row's updated state into
    its slab. Rows sharing the null slab overwrite each other there —
    harmless by construction (the null slab is never read as live state)."""
    return jax.tree.map(
        lambda full, row: full.at[slab_ids].set(row.astype(full.dtype)),
        pool_layer, new_rows)


def gather_pages(pool_layer: Dict, name: str, state: PagedState):
    """Dequantized gather for the jnp paths: (B, PP * page, KV, hd) f32 for
    GQA leaves, (B, PP * page, dim) for MLA leaves.

    Mixed-format pools: table entries ``>= P+1`` are frozen-region logical
    ids (``base + fidx``). Both regions are gathered with clamped indices
    (frozen ids clamp to the null page in the active store and vice versa)
    and the per-page format select is a ``where`` on the id class — the same
    dataflow the Pallas kernels implement with a prefetched frozen mask."""
    store = pool_layer[name]
    pf = pool_format(pool_layer)
    fz = frozen_format(pool_layer)
    has_heads = store.ndim == 4
    page = store.shape[1]
    b, pp = state.page_table.shape
    pt = state.page_table
    base = store.shape[0]  # P+1: first frozen logical id
    apt = jnp.minimum(pt, base - 1) if fz is not None else pt
    pages = store[apt]  # (B, PP, page, ...)
    if pf.quantized:
        smax = pool_layer[name + "_smax"][apt]  # (B, PP)
        shifts = pool_layer[name + "_shift"][apt]  # (B, PP, KV|1)
        d = store.shape[-1] * (2 if pf.packed else 1)
        vals = dequantize_pages(_with_head_axis(pages, has_heads), smax,
                                shifts, pf, d=d)
        if not has_heads:
            vals = vals[..., 0, :]
    else:
        vals = pages.astype(jnp.float32)
    if fz is not None:
        fstore = pool_layer[name + "_fz"]
        fpt = jnp.clip(pt - base, 0, fstore.shape[0] - 1)
        fsmax = pool_layer[name + "_fz_smax"][fpt]
        fshift = pool_layer[name + "_fz_shift"][fpt]
        fvals = dequantize_pages(_with_head_axis(fstore[fpt], has_heads),
                                 fsmax, fshift, fz, d=store.shape[-1])
        if not has_heads:
            fvals = fvals[..., 0, :]
        frozen = (pt >= base).reshape(b, pp, *([1] * (vals.ndim - 2)))
        vals = jnp.where(frozen, fvals, vals)
    return vals.reshape(b, pp * page, *vals.shape[3:])


def gather_history(pool_layer: Dict, state: PagedState, chunk_len: int):
    """History gather for a streaming-prefill chunk (the shared page math
    for the GQA and MLA model glue — keep it in one place).

    The chunk starts page-aligned at ``state.lengths[0]``, so every token
    of the gather below that (dynamic) position is fully-packed history:
    token i sits at absolute position i. The *whole* (engine-trimmed or
    power-of-two-bucketed) table is gathered — including the chunk's own
    just-written pages and any null-page fill — and the caller masks
    columns ``>= lengths[0]``: those positions are covered exactly by the
    chunk's inline K/V (no early FP8 round trip) or are pad. Returns
    ``({name: (B, W * page, ...)}, W * page)``, or ``({}, 0)`` when the
    table is no wider than the chunk itself (prompt fits one chunk,
    nothing could be history).
    """
    first = pool_layer[pool_keys(pool_layer)[0]]
    page = first.shape[1]
    if state.page_table.shape[1] <= -(-chunk_len // page):
        return {}, 0
    return ({name: gather_pages(pool_layer, name, state)
             for name in pool_keys(pool_layer)},
            state.page_table.shape[1] * page)


# ---------------------------------------------------------------------------
# Content-addressed shared-prefix cache (host-side index over frozen pages)
# ---------------------------------------------------------------------------
_PREFIX_ROOT = -1  # the parent node id of every depth-0 page


def page_key(parent: int, tokens: Sequence[int]) -> Tuple:
    """Content address of one *full* page: the page's token ids chained on
    the parent page's *node id* (an integer assigned at registration and
    never reissued), so the key identifies the whole prefix up to and
    including this page — two identical token windows at different depths,
    or under different histories, never collide. Keys are exact token
    tuples, not hashes, so there is no collision risk; the integer parent
    keeps each dict lookup O(page_size) instead of re-hashing the whole
    ancestor chain (a nested-tuple parent would make a d-page walk
    O(d^2 * page_size))."""
    return (parent, tuple(int(t) for t in tokens))


class PrefixCache:
    """Host-side radix index over *full, scale-frozen* KV pages.

    ZeroQuant-FP's scaling constraints make a full FP8 page an immutable,
    self-contained block: once the prefill stream (or the last decode
    append that filled it) has passed a page, its per-(page, head) M2
    scales are frozen at amax and the codes are never requantized again.
    That makes the page content a pure function of its token-id prefix, so
    full pages are content-addressable: requests sharing a prompt prefix
    (system prompts, few-shot headers) can map the same physical pages
    instead of re-prefilling and re-quantizing identical K/V.

    The index maps ``page_key(parent, tokens)`` -> page id, one entry per
    registered page (and one key per page: a page holds exactly one
    content). Ownership/refcounts live in the serving engine; the cache
    additionally tracks the **reusable LRU** — registered pages whose
    refcount dropped to zero. Those stay bit-reusable (a later request with
    the same prefix re-acquires them for free) until the allocator
    *reclaims* them, oldest-first, which drops the index entry and hands
    the physical page back as a blank. Reclaiming a mid-chain page strands
    its descendants (the walk can no longer reach them) — they simply age
    out of the LRU in turn.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        # key -> (pid, node id). The node id stands in for the full chain
        # as the parent component of children's keys; it is monotonically
        # assigned and never reissued, so a reclaimed page's stranded
        # descendants can never be re-attached under recycled-pid content
        self._by_key: Dict[Tuple, Tuple[int, int]] = {}
        self._by_pid: Dict[int, Tuple] = {}
        # refcount-0 registered pages, oldest-parked first
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self._next_node = 0
        # conditioning-digest -> synthetic root node id. Requests whose KV
        # depends on more than the token ids (enc-dec: cross-attention makes
        # decoder K/V a function of the *encoder frames* too) chain off a
        # per-digest root instead of _PREFIX_ROOT, so identical decoder
        # prompts under different audio never alias (node ids are unique)
        self._roots: Dict[str, int] = {}
        self.reclaims = 0

    def root_for(self, digest: str) -> int:
        """Radix root node for an extra conditioning digest (e.g. a hash of
        the encoder frames). Monotonic node ids, memoized per digest —
        walks/inserts under the same digest share a chain, different
        digests get disjoint chains by construction."""
        root = self._roots.get(digest)
        if root is None:
            root = self._next_node
            self._next_node += 1
            self._roots[digest] = root
        return root

    def __len__(self) -> int:
        return len(self._by_key)

    @property
    def n_reusable(self) -> int:
        """Registered pages at refcount 0 — allocatable without stealing."""
        return len(self._lru)

    def reusable_ids(self) -> List[int]:
        """The parked refcount-0 page ids, oldest first (LRU order)."""
        return list(self._lru)

    def registered(self, pid: int) -> bool:
        return int(pid) in self._by_pid

    def walk(self, tokens: Sequence[int], max_pages: Optional[int] = None,
             root: int = _PREFIX_ROOT) -> List[int]:
        """Longest chain of consecutive full-page hits for this token
        prefix, from ``root`` (the global root, or a ``root_for`` node for
        digest-conditioned requests): returns the page ids holding
        ``tokens[:len(hits) * page_size]``. ``max_pages`` caps the walk
        (the engine always leaves at least the last context token to the
        prefill stream, so admission caps at ``(len - 1) // page_size``)."""
        page = self.page_size
        limit = len(tokens) // page
        if max_pages is not None:
            limit = min(limit, max_pages)
        pids: List[int] = []
        parent = root
        for i in range(limit):
            key = page_key(parent, tokens[i * page: (i + 1) * page])
            hit = self._by_key.get(key)
            if hit is None:
                break
            pids.append(hit[0])
            parent = hit[1]
        return pids

    def insert(self, tokens: Sequence[int], pids: Sequence[int],
               root: int = _PREFIX_ROOT) -> List[int]:
        """Register the full pages covering ``tokens[:len(pids) * page]``
        (``pids[i]`` holds page ``i``'s frozen content), chained off
        ``root``. Returns the *canonical* pid per page: where the chain key
        already exists (an identical prefix was registered first), the
        existing page wins and the caller is expected to adopt it —
        releasing its duplicate — which keeps every slot's shared pages one
        contiguous leading run."""
        page = self.page_size
        out: List[int] = []
        parent = root
        for i, pid in enumerate(pids):
            pid = int(pid)
            key = page_key(parent, tokens[i * page: (i + 1) * page])
            cur = self._by_key.get(key)
            if cur is None:
                assert pid not in self._by_pid, \
                    f"page {pid} already registered under another prefix"
                cur = (pid, self._next_node)
                self._next_node += 1
                self._by_key[key] = cur
                self._by_pid[pid] = key
            out.append(cur[0])
            parent = cur[1]
        return out

    def park(self, pid: int):
        """A registered page's refcount hit zero: keep it bit-reusable in
        the LRU instead of freeing it (reclaim drains oldest-first)."""
        pid = int(pid)
        assert pid in self._by_pid, f"parking unregistered page {pid}"
        self._lru[pid] = None
        self._lru.move_to_end(pid)

    def unpark(self, pid: int):
        """A parked page was re-acquired (refcount 0 -> 1 via a hit)."""
        self._lru.pop(int(pid), None)

    def reclaim(self) -> Optional[int]:
        """Hand the least-recently-used refcount-0 page back to the
        allocator as a blank: drop its index entry (the content is gone for
        sharing purposes) and return the pid. None when nothing is
        parked."""
        if not self._lru:
            return None
        pid, _ = self._lru.popitem(last=False)
        key = self._by_pid.pop(pid)
        del self._by_key[key]
        self.reclaims += 1
        return pid

    def assert_unfrozen(self, page_ids: Iterable[int],
                        frozen_base: Optional[int] = None):
        """Frozen-page invariant: a registered page is shared-frozen —
        content-addressed and possibly mapped by several slots — so no
        write path (prefill chunk, decode append, spill restore) may ever
        target it. The serving engine checks every write set against this
        before issuing the write.

        ``frozen_base`` extends the check to the page *format*: in a
        mixed-precision pool every id >= base addresses the packed FP4
        frozen region, whose pages are read-only from the moment they are
        transcoded — a write there is a format violation even if the index
        entry has since been reclaimed."""
        for pid in page_ids:
            pid = int(pid)
            if frozen_base is not None and pid >= frozen_base:
                raise AssertionError(
                    f"write targets frozen FP4 page {pid} (>= frozen base "
                    f"{frozen_base}): packed FP4 pages are transcoded once "
                    "at freeze time and never written again")
            if pid in self._by_pid:
                raise AssertionError(
                    f"write targets shared-frozen page {pid}: frozen "
                    "pages are immutable (copy-on-write means the boundary "
                    "page must be private)")


# ---------------------------------------------------------------------------
# Accounting
# ---------------------------------------------------------------------------
def pool_bytes_per_token(pool: Dict) -> float:
    """Bytes of *active-store* storage per token slot (all value + scale
    leaves, across the stacked layers), excluding the reserved null page.
    The dedicated frozen region (``*_fz`` leaves) is a separate residency
    pool with its own page count — see ``page_bytes`` for the per-class
    figure the engine's residency accounting is built on."""
    first = pool[pool_keys(pool)[0]]
    n_layers, p1, page = first.shape[:3]
    tokens = (p1 - 1) * page
    total = 0
    for name, leaf in pool.items():
        if "_fz" in name or leaf.size == 0:
            continue
        frac = (leaf.shape[1] - 1) / leaf.shape[1]
        total += leaf.size * leaf.dtype.itemsize * frac
    return total / tokens


def bf16_bytes_per_token(pool: Dict) -> float:
    """What the same pool geometry would cost holding bf16 values (the
    monolithic-cache baseline the fp8 pool replaces)."""
    total = 0
    for name in pool_keys(pool):
        leaf = pool[name]
        per_tok = int(np.prod(leaf.shape[3:])) * leaf.shape[0]  # feat x layers
        total += per_tok * 2
    return float(total)


def page_bytes(pool: Dict, frozen: bool = False) -> float:
    """Bytes one page costs across the stacked layers (values + scales) in
    the requested class: the active store (``frozen=False``) or the packed
    frozen region (``frozen=True``, 0.0 when the pool is homogeneous). The
    building block of the engine's residency accounting — a mixed pool's
    live bytes are ``n_active_live * page_bytes(pool) + n_frozen_live *
    page_bytes(pool, frozen=True)``."""
    total = 0.0
    for name, leaf in pool.items():
        if leaf.size == 0 or ("_fz" in name) != frozen:
            continue
        axis = 1 if leaf.ndim >= 2 else 0
        total += leaf.size * leaf.dtype.itemsize / leaf.shape[axis]
    return total


def pages_needed(n_tokens: int, page_size: int) -> int:
    return max(1, math.ceil(n_tokens / page_size))


def payload_checksum(payload: List[Dict[str, np.ndarray]]) -> int:
    """CRC32 over a spill payload (the per-unit leaf dicts ``_preempt``
    builds: codes + scales + recurrent state). Leaf names are folded into
    the checksum in sorted order so the value is independent of dict
    insertion order; computed at preemption on the pristine host bytes and
    re-verified before a resume commits, so bit rot while spilled is
    caught instead of silently restored into the pool."""
    crc = 0
    for part in payload:
        for name in sorted(part):
            arr = np.ascontiguousarray(part[name])
            crc = zlib.crc32(name.encode(), crc)
            crc = zlib.crc32(arr.tobytes(), crc)
    return crc
