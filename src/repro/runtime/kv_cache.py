"""Quantized paged KV-cache pool for the serving engine.

The KV cache is the largest *activation* tensor the server holds, and decode
attention's dominant memory term. Following the paper's finding that FP
formats beat INT for LLM activations, this module stores K/V as packed FP8
E4M3 codes in fixed-size pages with per-(page, head) scales constrained by
the M2 machinery (core.scales.constrain_scales_m2): each page keeps one
full-precision s_max plus integer pow-2 shifts per head, so the decode
kernel applies scales as an exponent add (kernels.common.decode_fp8) and
multiplies by s_max once per page. Halving KV bytes doubles the slot pool
for the same HBM.

Layout (one pool dict per model segment, leading dim = stacked layers so it
rides the per-segment lax.scan exactly like the old monolithic caches):

  GQA:  k/v        (L, P+1, page, KV, hd)  uint8 codes (fp8) | bf16 values
        k/v_smax   (L, P+1)                f32   per-page full-precision S_max
        k/v_shift  (L, P+1, KV)            int32 pow-2 ratio exponents k_i
  MLA:  ckv        (L, P+1, page, r)   + smax/shift with a single "head"
        krope      (L, P+1, page, dr)    (the latent has no head axis)

Page ids are *global across layers*: page p of every layer belongs to the
same logical page, so one host-side free list serves the whole stack. The
last page id (index P) is a reserved null page — in-graph appends from
inactive batch rows are redirected there instead of corrupting a live page.

Write paths:
  * prefill splice (host-side, ``splice_prefill``): quantize the prompt's
    contiguous K/V page by page and scatter into the slot's allocated pages.
    The splice walks the prompt in ``chunk_pages`` groups so the f32
    staging transient is bounded by the chunk, not the prompt.
  * streaming prefill (in-graph, ``append_prefill_chunk``): the serving
    engine's chunked prefill writes each page-aligned chunk of prompt K/V
    straight into the pool from inside the forward — no contiguous
    max_seq scratch cache ever exists, so transient HBM tracks the chunk
    size and admission cost tracks the true prompt length.
  * decode append (in-graph, ``append_paged``): the touched page is
    gathered, dequantized, the new token written at its row's true offset,
    the page's per-head scales recomputed (amax -> M2), and the page
    re-encoded. With unchanged scales decode->encode is the identity on the
    FP8 grid, so requantization only rounds (once, <= 1/2 ulp) on the few
    steps where a page's amax actually grows.

``PagedState`` (page_table + per-slot true lengths) is the per-row cache
index that replaces the old scalar ``cache_index = max(lengths)`` masking
hack in the serving engine; models treat it as an opaque pytree.
"""
from __future__ import annotations

import math
import zlib
from collections import OrderedDict
from typing import Dict, Iterable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import FORMATS, fp_encode, quantize_to_grid
from repro.core.scales import constrain_scales_m2
from repro.kernels.common import decode_fp8

__all__ = [
    "PagedState",
    "PrefixCache",
    "page_key",
    "init_gqa_pool",
    "init_mla_pool",
    "init_cross_pool",
    "pool_keys",
    "quantize_pages",
    "dequantize_pages",
    "splice_prefill",
    "append_prefill_chunk",
    "write_cross_pages",
    "append_paged",
    "gather_pages",
    "gather_history",
    "gather_slabs",
    "scatter_slabs",
    "pool_bytes_per_token",
    "bf16_bytes_per_token",
    "payload_checksum",
]

_EPS = 1e-12


class PagedState(NamedTuple):
    """Per-row cache index for paged decode: which pages each slot owns and
    how many tokens it has really generated (no synchronized-length hack).

    The optional fields extend the same index to every decode family:
      * ``chunk_len`` — (1,) true token count of a *bucketed* streaming
        prefill chunk (the engine pads chunks to powers of two so jit trace
        count is O(log max_seq), not O(distinct lengths); positions >=
        chunk_len are pad and must be masked out of page writes/logits).
      * ``cross_table``/``enc_lengths`` — enc-dec decoders: page ids of the
        write-once cross-attention pages and the true encoder lengths.
      * ``slabs`` — recurrent families (SSM/xLSTM): per-row state-slab ids
        into the fixed-size slab pool (the last slab id is the reserved
        null slab, like the null page).
    Unused fields stay ``None``; models treat the tuple as an opaque pytree.
    """

    page_table: jnp.ndarray  # (B, pages_per_slot) int32 page ids
    lengths: jnp.ndarray  # (B,) int32 true per-slot lengths
    chunk_len: Optional[jnp.ndarray] = None  # (1,) true prefill-chunk tokens
    cross_table: Optional[jnp.ndarray] = None  # (B, cross_pp) int32 page ids
    enc_lengths: Optional[jnp.ndarray] = None  # (B,) int32 encoder lengths
    slabs: Optional[jnp.ndarray] = None  # (B,) int32 state-slab ids


def _is_fp8(pool: Dict) -> bool:
    first = next(k for k in ("k", "ckv") if k in pool)
    return pool[first].dtype == jnp.uint8


def pool_keys(pool: Dict):
    """The value-bearing leaf names of a pool ('k'/'v' or 'ckv'/'krope')."""
    return ("k", "v") if "k" in pool else ("ckv", "krope")


# ---------------------------------------------------------------------------
# Pool construction
# ---------------------------------------------------------------------------
def _init_store(n_layers, n_pages, page_size, n_kv, head_dim, fmt: Optional[str]):
    p1 = n_pages + 1  # + reserved null page
    if fmt is None:
        return {"_": jnp.zeros((n_layers, p1, page_size, n_kv, head_dim), jnp.bfloat16)}
    assert fmt == "fp8_e4m3", fmt
    return {
        "_": jnp.zeros((n_layers, p1, page_size, n_kv, head_dim), jnp.uint8),
        "_smax": jnp.zeros((n_layers, p1), jnp.float32),
        "_shift": jnp.zeros((n_layers, p1, n_kv), jnp.int32),
    }


def _named(store, name):
    return {(name if k == "_" else name + k): v for k, v in store.items()}


def init_gqa_pool(n_layers, n_pages, page_size, n_kv, head_dim,
                  fmt: Optional[str] = "fp8_e4m3") -> Dict:
    pool = {}
    for name in ("k", "v"):
        pool.update(_named(_init_store(n_layers, n_pages, page_size, n_kv,
                                       head_dim, fmt), name))
    return pool


def init_mla_pool(n_layers, n_pages, page_size, kv_lora_rank, qk_rope_dim,
                  fmt: Optional[str] = "fp8_e4m3") -> Dict:
    """Latent pages: the compressed c_kv and the shared rope key, each with a
    single scale 'head' (squeezed out of the stored value leaves)."""
    pool = {}
    for name, dim in (("ckv", kv_lora_rank), ("krope", qk_rope_dim)):
        store = _init_store(n_layers, n_pages, page_size, 1, dim, fmt)
        store["_"] = store["_"][:, :, :, 0]  # (L, P+1, page, dim)
        pool.update(_named(store, name))
    return pool


def init_cross_pool(n_layers, n_pages, page_size, n_kv, head_dim,
                    fmt: Optional[str] = "fp8_e4m3") -> Dict:
    """Immutable cross-attention pages (enc-dec decoders).

    Same storage layout as a GQA pool — k/v codes + per-(page, head) M2
    scales — but with *write-once* semantics: the encoder runs exactly once
    per request, so a slot's cross pages are written in one shot at encode
    time (``write_cross_pages``) and never touched again. There is no
    append path for them: decode only ever reads (``ops.paged_decode_attn``
    with ``kv_lens = enc_lengths``), which is what lets the per-page scales
    stay frozen at their encode-time amax for the request's whole lifetime.
    """
    return init_gqa_pool(n_layers, n_pages, page_size, n_kv, head_dim, fmt)


# ---------------------------------------------------------------------------
# Page quantization (the M2 machinery applied per (page, head))
# ---------------------------------------------------------------------------
def quantize_pages(vals, fmt_name: str = "fp8_e4m3"):
    """vals: (..., page, KV, hd) f32 -> (codes uint8, s_max (...,), shifts
    (..., KV)). Scales are amax/fmt_max per (page, head), M2-constrained
    across the page's heads: S_i = s_max * 2^-k_i."""
    fmt = FORMATS[fmt_name]
    amax = jnp.max(jnp.abs(vals), axis=(-3, -1))  # (..., KV)
    raw = jnp.maximum(amax * jnp.float32(1.0 / fmt.max_value), _EPS)
    # floor-rounded ratios: S_hat >= raw scale, so page content never
    # saturates (FP grids keep the same relative step one binade up)
    m2 = constrain_scales_m2(raw, group_axis=-1, rounding="floor")
    q = quantize_to_grid(vals / m2.scales[..., None, :, None], fmt)
    return fp_encode(q, fmt), m2.s_max[..., 0], m2.shifts


def dequantize_pages(codes, s_max, shifts, fmt_name: str = "fp8_e4m3"):
    """Inverse: exponent-add shift apply + one s_max multiply per page.
    codes (..., page, KV, hd); s_max (...,); shifts (..., KV) -> f32."""
    fmt = FORMATS[fmt_name]
    v = decode_fp8(codes, fmt, shifts[..., None, :, None])
    return v * s_max[..., None, None, None]


# ---------------------------------------------------------------------------
# Prefill splice (host-side: runs once per admitted request)
# ---------------------------------------------------------------------------
def _with_head_axis(arr, has_heads: bool):
    return arr if has_heads else arr[..., None, :]


def splice_prefill(pool: Dict, prefill_cache: Dict, page_ids: np.ndarray,
                   n_tokens: int, chunk_pages: int = 8) -> Dict:
    """Quantize a batch-1 prefill's contiguous K/V into this slot's pages.

    prefill_cache: the segment cache from ``models.prefill`` — leaves
    (L, 1, max_seq, KV, hd) (GQA) or (L, 1, max_seq, dim) (MLA).
    page_ids: (n_pages_used,) page ids covering ``n_tokens`` (tail zero-pad).
    chunk_pages: staging granularity — pages are quantized ``chunk_pages``
    at a time, so the f32 staging copy never exceeds one chunk (a long
    prompt no longer spikes a prompt-sized transient).
    """
    fp8 = _is_fp8(pool)
    out = dict(pool)
    n_total = len(page_ids)
    for c0 in range(0, n_total, chunk_pages):
        ids_np = np.asarray(page_ids[c0: c0 + chunk_pages], np.int32)
        npg = len(ids_np)
        for name in pool_keys(pool):
            has_heads = pool[name].ndim == 5
            page = pool[name].shape[2]
            t0 = c0 * page
            # the reserved pages may overhang the prefill cache's max_seq
            # (when max_seq is not a page multiple): take what exists, pad
            src = prefill_cache[name][:, 0, t0: t0 + npg * page].astype(jnp.float32)
            short = npg * page - src.shape[1]
            if short > 0:
                src = jnp.pad(src, ((0, 0), (0, short)) + ((0, 0),) * (src.ndim - 2))
            if t0 + npg * page > n_tokens:  # zero the tail beyond the prompt
                # so page amax stays clean
                mask = (t0 + jnp.arange(npg * page) < n_tokens).astype(jnp.float32)
                src = src * mask.reshape((1, npg * page) + (1,) * (src.ndim - 2))
            src = _with_head_axis(src, has_heads)
            nl, kv, hd = src.shape[0], src.shape[-2], src.shape[-1]
            vals = src.reshape(nl, npg, page, kv, hd)
            ids = jnp.asarray(ids_np)
            if fp8:
                codes, smax, shifts = quantize_pages(vals)
                if not has_heads:
                    codes = codes[..., 0, :]
                out[name] = out[name].at[:, ids].set(codes)
                out[name + "_smax"] = out[name + "_smax"].at[:, ids].set(smax)
                out[name + "_shift"] = out[name + "_shift"].at[:, ids].set(shifts)
            else:
                store = vals if has_heads else vals[..., 0, :]
                out[name] = out[name].at[:, ids].set(store.astype(pool[name].dtype))
    return out


# ---------------------------------------------------------------------------
# Decode append (in-graph: runs inside the jitted decode step, per layer)
# ---------------------------------------------------------------------------
def append_paged(pool_layer: Dict, new_vals: Dict, state: PagedState) -> Dict:
    """Write one new token per batch row at its row's true position.

    pool_layer: one layer's slice of a pool (no leading L dim).
    new_vals: {"k": (B, 1, KV, hd), "v": ...} or {"ckv": (B, 1, r), ...}.
    Rows with lengths == 0 (empty slots) are redirected to the null page.
    """
    fp8 = _is_fp8(pool_layer)
    b = state.lengths.shape[0]
    out = dict(pool_layer)
    rows = jnp.arange(b)
    for name in pool_keys(pool_layer):
        store = pool_layer[name]
        has_heads = store.ndim == 4  # (P+1, page, KV, hd) vs (P+1, page, dim)
        page = store.shape[1]
        null = store.shape[0] - 1
        slot = state.lengths // page
        off = state.lengths % page
        pid = jnp.take_along_axis(state.page_table, slot[:, None], axis=1)[:, 0]
        pid = jnp.where(state.lengths > 0, pid, null).astype(jnp.int32)
        new = new_vals[name].astype(jnp.float32)[:, 0]  # (B, KV, hd) | (B, dim)
        new = _with_head_axis(new, has_heads)  # (B, KV|1, hd)
        if not fp8:
            val = new if has_heads else new[:, 0]
            out[name] = store.at[pid, off].set(val.astype(store.dtype))
            continue
        fmt = FORMATS["fp8_e4m3"]
        codes = _with_head_axis(store[pid], has_heads)  # (B, page, KV|1, hd)
        smax = pool_layer[name + "_smax"][pid]  # (B,)
        shifts = pool_layer[name + "_shift"][pid]  # (B, KV|1)
        vals = dequantize_pages(codes, smax, shifts)
        vals = vals.at[rows, off].set(new)
        # zero page slots past this row's position: a recycled page may
        # carry a previous owner's stale codes, which must not leak into
        # the page amax (and so the scales) of its new owner
        live = jnp.arange(page)[None, :] <= off[:, None]
        vals = vals * live[:, :, None, None].astype(vals.dtype)
        ncodes, nsmax, nshift = quantize_pages(vals)
        if not has_heads:
            ncodes = ncodes[..., 0, :]
        out[name] = store.at[pid].set(ncodes)
        out[name + "_smax"] = pool_layer[name + "_smax"].at[pid].set(nsmax)
        out[name + "_shift"] = pool_layer[name + "_shift"].at[pid].set(nshift)
    return out


def append_prefill_chunk(pool_layer: Dict, new_vals: Dict,
                         state: PagedState) -> Dict:
    """Write one page-aligned chunk of a (batch-1) streaming prefill.

    pool_layer: one layer's slice of a pool (no leading L dim).
    new_vals: {"k": (1, S, KV, hd), ...} or {"ckv": (1, S, r), ...} — S
    prompt tokens starting at position ``state.lengths[0]``, which must be
    a page-size multiple (the engine feeds page-aligned chunks; only the
    final chunk of a prompt may be partial). The tail of a partial last
    page is zero-padded so the page amax stays clean; a later decode
    append at that offset requantizes the page exactly as usual.

    Unlike ``splice_prefill`` this runs *inside* the jitted chunk forward:
    the prompt's K/V never exists as a contiguous max_seq scratch cache —
    transient memory is bounded by the chunk, and the pages written here
    are immediately the attention source for the next chunk.

    When ``state.chunk_len`` is set, the chunk was padded to a power-of-two
    bucket: positions >= chunk_len carry pad-token K/V and are zeroed here
    so they cannot leak into the page amax (and so the scales). Pages the
    pad region overhangs must point at the null page in ``page_table``.
    """
    fp8 = _is_fp8(pool_layer)
    out = dict(pool_layer)
    start = state.lengths[0]
    for name in pool_keys(pool_layer):
        store = pool_layer[name]
        has_heads = store.ndim == 4  # (P+1, page, KV, hd) vs (P+1, page, dim)
        page = store.shape[1]
        new = new_vals[name].astype(jnp.float32)[0]  # (S, KV, hd) | (S, dim)
        s = new.shape[0]
        if state.chunk_len is not None:  # zero the pad tail of a bucketed chunk
            live = (jnp.arange(s) < state.chunk_len[0]).astype(jnp.float32)
            new = new * live.reshape((s,) + (1,) * (new.ndim - 1))
        npg = -(-s // page)
        pad = npg * page - s
        if pad:
            new = jnp.pad(new, ((0, pad),) + ((0, 0),) * (new.ndim - 1))
        new = _with_head_axis(new, has_heads)  # (npg * page, KV|1, hd)
        vals = new.reshape(npg, page, new.shape[-2], new.shape[-1])
        pid = jax.lax.dynamic_slice_in_dim(
            state.page_table[0], start // page, npg)
        if fp8:
            codes, smax, shifts = quantize_pages(vals)
            if not has_heads:
                codes = codes[..., 0, :]
            out[name] = store.at[pid].set(codes)
            out[name + "_smax"] = pool_layer[name + "_smax"].at[pid].set(smax)
            out[name + "_shift"] = pool_layer[name + "_shift"].at[pid].set(shifts)
        else:
            stv = vals if has_heads else vals[..., 0, :]
            out[name] = store.at[pid].set(stv.astype(store.dtype))
    return out


def write_cross_pages(pool_layer: Dict, new_vals: Dict,
                      cross_table: jnp.ndarray) -> Dict:
    """Write one layer's encoder-derived cross K/V into its (write-once)
    cross pages, in one shot at encode time.

    pool_layer: one layer's slice of an ``init_cross_pool`` pool.
    new_vals: {"k": (1, T_enc, KV, hd), "v": ...} — the full encoder
    sequence. cross_table: (1, cross_pp) page ids covering T_enc (tail
    entries past ceil(T_enc / page) are never written).

    This is the *only* writer of cross pages: decode never appends to them,
    so the per-(page, head) M2 scales computed here are final.
    """
    state = PagedState(cross_table, jnp.zeros((1,), jnp.int32))
    return append_prefill_chunk(pool_layer, new_vals, state)


# ---------------------------------------------------------------------------
# State slabs (SSM / xLSTM recurrent state)
# ---------------------------------------------------------------------------
def gather_slabs(pool_layer, slab_ids):
    """Recurrent-state read for one layer: slab-pool leaves (S+1, ...) ->
    per-row state (B, ...). ``slab_ids``: (B,) int32; the last slab (index
    S) is the reserved null slab inactive rows point at.

    A slab is the fixed-size analogue of a page for families whose decode
    state does not grow with context (SSM state + conv tail, xLSTM
    (c, n, m) cells): one slab per running request, allocated at admission,
    steal/spill-able like pages — just never grown."""
    return jax.tree.map(lambda a: a[slab_ids], pool_layer)


def scatter_slabs(pool_layer, slab_ids, new_rows):
    """Recurrent-state write-back: scatter each row's updated state into
    its slab. Rows sharing the null slab overwrite each other there —
    harmless by construction (the null slab is never read as live state)."""
    return jax.tree.map(
        lambda full, row: full.at[slab_ids].set(row.astype(full.dtype)),
        pool_layer, new_rows)


def gather_pages(pool_layer: Dict, name: str, state: PagedState):
    """Dequantized gather for the jnp paths: (B, PP * page, KV, hd) f32 for
    GQA leaves, (B, PP * page, dim) for MLA leaves."""
    store = pool_layer[name]
    has_heads = store.ndim == 4
    page = store.shape[1]
    b, pp = state.page_table.shape
    pages = store[state.page_table]  # (B, PP, page, ...)
    if _is_fp8(pool_layer):
        smax = pool_layer[name + "_smax"][state.page_table]  # (B, PP)
        shifts = pool_layer[name + "_shift"][state.page_table]  # (B, PP, KV|1)
        vals = dequantize_pages(_with_head_axis(pages, has_heads), smax, shifts)
        if not has_heads:
            vals = vals[..., 0, :]
    else:
        vals = pages.astype(jnp.float32)
    return vals.reshape(b, pp * page, *vals.shape[3:])


def gather_history(pool_layer: Dict, state: PagedState, chunk_len: int):
    """History gather for a streaming-prefill chunk (the shared page math
    for the GQA and MLA model glue — keep it in one place).

    The chunk starts page-aligned at ``state.lengths[0]``, so every token
    of the gather below that (dynamic) position is fully-packed history:
    token i sits at absolute position i. The *whole* (engine-trimmed or
    power-of-two-bucketed) table is gathered — including the chunk's own
    just-written pages and any null-page fill — and the caller masks
    columns ``>= lengths[0]``: those positions are covered exactly by the
    chunk's inline K/V (no early FP8 round trip) or are pad. Returns
    ``({name: (B, W * page, ...)}, W * page)``, or ``({}, 0)`` when the
    table is no wider than the chunk itself (prompt fits one chunk,
    nothing could be history).
    """
    first = pool_layer[pool_keys(pool_layer)[0]]
    page = first.shape[1]
    if state.page_table.shape[1] <= -(-chunk_len // page):
        return {}, 0
    return ({name: gather_pages(pool_layer, name, state)
             for name in pool_keys(pool_layer)},
            state.page_table.shape[1] * page)


# ---------------------------------------------------------------------------
# Content-addressed shared-prefix cache (host-side index over frozen pages)
# ---------------------------------------------------------------------------
_PREFIX_ROOT = -1  # the parent node id of every depth-0 page


def page_key(parent: int, tokens: Sequence[int]) -> Tuple:
    """Content address of one *full* page: the page's token ids chained on
    the parent page's *node id* (an integer assigned at registration and
    never reissued), so the key identifies the whole prefix up to and
    including this page — two identical token windows at different depths,
    or under different histories, never collide. Keys are exact token
    tuples, not hashes, so there is no collision risk; the integer parent
    keeps each dict lookup O(page_size) instead of re-hashing the whole
    ancestor chain (a nested-tuple parent would make a d-page walk
    O(d^2 * page_size))."""
    return (parent, tuple(int(t) for t in tokens))


class PrefixCache:
    """Host-side radix index over *full, scale-frozen* KV pages.

    ZeroQuant-FP's scaling constraints make a full FP8 page an immutable,
    self-contained block: once the prefill stream (or the last decode
    append that filled it) has passed a page, its per-(page, head) M2
    scales are frozen at amax and the codes are never requantized again.
    That makes the page content a pure function of its token-id prefix, so
    full pages are content-addressable: requests sharing a prompt prefix
    (system prompts, few-shot headers) can map the same physical pages
    instead of re-prefilling and re-quantizing identical K/V.

    The index maps ``page_key(parent, tokens)`` -> page id, one entry per
    registered page (and one key per page: a page holds exactly one
    content). Ownership/refcounts live in the serving engine; the cache
    additionally tracks the **reusable LRU** — registered pages whose
    refcount dropped to zero. Those stay bit-reusable (a later request with
    the same prefix re-acquires them for free) until the allocator
    *reclaims* them, oldest-first, which drops the index entry and hands
    the physical page back as a blank. Reclaiming a mid-chain page strands
    its descendants (the walk can no longer reach them) — they simply age
    out of the LRU in turn.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        # key -> (pid, node id). The node id stands in for the full chain
        # as the parent component of children's keys; it is monotonically
        # assigned and never reissued, so a reclaimed page's stranded
        # descendants can never be re-attached under recycled-pid content
        self._by_key: Dict[Tuple, Tuple[int, int]] = {}
        self._by_pid: Dict[int, Tuple] = {}
        # refcount-0 registered pages, oldest-parked first
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self._next_node = 0
        self.reclaims = 0

    def __len__(self) -> int:
        return len(self._by_key)

    @property
    def n_reusable(self) -> int:
        """Registered pages at refcount 0 — allocatable without stealing."""
        return len(self._lru)

    def reusable_ids(self) -> List[int]:
        """The parked refcount-0 page ids, oldest first (LRU order)."""
        return list(self._lru)

    def registered(self, pid: int) -> bool:
        return int(pid) in self._by_pid

    def walk(self, tokens: Sequence[int], max_pages: Optional[int] = None
             ) -> List[int]:
        """Longest chain of consecutive full-page hits for this token
        prefix, from the root: returns the page ids holding
        ``tokens[:len(hits) * page_size]``. ``max_pages`` caps the walk
        (the engine always leaves at least the last context token to the
        prefill stream, so admission caps at ``(len - 1) // page_size``)."""
        page = self.page_size
        limit = len(tokens) // page
        if max_pages is not None:
            limit = min(limit, max_pages)
        pids: List[int] = []
        parent = _PREFIX_ROOT
        for i in range(limit):
            key = page_key(parent, tokens[i * page: (i + 1) * page])
            hit = self._by_key.get(key)
            if hit is None:
                break
            pids.append(hit[0])
            parent = hit[1]
        return pids

    def insert(self, tokens: Sequence[int], pids: Sequence[int]) -> List[int]:
        """Register the full pages covering ``tokens[:len(pids) * page]``
        (``pids[i]`` holds page ``i``'s frozen content). Returns the
        *canonical* pid per page: where the chain key already exists (an
        identical prefix was registered first), the existing page wins and
        the caller is expected to adopt it — releasing its duplicate —
        which keeps every slot's shared pages one contiguous leading run."""
        page = self.page_size
        out: List[int] = []
        parent = _PREFIX_ROOT
        for i, pid in enumerate(pids):
            pid = int(pid)
            key = page_key(parent, tokens[i * page: (i + 1) * page])
            cur = self._by_key.get(key)
            if cur is None:
                assert pid not in self._by_pid, \
                    f"page {pid} already registered under another prefix"
                cur = (pid, self._next_node)
                self._next_node += 1
                self._by_key[key] = cur
                self._by_pid[pid] = key
            out.append(cur[0])
            parent = cur[1]
        return out

    def park(self, pid: int):
        """A registered page's refcount hit zero: keep it bit-reusable in
        the LRU instead of freeing it (reclaim drains oldest-first)."""
        pid = int(pid)
        assert pid in self._by_pid, f"parking unregistered page {pid}"
        self._lru[pid] = None
        self._lru.move_to_end(pid)

    def unpark(self, pid: int):
        """A parked page was re-acquired (refcount 0 -> 1 via a hit)."""
        self._lru.pop(int(pid), None)

    def reclaim(self) -> Optional[int]:
        """Hand the least-recently-used refcount-0 page back to the
        allocator as a blank: drop its index entry (the content is gone for
        sharing purposes) and return the pid. None when nothing is
        parked."""
        if not self._lru:
            return None
        pid, _ = self._lru.popitem(last=False)
        key = self._by_pid.pop(pid)
        del self._by_key[key]
        self.reclaims += 1
        return pid

    def assert_unfrozen(self, page_ids: Iterable[int]):
        """Frozen-page invariant: a registered page is shared-frozen —
        content-addressed and possibly mapped by several slots — so no
        write path (prefill chunk, decode append, spill restore) may ever
        target it. The serving engine checks every write set against this
        before issuing the write."""
        for pid in page_ids:
            if int(pid) in self._by_pid:
                raise AssertionError(
                    f"write targets shared-frozen page {int(pid)}: frozen "
                    "pages are immutable (copy-on-write means the boundary "
                    "page must be private)")


# ---------------------------------------------------------------------------
# Accounting
# ---------------------------------------------------------------------------
def pool_bytes_per_token(pool: Dict) -> float:
    """Bytes of pool storage per token slot (all value + scale leaves,
    across the stacked layers), excluding the reserved null page."""
    first = pool[pool_keys(pool)[0]]
    n_layers, p1, page = first.shape[:3]
    tokens = (p1 - 1) * page
    total = 0
    for leaf in pool.values():
        frac = (leaf.shape[1] - 1) / leaf.shape[1]
        total += leaf.size * leaf.dtype.itemsize * frac
    return total / tokens


def bf16_bytes_per_token(pool: Dict) -> float:
    """What the same pool geometry would cost holding bf16 values (the
    monolithic-cache baseline the fp8 pool replaces)."""
    total = 0
    for name in pool_keys(pool):
        leaf = pool[name]
        per_tok = int(np.prod(leaf.shape[3:])) * leaf.shape[0]  # feat x layers
        total += per_tok * 2
    return float(total)


def pages_needed(n_tokens: int, page_size: int) -> int:
    return max(1, math.ceil(n_tokens / page_size))


def payload_checksum(payload: List[Dict[str, np.ndarray]]) -> int:
    """CRC32 over a spill payload (the per-unit leaf dicts ``_preempt``
    builds: codes + scales + recurrent state). Leaf names are folded into
    the checksum in sorted order so the value is independent of dict
    insertion order; computed at preemption on the pristine host bytes and
    re-verified before a resume commits, so bit rot while spilled is
    caught instead of silently restored into the pool."""
    crc = 0
    for part in payload:
        for name in sorted(part):
            arr = np.ascontiguousarray(part[name])
            crc = zlib.crc32(name.encode(), crc)
            crc = zlib.crc32(arr.tobytes(), crc)
    return crc
