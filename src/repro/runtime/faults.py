"""Deterministic fault injection + structured failure types for the engine.

A production W4A8 serving engine must degrade *per request*, not per
process: a non-finite logit in one decode row (FP8 E4M3's NaN code point
and saturation behavior are the format's operational sharp edge), a
bit-rotted host spill, or a transient allocator stall should cost exactly
the affected request — never the batch, never the process. This module
provides the two halves of testing that claim:

  * ``FaultPlan`` — a seeded, deterministic fault schedule the Server
    consults through no-op-by-default hook points. It can poison the
    logits of a chosen (engine step, slot) with NaN *inside the jitted
    step* (upstream of the engine's own isfinite sentinel, so detection
    exercises the real path, not a mock), corrupt or drop a host spill
    payload byte-exactly (caught by the spill CRC at resume), and blank
    the page allocator for chosen engine ticks (transient exhaustion —
    the steal/defer machinery must absorb it). Every injection is
    recorded, so a chaos test can assert *exactly* the injected requests
    failed and nothing else changed.
  * ``ServingError`` — the drain-level failure (starvation / max_steps)
    carrying the requests that *did* finish plus per-request diagnostics
    for everything still pending, so strict-mode callers can recover
    partial results instead of losing the batch.
  * ``PoolCorruptionError`` — raised by ``Server.audit()`` when a pool
    ownership invariant breaks, with the violation list and a state dump.

No module here imports ``serve`` — the dependency points one way.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = ["FaultPlan", "PoolCorruptionError", "ServingError"]


class ServingError(RuntimeError):
    """Drain-level failure (starvation or max_steps exhaustion) that does
    not discard completed work: ``finished`` holds the requests retired
    during the failing ``run_until_drained`` call, ``pending`` one
    diagnostic dict per request still queued / spilled / active (rid,
    state, wait-line age, context length, pages needed...)."""

    def __init__(self, message: str, finished: Sequence = (),
                 pending: Sequence[Dict] = ()):
        super().__init__(message)
        self.finished = list(finished)
        self.pending = list(pending)


class PoolCorruptionError(RuntimeError):
    """A pool ownership invariant broke (refcount != table occupancy,
    leaked / double-owned page or slab, frozen page in a write set...).
    ``violations`` lists every broken invariant, ``dump`` is a host-side
    snapshot of the accounting state for post-mortem."""

    def __init__(self, violations: Sequence[str], dump: Dict = None):
        head = "; ".join(list(violations)[:4])
        more = len(violations) - min(len(violations), 4)
        super().__init__(
            f"pool corruption: {len(violations)} invariant violation(s): "
            f"{head}{f'; ... +{more} more' if more > 0 else ''}")
        self.violations = list(violations)
        self.dump = dict(dump or {})


@dataclasses.dataclass
class FaultPlan:
    """A deterministic fault schedule. All hooks are no-ops unless the
    matching schedule field names the current step / tick / spill.

    Schedule (what to inject):
      * ``nan_logits`` — (engine step, slot) pairs whose decode logits
        are poisoned to NaN in-graph (keyed on ``Server._step_no``, the
        decode-step counter: the poison rides the jitted step as a bool
        input, so there is no retrace).
      * ``corrupt_spills`` / ``drop_spills`` — spill *ordinals* (0 = the
        first preemption this server performs) whose host payload gets
        one byte flipped / is replaced with zeros. Caught by the spill
        CRC at resume -> tail re-prefill, the request still finishes.
      * ``alloc_fail_ticks`` — engine *ticks* (``Server._tick``, which
        advances every ``step()`` call even when no row decodes) on
        which the page allocator reports zero capacity. Tick-keyed so a
        blocked tick always passes: the exhaustion is transient by
        construction.

    Record (what actually landed — chaos tests assert against these):
      * ``nan_hits`` — (step, slot, rid) per poisoned row that held a
        live request (a poison aimed at an empty slot lands on nothing).
      * ``corrupted_rids`` / ``dropped_rids`` — rids whose spill payload
        was tampered with.
      * ``blocked_ticks`` — ticks on which the allocator was blanked.
    """

    seed: int = 0
    nan_logits: Tuple[Tuple[int, int], ...] = ()
    corrupt_spills: Tuple[int, ...] = ()
    drop_spills: Tuple[int, ...] = ()
    alloc_fail_ticks: Tuple[int, ...] = ()
    nan_hits: List[Tuple[int, int, int]] = dataclasses.field(
        default_factory=list)
    corrupted_rids: List[int] = dataclasses.field(default_factory=list)
    dropped_rids: List[int] = dataclasses.field(default_factory=list)
    blocked_ticks: List[int] = dataclasses.field(default_factory=list)
    _spill_no: int = dataclasses.field(default=0, repr=False)

    @classmethod
    def seeded(cls, seed: int, *, slots: int, max_step: int,
               n_nan: int = 1, n_corrupt: int = 1, n_drop: int = 0,
               n_alloc: int = 1, first_step: int = 2) -> "FaultPlan":
        """Draw a random-but-reproducible schedule: ``n_nan`` poisoned
        (step, slot) pairs in [first_step, max_step), the first
        ``n_corrupt`` spills corrupted and the next ``n_drop`` dropped,
        ``n_alloc`` blanked allocator ticks."""
        rng = np.random.default_rng(seed)
        lo, hi = first_step, max(first_step + 1, max_step)
        nan = tuple(sorted(
            (int(st), int(rng.integers(slots)))
            for st in rng.choice(np.arange(lo, hi),
                                 size=min(n_nan, hi - lo), replace=False)))
        alloc = tuple(sorted(
            int(t) for t in rng.choice(np.arange(lo, hi),
                                       size=min(n_alloc, hi - lo),
                                       replace=False)))
        return cls(seed=seed, nan_logits=nan,
                   corrupt_spills=tuple(range(n_corrupt)),
                   drop_spills=tuple(range(n_corrupt, n_corrupt + n_drop)),
                   alloc_fail_ticks=alloc)

    # -- hooks (called by Server; every one is a no-op off-schedule) -------
    def poison_rows(self, step: int, n_slots: int) -> np.ndarray:
        """Bool mask (n_slots,) of rows whose logits this decode step
        poisons to NaN (fed to the jitted step as an input)."""
        mask = np.zeros((n_slots,), bool)
        for st, sl in self.nan_logits:
            if st == step and 0 <= sl < n_slots:
                mask[sl] = True
        return mask

    def note_nan(self, step: int, slot: int, rid: int):
        self.nan_hits.append((step, slot, rid))

    def alloc_blocked(self, tick: int) -> bool:
        """True on ticks the page allocator must report zero capacity."""
        if tick in self.alloc_fail_ticks:
            self.blocked_ticks.append(tick)
            return True
        return False

    def spill_payload(self, rid: int,
                      payload: List[Dict[str, np.ndarray]]):
        """Tamper with a spill payload on its way to host residency (the
        spill's CRC was computed on the pristine bytes first — this
        models bit rot *while spilled*, which the resume-time verify
        must catch). Returns the (possibly tampered) payload."""
        ordinal = self._spill_no
        self._spill_no += 1
        if ordinal in self.drop_spills:
            self.dropped_rids.append(rid)
            return [{name: np.zeros_like(arr) for name, arr in part.items()}
                    for part in payload]
        if ordinal in self.corrupt_spills:
            rng = np.random.default_rng((self.seed, ordinal))
            leaves = [(pi, name) for pi, part in enumerate(payload)
                      for name in sorted(part) if part[name].size]
            if leaves:
                pi, name = leaves[int(rng.integers(len(leaves)))]
                payload = [dict(part) for part in payload]
                arr = np.array(payload[pi][name])  # host copy, contiguous
                flat = arr.view(np.uint8).reshape(-1)
                flat[int(rng.integers(flat.size))] ^= 0xFF
                payload[pi][name] = arr
                self.corrupted_rids.append(rid)
        return payload
