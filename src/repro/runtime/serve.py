"""Batched W4A8 serving loop: continuous-batching-lite over a fixed slot
pool, prefill + decode with the quantized checkpoint.

Serving model: ``Server`` owns `slots` concurrent sequences sharing one KV
cache (slot = batch row). Requests join free slots; each engine step decodes
one token for every active slot. Prefill for a new request runs row-wise
into its slot (single-row prefill + cache splice). This is the scheduling
skeleton of a vLLM-style engine adapted to fixed-shape jit programs (shapes
never change -> one compiled decode step).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import models

__all__ = ["Request", "Server"]


@contextlib.contextmanager
def _backend_scope(name: Optional[str]):
    """Temporarily select a kernel backend (None = leave untouched). Keeps a
    Server's backend choice scoped to its own prefill/decode tracing instead
    of leaking into every other model in the process."""
    if name is None:
        yield
        return
    from repro.kernels import ops as _kops

    prev = _kops.get_backend()
    _kops.set_backend(name)
    try:
        yield
    finally:
        _kops.set_backend(prev)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, params, cfg, slots: int = 4, max_seq: int = 512,
                 a_fmt: Optional[str] = "fp8_e4m3",
                 kernel_backend: Optional[str] = None):
        """``kernel_backend``: 'pallas' routes every PackedLinear matmul in
        prefill/decode through the fused single-pass W4A8 kernel (in-kernel
        FP8 act-quant + LoRC epilogue; MoE/MLA absorbed paths use the
        batched variant); 'ref' forces the jnp oracles; None keeps the
        process-wide setting (REPRO_KERNEL_BACKEND). The choice is scoped to
        this server's prefill/decode calls, not the whole process."""
        self.kernel_backend = kernel_backend
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.a_fmt = a_fmt
        self.caches = models.init_cache(cfg, slots, max_seq)
        self.lengths = np.zeros(slots, dtype=np.int64)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []

        self._decode = jax.jit(
            lambda p, c, t, i: models.decode_step(p, cfg, t, c, i, a_fmt=a_fmt)
        )

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.active[slot] = req
                self._prefill_slot(slot, req)

    def _prefill_slot(self, slot: int, req: Request):
        """Row-wise prefill: run the prompt through a batch-1 prefill and
        splice the resulting caches into this slot's row."""
        toks = jnp.asarray([req.prompt], jnp.int32)
        with _backend_scope(self.kernel_backend):
            logits, c1 = models.prefill(self.params, self.cfg,
                                        {"tokens": toks}, self.max_seq,
                                        a_fmt=self.a_fmt)

        def splice(full, one):
            return jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1
            )

        self.caches = jax.tree.map(splice, self.caches, c1)
        self.lengths[slot] = len(req.prompt)
        req.out.append(int(jnp.argmax(logits[0])))

    # -- engine step ----------------------------------------------------------
    def step(self):
        """One decode step for all active slots (synchronized lengths are not
        required: per-slot cache_index would need per-row attention masks;
        this engine keeps a common index = max length and relies on the
        kv_len mask for shorter rows — documented simplification)."""
        self._admit()
        if not any(self.active):
            return False
        tok = np.zeros((self.slots, 1), dtype=np.int32)
        for s, req in enumerate(self.active):
            if req is not None and req.out:
                tok[s, 0] = req.out[-1]
        idx = int(self.lengths.max())
        with _backend_scope(self.kernel_backend):
            logits, self.caches = self._decode(self.params, self.caches,
                                               jnp.asarray(tok), idx)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(nxt[s]))
            self.lengths[s] += 1
            if len(req.out) >= req.max_new or self.lengths[s] >= self.max_seq - 1:
                req.done = True
                self.active[s] = None
        return True

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        return finished
