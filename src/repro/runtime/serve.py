"""Batched W4A8 serving loop over a quantized paged KV-cache pool.

Serving model: ``Server`` owns `slots` concurrent sequences (slot = batch
row). Requests join free slots; each engine step decodes one token for every
active slot. Prefill for a new request runs row-wise (batch-1) and is
*spliced into pages*: the prompt's K/V is quantized page by page into the
pool (runtime.kv_cache), so the engine never holds a monolithic
(slots, max_seq, ...) cache. This is the scheduling skeleton of a
vLLM-style paged engine adapted to fixed-shape jit programs (page table and
per-slot lengths are jit *inputs*; shapes never change -> one compiled
decode step).

``kv_fmt`` selects the page payload: ``"fp8_e4m3"`` stores packed FP8 codes
with per-(page, head) M2 scales (~0.52x the bytes of bf16 -> ~2x the slot
pool per HBM byte), ``None`` keeps bf16 pages as the fallback path. Both
run the same paged decode attention with per-slot *true* lengths — the old
``idx = max(lengths)`` synchronized-index masking hack is gone; rows carry
their own positions and length masks end to end.

Families whose decode state cannot be paged (enc-dec cross-attention
caches, SSM/xLSTM recurrent states) keep the legacy monolithic engine.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.models.transformer import segments_for
from repro.runtime import kv_cache as kvc

__all__ = ["Request", "Server"]


@contextlib.contextmanager
def _backend_scope(name: Optional[str]):
    """Temporarily select a kernel backend (None = leave untouched). Keeps a
    Server's backend choice scoped to its own prefill/decode tracing instead
    of leaking into every other model in the process."""
    if name is None:
        yield
        return
    from repro.kernels import ops as _kops

    prev = _kops.get_backend()
    _kops.set_backend(name)
    try:
        yield
    finally:
        _kops.set_backend(prev)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, params, cfg, slots: int = 4, max_seq: int = 512,
                 a_fmt: Optional[str] = "fp8_e4m3",
                 kernel_backend: Optional[str] = None,
                 kv_fmt: Optional[str] = None,
                 page_size: int = 64,
                 pool_pages: Optional[int] = None):
        """``kernel_backend``: 'pallas' routes every PackedLinear matmul in
        prefill/decode through the fused single-pass W4A8 kernel, and paged
        decode attention through the flash-decoding page-gather kernel;
        'ref' forces the jnp oracles; None keeps the process-wide setting.

        ``kv_fmt``: KV page payload — 'fp8_e4m3' (packed codes +
        per-(page, head) M2 scales) or None (bf16 pages, fallback path).
        ``page_size``: tokens per page. ``pool_pages``: pool capacity in
        pages (default: slots * pages_per_slot — full backing)."""
        self.kernel_backend = kernel_backend
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.a_fmt = a_fmt
        self.kv_fmt = kv_fmt
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []

        self.paged = cfg.encoder_layers == 0 and cfg.ssm is None
        if not self.paged:
            if kv_fmt is not None:
                raise ValueError(
                    f"kv_fmt={kv_fmt!r}: paged KV quantization needs pageable "
                    "decode state (enc-dec / SSM families keep bf16 caches)")
            self.caches = models.init_cache(cfg, slots, max_seq)
            self.lengths = np.zeros(slots, dtype=np.int64)
            self._decode = jax.jit(
                lambda p, c, t, i: models.decode_step(p, cfg, t, c, i, a_fmt=a_fmt)
            )
            return

        # ---- paged pool + host-side allocator ----------------------------
        self.page_size = page_size
        self.pages_per_slot = math.ceil(max_seq / page_size)
        n_pages = pool_pages or slots * self.pages_per_slot
        self._n_pages = n_pages
        self.pools = []
        for seg in segments_for(cfg):
            if seg.mixer == "gqa":
                pool = kvc.init_gqa_pool(seg.count, n_pages, page_size,
                                         cfg.n_kv_heads, cfg.resolved_head_dim,
                                         kv_fmt)
            elif seg.mixer == "mla":
                pool = kvc.init_mla_pool(seg.count, n_pages, page_size,
                                         cfg.mla.kv_lora_rank,
                                         cfg.mla.qk_rope_dim, kv_fmt)
            else:  # pragma: no cover — guarded by self.paged above
                raise ValueError(f"unpageable mixer {seg.mixer!r}")
            self.pools.append({"kv": pool})
        self.free_pages: List[int] = list(range(n_pages))
        self.slot_pages: List[List[int]] = [[] for _ in range(slots)]
        self.page_table = np.zeros((slots, self.pages_per_slot), np.int32)
        self.lengths = np.zeros(slots, dtype=np.int32)
        self._decode = jax.jit(
            lambda p, c, t, st: models.decode_step(p, cfg, t, c, st, a_fmt=a_fmt)
        )

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        if self.paged:  # fail fast on requests no retirement can ever fit
            need = kvc.pages_needed(
                min(len(req.prompt) + req.max_new, self.max_seq), self.page_size)
            if need > self._n_pages:
                raise ValueError(
                    f"request {req.rid}: needs {need} pages but the pool has "
                    f"{self._n_pages}; raise pool_pages or shrink prompt/max_new")
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.slots):
            if self.active[slot] is None and self.queue:
                if self.paged and not self._reserve(slot, self.queue[0]):
                    break  # pool exhausted: wait for retirements
                req = self.queue.pop(0)
                self.active[slot] = req
                self._prefill_slot(slot, req)

    def _reserve(self, slot: int, req: Request) -> bool:
        """Reserve this request's worst-case pages up front (prompt +
        generated tokens): no mid-flight stalls once admitted."""
        need_tokens = min(len(req.prompt) + req.max_new, self.max_seq)
        npg = kvc.pages_needed(need_tokens, self.page_size)
        if len(self.free_pages) < npg:
            return False
        ids = [self.free_pages.pop(0) for _ in range(npg)]
        self.slot_pages[slot] = ids
        row = np.zeros(self.pages_per_slot, np.int32)
        row[: len(ids)] = ids
        self.page_table[slot] = row
        return True

    def _prefill_slot(self, slot: int, req: Request):
        """Row-wise prefill, then splice the prompt's caches into this
        slot's row (legacy) or quantize them into the slot's pages."""
        toks = jnp.asarray([req.prompt], jnp.int32)
        with _backend_scope(self.kernel_backend):
            logits, c1 = models.prefill(self.params, self.cfg,
                                        {"tokens": toks}, self.max_seq,
                                        a_fmt=self.a_fmt)
        n = len(req.prompt)
        if self.paged:
            used = kvc.pages_needed(n, self.page_size)
            ids = np.asarray(self.slot_pages[slot][:used], np.int32)
            for i, pool in enumerate(self.pools):
                self.pools[i] = {"kv": kvc.splice_prefill(pool["kv"],
                                                          c1[i]["kv"], ids, n)}
        else:
            def splice(full, one):
                return jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=1
                )

            self.caches = jax.tree.map(splice, self.caches, c1)
        self.lengths[slot] = n
        req.out.append(int(jnp.argmax(logits[0])))

    # -- retirement ----------------------------------------------------------
    def _retire(self, slot: int, req: Request):
        req.done = True
        self.active[slot] = None
        self.finished.append(req)
        if not self.paged:
            return
        # freed pages are NOT zeroed (that would rewrite the whole pool per
        # retirement): recycled pages are overwritten by splice_prefill, and
        # decode appends mask positions past the new owner's length before
        # recomputing page scales, so stale codes can never leak
        self.free_pages.extend(self.slot_pages[slot])
        self.slot_pages[slot] = []
        self.page_table[slot] = 0
        self.lengths[slot] = 0

    # -- engine step ----------------------------------------------------------
    def step(self):
        """One decode step for all active slots. The paged engine passes
        per-slot true lengths + the page table into the jitted step (per-row
        positions and length masks); the legacy engine keeps the documented
        common-index simplification."""
        self._admit()
        if not any(self.active):
            return False
        tok = np.zeros((self.slots, 1), dtype=np.int32)
        for s, req in enumerate(self.active):
            if req is not None and req.out:
                tok[s, 0] = req.out[-1]
        with _backend_scope(self.kernel_backend):
            if self.paged:
                state = kvc.PagedState(jnp.asarray(self.page_table),
                                       jnp.asarray(self.lengths))
                logits, self.pools = self._decode(self.params, self.pools,
                                                  jnp.asarray(tok), state)
            else:
                idx = int(self.lengths.max())
                logits, self.caches = self._decode(self.params, self.caches,
                                                   jnp.asarray(tok), idx)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(nxt[s]))
            self.lengths[s] += 1
            if len(req.out) >= req.max_new or self.lengths[s] >= self.max_seq - 1:
                self._retire(s, req)
        return True

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        """Step until queue + slots are empty; returns the requests finished
        during this call (in retirement order)."""
        start = len(self.finished)
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        return self.finished[start:]

    # -- accounting ------------------------------------------------------------
    def kv_bytes_per_token(self) -> float:
        """Pool bytes per token slot across the whole layer stack (paged
        engine only) — the number the FP8 pool halves vs bf16."""
        assert self.paged
        return sum(kvc.pool_bytes_per_token(p["kv"]) for p in self.pools)

    def kv_bf16_bytes_per_token(self) -> float:
        assert self.paged
        return sum(kvc.bf16_bytes_per_token(p["kv"]) for p in self.pools)
