"""Batched W4A8 serving loop over a quantized paged KV-cache pool.

Serving model: ``Server`` owns `slots` concurrent sequences (slot = batch
row). Requests join free slots; each engine step decodes one token for every
active slot. Prefill for a new request runs row-wise (batch-1) and is
*streamed into pages*: the prompt is fed through the model in page-aligned
chunks and each chunk's K/V is quantized straight into the pool
(runtime.kv_cache.append_prefill_chunk), so the engine never holds a
monolithic (slots, max_seq, ...) cache — nor even a transient per-request
max_seq scratch. This is the scheduling skeleton of a vLLM-style paged
engine adapted to fixed-shape jit programs (page table and per-slot lengths
are jit *inputs*; shapes never change -> one compiled decode step).

The paged pool is the *single* decode path — every model family runs on it:

  * decoder-only transformers (GQA and MLA attention, dense or MoE) keep
    per-layer K/V (or compressed latent) pages; MLA decode runs entirely
    inside the latent flash-decoding kernel (ops.paged_mla_decode_attn).
  * enc-dec (Whisper-style) decoders add *write-once cross pages*: the
    encoder runs once at admission, every decoder layer's cross K/V is
    quantized into immutable pages (kv_cache.write_cross_pages), and
    admission charges ``pages(prompt) + pages(encoder_seq)`` from the same
    free list.
  * recurrent families (SSM / xLSTM, and the Zamba2 hybrid's Mamba2
    backbone) hold their fixed-size decode state in *state slabs*: one
    slab per running request, allocated at admission, steal/spill-able
    exactly like pages — just never grown. The hybrid's shared-attention
    KV rides an ordinary page pool with the invocation index as the
    layer axis.

Scheduling (``scheduler`` knob):
  * ``"token_budget"`` (default): admission charges only the prompt's pages
    plus ``headroom_pages`` of decode headroom (plus the encoder pages /
    one slab where the family needs them); every step allocates pages on
    demand as rows cross page boundaries. On pool exhaustion the scheduler
    preempts the lowest-priority running request by *stealing its pages*
    (and slab): the victim's payload (codes + scales + recurrent state,
    all layers) is spilled to host memory and its pages returned to the
    pool, so it resumes token-identically — bit-identical contents are
    restored into whatever pages are free — once capacity returns.
    Watermarks and a steal cooldown give anti-thrash hysteresis;
    readmission is one global longest-waiting-first wait line over spilled
    *and* fresh requests, keyed by (step entered the line, arrival seq) —
    the head of the line is never overtaken while it does not fit, and a
    budget-evicted spill keeps the place it already earned. Host spill
    residency is bounded by
    ``spill_budget_bytes``: when exceeded, the oldest spill is *evicted* —
    its request re-queues at the head of the line and re-prefills its full
    context instead of restoring bytes (host memory can no longer OOM on
    pathological steal storms).
  * ``"reserve"``: the legacy reserve-on-admit policy — worst-case pages
    (prompt + max_new) are reserved up front, so admitted requests never
    stall but slot utilization collapses under long-tail ``max_new``. Kept
    as the serving benchmark's baseline.

Streaming-prefill chunks are *bucketed*: chunk lengths and page-table
widths are padded to powers of two (pad tokens masked everywhere — page
writes, attention, logits row), so a high-entropy prompt-length workload
compiles O(log max_seq) prefill programs instead of one per distinct
(chunk_len, table_width) pair. Families with recurrent state stream exact
chunks instead (pad tokens cannot be masked out of a recurrence's carry).

``ServerConfig.cache`` (a :class:`runtime.kv_cache.CachePolicy`) selects
the page payload *per page class*: ``active_fmt`` for every page a write
path can still touch ("fp8_e4m3" packed FP8 codes with per-(page, head) M2
scales ~0.52x the bytes of bf16, or None for bf16 pages), ``frozen_fmt``
for prefix-cache-registered pages (``"fp4_e2m1"`` transcodes each page
FP8 -> packed FP4 exactly once at the freeze point, halving frozen-page
bytes again), and ``cross_fmt`` for write-once enc-dec cross pages. The
flat ``kv_fmt`` string knob still maps onto
``CachePolicy(active_fmt=...)`` through a DeprecationWarning shim. Every
format runs the same paged decode attention with per-slot *true*
lengths — rows carry their own positions and length masks end to end;
in a mixed-precision pool, page-table entries ``>= n_pages + 1`` address
the packed FP4 frozen region and the kernels select the decode format
per page by id class.

Page ownership is **refcounted**, and full scale-frozen prompt pages are
**content-addressable** (``prefix_cache=True``, pure page families only):
once the prefill stream passes a page its per-(page, head) M2 scales are
frozen at amax and the codes never requantize again, so the page content
is a pure function of its token-id prefix. A host-side radix index
(runtime.kv_cache.PrefixCache) registers every full prompt page after its
prefill; admission walks a request's prompt through the index and maps
every hit straight into the slot's page table (refcount++, zero prefill
compute), streaming only the uncached tail through the prefill. The
boundary page is always private — only *full* pages are ever shared — so
the decode append's in-place requantize can never touch a shared page:
copy-on-write falls out structurally. Pages whose refcount drops to zero
park in an LRU reusable set that the allocator reclaims *before* any live
request is stolen from; preemption spills only privately-owned payload and
re-resolves the shared prefix through the index on resume (falling back to
a tail re-prefill when the cached pages were reclaimed meanwhile).
"""
from __future__ import annotations

import bisect
import contextlib
import dataclasses
import functools
import hashlib
import math
import time
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import models
from repro.models.transformer import segments_for
from repro.runtime import kv_cache as kvc
from repro.runtime import sampling as smp
from repro.runtime.faults import (FaultPlan, PoolCorruptionError,
                                  ServingError)
from repro.runtime.kv_cache import CachePolicy
from repro.runtime.sampling import SamplingParams

__all__ = ["Request", "RequestResult", "TokenEvent", "Server",
           "ServerConfig", "SchedulerConfig", "MeshPlan", "CachePolicy",
           "SamplingParams", "FaultPlan", "PoolCorruptionError",
           "ServingError"]


def _decode_step(params, caches, tokens, cache_index, poison, samp,
                 cfg, a_fmt):
    """The engine step, as a plain traceable function. ``_decode_step_jit``
    below is the shared single-device jit of it; a mesh-driving Server
    jits the SAME function with ``out_shardings`` pinning the cache
    outputs to its canonical per-mesh-axis pool layouts (placement can
    never drift step to step, so the fixed-trace property holds on a
    mesh exactly as it does on one device).

    ``cfg`` is a frozen (hashable) ArchConfig, so the compiled program
    cache is shared across Server instances — a restarted or side-by-side
    server reuses every prefill-chunk and decode executable instead of
    recompiling.

    Returns ``(nxt, row_ok, caches)``: ``nxt`` is the per-row next token
    — sampled in-graph from the logits by ``samp``, a 5-tuple of per-row
    arrays (temperature, top_k, top_p, seed, emitted-count; see
    runtime.sampling). Greedy rows (temperature 0) take the argmax, so
    the pre-sampling engine's output is reproduced bit-exactly; all of
    it is fixed-trace — sampling params are jit *inputs*, never retrace
    keys. ``row_ok`` is the per-row isfinite sentinel — True iff every
    logit in the row is finite — and is the engine's detection path for
    FP8's operational sharp edge (a NaN code point or overflow
    saturating through the cache poisons the row's logits). ``poison``
    is a per-row bool *input* (no retrace): fault injection sets it to
    force NaN upstream of the sentinel, so chaos tests exercise the same
    detection path production does."""
    logits, caches = models.decode_step(params, cfg, tokens, caches,
                                        cache_index, a_fmt=a_fmt)
    logits = jnp.where(poison[:, None], jnp.float32(jnp.nan), logits)
    row_ok = jnp.all(jnp.isfinite(logits), axis=-1)
    nxt = smp.sample_tokens(logits, *samp)
    return nxt, row_ok, caches


_decode_step_jit = jax.jit(_decode_step, static_argnames=("cfg", "a_fmt"))


@functools.partial(jax.jit, static_argnames=("cfg", "a_fmt"))
def _encode_cross_jit(params, frames, caches, cross_table, cfg, a_fmt):
    """Enc-dec admission step: encoder forward + write-once cross pages."""
    return models.encode_cross_pages(params, cfg, frames, caches,
                                     cross_table, a_fmt=a_fmt)


@contextlib.contextmanager
def _backend_scope(name: Optional[str]):
    """Temporarily select a kernel backend (None = leave untouched). Keeps a
    Server's backend choice scoped to its own prefill/decode tracing instead
    of leaking into every other model in the process."""
    if name is None:
        yield
        return
    from repro.kernels import ops as _kops

    prev = _kops.get_backend()
    _kops.set_backend(name)
    try:
        yield
    finally:
        _kops.set_backend(prev)


def _migrate_legacy_kwarg(message: str, *, conflict: Optional[str] = None,
                          stacklevel: int = 3):
    """One shim for every legacy->current config-migration spelling
    (``kv_fmt`` -> ``CachePolicy``, flat ``Server(...)`` kwargs ->
    ``ServerConfig``): raise ``TypeError`` with ``conflict`` when the
    caller mixed the old and new spellings, else emit the
    ``DeprecationWarning`` and let the caller normalize the value.
    ``stacklevel`` points the warning at the deprecated call site."""
    if conflict is not None:
        raise TypeError(conflict)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)


def _next_pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length()


def _is_hybrid(cfg) -> bool:
    return (cfg.ssm is not None and cfg.ssm.kind == "mamba2"
            and cfg.family == "hybrid")


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Admission/preemption policy knobs (see the module docstring).

    ``policy``: ``"token_budget"`` (default — prompt pages + headroom at
    admission, on-demand growth, preemption by page steal) or
    ``"reserve"`` (legacy worst-case reserve-on-admit; the serving
    benchmark's baseline). The remaining knobs only act under
    ``token_budget``:
      * ``headroom_pages``: decode headroom charged at admission on top
        of the prompt's pages — the first page boundary never stalls.
      * ``low_watermark``: pages that must stay free *after* admitting
        fresh work while other requests run (growth slack; hysteresis
        against admit-then-steal thrash).
      * ``resume_watermark``: extra free pages, beyond the spilled
        context, required to resume a preempted request while other
        requests run (hysteresis against steal/resume ping-pong).
      * ``steal_cooldown``: steps a freshly admitted/resumed request is
        protected from preemption (unless no other victim exists).
      * ``prefill_chunk_pages``: streaming-prefill chunk size, in pages.
      * ``spill_budget_bytes``: cap on host bytes held by spills; on
        overflow the oldest spill is evicted and its request re-queued
        for a full re-prefill (None = unbounded).
    Both watermarks are bypassed when nothing is running — the pool is
    then fully available, so progress is always made when physically
    possible.

    ``engine`` selects the step architecture (orthogonal to ``policy``):
      * ``"mixed"`` (default) — every ``Server.step()`` carries all
        active decode rows *plus* up to ``prefill_token_budget`` tokens
        of one request's next prefill chunk, fused into a single jitted
        program: decode never stalls while a prompt streams in.
        Families the fusion does not apply to (recurrent/slab, enc-dec,
        multi-device meshes) fall back to alternating automatically —
        ``Server.engine`` reports the resolved choice.
      * ``"alternating"`` — the legacy shape: whole prompts stream at
        admission (serial chunk steps), decode steps carry decode rows
        only. Kept as the bench baseline and the fallback target.
    ``prefill_token_budget`` is the per-step prefill chunk size in
    *tokens* for both engines — the mixed step's piggyback cap and the
    alternating stream's chunk length (None = ``prefill_chunk_pages``
    worth), so the engines stay chunk-for-chunk comparable. It is
    rounded down to a page multiple (min one page) so chunk starts stay
    page-aligned — the ``append_prefill_chunk`` contract."""

    policy: str = "token_budget"
    headroom_pages: int = 1
    low_watermark: int = 0
    resume_watermark: int = 1
    steal_cooldown: int = 2
    prefill_chunk_pages: int = 4
    spill_budget_bytes: Optional[int] = None
    engine: str = "mixed"
    prefill_token_budget: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Device-mesh layout for one serving engine: a ('data', 'model')
    ``jax.sharding.Mesh`` over ``data * model`` local devices, plus the
    per-mesh-axis layout every pool leaf and weight shard follows:

      * GQA KV pages, their per-(page, head) scales and the decode
        attention shard by KV head along 'model' (head counts are
        asserted divisible at Server construction);
      * MLA latent pages replicate (no head axis) while the absorbed
        query heads shard along 'model';
      * MoE decode routes expert-parallel (expert-stacked W4A8 weights
        sharded over the mesh, partial outputs all-reduced);
      * W4A8 weight shards are placed by ``launch.sharding.serve_rules``.

    The host-side scheduler stays a single brain above all of it: page
    tables, refcounts, the prefix radix index, spill CRCs and ``audit()``
    are host-global, and spill/restore gathers/scatters per shard
    implicitly (``np.asarray`` of a sharded leaf is the global array).

    ``total == 1`` (the default, and ``ServerConfig.mesh=None``) keeps
    the single-device engine byte-for-byte: no Mesh is ever built and
    every code path is exactly the pre-mesh one (asserted by tests)."""

    data: int = 1
    model: int = 1

    def __post_init__(self):
        if self.data < 1 or self.model < 1:
            raise ValueError(
                f"MeshPlan axes must be >= 1, got data={self.data} "
                f"model={self.model}")

    @property
    def total(self) -> int:
        return self.data * self.model

    def build(self):
        """Build the ('data', 'model') Mesh over the first ``total``
        local devices (CPU CI simulates them via
        ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
        from repro.launch.mesh import make_mesh

        ndev = len(jax.devices())
        if self.total > ndev:
            raise ValueError(
                f"MeshPlan(data={self.data}, model={self.model}) needs "
                f"{self.total} devices but only {ndev} are visible (set "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N "
                "before importing jax to simulate a CPU mesh)")
        return make_mesh((self.data, self.model), ("data", "model"))


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Frozen Server construction spec (replaces the old 19-kwarg flat
    ``Server.__init__``; those kwargs still map here through a
    ``DeprecationWarning`` shim).

    ``kernel_backend``: 'pallas' routes every PackedLinear matmul in
    prefill/decode through the fused single-pass W4A8 kernel, and paged
    decode attention (GQA and MLA-latent) through the flash-decoding
    page-gather kernels; 'ref' forces the jnp oracles; None keeps the
    process-wide setting.

    ``cache``: a :class:`runtime.kv_cache.CachePolicy` — the KV-cache
    precision policy, per page class: ``active_fmt`` for writable pages
    ('fp8_e4m3' packed codes + per-(page, head) M2 scales, or None for
    bf16), ``frozen_fmt`` for prefix-cache-registered pages
    ('fp4_e2m1' transcodes each page to packed FP4 exactly once at
    freeze time), ``cross_fmt`` for write-once enc-dec cross pages and
    ``frozen_pages`` sizing the dedicated frozen region. Recurrent
    state slabs always hold exact f32 state regardless.

    ``kv_fmt``: DEPRECATED — the old flat payload string. Still
    accepted (with a ``DeprecationWarning``) and normalized onto
    ``cache=CachePolicy(active_fmt=kv_fmt)``; mixing it with an
    explicit non-default ``cache`` raises ``TypeError``.

    ``page_size``: tokens per page. ``pool_pages``: pool capacity in
    pages (default: full backing — slots * pages per slot, plus the
    encoder pages for enc-dec). ``pool_slabs``: state slabs for
    recurrent families (default: one per slot — full backing).

    ``scheduler``: a nested :class:`SchedulerConfig`.

    ``mesh``: a nested :class:`MeshPlan` — None (or a 1-device plan)
    keeps today's single-device engine byte-for-byte; a larger plan
    makes this Server drive a ('data', 'model') device mesh with KV
    pages/decode attention sharded by head, MLA latents replicated,
    MoE decode expert-parallel and weights placed by ``serve_rules``
    (pure page families only: GQA/MLA decoders, no enc-dec cross pages
    or recurrent state slabs).

    ``prefix_cache``: content-addressed sharing of full, scale-frozen
    prompt pages across requests (refcounted pages + host-side radix
    index; see the module docstring). Active only for pure page
    families: enc-dec decoder K/V depends on the encoder frames, not
    just the token prefix, and recurrent families cannot skip a prefill
    chunk — both fall back to exclusive prefills automatically.

    Failure semantics (see runtime/README.md):
      * ``strict=True`` (default): ``run_until_drained`` raises
        ``ServingError`` on starvation — fail-fast for tests/bench.
        ``strict=False`` degrades per request instead: permanently
        unadmittable work retires with ``status='failed'`` and the
        drain completes (production mode: one oversized or starved
        request never takes the batch down).
      * ``audit_every=N``: every N decode steps, run the full pool
        ownership audit (``Server.audit()``) in-line and raise
        ``PoolCorruptionError`` on any violation (0 = off)."""

    slots: int = 4
    max_seq: int = 512
    a_fmt: Optional[str] = "fp8_e4m3"
    kernel_backend: Optional[str] = None
    cache: CachePolicy = CachePolicy()
    kv_fmt: Optional[str] = None  # deprecated -> cache=CachePolicy(active_fmt=)
    page_size: int = 64
    pool_pages: Optional[int] = None
    pool_slabs: Optional[int] = None
    scheduler: SchedulerConfig = SchedulerConfig()
    mesh: Optional[MeshPlan] = None
    prefix_cache: bool = True
    strict: bool = True
    audit_every: int = 0

    def __post_init__(self):
        if self.kv_fmt is None:
            return
        _migrate_legacy_kwarg(
            "ServerConfig(kv_fmt=...) is deprecated; pass "
            "ServerConfig(cache=CachePolicy(active_fmt=...))",
            conflict=("pass either cache=CachePolicy(...) or the "
                      "deprecated kv_fmt=..., not both")
            if self.cache != CachePolicy() else None,
            stacklevel=4)
        # normalize so ServerConfig(kv_fmt=f) == ServerConfig(
        # cache=CachePolicy(active_fmt=f)) — the shimmed spelling is
        # indistinguishable downstream (token-identical serving)
        object.__setattr__(self, "cache",
                           CachePolicy(active_fmt=self.kv_fmt))
        object.__setattr__(self, "kv_fmt", None)


# legacy flat-kwarg -> config-field mapping for the deprecation shim
_LEGACY_SCHED_KW = ("headroom_pages", "low_watermark", "resume_watermark",
                    "steal_cooldown", "prefill_chunk_pages",
                    "spill_budget_bytes")
_LEGACY_TOP_KW = ("slots", "max_seq", "a_fmt", "kernel_backend", "kv_fmt",
                  "page_size", "pool_pages", "pool_slabs", "prefix_cache",
                  "strict", "audit_every")


def _config_from_legacy(kwargs: Dict) -> ServerConfig:
    """Map the pre-redesign flat ``Server.__init__`` kwargs onto a
    ``ServerConfig`` (+ nested ``SchedulerConfig``). Unknown names raise
    TypeError exactly like a normal bad keyword would."""
    sched = {k: kwargs.pop(k) for k in _LEGACY_SCHED_KW if k in kwargs}
    if "scheduler" in kwargs:
        sched["policy"] = kwargs.pop("scheduler")
    top = {k: kwargs.pop(k) for k in _LEGACY_TOP_KW if k in kwargs}
    if kwargs:
        raise TypeError(
            f"Server() got unexpected keyword argument(s) {sorted(kwargs)}")
    if "kv_fmt" in top:
        # map straight onto the policy here so the flat-kwarg call warns
        # exactly once (the shim in ServerConfig.__post_init__ would warn
        # a second time for the same call site)
        top["cache"] = CachePolicy(active_fmt=top.pop("kv_fmt"))
    return ServerConfig(scheduler=SchedulerConfig(**sched), **top)


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One engine emission: a decoded token for a request, or (with
    ``finished=True`` and ``token=None``) the request's terminal event.
    ``index`` is the emitted-token index (0 = the prefill's seed token),
    ``t`` the host perf_counter timestamp at decode — the raw material
    for TTFT / inter-token-latency measurement. Buffered by the Server
    only while a front-end has switched ``collect_events`` on."""

    rid: int
    token: Optional[int]
    index: int
    t: float
    finished: bool = False
    status: Optional[str] = None  # terminal status on the finished event


@dataclasses.dataclass(frozen=True)
class RequestResult:
    """Immutable outcome of one served request — what
    ``run_until_drained`` returns and the async front-end resolves to,
    split from the mutable in-flight ``Request``. ``status`` is the one
    source of truth for how the request ended: ``"ok"`` (hit max_new),
    ``"truncated"`` (retired at the max_seq bound with fewer tokens) or
    ``"failed"`` (quarantined; ``error`` has the diagnostic).
    ``token_times`` holds the per-token host timestamps the engine
    recorded at decode — ``ttft``/``itl`` derive latency from them."""

    rid: int
    tokens: Tuple[int, ...]
    status: str
    error: Optional[str]
    prompt_len: int
    preemptions: int  # times this request's pages were stolen
    evictions: int  # times its host spill was dropped (re-prefilled)
    submitted_at: float
    token_times: Tuple[float, ...]

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    @property
    def truncated(self) -> bool:
        return self.status == "truncated"

    @property
    def ttft(self) -> Optional[float]:
        """Seconds from submit to the first token (None if none came)."""
        if not self.token_times:
            return None
        return self.token_times[0] - self.submitted_at

    @property
    def itl(self) -> Tuple[float, ...]:
        """Inter-token gaps in seconds (empty with < 2 tokens)."""
        ts = self.token_times
        return tuple(b - a for a, b in zip(ts, ts[1:]))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int = 16
    sampling: SamplingParams = SamplingParams()  # frozen -> safe default
    priority: int = 0  # higher = steal from it last; ties -> slack, then age
    deadline_step: Optional[int] = None  # SLO: engine step to finish by;
    # victim selection steals the most slack first within a priority class
    # (slack = deadline - step - tokens remaining; None = infinite slack)
    frames: Optional[np.ndarray] = None  # enc-dec: (encoder_seq, d) embeddings
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    status: str = "ok"  # terminal status: "ok" | "truncated" | "failed"
    error: Optional[str] = None  # diagnostic when status == "failed"
    preemptions: int = 0  # times this request's pages were stolen
    evictions: int = 0  # times its host spill was dropped (re-prefilled)
    resume_ctx: Optional[list] = None  # evicted: full context to re-prefill
    since: int = 0  # server-managed: step this request entered the wait line
    seq: int = 0  # server-managed: global arrival sequence (tie-break)
    t_submit: float = 0.0  # server-managed: perf_counter at submit()
    token_times: list = dataclasses.field(default_factory=list)
    _frames_digest: Optional[str] = dataclasses.field(
        default=None, repr=False, compare=False)

    def frames_digest(self) -> str:
        """Content digest of the encoder frames (enc-dec requests only),
        computed once per request. The prefix cache chains this request's
        radix root on it: decoder K/V depends on the frames through
        cross-attention, so identical token prefixes under different
        frames must never share pages — and under *identical* frames they
        safely can (sha256 over the exact frame bytes: no float tolerance,
        bit-equality or nothing)."""
        if self._frames_digest is None:
            assert self.frames is not None
            self._frames_digest = hashlib.sha256(
                np.ascontiguousarray(self.frames).tobytes()).hexdigest()
        return self._frames_digest

    @property
    def truncated(self) -> bool:
        """Retired at the max_seq bound with < max_new tokens out. Folded
        into ``status`` — one source of truth for how the request ended."""
        return self.status == "truncated"

    def result(self) -> RequestResult:
        """Snapshot this (retired) request as an immutable result."""
        return RequestResult(
            rid=self.rid, tokens=tuple(self.out), status=self.status,
            error=self.error, prompt_len=len(self.prompt),
            preemptions=self.preemptions, evictions=self.evictions,
            submitted_at=self.t_submit, token_times=tuple(self.token_times))


@dataclasses.dataclass
class _Spill:
    """A preempted request's resumable state: the exact *privately-owned*
    page / slab payload (codes + scales + recurrent state per pool leaf,
    all layers) at preemption time. Shared-frozen prefix pages are not
    spilled — they stay resident in the content index (parked at refcount
    0 if nobody else maps them) and are re-resolved by token id on resume.
    Restoring the private bytes into any free pages/slab behind the
    re-acquired prefix reproduces the pool state bit-exactly, so the
    resumed request generates token-identical output. The wait-line key
    (``req.since``/``req.seq``) lives on the request and survives both
    resume and budget eviction — one global longest-waiting-first line."""

    req: Request
    ctx_len: int  # tokens of KV spilled (prompt + generated-so-far)
    shared_pages: int  # leading content-shared pages (not in the payload)
    payload: List[Dict[str, np.ndarray]]  # per engine unit: leaf -> array
    nbytes: int  # host bytes this spill holds (spill_budget accounting)
    crc: int = 0  # CRC32 of the pristine payload (kvc.payload_checksum),
    # re-verified before a resume commits: bit rot while spilled falls
    # back to a tail re-prefill instead of restoring garbage into the pool
    rng_seed: int = 0  # sampling RNG root at preemption — with ``emitted``
    emitted: int = 0  # (tokens sampled so far) this is the complete RNG
    # state of the stream: token i's key is fold_in(PRNGKey(seed), i), so
    # a resume continues the stream token-identically from index
    # ``emitted``. Both ride on the Request too (sampling.seed / len(out));
    # the spill carries them explicitly so _resume can assert the
    # restored stream position matches the bytes being restored


class Server:
    def __init__(self, params, cfg, config: Optional[ServerConfig] = None,
                 *, faults: Optional[FaultPlan] = None, **legacy):
        """``config``: a frozen :class:`ServerConfig` (every construction
        knob lives there; scheduler policy knobs nest in its
        ``scheduler: SchedulerConfig``). ``faults`` is runtime state, not
        configuration — a ``runtime.faults.FaultPlan`` consulted at the
        engine's injection hook points; None (default) keeps every hook a
        no-op, and injection never changes the jitted programs (the NaN
        poison is a jit *input*).

        The pre-redesign flat kwargs (``slots=``, ``max_seq=``,
        ``scheduler="token_budget"``, ``headroom_pages=``, ...) still
        work through a ``DeprecationWarning`` shim that maps them onto a
        ``ServerConfig`` — but cannot be mixed with an explicit
        ``config``."""
        if legacy:
            _migrate_legacy_kwarg(
                "flat Server(...) kwargs are deprecated; pass "
                "Server(params, cfg, ServerConfig(...)) — scheduler knobs "
                "nest under ServerConfig(scheduler=SchedulerConfig(...))",
                conflict=("pass either a ServerConfig or legacy flat "
                          f"kwargs, not both (got config= and "
                          f"{sorted(legacy)})")
                if config is not None else None,
                stacklevel=3)
            config = _config_from_legacy(legacy)
        if config is None:
            config = ServerConfig()
        sched = config.scheduler
        if sched.policy not in ("token_budget", "reserve"):
            raise ValueError(f"unknown scheduler policy {sched.policy!r}")
        if sched.engine not in ("mixed", "alternating"):
            raise ValueError(f"unknown scheduler engine {sched.engine!r}")
        self.config = config
        slots, max_seq = config.slots, config.max_seq
        policy, page_size = config.cache, config.page_size
        pool_pages, pool_slabs = config.pool_pages, config.pool_slabs
        a_fmt, prefix_cache = config.a_fmt, config.prefix_cache
        self.kernel_backend = config.kernel_backend
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.a_fmt = a_fmt
        self.policy = policy
        self.kv_fmt = policy.active_fmt  # legacy read-side alias
        self.scheduler = sched.policy
        self.headroom_pages = sched.headroom_pages
        self.low_watermark = sched.low_watermark
        self.resume_watermark = sched.resume_watermark
        self.steal_cooldown = sched.steal_cooldown
        self.prefill_chunk_pages = sched.prefill_chunk_pages
        self.spill_budget_bytes = sched.spill_budget_bytes
        # mixed-step prefill piggyback budget, in tokens: rounded down to a
        # page multiple (min one page) so chunk starts stay page-aligned —
        # the append_prefill_chunk contract every pool invariant rides on
        budget = sched.prefill_token_budget
        if budget is None:
            budget = sched.prefill_chunk_pages * config.page_size
        self.prefill_token_budget = max(
            config.page_size, (budget // config.page_size) * config.page_size)
        self.strict = config.strict
        self.audit_every = config.audit_every
        self.faults = faults
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.preempted: List[_Spill] = []
        self.finished: List[Request] = []
        self.stats = {
            "steps": 0, "slot_steps": 0, "decoded_tokens": 0,
            "programs": 0,  # every jitted launch: encode/prefill/decode/mixed
            "prefill_tokens": 0, "preemptions": 0, "resumes": 0,
            "pages_stolen": 0, "spill_evictions": 0, "truncated": 0,
            "prefix_hit_pages": 0, "prefix_hit_tokens": 0,
            "prefix_reclaims": 0, "resume_fallbacks": 0,
            "failed": 0, "spill_integrity_failures": 0,
            "fp4_frozen_pages": 0,  # cumulative freeze-time transcodes
        }
        self._step_no = 0
        # engine tick: advances every step() *call*, decoded or not — the
        # clock fault hooks key on (a blocked alloc tick always passes,
        # so injected exhaustion is transient by construction)
        self._tick = 0
        self._alloc_faulted = False
        self._submit_seq = 0
        self._spill_bytes = 0
        # distinct (padded_chunk_len, table_width) prefill signatures fed to
        # the jitted step — with a fixed cfg this IS the prefill trace
        # count, which bucketing bounds to O(log max_seq)
        self.prefill_traces: set = set()

        self._encdec = cfg.encoder_layers > 0
        self._hybrid = _is_hybrid(cfg)
        self.page_size = page_size
        self.pages_per_slot = math.ceil(max_seq / page_size)
        self._cross_pp = (kvc.pages_needed(cfg.encoder_seq, page_size)
                          if self._encdec else 0)

        # ---- device mesh (MeshPlan) --------------------------------------
        # total == 1 (or mesh=None) never builds a Mesh: the engine runs
        # today's exact single-device code path, bit-for-bit
        self._mesh = None
        self._heads_sharding = None
        self._pool_shardings = None
        plan = config.mesh
        if plan is not None and plan.total > 1:
            if self._encdec or self._hybrid or cfg.ssm is not None or any(
                    seg.mixer not in ("gqa", "mla")
                    for seg in segments_for(cfg)):
                raise ValueError(
                    "MeshPlan(total>1) serves pure page families only "
                    "(GQA/MLA decoders); enc-dec cross pages and recurrent "
                    "state slabs are single-device")
            if cfg.n_heads % plan.model:
                raise ValueError(
                    f"n_heads={cfg.n_heads} must be divisible by "
                    f"MeshPlan.model={plan.model} (decode attention "
                    "shards by head)")
            if any(seg.mixer == "gqa" for seg in segments_for(cfg)) \
                    and cfg.n_kv_heads % plan.model:
                raise ValueError(
                    f"n_kv_heads={cfg.n_kv_heads} must be divisible by "
                    f"MeshPlan.model={plan.model} (KV pages and their "
                    "scales co-shard by KV head)")
            self._mesh = plan.build()
            self._heads_sharding = NamedSharding(
                self._mesh, P(None, None, "model", None))
        if self._mesh is None:
            self._decode = functools.partial(_decode_step_jit, cfg=cfg,
                                             a_fmt=a_fmt)
        # mesh > 1: the per-server jit of the SAME trace function is
        # installed by _shard_state() once the pools exist (it pins the
        # cache outputs to the canonical pool shardings)

        # ---- pools: one unit per (path into the cache tree, kind) --------
        # every unit's leaves are (lead, pool_size + 1, ...): lead = stacked
        # layers (or hybrid shared-block invocations), index 1 = page/slab id
        # with the last id reserved (null page / null slab)
        self._units: List[Tuple[tuple, str]] = []
        kv_n, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        self._n_slabs = 0
        if cfg.ssm is not None:
            self._n_slabs = pool_slabs or slots
        n_pages = pool_pages or slots * (self.pages_per_slot
                                         + (self._cross_pp if self._encdec
                                            else 0))
        # mixed-precision frozen pages exist only where the prefix cache
        # does: the FP4 region is written exclusively by the freeze-time
        # transcode, so a family that can never freeze a page (recurrent/
        # hybrid state, prefix_cache=False) has no use for it. Enc-dec
        # decoders DO freeze pages: their radix chains hang off a
        # per-frames-digest root (Request.frames_digest), so sharing is
        # conditioned on the encoder input, not just the token prefix
        supports_prefix = (prefix_cache
                           and not self._hybrid and cfg.ssm is None
                           and all(seg.mixer in ("gqa", "mla")
                                   for seg in segments_for(cfg)))
        if policy.mixed and not supports_prefix:
            raise ValueError(
                "CachePolicy(frozen_fmt=...) needs an active prefix cache: "
                "frozen FP4 pages hold only content-shared prefix pages, "
                "which exist for pure page families with prefix_cache=True")
        self._mixed = policy.mixed
        self._n_frozen = ((policy.frozen_pages or n_pages)
                          if self._mixed else 0)
        frozen_fmt = policy.frozen if self._mixed else None
        if self._hybrid:
            from repro.models.hybrid import n_attn_invocations
            from repro.models.ssm import init_mamba2_cache

            one = init_mamba2_cache(cfg, self._n_slabs + 1)
            mamba = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)
            self.pools = {"mamba": mamba}
            self._units.append((("mamba",), "slab"))
            n_inv = n_attn_invocations(cfg)
            if n_inv:
                self.pools["shared_kv"] = kvc.init_gqa_pool(
                    n_inv, n_pages, page_size, kv_n, hd, policy.active)
                self._units.append((("shared_kv",), "kv"))
        else:
            self.pools = []
            for i, seg in enumerate(segments_for(cfg)):
                seg_pools = {}
                if seg.mixer == "gqa":
                    seg_pools["kv"] = kvc.init_gqa_pool(
                        seg.count, n_pages, page_size, kv_n, hd,
                        policy.active, frozen_fmt=frozen_fmt,
                        n_frozen=self._n_frozen)
                    self._units.append(((i, "kv"), "kv"))
                    if seg.cross:
                        seg_pools["cross"] = kvc.init_cross_pool(
                            seg.count, n_pages, page_size, kv_n, hd,
                            policy.cross)
                        self._units.append(((i, "cross"), "cross"))
                elif seg.mixer == "mla":
                    seg_pools["kv"] = kvc.init_mla_pool(
                        seg.count, n_pages, page_size, cfg.mla.kv_lora_rank,
                        cfg.mla.qk_rope_dim, policy.active,
                        frozen_fmt=frozen_fmt, n_frozen=self._n_frozen)
                    self._units.append(((i, "kv"), "kv"))
                elif seg.mixer == "xlstm_pair":
                    from repro.models.xlstm import (init_mlstm_cache,
                                                    init_slstm_cache)

                    for name, init in (("mlstm", init_mlstm_cache),
                                       ("slstm", init_slstm_cache)):
                        one = init(cfg, self._n_slabs + 1)
                        seg_pools[name] = jax.tree.map(
                            lambda a: jnp.broadcast_to(
                                a, (seg.count,) + a.shape), one)
                        self._units.append(((i, name), "slab"))
                elif seg.mixer == "mamba2":
                    from repro.models.ssm import init_mamba2_cache

                    one = init_mamba2_cache(cfg, self._n_slabs + 1)
                    seg_pools["ssm"] = jax.tree.map(
                        lambda a: jnp.broadcast_to(
                            a, (seg.count,) + a.shape), one)
                    self._units.append(((i, "ssm"), "slab"))
                else:  # pragma: no cover
                    raise ValueError(f"unknown mixer {seg.mixer!r}")
                self.pools.append(seg_pools)

        self._has_pages = any(kind in ("kv", "cross")
                              for _, kind in self._units)
        self._has_slabs = any(kind == "slab" for _, kind in self._units)
        # pristine one-slab state per slab unit: recycled slabs are reset to
        # this at allocation (pages are fully overwritten by the prefill
        # stream, but a recurrent prefill *continues* from whatever state
        # its slab holds — a previous owner's leftovers must not leak in)
        self._slab_init = {
            ui: {name: np.asarray(leaf[:, :1])
                 for name, leaf in self._unit(path).items()}
            for ui, (path, kind) in enumerate(self._units) if kind == "slab"
        }
        # (recurrent-only families hold exact f32 state slabs: there is no
        # page payload for the cache policy to select, and it goes unused)
        self._n_pages = n_pages if self._has_pages else 0
        # recurrent state cannot mask pad tokens out of its carry, so
        # slab-holding families stream exact chunk lengths instead
        self._bucket_prefill = not self._has_slabs
        # resolved step architecture: the mixed (fused prefill+decode) step
        # applies to pure single-device page families only — recurrent
        # state cannot ride a padded fused row, enc-dec admission runs the
        # encoder eagerly, and the sharded engine keeps the alternating
        # shape its token-identity suite pins down. Everything else falls
        # back to alternating steps (Server.engine reports the choice).
        self._mixed_step = (sched.engine == "mixed" and self._has_pages
                            and not self._has_slabs and not self._encdec
                            and self._mesh is None)
        if self._mesh is not None:
            self._shard_state(cfg, a_fmt)

        self.free_pages: List[int] = list(range(self._n_pages))
        # frozen-region allocator (mixed-precision pools only): frozen
        # pages live in a unified *logical* id space behind the active
        # pool — id = _frozen_base + row index into the *_fz stores — so
        # page tables, refcounts and the prefix index need no second
        # namespace. The region's only writer is the freeze-time transcode.
        self.free_frozen: List[int] = [self._frozen_base + i
                                       for i in range(self._n_frozen)]
        # refcounted ownership: page_refs[pid] = number of slots mapping the
        # page right now. Private pages have refcount 1; content-shared
        # prefix pages can be mapped by many slots at once; refcount-0
        # registered pages park in the prefix cache's reusable LRU. Indexed
        # by logical id, so it spans the active pool, the (never-mapped)
        # null page, and the frozen region.
        self.page_refs = np.zeros(self._n_pages + 1 + self._n_frozen,
                                  np.int32)
        self.slot_pages: List[List[int]] = [[] for _ in range(slots)]
        # leading run of content-shared (frozen, registered) pages per slot;
        # everything past it in slot_pages is private (refcount 1, writable
        # at the boundary, spillable)
        self.slot_shared: List[int] = [0] * slots
        self._prefix: Optional[kvc.PrefixCache] = (
            kvc.PrefixCache(page_size)
            if (prefix_cache and self._has_pages and not self._has_slabs)
            else None)
        self.page_table = np.full(
            (slots, max(1, self.pages_per_slot if self._has_pages else 1)),
            self._null_page, np.int32)
        self.free_slabs: List[int] = list(range(self._n_slabs))
        self.slot_slab: List[int] = [-1] * slots
        self.slab_table = np.full((slots,), self._n_slabs, np.int32)
        self.slot_cross: List[List[int]] = [[] for _ in range(slots)]
        self.cross_table = np.full((slots, max(1, self._cross_pp)),
                                   self._null_page, np.int32)
        self.enc_lengths = np.zeros((slots,), np.int32)
        self.lengths = np.zeros(slots, dtype=np.int32)
        self._slot_seq = [0] * slots  # admission sequence of the occupant
        self._slot_since = [0] * slots  # step admitted/resumed (cooldown)
        # clean poison masks for the jitted step (fault injection swaps in
        # a real mask; reused so the no-fault path allocates nothing)
        self._no_poison = jnp.zeros((slots,), jnp.bool_)
        self._no_poison1 = jnp.zeros((1,), jnp.bool_)
        self._no_poison_m = jnp.zeros((slots + 1,), jnp.bool_)
        # per-slot sampling params threaded into the jitted step as five
        # flat arrays (greedy defaults on idle rows); refreshed from the
        # active requests every step — fixed-trace, never a retrace key
        self._samp = smp.slot_arrays(slots)
        # the mixed step's sampling rows: one per slot plus the prefill row
        self._samp_m = smp.slot_arrays(slots + 1)
        # engine emissions for the streaming front-end: decoded-token and
        # terminal events, buffered only while ``collect_events`` is on
        # (a sync run_until_drained caller would otherwise grow the
        # buffer unboundedly with nobody draining it)
        self.collect_events = False
        self._events: List[TokenEvent] = []

    @property
    def engine(self) -> str:
        """The *resolved* step architecture: ``"mixed"`` when the fused
        prefill+decode step is in effect, ``"alternating"`` when the
        engine fell back (recurrent/slab and enc-dec families, meshes) or
        was configured that way. May differ from
        ``config.scheduler.engine`` — that is the request, this is what
        actually runs."""
        return "mixed" if self._mixed_step else "alternating"

    @property
    def _null_page(self) -> int:
        """The reserved null page id (index P of every page pool)."""
        return getattr(self, "_n_pages", 0)

    @property
    def _frozen_base(self) -> int:
        """First frozen-region logical id: table entries >= this address
        the packed FP4 frozen stores (row ``pid - base``). Equals the
        active store's row count (P+1), matching the kernels' id-class
        select."""
        return self._null_page + 1

    def _unit(self, path):
        node = self.pools
        for p in path:
            node = node[p]
        return node

    def _set_unit(self, path, value):
        node = self.pools
        for p in path[:-1]:
            node = node[p]
        node[path[-1]] = value

    # -- mesh placement --------------------------------------------------------
    def _shard_state(self, cfg, a_fmt):
        """Place the engine's device state on the mesh and install the
        per-server decode jit. Params follow ``serve_rules`` (heads/ffn/
        vocab TP over 'model', experts EP over the whole mesh); every pool
        leaf follows its per-mesh-axis layout from ``serve_pool_pspecs``
        (GQA codes + shifts sharded by KV head, smax and MLA latents
        replicated). The recorded sharding tree doubles as the decode
        jit's cache ``out_shardings`` — placement is pinned, so the step
        compiles exactly once per input signature (fixed trace) — and as
        the re-pin target after host-driven pool writes."""
        from repro.launch import sharding as shardlib

        mesh = self._mesh
        self.params = jax.device_put(
            self.params,
            shardlib.serve_param_shardings(cfg, self.params, mesh))
        self._pool_shardings = [
            {key: {name: NamedSharding(
                mesh, shardlib.serve_pool_pspecs(pool, mesh)[name])
                for name in pool}
             for key, pool in seg_pools.items()}
            for seg_pools in self.pools]
        self.pools = jax.tree.map(
            lambda leaf, s: jax.device_put(leaf, s),
            self.pools, self._pool_shardings)
        repl = NamedSharding(mesh, P())
        self._decode = functools.partial(
            jax.jit(_decode_step, static_argnames=("cfg", "a_fmt"),
                    out_shardings=(repl, repl, self._pool_shardings)),
            cfg=cfg, a_fmt=a_fmt)

    def _pin_pools(self):
        """Re-place every pool leaf on its canonical mesh sharding after a
        host-driven scatter (spill restore, quarantine scrub, freeze-time
        transcode): eager ``.at[].set`` updates follow their operands, so
        this keeps the layout byte-identical to what the decode jit's
        ``out_shardings`` pin — a no-op when already placed, and a no-op
        entirely off-mesh."""
        if self._mesh is None:
            return
        self.pools = jax.tree.map(
            lambda leaf, s: jax.device_put(leaf, s),
            self.pools, self._pool_shardings)

    def shard_residency(self) -> Dict[str, int]:
        """Resident pool bytes per device — the per-shard page residency
        the sharded serving bench and ``examples/serve_w4a8.py --mesh``
        report. Off-mesh this is the single default device's total."""
        per: Dict[str, int] = {}
        for path, _ in self._units:
            for leaf in self._unit(path).values():
                shards = getattr(leaf, "addressable_shards", None)
                if shards is None:  # non-jax leaf (tests with np stubs)
                    continue
                for sh in shards:
                    key = str(sh.device)
                    per[key] = per.get(key, 0) + int(sh.data.nbytes)
        return dict(sorted(per.items()))

    @contextlib.contextmanager
    def _trace_scope(self):
        """Every engine trace (encoder run, prefill chunk, decode step)
        enters through here: the Server's kernel backend, plus — on a
        mesh — the trace-time sharding globals: the decode-attention
        shard_map mesh (kernels.ops), the expert-parallel MoE decode impl
        (models.moe_a2a) and the head-sharding hint (models.layers). All
        are restored on exit, so side-by-side servers (or a train step in
        the same process) never see another engine's placement."""
        with _backend_scope(self.kernel_backend):
            if self._mesh is None:
                yield
                return
            from repro.kernels import ops as _kops
            from repro.models import layers as _layers
            from repro.models import moe_a2a as _moe

            prev_mesh = _kops.get_decode_mesh()
            prev_impl = _moe.get_moe_impl()
            prev_res = _layers._RESIDUAL_SHARDING[0]
            prev_heads = _layers._HEADS_SHARDING[0]
            _kops.set_decode_mesh(self._mesh)
            if self.cfg.moe is not None:
                _moe.set_moe_impl("ep_decode", self._mesh)
            _layers.set_residual_sharding(prev_res, self._heads_sharding)
            try:
                yield
            finally:
                _kops.set_decode_mesh(prev_mesh)
                _moe.set_moe_impl(*prev_impl)
                _layers.set_residual_sharding(prev_res, prev_heads)

    # -- page accounting -------------------------------------------------------
    def _worst_case_pages(self, req: Request) -> int:
        """Pages this request can ever hold (prompt + max_new capped at
        max_seq, plus the write-once encoder pages for enc-dec)."""
        if not self._has_pages:
            return 0
        return kvc.pages_needed(
            min(len(req.prompt) + req.max_new, self.max_seq),
            self.page_size) + self._cross_pp

    def _free_capacity(self) -> int:
        """Active-class pages allocatable right now: the free list plus the
        prefix cache's refcount-0 reusable LRU — reclaimed (blanked) before
        any live request is ever stolen from. In a mixed-precision pool
        every registered (and so every parked) page is frozen-class, which
        a private allocation can never use: only the free list counts."""
        if self._alloc_faulted:
            # injected transient exhaustion: the allocator reports dry for
            # this tick, so admission defers and growth falls back to the
            # normal steal response — exactly what a real stall triggers
            return 0
        n = len(self.free_pages)
        if self._prefix is not None and not self._mixed:
            n += self._prefix.n_reusable
        return n

    def _take_page(self) -> int:
        """One blank active-class page for a new private allocation: the
        free list first, then reclaim the LRU refcount-0 cached page
        (dropping its content from the prefix index). A mixed pool never
        reclaims here — its parked pages are frozen-class and would hand
        the allocator an id no write path may target."""
        if self.free_pages:
            return self.free_pages.pop(0)
        assert not self._mixed, "allocator called with zero free capacity"
        pid = self._prefix.reclaim()
        assert pid is not None, "allocator called with zero free capacity"
        self.stats["prefix_reclaims"] += 1
        return pid

    def _take_frozen(self) -> Optional[int]:
        """One blank frozen-region logical id for a freeze-time transcode:
        the frozen free list first, then reclaim the LRU parked page (in a
        mixed pool every registered page is frozen-class, so reclaim always
        yields a frozen id here). None when the region is fully live —
        the caller stops registering and leaves the tail private FP8."""
        if self.free_frozen:
            return self.free_frozen.pop(0)
        pid = self._prefix.reclaim()
        if pid is not None:
            self.stats["prefix_reclaims"] += 1
        return pid

    def _release_page(self, pid: int):
        """Drop one mapping of ``pid``. At refcount 0 a registered page
        parks in the prefix cache's reusable LRU (still bit-reusable by a
        future identical prefix); an unregistered page returns to its
        class's free list (frozen logical ids >= _frozen_base go back to
        the frozen region's list)."""
        self.page_refs[pid] -= 1
        assert self.page_refs[pid] >= 0, f"double-free of page {pid}"
        if self.page_refs[pid] > 0:
            return
        if self._prefix is not None and self._prefix.registered(pid):
            self._prefix.park(pid)
        elif pid >= self._frozen_base:
            self.free_frozen.append(pid)
        else:
            self.free_pages.append(pid)

    def _parked_among(self, pids: List[int]) -> int:
        """How many of these prefix hits sit in the reusable LRU (refcount
        0) *and* count as active-class allocatable capacity. They stop
        being allocatable the moment the admission maps them — feasibility
        must charge them against the free pool. Frozen-class hits never
        counted in ``_free_capacity`` to begin with, so they charge 0."""
        return sum(1 for pid in pids
                   if pid < self._n_pages and self.page_refs[pid] == 0)

    def _prefix_root(self, req: Request) -> int:
        """Radix-chain root for this request's prefix walks/inserts.
        Pure-token families share the global root; enc-dec requests chain
        off a per-frames-digest root node (decoder K/V depends on the
        encoder frames through cross-attention, so a token prefix is only
        shareable *under the same frames* — different frames get disjoint
        chains by construction, collision-safe with zero probability
        argument: the root node id differs)."""
        if not self._encdec:
            return kvc._PREFIX_ROOT
        return self._prefix.root_for(req.frames_digest())

    def _map_shared(self, slot: int, pids: List[int]):
        """Map content-shared prefix pages into an empty slot (refcount++;
        unpark any that sat in the reusable LRU). Zero prefill compute —
        the pages already hold the frozen K/V for these tokens."""
        assert not self.slot_pages[slot]
        for pid in pids:
            if self.page_refs[pid] == 0:
                self._prefix.unpark(pid)
            self.page_refs[pid] += 1
        self.slot_pages[slot] = list(pids)
        self.slot_shared[slot] = len(pids)
        self.page_table[slot, :len(pids)] = pids

    def _alloc(self, slot: int, npg: int) -> List[int]:
        ids = [self._take_page() for _ in range(npg)]
        for pid in ids:
            self.page_refs[pid] += 1
        self.slot_pages[slot].extend(ids)
        owned = self.slot_pages[slot]
        self.page_table[slot, :len(owned)] = owned
        return ids

    def _alloc_cross(self, slot: int) -> List[int]:
        ids = [self._take_page() for _ in range(self._cross_pp)]
        for pid in ids:
            self.page_refs[pid] += 1
        self.slot_cross[slot] = ids
        self.cross_table[slot, :len(ids)] = ids
        return ids

    def _alloc_slab(self, slot: int, reset: bool = True) -> int:
        sid = self.free_slabs.pop(0)
        self.slot_slab[slot] = sid
        self.slab_table[slot] = sid
        if reset:  # a resume overwrites the slab with its spill right after
            ids = jnp.asarray([sid], jnp.int32)
            for ui, (path, kind) in enumerate(self._units):
                if kind != "slab":
                    continue
                pool = dict(self._unit(path))
                for name, arr in self._slab_init[ui].items():
                    pool[name] = pool[name].at[:, ids].set(jnp.asarray(arr))
                self._set_unit(path, pool)
        return sid

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        if not len(req.prompt):
            raise ValueError(
                f"request {req.rid}: empty prompt (decode needs at least "
                "one context token to seed the first logits row)")
        if req.max_new < 1:
            raise ValueError(
                f"request {req.rid}: max_new={req.max_new} must be >= 1")
        # same fail-fast contract as the prompt checks: a bad sampling
        # bound surfaces here as a clear ValueError, never as an opaque
        # in-graph mask (top_p <= 0 would silently kill every token)
        req.sampling.validate(req.rid)
        lo, hi = min(req.prompt), max(req.prompt)
        if lo < 0 or hi >= self.cfg.vocab_size:
            raise ValueError(
                f"request {req.rid}: prompt token ids must be in "
                f"[0, {self.cfg.vocab_size}), got "
                f"{lo if lo < 0 else hi} (an out-of-vocab id would surface "
                "as an opaque in-graph embedding gather)")
        if len(req.prompt) >= self.max_seq:
            # fail fast here: the streaming prefill would otherwise run out
            # of reserved pages mid-chunk with an opaque shape error
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} must be "
                f"< max_seq={self.max_seq} (no room left to decode)")
        if self._encdec:
            if req.frames is None:
                raise ValueError(
                    f"request {req.rid}: enc-dec serving needs per-request "
                    "encoder frames (Request.frames)")
            if req.frames.shape[0] != self.cfg.encoder_seq:
                raise ValueError(
                    f"request {req.rid}: frames length {req.frames.shape[0]} "
                    f"!= encoder_seq={self.cfg.encoder_seq} (pad the input; "
                    "the encoder program is fixed-shape)")
        if self._has_pages and self._worst_case_pages(req) > self._n_pages:
            # fail fast on requests no retirement can ever fit
            raise ValueError(
                f"request {req.rid}: needs {self._worst_case_pages(req)} pages "
                f"but the pool has {self._n_pages}; raise pool_pages or "
                "shrink prompt/max_new")
        req.since = self._step_no
        req.seq = self._submit_seq
        req.t_submit = time.perf_counter()
        self._submit_seq += 1
        self.queue.append(req)  # (since, seq) is monotonic here: stays sorted

    def _enqueue(self, req: Request):
        """Re-insert an evicted request into the queue at its wait-line
        position: it keeps the (since, seq) it already earned, so eviction
        moves a spill between containers without losing its place."""
        keys = [(r.since, r.seq) for r in self.queue]
        self.queue.insert(bisect.bisect(keys, (req.since, req.seq)), req)

    def _admit(self):
        for slot in range(self.slots):
            if self.active[slot] is not None:
                continue
            if not (self.preempted or self.queue):
                break
            if not self._admit_one(slot):
                break  # head of line does not fit: wait (no overtaking)

    def _slack(self, req: Request) -> float:
        """Deadline slack in engine steps: how many steps this request can
        lose before it misses its SLO (deadline − now − tokens it still has
        to decode). No deadline = infinite slack (nothing to miss) — and so
        is a deadline that is already unmeetable (slack <= 0): shielding a
        request whose SLO is lost either way would only make a still-
        meetable peer miss too, so a dead deadline stops protecting."""
        if req.deadline_step is None:
            return math.inf
        slack = (req.deadline_step - self._step_no
                 - (req.max_new - len(req.out)))
        return slack if slack > 0 else math.inf

    def _pick_victim(self) -> Optional[int]:
        """Steal victim: lowest priority first, then — ROADMAP scheduler
        item (c) — the most deadline slack within that priority class (a
        request about to miss its SLO is shielded; one with no deadline, or
        with steps to spare, yields first), then the most recently arrived.
        Requests inside the steal cooldown are protected unless no other
        victim exists."""
        cands = [s for s, r in enumerate(self.active) if r is not None]
        if not cands:
            return None
        warm = [s for s in cands
                if self._step_no - self._slot_since[s] >= self.steal_cooldown]
        pick_from = warm or cands
        return min(pick_from,
                   key=lambda s: (self.active[s].priority,
                                  -self._slack(self.active[s]),
                                  -self._slot_seq[s]))

    def _slab_available(self, want_priority: int) -> bool:
        """True if a slab is free, or (token-budget scheduler only) one can
        be stolen for a waiter whose priority strictly beats the victim's.
        Reserve-on-admit never preempts — that is its whole contract — so
        under it slab exhaustion simply defers admission."""
        if not self._has_slabs:
            return True
        if self.free_slabs:
            return True
        if self.scheduler != "token_budget":
            return False
        victim = self._pick_victim()
        if victim is not None and self.active[victim].priority < want_priority:
            self._preempt(victim)
            return True
        return False

    def _admit_one(self, slot: int) -> bool:
        """Admit the longest-waiting candidate into ``slot``. Spilled and
        fresh requests share ONE ordered wait line keyed on (since, seq):
        a spill evicted by the budget keeps the place it already earned —
        it does not fall behind every younger spill just because it moved
        from ``preempted`` to ``queue`` — and the head of the line is never
        overtaken when it does not fit."""
        any_active = any(r is not None for r in self.active)
        free = self._free_capacity()
        spill = None
        if self.scheduler == "token_budget" and self.preempted:
            spill = min(self.preempted,
                        key=lambda sp: (sp.req.since, sp.req.seq))
        fresh = self.queue[0] if self.queue else None
        if spill is not None and fresh is not None and \
                (fresh.since, fresh.seq) < (spill.req.since, spill.req.seq):
            spill = None  # the fresh head has waited longer
        if spill is not None:
            req = spill.req
            shared_pids: List[int] = []
            if spill.shared_pages:
                ctx = list(req.prompt) + list(req.out[:-1])
                shared_pids = self._prefix.walk(
                    ctx, max_pages=spill.shared_pages,
                    root=self._prefix_root(req))
                if len(shared_pids) < spill.shared_pages:
                    # part of the shared prefix was reclaimed while this
                    # request sat spilled: the private payload no longer
                    # abuts a resolvable prefix — drop the bytes and fall
                    # back to a tail re-prefill through the normal
                    # admission walk (whatever hits remain still count)
                    self.stats["resume_fallbacks"] += 1
                    self._evict_spill(spill)
                    return self._admit_one(slot)
            need = 0
            if self._has_pages:
                # parked hits count in _free_capacity() but stop being
                # allocatable the moment this admission maps them — charge
                # them against the free pool or _alloc could run dry
                free -= self._parked_among(shared_pids)
                need = min(kvc.pages_needed(spill.ctx_len, self.page_size)
                           - spill.shared_pages + self.headroom_pages,
                           self._worst_case_pages(req) - self._cross_pp
                           - spill.shared_pages)
                need += self._cross_pp
                margin = self.resume_watermark if any_active else 0
                if free - need < margin:
                    return False
            if not self._slab_available(req.priority):
                return False
            if kvc.payload_checksum(spill.payload) != spill.crc:
                # bit rot while spilled: restoring these bytes would put
                # silent garbage in the pool. Drop them and fall back to
                # the eviction-style tail re-prefill — the request still
                # finishes, token-identically, it just pays the prefill
                self.stats["spill_integrity_failures"] += 1
                self._evict_spill(spill)
                return self._admit_one(slot)
            self.preempted.remove(spill)
            self._spill_bytes -= spill.nbytes
            self._resume(slot, spill, shared_pids, need - self._cross_pp)
            return True
        if fresh is None:
            return False
        req = fresh
        ctx = req.resume_ctx if req.resume_ctx is not None else req.prompt
        ctx_len = len(ctx)
        hits: List[int] = []
        if self._prefix is not None:
            # the last context token always streams through the prefill
            # (its logits seed decode), so cap the walk one token short —
            # this also keeps the boundary page private by construction
            hits = self._prefix.walk(
                ctx, max_pages=(ctx_len - 1) // self.page_size,
                root=self._prefix_root(req))
        need = 0
        if self._has_pages:
            free -= self._parked_among(hits)  # mapping a parked hit uses it
            if self.scheduler == "reserve":
                need = self._worst_case_pages(req) - len(hits)
                if free < need:
                    return False
            else:
                need = min(kvc.pages_needed(ctx_len, self.page_size)
                           - len(hits) + self.headroom_pages,
                           self._worst_case_pages(req) - self._cross_pp
                           - len(hits))
                need += self._cross_pp
                margin = self.low_watermark if any_active else 0
                if free - need < margin:
                    return False
        if not self._slab_available(req.priority):
            return False
        self.queue.pop(0)
        self.active[slot] = req
        self._slot_seq[slot] = req.seq
        self._slot_since[slot] = self._step_no
        if self._has_pages:
            if hits:
                self._map_shared(slot, hits)
                self.lengths[slot] = len(hits) * self.page_size
                self.stats["prefix_hit_pages"] += len(hits)
                self.stats["prefix_hit_tokens"] += len(hits) * self.page_size
            self._alloc(slot, need - self._cross_pp)
            if self._encdec:
                self._alloc_cross(slot)
        if self._has_slabs:
            self._alloc_slab(slot)
        if self._mixed_step:
            # mixed engine: the context streams through subsequent fused
            # engine steps (one budgeted chunk piggybacked per step), so
            # admission only maps/allocates pages. Run the write-target
            # freeze check here — the stream's pages were just mapped and
            # stay private (only this slot's own _register_prefix can
            # freeze them) until the stream completes.
            if self._prefix is not None:
                self._prefix.assert_unfrozen(
                    self.slot_pages[slot][
                        int(self.lengths[slot]) // self.page_size:
                        kvc.pages_needed(ctx_len, self.page_size)],
                    frozen_base=self._frozen_base)
            # the mixed step derives the stream context and fresh-ness from
            # the request itself (prompt + out[:-1]; fresh iff no out), so
            # a resume marker has nothing left to carry
            req.resume_ctx = None
        else:
            self._prefill_slot(slot, req)
        return True

    # -- streaming paged prefill ----------------------------------------------
    def _state_for(self, rows, lengths, chunk_len=None):
        """Build the PagedState for ``rows`` (a slice or index list)."""
        return kvc.PagedState(
            page_table=jnp.asarray(self.page_table[rows]),
            lengths=jnp.asarray(lengths),
            chunk_len=chunk_len,
            cross_table=(jnp.asarray(self.cross_table[rows])
                         if self._encdec else None),
            enc_lengths=(jnp.asarray(self.enc_lengths[rows])
                         if self._encdec else None),
            slabs=(jnp.asarray(self.slab_table[rows])
                   if self._has_slabs else None),
        )

    def _chunk_plan(self, slot: int, n: int, pos: int, budget: int):
        """Plan one streaming-prefill chunk for ``slot`` at stream position
        ``pos`` of an ``n``-token context: the true chunk length ``take``,
        its power-of-two bucketed pad length ``padded``, the bucketed page
        table width ``w`` and the (1, w) trimmed table. Shared by the
        serial prefill loop and the mixed engine step, so both compile the
        same O(log max_seq) family of chunk shapes. Only pages holding
        real data up to the chunk's true end are mapped: a bucketed
        chunk's zeroed pad writes overhang the last data page, and
        append_prefill_chunk's contract is that those positions must point
        at the null page — not at allocated headroom (harmless while
        private, corruption once shared)."""
        page = self.page_size
        take = min(budget, n - pos)
        if self._bucket_prefill:
            padded = min(_next_pow2(take), budget)
            w = _next_pow2(pos // page + kvc.pages_needed(padded, page))
        else:
            padded = take
            w = (kvc.pages_needed(pos + take, page) if self._has_pages
                 else 1)
        own = self.slot_pages[slot]
        table = np.full((1, w), self._null_page, np.int32)
        m = min(w, len(own), kvc.pages_needed(pos + take, page))
        table[0, :m] = own[:m]
        return take, padded, w, table

    def _prefill_slot(self, slot: int, req: Request):
        """Prefill a (re)admitted request: stream its context through the
        model in page-aligned chunks, each chunk's K/V written straight
        into this slot's pages inside the jitted forward (no contiguous
        max_seq scratch cache). When admission mapped content-shared prefix
        pages, ``lengths[slot]`` already covers them and only the uncached
        tail streams here. Chunk lengths and page-table widths are bucketed
        to powers of two (pad + mask) so trace count is O(log max_seq);
        recurrent families stream exact chunks (pad tokens cannot be masked
        out of a recurrence). Enc-dec requests first run the encoder once,
        writing every decoder layer's cross K/V into the slot's write-once
        cross pages. Afterwards every full prompt page is registered in the
        prefix index (scale-frozen from here on: only the private boundary
        page is ever requantized again)."""
        ctx = req.resume_ctx if req.resume_ctx is not None else list(req.prompt)
        fresh = req.resume_ctx is None
        req.resume_ctx = None
        n = len(ctx)
        page = self.page_size
        if self._encdec:
            frames = jnp.asarray(req.frames, jnp.float32)[None]
            table = jnp.asarray(self.cross_table[slot:slot + 1])
            with self._trace_scope():
                self.pools = _encode_cross_jit(self.params, frames,
                                               self.pools, table,
                                               cfg=self.cfg, a_fmt=self.a_fmt)
            self.stats["programs"] += 1
            self.enc_lengths[slot] = self.cfg.encoder_seq

        chunk = self.prefill_token_budget
        start = int(self.lengths[slot])  # > 0: shared prefix already mapped
        if self._prefix is not None:
            # the stream writes pages [start/page, ceil(n/page)) — none of
            # them may be shared-frozen (boundary pages stay private), and
            # in a mixed pool none may be a packed FP4 logical id
            self._prefix.assert_unfrozen(
                self.slot_pages[slot][start // page:
                                      kvc.pages_needed(n, page)],
                frozen_base=self._frozen_base)
        # the final chunk's in-graph sample seeds the stream (emitted-token
        # index = len(out): 0 for a fresh prefill; a resume re-prefill
        # discards the draw, so the index is never consumed twice)
        samp1 = smp.slot_arrays(1)
        smp.fill_slot(samp1, 0, req.sampling, len(req.out))
        samp1 = smp.as_tuple(samp1)
        nxt = None
        ok = True
        pos = start
        while pos < n:
            take, padded, w, table = self._chunk_plan(slot, n, pos, chunk)
            toks = ctx[pos: pos + take] + [0] * (padded - take)
            # chunk_len rides along for every prefill chunk (not just
            # bucketed ones): models use it both to mask pad positions and
            # to tell a 1-token chunk apart from a decode step
            chunk_len = jnp.asarray([take], jnp.int32)
            state = self._state_for(slice(slot, slot + 1),
                                    np.asarray([pos], np.int32), chunk_len)
            state = state._replace(page_table=jnp.asarray(table))
            with self._trace_scope():
                nxt, row_ok, pools = self._decode(
                    self.params, self.pools, jnp.asarray([toks], jnp.int32),
                    state, self._no_poison1, samp1)
            self.pools = pools
            self.stats["programs"] += 1
            ok = ok and bool(np.asarray(row_ok)[0])
            self.prefill_traces.add((padded, w))
            pos += take
        self.lengths[slot] = n
        self.stats["prefill_tokens"] += n - start
        if not ok:
            # non-finite logits during this request's prefill: quarantine
            # the request alone. Its pages are NOT registered in the
            # prefix index (frozen garbage would poison every future hit)
            # and no seed token is appended — retire through the normal
            # path so pages/slab accounting stays intact
            self._fail_slot(slot, req,
                            f"non-finite logits during prefill of request "
                            f"{req.rid} ({n} context tokens)",
                            scrub_null=True)
            return
        if self._prefix is not None:
            self._register_prefix(slot, req)
        if fresh:
            self._emit_token(req, int(np.asarray(nxt)[0]))

    def _register_prefix(self, slot: int, req: Request):
        """Promote this slot's full prompt pages to shared-frozen: register
        them in the content index so later requests with the same prefix
        map them for free. Only the *prompt* region is registered — its
        pages were written by the (deterministic) prefill stream in one
        shot, so their frozen content is bit-reusable by any owner;
        decode-grown pages went through per-step requantization and are
        not. If another slot registered the same chain first (e.g. the
        walk was capped short of an exactly-page-aligned prompt), adopt the
        canonical page and release our duplicate — dedup keeps the shared
        pages one contiguous leading run.

        Mixed-precision policy (``CachePolicy.frozen_fmt="fp4_e2m1"``):
        registration IS the freeze point, so this is where each page is
        transcoded FP8 -> packed FP4, exactly once — the frozen region's
        only write. Per newly-full prompt page: adopt the already-frozen
        canonical if the chain exists, else allocate a frozen logical id,
        ``kv_cache.transcode_page`` the FP8 page into it, remap the slot to
        the frozen id and release the FP8 source back to the free list.
        When the frozen region runs dry the loop stops gracefully — the
        remaining prompt pages simply stay private FP8 (unshared but
        correct), keeping the shared run contiguous."""
        page = self.page_size
        n_full = len(req.prompt) // page
        shared = self.slot_shared[slot]
        if n_full <= shared:
            return  # nothing new beyond the already-mapped prefix
        own = self.slot_pages[slot]
        if not self._mixed:
            canon = self._prefix.insert(req.prompt[:n_full * page],
                                        own[:n_full],
                                        root=self._prefix_root(req))
            for i in range(shared, n_full):
                if canon[i] != own[i]:  # duplicate content: adopt canonical
                    dup = own[i]
                    if self.page_refs[canon[i]] == 0:
                        self._prefix.unpark(canon[i])
                    self.page_refs[canon[i]] += 1
                    own[i] = canon[i]
                    self.page_table[slot, i] = canon[i]
                    self._release_page(dup)  # private, refcount 1 -> free
            self.slot_shared[slot] = n_full
            return
        # mixed: every registered page lives in the packed FP4 region
        canon = self._prefix.walk(req.prompt, max_pages=n_full,
                                  root=self._prefix_root(req))
        end = shared
        for i in range(shared, n_full):
            src = own[i]
            if i < len(canon):  # identical prefix already frozen: adopt it
                fid = canon[i]
                if self.page_refs[fid] == 0:
                    self._prefix.unpark(fid)
            else:
                fid = self._take_frozen()
                if fid is None:
                    break  # frozen region fully live: tail stays private
                for path, kind in self._units:
                    if kind == "kv":
                        self._set_unit(path, kvc.transcode_page(
                            self._unit(path), src,
                            fid - self._frozen_base))
                self.stats["fp4_frozen_pages"] += 1
            self.page_refs[fid] += 1
            own[i] = fid
            self.page_table[slot, i] = fid
            self._release_page(src)  # the FP8 source, refcount 1 -> free
            end = i + 1
        self._pin_pools()  # freeze-time transcodes wrote the fz region
        if end > shared:
            self._prefix.insert(req.prompt[:end * page], own[:end],
                                root=self._prefix_root(req))
        self.slot_shared[slot] = end

    # -- preemption by page steal ----------------------------------------------
    def _preempt(self, slot: int):
        """Steal this slot's pages (and slab): spill its *private* payload
        (codes + scales + recurrent state, bit-exact) to host memory and
        drop every page mapping. Content-shared prefix pages are not
        spilled — their frozen bytes stay resident in the prefix index
        (parked at refcount 0 if no other slot maps them) and are
        re-resolved by token id on resume."""
        req = self.active[slot]
        ctx_len = int(self.lengths[slot])
        shared = self.slot_shared[slot]
        npg = kvc.pages_needed(ctx_len, self.page_size)
        priv = self.slot_pages[slot][shared:npg]
        payload = []
        nbytes = 0
        for path, kind in self._units:
            pool = self._unit(path)
            if kind == "kv":
                ids = jnp.asarray(priv, jnp.int32)
            elif kind == "cross":
                ids = jnp.asarray(self.slot_cross[slot], jnp.int32)
            else:  # slab
                ids = jnp.asarray([self.slot_slab[slot]], jnp.int32)
            # only private pages spill, and those are always active-class:
            # the frozen-region ``*_fz`` leaves (different row count, ids
            # are logical) and the zero-size format marker never ride along
            part = {name: np.asarray(leaf[:, ids])
                    for name, leaf in pool.items()
                    if "_fz" not in name and leaf.size}
            nbytes += sum(a.nbytes for a in part.values())
            payload.append(part)
        # integrity checksum over the pristine bytes; the fault hook runs
        # *after* it (tampering models bit rot during host residency, so
        # the resume-time verify is what must catch it)
        crc = kvc.payload_checksum(payload)
        if self.faults is not None:
            payload = self.faults.spill_payload(req.rid, payload)
        req.since = self._step_no  # re-enters the wait line now
        self.preempted.append(_Spill(req=req, ctx_len=ctx_len,
                                     shared_pages=shared, payload=payload,
                                     nbytes=nbytes, crc=crc,
                                     rng_seed=req.sampling.seed,
                                     emitted=len(req.out)))
        self._spill_bytes += nbytes
        req.preemptions += 1
        self.stats["preemptions"] += 1
        self.stats["pages_stolen"] += (len(self.slot_pages[slot]) - shared
                                       + len(self.slot_cross[slot]))
        for pid in self.slot_pages[slot]:
            self._release_page(pid)
        for pid in self.slot_cross[slot]:
            self._release_page(pid)
        self.slot_pages[slot] = []
        self.slot_cross[slot] = []
        self.slot_shared[slot] = 0
        self.page_table[slot] = self._null_page
        self.cross_table[slot] = self._null_page
        self.enc_lengths[slot] = 0
        if self.slot_slab[slot] >= 0:
            self.free_slabs.append(self.slot_slab[slot])
            self.slot_slab[slot] = -1
            self.slab_table[slot] = self._n_slabs
        self.lengths[slot] = 0
        self.active[slot] = None

    def _evict_spill(self, sp: _Spill):
        """Drop a spill's host bytes and re-queue its request for a full
        context re-prefill. The request keeps its wait-line key
        (``since``/``seq`` earned at preemption), so eviction moves it
        between containers without losing its place — readmission order
        stays one global longest-waiting-first line, and a budget eviction
        can no longer push the *oldest* waiter behind every younger spill."""
        self.preempted.remove(sp)
        self._spill_bytes -= sp.nbytes
        req = sp.req
        # KV context at preemption = prompt + out[:-1] (the newest token
        # was produced but not yet fed back); re-prefilling exactly that
        # context lets decode continue by feeding out[-1] as usual. A
        # request spilled mid-prefill (no tokens out yet, mixed engine)
        # re-enters as fresh — marking it resumed would swallow the seed
        # token its first completed prefill is supposed to emit
        req.resume_ctx = (list(req.prompt) + list(req.out[:-1])
                          if req.out else None)
        req.evictions += 1
        self.stats["spill_evictions"] += 1
        self._enqueue(req)

    def _enforce_spill_budget(self):
        """ROADMAP (b): host spills are bounded. When resident spill bytes
        exceed ``spill_budget_bytes``, evict oldest-first: drop the spill's
        bytes and re-queue its request — at its existing wait-line position
        — with its full context (prompt + tokens generated so far) marked
        for re-prefill; the request still finishes, token-identically, it
        just pays a prompt re-prefill instead of a byte restore.

        Runs at the top of every engine step, never from inside
        ``_preempt``: a steal can fire mid-admission (``_slab_available``),
        and evicting there would mutate ``queue``/``preempted`` under
        ``_admit_one``'s feet — the admitted request's ``queue.pop(0)``
        would pop the freshly re-queued eviction instead. Enforcing at the
        step boundary means the budget can overshoot by the spills of a
        single scheduling round, and evicted requests re-enter admission
        in the same step they are dropped."""
        if self.spill_budget_bytes is None:
            return
        while (self._spill_bytes > self.spill_budget_bytes
               and self.preempted):
            self._evict_spill(min(self.preempted,
                                  key=lambda s: (s.req.since, s.req.seq)))

    def _resume(self, slot: int, spill: _Spill, shared_pids: List[int],
                need_kv: int):
        """Restore a spilled request: map its re-resolved content-shared
        prefix pages (zero bytes moved — the frozen content never left the
        pool), then restore the private payload bit-exactly into fresh
        pages/slab behind them (token-identical: page/slab ids are logical,
        the model only sees the tables)."""
        self.active[slot] = spill.req
        self._slot_seq[slot] = spill.req.seq  # keeps its original age
        self._slot_since[slot] = self._step_no
        new_kv: List[int] = []
        new_cross: List[int] = []
        if self._has_pages:
            if shared_pids:
                self._map_shared(slot, shared_pids)
            new_kv = self._alloc(slot, need_kv)
            if self._prefix is not None:  # restore targets must be writable
                self._prefix.assert_unfrozen(new_kv,
                                             frozen_base=self._frozen_base)
            if self._encdec:
                new_cross = self._alloc_cross(slot)
                self.enc_lengths[slot] = self.cfg.encoder_seq
        if self._has_slabs:
            self._alloc_slab(slot, reset=False)  # restored from spill below
        npg_priv = (kvc.pages_needed(spill.ctx_len, self.page_size)
                    - spill.shared_pages)
        for (path, kind), part in zip(self._units, spill.payload):
            if kind == "kv":
                ids = jnp.asarray(new_kv[:npg_priv], jnp.int32)
            elif kind == "cross":
                ids = jnp.asarray(new_cross, jnp.int32)
            else:  # slab
                ids = jnp.asarray([self.slot_slab[slot]], jnp.int32)
            pool = dict(self._unit(path))
            for name, arr in part.items():
                pool[name] = pool[name].at[:, ids].set(jnp.asarray(arr))
            self._set_unit(path, pool)
        self._pin_pools()  # host scatter -> back onto the canonical layout
        self.lengths[slot] = spill.ctx_len
        # RNG continuity: the spill carries the request's complete sampling
        # state (seed + emitted count). The key for the next draw is
        # fold_in(PRNGKey(seed), len(out)) — recomputed from the request,
        # so the spilled copy is an integrity check, not a live register.
        assert spill.rng_seed == spill.req.sampling.seed
        assert spill.emitted == len(spill.req.out), (
            f"request {spill.req.rid}: spill recorded {spill.emitted} "
            f"emitted tokens but the request holds {len(spill.req.out)} — "
            "the resumed RNG stream would diverge")
        self.stats["resumes"] += 1

    def _steal_for(self, needer: int) -> bool:
        """Free pages by preempting the cooldown-aware lowest-priority
        victim (see _pick_victim). The needer itself is a valid victim —
        if it is the lowest-priority request running, it is the one that
        yields."""
        victim = self._pick_victim()
        if victim is None:
            return False
        self._preempt(victim)
        return True

    def _grow(self):
        """On-demand page allocation: before the decode step, every active
        row whose next token crosses into an unallocated page gets one from
        the pool — reclaiming refcount-0 cached pages first, and stealing
        from the lowest-priority request only when even the reusable set is
        dry. Rows are served in priority order (then arrival order), so a
        steal always benefits the higher-priority work."""
        if not self._has_pages:
            return
        order = sorted(
            (s for s, r in enumerate(self.active) if r is not None),
            key=lambda s: (-self.active[s].priority, self._slot_seq[s]))
        for slot in order:
            while self.active[slot] is not None:
                need_idx = int(self.lengths[slot]) // self.page_size
                if need_idx < len(self.slot_pages[slot]):
                    break
                if self._free_capacity():
                    self._alloc(slot, 1)
                elif not self._steal_for(slot):
                    break  # pragma: no cover — needer itself is a candidate

    # -- streaming emissions ---------------------------------------------------
    def _emit_token(self, req: Request, token: int):
        """Append a decoded token to the request and (when a front-end is
        listening) buffer its TokenEvent with the decode timestamp — the
        raw material for TTFT / inter-token latency."""
        t = time.perf_counter()
        req.out.append(token)
        req.token_times.append(t)
        if self.collect_events:
            self._events.append(TokenEvent(
                rid=req.rid, token=token, index=len(req.out) - 1, t=t))

    def _emit_finished(self, req: Request):
        if self.collect_events:
            self._events.append(TokenEvent(
                rid=req.rid, token=None, index=len(req.out),
                t=time.perf_counter(), finished=True, status=req.status))

    def pop_events(self) -> List[TokenEvent]:
        """Drain the buffered engine emissions (empty unless a front-end
        switched ``collect_events`` on). Every decoded token yields one
        event in decode order; every retirement (ok / truncated / failed)
        yields a terminal event with the request's status."""
        ev, self._events = self._events, []
        return ev

    # -- retirement ----------------------------------------------------------
    def _retire(self, slot: int, req: Request):
        req.done = True
        self.active[slot] = None
        self.finished.append(req)
        self._emit_finished(req)
        # freed pages are NOT zeroed (that would rewrite the whole pool per
        # retirement): recycled pages are overwritten by the prefill stream,
        # and decode appends mask positions past the new owner's length
        # before recomputing page scales, so stale codes can never leak.
        # Registered prompt pages whose refcount drops to 0 here park in
        # the prefix cache's reusable LRU instead of the free list — the
        # request's system-prompt K/V outlives it for the next hit
        for pid in self.slot_pages[slot]:
            self._release_page(pid)
        for pid in self.slot_cross[slot]:
            self._release_page(pid)
        self.slot_pages[slot] = []
        self.slot_cross[slot] = []
        self.slot_shared[slot] = 0
        self.page_table[slot] = self._null_page
        self.cross_table[slot] = self._null_page
        self.enc_lengths[slot] = 0
        if self.slot_slab[slot] >= 0:
            self.free_slabs.append(self.slot_slab[slot])
            self.slot_slab[slot] = -1
            self.slab_table[slot] = self._n_slabs
        self.lengths[slot] = 0

    # -- request-level failure isolation --------------------------------------
    def _scrub_slot(self, slot: int, include_null: bool = False):
        """Zero every pool page / slab a quarantined row may have written:
        its private non-registered pages, cross pages and slab — plus the
        shared null page when a failing prefill's bucketed overhang wrote
        there. Necessary, not cosmetic: a non-finite upstream activation
        writes NaN K/V codes, and NaN survives attention's zero-weight
        masking (0 * NaN = NaN) — a recycled free-list page or the null
        page holding NaN bytes would fail *healthy* rows, breaking exactly
        the isolation the quarantine guarantees. Registered pages are
        excluded: they were frozen by a healthy prefill (the CoW invariant
        keeps a failing row's writes out of them)."""
        priv = list(self.slot_pages[slot][self.slot_shared[slot]:])
        if include_null:
            priv.append(self._null_page)
        kv_ids = jnp.asarray(priv, jnp.int32) if priv else None
        cross_ids = (jnp.asarray(self.slot_cross[slot], jnp.int32)
                     if self.slot_cross[slot] else None)
        slab_ids = (jnp.asarray([self.slot_slab[slot]], jnp.int32)
                    if self.slot_slab[slot] >= 0 else None)
        for path, kind in self._units:
            ids = {"kv": kv_ids, "cross": cross_ids}.get(kind, slab_ids)
            if ids is None:
                continue
            pool = self._unit(path)
            for name in pool:
                # scrub only the active-class stores: a quarantined row can
                # never have written the frozen region (transcode is its
                # only writer), and the ids here would misindex its rows
                if "_fz" in name or not pool[name].size:
                    continue
                pool[name] = pool[name].at[:, ids].set(0)
            self._set_unit(path, pool)
        self._pin_pools()  # host scatter -> back onto the canonical layout

    def _fail_slot(self, slot: int, req: Request, error: str,
                   scrub_null: bool = False):
        """Quarantine one active row: scrub the pool bytes it wrote, mark
        it failed and retire it through the normal path — its pages/slab
        free (or park) with refcounts intact, every other row keeps
        decoding. The per-process blast radius of a poisoned row is
        exactly that row."""
        self._scrub_slot(slot, include_null=scrub_null)
        req.status = "failed"
        req.error = error
        self.stats["failed"] += 1
        self._retire(slot, req)

    def _fail_request(self, req: Request, error: str):
        """Fail a request that holds no pool state (queued or already
        spilled-and-dropped): it retires straight into ``finished``."""
        req.status = "failed"
        req.error = error
        req.done = True
        self.stats["failed"] += 1
        self.finished.append(req)
        self._emit_finished(req)

    def _fail_pending(self, reason: str):
        """Non-strict starvation response: fail every queued and spilled
        request individually (dropping spill bytes) instead of raising a
        drain-wide error — active rows are untouched and keep decoding."""
        for sp in list(self.preempted):
            self.preempted.remove(sp)
            self._spill_bytes -= sp.nbytes
            self._fail_request(sp.req, reason)
        for req in list(self.queue):
            self.queue.remove(req)
            self._fail_request(req, reason)

    def _pending_diagnostics(self) -> List[Dict]:
        """One diagnostic dict per request still waiting or running —
        attached to ServingError so strict-mode callers see *why* each
        straggler could not finish."""
        diag = []
        for req in self.queue:
            ctx = req.resume_ctx if req.resume_ctx is not None else req.prompt
            diag.append({
                "rid": req.rid, "state": "queued", "since": req.since,
                "out_tokens": len(req.out), "ctx_len": len(ctx),
                "pages_needed": (kvc.pages_needed(len(ctx), self.page_size)
                                 + self._cross_pp if self._has_pages else 0)})
        for sp in self.preempted:
            diag.append({
                "rid": sp.req.rid, "state": "spilled", "since": sp.req.since,
                "out_tokens": len(sp.req.out), "ctx_len": sp.ctx_len,
                "pages_needed": (kvc.pages_needed(sp.ctx_len, self.page_size)
                                 + self._cross_pp if self._has_pages else 0),
                "spill_bytes": sp.nbytes})
        for s, req in enumerate(self.active):
            if req is not None:
                diag.append({
                    "rid": req.rid, "state": "active", "slot": s,
                    "out_tokens": len(req.out),
                    "ctx_len": int(self.lengths[s])})
        return diag

    # -- engine step ----------------------------------------------------------
    @staticmethod
    def _ctx_target(req: Request) -> int:
        """The KV length at which ``req`` is fully prefilled and decoding:
        its prompt plus every emitted token except the newest (produced but
        not yet fed back). A slot below this target is mid-prefill."""
        return len(req.prompt) + max(len(req.out) - 1, 0)

    def _extend_shared(self, slot: int, ctx: List[int]):
        """Stream-start prefix re-walk for the mixed engine. Between this
        request's admission and the first chunk of its stream, a sibling
        stream may have registered exactly the prefix this slot is about
        to recompute — a window the alternating engine never has (its
        prefill completes inside admission, so the walk and the stream
        are atomic). Re-walk the index and adopt any newly frozen pages:
        map each over the private page admission allocated for the same
        position (released back to the pool — or appended, when a spill
        restored fewer pages than the walk now covers) and advance the
        stream past them. Adopted content is bit-identical to what the
        stream would have written, by the same determinism argument
        admission-time hits rely on."""
        page = self.page_size
        shared = self.slot_shared[slot]
        req = self.active[slot]
        hits = self._prefix.walk(ctx, max_pages=(len(ctx) - 1) // page,
                                 root=self._prefix_root(req))
        if len(hits) <= shared:
            return
        own = self.slot_pages[slot]
        for i in range(shared, len(hits)):
            pid = hits[i]
            if self.page_refs[pid] == 0:
                self._prefix.unpark(pid)
            self.page_refs[pid] += 1
            if i < len(own):
                self._release_page(own[i])
                own[i] = pid
            else:
                own.append(pid)
            self.page_table[slot, i] = pid
        self.slot_shared[slot] = len(hits)
        self.lengths[slot] = len(hits) * page
        self.stats["prefix_hit_pages"] += len(hits) - shared
        self.stats["prefix_hit_tokens"] += (len(hits) - shared) * page

    def _grow_for_chunk(self, slot: int):
        """Make sure ``slot`` owns every page its next prefill chunk will
        write. Fresh admission allocates the whole context up front, but a
        request resumed from a mid-prefill spill only got its already-
        written pages restored — the remaining stream pages are allocated
        here, chunk by chunk, with the same reclaim-then-steal ladder
        ``_grow`` uses (the needer itself is a valid victim)."""
        page = self.page_size
        while self.active[slot] is not None:
            req = self.active[slot]
            end = min(int(self.lengths[slot]) + self.prefill_token_budget,
                      self._ctx_target(req))
            if kvc.pages_needed(end, page) <= len(self.slot_pages[slot]):
                break
            if self._free_capacity():
                self._alloc(slot, 1)
            elif not self._steal_for(slot):
                break  # pragma: no cover — needer itself is a candidate

    def _pick_prefill_slot(self) -> Optional[int]:
        """The mixed engine's per-step prefill decision: the mid-prefill
        slot whose request has waited longest (the same longest-waiting-
        first key admission uses), or None when every active row is
        decoding. One slot per step — the chunk budget is the fused
        program's prefill lane and it is not split across requests."""
        best = None
        for s, req in enumerate(self.active):
            if req is None or int(self.lengths[s]) >= self._ctx_target(req):
                continue
            key = (req.since, req.seq)
            if best is None or key < best[0]:
                best = (key, s)
        return None if best is None else best[1]

    def step(self):
        """One engine step. Alternating engine (or a mixed step with
        nothing streaming): one decode token for every active slot.
        Mixed engine with a request mid-prefill: the same decode rows
        PLUS up to ``prefill_token_budget`` tokens of that request's next
        chunk ride in one fused jitted program — decode never stalls
        behind a long prompt. Per-slot true lengths, the page table (and
        for enc-dec the cross table / for recurrent families the slab
        ids) ride into the jitted step as inputs — per-row positions and
        length masks, one fixed-shape program per (chunk bucket, table
        bucket). Returns True if any slot made progress."""
        self._tick += 1
        self._alloc_faulted = (self.faults is not None
                               and self.faults.alloc_blocked(self._tick))
        self._enforce_spill_budget()
        self._admit()
        if self.scheduler == "token_budget":
            self._grow()
        pf_slot = self._pick_prefill_slot() if self._mixed_step else None
        if pf_slot is not None:
            r = self.active[pf_slot]
            if (self._prefix is not None
                    and int(self.lengths[pf_slot])
                    == self.slot_shared[pf_slot] * self.page_size):
                self._extend_shared(
                    pf_slot, list(r.prompt) + list(r.out[:-1]))
            self._grow_for_chunk(pf_slot)
            pf_slot = self._pick_prefill_slot()  # a steal may have hit it
        if not any(self.active):
            return False
        self._step_no += 1
        self.stats["steps"] += 1
        # decoding rows are the active slots at their context target; in
        # the alternating engine that is every active slot (prefill runs
        # to completion inside admission), in the mixed engine mid-prefill
        # slots are excluded — they stream, they don't decode yet
        decoding = [s for s, r in enumerate(self.active) if r is not None
                    and int(self.lengths[s]) >= self._ctx_target(r)]
        self.stats["slot_steps"] += len(decoding)
        if self._prefix is not None:
            # copy-on-write invariant: the page each row's append will
            # requantize (its boundary page — for a mid-prefill row, the
            # first page its next chunk writes) must be private — a shared
            # frozen page in that position would corrupt every other owner
            self._prefix.assert_unfrozen(
                (self.slot_pages[s][int(self.lengths[s]) // self.page_size]
                 for s, r in enumerate(self.active) if r is not None),
                frozen_base=self._frozen_base)
        pmask = (self.faults.poison_rows(self._step_no, self.slots)
                 if self.faults is not None else None)
        if pf_slot is not None:
            self._step_mixed(pf_slot, decoding, pmask)
        else:
            self._step_decode(pmask)
        if self.audit_every and self._step_no % self.audit_every == 0:
            self.audit()
        return True

    def _step_decode(self, pmask):
        """Pure-decode engine step: every active row is at its context
        target and decodes one token."""
        tok = np.zeros((self.slots, 1), dtype=np.int32)
        for s, req in enumerate(self.active):
            if req is not None and req.out:
                tok[s, 0] = req.out[-1]
            if req is not None:
                # count = tokens emitted so far = RNG index of this draw;
                # recomputed from the request each step, so the stream
                # position survives steals and resumes for free
                smp.fill_slot(self._samp, s, req.sampling, len(req.out))
            else:
                smp.clear_slot(self._samp, s)
        poison = (jnp.asarray(pmask) if pmask is not None and pmask.any()
                  else self._no_poison)
        state = self._state_for(slice(None), self.lengths)
        with self._trace_scope():
            nxt_dev, row_ok, self.pools = self._decode(
                self.params, self.pools, jnp.asarray(tok), state, poison,
                smp.as_tuple(self._samp))
        self.stats["programs"] += 1
        nxt = np.asarray(nxt_dev)
        okrow = np.asarray(row_ok)
        for s, req in enumerate(self.active):
            if req is not None:
                self._finish_decode_row(s, req, okrow[s], nxt[s], pmask)

    def _finish_decode_row(self, s: int, req: Request, ok: bool, nxt,
                           pmask):
        """Commit one decode row's step result: emit / retire, or
        quarantine exactly this request when the in-graph isfinite
        sentinel tripped (its garbage token is never appended; pages/slab
        retire through the normal path) while the rest of the batch keeps
        going."""
        if not ok:
            if pmask is not None and pmask[s]:
                self.faults.note_nan(self._step_no, s, req.rid)
            self._fail_slot(s, req,
                            f"non-finite logits at decode step "
                            f"{self._step_no} (slot {s})")
            return
        self._emit_token(req, int(nxt))
        self.lengths[s] += 1
        self.stats["decoded_tokens"] += 1
        if len(req.out) >= req.max_new or self.lengths[s] >= self.max_seq - 1:
            if len(req.out) < req.max_new:
                # hit the max_seq - 1 context bound: the request ends
                # short of its token budget — flag it instead of
                # retiring silently as if it were satisfied
                req.status = "truncated"
                self.stats["truncated"] += 1
            self._retire(s, req)

    def _step_mixed(self, pf_slot: int, decoding: List[int], pmask):
        """The fused mixed engine step: one jitted program carrying every
        decoding row's next token plus up to ``prefill_token_budget``
        tokens of ``pf_slot``'s next prefill chunk.

        Anatomy: the token row is ``(1, slots + padded)`` — one decode
        token per slot (garbage for non-decoding slots) followed by the
        bucketed chunk. The cache index is the full-batch decode
        PagedState with a nested batch-1 ``prefill`` state for the chunk;
        mid-prefill slots (including ``pf_slot`` itself) ride with their
        lengths zeroed so their garbage decode-lane appends null-redirect
        instead of requantizing a page mid-stream. Logits come back
        ``(slots + 1, V)``: one row per slot plus the chunk's last true
        token, each sampled by its own fixed-trace sampling row and
        covered by its own isfinite quarantine sentinel. Chunk and table
        sizes are power-of-two bucketed by the same _chunk_plan the
        serial loop uses, so trace count stays O(log max_seq)."""
        req = self.active[pf_slot]
        ctx = list(req.prompt) + list(req.out[:-1])
        n = len(ctx)
        pos = int(self.lengths[pf_slot])
        take, padded, w, table = self._chunk_plan(
            pf_slot, n, pos, self.prefill_token_budget)
        is_decoding = np.zeros((self.slots,), bool)
        is_decoding[decoding] = True
        tok = np.zeros((1, self.slots + padded), np.int32)
        dec_lengths = np.where(is_decoding, self.lengths, 0).astype(np.int32)
        for s in range(self.slots):
            r = self.active[s]
            if r is not None and is_decoding[s]:
                tok[0, s] = r.out[-1]
                smp.fill_slot(self._samp_m, s, r.sampling, len(r.out))
            else:
                smp.clear_slot(self._samp_m, s)
        tok[0, self.slots: self.slots + take] = ctx[pos: pos + take]
        # the chunk row samples at RNG index len(out): consumed as the
        # stream's seed token only by a fresh request's final chunk —
        # intermediate (and resume re-prefill) draws are discarded, and
        # the stateless fold_in keying means the index is never burned
        smp.fill_slot(self._samp_m, self.slots, req.sampling, len(req.out))
        if pmask is not None and pmask.any():
            # the chunk row inherits pf_slot's poison: a fault injected
            # into the streaming request mid-prefill must trip the chunk
            # row's sentinel (its decode-lane row is garbage and ignored)
            poison = jnp.asarray(
                np.concatenate([pmask, pmask[pf_slot:pf_slot + 1]]))
        else:
            poison = self._no_poison_m
        pre_state = kvc.PagedState(
            page_table=jnp.asarray(table),
            lengths=jnp.asarray([pos], np.int32),
            chunk_len=jnp.asarray([take], jnp.int32))
        state = self._state_for(slice(None), dec_lengths)
        state = state._replace(prefill=pre_state)
        with self._trace_scope():
            nxt_dev, row_ok, self.pools = self._decode(
                self.params, self.pools, jnp.asarray(tok), state, poison,
                smp.as_tuple(self._samp_m))
        self.stats["programs"] += 1
        self.prefill_traces.add((padded, w))
        nxt = np.asarray(nxt_dev)
        okrow = np.asarray(row_ok)
        self.lengths[pf_slot] = pos + take
        self.stats["prefill_tokens"] += take
        if not okrow[self.slots]:
            # non-finite logits in the chunk row: quarantine the streaming
            # request alone. Its pages are NOT registered in the prefix
            # index (frozen garbage would poison every future hit) and no
            # seed token is appended — retire through the normal path so
            # pages/slab accounting stays intact
            if pmask is not None and pmask[pf_slot]:
                self.faults.note_nan(self._step_no, pf_slot, req.rid)
            self._fail_slot(pf_slot, req,
                            f"non-finite logits during prefill of request "
                            f"{req.rid} ({n} context tokens)",
                            scrub_null=True)
        elif pos + take == n:
            if self._prefix is not None:
                self._register_prefix(pf_slot, req)
            if not req.out:  # fresh: the final chunk's draw seeds decode
                self._emit_token(req, int(nxt[self.slots]))
        for s in decoding:
            r = self.active[s]
            if r is not None:
                self._finish_decode_row(s, r, okrow[s], nxt[s], pmask)

    def run_until_drained(self, max_steps: int = 10_000) -> List[RequestResult]:
        """Step until queue, preempted set and slots are all empty; returns
        one immutable ``RequestResult`` snapshot per request finished during
        this call (in retirement order). The mutable ``Request`` stays the
        engine's working record; callers get the frozen view.

        Starvation guard: if an engine step makes no progress while work is
        still waiting (queued or preempted-but-never-resumed — e.g. the pool
        was fully stolen and nothing can be readmitted), ``strict=True``
        raises ``ServingError`` — carrying the requests that *did* finish
        during this call plus per-request pending diagnostics, so callers
        recover partial results — instead of spinning to ``max_steps`` and
        silently dropping the stragglers. ``strict=False`` instead fails
        exactly the unadmittable requests (``status='failed'`` with the
        starvation diagnostic as ``Request.error``) and completes the
        drain: request-level isolation for production traffic. A step
        blocked only by an injected transient allocator fault is not
        starvation — capacity returns on the next tick."""
        start = len(self.finished)
        for _ in range(max_steps):
            if self.step():
                continue
            if not self.queue and not self.preempted:
                break
            if self._alloc_faulted:
                continue  # injected transient exhaustion, not starvation
            msg = (
                f"serving starved: {len(self.queue)} queued + "
                f"{len(self.preempted)} preempted request(s) cannot be "
                f"(re)admitted with {self._free_capacity()}/{self._n_pages} "
                f"allocatable pool pages (incl. reusable cached) and "
                f"{len(self.free_slabs)}/{self._n_slabs} "
                "slabs free and no active work to retire — the pool is "
                "too small for the waiting context (or pages leaked)")
            if not self.strict:
                self._fail_pending(msg)
                continue  # active rows (if any) still drain normally
            raise ServingError(
                msg,
                finished=[r.result() for r in self.finished[start:]],
                pending=self._pending_diagnostics())
        else:
            pending = (len(self.queue) + len(self.preempted)
                       + sum(r is not None for r in self.active))
            if pending:
                raise ServingError(
                    f"run_until_drained: max_steps={max_steps} exhausted "
                    f"with {pending} request(s) still pending",
                    finished=[r.result() for r in self.finished[start:]],
                    pending=self._pending_diagnostics())
        return [r.result() for r in self.finished[start:]]

    # -- accounting ------------------------------------------------------------
    def audit(self) -> Dict:
        """Full pool-ownership audit: the invariants the scheduler fuzz
        tests assert, promoted to a production check (run it ad hoc, or
        every N decode steps via ``audit_every``). Raises a structured
        ``PoolCorruptionError`` — every violation plus a state dump — if
        anything is broken; returns a summary dict when clean.

        Invariants: page refcounts equal table occupancy; the mapped /
        parked / free sets are pairwise disjoint and partition the pool
        (no leaks, no double-frees); the device page table mirrors the
        host slot lists; each slot's pages are a leading shared-frozen
        registered run followed by exclusively-owned unregistered private
        pages; no active row's boundary (write-target) page is frozen;
        slabs are exclusively owned, owned + free partition the slab
        pool, and the slab table mirrors ownership."""
        from collections import Counter

        v: List[str] = []
        base = self._frozen_base
        all_ids = (list(range(self._n_pages))
                   + list(range(base, base + self._n_frozen)))
        mapped = Counter()
        for ids in self.slot_pages:
            mapped.update(ids)
        for ids in self.slot_cross:
            mapped.update(ids)
        for pid in all_ids:
            if self.page_refs[pid] != mapped.get(pid, 0):
                v.append(f"page {pid}: refcount {int(self.page_refs[pid])} "
                         f"!= {mapped.get(pid, 0)} table mappings")
        free = self.free_pages + self.free_frozen
        parked = self.reusable_pages
        if len(free) != len(set(free)):
            v.append(f"double-freed pages in the free lists: {free}")
        if any(pid >= base for pid in self.free_pages) or \
                any(pid < base for pid in self.free_frozen):
            v.append(f"free-list class mixup: active {self.free_pages} / "
                     f"frozen {self.free_frozen} (frozen base {base})")
        for kind_a, kind_b, inter in (
                ("mapped", "free", set(mapped) & set(free)),
                ("mapped", "parked", set(mapped) & set(parked)),
                ("free", "parked", set(free) & set(parked))):
            if inter:
                v.append(f"pages both {kind_a} and {kind_b}: {sorted(inter)}")
        if sorted(set(mapped) | set(free) | set(parked)) != sorted(all_ids):
            lost = set(all_ids) - set(mapped) - set(free) - set(parked)
            v.append(f"pages leaked from the pool: {sorted(lost)}")
        for slot, ids in enumerate(self.slot_pages):
            if not np.array_equal(self.page_table[slot, :len(ids)], ids):
                v.append(f"slot {slot}: page table "
                         f"{self.page_table[slot, :len(ids)].tolist()} != "
                         f"owned pages {ids}")
            for i, pid in enumerate(ids):
                if i < self.slot_shared[slot]:
                    if self._prefix is None or \
                            not self._prefix.registered(pid):
                        v.append(f"slot {slot}: shared page {pid} not "
                                 "registered in the prefix index")
                    if self._mixed and pid < base:
                        v.append(f"slot {slot}: shared page {pid} is "
                                 "active-class in a mixed-precision pool "
                                 "(freeze-time transcode missed it)")
                else:
                    if self.page_refs[pid] != 1:
                        v.append(f"slot {slot}: private page {pid} has "
                                 f"refcount {int(self.page_refs[pid])} "
                                 "(copy-on-write violated)")
                    if self._prefix is not None and \
                            self._prefix.registered(pid):
                        v.append(f"slot {slot}: private page {pid} is "
                                 "registered (would be written while "
                                 "shared-frozen)")
                    if pid >= base:
                        v.append(f"slot {slot}: private page {pid} is a "
                                 "frozen FP4 logical id — no write path "
                                 "may own a packed page")
        for slot, ids in enumerate(self.slot_cross):
            for pid in ids:
                if pid >= base:
                    v.append(f"slot {slot}: cross page {pid} is a frozen "
                             "FP4 logical id (cross pages live in their "
                             "own write-once pool)")
        if self._prefix is not None:
            for s, req in enumerate(self.active):
                if req is None:
                    continue
                bidx = int(self.lengths[s]) // self.page_size
                if bidx < len(self.slot_pages[s]) and \
                        self._prefix.registered(self.slot_pages[s][bidx]):
                    v.append(f"slot {s}: boundary (write-target) page "
                             f"{self.slot_pages[s][bidx]} is frozen")
        owned = [s for s in self.slot_slab if s >= 0]
        if len(owned) != len(set(owned)):
            v.append(f"slab double-owned: {owned}")
        if sorted(owned + self.free_slabs) != list(range(self._n_slabs)):
            v.append(f"slabs leaked: owned {sorted(owned)} + free "
                     f"{sorted(self.free_slabs)} != 0..{self._n_slabs - 1}")
        for slot in range(self.slots):
            want = self.slot_slab[slot] if self.slot_slab[slot] >= 0 \
                else self._n_slabs
            if int(self.slab_table[slot]) != want:
                v.append(f"slot {slot}: slab table "
                         f"{int(self.slab_table[slot])} != owned {want}")
        if v:
            dump = {
                "step": self._step_no, "tick": self._tick,
                "page_refs": self.page_refs.tolist(),
                "slot_pages": [list(p) for p in self.slot_pages],
                "slot_cross": [list(p) for p in self.slot_cross],
                "slot_shared": list(self.slot_shared),
                "free_pages": list(self.free_pages),
                "free_frozen": list(self.free_frozen),
                "parked_pages": list(parked),
                "slot_slab": list(self.slot_slab),
                "free_slabs": list(self.free_slabs),
                "lengths": self.lengths.tolist(),
                "active_rids": [r.rid if r is not None else None
                                for r in self.active],
            }
            raise PoolCorruptionError(v, dump)
        return {"step": self._step_no,
                "pages_mapped": len(mapped), "pages_free": len(free),
                "pages_parked": len(parked),
                "frozen_mapped": sum(1 for pid in mapped if pid >= base),
                "frozen_free": len(self.free_frozen),
                "slabs_owned": len(owned),
                "slabs_free": len(self.free_slabs),
                "active": sum(r is not None for r in self.active),
                "violations": 0}

    def utilization(self) -> float:
        """Mean fraction of slots that decoded per engine step — the number
        the token-budget scheduler raises under long-tail max_new."""
        if not self.stats["steps"]:
            return 0.0
        return self.stats["slot_steps"] / (self.stats["steps"] * self.slots)

    def engine_utilization(self) -> float:
        """Decoded tokens per jitted program launch, normalized by slot
        count — the whole-engine number the mixed step raises over the
        alternating engine. The alternating engine spends entire programs
        on serial prefill chunks that decode nothing; the mixed engine
        piggybacks those chunks on decode steps, so every launch carries
        the full decode batch. Counts every launch: encode, prefill
        chunks, decode and mixed steps."""
        if not self.stats["programs"]:
            return 0.0
        return (self.stats["decoded_tokens"]
                / (self.stats["programs"] * self.slots))

    @property
    def reusable_pages(self) -> List[int]:
        """Refcount-0 registered pages parked in the prefix cache's LRU:
        allocatable like free pages, still bit-reusable by a matching
        prefix until reclaimed."""
        return self._prefix.reusable_ids() if self._prefix is not None else []

    def prefix_hit_rate(self) -> float:
        """Fraction of prefilled-or-hit context tokens served straight
        from the prefix cache (zero prefill compute)."""
        total = self.stats["prefix_hit_tokens"] + self.stats["prefill_tokens"]
        return self.stats["prefix_hit_tokens"] / total if total else 0.0

    def kv_bytes_per_token(self) -> float:
        """Pool bytes per token slot across the whole layer stack (page
        units only) — the number the FP8 pool halves vs bf16."""
        return sum(kvc.pool_bytes_per_token(self._unit(path))
                   for path, kind in self._units if kind in ("kv", "cross"))

    def kv_bf16_bytes_per_token(self) -> float:
        return sum(kvc.bf16_bytes_per_token(self._unit(path))
                   for path, kind in self._units if kind in ("kv", "cross"))

    def cache_residency(self) -> Dict:
        """Per-class residency accounting for the mixed-precision cache:
        how many pages of each class hold live (mapped or parked-reusable)
        content, what they cost per token, and the blended bytes-per-token
        across everything resident. ``frozen_bytes_per_token`` /
        ``active_bytes_per_token`` is the page-class density ratio the
        serving bench gates (<= 0.55 for packed FP4 behind FP8)."""
        kv_units = [path for path, kind in self._units
                    if kind in ("kv", "cross")]
        page = self.page_size
        active_pb = sum(kvc.page_bytes(self._unit(p)) for p in kv_units)
        frozen_pb = sum(kvc.page_bytes(self._unit(p), frozen=True)
                        for p in kv_units)
        parked = set(self.reusable_pages)
        base = self._frozen_base
        n_active = sum(1 for pid in range(self._n_pages)
                       if self.page_refs[pid] > 0 or pid in parked)
        n_frozen = sum(1 for pid in range(base, base + self._n_frozen)
                       if self.page_refs[pid] > 0 or pid in parked)
        live_bytes = n_active * active_pb + n_frozen * frozen_pb
        tokens = (n_active + n_frozen) * page
        return {
            "n_active_live": int(n_active),
            "n_frozen_live": int(n_frozen),
            "active_bytes_per_token": active_pb / page if page else 0.0,
            "frozen_bytes_per_token": (frozen_pb / page
                                       if self._mixed else 0.0),
            "live_bytes": float(live_bytes),
            "resident_tokens": int(tokens),
            "bytes_per_token": float(live_bytes / tokens) if tokens else 0.0,
        }
