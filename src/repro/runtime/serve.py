"""Batched W4A8 serving loop over a quantized paged KV-cache pool.

Serving model: ``Server`` owns `slots` concurrent sequences (slot = batch
row). Requests join free slots; each engine step decodes one token for every
active slot. Prefill for a new request runs row-wise (batch-1) and is
*streamed into pages*: the prompt is fed through the model in page-aligned
chunks and each chunk's K/V is quantized straight into the pool
(runtime.kv_cache.append_prefill_chunk), so the engine never holds a
monolithic (slots, max_seq, ...) cache — nor even a transient per-request
max_seq scratch. This is the scheduling skeleton of a vLLM-style paged
engine adapted to fixed-shape jit programs (page table and per-slot lengths
are jit *inputs*; shapes never change -> one compiled decode step).

Scheduling (``scheduler`` knob):
  * ``"token_budget"`` (default): admission charges only the prompt's pages
    plus ``headroom_pages`` of decode headroom; every step allocates pages
    on demand as rows cross page boundaries. On pool exhaustion the
    scheduler preempts the lowest-priority running request by *stealing its
    pages*: the victim's page payload (codes + scales) is spilled to host
    memory and its pages returned to the pool, so it resumes
    token-identically — bit-identical page contents are restored into
    whatever pages are free — once capacity returns. Watermarks and a
    steal cooldown give anti-thrash hysteresis; readmission is
    longest-waiting-first, with preempted requests strictly ahead of fresh
    ones (no overtaking — fresh work cannot starve a spilled request).
  * ``"reserve"``: the legacy reserve-on-admit policy — worst-case pages
    (prompt + max_new) are reserved up front, so admitted requests never
    stall but slot utilization collapses under long-tail ``max_new``.

``kv_fmt`` selects the page payload: ``"fp8_e4m3"`` stores packed FP8 codes
with per-(page, head) M2 scales (~0.52x the bytes of bf16 -> ~2x the slot
pool per HBM byte), ``None`` keeps bf16 pages as the fallback path. Both
run the same paged decode attention with per-slot *true* lengths — rows
carry their own positions and length masks end to end.

Families whose decode state cannot be paged (enc-dec cross-attention
caches, SSM/xLSTM recurrent states) keep the legacy monolithic engine.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.models.transformer import segments_for
from repro.runtime import kv_cache as kvc

__all__ = ["Request", "Server"]


@functools.partial(jax.jit, static_argnames=("cfg", "a_fmt"))
def _decode_step_jit(params, caches, tokens, cache_index, cfg, a_fmt):
    """Module-level jitted engine step: ``cfg`` is a frozen (hashable)
    ArchConfig, so the compiled program cache is shared across Server
    instances — a restarted or side-by-side server reuses every
    prefill-chunk and decode executable instead of recompiling."""
    return models.decode_step(params, cfg, tokens, caches, cache_index,
                              a_fmt=a_fmt)


@contextlib.contextmanager
def _backend_scope(name: Optional[str]):
    """Temporarily select a kernel backend (None = leave untouched). Keeps a
    Server's backend choice scoped to its own prefill/decode tracing instead
    of leaking into every other model in the process."""
    if name is None:
        yield
        return
    from repro.kernels import ops as _kops

    prev = _kops.get_backend()
    _kops.set_backend(name)
    try:
        yield
    finally:
        _kops.set_backend(prev)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int = 16
    priority: int = 0  # higher = steal from it last; ties -> newest admitted
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    preemptions: int = 0  # times this request's pages were stolen


@dataclasses.dataclass
class _Spill:
    """A preempted request's resumable state: the exact page payload
    (codes + scales per pool leaf, all layers) at preemption time. Restoring
    these bytes into any free pages reproduces the pool state bit-exactly,
    so the resumed request generates token-identical output."""

    req: Request
    ctx_len: int  # tokens of KV spilled (prompt + generated-so-far)
    pages: List[Dict[str, np.ndarray]]  # per segment: leaf -> (L, npg, ...)
    since: int  # engine step when preempted (longest-waiting-first key)
    seq: int  # original admission sequence — age/priority is kept on resume


class Server:
    def __init__(self, params, cfg, slots: int = 4, max_seq: int = 512,
                 a_fmt: Optional[str] = "fp8_e4m3",
                 kernel_backend: Optional[str] = None,
                 kv_fmt: Optional[str] = None,
                 page_size: int = 64,
                 pool_pages: Optional[int] = None,
                 scheduler: str = "token_budget",
                 headroom_pages: int = 1,
                 low_watermark: int = 0,
                 resume_watermark: int = 1,
                 steal_cooldown: int = 2,
                 prefill_chunk_pages: int = 4):
        """``kernel_backend``: 'pallas' routes every PackedLinear matmul in
        prefill/decode through the fused single-pass W4A8 kernel, and paged
        decode attention through the flash-decoding page-gather kernel;
        'ref' forces the jnp oracles; None keeps the process-wide setting.

        ``kv_fmt``: KV page payload — 'fp8_e4m3' (packed codes +
        per-(page, head) M2 scales) or None (bf16 pages, fallback path).
        ``page_size``: tokens per page. ``pool_pages``: pool capacity in
        pages (default: slots * pages_per_slot — full backing).

        Scheduler knobs (paged engine, ``scheduler='token_budget'``):
          * ``headroom_pages``: decode headroom charged at admission on top
            of the prompt's pages — the first page boundary never stalls.
          * ``low_watermark``: pages that must stay free *after* admitting
            fresh work while other requests run (growth slack; hysteresis
            against admit-then-steal thrash).
          * ``resume_watermark``: extra free pages, beyond the spilled
            context, required to resume a preempted request while other
            requests run (hysteresis against steal/resume ping-pong).
          * ``steal_cooldown``: steps a freshly admitted/resumed request is
            protected from preemption (unless no other victim exists).
          * ``prefill_chunk_pages``: streaming-prefill chunk, in pages.
        Both watermarks are bypassed when nothing is running — the pool is
        then fully available, so progress is always made when physically
        possible."""
        if scheduler not in ("token_budget", "reserve"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        self.kernel_backend = kernel_backend
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.a_fmt = a_fmt
        self.kv_fmt = kv_fmt
        self.scheduler = scheduler
        self.headroom_pages = headroom_pages
        self.low_watermark = low_watermark
        self.resume_watermark = resume_watermark
        self.steal_cooldown = steal_cooldown
        self.prefill_chunk_pages = prefill_chunk_pages
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.preempted: List[_Spill] = []
        self.finished: List[Request] = []
        self.stats = {
            "steps": 0, "slot_steps": 0, "decoded_tokens": 0,
            "prefill_tokens": 0, "preemptions": 0, "resumes": 0,
            "pages_stolen": 0,
        }
        self._step_no = 0
        self._admit_seq = 0

        self.paged = cfg.encoder_layers == 0 and cfg.ssm is None
        if not self.paged:
            if kv_fmt is not None:
                raise ValueError(
                    f"kv_fmt={kv_fmt!r}: paged KV quantization needs pageable "
                    "decode state (enc-dec / SSM families keep bf16 caches)")
            self.caches = models.init_cache(cfg, slots, max_seq)
            self.lengths = np.zeros(slots, dtype=np.int64)
            self._decode = functools.partial(_decode_step_jit, cfg=cfg,
                                             a_fmt=a_fmt)
            return

        # ---- paged pool + host-side allocator ----------------------------
        self.page_size = page_size
        self.pages_per_slot = math.ceil(max_seq / page_size)
        n_pages = pool_pages or slots * self.pages_per_slot
        self._n_pages = n_pages
        self.pools = []
        for seg in segments_for(cfg):
            if seg.mixer == "gqa":
                pool = kvc.init_gqa_pool(seg.count, n_pages, page_size,
                                         cfg.n_kv_heads, cfg.resolved_head_dim,
                                         kv_fmt)
            elif seg.mixer == "mla":
                pool = kvc.init_mla_pool(seg.count, n_pages, page_size,
                                         cfg.mla.kv_lora_rank,
                                         cfg.mla.qk_rope_dim, kv_fmt)
            else:  # pragma: no cover — guarded by self.paged above
                raise ValueError(f"unpageable mixer {seg.mixer!r}")
            self.pools.append({"kv": pool})
        self.free_pages: List[int] = list(range(n_pages))
        self.slot_pages: List[List[int]] = [[] for _ in range(slots)]
        self.page_table = np.zeros((slots, self.pages_per_slot), np.int32)
        self.lengths = np.zeros(slots, dtype=np.int32)
        self._slot_seq = [0] * slots  # admission sequence of the occupant
        self._slot_since = [0] * slots  # step admitted/resumed (cooldown)
        self._decode = functools.partial(_decode_step_jit, cfg=cfg,
                                         a_fmt=a_fmt)

    # -- page accounting -------------------------------------------------------
    def _worst_case_pages(self, req: Request) -> int:
        """Pages this request can ever hold (prompt + max_new, max_seq cap)."""
        return kvc.pages_needed(
            min(len(req.prompt) + req.max_new, self.max_seq), self.page_size)

    def _alloc(self, slot: int, npg: int) -> List[int]:
        ids = [self.free_pages.pop(0) for _ in range(npg)]
        self.slot_pages[slot].extend(ids)
        owned = self.slot_pages[slot]
        self.page_table[slot, :len(owned)] = owned
        return ids

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        if len(req.prompt) >= self.max_seq:
            # fail fast here: the streaming prefill would otherwise run out
            # of reserved pages mid-chunk with an opaque shape error
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} must be "
                f"< max_seq={self.max_seq} (no room left to decode)")
        if self.paged and self._worst_case_pages(req) > self._n_pages:
            # fail fast on requests no retirement can ever fit
            raise ValueError(
                f"request {req.rid}: needs {self._worst_case_pages(req)} pages "
                f"but the pool has {self._n_pages}; raise pool_pages or "
                "shrink prompt/max_new")
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.slots):
            if self.active[slot] is not None:
                continue
            if not (self.preempted or self.queue):
                break
            if not self._admit_one(slot):
                break  # head of line does not fit: wait (no overtaking)

    def _admit_one(self, slot: int) -> bool:
        """Admit the next candidate into ``slot``. Preempted requests come
        strictly first (longest-waiting-first) so fresh arrivals can never
        starve a spilled request whose readmission they would outbid."""
        any_active = any(r is not None for r in self.active)
        free = len(self.free_pages)
        if not self.paged:
            req = self.queue.pop(0)
            self.active[slot] = req
            self._prefill_slot(slot, req)
            return True
        if self.scheduler == "token_budget" and self.preempted:
            spill = min(self.preempted, key=lambda sp: sp.since)
            need = min(kvc.pages_needed(spill.ctx_len, self.page_size)
                       + self.headroom_pages,
                       self._worst_case_pages(spill.req))
            margin = self.resume_watermark if any_active else 0
            if free - need < margin:
                return False
            self.preempted.remove(spill)
            self._resume(slot, spill, need)
            return True
        if not self.queue:
            return False
        req = self.queue[0]
        if self.scheduler == "reserve":
            need = self._worst_case_pages(req)
            if free < need:
                return False
        else:
            need = min(kvc.pages_needed(len(req.prompt), self.page_size)
                       + self.headroom_pages, self._worst_case_pages(req))
            margin = self.low_watermark if any_active else 0
            if free - need < margin:
                return False
        self.queue.pop(0)
        self.active[slot] = req
        self._slot_seq[slot] = self._admit_seq
        self._slot_since[slot] = self._step_no
        self._admit_seq += 1
        self._alloc(slot, need)
        self._prefill_slot(slot, req)
        return True

    # -- streaming paged prefill ----------------------------------------------
    def _prefill_slot(self, slot: int, req: Request):
        """Prefill a new request. Paged engine: stream the prompt through
        the model in page-aligned chunks, each chunk's K/V written straight
        into this slot's pages inside the jitted forward (no contiguous
        max_seq scratch cache; the page table passed per chunk is trimmed
        to the pages covering the prompt so far). Legacy engine: row-wise
        monolithic prefill spliced into the batch cache."""
        n = len(req.prompt)
        if not self.paged:
            toks = jnp.asarray([req.prompt], jnp.int32)
            with _backend_scope(self.kernel_backend):
                logits, c1 = models.prefill(self.params, self.cfg,
                                            {"tokens": toks}, self.max_seq,
                                            a_fmt=self.a_fmt)

            def splice(full, one):
                return jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=1
                )

            self.caches = jax.tree.map(splice, self.caches, c1)
            self.lengths[slot] = n
            req.out.append(int(jnp.argmax(logits[0])))
            return

        chunk = self.prefill_chunk_pages * self.page_size
        ids = self.slot_pages[slot]
        logits = None
        pos = 0
        while pos < n:
            take = min(chunk, n - pos)
            toks = jnp.asarray([req.prompt[pos: pos + take]], jnp.int32)
            w = kvc.pages_needed(pos + take, self.page_size)
            table = np.zeros((1, w), np.int32)
            table[0] = ids[:w]
            state = kvc.PagedState(jnp.asarray(table),
                                   jnp.asarray([pos], jnp.int32))
            with _backend_scope(self.kernel_backend):
                logits, pools = self._decode(self.params, self.pools,
                                             toks, state)
            self.pools = pools
            pos += take
        self.lengths[slot] = n
        self.stats["prefill_tokens"] += n
        req.out.append(int(jnp.argmax(logits[0])))

    # -- preemption by page steal ----------------------------------------------
    def _preempt(self, slot: int):
        """Steal this slot's pages: spill its page payload (codes + scales,
        bit-exact) to host memory, return the pages to the pool, and park
        the request for longest-waiting-first readmission."""
        req = self.active[slot]
        ctx_len = int(self.lengths[slot])
        npg = kvc.pages_needed(ctx_len, self.page_size)
        ids = jnp.asarray(self.slot_pages[slot][:npg], jnp.int32)
        pages = []
        for seg in self.pools:
            pool = seg["kv"]
            pages.append({name: np.asarray(leaf[:, ids])
                          for name, leaf in pool.items()})
        self.preempted.append(_Spill(req=req, ctx_len=ctx_len, pages=pages,
                                     since=self._step_no,
                                     seq=self._slot_seq[slot]))
        req.preemptions += 1
        self.stats["preemptions"] += 1
        self.stats["pages_stolen"] += len(self.slot_pages[slot])
        self.free_pages.extend(self.slot_pages[slot])
        self.slot_pages[slot] = []
        self.page_table[slot] = 0
        self.lengths[slot] = 0
        self.active[slot] = None

    def _resume(self, slot: int, spill: _Spill, need: int):
        """Restore a spilled request into fresh pages (token-identical: the
        page payload is bit-exact, and page ids are logical — attention
        only sees the page table)."""
        self.active[slot] = spill.req
        self._slot_seq[slot] = spill.seq  # keeps its original age/priority
        self._slot_since[slot] = self._step_no
        new_ids = self._alloc(slot, need)
        npg = kvc.pages_needed(spill.ctx_len, self.page_size)
        ids = jnp.asarray(new_ids[:npg], jnp.int32)
        for i, seg_pages in enumerate(spill.pages):
            pool = dict(self.pools[i]["kv"])
            for name, arr in seg_pages.items():
                pool[name] = pool[name].at[:, ids].set(jnp.asarray(arr))
            self.pools[i] = {"kv": pool}
        self.lengths[slot] = spill.ctx_len
        self.stats["resumes"] += 1

    def _steal_for(self, needer: int) -> bool:
        """Free pages by preempting the lowest-priority active request
        (ties: most recently admitted). Requests inside the steal cooldown
        are protected unless no other victim exists. The needer itself is a
        valid victim — if it is the lowest-priority request running, it is
        the one that yields."""
        cands = [s for s, r in enumerate(self.active) if r is not None]
        if not cands:
            return False
        warm = [s for s in cands
                if self._step_no - self._slot_since[s] >= self.steal_cooldown]
        pick_from = warm or cands
        victim = min(pick_from,
                     key=lambda s: (self.active[s].priority, -self._slot_seq[s]))
        self._preempt(victim)
        return True

    def _grow(self):
        """On-demand page allocation: before the decode step, every active
        row whose next token crosses into an unallocated page gets one from
        the pool — stealing from the lowest-priority request on exhaustion.
        Rows are served in priority order (then admission order), so a
        steal always benefits the higher-priority work."""
        order = sorted(
            (s for s, r in enumerate(self.active) if r is not None),
            key=lambda s: (-self.active[s].priority, self._slot_seq[s]))
        for slot in order:
            while self.active[slot] is not None:
                need_idx = int(self.lengths[slot]) // self.page_size
                if need_idx < len(self.slot_pages[slot]):
                    break
                if self.free_pages:
                    self._alloc(slot, 1)
                elif not self._steal_for(slot):
                    break  # pragma: no cover — needer itself is a candidate

    # -- retirement ----------------------------------------------------------
    def _retire(self, slot: int, req: Request):
        req.done = True
        self.active[slot] = None
        self.finished.append(req)
        if not self.paged:
            return
        # freed pages are NOT zeroed (that would rewrite the whole pool per
        # retirement): recycled pages are overwritten by the prefill stream,
        # and decode appends mask positions past the new owner's length
        # before recomputing page scales, so stale codes can never leak
        self.free_pages.extend(self.slot_pages[slot])
        self.slot_pages[slot] = []
        self.page_table[slot] = 0
        self.lengths[slot] = 0

    # -- engine step ----------------------------------------------------------
    def step(self):
        """One decode step for all active slots. The paged engine passes
        per-slot true lengths + the page table into the jitted step (per-row
        positions and length masks); the legacy engine keeps the documented
        common-index simplification. Returns True if any slot decoded."""
        self._admit()
        if self.paged and self.scheduler == "token_budget":
            self._grow()
        if not any(self.active):
            return False
        self._step_no += 1
        self.stats["steps"] += 1
        self.stats["slot_steps"] += sum(r is not None for r in self.active)
        tok = np.zeros((self.slots, 1), dtype=np.int32)
        for s, req in enumerate(self.active):
            if req is not None and req.out:
                tok[s, 0] = req.out[-1]
        with _backend_scope(self.kernel_backend):
            if self.paged:
                state = kvc.PagedState(jnp.asarray(self.page_table),
                                       jnp.asarray(self.lengths))
                logits, self.pools = self._decode(self.params, self.pools,
                                                  jnp.asarray(tok), state)
            else:
                idx = int(self.lengths.max())
                logits, self.caches = self._decode(self.params, self.caches,
                                                   jnp.asarray(tok), idx)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(nxt[s]))
            self.lengths[s] += 1
            self.stats["decoded_tokens"] += 1
            if len(req.out) >= req.max_new or self.lengths[s] >= self.max_seq - 1:
                self._retire(s, req)
        return True

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        """Step until queue, preempted set and slots are all empty; returns
        the requests finished during this call (in retirement order).

        Starvation guard: if an engine step makes no progress while work is
        still waiting (queued or preempted-but-never-resumed — e.g. the pool
        was fully stolen and nothing can be readmitted), this raises instead
        of spinning to ``max_steps`` and silently dropping the stragglers."""
        start = len(self.finished)
        for _ in range(max_steps):
            if self.step():
                continue
            if not self.queue and not self.preempted:
                break
            raise RuntimeError(
                f"serving starved: {len(self.queue)} queued + "
                f"{len(self.preempted)} preempted request(s) cannot be "
                f"(re)admitted with {len(self.free_pages)}/{self._n_pages} "
                "pool pages free and no active work to retire — the pool is "
                "too small for the waiting context (or pages leaked)")
        else:
            pending = (len(self.queue) + len(self.preempted)
                       + sum(r is not None for r in self.active))
            if pending:
                raise RuntimeError(
                    f"run_until_drained: max_steps={max_steps} exhausted "
                    f"with {pending} request(s) still pending")
        return self.finished[start:]

    # -- accounting ------------------------------------------------------------
    def utilization(self) -> float:
        """Mean fraction of slots that decoded per engine step — the number
        the token-budget scheduler raises under long-tail max_new."""
        if not self.stats["steps"]:
            return 0.0
        return self.stats["slot_steps"] / (self.stats["steps"] * self.slots)

    def kv_bytes_per_token(self) -> float:
        """Pool bytes per token slot across the whole layer stack (paged
        engine only) — the number the FP8 pool halves vs bf16."""
        assert self.paged
        return sum(kvc.pool_bytes_per_token(p["kv"]) for p in self.pools)

    def kv_bf16_bytes_per_token(self) -> float:
        assert self.paged
        return sum(kvc.bf16_bytes_per_token(p["kv"]) for p in self.pools)
