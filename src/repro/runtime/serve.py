"""Batched W4A8 serving loop over a quantized paged KV-cache pool.

Serving model: ``Server`` owns `slots` concurrent sequences (slot = batch
row). Requests join free slots; each engine step decodes one token for every
active slot. Prefill for a new request runs row-wise (batch-1) and is
*streamed into pages*: the prompt is fed through the model in page-aligned
chunks and each chunk's K/V is quantized straight into the pool
(runtime.kv_cache.append_prefill_chunk), so the engine never holds a
monolithic (slots, max_seq, ...) cache — nor even a transient per-request
max_seq scratch. This is the scheduling skeleton of a vLLM-style paged
engine adapted to fixed-shape jit programs (page table and per-slot lengths
are jit *inputs*; shapes never change -> one compiled decode step).

The paged pool is the *single* decode path — every model family runs on it:

  * decoder-only transformers (GQA and MLA attention, dense or MoE) keep
    per-layer K/V (or compressed latent) pages; MLA decode runs entirely
    inside the latent flash-decoding kernel (ops.paged_mla_decode_attn).
  * enc-dec (Whisper-style) decoders add *write-once cross pages*: the
    encoder runs once at admission, every decoder layer's cross K/V is
    quantized into immutable pages (kv_cache.write_cross_pages), and
    admission charges ``pages(prompt) + pages(encoder_seq)`` from the same
    free list.
  * recurrent families (SSM / xLSTM, and the Zamba2 hybrid's Mamba2
    backbone) hold their fixed-size decode state in *state slabs*: one
    slab per running request, allocated at admission, steal/spill-able
    exactly like pages — just never grown. The hybrid's shared-attention
    KV rides an ordinary page pool with the invocation index as the
    layer axis.

Scheduling (``scheduler`` knob):
  * ``"token_budget"`` (default): admission charges only the prompt's pages
    plus ``headroom_pages`` of decode headroom (plus the encoder pages /
    one slab where the family needs them); every step allocates pages on
    demand as rows cross page boundaries. On pool exhaustion the scheduler
    preempts the lowest-priority running request by *stealing its pages*
    (and slab): the victim's payload (codes + scales + recurrent state,
    all layers) is spilled to host memory and its pages returned to the
    pool, so it resumes token-identically — bit-identical contents are
    restored into whatever pages are free — once capacity returns.
    Watermarks and a steal cooldown give anti-thrash hysteresis;
    readmission is longest-waiting-first, with preempted requests strictly
    ahead of fresh ones (no overtaking — fresh work cannot starve a
    spilled request). Host spill residency is bounded by
    ``spill_budget_bytes``: when exceeded, the oldest spill is *evicted* —
    its request re-queues at the head of the line and re-prefills its full
    context instead of restoring bytes (host memory can no longer OOM on
    pathological steal storms).
  * ``"reserve"``: the legacy reserve-on-admit policy — worst-case pages
    (prompt + max_new) are reserved up front, so admitted requests never
    stall but slot utilization collapses under long-tail ``max_new``. Kept
    as the serving benchmark's baseline.

Streaming-prefill chunks are *bucketed*: chunk lengths and page-table
widths are padded to powers of two (pad tokens masked everywhere — page
writes, attention, logits row), so a high-entropy prompt-length workload
compiles O(log max_seq) prefill programs instead of one per distinct
(chunk_len, table_width) pair. Families with recurrent state stream exact
chunks instead (pad tokens cannot be masked out of a recurrence's carry).

``kv_fmt`` selects the page payload: ``"fp8_e4m3"`` stores packed FP8 codes
with per-(page, head) M2 scales (~0.52x the bytes of bf16 -> ~2x the slot
pool per HBM byte), ``None`` keeps bf16 pages as the fallback path. Both
run the same paged decode attention with per-slot *true* lengths — rows
carry their own positions and length masks end to end.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.models.transformer import segments_for
from repro.runtime import kv_cache as kvc

__all__ = ["Request", "Server"]


@functools.partial(jax.jit, static_argnames=("cfg", "a_fmt"))
def _decode_step_jit(params, caches, tokens, cache_index, cfg, a_fmt):
    """Module-level jitted engine step: ``cfg`` is a frozen (hashable)
    ArchConfig, so the compiled program cache is shared across Server
    instances — a restarted or side-by-side server reuses every
    prefill-chunk and decode executable instead of recompiling."""
    return models.decode_step(params, cfg, tokens, caches, cache_index,
                              a_fmt=a_fmt)


@functools.partial(jax.jit, static_argnames=("cfg", "a_fmt"))
def _encode_cross_jit(params, frames, caches, cross_table, cfg, a_fmt):
    """Enc-dec admission step: encoder forward + write-once cross pages."""
    return models.encode_cross_pages(params, cfg, frames, caches,
                                     cross_table, a_fmt=a_fmt)


@contextlib.contextmanager
def _backend_scope(name: Optional[str]):
    """Temporarily select a kernel backend (None = leave untouched). Keeps a
    Server's backend choice scoped to its own prefill/decode tracing instead
    of leaking into every other model in the process."""
    if name is None:
        yield
        return
    from repro.kernels import ops as _kops

    prev = _kops.get_backend()
    _kops.set_backend(name)
    try:
        yield
    finally:
        _kops.set_backend(prev)


def _next_pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length()


def _is_hybrid(cfg) -> bool:
    return (cfg.ssm is not None and cfg.ssm.kind == "mamba2"
            and cfg.family == "hybrid")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int = 16
    priority: int = 0  # higher = steal from it last; ties -> newest admitted
    frames: Optional[np.ndarray] = None  # enc-dec: (encoder_seq, d) embeddings
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    preemptions: int = 0  # times this request's pages were stolen
    evictions: int = 0  # times its host spill was dropped (re-prefilled)
    resume_ctx: Optional[list] = None  # evicted: full context to re-prefill


@dataclasses.dataclass
class _Spill:
    """A preempted request's resumable state: the exact page / slab payload
    (codes + scales + recurrent state per pool leaf, all layers) at
    preemption time. Restoring these bytes into any free pages/slab
    reproduces the pool state bit-exactly, so the resumed request generates
    token-identical output."""

    req: Request
    ctx_len: int  # tokens of KV spilled (prompt + generated-so-far)
    payload: List[Dict[str, np.ndarray]]  # per engine unit: leaf -> array
    nbytes: int  # host bytes this spill holds (spill_budget accounting)
    since: int  # engine step when preempted (longest-waiting-first key)
    seq: int  # original admission sequence — age/priority is kept on resume


class Server:
    def __init__(self, params, cfg, slots: int = 4, max_seq: int = 512,
                 a_fmt: Optional[str] = "fp8_e4m3",
                 kernel_backend: Optional[str] = None,
                 kv_fmt: Optional[str] = None,
                 page_size: int = 64,
                 pool_pages: Optional[int] = None,
                 pool_slabs: Optional[int] = None,
                 scheduler: str = "token_budget",
                 headroom_pages: int = 1,
                 low_watermark: int = 0,
                 resume_watermark: int = 1,
                 steal_cooldown: int = 2,
                 prefill_chunk_pages: int = 4,
                 spill_budget_bytes: Optional[int] = None):
        """``kernel_backend``: 'pallas' routes every PackedLinear matmul in
        prefill/decode through the fused single-pass W4A8 kernel, and paged
        decode attention (GQA and MLA-latent) through the flash-decoding
        page-gather kernels; 'ref' forces the jnp oracles; None keeps the
        process-wide setting.

        ``kv_fmt``: KV page payload — 'fp8_e4m3' (packed codes +
        per-(page, head) M2 scales) or None (bf16 pages, fallback path).
        Recurrent state slabs always hold exact f32 state regardless.
        ``page_size``: tokens per page. ``pool_pages``: pool capacity in
        pages (default: full backing — slots * pages per slot, plus the
        encoder pages for enc-dec). ``pool_slabs``: state slabs for
        recurrent families (default: one per slot — full backing).

        Scheduler knobs (``scheduler='token_budget'``):
          * ``headroom_pages``: decode headroom charged at admission on top
            of the prompt's pages — the first page boundary never stalls.
          * ``low_watermark``: pages that must stay free *after* admitting
            fresh work while other requests run (growth slack; hysteresis
            against admit-then-steal thrash).
          * ``resume_watermark``: extra free pages, beyond the spilled
            context, required to resume a preempted request while other
            requests run (hysteresis against steal/resume ping-pong).
          * ``steal_cooldown``: steps a freshly admitted/resumed request is
            protected from preemption (unless no other victim exists).
          * ``prefill_chunk_pages``: streaming-prefill chunk, in pages.
          * ``spill_budget_bytes``: cap on host bytes held by spills; on
            overflow the oldest spill is evicted and its request re-queued
            for a full re-prefill (None = unbounded).
        Both watermarks are bypassed when nothing is running — the pool is
        then fully available, so progress is always made when physically
        possible."""
        if scheduler not in ("token_budget", "reserve"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        self.kernel_backend = kernel_backend
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.a_fmt = a_fmt
        self.kv_fmt = kv_fmt
        self.scheduler = scheduler
        self.headroom_pages = headroom_pages
        self.low_watermark = low_watermark
        self.resume_watermark = resume_watermark
        self.steal_cooldown = steal_cooldown
        self.prefill_chunk_pages = prefill_chunk_pages
        self.spill_budget_bytes = spill_budget_bytes
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.preempted: List[_Spill] = []
        self.finished: List[Request] = []
        self.stats = {
            "steps": 0, "slot_steps": 0, "decoded_tokens": 0,
            "prefill_tokens": 0, "preemptions": 0, "resumes": 0,
            "pages_stolen": 0, "spill_evictions": 0,
        }
        self._step_no = 0
        self._admit_seq = 0
        self._spill_bytes = 0
        # distinct (padded_chunk_len, table_width) prefill signatures fed to
        # the jitted step — with a fixed cfg this IS the prefill trace
        # count, which bucketing bounds to O(log max_seq)
        self.prefill_traces: set = set()

        self._encdec = cfg.encoder_layers > 0
        self._hybrid = _is_hybrid(cfg)
        self.page_size = page_size
        self.pages_per_slot = math.ceil(max_seq / page_size)
        self._cross_pp = (kvc.pages_needed(cfg.encoder_seq, page_size)
                          if self._encdec else 0)
        self._decode = functools.partial(_decode_step_jit, cfg=cfg,
                                         a_fmt=a_fmt)

        # ---- pools: one unit per (path into the cache tree, kind) --------
        # every unit's leaves are (lead, pool_size + 1, ...): lead = stacked
        # layers (or hybrid shared-block invocations), index 1 = page/slab id
        # with the last id reserved (null page / null slab)
        self._units: List[Tuple[tuple, str]] = []
        kv_n, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        self._n_slabs = 0
        if cfg.ssm is not None:
            self._n_slabs = pool_slabs or slots
        n_pages = pool_pages or slots * (self.pages_per_slot
                                         + (self._cross_pp if self._encdec
                                            else 0))
        if self._hybrid:
            from repro.models.hybrid import n_attn_invocations
            from repro.models.ssm import init_mamba2_cache

            one = init_mamba2_cache(cfg, self._n_slabs + 1)
            mamba = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)
            self.pools = {"mamba": mamba}
            self._units.append((("mamba",), "slab"))
            n_inv = n_attn_invocations(cfg)
            if n_inv:
                self.pools["shared_kv"] = kvc.init_gqa_pool(
                    n_inv, n_pages, page_size, kv_n, hd, kv_fmt)
                self._units.append((("shared_kv",), "kv"))
        else:
            self.pools = []
            for i, seg in enumerate(segments_for(cfg)):
                seg_pools = {}
                if seg.mixer == "gqa":
                    seg_pools["kv"] = kvc.init_gqa_pool(
                        seg.count, n_pages, page_size, kv_n, hd, kv_fmt)
                    self._units.append(((i, "kv"), "kv"))
                    if seg.cross:
                        seg_pools["cross"] = kvc.init_cross_pool(
                            seg.count, n_pages, page_size, kv_n, hd, kv_fmt)
                        self._units.append(((i, "cross"), "cross"))
                elif seg.mixer == "mla":
                    seg_pools["kv"] = kvc.init_mla_pool(
                        seg.count, n_pages, page_size, cfg.mla.kv_lora_rank,
                        cfg.mla.qk_rope_dim, kv_fmt)
                    self._units.append(((i, "kv"), "kv"))
                elif seg.mixer == "xlstm_pair":
                    from repro.models.xlstm import (init_mlstm_cache,
                                                    init_slstm_cache)

                    for name, init in (("mlstm", init_mlstm_cache),
                                       ("slstm", init_slstm_cache)):
                        one = init(cfg, self._n_slabs + 1)
                        seg_pools[name] = jax.tree.map(
                            lambda a: jnp.broadcast_to(
                                a, (seg.count,) + a.shape), one)
                        self._units.append(((i, name), "slab"))
                elif seg.mixer == "mamba2":
                    from repro.models.ssm import init_mamba2_cache

                    one = init_mamba2_cache(cfg, self._n_slabs + 1)
                    seg_pools["ssm"] = jax.tree.map(
                        lambda a: jnp.broadcast_to(
                            a, (seg.count,) + a.shape), one)
                    self._units.append(((i, "ssm"), "slab"))
                else:  # pragma: no cover
                    raise ValueError(f"unknown mixer {seg.mixer!r}")
                self.pools.append(seg_pools)

        self._has_pages = any(kind in ("kv", "cross")
                              for _, kind in self._units)
        self._has_slabs = any(kind == "slab" for _, kind in self._units)
        # pristine one-slab state per slab unit: recycled slabs are reset to
        # this at allocation (pages are fully overwritten by the prefill
        # stream, but a recurrent prefill *continues* from whatever state
        # its slab holds — a previous owner's leftovers must not leak in)
        self._slab_init = {
            ui: {name: np.asarray(leaf[:, :1])
                 for name, leaf in self._unit(path).items()}
            for ui, (path, kind) in enumerate(self._units) if kind == "slab"
        }
        # (recurrent-only families hold exact f32 state slabs: there is no
        # page payload for kv_fmt to select, and the knob is simply unused)
        self._n_pages = n_pages if self._has_pages else 0
        # recurrent state cannot mask pad tokens out of its carry, so
        # slab-holding families stream exact chunk lengths instead
        self._bucket_prefill = not self._has_slabs

        self.free_pages: List[int] = list(range(self._n_pages))
        self.slot_pages: List[List[int]] = [[] for _ in range(slots)]
        self.page_table = np.full(
            (slots, max(1, self.pages_per_slot if self._has_pages else 1)),
            self._null_page, np.int32)
        self.free_slabs: List[int] = list(range(self._n_slabs))
        self.slot_slab: List[int] = [-1] * slots
        self.slab_table = np.full((slots,), self._n_slabs, np.int32)
        self.slot_cross: List[List[int]] = [[] for _ in range(slots)]
        self.cross_table = np.full((slots, max(1, self._cross_pp)),
                                   self._null_page, np.int32)
        self.enc_lengths = np.zeros((slots,), np.int32)
        self.lengths = np.zeros(slots, dtype=np.int32)
        self._slot_seq = [0] * slots  # admission sequence of the occupant
        self._slot_since = [0] * slots  # step admitted/resumed (cooldown)

    @property
    def _null_page(self) -> int:
        """The reserved null page id (index P of every page pool)."""
        return getattr(self, "_n_pages", 0)

    def _unit(self, path):
        node = self.pools
        for p in path:
            node = node[p]
        return node

    def _set_unit(self, path, value):
        node = self.pools
        for p in path[:-1]:
            node = node[p]
        node[path[-1]] = value

    # -- page accounting -------------------------------------------------------
    def _worst_case_pages(self, req: Request) -> int:
        """Pages this request can ever hold (prompt + max_new capped at
        max_seq, plus the write-once encoder pages for enc-dec)."""
        if not self._has_pages:
            return 0
        return kvc.pages_needed(
            min(len(req.prompt) + req.max_new, self.max_seq),
            self.page_size) + self._cross_pp

    def _alloc(self, slot: int, npg: int) -> List[int]:
        ids = [self.free_pages.pop(0) for _ in range(npg)]
        self.slot_pages[slot].extend(ids)
        owned = self.slot_pages[slot]
        self.page_table[slot, :len(owned)] = owned
        return ids

    def _alloc_cross(self, slot: int) -> List[int]:
        ids = [self.free_pages.pop(0) for _ in range(self._cross_pp)]
        self.slot_cross[slot] = ids
        self.cross_table[slot, :len(ids)] = ids
        return ids

    def _alloc_slab(self, slot: int, reset: bool = True) -> int:
        sid = self.free_slabs.pop(0)
        self.slot_slab[slot] = sid
        self.slab_table[slot] = sid
        if reset:  # a resume overwrites the slab with its spill right after
            ids = jnp.asarray([sid], jnp.int32)
            for ui, (path, kind) in enumerate(self._units):
                if kind != "slab":
                    continue
                pool = dict(self._unit(path))
                for name, arr in self._slab_init[ui].items():
                    pool[name] = pool[name].at[:, ids].set(jnp.asarray(arr))
                self._set_unit(path, pool)
        return sid

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        if len(req.prompt) >= self.max_seq:
            # fail fast here: the streaming prefill would otherwise run out
            # of reserved pages mid-chunk with an opaque shape error
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} must be "
                f"< max_seq={self.max_seq} (no room left to decode)")
        if self._encdec:
            if req.frames is None:
                raise ValueError(
                    f"request {req.rid}: enc-dec serving needs per-request "
                    "encoder frames (Request.frames)")
            if req.frames.shape[0] != self.cfg.encoder_seq:
                raise ValueError(
                    f"request {req.rid}: frames length {req.frames.shape[0]} "
                    f"!= encoder_seq={self.cfg.encoder_seq} (pad the input; "
                    "the encoder program is fixed-shape)")
        if self._has_pages and self._worst_case_pages(req) > self._n_pages:
            # fail fast on requests no retirement can ever fit
            raise ValueError(
                f"request {req.rid}: needs {self._worst_case_pages(req)} pages "
                f"but the pool has {self._n_pages}; raise pool_pages or "
                "shrink prompt/max_new")
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.slots):
            if self.active[slot] is not None:
                continue
            if not (self.preempted or self.queue):
                break
            if not self._admit_one(slot):
                break  # head of line does not fit: wait (no overtaking)

    def _pick_victim(self) -> Optional[int]:
        """Lowest-priority active slot (ties: most recently admitted).
        Requests inside the steal cooldown are protected unless no other
        victim exists."""
        cands = [s for s, r in enumerate(self.active) if r is not None]
        if not cands:
            return None
        warm = [s for s in cands
                if self._step_no - self._slot_since[s] >= self.steal_cooldown]
        pick_from = warm or cands
        return min(pick_from,
                   key=lambda s: (self.active[s].priority, -self._slot_seq[s]))

    def _slab_available(self, want_priority: int) -> bool:
        """True if a slab is free, or (token-budget scheduler only) one can
        be stolen for a waiter whose priority strictly beats the victim's.
        Reserve-on-admit never preempts — that is its whole contract — so
        under it slab exhaustion simply defers admission."""
        if not self._has_slabs:
            return True
        if self.free_slabs:
            return True
        if self.scheduler != "token_budget":
            return False
        victim = self._pick_victim()
        if victim is not None and self.active[victim].priority < want_priority:
            self._preempt(victim)
            return True
        return False

    def _admit_one(self, slot: int) -> bool:
        """Admit the next candidate into ``slot``. Preempted requests come
        strictly first (longest-waiting-first) so fresh arrivals can never
        starve a spilled request whose readmission they would outbid."""
        any_active = any(r is not None for r in self.active)
        free = len(self.free_pages)
        if self.scheduler == "token_budget" and self.preempted:
            spill = min(self.preempted, key=lambda sp: sp.since)
            need = 0
            if self._has_pages:
                need = min(kvc.pages_needed(spill.ctx_len, self.page_size)
                           + self.headroom_pages,
                           self._worst_case_pages(spill.req) - self._cross_pp)
                need += self._cross_pp
                margin = self.resume_watermark if any_active else 0
                if free - need < margin:
                    return False
            if not self._slab_available(spill.req.priority):
                return False
            self.preempted.remove(spill)
            self._spill_bytes -= spill.nbytes
            self._resume(slot, spill, need - self._cross_pp)
            return True
        if not self.queue:
            return False
        req = self.queue[0]
        ctx_len = len(req.resume_ctx if req.resume_ctx is not None
                      else req.prompt)
        need = 0
        if self._has_pages:
            if self.scheduler == "reserve":
                need = self._worst_case_pages(req)
                if free < need:
                    return False
            else:
                need = min(kvc.pages_needed(ctx_len, self.page_size)
                           + self.headroom_pages,
                           self._worst_case_pages(req) - self._cross_pp)
                need += self._cross_pp
                margin = self.low_watermark if any_active else 0
                if free - need < margin:
                    return False
        if not self._slab_available(req.priority):
            return False
        self.queue.pop(0)
        self.active[slot] = req
        self._slot_seq[slot] = self._admit_seq
        self._slot_since[slot] = self._step_no
        self._admit_seq += 1
        if self._has_pages:
            self._alloc(slot, need - self._cross_pp)
            if self._encdec:
                self._alloc_cross(slot)
        if self._has_slabs:
            self._alloc_slab(slot)
        self._prefill_slot(slot, req)
        return True

    # -- streaming paged prefill ----------------------------------------------
    def _state_for(self, rows, lengths, chunk_len=None):
        """Build the PagedState for ``rows`` (a slice or index list)."""
        return kvc.PagedState(
            page_table=jnp.asarray(self.page_table[rows]),
            lengths=jnp.asarray(lengths),
            chunk_len=chunk_len,
            cross_table=(jnp.asarray(self.cross_table[rows])
                         if self._encdec else None),
            enc_lengths=(jnp.asarray(self.enc_lengths[rows])
                         if self._encdec else None),
            slabs=(jnp.asarray(self.slab_table[rows])
                   if self._has_slabs else None),
        )

    def _prefill_slot(self, slot: int, req: Request):
        """Prefill a (re)admitted request: stream its context through the
        model in page-aligned chunks, each chunk's K/V written straight
        into this slot's pages inside the jitted forward (no contiguous
        max_seq scratch cache). Chunk lengths and page-table widths are
        bucketed to powers of two (pad + mask) so trace count is
        O(log max_seq); recurrent families stream exact chunks (pad tokens
        cannot be masked out of a recurrence). Enc-dec requests first run
        the encoder once, writing every decoder layer's cross K/V into the
        slot's write-once cross pages."""
        ctx = req.resume_ctx if req.resume_ctx is not None else list(req.prompt)
        fresh = req.resume_ctx is None
        req.resume_ctx = None
        n = len(ctx)
        page = self.page_size
        if self._encdec:
            frames = jnp.asarray(req.frames, jnp.float32)[None]
            table = jnp.asarray(self.cross_table[slot:slot + 1])
            with _backend_scope(self.kernel_backend):
                self.pools = _encode_cross_jit(self.params, frames,
                                               self.pools, table,
                                               cfg=self.cfg, a_fmt=self.a_fmt)
            self.enc_lengths[slot] = self.cfg.encoder_seq

        chunk = self.prefill_chunk_pages * page
        own = self.slot_pages[slot]
        logits = None
        pos = 0
        while pos < n:
            take = min(chunk, n - pos)
            if self._bucket_prefill:
                padded = min(_next_pow2(take), chunk)
                w = _next_pow2(pos // page + kvc.pages_needed(padded, page))
            else:
                padded = take
                w = (kvc.pages_needed(pos + take, page) if self._has_pages
                     else 1)
            toks = ctx[pos: pos + take] + [0] * (padded - take)
            table = np.full((1, w), self._null_page, np.int32)
            m = min(w, len(own))
            table[0, :m] = own[:m]
            # chunk_len rides along for every prefill chunk (not just
            # bucketed ones): models use it both to mask pad positions and
            # to tell a 1-token chunk apart from a decode step
            chunk_len = jnp.asarray([take], jnp.int32)
            state = self._state_for(slice(slot, slot + 1),
                                    np.asarray([pos], np.int32), chunk_len)
            state = state._replace(page_table=jnp.asarray(table))
            with _backend_scope(self.kernel_backend):
                logits, pools = self._decode(self.params, self.pools,
                                             jnp.asarray([toks], jnp.int32),
                                             state)
            self.pools = pools
            self.prefill_traces.add((padded, w))
            pos += take
        self.lengths[slot] = n
        self.stats["prefill_tokens"] += n
        if fresh:
            req.out.append(int(jnp.argmax(logits[0])))

    # -- preemption by page steal ----------------------------------------------
    def _preempt(self, slot: int):
        """Steal this slot's pages (and slab): spill its payload (codes +
        scales + recurrent state, bit-exact) to host memory, return the
        pages to the pool, and park the request for longest-waiting-first
        readmission."""
        req = self.active[slot]
        ctx_len = int(self.lengths[slot])
        npg = kvc.pages_needed(ctx_len, self.page_size)
        payload = []
        nbytes = 0
        for path, kind in self._units:
            pool = self._unit(path)
            if kind == "kv":
                ids = jnp.asarray(self.slot_pages[slot][:npg], jnp.int32)
            elif kind == "cross":
                ids = jnp.asarray(self.slot_cross[slot], jnp.int32)
            else:  # slab
                ids = jnp.asarray([self.slot_slab[slot]], jnp.int32)
            part = {name: np.asarray(leaf[:, ids])
                    for name, leaf in pool.items()}
            nbytes += sum(a.nbytes for a in part.values())
            payload.append(part)
        self.preempted.append(_Spill(req=req, ctx_len=ctx_len,
                                     payload=payload, nbytes=nbytes,
                                     since=self._step_no,
                                     seq=self._slot_seq[slot]))
        self._spill_bytes += nbytes
        req.preemptions += 1
        self.stats["preemptions"] += 1
        self.stats["pages_stolen"] += (len(self.slot_pages[slot])
                                       + len(self.slot_cross[slot]))
        self.free_pages.extend(self.slot_pages[slot])
        self.free_pages.extend(self.slot_cross[slot])
        self.slot_pages[slot] = []
        self.slot_cross[slot] = []
        self.page_table[slot] = self._null_page
        self.cross_table[slot] = self._null_page
        self.enc_lengths[slot] = 0
        if self.slot_slab[slot] >= 0:
            self.free_slabs.append(self.slot_slab[slot])
            self.slot_slab[slot] = -1
            self.slab_table[slot] = self._n_slabs
        self.lengths[slot] = 0
        self.active[slot] = None

    def _enforce_spill_budget(self):
        """ROADMAP (b): host spills are bounded. When resident spill bytes
        exceed ``spill_budget_bytes``, evict oldest-first: drop the spill's
        bytes and re-queue its request at the head of the line with its
        full context (prompt + tokens generated so far) marked for
        re-prefill — the request still finishes, token-identically, it
        just pays a prompt re-prefill instead of a byte restore.

        Runs at the top of every engine step, never from inside
        ``_preempt``: a steal can fire mid-admission (``_slab_available``),
        and evicting there would mutate ``queue``/``preempted`` under
        ``_admit_one``'s feet — the admitted request's ``queue.pop(0)``
        would pop the freshly re-queued eviction instead. Enforcing at the
        step boundary means the budget can overshoot by the spills of a
        single scheduling round, and evicted requests re-enter admission
        in the same step they are dropped."""
        if self.spill_budget_bytes is None:
            return
        evicted = []
        while (self._spill_bytes > self.spill_budget_bytes
               and self.preempted):
            sp = min(self.preempted, key=lambda s: s.since)
            self.preempted.remove(sp)
            self._spill_bytes -= sp.nbytes
            req = sp.req
            # KV context at preemption = prompt + out[:-1] (the newest token
            # was produced but not yet fed back); re-prefilling exactly that
            # context lets decode continue by feeding out[-1] as usual
            req.resume_ctx = list(req.prompt) + list(req.out[:-1])
            req.evictions += 1
            self.stats["spill_evictions"] += 1
            evicted.append(sp)
        self.queue[:0] = [sp.req for sp in sorted(evicted,
                                                  key=lambda s: s.since)]

    def _resume(self, slot: int, spill: _Spill, need_kv: int):
        """Restore a spilled request into fresh pages/slab (token-identical:
        the payload is bit-exact, and page/slab ids are logical — the model
        only sees the tables)."""
        self.active[slot] = spill.req
        self._slot_seq[slot] = spill.seq  # keeps its original age/priority
        self._slot_since[slot] = self._step_no
        new_kv: List[int] = []
        new_cross: List[int] = []
        if self._has_pages:
            new_kv = self._alloc(slot, need_kv)
            if self._encdec:
                new_cross = self._alloc_cross(slot)
                self.enc_lengths[slot] = self.cfg.encoder_seq
        if self._has_slabs:
            self._alloc_slab(slot, reset=False)  # restored from spill below
        npg = kvc.pages_needed(spill.ctx_len, self.page_size)
        for (path, kind), part in zip(self._units, spill.payload):
            if kind == "kv":
                ids = jnp.asarray(new_kv[:npg], jnp.int32)
            elif kind == "cross":
                ids = jnp.asarray(new_cross, jnp.int32)
            else:  # slab
                ids = jnp.asarray([self.slot_slab[slot]], jnp.int32)
            pool = dict(self._unit(path))
            for name, arr in part.items():
                pool[name] = pool[name].at[:, ids].set(jnp.asarray(arr))
            self._set_unit(path, pool)
        self.lengths[slot] = spill.ctx_len
        self.stats["resumes"] += 1

    def _steal_for(self, needer: int) -> bool:
        """Free pages by preempting the cooldown-aware lowest-priority
        victim (see _pick_victim). The needer itself is a valid victim —
        if it is the lowest-priority request running, it is the one that
        yields."""
        victim = self._pick_victim()
        if victim is None:
            return False
        self._preempt(victim)
        return True

    def _grow(self):
        """On-demand page allocation: before the decode step, every active
        row whose next token crosses into an unallocated page gets one from
        the pool — stealing from the lowest-priority request on exhaustion.
        Rows are served in priority order (then admission order), so a
        steal always benefits the higher-priority work."""
        if not self._has_pages:
            return
        order = sorted(
            (s for s, r in enumerate(self.active) if r is not None),
            key=lambda s: (-self.active[s].priority, self._slot_seq[s]))
        for slot in order:
            while self.active[slot] is not None:
                need_idx = int(self.lengths[slot]) // self.page_size
                if need_idx < len(self.slot_pages[slot]):
                    break
                if self.free_pages:
                    self._alloc(slot, 1)
                elif not self._steal_for(slot):
                    break  # pragma: no cover — needer itself is a candidate

    # -- retirement ----------------------------------------------------------
    def _retire(self, slot: int, req: Request):
        req.done = True
        self.active[slot] = None
        self.finished.append(req)
        # freed pages are NOT zeroed (that would rewrite the whole pool per
        # retirement): recycled pages are overwritten by the prefill stream,
        # and decode appends mask positions past the new owner's length
        # before recomputing page scales, so stale codes can never leak
        self.free_pages.extend(self.slot_pages[slot])
        self.free_pages.extend(self.slot_cross[slot])
        self.slot_pages[slot] = []
        self.slot_cross[slot] = []
        self.page_table[slot] = self._null_page
        self.cross_table[slot] = self._null_page
        self.enc_lengths[slot] = 0
        if self.slot_slab[slot] >= 0:
            self.free_slabs.append(self.slot_slab[slot])
            self.slot_slab[slot] = -1
            self.slab_table[slot] = self._n_slabs
        self.lengths[slot] = 0

    # -- engine step ----------------------------------------------------------
    def step(self):
        """One decode step for all active slots. Per-slot true lengths, the
        page table (and for enc-dec the cross table / for recurrent
        families the slab ids) ride into the jitted step as inputs —
        per-row positions and length masks, one fixed-shape program.
        Returns True if any slot decoded."""
        self._enforce_spill_budget()
        self._admit()
        if self.scheduler == "token_budget":
            self._grow()
        if not any(self.active):
            return False
        self._step_no += 1
        self.stats["steps"] += 1
        self.stats["slot_steps"] += sum(r is not None for r in self.active)
        tok = np.zeros((self.slots, 1), dtype=np.int32)
        for s, req in enumerate(self.active):
            if req is not None and req.out:
                tok[s, 0] = req.out[-1]
        state = self._state_for(slice(None), self.lengths)
        with _backend_scope(self.kernel_backend):
            logits, self.pools = self._decode(self.params, self.pools,
                                              jnp.asarray(tok), state)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(nxt[s]))
            self.lengths[s] += 1
            self.stats["decoded_tokens"] += 1
            if len(req.out) >= req.max_new or self.lengths[s] >= self.max_seq - 1:
                self._retire(s, req)
        return True

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        """Step until queue, preempted set and slots are all empty; returns
        the requests finished during this call (in retirement order).

        Starvation guard: if an engine step makes no progress while work is
        still waiting (queued or preempted-but-never-resumed — e.g. the pool
        was fully stolen and nothing can be readmitted), this raises instead
        of spinning to ``max_steps`` and silently dropping the stragglers."""
        start = len(self.finished)
        for _ in range(max_steps):
            if self.step():
                continue
            if not self.queue and not self.preempted:
                break
            raise RuntimeError(
                f"serving starved: {len(self.queue)} queued + "
                f"{len(self.preempted)} preempted request(s) cannot be "
                f"(re)admitted with {len(self.free_pages)}/{self._n_pages} "
                f"pool pages and {len(self.free_slabs)}/{self._n_slabs} "
                "slabs free and no active work to retire — the pool is "
                "too small for the waiting context (or pages leaked)")
        else:
            pending = (len(self.queue) + len(self.preempted)
                       + sum(r is not None for r in self.active))
            if pending:
                raise RuntimeError(
                    f"run_until_drained: max_steps={max_steps} exhausted "
                    f"with {pending} request(s) still pending")
        return self.finished[start:]

    # -- accounting ------------------------------------------------------------
    def utilization(self) -> float:
        """Mean fraction of slots that decoded per engine step — the number
        the token-budget scheduler raises under long-tail max_new."""
        if not self.stats["steps"]:
            return 0.0
        return self.stats["slot_steps"] / (self.stats["steps"] * self.slots)

    def kv_bytes_per_token(self) -> float:
        """Pool bytes per token slot across the whole layer stack (page
        units only) — the number the FP8 pool halves vs bf16."""
        return sum(kvc.pool_bytes_per_token(self._unit(path))
                   for path, kind in self._units if kind in ("kv", "cross"))

    def kv_bf16_bytes_per_token(self) -> float:
        return sum(kvc.bf16_bytes_per_token(self._unit(path))
                   for path, kind in self._units if kind in ("kv", "cross"))
