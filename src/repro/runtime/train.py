"""Training-loop orchestration: step function + checkpointing + resume +
straggler policy, mesh-agnostic (1-CPU smoke runs to 512-chip dry-runs).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro import models
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.steps import TrainState, make_train_step
from repro.optimizer import AdamWConfig, adamw_init

from .straggler import StragglerPolicy

__all__ = ["TrainLoopConfig", "train_loop"]


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    grad_compress: bool = False
    accum_steps: int = 1


def train_loop(cfg, data_cfg: DataConfig, opt_cfg: AdamWConfig,
               loop: TrainLoopConfig, jit: bool = True,
               on_metrics: Optional[Callable] = None):
    """Returns (final_state, history). Single-host execution path; the
    multi-pod variant swaps the data pipeline host params + jit shardings
    (launch/steps.lower_cell shows the full-mesh wiring)."""
    rng = jax.random.PRNGKey(loop.seed)
    params = models.init_params(cfg, rng)
    state = TrainState(params=params, opt=adamw_init(params, opt_cfg))

    grad_compress = None
    if loop.grad_compress:
        from .compress import make_fp8_compressor

        grad_compress = make_fp8_compressor()

    step_fn = make_train_step(cfg, opt_cfg, accum_steps=loop.accum_steps,
                              grad_compress=grad_compress)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    mgr = None
    start = 0
    if loop.ckpt_dir:
        mgr = CheckpointManager(loop.ckpt_dir, every=loop.ckpt_every)
        state, start = mgr.resume_or(state)

    data = SyntheticLM(data_cfg)
    policy = StragglerPolicy(n_hosts=data_cfg.n_hosts)
    history = []
    for step in range(start, loop.steps):
        t0 = time.time()
        batch = data.batch(step)
        state, metrics = step_fn(state, batch)
        dt = time.time() - t0
        policy.record(data_cfg.host_index, dt)
        if step % loop.log_every == 0 or step == loop.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["sec"] = round(dt, 3)
            history.append(m)
            if on_metrics:
                on_metrics(m)
        if mgr:
            mgr.maybe_save(step + 1, state)
    return state, history
