"""Asyncio streaming front-end over the paged serving engine.

``AsyncServer`` turns the synchronous step-loop engine (runtime.serve)
into a per-request token stream: ``generate(...)`` submits into the
*running* scheduler and yields a ``TokenEvent`` per decoded token as the
engine steps — continuous batching means a request submitted mid-flight
joins the next step's batch, and two requests sharing a prompt prefix
share its scale-frozen KV pages through the PR 5 prefix cache with no
extra plumbing here.

Concurrency model: one cooperative pump, no threads, no locks. The
engine is synchronous and single-owner; ``AsyncServer`` runs it from a
single asyncio task that (a) calls ``Server.step()`` — which blocks the
loop for one decode step, the latency floor of the engine — (b) drains
``Server.pop_events()`` into per-request queues, and (c) yields to the
loop so waiting generators and fresh ``generate()`` calls interleave
between steps. The pump exists only while the engine has work; it is
(re)started by the next ``generate()``. Because everything engine-side
happens on one task, no Server state is ever touched concurrently.

Starvation mirrors ``run_until_drained``: a step that makes no progress
while work still waits raises ``ServingError`` under ``strict=True``
(delivered to every waiting generator — partial tokens already streamed
stay streamed), or fails exactly the unadmittable requests under
``strict=False`` (their streams end with a ``status="failed"`` terminal
event; active rows keep decoding).

``serve_http`` exposes the same streams as a minimal OpenAI-style
``POST /v1/completions`` endpoint speaking SSE (``stream: true``) or a
single JSON body. It is stdlib-only (``asyncio.start_server`` + manual
HTTP parsing) — the container has no web framework, and the endpoint
needs exactly one route. Prompts are token-id lists (the repo has no
tokenizer); ``choices[0].text`` carries space-joined token ids.
"""
from __future__ import annotations

import asyncio
import json
from typing import AsyncIterator, Dict, List, Optional

from repro.runtime.faults import ServingError
from repro.runtime.serve import (Request, RequestResult, SamplingParams,
                                 Server, TokenEvent)

__all__ = ["AsyncServer", "serve_http"]

# terminal sentinel pushed into a stream's queue on engine-wide failure
_ABORT = object()


class AsyncServer:
    """Async streaming facade over a (synchronous) ``Server``.

    The wrapped engine must not be stepped by anyone else while the
    front-end owns it — ``AsyncServer`` switches ``collect_events`` on
    and drains the event buffer from its pump.
    """

    def __init__(self, server: Server):
        self.server = server
        server.collect_events = True
        self._queues: Dict[int, asyncio.Queue] = {}
        self._results: Dict[int, RequestResult] = {}
        self._pump_task: Optional[asyncio.Task] = None
        self._next_rid = 0

    # -- public API -----------------------------------------------------------
    async def generate(self, prompt: List[int], max_new: int = 16,
                       sampling: SamplingParams = SamplingParams(),
                       rid: Optional[int] = None, priority: int = 0,
                       frames=None,
                       ) -> AsyncIterator[TokenEvent]:
        """Submit one request and stream its TokenEvents as decoded.

        Yields one event per token (``event.token``) and finally the
        terminal event (``event.finished``; its ``status`` is the
        request's outcome — after iteration ``result(rid)`` returns the
        frozen ``RequestResult``). ``frames`` carries the encoder input
        for enc-dec engines (forwarded to ``Request.frames``; the prefix
        cache keys shared pages on its content digest). Submission raises
        the same fail-fast ValueErrors as ``Server.submit``. A failed
        request ends its stream with a ``status="failed"`` terminal event
        rather than an exception; an engine-wide strict starvation raises
        ``ServingError`` into every open stream."""
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid + 1)
        req = Request(rid=rid, prompt=list(prompt), max_new=max_new,
                      sampling=sampling, priority=priority, frames=frames)
        self.server.submit(req)  # validates; raises before any stream state
        q: asyncio.Queue = asyncio.Queue()
        self._queues[rid] = q  # no await between submit and registration,
        self._ensure_pump()    # so the pump cannot emit for rid before it
        try:
            while True:
                ev = await q.get()
                if ev is _ABORT:
                    raise self._abort_error
                yield ev
                if ev.finished:
                    self._results[rid] = req.result()
                    return
        finally:
            self._queues.pop(rid, None)

    def result(self, rid: int) -> Optional[RequestResult]:
        """The frozen result of a finished stream (None if not done)."""
        return self._results.get(rid)

    async def close(self):
        """Cancel the pump (open streams see ServingError)."""
        if self._pump_task is not None and not self._pump_task.done():
            self._pump_task.cancel()
            try:
                await self._pump_task
            except asyncio.CancelledError:
                pass
        self._pump_task = None

    # -- engine pump ----------------------------------------------------------
    def _ensure_pump(self):
        if self._pump_task is None or self._pump_task.done():
            self._pump_task = asyncio.get_running_loop().create_task(
                self._pump())

    def _has_work(self) -> bool:
        sv = self.server
        return bool(sv.queue or sv.preempted
                    or any(r is not None for r in sv.active))

    def _dispatch(self):
        for ev in self.server.pop_events():
            q = self._queues.get(ev.rid)
            if q is not None:
                q.put_nowait(ev)

    def _abort_streams(self, err: ServingError):
        self._abort_error = err
        for q in self._queues.values():
            q.put_nowait(_ABORT)

    async def _pump(self):
        """Step the engine while it has work, fanning events out to the
        per-request queues. One step per loop pass, then yield — token
        cadence is one engine step, and submissions between steps join
        the next batch (continuous batching)."""
        sv = self.server
        try:
            while self._has_work():
                progressed = sv.step()
                self._dispatch()
                if not progressed and (sv.queue or sv.preempted):
                    if sv._alloc_faulted:
                        await asyncio.sleep(0)
                        continue  # injected transient exhaustion
                    msg = ("serving starved: waiting work cannot be "
                           "(re)admitted and no active work remains "
                           "(see run_until_drained)")
                    if not sv.strict:
                        sv._fail_pending(msg)  # emits terminal events
                        self._dispatch()
                        continue
                    raise ServingError(
                        msg, pending=sv._pending_diagnostics())
                await asyncio.sleep(0)
        except ServingError as e:
            self._abort_streams(e)


# -- minimal OpenAI-style SSE endpoint ----------------------------------------

def _http_error(status: int, msg: str) -> bytes:
    body = json.dumps({"error": {"message": msg}}).encode()
    return (f"HTTP/1.1 {status} {'Bad Request' if status == 400 else 'Error'}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\nConnection: close\r\n\r\n"
            ).encode() + body


def _finish_reason(status: Optional[str]) -> str:
    # OpenAI vocabulary: "stop" = natural end, "length" = token budget
    return {"ok": "stop", "truncated": "length"}.get(status or "", "error")


async def _read_request(reader: asyncio.StreamReader):
    """Parse one HTTP/1.1 request (start line, headers, sized body)."""
    head = await reader.readuntil(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    method, path, _ = lines[0].split(" ", 2)
    headers = {}
    for ln in lines[1:]:
        if ":" in ln:
            k, v = ln.split(":", 1)
            headers[k.strip().lower()] = v.strip()
    n = int(headers.get("content-length", "0"))
    body = await reader.readexactly(n) if n else b""
    return method, path, headers, body


async def _handle(front: AsyncServer, reader: asyncio.StreamReader,
                  writer: asyncio.StreamWriter):
    try:
        try:
            method, path, _, body = await _read_request(reader)
        except (asyncio.IncompleteReadError, ValueError):
            return
        if method != "POST" or path.split("?")[0] != "/v1/completions":
            writer.write(_http_error(404, f"no route {method} {path}"))
            return
        try:
            payload = json.loads(body or b"{}")
            prompt = payload["prompt"]
            if (not isinstance(prompt, list)
                    or not all(isinstance(t, int) for t in prompt)):
                raise ValueError(
                    "prompt must be a list of token ids (no tokenizer here)")
            sampling = SamplingParams(
                temperature=float(payload.get("temperature", 0.0)),
                top_k=int(payload.get("top_k", 0)),
                top_p=float(payload.get("top_p", 1.0)),
                seed=int(payload.get("seed", 0))).validate()
            max_new = int(payload.get("max_tokens", 16))
            stream = bool(payload.get("stream", False))
            # generate() is an async generator: its submit-time ValueError
            # only surfaces at first iteration, past this except — the
            # validate() above keeps bad params a 400, not a broken stream
            gen = front.generate(prompt, max_new=max_new, sampling=sampling)
        except (KeyError, TypeError, ValueError) as e:
            writer.write(_http_error(400, str(e)))
            return

        if stream:
            # SSE: chunk-per-token, stream delimited by [DONE] + close
            # (stdlib server: Connection: close instead of chunked coding)
            writer.write(b"HTTP/1.1 200 OK\r\n"
                         b"Content-Type: text/event-stream\r\n"
                         b"Cache-Control: no-cache\r\n"
                         b"Connection: close\r\n\r\n")
            await writer.drain()
            try:
                async for ev in gen:
                    if ev.finished:
                        chunk = {"object": "text_completion.chunk",
                                 "choices": [{"index": 0, "text": "",
                                              "finish_reason":
                                              _finish_reason(ev.status)}]}
                    else:
                        chunk = {"object": "text_completion.chunk",
                                 "choices": [{"index": 0,
                                              "text": f"{ev.token} ",
                                              "token": ev.token,
                                              "index_in_stream": ev.index,
                                              "finish_reason": None}]}
                    writer.write(b"data: " + json.dumps(chunk).encode()
                                 + b"\n\n")
                    await writer.drain()
                writer.write(b"data: [DONE]\n\n")
            except ServingError as e:
                writer.write(b"data: " + json.dumps(
                    {"error": {"message": str(e)}}).encode() + b"\n\n")
        else:
            toks: List[int] = []
            status = "failed"
            try:
                async for ev in gen:
                    if ev.finished:
                        status = ev.status or "failed"
                    elif ev.token is not None:
                        toks.append(ev.token)
            except ServingError as e:
                writer.write(_http_error(500, str(e)))
                return
            out = json.dumps({
                "object": "text_completion",
                "choices": [{"index": 0,
                             "text": " ".join(str(t) for t in toks),
                             "tokens": toks,
                             "finish_reason": _finish_reason(status)}],
                "usage": {"prompt_tokens": len(prompt),
                          "completion_tokens": len(toks)}}).encode()
            writer.write(b"HTTP/1.1 200 OK\r\n"
                         b"Content-Type: application/json\r\n"
                         + f"Content-Length: {len(out)}\r\n".encode()
                         + b"Connection: close\r\n\r\n" + out)
        await writer.drain()
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass


async def serve_http(front: AsyncServer, host: str = "127.0.0.1",
                     port: int = 8000) -> asyncio.AbstractServer:
    """Start the ``/v1/completions`` endpoint; returns the asyncio server
    (caller owns its lifecycle: ``srv.close(); await srv.wait_closed()``).
    Requests hitting it concurrently batch in the shared engine — and
    share prefix KV pages when their prompts overlap."""
    return await asyncio.start_server(
        lambda r, w: _handle(front, r, w), host, port)
