"""Straggler mitigation & fault-tolerance policies (host-level logic).

On a real fleet, the failure modes are: a host stops responding (crash /
preemption), or responds slowly (straggler). The collective runtime itself
cannot proceed without every participant, so mitigation happens at the
orchestration layer:

  * heartbeat tracking with an EWMA of per-host step latencies;
  * straggler detection: latency > ``threshold`` x fleet median for
    ``patience`` consecutive steps;
  * mitigation ladder: (1) redistribute the straggler's data shard to its
    backup host (the data pipeline is stateless — `SyntheticLM.batch(step,
    host)` can be computed by ANY host), (2) if the host misses heartbeats
    entirely, evict it and trigger an ELASTIC RESTART: the job re-forms the
    mesh with the survivors and restores the topology-independent
    checkpoint (checkpoint/manager.py), resuming at the last saved step.

The policy layer is pure logic (unit-tested below in tests/test_runtime.py);
the single-process container cannot exercise real preemption, so the restart
path is validated by the elastic restore test (save on mesh A, restore on
mesh B).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

__all__ = ["HostState", "StragglerPolicy"]


@dataclasses.dataclass
class HostState:
    ewma_s: float = 0.0
    slow_streak: int = 0
    last_seen: float = 0.0
    evicted: bool = False


class StragglerPolicy:
    def __init__(self, n_hosts: int, threshold: float = 1.5, patience: int = 3,
                 heartbeat_timeout_s: float = 60.0, alpha: float = 0.3):
        self.hosts: Dict[int, HostState] = {i: HostState() for i in range(n_hosts)}
        self.threshold = threshold
        self.patience = patience
        self.timeout = heartbeat_timeout_s
        self.alpha = alpha

    # -- telemetry ----------------------------------------------------------
    def record(self, host: int, step_latency_s: float, now: Optional[float] = None):
        st = self.hosts[host]
        st.ewma_s = (
            step_latency_s if st.ewma_s == 0.0
            else self.alpha * step_latency_s + (1 - self.alpha) * st.ewma_s
        )
        st.last_seen = time.time() if now is None else now

    def _median_ewma(self) -> float:
        vals = sorted(s.ewma_s for s in self.hosts.values() if not s.evicted and s.ewma_s > 0)
        return vals[len(vals) // 2] if vals else 0.0

    # -- decisions ----------------------------------------------------------
    def stragglers(self) -> List[int]:
        med = self._median_ewma()
        out = []
        if med <= 0:
            return out
        for i, st in self.hosts.items():
            if st.evicted:
                continue
            if st.ewma_s > self.threshold * med:
                st.slow_streak += 1
            else:
                st.slow_streak = 0
            if st.slow_streak >= self.patience:
                out.append(i)
        return out

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        return [
            i for i, st in self.hosts.items()
            if not st.evicted and st.last_seen and now - st.last_seen > self.timeout
        ]

    def reassign_shard(self, straggler: int) -> int:
        """Backup host for a straggler's data shard: the next live host.
        (The stateless pipeline lets the backup compute batch(step, straggler)
        directly — no data transfer.)"""
        live = [i for i, s in self.hosts.items() if not s.evicted and i != straggler]
        assert live, "no live hosts left"
        return live[straggler % len(live)]

    def evict(self, host: int):
        self.hosts[host].evicted = True

    def live_count(self) -> int:
        return sum(not s.evicted for s in self.hosts.values())
