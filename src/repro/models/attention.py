"""GQA attention: grouped-query, rope, chunked (flash-style) softmax for long
sequences, KV-cache decode, optional cross-attention (enc-dec).

Memory strategy (CPU-compile friendly, TPU-realistic):
  * train/prefill: blockwise attention — outer scan over query chunks, inner
    scan over kv chunks with online softmax (m, l, o) accumulation. Scores
    never exceed (B, H, qc, kc). Fully-masked off-diagonal blocks are still
    computed (standard blockwise trade-off, <= 2x causal-optimal attention
    FLOPs — negligible vs GEMM FLOPs for every assigned arch; noted in
    EXPERIMENTS.md §Roofline).
  * decode: the single-token query attends to the whole cache directly
    (scores are (B, H, 1, T) — small).

SPMD design notes (validated against compiled HLO — EXPERIMENTS.md §Perf):
  * masks are built from iota + scalar block offsets — (qc, kc), no batch
    dim, no position tensors. Batch-shaped f32 masks were observed to drag
    256 MiB all-to-alls into the inner kv loop via GSPMD resharding.
  * q/k/v stay bf16 into the score einsum with f32 accumulation
    (preferred_element_type) — the MXU path; f32 casts before the loop
    double HBM + collective traffic.
  * K/V heads are replicated when n_kv < model-axis size and repeated to
    the query-head count (Megatron convention) — `jnp.repeat` of a
    replicated tensor propagates cleanly to the head-sharded layout.

``positions`` throughout is a 1-D (seq,) int32 vector (all rows share it;
no packing), used only for rope. Masks never see it.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.runtime.kv_cache import (PagedState, append_paged,
                                    append_prefill_chunk, gather_history,
                                    gather_pages)

from .layers import ParamDef, accum_dtype, apply_rope, linear, quant_act, shard_heads

__all__ = ["attn_params", "attention", "paged_cross_attention", "init_kv_cache"]

_NEG_INF = -1e30


def attn_params(cfg, d_model=None, n_heads=None, n_kv=None, head_dim=None):
    d = d_model or cfg.d_model
    h = n_heads or cfg.n_heads
    kv = n_kv or cfg.n_kv_heads
    hd = head_dim or cfg.resolved_head_dim
    dt = cfg.param_dtype
    p = {
        "wq": ParamDef((h * hd, d), ("heads", "embed"), dt),
        "wk": ParamDef((kv * hd, d), ("kv", "embed"), dt),
        "wv": ParamDef((kv * hd, d), ("kv", "embed"), dt),
        "wo": ParamDef((d, h * hd), ("embed", "heads"), dt),
    }
    if cfg.use_bias:
        p["bq"] = ParamDef((h * hd,), ("heads",), dt, "zeros")
        p["bv"] = ParamDef((kv * hd,), ("kv",), dt, "zeros")
        p["bo"] = ParamDef((d,), ("embed",), dt, "zeros")
    return p


def init_kv_cache(batch, max_seq, n_kv, head_dim, dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, max_seq, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_seq, n_kv, head_dim), dtype),
    }


def block_mask(sq: int, sk: int, q_start, k_start, causal: bool, window: int,
               kv_len=None):
    """(sq, sk) additive f32 mask from scalar block offsets (iota-based)."""
    qi = q_start + jnp.arange(sq)[:, None]
    ki = k_start + jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), jnp.bool_)
    if causal:
        ok &= ki <= qi
    if window:
        ok &= ki > qi - window
    if kv_len is not None:
        ok &= ki < kv_len
    return jnp.where(ok, 0.0, _NEG_INF).astype(jnp.float32)


def _repeat_kv(k, g: int):
    """(B, T, KV, hd) -> (B, T, KV*g, hd) by head repetition."""
    if g == 1:
        return k
    return jnp.repeat(k, g, axis=2)


def _sdpa_full(q, k, v, mask):
    """q: (B, Sq, H, hd) bf16ish, k/v: (B, Sk, H, hd), mask: (Sq, Sk) f32."""
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    s = jnp.einsum("bqhd,bthd->bhqt", q, k, preferred_element_type=accum_dtype())
    s = s.astype(jnp.float32) * scale + mask[None, None]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqt,bthd->bqhd", p.astype(v.dtype), v,
                   preferred_element_type=accum_dtype())
    return o.astype(v.dtype)


def _sdpa_chunked(q, k, v, causal, window, q_chunk, kv_chunk, q_offset=0):
    """Query-chunked attention: lax.scan over query blocks; each block runs
    a full softmax row against the WHOLE (loop-invariant) K/V.

    Single-level looping on purpose: a nested kv-block online-softmax keeps
    (m, l, o) carries and slices K/V per step — observed to make GSPMD
    reshard 100+ MiB per inner iteration under SP sharding (EXPERIMENTS.md
    §Perf). With K/V loop-invariant and heads-sharded, every einsum is local
    to the 'model' axis; peak live scores are (B, H, qc, T) f32.

    q: (B, S, H, hd); k/v: (B, T, H, hd/dv). q position i sits at absolute
    position q_offset + i; k positions start at 0.
    """
    del kv_chunk  # single-level: kept for call-site compatibility
    b, s, h, hd = q.shape
    dv = v.shape[-1]  # may differ from hd (MLA: v_head_dim != qk dim)
    t = k.shape[1]
    q_chunk = min(q_chunk, s)
    nq = -(-s // q_chunk)
    pad_q = nq * q_chunk - s
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))

    scale = 1.0 / float(hd) ** 0.5
    qs = q.reshape(b, nq, q_chunk, h, hd)

    def q_block(_, qi):
        qb = qs[:, qi]  # (B, qc, H, hd)
        sblk = jnp.einsum("bqhd,bthd->bhqt", qb, k,
                          preferred_element_type=accum_dtype()).astype(jnp.float32) * scale
        msk = block_mask(q_chunk, t, q_offset + qi * q_chunk, 0, causal, window)
        p = jax.nn.softmax(sblk + msk[None, None], axis=-1)
        ob = jnp.einsum("bhqt,bthd->bqhd", p.astype(v.dtype), v,
                        preferred_element_type=accum_dtype())
        return _, ob.astype(v.dtype)  # (B, qc, H, dv)

    _, outs = jax.lax.scan(q_block, 0, jnp.arange(nq))
    # outs: (nq, B, qc, H, dv) -> (B, S, H, dv)
    outs = jnp.moveaxis(outs, 0, 1).reshape(b, nq * q_chunk, h, dv)
    return outs[:, :s]


def _paged_chunk_attn(q, k, v, pool_layer, state, g: int, window: int):
    """Attention for one (batch-1) streaming-prefill chunk over the paged
    pool: gathered history pages + the chunk's own exact K/V inline (the
    chunk never round-trips the FP8 grid early). Shared by the pure chunk
    branch and the mixed step's prefill rows — the mask/gather math must
    stay identical so the two engines are bit-identical.

    q/k/v: (1, S, ·, hd) — the chunk's queries and fresh K/V, rope applied.
    ``state`` is the batch-1 chunk PagedState (lengths[0] = chunk start,
    page-aligned). Gathered columns at or past the start — the chunk's own
    just-written pages, or null-page fill from bucketing — are masked; only
    true history is read from pages.
    """
    s = q.shape[1]
    hist, hist_len = gather_history(pool_layer, state, s)
    start = state.lengths[0]
    kc, vc = k, v
    if hist_len:
        kc = jnp.concatenate([hist["k"].astype(k.dtype), k], 1)
        vc = jnp.concatenate([hist["v"].astype(v.dtype), v], 1)
    kf, vf = _repeat_kv(kc, g), _repeat_kv(vc, g)
    # within the chunk the mask is plain tril (a bucketed chunk's pad
    # columns are only visible to pad rows, whose outputs are discarded);
    # history columns are visible iff truly history
    ok = jnp.concatenate(
        [jnp.broadcast_to(jnp.arange(hist_len)[None, :] < start,
                          (s, hist_len)),
         jnp.tril(jnp.ones((s, s), jnp.bool_))], axis=1)
    if window:
        qi = start + jnp.arange(s)
        ki = jnp.concatenate([jnp.arange(hist_len), qi])
        ok &= ki[None, :] > qi[:, None] - window
    return _sdpa_full(q, kf, vf,
                      jnp.where(ok, 0.0, _NEG_INF).astype(jnp.float32))


def attention(
    p,
    x,
    cfg,
    positions,
    kv_cache=None,
    cache_index=None,
    a_fmt: Optional[str] = None,
    cross_kv=None,
    causal: Optional[bool] = None,
    n_heads=None,
    n_kv=None,
    head_dim=None,
):
    """Returns (out, new_kv_cache_or_None). ``positions``: (S,) int32.

    Modes (decided statically from shapes):
      * train forward: kv_cache None — chunked/full attention over x.
      * prefill: kv_cache given, s > 1 — attends within x (fresh k/v,
        assumes cache_index = 0) and writes the cache.
      * decode: kv_cache given, s == 1 — appends at cache_index, attends to
        the filled cache prefix.
      * cross attention: cross_kv = (k, v) from the encoder; no cache.
    """
    h = n_heads or cfg.n_heads
    kv = n_kv or cfg.n_kv_heads
    hd = head_dim or cfg.resolved_head_dim
    g = h // kv
    causal = cfg.causal if causal is None else causal
    b, s, _ = x.shape

    xq = quant_act(x, a_fmt)
    # head-dim layout hint (no-op off-mesh): in SP training this keeps the
    # seq-sharded residual from gathering early; on a serving mesh it pins
    # decode's (B, 1, H, hd) q to the same head partitioning the sharded
    # paged-attention shard_map consumes, avoiding a resharding round-trip
    q = linear(p["wq"], xq, p.get("bq")).reshape(b, s, h, hd)
    q = shard_heads(q)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)

    if cross_kv is not None:
        k, v = cross_kv  # (B, T, H_or_KV, hd) — precomputed by the encoder
        if k.shape[2] != h:
            k, v = _repeat_kv(k, h // k.shape[2]), _repeat_kv(v, h // v.shape[2])
        t = k.shape[1]
        msk = jnp.zeros((s, t), jnp.float32)
        o = _sdpa_full(q, k, v, msk)
        o = o.reshape(b, s, h * hd)
        return linear(p["wo"], quant_act(o, a_fmt), p.get("bo")), None

    k = linear(p["wk"], xq).reshape(b, s, kv, hd)
    v = linear(p["wv"], xq, p.get("bv")).reshape(b, s, kv, hd)
    if cfg.pos_embedding == "rope":
        k = apply_rope(k, positions, cfg.rope_theta)

    if isinstance(cache_index, PagedState):
        if cache_index.prefill is not None:
            # mixed engine step: one fused (batch-1) token row carrying one
            # decode token per slot followed by one request's bucketed
            # prefill chunk. The first ``nd`` positions split out into the
            # s == 1 decode path (slot batch restored on axis 0), the tail
            # runs the streaming-chunk path — both appends commit to
            # disjoint pages inside this same program (decode rows only
            # touch their private boundary pages, the chunk only its own
            # table; mid-prefill slots ride along with lengths zeroed, so
            # their decode append null-redirects).
            from repro.kernels import ops

            assert causal, "mixed step assumes causal decode LMs"
            assert b == 1, "mixed step is one fused token row (batch 1)"
            pre = cache_index.prefill
            dec = cache_index._replace(prefill=None)
            nd = dec.lengths.shape[0]
            k_dec = jnp.swapaxes(k[:, :nd], 0, 1)  # (nd, 1, KV, hd)
            v_dec = jnp.swapaxes(v[:, :nd], 0, 1)
            cache1 = append_paged(kv_cache, {"k": k_dec, "v": v_dec}, dec)
            new_cache = append_prefill_chunk(
                cache1, {"k": k[:, nd:], "v": v[:, nd:]}, pre)
            q_dec = jnp.swapaxes(q[:, :nd], 0, 1)
            o_dec = ops.paged_decode_attn(
                q_dec[:, 0], new_cache, dec.page_table, dec.lengths + 1,
                window=cfg.window,
            )
            o_pre = _paged_chunk_attn(q[:, nd:], k[:, nd:], v[:, nd:],
                                      new_cache, pre, g, cfg.window)
            o = jnp.concatenate(
                [jnp.swapaxes(o_dec[:, None], 0, 1).astype(x.dtype),
                 o_pre.astype(x.dtype)], axis=1)  # (1, nd + S, H, hd)
            o = o.reshape(b, s, h * hd)
            return linear(p["wo"], quant_act(o, a_fmt), p.get("bo")), new_cache
        # chunk_len distinguishes a (possibly length-1) streaming-prefill
        # chunk from a decode step: decode's append redirects lengths == 0
        # rows to the null page, which would silently drop a prompt's
        # first token if a 1-token chunk took that path
        if s == 1 and cache_index.chunk_len is None:
            # paged decode: append this token at each row's true length,
            # then run flash-decoding over the quantized page pool
            # (kernels.ops routes pallas kernel vs jnp oracle). Per-row
            # length masks replace the engine-level synchronized index.
            from repro.kernels import ops

            new_cache = append_paged(kv_cache, {"k": k, "v": v}, cache_index)
            o = ops.paged_decode_attn(
                q[:, 0], new_cache, cache_index.page_table,
                cache_index.lengths + 1, window=cfg.window,
            )
            o = o[:, None].astype(x.dtype)  # (B, 1, H, hd)
        else:
            # streaming paged prefill: write this page-aligned prompt chunk
            # straight into the pool in-graph, then attend over the gathered
            # table plus the chunk's own exact K/V (the chunk does not
            # round-trip through the page grid, matching the monolithic
            # prefill numerics). No contiguous max_seq scratch cache is ever
            # materialized; gathered columns at or past the chunk start —
            # the chunk's own pages, or null-page fill when the engine
            # bucketed the table width — are masked, so only true history
            # (token i of the gather at absolute position i < start) is read
            # from pages.
            assert causal, "streaming paged prefill assumes causal decode LMs"
            assert b == 1, "streaming paged prefill is row-wise (batch 1)"
            new_cache = append_prefill_chunk(kv_cache, {"k": k, "v": v},
                                             cache_index)
            o = _paged_chunk_attn(q, k, v, new_cache, cache_index, g,
                                  cfg.window)
        o = o.reshape(b, s, h * hd)
        out = linear(p["wo"], quant_act(o, a_fmt), p.get("bo"))
        return out, new_cache

    new_cache = None
    is_decode = kv_cache is not None and s == 1
    if kv_cache is not None:
        idx = 0 if cache_index is None else cache_index
        kc = jax.lax.dynamic_update_slice(
            kv_cache["k"], k.astype(kv_cache["k"].dtype), (0, idx, 0, 0)
        )
        vc = jax.lax.dynamic_update_slice(
            kv_cache["v"], v.astype(kv_cache["v"].dtype), (0, idx, 0, 0)
        )
        new_cache = {"k": kc, "v": vc}

    if is_decode:
        kf = _repeat_kv(new_cache["k"], g)
        vf = _repeat_kv(new_cache["v"], g)
        t = kf.shape[1]
        msk = block_mask(1, t, cache_index, 0, causal, cfg.window,
                         kv_len=cache_index + s)
        o = _sdpa_full(q, kf, vf, msk)
    else:
        kf, vf = shard_heads(_repeat_kv(k, g)), shard_heads(_repeat_kv(v, g))
        if s > cfg.attn_chunk:
            o = _sdpa_chunked(q, kf, vf, causal, cfg.window,
                              cfg.attn_chunk, cfg.attn_chunk)
        else:
            o = _sdpa_full(q, kf, vf, block_mask(s, s, 0, 0, causal, cfg.window))

    o = o.reshape(b, s, h * hd)
    out = linear(p["wo"], quant_act(o, a_fmt), p.get("bo"))
    return out, new_cache


def paged_cross_attention(p, x, cfg, positions, cross_layer,
                          state: PagedState, a_fmt: Optional[str] = None):
    """Enc-dec decoder cross-attention over *write-once* cross pages.

    The encoder ran once at admission and its per-layer K/V was quantized
    into immutable cross pages (``kv_cache.write_cross_pages``); here the
    decoder only ever reads them. Decode (s == 1) runs the same paged
    flash-decoding kernel as self-attention with ``kv_lens =
    state.enc_lengths`` — cross-attention is non-causal, so the per-row
    length mask *is* the whole mask. Prefill chunks (s > 1, batch 1) gather
    the cross pages once and attend with the encoder-length mask.

    Returns the projected output (no cache: cross pages never change).
    """
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = h // kv
    b, s, _ = x.shape
    xq = quant_act(x, a_fmt)
    q = linear(p["wq"], xq, p.get("bq")).reshape(b, s, h, hd)
    if cfg.pos_embedding == "rope":  # mirror the legacy cross path
        q = apply_rope(q, positions, cfg.rope_theta)
    if s == 1 and state.chunk_len is None:
        from repro.kernels import ops

        o = ops.paged_decode_attn(q[:, 0], cross_layer, state.cross_table,
                                  state.enc_lengths, window=0)
        o = o[:, None].astype(x.dtype)  # (B, 1, H, hd)
    else:
        assert b == 1, "streaming paged prefill is row-wise (batch 1)"
        cstate = PagedState(state.cross_table, state.enc_lengths)
        kf = gather_pages(cross_layer, "k", cstate).astype(x.dtype)
        vf = gather_pages(cross_layer, "v", cstate).astype(x.dtype)
        t = kf.shape[1]
        kf, vf = _repeat_kv(kf, g), _repeat_kv(vf, g)
        ok = jnp.arange(t)[None, :] < state.enc_lengths[:1, None]  # (1, t)
        msk = jnp.where(jnp.broadcast_to(ok, (s, t)), 0.0, _NEG_INF)
        o = _sdpa_full(q, kf, vf, msk.astype(jnp.float32))
    o = o.reshape(b, s, h * hd)
    return linear(p["wo"], quant_act(o, a_fmt), p.get("bo"))
