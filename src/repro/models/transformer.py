"""Decoder-LM assembly: generic (mixer x ffn) blocks, scanned segments.

Covers: minitron-8b, nemotron-4-340b, olmo-1b, llava-next-34b (gqa+mlp),
minicpm3-4b (mla+mlp), deepseek-v3-671b (mla + [dense mlp x3, moe x58] +
MTP), olmoe-1b-7b (gqa+moe), xlstm-125m (mlstm/slstm pairs). Whisper
(encdec.py) and Zamba2 (hybrid.py) build on the same block primitives.

Design notes:
  * layers are stacked and scanned (jax.lax.scan) so HLO size is O(1) in
    depth — essential for compiling 61..96-layer configs on the CPU host.
  * parameters are ParamDef trees (models/params.py): one builder serves
    init / dry-run ShapeDtypeStructs / PartitionSpecs.
  * `a_fmt` threads the paper's token-wise activation quantization through
    every linear; weights are swapped to PackedLinear leaves by the PTQ
    driver for W4A8 serving.
  * remat: full per-block rematerialization in train mode.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.runtime.kv_cache import PagedState, gather_slabs, scatter_slabs

from .attention import attention, attn_params, init_kv_cache, paged_cross_attention
from .layers import (ParamDef, linear, mlp, mlp_params, norm, norm_params,
                     quant_act, shard_residual)
from .mla import init_mla_cache, mla_attention, mla_params
from .moe import moe_layer, moe_params
from .ssm import init_mamba2_cache, mamba2_block, mamba2_params
from .xlstm import (
    init_mlstm_cache,
    init_slstm_cache,
    mlstm_block,
    mlstm_params,
    slstm_block,
    slstm_params,
)

__all__ = [
    "SegmentSpec",
    "segments_for",
    "build_lm",
    "lm_forward",
    "init_lm_cache",
    "lm_logits",
    "block_params",
    "block_apply",
    "init_block_cache",
]


@dataclasses.dataclass(frozen=True)
class SegmentSpec:
    mixer: str  # 'gqa' | 'mla' | 'mamba2' | 'xlstm_pair'
    ffn: str  # 'mlp' | 'moe' | 'none'
    count: int
    d_ff: int = 0  # override cfg.d_ff (deepseek dense layers)
    cross: bool = False  # decoder cross-attention (whisper)


def segments_for(cfg) -> List[SegmentSpec]:
    if cfg.ssm is not None and cfg.ssm.kind == "xlstm":
        assert cfg.n_layers % 2 == 0
        return [SegmentSpec("xlstm_pair", "none", cfg.n_layers // 2)]
    if cfg.moe is not None:
        segs = []
        if cfg.moe.n_dense_layers:
            segs.append(
                SegmentSpec(cfg.attn_kind, "mlp", cfg.moe.n_dense_layers,
                            d_ff=cfg.moe.dense_d_ff or cfg.d_ff)
            )
        segs.append(SegmentSpec(cfg.attn_kind, "moe", cfg.n_layers - cfg.moe.n_dense_layers))
        return segs
    return [SegmentSpec(cfg.attn_kind, "mlp", cfg.n_layers, cross=bool(cfg.encoder_layers))]


# ---------------------------------------------------------------------------
# Block params
# ---------------------------------------------------------------------------
def _mixer_params(cfg, kind: str, cross: bool = False):
    if kind == "gqa":
        p = {"ln": norm_params(cfg), "attn": attn_params(cfg)}
        if cross:
            p["ln_cross"] = norm_params(cfg)
            p["cross"] = attn_params(cfg)
        return p
    if kind == "mla":
        return {"ln": norm_params(cfg), "attn": mla_params(cfg)}
    if kind == "mamba2":
        return {"ln": norm_params(cfg), "mamba": mamba2_params(cfg)}
    if kind == "xlstm_pair":
        return {
            "ln_m": norm_params(cfg),
            "mlstm": mlstm_params(cfg),
            "ln_s": norm_params(cfg),
            "slstm": slstm_params(cfg),
        }
    raise ValueError(kind)


def _ffn_params(cfg, kind: str, d_ff: int = 0):
    if kind == "mlp":
        return {"ln": norm_params(cfg), "mlp": mlp_params(cfg, d_ff=d_ff or cfg.d_ff)}
    if kind == "moe":
        return {"ln": norm_params(cfg), "moe": moe_params(cfg)}
    if kind == "none":
        return {}
    raise ValueError(kind)


def block_params(cfg, seg: SegmentSpec):
    p = {"mixer": _mixer_params(cfg, seg.mixer, seg.cross)}
    f = _ffn_params(cfg, seg.ffn, seg.d_ff)
    if f:
        p["ffn"] = f
    return p


def _stack_defs(tree, n: int):
    """Prepend a ('layers', n) dim to every ParamDef leaf."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes, d.dtype, d.init, d.scale),
        tree,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


# ---------------------------------------------------------------------------
# Block apply
# ---------------------------------------------------------------------------
def block_apply(
    p,
    x,
    cfg,
    seg: SegmentSpec,
    positions,
    cache=None,
    cache_index=None,
    a_fmt=None,
    enc_out=None,
):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    nk = cfg.norm_kind
    pm = p["mixer"]
    new_cache = None
    paged = isinstance(cache_index, PagedState)

    if seg.mixer == "gqa":
        h, new_kv = attention(
            pm["attn"], norm(pm["ln"], x, nk, cfg.norm_eps), cfg, positions,
            kv_cache=None if cache is None else cache["kv"],
            cache_index=cache_index, a_fmt=a_fmt,
        )
        x = x + h
        if cache is not None:
            new_cache = dict(cache, kv=new_kv)
        if seg.cross:
            if paged:
                # write-once cross pages: the engine ran the encoder at
                # admission and quantized its K/V into cache["cross"];
                # decode and prefill chunks only ever read them
                h = paged_cross_attention(
                    pm["cross"], norm(pm["ln_cross"], x, nk, cfg.norm_eps),
                    cfg, positions, cache["cross"], cache_index, a_fmt=a_fmt,
                )
            else:
                is_decode = cache is not None and x.shape[1] == 1
                if is_decode:  # prefill computed + stored these from enc_out
                    cross_kv = (cache["cross_k"], cache["cross_v"])
                else:
                    b, t = x.shape[0], enc_out.shape[1]
                    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
                    ek = linear(pm["cross"]["wk"], enc_out).reshape(b, t, kv, hd)
                    ev = linear(pm["cross"]["wv"], enc_out, pm["cross"].get("bv")).reshape(b, t, kv, hd)
                    cross_kv = (ek, ev)
                    if cache is not None:
                        new_cache = dict(new_cache, cross_k=ek, cross_v=ev)
                h, _ = attention(
                    pm["cross"], norm(pm["ln_cross"], x, nk, cfg.norm_eps),
                    cfg, positions, a_fmt=a_fmt, cross_kv=cross_kv,
                )
            x = x + h
    elif seg.mixer == "mla":
        h, new_kv = mla_attention(
            pm["attn"], norm(pm["ln"], x, nk, cfg.norm_eps), cfg, positions,
            kv_cache=None if cache is None else cache["kv"],
            cache_index=cache_index, a_fmt=a_fmt,
        )
        x = x + h
        if cache is not None:
            new_cache = dict(cache, kv=new_kv)
    elif seg.mixer == "mamba2":
        # slab-pooled recurrent state (paged engine): leaves are
        # (n_slabs + 1, ...); gather each row's slab, step, scatter back
        mc = None if cache is None else cache["ssm"]
        if paged and cache is not None:
            mc = gather_slabs(mc, cache_index.slabs)
        h, new_ssm = mamba2_block(
            pm["mamba"], norm(pm["ln"], x, nk, cfg.norm_eps), cfg,
            cache=mc, a_fmt=a_fmt,
        )
        x = x + h
        if cache is not None:
            if paged:
                new_ssm = scatter_slabs(cache["ssm"], cache_index.slabs, new_ssm)
            new_cache = dict(cache, ssm=new_ssm)
    elif seg.mixer == "xlstm_pair":
        mlc = None if cache is None else cache["mlstm"]
        slc = None if cache is None else cache["slstm"]
        if paged and cache is not None:
            mlc = gather_slabs(mlc, cache_index.slabs)
            slc = gather_slabs(slc, cache_index.slabs)
        h, new_m = mlstm_block(
            pm["mlstm"], norm(pm["ln_m"], x, nk, cfg.norm_eps), cfg,
            cache=mlc, a_fmt=a_fmt,
        )
        x = x + h
        h, new_s = slstm_block(
            pm["slstm"], norm(pm["ln_s"], x, nk, cfg.norm_eps), cfg,
            cache=slc, a_fmt=a_fmt,
        )
        x = x + h
        if cache is not None:
            if paged:
                new_m = scatter_slabs(cache["mlstm"], cache_index.slabs, new_m)
                new_s = scatter_slabs(cache["slstm"], cache_index.slabs, new_s)
            new_cache = dict(cache, mlstm=new_m, slstm=new_s)
    else:
        raise ValueError(seg.mixer)

    if seg.ffn != "none":
        pf = p["ffn"]
        if seg.ffn == "mlp":
            x = x + mlp(pf["mlp"], norm(pf["ln"], x, nk, cfg.norm_eps), cfg, a_fmt=a_fmt)
        else:
            from .moe_a2a import get_moe_impl, moe_decode_ep, moe_layer_a2a

            kind, mesh = get_moe_impl()
            x_ln = norm(pf["ln"], x, nk, cfg.norm_eps)
            # Serving (paged) routes per-token: group_size=1 puts every
            # token in its own dispatch group, so capacity never drops an
            # assignment and each token's experts depend only on its own
            # hidden state. Batch composition — which rows share the
            # program, decode lanes vs a piggybacked prefill chunk, chunk
            # bucketing — can then never change a token's routing, which
            # is what makes serving outputs independent of batchmates and
            # the mixed engine bit-identical to the alternating one.
            # Training keeps the capacity-bounded grouped dispatch.
            gs = 1 if paged else 1024
            ok_a2a = (
                kind == "a2a" and mesh is not None and not paged
                and x.shape[1] % mesh.shape.get("model", 1) == 0
                and x.shape[0] % mesh.shape.get("data", 1) == 0
            )
            if ok_a2a:  # MTP's S-1 path etc. fall back to einsum dispatch
                h, aux = moe_layer_a2a(pf["moe"], x_ln, cfg, mesh, a_fmt=a_fmt)
            elif kind == "ep_decode" and mesh is not None:
                # serving on a mesh: replicated einsum dispatch (token-
                # identical routing), expert FFNs sharded over the stack
                h, aux = moe_decode_ep(pf["moe"], x_ln, cfg, mesh,
                                       a_fmt=a_fmt, group_size=gs)
            else:
                h, aux = moe_layer(pf["moe"], x_ln, cfg, a_fmt=a_fmt,
                                   group_size=gs)
            x = x + h
    return x, new_cache, aux


def init_block_cache(cfg, seg: SegmentSpec, batch: int, max_seq: int, enc_seq: int = 0):
    """Per-layer cache structure for one segment's block."""
    if seg.mixer == "gqa":
        c = {"kv": init_kv_cache(batch, max_seq, cfg.n_kv_heads, cfg.resolved_head_dim)}
        if seg.cross:
            kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
            c["cross_k"] = jnp.zeros((batch, enc_seq, kv, hd), jnp.bfloat16)
            c["cross_v"] = jnp.zeros((batch, enc_seq, kv, hd), jnp.bfloat16)
        return c
    if seg.mixer == "mla":
        return {"kv": init_mla_cache(cfg, batch, max_seq)}
    if seg.mixer == "mamba2":
        return {"ssm": init_mamba2_cache(cfg, batch)}
    if seg.mixer == "xlstm_pair":
        return {"mlstm": init_mlstm_cache(cfg, batch), "slstm": init_slstm_cache(cfg, batch)}
    raise ValueError(seg.mixer)


# ---------------------------------------------------------------------------
# Whole-LM build / forward
# ---------------------------------------------------------------------------
def build_lm(cfg):
    """ParamDef tree for a decoder LM (token embeddings + segments + head)."""
    d, dt = cfg.d_model, cfg.param_dtype
    p = {"embed": ParamDef((cfg.vocab_size, d), ("vocab", "embed"), dt, "embed")}
    if cfg.pos_embedding == "learned":
        p["pos_embed"] = ParamDef((cfg.max_position, d), (None, "embed"), dt, "embed")
    if cfg.frontend == "vision_patches":
        # LLaVA-style 2-layer MLP projector from the (stub) vision encoder dim
        p["mm_proj"] = {
            "fc1": ParamDef((d, 1024), ("embed", None), dt),
            "fc2": ParamDef((d, d), ("embed", None), dt),
        }
    p["segments"] = [
        _stack_defs(block_params(cfg, seg), seg.count) for seg in segments_for(cfg)
    ]
    p["final_ln"] = norm_params(cfg)
    if not cfg.tie_embeddings:
        p["lm_head"] = ParamDef((cfg.vocab_size, d), ("vocab", "embed"), dt, "embed")
    if cfg.mtp_depth:
        seg0 = segments_for(cfg)[-1]
        p["mtp"] = {
            "block": block_params(cfg, seg0),
            "ln": norm_params(cfg),
            "proj": ParamDef((d, 2 * d), ("embed", None), dt),
        }
    return p


def _embed_tokens(params, cfg, tokens, embeds_prefix=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if embeds_prefix is not None:
        if cfg.frontend == "vision_patches":
            pe = embeds_prefix
            h = jax.nn.gelu(linear(params["mm_proj"]["fc1"], pe), approximate=True)
            pe = linear(params["mm_proj"]["fc2"], h)
        else:  # audio frames arrive at d_model already (conv frontend stub)
            pe = embeds_prefix.astype(x.dtype)
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
    return x


def _segment_scan(p_stack, x, cfg, seg, positions, caches, cache_index, a_fmt, enc_out, remat):
    """Scan one segment's stacked params (and stacked caches) over depth."""

    def body(carry, layer_in):
        h, aux_acc = carry
        p_layer, cache_layer = layer_in
        h = shard_residual(h)  # sequence-parallel residual (no-op off-mesh)
        h, new_cache, aux = block_apply(
            p_layer, h, cfg, seg, positions, cache_layer, cache_index, a_fmt, enc_out
        )
        return (h, aux_acc + aux), new_cache

    if remat:
        body = jax.checkpoint(body)

    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), (p_stack, caches))
    return x, aux, new_caches


def lm_forward(
    params,
    cfg,
    tokens,
    positions=None,
    embeds_prefix=None,
    caches=None,
    cache_index=None,
    a_fmt: Optional[str] = None,
    enc_out=None,
    remat: bool = False,
):
    """Returns (hidden (B, S, d), new_caches, aux).

    caches: list (one per segment) of stacked per-layer caches, or None.
    """
    from repro.runtime.kv_cache import PagedState

    x = _embed_tokens(params, cfg, tokens, embeds_prefix)
    b, s = x.shape[:2]
    paged = isinstance(cache_index, PagedState)
    if positions is None:
        if paged and cache_index.prefill is not None:
            # mixed step: one fused batch-1 row = [one decode token per
            # slot | one bucketed prefill chunk]; positions follow suit —
            # each decode token sits at its slot's true length, chunk
            # token j at (chunk start + j)
            nd = cache_index.lengths.shape[0]
            positions = jnp.concatenate(
                [cache_index.lengths,
                 cache_index.prefill.lengths[0] + jnp.arange(s - nd)])[None]
        elif paged:  # per-row true lengths -> (B, S) positions (rope
            # broadcasts them; the synchronized-offset hack is gone)
            positions = cache_index.lengths[:, None] + jnp.arange(s)[None]
        else:
            offset = 0 if cache_index is None else cache_index
            positions = jnp.arange(s) + offset
    if cfg.pos_embedding == "learned":
        if paged:
            x = x + jnp.take(params["pos_embed"], positions, axis=0).astype(x.dtype)
        else:
            x = x + jax.lax.dynamic_slice_in_dim(
                params["pos_embed"], 0 if cache_index is None else cache_index,
                s, axis=0,
            )[None].astype(x.dtype)

    segs = segments_for(cfg)
    new_caches = []
    aux_total = jnp.zeros((), jnp.float32)
    for i, seg in enumerate(segs):
        cache_i = None if caches is None else caches[i]
        x, aux, nc = _segment_scan(
            params["segments"][i], x, cfg, seg, positions, cache_i, cache_index,
            a_fmt, enc_out, remat,
        )
        aux_total = aux_total + aux
        new_caches.append(nc)
    x = norm(params["final_ln"], x, cfg.norm_kind, cfg.norm_eps)
    return x, (new_caches if caches is not None else None), aux_total


def init_lm_cache(cfg, batch: int, max_seq: int, enc_seq: int = 0):
    """Stacked caches per segment (leading dim = layer count)."""
    caches = []
    for seg in segments_for(cfg):
        one = init_block_cache(cfg, seg, batch, max_seq, enc_seq)
        caches.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (seg.count,) + a.shape), one))
    return caches


def lm_logits(params, cfg, hidden):
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    from .layers import accum_dtype

    return jax.lax.dot_general(
        hidden, w, (((hidden.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=accum_dtype(),
    ).astype(jnp.float32)
