"""Whisper-style encoder-decoder (whisper-tiny backbone).

The audio conv frontend is a STUB per the assignment: `input_specs()`
supplies precomputed frame embeddings (B, encoder_seq, d_model) — the output
the two-conv mel frontend would produce. Everything downstream (encoder
blocks, decoder self+cross attention, LM head) is real and quantizable.

Encoder: pre-LN transformer, learned positions, non-causal.
Decoder: pre-LN transformer, learned positions, causal self-attn + cross.
"""
from __future__ import annotations

from typing import Optional

import dataclasses
import jax
import jax.numpy as jnp

from repro.runtime.kv_cache import PagedState, write_cross_pages

from .layers import ParamDef, linear, norm, norm_params
from .transformer import (
    SegmentSpec,
    _segment_scan,
    _stack_defs,
    block_params,
    init_block_cache,
    lm_logits,
)

__all__ = ["build_encdec", "encode", "encode_cross_pages", "encdec_forward",
           "init_encdec_cache"]


def _enc_seg(cfg) -> SegmentSpec:
    return SegmentSpec("gqa", "mlp", cfg.encoder_layers)


def _dec_seg(cfg) -> SegmentSpec:
    return SegmentSpec("gqa", "mlp", cfg.n_layers, cross=True)


def build_encdec(cfg):
    d, dt = cfg.d_model, cfg.param_dtype
    return {
        "enc_pos": ParamDef((cfg.encoder_seq, d), (None, "embed"), dt, "embed"),
        "encoder": _stack_defs(block_params(cfg, _enc_seg(cfg)), cfg.encoder_layers),
        "enc_ln": norm_params(cfg),
        "embed": ParamDef((cfg.vocab_size, d), ("vocab", "embed"), dt, "embed"),
        "pos_embed": ParamDef((cfg.max_position, d), (None, "embed"), dt, "embed"),
        "decoder": _stack_defs(block_params(cfg, _dec_seg(cfg)), cfg.n_layers),
        "final_ln": norm_params(cfg),
        # whisper ties the output head to the token embedding
    }


def encode(params, cfg, frames, a_fmt: Optional[str] = None, remat: bool = False):
    """frames: (B, encoder_seq, d) stub embeddings -> (B, T_enc, d)."""
    b, t, _ = frames.shape
    frames = frames.astype(jnp.dtype(cfg.param_dtype))
    x = frames + params["enc_pos"][None, :t].astype(frames.dtype)
    positions = jnp.arange(t)
    enc_cfg = dataclasses.replace(cfg, causal=False, pos_embedding="learned_applied")
    x, _, _ = _segment_scan(
        params["encoder"], x, enc_cfg, _enc_seg(cfg), positions, None, None, a_fmt, None, remat
    )
    return norm(params["enc_ln"], x, cfg.norm_kind, cfg.norm_eps)


def encode_cross_pages(params, cfg, frames, caches, cross_table,
                       a_fmt: Optional[str] = None):
    """Run the encoder once and quantize every decoder layer's cross K/V
    into its *write-once* cross pages (the paged engine's admission step).

    frames: (1, T_enc, d) stub frame embeddings; caches: the paged cache
    list — ``caches[0]["cross"]`` holds the decoder's cross pool, leaves
    (L, P+1, page, KV, hd); cross_table: (1, cross_pp) page ids reserved
    for this request. Returns the cache list with the cross pool written;
    the pages are never touched again for the request's lifetime (decode
    only reads them — see kv_cache.init_cross_pool).
    """
    enc_out = encode(params, cfg, frames, a_fmt=a_fmt)  # (1, T_enc, d)
    b, t = enc_out.shape[:2]
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim

    def body(_, xs):
        p_layer, pool_layer = xs
        pc = p_layer["mixer"]["cross"]
        ek = linear(pc["wk"], enc_out).reshape(b, t, kv, hd)
        ev = linear(pc["wv"], enc_out, pc.get("bv")).reshape(b, t, kv, hd)
        return _, write_cross_pages(pool_layer, {"k": ek, "v": ev},
                                    cross_table)

    cross = caches[0]["cross"]
    _, new_cross = jax.lax.scan(body, 0, (params["decoder"], cross))
    return [dict(caches[0], cross=new_cross)]


def encdec_forward(
    params,
    cfg,
    tokens,
    enc_out,
    caches=None,
    cache_index=None,
    a_fmt: Optional[str] = None,
    remat: bool = False,
):
    """Decoder pass. Returns (hidden, new_caches, aux)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if isinstance(cache_index, PagedState):
        # per-row true lengths -> (B, S) positions (each slot decodes at
        # its own depth; no synchronized offset)
        positions = cache_index.lengths[:, None] + jnp.arange(s)[None]
        x = x + jnp.take(params["pos_embed"], positions, axis=0).astype(x.dtype)
    else:
        offset = 0 if cache_index is None else cache_index
        positions = jnp.arange(s) + offset
        x = x + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], offset, s, axis=0)[None].astype(x.dtype)
    dec_cfg = dataclasses.replace(cfg, pos_embedding="learned_applied")
    paged = isinstance(cache_index, PagedState)
    seg_caches = caches[0] if (paged and caches is not None) else caches
    x, aux, new_caches = _segment_scan(
        params["decoder"], x, dec_cfg, _dec_seg(cfg), positions, seg_caches,
        cache_index, a_fmt, enc_out, remat,
    )
    x = norm(params["final_ln"], x, cfg.norm_kind, cfg.norm_eps)
    return x, ([new_caches] if paged else new_caches), aux


def init_encdec_cache(cfg, batch: int, max_seq: int):
    one = init_block_cache(cfg, _dec_seg(cfg), batch, max_seq, enc_seq=cfg.encoder_seq)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)
