"""Whisper-style encoder-decoder (whisper-tiny backbone).

The audio conv frontend is a STUB per the assignment: `input_specs()`
supplies precomputed frame embeddings (B, encoder_seq, d_model) — the output
the two-conv mel frontend would produce. Everything downstream (encoder
blocks, decoder self+cross attention, LM head) is real and quantizable.

Encoder: pre-LN transformer, learned positions, non-causal.
Decoder: pre-LN transformer, learned positions, causal self-attn + cross.
"""
from __future__ import annotations

from typing import Optional

import dataclasses
import jax
import jax.numpy as jnp

from .layers import ParamDef, norm, norm_params
from .transformer import (
    SegmentSpec,
    _segment_scan,
    _stack_defs,
    block_params,
    init_block_cache,
    lm_logits,
)

__all__ = ["build_encdec", "encode", "encdec_forward", "init_encdec_cache"]


def _enc_seg(cfg) -> SegmentSpec:
    return SegmentSpec("gqa", "mlp", cfg.encoder_layers)


def _dec_seg(cfg) -> SegmentSpec:
    return SegmentSpec("gqa", "mlp", cfg.n_layers, cross=True)


def build_encdec(cfg):
    d, dt = cfg.d_model, cfg.param_dtype
    return {
        "enc_pos": ParamDef((cfg.encoder_seq, d), (None, "embed"), dt, "embed"),
        "encoder": _stack_defs(block_params(cfg, _enc_seg(cfg)), cfg.encoder_layers),
        "enc_ln": norm_params(cfg),
        "embed": ParamDef((cfg.vocab_size, d), ("vocab", "embed"), dt, "embed"),
        "pos_embed": ParamDef((cfg.max_position, d), (None, "embed"), dt, "embed"),
        "decoder": _stack_defs(block_params(cfg, _dec_seg(cfg)), cfg.n_layers),
        "final_ln": norm_params(cfg),
        # whisper ties the output head to the token embedding
    }


def encode(params, cfg, frames, a_fmt: Optional[str] = None, remat: bool = False):
    """frames: (B, encoder_seq, d) stub embeddings -> (B, T_enc, d)."""
    b, t, _ = frames.shape
    frames = frames.astype(jnp.dtype(cfg.param_dtype))
    x = frames + params["enc_pos"][None, :t].astype(frames.dtype)
    positions = jnp.arange(t)
    enc_cfg = dataclasses.replace(cfg, causal=False, pos_embedding="learned_applied")
    x, _, _ = _segment_scan(
        params["encoder"], x, enc_cfg, _enc_seg(cfg), positions, None, None, a_fmt, None, remat
    )
    return norm(params["enc_ln"], x, cfg.norm_kind, cfg.norm_eps)


def encdec_forward(
    params,
    cfg,
    tokens,
    enc_out,
    caches=None,
    cache_index=None,
    a_fmt: Optional[str] = None,
    remat: bool = False,
):
    """Decoder pass. Returns (hidden, new_caches, aux)."""
    b, s = tokens.shape
    offset = 0 if cache_index is None else cache_index
    positions = jnp.arange(s) + offset
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + jax.lax.dynamic_slice_in_dim(params["pos_embed"], offset, s, axis=0)[None].astype(x.dtype)
    dec_cfg = dataclasses.replace(cfg, pos_embedding="learned_applied")
    x, aux, new_caches = _segment_scan(
        params["decoder"], x, dec_cfg, _dec_seg(cfg), positions, caches, cache_index,
        a_fmt, enc_out, remat,
    )
    x = norm(params["final_ln"], x, cfg.norm_kind, cfg.norm_eps)
    return x, new_caches, aux


def init_encdec_cache(cfg, batch: int, max_seq: int):
    one = init_block_cache(cfg, _dec_seg(cfg), batch, max_seq, enc_seq=cfg.encoder_seq)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)
