"""Multi-head Latent Attention (DeepSeek-V2/V3, MiniCPM3).

Two execution forms, chosen statically by mode:
  * train/prefill: materialized — expand the compressed latent into
    per-head K/V and run standard chunked attention (cheapest at large S).
  * decode: absorbed — the k_up projection is folded into the query and
    v_up into the output, so attention runs in the (kv_lora_rank +
    qk_rope_dim)-dim latent space against the *compressed* cache. The cache
    stores only (c_kv, k_rope): (kv_lora_rank + qk_rope_dim) per token per
    layer — MLA's whole point for serving.

All projections are quantizable linears (the paper's W4A8 path applies).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.runtime.kv_cache import (PagedState, append_paged,
                                    append_prefill_chunk, gather_history)

from .layers import (ParamDef, PackedLinear, accum_dtype, apply_rope, as_dense,
                     batched_linear, linear, norm, packed_head_view, quant_act,
                     shard_heads)
from .attention import block_mask, _sdpa_chunked, _sdpa_full

__all__ = ["mla_params", "mla_attention", "init_mla_cache"]


def mla_params(cfg):
    d, dt = cfg.d_model, cfg.param_dtype
    m = cfg.mla
    h = cfg.n_heads
    dq = m.qk_nope_dim + m.qk_rope_dim
    p = {}
    if m.q_lora_rank:
        p["wq_a"] = ParamDef((m.q_lora_rank, d), ("lora", "embed"), dt)
        p["q_norm"] = {"scale": ParamDef((m.q_lora_rank,), ("lora",), dt, "ones")}
        p["wq_b"] = ParamDef((h * dq, m.q_lora_rank), ("heads", "lora"), dt)
    else:
        p["wq"] = ParamDef((h * dq, d), ("heads", "embed"), dt)
    p["wkv_a"] = ParamDef((m.kv_lora_rank + m.qk_rope_dim, d), ("lora", "embed"), dt)
    p["kv_norm"] = {"scale": ParamDef((m.kv_lora_rank,), ("lora",), dt, "ones")}
    p["wk_b"] = ParamDef((h * m.qk_nope_dim, m.kv_lora_rank), ("heads", "lora"), dt)
    p["wv_b"] = ParamDef((h * m.v_head_dim, m.kv_lora_rank), ("heads", "lora"), dt)
    p["wo"] = ParamDef((d, h * m.v_head_dim), ("embed", "heads"), dt)
    return p


def init_mla_cache(cfg, batch, max_seq, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_seq, m.qk_rope_dim), dtype),
    }


def _project_q(p, xq, cfg):
    m, h = cfg.mla, cfg.n_heads
    dq = m.qk_nope_dim + m.qk_rope_dim
    if "wq_a" in p:
        ql = linear(p["wq_a"], xq)
        ql = norm(p["q_norm"], ql, "rmsnorm", cfg.norm_eps)
        q = linear(p["wq_b"], ql)
    else:
        q = linear(p["wq"], xq)
    b, s = xq.shape[:2]
    q = q.reshape(b, s, h, dq)
    return q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]


def mla_attention(
    p,
    x,
    cfg,
    positions,
    kv_cache=None,
    cache_index=None,
    a_fmt: Optional[str] = None,
):
    """Returns (out, new_cache_or_None)."""
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    scale_dim = m.qk_nope_dim + m.qk_rope_dim

    xq = quant_act(x, a_fmt)
    q_nope, q_rope = _project_q(p, xq, cfg)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = linear(p["wkv_a"], xq)  # (B, S, r + dr)
    c_kv = norm(p["kv_norm"], kv_a[..., : m.kv_lora_rank], "rmsnorm", cfg.norm_eps)
    k_rope = kv_a[..., m.kv_lora_rank :][:, :, None, :]  # (B, S, 1, dr)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]

    new_cache = None
    paged = isinstance(cache_index, PagedState)
    # the absorbed form serves both paged modes: single-token decode and
    # the streaming prefill chunk (s > 1) — its einsums are s-generic
    is_decode = kv_cache is not None and (s == 1 or paged)
    if paged:
        # paged decode / streaming prefill chunk: append the compressed
        # latent + rope key at each row's true position (one token) or the
        # whole page-aligned chunk. Single-token decode then runs entirely
        # inside the latent flash-decoding kernel (ops.paged_mla_decode_attn
        # — KV = 1 head, k = concat(ckv, krope), v = the ckv view); the
        # chunk path attends the dequantized page gather in jnp.
        if cache_index.prefill is not None:
            # mixed engine step: the fused batch-1 row is [one decode token
            # per slot | one request's bucketed prefill chunk]. Decode
            # latents split out onto axis 0 and append at each slot's true
            # position (mid-prefill slots have lengths zeroed, so their
            # append null-redirects); the chunk tail appends page-aligned.
            # The appends target disjoint pages, so committing both in one
            # program preserves every pool invariant.
            pre = cache_index.prefill
            nd = cache_index.lengths.shape[0]
            dec = cache_index._replace(prefill=None)
            ckv_dec = jnp.swapaxes(c_kv[:, :nd], 0, 1)  # (nd, 1, r)
            kr_dec = jnp.swapaxes(k_rope[:, :nd], 0, 1)
            cache1 = append_paged(
                kv_cache, {"ckv": ckv_dec, "krope": kr_dec}, dec)
            new_cache = append_prefill_chunk(
                cache1, {"ckv": c_kv[:, nd:], "krope": k_rope[:, nd:]}, pre)
            sc = s - nd
            hist, hist_len = gather_history(new_cache, pre, sc)
            start = pre.lengths[0]
            ckv = c_kv[:, nd:].astype(jnp.bfloat16)
            krope = k_rope[:, nd:].astype(jnp.bfloat16)
            if hist_len:
                ckv = jnp.concatenate(
                    [hist["ckv"].astype(jnp.bfloat16), ckv], axis=1)
                krope = jnp.concatenate(
                    [hist["krope"].astype(jnp.bfloat16), krope], axis=1)
            ok = jnp.concatenate(
                [jnp.broadcast_to(jnp.arange(hist_len)[None, :] < start,
                                  (sc, hist_len)),
                 jnp.tril(jnp.ones((sc, sc), jnp.bool_))], axis=1)
            pmsk4 = jnp.where(ok, 0.0, -1e30)[None, None].astype(jnp.float32)
        elif s == 1 and cache_index.chunk_len is None:
            new_cache = append_paged(
                kv_cache, {"ckv": c_kv, "krope": k_rope}, cache_index
            )
        else:
            # streaming prefill: write the page-aligned chunk in-graph, then
            # attend over the gathered table + the chunk's own exact latents
            # (no page-grid round trip for the chunk itself). Gathered
            # columns at or past the chunk start — the chunk's own pages or
            # bucketed null-page fill — are masked; true history key i sits
            # at absolute position i < start, always causally visible. The
            # chunk masks plain tril (bucketed pad columns are only visible
            # to pad rows, whose outputs are discarded).
            assert b == 1, "streaming paged prefill is row-wise (batch 1)"
            new_cache = append_prefill_chunk(
                kv_cache, {"ckv": c_kv, "krope": k_rope}, cache_index
            )
            hist, hist_len = gather_history(new_cache, cache_index, s)
            start = cache_index.lengths[0]
            ckv = c_kv.astype(jnp.bfloat16)
            krope = k_rope.astype(jnp.bfloat16)
            if hist_len:
                ckv = jnp.concatenate(
                    [hist["ckv"].astype(jnp.bfloat16), ckv], axis=1)
                krope = jnp.concatenate(
                    [hist["krope"].astype(jnp.bfloat16), krope], axis=1)
            ok = jnp.concatenate(
                [jnp.broadcast_to(jnp.arange(hist_len)[None, :] < start,
                                  (s, hist_len)),
                 jnp.tril(jnp.ones((s, s), jnp.bool_))], axis=1)
            pmsk4 = jnp.where(ok, 0.0, -1e30)[None, None].astype(jnp.float32)
    elif kv_cache is not None:
        idx = 0 if cache_index is None else cache_index
        ckv_c = jax.lax.dynamic_update_slice(
            kv_cache["ckv"], c_kv.astype(kv_cache["ckv"].dtype), (0, idx, 0)
        )
        kr_c = jax.lax.dynamic_update_slice(
            kv_cache["krope"], k_rope.astype(kv_cache["krope"].dtype), (0, idx, 0)
        )
        new_cache = {"ckv": ckv_c, "krope": kr_c}

    if is_decode:
        # ---- absorbed form against the compressed cache -------------------
        if not paged:
            ckv = new_cache["ckv"]  # (B, T, r) bf16
            krope = new_cache["krope"]  # (B, T, dr)
        # q absorbed into latent space: (B, S, H, r). The projection
        # contracts wk_b's *out* rows (per head), so a packed weight runs
        # the batched fused kernel in transposed orientation — no densify.
        if isinstance(p["wk_b"], PackedLinear):
            wk_v = packed_head_view(p["wk_b"], h)  # (H, nope, r) packed
            q_h = jnp.moveaxis(q_nope, 2, 0).reshape(h, b * s, m.qk_nope_dim)
            q_lat = batched_linear(wk_v, q_h, transpose_w=True, quantize_acts=False)
            q_lat = jnp.moveaxis(
                q_lat.reshape(h, b, s, m.kv_lora_rank), 0, 2).astype(x.dtype)
        else:
            wk_b = as_dense(p["wk_b"], x.dtype).reshape(h, m.qk_nope_dim, m.kv_lora_rank)
            # batch-major einsum outputs (hbsr) — the CPU DotThunk rejects
            # bf16xbf16->f32 dots whose output interleaves batch dims
            q_lat = jnp.moveaxis(
                jnp.einsum("bshn,hnr->hbsr", q_nope, wk_b,
                           preferred_element_type=accum_dtype()), 0, 2
            ).astype(x.dtype)
        if paged and cache_index.prefill is not None:
            # mixed step: decode rows run the latent flash-decoding kernel
            # exactly as a pure decode step (same shapes, same inputs — the
            # token streams stay bit-identical), the chunk tail runs the
            # masked einsum over the gathered history built above
            from repro.kernels import ops

            q_lat_d = shard_heads(jnp.swapaxes(q_lat[:, :nd], 0, 1))
            q_rope_d = shard_heads(jnp.swapaxes(q_rope[:, :nd], 0, 1))
            ctx_dec = ops.paged_mla_decode_attn(
                q_lat_d[:, 0], q_rope_d[:, 0], new_cache,
                dec.page_table, dec.lengths + 1,
                scale=1.0 / float(scale_dim) ** 0.5,
            )  # (nd, H, r)
            s_lat = jnp.einsum(
                "bshr,btr->bhst", q_lat[:, nd:], ckv,
                preferred_element_type=accum_dtype()).astype(jnp.float32)
            s_rope = jnp.einsum(
                "bshr,btr->bhst", q_rope[:, nd:], krope.astype(q_rope.dtype),
                preferred_element_type=accum_dtype()).astype(jnp.float32)
            att = jax.nn.softmax(
                (s_lat + s_rope) / jnp.sqrt(scale_dim) + pmsk4, axis=-1)
            ctx_pre = jnp.moveaxis(
                jnp.einsum("bhst,btr->bhsr", att.astype(ckv.dtype), ckv,
                           preferred_element_type=accum_dtype()), 1, 2)
            ctx_lat = jnp.concatenate(
                [jnp.swapaxes(ctx_dec[:, None], 0, 1).astype(x.dtype),
                 ctx_pre.astype(x.dtype)], axis=1)  # (1, nd + S, H, r)
        elif paged and s == 1 and cache_index.chunk_len is None:
            # latent flash decoding over the page pool: the gather, FP8
            # dequant, score concat and online softmax all happen inside
            # the kernel (ref backend: the jnp oracle with identical
            # semantics) — no dequantized (B, T, r) latent gather in HBM
            from repro.kernels import ops

            # absorbed heads shard over 'model' on a serving mesh (the
            # latent pages themselves replicate — no head axis); these
            # hints are no-ops off-mesh
            q_lat = shard_heads(q_lat)
            q_rope = shard_heads(q_rope)
            ctx_lat = ops.paged_mla_decode_attn(
                q_lat[:, 0], q_rope[:, 0], new_cache,
                cache_index.page_table, cache_index.lengths + 1,
                scale=1.0 / float(scale_dim) ** 0.5,
            )[:, None].astype(x.dtype)  # (B, 1, H, r)
        else:
            t = ckv.shape[1]
            s_lat = jnp.einsum("bshr,btr->bhst", q_lat, ckv,
                               preferred_element_type=accum_dtype()).astype(jnp.float32)
            s_rope = jnp.einsum("bshr,btr->bhst", q_rope, krope.astype(q_rope.dtype),
                                preferred_element_type=accum_dtype()).astype(jnp.float32)
            if paged:  # per-row masks built alongside the page gather above
                msk4 = pmsk4
            else:
                msk4 = block_mask(s, t, cache_index, 0, False, 0,
                                  kv_len=cache_index + s)[None, None]
            att = jax.nn.softmax((s_lat + s_rope) / jnp.sqrt(scale_dim) + msk4,
                                 axis=-1)
            ctx_lat = jnp.moveaxis(
                jnp.einsum("bhst,btr->bhsr", att.astype(ckv.dtype), ckv,
                           preferred_element_type=accum_dtype()), 1, 2
            ).astype(x.dtype)
        if isinstance(p["wv_b"], PackedLinear):
            wv_v = packed_head_view(p["wv_b"], h)  # (H, v, r) packed
            ctx_h = jnp.moveaxis(ctx_lat, 2, 0).reshape(h, b * s, m.kv_lora_rank)
            o = batched_linear(wv_v, ctx_h, quantize_acts=False)
            o = jnp.moveaxis(o.reshape(h, b, s, m.v_head_dim), 0, 2).astype(x.dtype)
        else:
            wv_b = as_dense(p["wv_b"], x.dtype).reshape(h, m.v_head_dim, m.kv_lora_rank)
            o = jnp.einsum("bshr,hvr->bshv", ctx_lat, wv_b,
                           preferred_element_type=accum_dtype()).astype(x.dtype)
    else:
        # ---- materialized form (train / prefill) --------------------------
        k_nope = linear(p["wk_b"], c_kv).reshape(b, s, h, m.qk_nope_dim)
        v = linear(p["wv_b"], c_kv).reshape(b, s, h, m.v_head_dim)
        k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, m.qk_rope_dim))
        q_full = shard_heads(jnp.concatenate([q_nope, q_rope], axis=-1))
        k_full = shard_heads(jnp.concatenate([k_nope, k_rope_h.astype(k_nope.dtype)], axis=-1))
        v = shard_heads(v)
        # v padded to qk dim? no — chunked kernel handles distinct v dim via
        # separate head_dim; _sdpa_* use v's own last dim.
        if s > cfg.attn_chunk:
            o = _sdpa_chunked(q_full, k_full, v, cfg.causal, cfg.window,
                              cfg.attn_chunk, cfg.attn_chunk)
        else:
            o = _sdpa_full(q_full, k_full, v, block_mask(s, s, 0, 0, cfg.causal, 0))

    o = o.reshape(b, s, h * m.v_head_dim)
    out = linear(p["wo"], quant_act(o, a_fmt), p.get("bo"))
    return out, new_cache
