"""xLSTM blocks (Beck et al. 2024): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential), assembled in alternating pairs.

mLSTM cell (stabilized exponential gating):
    weight(t, s) = exp(L_t - L_s + i_s - m_t),  L = cumsum(logsigmoid(f)),
    m_t = running max of the exponent (flash-attention-style online max),
    h_t = [sum_s w(t,s) (q_t.k_s/sqrt(dk)) v_s] / max(|den_t|, exp(-m_t)).
Evaluated blockwise like chunked attention (train/prefill) and as an exact
recurrent step with (C, n, m) carry for decode — the long_500k path.

sLSTM: per-head scalar memory with recurrent gate preactivations through a
block-diagonal R; evaluated with lax.scan over time (inherently sequential;
the xLSTM paper's point). Decode is a single step of the same cell.

Both blocks' projections are quantizable linears (paper's W4A8 applies).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import ParamDef, as_dense, linear, norm, quant_act
from .ssm import causal_conv

__all__ = [
    "mlstm_params",
    "mlstm_block",
    "init_mlstm_cache",
    "slstm_params",
    "slstm_block",
    "init_slstm_cache",
]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def _mlstm_dims(cfg):
    d_in = 2 * cfg.d_model  # projection factor 2
    h = cfg.n_heads
    dk = d_in // h
    return d_in, h, dk


def mlstm_params(cfg):
    d, dt = cfg.d_model, cfg.param_dtype
    d_in, h, dk = _mlstm_dims(cfg)
    return {
        "up_proj": ParamDef((2 * d_in, d), ("ffn", "embed"), dt),  # x branch + z gate
        "conv_w": ParamDef((4, d_in), ("conv", None), dt, "normal", 0.5),
        "wq": ParamDef((d_in, d_in), ("heads", "ffn"), dt),
        "wk": ParamDef((d_in, d_in), ("heads", "ffn"), dt),
        "wv": ParamDef((d_in, d_in), ("heads", "ffn"), dt),
        "wi": ParamDef((h, d_in), (None, "ffn"), dt, "normal", 0.5),
        "wf": ParamDef((h, d_in), (None, "ffn"), dt, "normal", 0.5),
        "bi": ParamDef((h,), (None,), "float32", "zeros"),
        "bf": ParamDef((h,), (None,), "float32", "ones"),
        "out_norm": {"scale": ParamDef((d_in,), ("ffn",), dt, "ones")},
        "down_proj": ParamDef((d, d_in), ("embed", "ffn"), dt),
    }


def init_mlstm_cache(cfg, batch):
    d_in, h, dk = _mlstm_dims(cfg)
    return {
        "c": jnp.zeros((batch, h, dk, dk), jnp.float32),
        "n": jnp.zeros((batch, h, dk), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, 3, d_in), jnp.float32),
    }


def _mlstm_chunked(q, k, v, log_f, log_i, chunk: int, s0=None):
    """q,k,v: (B, T, H, dk); log_f (<=0), log_i: (B, T, H).
    ``s0``: optional incoming (c, n, m) state in the recurrent-step
    convention (what ``_mlstm_step`` carries) — used by the serving
    engine's streaming prefill to continue a prompt chunk by chunk.
    Returns (h (B,T,H,dk), state (c, n, m))."""
    b, t, h, dk = q.shape
    chunk = min(chunk, t)
    nc = -(-t // chunk)
    pad = nc * chunk - t
    if pad:
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, zp), jnp.pad(k, zp), jnp.pad(v, zp)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)

    scale = 1.0 / jnp.sqrt(dk)
    qs = (q * scale).reshape(b, nc, chunk, h, dk).astype(jnp.float32)
    ks = k.reshape(b, nc, chunk, h, dk).astype(jnp.float32)
    vs = v.reshape(b, nc, chunk, h, dk).astype(jnp.float32)
    lfs = log_f.reshape(b, nc, chunk, h).astype(jnp.float32)
    lis = log_i.reshape(b, nc, chunk, h).astype(jnp.float32)

    causal = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))

    def step(carry, ci):
        # carry state is in the recurrent-step convention: c/n stabilized by
        # m_st, decayed to the end of the previous chunk. All exponents here
        # are *chunk-local* (L measured from the chunk start): the decay
        # from the previous chunk's end to position t is exp(L_t), so
        # e_inter = L_t + m_st and the carry-to-carry decay uses L_tot.
        # (A global running L offset in the carry double-counted the decay
        # of earlier chunks — state died off exp(L_prev) too fast for any
        # T > chunk.)
        c_st, n_st, m_st = carry
        qb, kb, vb = qs[:, ci], ks[:, ci], vs[:, ci]
        lf, li = lfs[:, ci], lis[:, ci]
        lcum = jnp.cumsum(lf, axis=1)  # chunk-local L_t, (B, c, H)
        lt = jnp.transpose(lcum, (0, 2, 1))  # (B, H, c)
        # intra-chunk exponent: E_ts = L_t - L_s + i_s
        e_intra = lt[:, :, :, None] - lt[:, :, None, :] + jnp.transpose(li, (0, 2, 1))[:, :, None, :]
        e_intra = jnp.where(causal[None, None] > 0, e_intra, -jnp.inf)
        # inter-chunk exponent for state use: L_t + m_st
        e_inter = lt + m_st[..., None]  # (B, H, c)
        m_new = jnp.maximum(jnp.max(e_intra, axis=-1), e_inter)  # (B, H, c)
        m_new = jnp.maximum(m_new, -1e30)
        w = jnp.exp(e_intra - m_new[..., None])  # (B, H, t, s)
        scores = jnp.einsum("bthd,bshd->bhts", qb, kb) * w
        num = jnp.einsum("bhts,bshd->bthd", scores, vb)
        den = jnp.sum(scores, axis=-1)  # (B, H, t) -> transpose to (B, t, H)
        inter_w = jnp.exp(e_inter - m_new)  # (B, H, c)
        num = num + jnp.einsum("bthd,bhdv->bthv", qb, c_st) * jnp.transpose(inter_w, (0, 2, 1))[..., None]
        den = den + jnp.einsum("bthd,bhd->bht", qb, n_st) * inter_w
        den_t = jnp.transpose(den, (0, 2, 1))  # (B, t, H)
        m_t = jnp.transpose(m_new, (0, 2, 1))  # (B, t, H)
        h_out = num / jnp.maximum(jnp.abs(den_t), jnp.exp(-m_t))[..., None]

        # state update to end of chunk, stabilizer m_end = m at last position
        l_tot = lcum[:, -1]  # (B, H)
        m_end = jnp.transpose(m_new, (0, 2, 1))[:, -1]  # (B, H)
        # contributions: exp(L_tot - L_s + i_s - m_end)
        wk_exp = jnp.exp(l_tot[:, None] - lcum + li - m_end[:, None])  # (B, c, H)
        kb_w = kb * wk_exp[..., None]  # fold the gate into k FIRST — a
        # 3-operand einsum here can materialize a (B,c,H,dk,dk) intermediate
        c_new = c_st * jnp.exp(m_st + l_tot - m_end)[..., None, None] + jnp.einsum(
            "bshd,bshv->bhdv", kb_w, vb
        )
        n_new = n_st * jnp.exp(m_st + l_tot - m_end)[..., None] + jnp.einsum(
            "bshd,bsh->bhd", kb, wk_exp
        )
        return (c_new, n_new, m_end), h_out

    if s0 is None:
        c0 = jnp.zeros((b, h, dk, dk), jnp.float32)
        n0 = jnp.zeros((b, h, dk), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = (s.astype(jnp.float32) for s in s0)
    (c_f, n_f, m_f), hs = jax.lax.scan(step, (c0, n0, m0), jnp.arange(nc))
    hh = jnp.moveaxis(hs, 0, 1).reshape(b, nc * chunk, h, dk)[:, :t]
    return hh, (c_f, n_f, m_f)


def _mlstm_step(q, k, v, log_f, log_i, c, n, m):
    """Exact recurrent step. q,k,v: (B, H, dk); gates: (B, H)."""
    scale = 1.0 / jnp.sqrt(q.shape[-1])
    q = q.astype(jnp.float32) * scale
    k, v = k.astype(jnp.float32), v.astype(jnp.float32)
    m_new = jnp.maximum(log_f + m, log_i)
    fw = jnp.exp(log_f + m - m_new)
    iw = jnp.exp(log_i - m_new)
    c_new = c * fw[..., None, None] + iw[..., None, None] * jnp.einsum("bhd,bhv->bhdv", k, v)
    n_new = n * fw[..., None] + iw[..., None] * k
    num = jnp.einsum("bhd,bhdv->bhv", q, c_new)
    den = jnp.einsum("bhd,bhd->bh", q, n_new)
    h_out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h_out, (c_new, n_new, m_new)


def mlstm_block(p, x, cfg, cache=None, a_fmt: Optional[str] = None):
    """x: (B, T, d) -> (y, new_cache)."""
    d_in, h, dk = _mlstm_dims(cfg)
    b, t, _ = x.shape
    xq = quant_act(x, a_fmt)
    up = linear(p["up_proj"], xq)
    xm, z = up[..., :d_in], up[..., d_in:]

    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = causal_conv(xm, p["conv_w"], conv_state)

    xcq = quant_act(xc, a_fmt)
    q = linear(p["wq"], xcq).reshape(b, t, h, dk)
    k = linear(p["wk"], xcq).reshape(b, t, h, dk)
    v = xm.reshape(b, t, h, dk)  # value from the un-conv'd branch

    wi = as_dense(p["wi"], jnp.float32).astype(jnp.float32)
    wf = as_dense(p["wf"], jnp.float32).astype(jnp.float32)
    log_i = (xc.astype(jnp.float32) @ wi.T) + p["bi"]
    log_f = jax.nn.log_sigmoid((xc.astype(jnp.float32) @ wf.T) + p["bf"])

    new_cache = None
    if cache is not None and t == 1:
        hh, (c_n, n_n, m_n) = _mlstm_step(
            q[:, 0], k[:, 0], v[:, 0], log_f[:, 0], log_i[:, 0],
            cache["c"], cache["n"], cache["m"],
        )
        hh = hh[:, None]
        new_cache = {"c": c_n, "n": n_n, "m": m_n, "conv": new_conv.astype(jnp.float32)}
    else:
        s0 = None
        if cache is not None:  # streaming prefill continues the carried state
            s0 = (cache["c"], cache["n"], cache["m"])
        hh, (c_n, n_n, m_n) = _mlstm_chunked(q, k, v, log_f, log_i, chunk=256,
                                             s0=s0)
        if cache is not None:
            new_cache = {"c": c_n, "n": n_n, "m": m_n, "conv": new_conv.astype(jnp.float32)}

    hh = hh.reshape(b, t, d_in).astype(x.dtype) + xc  # learnable-skip simplified to conv skip
    hh = norm(p["out_norm"], hh, "rmsnorm", cfg.norm_eps)
    hh = hh * jax.nn.silu(z.astype(jnp.float32)).astype(hh.dtype)
    return linear(p["down_proj"], quant_act(hh, a_fmt)), new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_params(cfg):
    d, dt = cfg.d_model, cfg.param_dtype
    h = cfg.n_heads
    dh = d // h
    return {
        "w_gates": ParamDef((4 * d, d), ("ffn", "embed"), dt),  # i,f,z,o from x
        "r_gates": ParamDef((h, 4 * dh, dh), (None, None, None), dt, "normal", 0.5),
        "b_gates": ParamDef((4 * d,), ("ffn",), "float32", "zeros"),
        "out_norm": {"scale": ParamDef((d,), ("embed",), dt, "ones")},
        # post-cell gated FFN (proj factor 4/3, xLSTM paper)
        "ffn_up": ParamDef((2 * (4 * d // 3), d), ("ffn", "embed"), dt),
        "ffn_down": ParamDef((d, 4 * d // 3), ("embed", "ffn"), dt),
    }


def init_slstm_cache(cfg, batch):
    d = cfg.d_model
    h = cfg.n_heads
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_cell(gx, state, r_gates, h_heads, dh):
    """One timestep. gx: (B, 4d) gate preacts from x; state dict of (B, d)."""
    c, n, m, h_prev = state
    b = gx.shape[0]
    hp = h_prev.reshape(b, h_heads, dh)
    rec = jnp.einsum("bhd,hgd->bhg", hp, r_gates).reshape(b, 4 * h_heads * dh)
    pre = (gx + rec).reshape(b, 4, h_heads * dh)
    i_t, f_t, z_t, o_t = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    lf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(lf + m, i_t)
    iw = jnp.exp(i_t - m_new)
    fw = jnp.exp(lf + m - m_new)
    c_new = fw * c + iw * jnp.tanh(z_t)
    n_new = fw * n + iw
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_block(p, x, cfg, cache=None, a_fmt: Optional[str] = None):
    """x: (B, T, d) -> (y, new_cache). lax.scan over time (sequential)."""
    d = cfg.d_model
    h_heads = cfg.n_heads
    dh = d // h_heads
    b, t, _ = x.shape

    xq = quant_act(x, a_fmt)
    gx = linear(p["w_gates"], xq).astype(jnp.float32) + p["b_gates"]  # (B, T, 4d)

    if cache is not None:
        st = (cache["c"], cache["n"], cache["m"], cache["h"])
    else:
        st = (
            jnp.zeros((b, d), jnp.float32),
            jnp.ones((b, d), jnp.float32),
            jnp.zeros((b, d), jnp.float32),
            jnp.zeros((b, d), jnp.float32),
        )

    r_gates = as_dense(p["r_gates"], jnp.float32).astype(jnp.float32)

    def step(state, gx_t):
        return _slstm_cell(gx_t, state, r_gates, h_heads, dh)

    st_f, hs = jax.lax.scan(step, st, jnp.moveaxis(gx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)  # (B, T, d)
    y = norm(p["out_norm"], y, "rmsnorm", cfg.norm_eps)

    # gated FFN
    yq = quant_act(y, a_fmt)
    upd = linear(p["ffn_up"], yq)
    half = upd.shape[-1] // 2
    y = linear(p["ffn_down"], quant_act(
        jax.nn.silu(upd[..., :half].astype(jnp.float32)).astype(x.dtype) *
        upd[..., half:], a_fmt))

    new_cache = None
    if cache is not None:
        new_cache = {"c": st_f[0], "n": st_f[1], "m": st_f[2], "h": st_f[3]}
    return y, new_cache
