"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention+MLP block
applied every `ssm.attn_every` layers (weight sharing is Zamba's signature —
the shared block's parameters are reused at every invocation, but each
invocation has its own KV cache because its inputs differ by depth).

Implementation: lax.scan over the stacked mamba2 layers; inside the body a
lax.cond fires the shared block when (layer_index % attn_every == 0). The
shared block's KV caches are stacked (n_invocations, ...) and indexed by
invocation = layer_index // attn_every.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.runtime.kv_cache import PagedState, gather_slabs, scatter_slabs

from .attention import attention, attn_params, init_kv_cache
from .layers import ParamDef, mlp, mlp_params, norm, norm_params, shard_residual
from .ssm import init_mamba2_cache, mamba2_block, mamba2_params
from .transformer import _stack_defs, lm_logits

__all__ = ["build_hybrid", "hybrid_forward", "init_hybrid_cache", "n_attn_invocations"]


def n_attn_invocations(cfg) -> int:
    k = cfg.ssm.attn_every
    return 0 if not k else -(-cfg.n_layers // k)


def build_hybrid(cfg):
    d, dt = cfg.d_model, cfg.param_dtype
    p = {
        "embed": ParamDef((cfg.vocab_size, d), ("vocab", "embed"), dt, "embed"),
        "mamba": _stack_defs(
            {"ln": norm_params(cfg), "mamba": mamba2_params(cfg)}, cfg.n_layers
        ),
        "final_ln": norm_params(cfg),
    }
    if cfg.ssm.attn_every:
        p["shared"] = {
            "ln1": norm_params(cfg),
            "attn": attn_params(cfg),
            "ln2": norm_params(cfg),
            "mlp": mlp_params(cfg),
        }
    if not cfg.tie_embeddings:
        p["lm_head"] = ParamDef((cfg.vocab_size, d), ("vocab", "embed"), dt, "embed")
    return p


def _shared_block(p, x, cfg, positions, kv_cache, cache_index, a_fmt):
    h, new_kv = attention(
        p["attn"], norm(p["ln1"], x, cfg.norm_kind, cfg.norm_eps), cfg, positions,
        kv_cache=kv_cache, cache_index=cache_index, a_fmt=a_fmt,
    )
    x = x + h
    x = x + mlp(p["mlp"], norm(p["ln2"], x, cfg.norm_kind, cfg.norm_eps), cfg, a_fmt=a_fmt)
    return x, new_kv


def hybrid_forward(
    params,
    cfg,
    tokens,
    caches=None,
    cache_index=None,
    a_fmt: Optional[str] = None,
    remat: bool = False,
):
    """Returns (hidden, new_caches, aux). caches = {'mamba': stacked ssm
    caches, 'shared_kv': (n_inv, B, S, kv, hd) x2} or None.

    Paged engine (``cache_index`` is a PagedState): 'mamba' leaves are
    slab-pooled — (L, n_slabs + 1, ...), gathered per row by
    ``cache_index.slabs`` — and 'shared_kv' is a paged KV pool with the
    invocation index in place of the layer axis ((n_inv, P+1, page, kv,
    hd) + scale leaves), so the shared block's per-invocation caches ride
    the same page table as any GQA layer stack."""
    b, s = tokens.shape
    paged = isinstance(cache_index, PagedState)
    if paged:
        positions = cache_index.lengths[:, None] + jnp.arange(s)[None]
    else:
        offset = 0 if cache_index is None else cache_index
        positions = jnp.arange(s) + offset
    x = jnp.take(params["embed"], tokens, axis=0)

    every = cfg.ssm.attn_every
    shared_p = params.get("shared")

    def body(carry, layer_in):
        h, shared_kv = carry
        (p_layer, mcache_pool), li = layer_in
        mcache = mcache_pool
        if paged and mcache_pool is not None:
            mcache = gather_slabs(mcache_pool, cache_index.slabs)
        h = shard_residual(h)  # sequence-parallel residual (no-op off-mesh)

        if shared_p is not None:

            def with_attn(h, shared_kv):
                inv = li // every
                if shared_kv is not None:
                    kv_i = jax.tree.map(lambda c: c[inv], shared_kv)
                else:
                    kv_i = None
                h2, new_kv = _shared_block(
                    shared_p, h, cfg, positions, kv_i, cache_index, a_fmt
                )
                if shared_kv is not None:
                    shared_kv = jax.tree.map(
                        lambda full, one: jax.lax.dynamic_update_index_in_dim(
                            full, one.astype(full.dtype), inv, 0
                        ),
                        shared_kv,
                        new_kv,
                    )
                return h2, shared_kv

            def without_attn(h, shared_kv):
                return h, shared_kv

            h, shared_kv = jax.lax.cond(
                li % every == 0, with_attn, without_attn, h, shared_kv
            )

        dh, new_m = mamba2_block(
            p_layer["mamba"], norm(p_layer["ln"], h, cfg.norm_kind, cfg.norm_eps), cfg,
            cache=mcache, a_fmt=a_fmt,
        )
        h = h + dh
        if paged and new_m is not None:
            new_m = scatter_slabs(mcache_pool, cache_index.slabs, new_m)
        return (h, shared_kv), new_m

    if remat:
        body = jax.checkpoint(body)

    mamba_caches = None if caches is None else caches["mamba"]
    shared_kv0 = None if caches is None else caches["shared_kv"]
    (x, shared_kv_f), new_mamba = jax.lax.scan(
        body, (x, shared_kv0), ((params["mamba"], mamba_caches), jnp.arange(cfg.n_layers))
    )
    x = norm(params["final_ln"], x, cfg.norm_kind, cfg.norm_eps)
    new_caches = None
    if caches is not None:
        new_caches = {"mamba": new_mamba, "shared_kv": shared_kv_f}
    return x, new_caches, jnp.zeros((), jnp.float32)


def init_hybrid_cache(cfg, batch: int, max_seq: int):
    one_m = {"_": init_mamba2_cache(cfg, batch)}
    mamba = jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one_m["_"])
    c = {"mamba": mamba}
    n_inv = n_attn_invocations(cfg)
    if n_inv:
        kv = init_kv_cache(batch, max_seq, cfg.n_kv_heads, cfg.resolved_head_dim)
        c["shared_kv"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_inv,) + a.shape), kv
        )
    return c
