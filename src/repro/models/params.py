"""Parameter definition trees.

A model's parameters are described once as a pytree of ``ParamDef`` leaves
(shape + dtype + logical axis names + init law). The same tree is then
interpreted three ways:

  * ``init_tree``   -> concrete arrays (training / smoke tests)
  * ``shape_tree``  -> jax.ShapeDtypeStruct stand-ins (multi-pod dry-run,
                       zero allocation)
  * ``pspec_tree``  -> jax.sharding.PartitionSpec per leaf, from a logical->
                       mesh-axis rules table (pjit in_shardings)

Logical axis names used across the zoo:
  'embed'   — d_model-sized dims (replicated)
  'vocab'   — vocabulary (sharded over model axis)
  'heads'   — attention head count dims
  'kv'      — kv-head dims (sharded if divisible, else replicated)
  'ffn'     — MLP intermediate
  'expert'  — MoE expert count (expert parallelism)
  'layers'  — stacked-layer leading dim of scanned blocks (never sharded)
  'lora'    — MLA/LoRC low-rank dims (replicated)
  'state'   — SSM state dims (replicated)
  None      — replicated
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = ["ParamDef", "init_tree", "shape_tree", "pspec_tree", "DEFAULT_RULES", "ZERO1_RULES"]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical name per dim, len == len(shape)
    dtype: str = "bfloat16"
    init: str = "normal"  # 'normal' | 'zeros' | 'ones' | 'embed'
    scale: float = 1.0  # stddev multiplier for 'normal' (fan-in handled here)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x):
    return isinstance(x, ParamDef)


def init_leaf(d: ParamDef, key) -> jnp.ndarray:
    dtype = jnp.dtype(d.dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "embed":
        return (jax.random.normal(key, d.shape, jnp.float32) * 0.02 * d.scale).astype(dtype)
    # fan-in scaled normal over the last axis
    fan_in = d.shape[-1] if len(d.shape) >= 1 else 1
    std = d.scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)


def init_tree(tree, rng):
    """Materialize a ParamDef tree into arrays with per-leaf folded keys."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_def)
    keys = jax.random.split(rng, max(len(leaves), 1))
    out = [init_leaf(d, k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def shape_tree(tree):
    """ShapeDtypeStruct stand-ins — no allocation (dry-run path)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
        tree,
        is_leaf=_is_def,
    )


# ---------------------------------------------------------------------------
# Logical -> physical sharding rules
# ---------------------------------------------------------------------------
# Tensor-parallel rules: model axis carries heads/ffn/vocab/experts.
DEFAULT_RULES = {
    "embed": None,
    "vocab": "model",
    "heads": "model",
    "kv": "model",  # resolved with divisibility fallback below
    "ffn": "model",
    "expert": "model",
    "layers": None,
    "lora": None,
    "state": None,
    "conv": None,
}

# ZeRO flavour: additionally shard the 'embed' (largest replicated) dim of
# params/optimizer moments over the data axis.
ZERO1_RULES = dict(DEFAULT_RULES, embed="data")


def _axis_size(mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        s = 1
        for n in name:
            s *= _axis_size(mesh, n)
        return s
    return mesh.shape[name] if name in mesh.shape else 0


def pspec_leaf(d: ParamDef, rules, mesh=None) -> P:
    """PartitionSpec for one leaf. Falls back to replication when the dim
    size is not divisible by the assigned mesh-axis size (e.g. 8 kv heads on
    a 16-way model axis, or odd vocab sizes)."""
    spec = []
    used = set()
    for size, ax in zip(d.shape, d.axes):
        phys = rules.get(ax) if ax is not None else None
        parts = (phys,) if isinstance(phys, str) else tuple(phys or ())
        if phys is None or any(a in used for a in parts):
            spec.append(None)
            continue
        if mesh is not None:
            asize = _axis_size(mesh, phys)
            if asize == 0 or size % asize != 0:
                spec.append(None)
                continue
        spec.append(phys)
        used.update(parts)
    return P(*spec)


def pspec_tree(tree, rules=DEFAULT_RULES, mesh=None):
    return jax.tree.map(lambda d: pspec_leaf(d, rules, mesh), tree, is_leaf=_is_def)
