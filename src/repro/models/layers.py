"""Shared layer library: norms, activations, rotary embeddings, MLPs, and the
quantizable linear — the single place where the paper's W4A8 serving path
plugs into every architecture."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantize import fake_quantize_act
from .params import ParamDef

__all__ = [
    "PackedLinear",
    "as_dense",
    "batched_linear",
    "packed_head_view",
    "set_accum_dtype",
    "accum_dtype",
    "set_residual_sharding",
    "shard_residual",
    "shard_heads",
    "linear",
    "norm",
    "norm_params",
    "activation",
    "mlp_params",
    "mlp",
    "rope_freqs",
    "apply_rope",
]


# ---------------------------------------------------------------------------
# Quantized linear container (serving path)
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PackedLinear:
    """W4A8-deployed linear: packed FP4 codes + (pow-2-constrained) scales
    [+ optional LoRC factors]. Produced by core.ptq.pack_linear.

    codes:  (out, in/2) uint8 — two E2M1 nibbles per byte
    scale:  (out, n_groups) f32 — real scales (already M1/M2-constrained
            when the policy asks for it)
    s_max / shifts: M2 decomposition (s_max per row, k per group) or None
    lorc_a/lorc_b: rank-r compensation factors or None
    """

    codes: jnp.ndarray
    scale: jnp.ndarray
    s_max: Optional[jnp.ndarray]
    shifts: Optional[jnp.ndarray]
    lorc_a: Optional[jnp.ndarray]
    lorc_b: Optional[jnp.ndarray]
    w_fmt: str = dataclasses.field(metadata=dict(static=True), default="fp4_e2m1")
    a_fmt: Optional[str] = dataclasses.field(metadata=dict(static=True), default="fp8_e4m3")
    group_size: int = dataclasses.field(metadata=dict(static=True), default=256)

    @property
    def out_features(self) -> int:
        return self.codes.shape[0]

    @property
    def in_features(self) -> int:
        return self.codes.shape[-1] * 2


def linear(w, x, bias=None):
    """y = x @ W^T [+ b].

    ``w`` is either a plain (out, in) array (train / fake-quant sim) or a
    PackedLinear (W4A8 serving). Activations are f32/bf16; output keeps the
    activation dtype; accumulation in f32 via preferred_element_type.
    """
    if isinstance(w, PackedLinear):
        from repro.kernels import ops  # local import: kernels depend on core only

        y = ops.w4a8_matmul(x, w)
    else:
        y = jax.lax.dot_general(
            x,
            w,
            (((x.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=accum_dtype(),
        ).astype(x.dtype)
    if bias is not None:
        y = y + bias
    return y


# ---------------------------------------------------------------------------
# Activation (residual-stream) sharding hook — set by the launcher to enable
# Megatron-style sequence parallelism; no-op by default.
# ---------------------------------------------------------------------------
_RESIDUAL_SHARDING = [None]
_HEADS_SHARDING = [None]
# Matmul accumulation dtype exposed to XLA via preferred_element_type.
# f32 for execution paths (CPU tests/examples). The DRY-RUN lowers with
# bf16: the CPU backend rewrites bf16xbf16->f32 dots into convert-to-f32 +
# f32 dot, which would poison every adjacent collective/HBM measurement
# with 2x-sized f32 tensors; a TPU consumes bf16 operands directly (f32
# accumulation is internal to the MXU), which bf16-preferred lowering
# mirrors exactly (results are cast back to bf16 right after each matmul
# in this codebase anyway).
_ACCUM_DTYPE = [None]


def set_accum_dtype(dt):
    _ACCUM_DTYPE[0] = dt


def accum_dtype():
    return _ACCUM_DTYPE[0] or jnp.float32


def set_residual_sharding(named_sharding, heads_sharding=None):
    """named_sharding: NamedSharding for the (B, S, d) residual (Megatron SP:
    seq over 'model') or None. heads_sharding: NamedSharding for (B, S, H,
    hd) attention tensors (heads over 'model') — pins GSPMD to the Megatron
    layout so the q-chunk loop never slices across a sharded seq dim."""
    _RESIDUAL_SHARDING[0] = named_sharding
    _HEADS_SHARDING[0] = heads_sharding


def shard_residual(x):
    ns = _RESIDUAL_SHARDING[0]
    if ns is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, ns)


def shard_heads(x):
    """Constraint for (B, S, H, hd) q/k/v tensors (no-op off-mesh)."""
    ns = _HEADS_SHARDING[0]
    if ns is None or x.ndim != 4:
        return x
    return jax.lax.with_sharding_constraint(x, ns)


def as_dense(w, dtype=jnp.bfloat16):
    """Materialize a (possibly Packed) weight as a dense array. Only the
    ref-backend einsum call-sites still densify; the serving hot paths (MoE
    expert stacks, MLA absorbed projections) go through batched_linear,
    which keeps the weights packed under the pallas backend."""
    if isinstance(w, PackedLinear):
        from repro.kernels import ops

        return ops.dequant_packed(w).astype(dtype)
    return w


def batched_linear(w, x, transpose_w: bool = False, quantize_acts: bool = True):
    """Stacked-expert/head linear over a leading batch axis.

    x: (E, M, D); ``w`` is a stacked dense (E, N, K) array or a batched
    PackedLinear (codes (E, N, K/2)).
      normal:     y[e] = x[e] @ w[e]^T  (D == K)        -> (E, M, N)
      transposed: y[e] = x[e] @ w[e]    (D == N)        -> (E, M, K)
    Packed weights run the fused batched W4A8 kernel under the pallas
    backend (in-kernel FP8 act-quant + LoRC epilogue, no densify) and the
    batched jnp oracle otherwise. ``quantize_acts=False`` skips activation
    quantization (MLA absorbed latent paths)."""
    if isinstance(w, PackedLinear):
        from repro.kernels import ops  # local import: kernels depend on core only

        y = ops.w4a8_matmul_batched(x, w, transpose_w=transpose_w,
                                    quantize_acts=quantize_acts)
        return y.astype(x.dtype)
    eq = "emn,enk->emk" if transpose_w else "emk,enk->emn"
    return jnp.einsum(eq, x, w, preferred_element_type=accum_dtype()).astype(x.dtype)


def packed_head_view(w: PackedLinear, heads: int) -> PackedLinear:
    """(H*out, in) PackedLinear -> (H, out, in) batched view for per-head
    absorbed matmuls (MLA). Pure reshapes of the packed fields — codes stay
    packed; lorc_b (rank, in) has no head dim and is broadcast."""
    assert w.codes.ndim == 2 and w.codes.shape[0] % heads == 0, w.codes.shape
    resh = lambda a: None if a is None else a.reshape(heads, a.shape[0] // heads, *a.shape[1:])
    lorc_b = None if w.lorc_b is None else jnp.broadcast_to(
        w.lorc_b[None], (heads,) + w.lorc_b.shape)
    return dataclasses.replace(
        w, codes=resh(w.codes), scale=resh(w.scale), s_max=resh(w.s_max),
        shifts=resh(w.shifts), lorc_a=resh(w.lorc_a), lorc_b=lorc_b,
    )


def quant_act(x, a_fmt: Optional[str]):
    """Token-wise activation fake-quant used on the serving path."""
    if a_fmt is None:
        return x
    return fake_quantize_act(x, a_fmt)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def norm_params(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm_kind == "rmsnorm":
        return {"scale": ParamDef((d,), ("embed",), cfg.param_dtype, "ones")}
    if cfg.norm_kind == "layernorm":
        p = {"scale": ParamDef((d,), ("embed",), cfg.param_dtype, "ones")}
        p["bias"] = ParamDef((d,), ("embed",), cfg.param_dtype, "zeros")
        return p
    if cfg.norm_kind == "nonparam_ln":  # OLMo: LN without affine params
        return {}
    raise ValueError(cfg.norm_kind)


def norm(p, x, kind: str, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    # layernorm / nonparam_ln
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------
def activation(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "relu2":  # Nemotron-4 squared ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# MLP (gated a-la SwiGLU, or plain 2-matmul)
# ---------------------------------------------------------------------------
def mlp_params(cfg, d_ff=None):
    d, dtype = cfg.d_model, cfg.param_dtype
    d_ff = d_ff or cfg.d_ff
    p = {
        "up": ParamDef((d_ff, d), ("ffn", "embed"), dtype),
        "down": ParamDef((d, d_ff), ("embed", "ffn"), dtype),
    }
    if cfg.mlp_gated:
        p["gate"] = ParamDef((d_ff, d), ("ffn", "embed"), dtype)
    if cfg.use_bias:
        p["up_b"] = ParamDef((d_ff,), ("ffn",), dtype, "zeros")
        p["down_b"] = ParamDef((d,), ("embed",), dtype, "zeros")
    return p


def mlp(p, x, cfg, a_fmt=None):
    xq = quant_act(x, a_fmt)
    up = linear(p["up"], xq, p.get("up_b"))
    if "gate" in p:
        h = activation(linear(p["gate"], xq), cfg.act_kind) * up
    else:
        h = activation(up, cfg.act_kind)
    hq = quant_act(h, a_fmt)
    return linear(p["down"], hq, p.get("down_b"))


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(positions, dim: int, theta: float):
    """positions: (...,) int -> (..., dim/2) angles."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions.astype(jnp.float32)[..., None] * inv


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    ang = rope_freqs(positions, hd, theta)  # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
