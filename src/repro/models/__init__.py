"""repro.models — the architecture zoo (pure functional JAX)."""
from .api import (
    build_def,
    decode_step,
    encode_cross_pages,
    forward_hidden,
    init_cache,
    init_params,
    loss_fn,
    param_pspecs,
    param_shapes,
    prefill,
)
from .config import ArchConfig, MLASpec, MoESpec, SSMSpec
from .params import DEFAULT_RULES, ZERO1_RULES, ParamDef, init_tree, pspec_tree, shape_tree

__all__ = [
    "ArchConfig", "MLASpec", "MoESpec", "SSMSpec", "ParamDef",
    "build_def", "decode_step", "encode_cross_pages", "forward_hidden",
    "init_cache", "init_params",
    "loss_fn", "param_pspecs", "param_shapes", "prefill",
    "DEFAULT_RULES", "ZERO1_RULES", "init_tree", "pspec_tree", "shape_tree",
]
