"""ArchConfig — one dataclass describing every supported architecture.

Each assigned architecture gets a module in repro/configs/ that instantiates
this dataclass with the exact published numbers plus a reduced smoke variant.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig", "MoESpec", "MLASpec", "SSMSpec"]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert intermediate size
    n_shared_experts: int = 0
    shared_d_ff: int = 0  # defaults to d_ff if 0
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    n_dense_layers: int = 0  # leading dense layers (deepseek-v3: 3)
    dense_d_ff: int = 0  # d_ff of the leading dense layers


@dataclasses.dataclass(frozen=True)
class MLASpec:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    kind: str  # 'mamba2' | 'xlstm'
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64  # mamba2 head dim
    d_conv: int = 4
    chunk: int = 256
    # zamba2-style hybrid: a single shared attention block applied every
    # `attn_every` ssm layers (0 = no shared attention)
    attn_every: int = 0


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'encdec' | 'vlm' | 'audio'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # attention
    attn_kind: str = "gqa"  # 'gqa' | 'mla' | 'none'
    head_dim: int = 0  # 0 => d_model // n_heads
    rope_theta: float = 10000.0
    causal: bool = True
    attn_chunk: int = 1024  # kv-block size for chunked (flash-style) attention
    window: int = 0  # 0 = full attention; >0 = sliding window

    # norm / activation
    norm_kind: str = "rmsnorm"  # 'rmsnorm' | 'layernorm' | 'nonparam_ln'
    act_kind: str = "silu"  # 'silu' | 'gelu' | 'relu2'
    mlp_gated: bool = True
    use_bias: bool = False

    # optional sub-specs
    moe: Optional[MoESpec] = None
    mla: Optional[MLASpec] = None
    ssm: Optional[SSMSpec] = None

    # xlstm: alternate (mlstm, slstm) pairs when family == 'ssm' & kind xlstm
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0  # fixed encoder sequence (whisper: 1500 frames)

    # modality frontend stubs: 'none' | 'audio_frames' | 'vision_patches'
    frontend: str = "none"
    n_patches: int = 0  # vision_patches: patches prepended to the sequence

    # MTP (deepseek-v3): extra next^2-token prediction block
    mtp_depth: int = 0

    # embeddings
    tie_embeddings: bool = False
    pos_embedding: str = "rope"  # 'rope' | 'learned' | 'none'
    max_position: int = 524288

    # numeric
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-5

    # provenance tag, e.g. '[arXiv:2402.16819; unverified]'
    source: str = ""

    # --- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def is_sub_quadratic(self) -> bool:
        """Can this arch run the long_500k shape? (SSM / hybrid backbones)"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # no encoder-only archs in the assigned pool

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for rooflines."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        hd = self.resolved_head_dim
        for layer in range(self.n_layers):
            if self.attn_kind == "gqa":
                attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + hd * self.n_heads * d
            elif self.attn_kind == "mla":
                m = self.mla
                q_in = m.q_lora_rank or d
                attn = (
                    (d * m.q_lora_rank if m.q_lora_rank else 0)
                    + q_in * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_dim)
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d
                )
            else:
                attn = 0
            if self.moe is not None and layer >= self.moe.n_dense_layers:
                e_ff = self.moe.d_ff
                mult = 3 if self.mlp_gated else 2
                mlp = self.moe.n_experts * mult * d * e_ff + d * self.moe.n_experts
                if self.moe.n_shared_experts:
                    mlp += self.moe.n_shared_experts * mult * d * (self.moe.shared_d_ff or e_ff)
            elif self.moe is not None:
                mlp = (3 if self.mlp_gated else 2) * d * (self.moe.dense_d_ff or self.d_ff)
            elif self.ssm is not None and self.ssm.kind == "mamba2":
                d_in = d * self.ssm.expand
                mlp = d * (2 * d_in + 2 * self.ssm.d_state) + d_in * d
            elif self.ssm is not None and self.ssm.kind == "xlstm":
                mlp = 8 * d * d  # rough: mlstm up/down + gates
            else:
                mlp = (3 if self.mlp_gated else 2) * d * self.d_ff
            total += attn + mlp
        if self.encoder_layers:
            enc = self.encoder_layers * (4 * d * hd * self.n_heads + 2 * d * self.d_ff)
            total += enc
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        mult = 3 if self.mlp_gated else 2
        n_moe_layers = self.n_layers - self.moe.n_dense_layers
        inactive = (
            n_moe_layers
            * (self.moe.n_experts - self.moe.top_k)
            * mult
            * d
            * self.moe.d_ff
        )
        return self.param_count() - int(inactive)
