"""Model API — family dispatch for build / forward / prefill / decode / loss.

This is the single surface the launcher, PTQ driver, dry-run and tests use.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import encdec as _encdec
from . import hybrid as _hybrid
from . import transformer as _tf
from .losses import chunked_xent, mtp_loss
from .params import init_tree, pspec_tree, shape_tree

__all__ = [
    "build_def",
    "init_params",
    "param_shapes",
    "param_pspecs",
    "loss_fn",
    "prefill",
    "decode_step",
    "encode_cross_pages",
    "forward_hidden",
    "init_cache",
]


def encode_cross_pages(params, cfg, frames, caches, cross_table, a_fmt=None):
    """Enc-dec admission step: run the encoder once and write every decoder
    layer's cross K/V into its write-once cross pages (see encdec module)."""
    return _encdec.encode_cross_pages(params, cfg, frames, caches,
                                      cross_table, a_fmt=a_fmt)


def _is_encdec(cfg) -> bool:
    return cfg.encoder_layers > 0


def _is_hybrid(cfg) -> bool:
    return cfg.ssm is not None and cfg.ssm.kind == "mamba2" and cfg.family == "hybrid"


def build_def(cfg):
    if _is_encdec(cfg):
        return _encdec.build_encdec(cfg)
    if _is_hybrid(cfg):
        return _hybrid.build_hybrid(cfg)
    return _tf.build_lm(cfg)


def init_params(cfg, rng):
    return init_tree(build_def(cfg), rng)


def param_shapes(cfg):
    return shape_tree(build_def(cfg))


def param_pspecs(cfg, rules=None, mesh=None):
    from .params import DEFAULT_RULES

    return pspec_tree(build_def(cfg), rules or DEFAULT_RULES, mesh)


def _head_w(params, cfg):
    return params["embed"] if (cfg.tie_embeddings or "lm_head" not in params) else params["lm_head"]


def forward_hidden(params, cfg, batch, a_fmt=None, remat=False, caches=None, cache_index=None):
    """Full forward to final hidden states. Returns (hidden, new_caches, aux)."""
    if _is_encdec(cfg):
        enc = _encdec.encode(params, cfg, batch["frames"], a_fmt=a_fmt, remat=remat)
        return _encdec.encdec_forward(
            params, cfg, batch["tokens"], enc, caches=caches, cache_index=cache_index,
            a_fmt=a_fmt, remat=remat,
        )
    if _is_hybrid(cfg):
        return _hybrid.hybrid_forward(
            params, cfg, batch["tokens"], caches=caches, cache_index=cache_index,
            a_fmt=a_fmt, remat=remat,
        )
    prefix = batch.get("patches")
    if prefix is None:
        prefix = batch.get("frames_prefix")
    return _tf.lm_forward(
        params, cfg, batch["tokens"], embeds_prefix=prefix,
        caches=caches, cache_index=cache_index, a_fmt=a_fmt, remat=remat,
    )


def loss_fn(params, cfg, batch, a_fmt=None, remat=True, aux_weight=0.01, mtp_weight=0.0):
    """Scalar training loss (+ metrics dict)."""
    hidden, _, aux = forward_hidden(params, cfg, batch, a_fmt=a_fmt, remat=remat)
    labels = batch["labels"]
    if hidden.shape[1] != labels.shape[1]:  # vision/audio prefix tokens carry no loss
        hidden = hidden[:, hidden.shape[1] - labels.shape[1] :]
    loss, n_tok = chunked_xent(hidden, _head_w(params, cfg), labels, mask=batch.get("mask"))
    total = loss + aux_weight * aux
    metrics = {"nll": loss, "aux": aux, "tokens": n_tok}
    if mtp_weight and cfg.mtp_depth and "mtp" in params:
        seg = _tf.segments_for(cfg)[-1]
        ml = mtp_loss(
            params, cfg, hidden, batch["tokens"], labels, seg, _tf.block_apply,
            _head_w(params, cfg),
        )
        total = total + mtp_weight * ml
        metrics["mtp"] = ml
    return total, metrics


def init_cache(cfg, batch: int, max_seq: int):
    if _is_encdec(cfg):
        return _encdec.init_encdec_cache(cfg, batch, max_seq)
    if _is_hybrid(cfg):
        return _hybrid.init_hybrid_cache(cfg, batch, max_seq)
    return _tf.init_lm_cache(cfg, batch, max_seq)


def prefill(params, cfg, batch, max_seq: int, a_fmt=None):
    """Run the prompt through the model, filling caches.
    Returns (last_token_logits, caches)."""
    caches = init_cache(cfg, batch["tokens"].shape[0], max_seq)
    hidden, caches, _ = forward_hidden(
        params, cfg, batch, a_fmt=a_fmt, caches=caches, cache_index=0
    )
    w = _head_w(params, cfg)
    from .layers import accum_dtype

    logits = jax.lax.dot_general(
        hidden[:, -1], w, (((1,), (1,)), ((), ())), preferred_element_type=accum_dtype()
    ).astype(jnp.float32)
    return logits, caches


def decode_step(params, cfg, tokens, caches, cache_index, a_fmt=None):
    """One serving step: tokens (B, 1) + caches at cache_index.
    Returns (logits (B, V), new_caches).

    ``cache_index`` is either a scalar int (legacy contiguous caches, one
    synchronized position for every row) or a runtime.kv_cache.PagedState
    (paged pool: per-row true lengths + page table — each row gets its own
    positions and length masks). A PagedState with ``chunk_len`` set is a
    bucketed streaming-prefill chunk: positions past chunk_len are pad, so
    the logits row is the last *true* token, not the last row. A PagedState
    with ``prefill`` set is a *mixed* engine step — tokens is the fused
    (1, slots + chunk) row and the logits come back (slots + 1, V): one row
    per decode slot plus the chunk's last true token."""
    from repro.runtime.kv_cache import PagedState

    batch = {"tokens": tokens}
    if _is_encdec(cfg):
        hidden, caches, _ = _encdec_decode(params, cfg, tokens, caches, cache_index, a_fmt)
    else:
        hidden, caches, _ = forward_hidden(
            params, cfg, batch, a_fmt=a_fmt, caches=caches, cache_index=cache_index
        )
    if isinstance(cache_index, PagedState) and cache_index.prefill is not None:
        nd = cache_index.lengths.shape[0]
        h_pre = hidden[0, nd + cache_index.prefill.chunk_len[0] - 1]
        h_last = jnp.concatenate([hidden[0, :nd], h_pre[None]], axis=0)
    elif isinstance(cache_index, PagedState) and cache_index.chunk_len is not None:
        h_last = hidden[:, cache_index.chunk_len[0] - 1]
    else:
        h_last = hidden[:, -1]
    w = _head_w(params, cfg)
    from .layers import accum_dtype

    logits = jax.lax.dot_general(
        h_last, w, (((1,), (1,)), ((), ())), preferred_element_type=accum_dtype()
    ).astype(jnp.float32)
    return logits, caches


def _encdec_decode(params, cfg, tokens, caches, cache_index, a_fmt):
    # decode uses cached cross-k/v (computed at prefill); enc_out unused
    return _encdec.encdec_forward(
        params, cfg, tokens, enc_out=None, caches=caches, cache_index=cache_index, a_fmt=a_fmt
    )
