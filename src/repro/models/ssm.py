"""State-space / linear-attention substrate.

`gla_chunked` is the shared chunkwise engine: the recurrence
    S_t = a_t * S_{t-1} + k_t v_t^T ,   y_t = q_t^T S_t
with per-(head, step) scalar decay a_t = exp(log_a_t) <= 1 is evaluated in
chunks — intra-chunk quadratic attention with decay weights, inter-chunk
state carried by lax.scan. All exponents are <= 0, so no stabilizer is
needed (Mamba2's SSD: a_t = exp(A * dt), A < 0).

Mamba2 block: in_proj -> causal depthwise conv(4) -> SSD -> gated RMSNorm ->
out_proj, with single-step recurrent decode carrying (ssm state, conv tail).

All large projections are quantizable linears (the paper's W4A8 applies).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import ParamDef, linear, norm, quant_act

__all__ = [
    "gla_chunked",
    "gla_step",
    "mamba2_params",
    "mamba2_block",
    "init_mamba2_cache",
]


# ---------------------------------------------------------------------------
# Chunkwise gated linear attention
# ---------------------------------------------------------------------------
def gla_chunked(q, k, v, log_a, s0=None, chunk: int = 256):
    """q,k: (B, T, H, dk); v: (B, T, H, dv); log_a: (B, T, H) (<= 0).

    Returns (y (B, T, H, dv), s_final (B, H, dk, dv)).
    """
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, t)
    nc = -(-t // chunk)
    pad = nc * chunk - t
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))  # decay 1 on pad

    qs = q.reshape(b, nc, chunk, h, dk).astype(jnp.float32)
    ks = k.reshape(b, nc, chunk, h, dk).astype(jnp.float32)
    vs = v.reshape(b, nc, chunk, h, dv).astype(jnp.float32)
    las = log_a.reshape(b, nc, chunk, h).astype(jnp.float32)

    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    else:
        s0 = s0.astype(jnp.float32)

    causal = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))

    def step(s_in, ci):
        qb, kb, vb, lab = qs[:, ci], ks[:, ci], vs[:, ci], las[:, ci]
        lcum = jnp.cumsum(lab, axis=1)  # (B, c, H) inclusive
        ltot = lcum[:, -1]  # (B, H)
        # intra-chunk: w_ts = exp(L_t - L_s) * (q_t . k_s), s <= t
        scores = jnp.einsum("bthd,bshd->bhts", qb, kb)
        # decay matrix (B, H, t, s) = exp(L_t - L_s); mask s > t BEFORE the
        # exp (the upper triangle has positive exponent -> inf * 0 = NaN)
        expo = (
            jnp.transpose(lcum, (0, 2, 1))[:, :, :, None]
            - jnp.transpose(lcum, (0, 2, 1))[:, :, None, :]
        )
        decay = jnp.exp(jnp.where(causal[None, None] > 0, expo, -jnp.inf))
        w = scores * decay
        y_intra = jnp.einsum("bhts,bshd->bthd", w, vb)
        # inter-chunk: y_t += exp(L_t) q_t^T S_in
        y_inter = jnp.einsum("bthd,bhdv->bthv", qb * jnp.exp(lcum)[..., None], s_in)
        # state update: S_out = exp(L_tot) S_in + sum_s exp(L_tot - L_s) k_s v_s^T
        kw = kb * jnp.exp(ltot[:, None] - lcum)[..., None]
        s_out = s_in * jnp.exp(ltot)[..., None, None] + jnp.einsum(
            "bshd,bshv->bhdv", kw, vb
        )
        return s_out, (y_intra + y_inter)

    s_fin, ys = jax.lax.scan(step, s0, jnp.arange(nc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * chunk, h, dv)[:, :t]
    return y.astype(v.dtype), s_fin


def gla_step(q, k, v, log_a, s):
    """Single-token recurrent step. q,k: (B, H, dk); v: (B, H, dv);
    log_a: (B, H); s: (B, H, dk, dv). Returns (y (B, H, dv), s')."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    s_new = s * a + jnp.einsum("bhd,bhv->bhdv", k.astype(jnp.float32), v.astype(jnp.float32))
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), s_new)
    return y.astype(v.dtype), s_new


# ---------------------------------------------------------------------------
# Causal depthwise conv (width d_conv) with decode state
# ---------------------------------------------------------------------------
def causal_conv(x, w, conv_state=None):
    """x: (B, T, C); w: (d_conv, C). Returns (y, new_state (B, d_conv-1, C)).

    Implemented as shifted adds (d_conv is tiny: 4)."""
    dconv, c = w.shape
    b, t, _ = x.shape
    if conv_state is None:
        hist = jnp.zeros((b, dconv - 1, c), x.dtype)
    else:
        hist = conv_state.astype(x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)  # (B, T + dconv - 1, C)
    y = jnp.zeros((b, t, c), jnp.float32)
    for j in range(dconv):
        y = y + xp[:, j : j + t].astype(jnp.float32) * w[j].astype(jnp.float32)
    new_state = xp[:, -(dconv - 1) :] if dconv > 1 else jnp.zeros((b, 0, c), x.dtype)
    return jax.nn.silu(y).astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------
def _mamba_dims(cfg):
    ssm = cfg.ssm
    d_in = cfg.d_model * ssm.expand
    n_heads = d_in // ssm.head_dim
    return d_in, n_heads, ssm.d_state, ssm.head_dim, ssm.d_conv


def mamba2_params(cfg):
    d, dt = cfg.d_model, cfg.param_dtype
    d_in, h, n, p_dim, dconv = _mamba_dims(cfg)
    proj_out = 2 * d_in + 2 * n + h  # z, x, B, C, dt
    return {
        "in_proj": ParamDef((proj_out, d), ("ffn", "embed"), dt),
        "conv_w": ParamDef((dconv, d_in + 2 * n), ("conv", None), dt, "normal", 0.5),
        "dt_bias": ParamDef((h,), (None,), "float32", "zeros"),
        "a_log": ParamDef((h,), (None,), "float32", "ones"),
        "d_skip": ParamDef((h,), (None,), "float32", "ones"),
        "out_norm": {"scale": ParamDef((d_in,), ("ffn",), dt, "ones")},
        "out_proj": ParamDef((d, d_in), ("embed", "ffn"), dt),
    }


def init_mamba2_cache(cfg, batch, dtype=jnp.float32):
    d_in, h, n, p_dim, dconv = _mamba_dims(cfg)
    return {
        "ssm": jnp.zeros((batch, h, n, p_dim), jnp.float32),
        "conv": jnp.zeros((batch, dconv - 1, d_in + 2 * n), dtype),
    }


def mamba2_block(p, x, cfg, cache=None, a_fmt: Optional[str] = None):
    """x: (B, T, d). cache (decode): {'ssm', 'conv'}. Returns (y, new_cache)."""
    d_in, h, n, p_dim, dconv = _mamba_dims(cfg)
    b, t, _ = x.shape

    xq = quant_act(x, a_fmt)
    zxbcdt = linear(p["in_proj"], xq)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : d_in + d_in + 2 * n]
    dt_raw = zxbcdt[..., -h:]

    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = causal_conv(xbc, p["conv_w"], conv_state)
    xs = xbc[..., :d_in]
    b_in = xbc[..., d_in : d_in + n]  # (B, T, N), shared across heads (groups=1)
    c_in = xbc[..., d_in + n :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B, T, H)
    a = -jnp.exp(p["a_log"])  # (H,) negative
    log_decay = a[None, None, :] * dt  # (B, T, H) <= 0

    # v = dt * x per head: (B, T, H, P)
    v = xs.reshape(b, t, h, p_dim).astype(jnp.float32) * dt[..., None]
    q = jnp.broadcast_to(c_in[:, :, None, :], (b, t, h, n))
    k = jnp.broadcast_to(b_in[:, :, None, :], (b, t, h, n))

    s0 = cache["ssm"] if cache is not None else None
    if t == 1 and cache is not None:
        y1, s_new = gla_step(q[:, 0], k[:, 0], v[:, 0], log_decay[:, 0], s0)
        y = y1[:, None]
    else:
        # (B,H,dk,dv) layout: dk=n (state), dv=p (head channel)
        y, s_new = gla_chunked(q, k, v, log_decay, s0=s0, chunk=cfg.ssm.chunk)

    y = y + xs.reshape(b, t, h, p_dim).astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, t, d_in)
    y = norm(p["out_norm"], y.astype(x.dtype), "rmsnorm", cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = linear(p["out_proj"], quant_act(y, a_fmt))

    new_cache = None
    if cache is not None:
        new_cache = {"ssm": s_new, "conv": new_conv.astype(cache["conv"].dtype)}
    return out, new_cache
