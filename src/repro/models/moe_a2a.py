"""All-to-all expert parallelism (beyond-paper perf work — EXPERIMENTS.md
§Perf, deepseek-v3 train hillclimb).

The einsum-dispatch MoE (models/moe.py) keeps tokens data-sharded and
experts model-sharded; at deepseek-v3 scale that forces ZeRO-3 at rest and
GSPMD then ALL-GATHERS ~22 GB of expert weights per layer per direction —
the dominant collective term of the train_4k baseline (53.7 s).

This variant moves TOKENS instead of WEIGHTS (classic EP / DeepSpeed-MoE /
Switch):
  * experts shard over the WHOLE mesh (E == P ranks x E_loc); each rank's
    expert weights are fully local — no weight collectives at all;
  * each rank dispatches its own sequence shard (exactly the Megatron-SP
    residual shard, so no extra resharding on entry/exit);
  * dispatch is sort-based: assignments argsorted by expert id, packed into
    capacity-C per-destination slots (overflow dropped — same capacity
    semantics as the einsum path), moved with lax.all_to_all, FFN'd
    locally, moved back, combined by scatter-add with routing weights.

Traffic per layer per device ~= 2 directions x n_loc x top_k x d x cf
bytes — independent of expert count/size.

Enabled via set_moe_impl('a2a', mesh) (the launcher does this for train
cells when cfg.moe.n_experts is divisible by the mesh size).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .layers import activation, batched_linear, linear, mlp, quant_act
from .moe import _dispatch_masks

__all__ = ["set_moe_impl", "get_moe_impl", "moe_layer_a2a", "moe_decode_ep"]

_MOE_IMPL = [("einsum", None)]  # ('einsum'|'a2a'|'ep_decode', mesh)


def set_moe_impl(kind: str, mesh=None):
    _MOE_IMPL[0] = (kind, mesh)


def get_moe_impl():
    return _MOE_IMPL[0]


def _dispatch_local(x, logits, top_k: int, capacity: int, n_experts: int):
    """Sort-based local dispatch. x: (n, d); logits: (n, E) f32.
    Returns (send (E, C, d), combine_idx (n*k,), slot (n*k,), weight (n*k,))."""
    n, d = x.shape
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)  # (n, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_e = top_i.reshape(-1)  # (n*k,)
    flat_t = jnp.repeat(jnp.arange(n), top_k)
    flat_p = top_p.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_t = flat_t[order]
    sorted_p = flat_p[order]
    # position within expert run
    pos = jnp.arange(n * top_k) - jnp.searchsorted(sorted_e, sorted_e, side="left")
    keep = pos < capacity
    slot = jnp.where(keep, sorted_e * capacity + pos, n_experts * capacity)  # drop slot

    send = jnp.zeros((n_experts * capacity, d), x.dtype)
    send = send.at[slot].set(x[sorted_t], mode="drop")
    return send.reshape(n_experts, capacity, d), sorted_t, slot, sorted_p * keep


def _a2a2(x, axes):
    """all_to_all over one or two mesh axes. x: (P, C, d) with P = prod of
    axis sizes; returns the transposed exchange (P, C, d)."""
    if len(axes) == 1:
        return jax.lax.all_to_all(x, axes[0], split_axis=0, concat_axis=0,
                                  tiled=True)
    from repro.launch.mesh import axis_size

    a, b = axes
    na = axis_size(a)
    nb = axis_size(b)
    p, c, d = x.shape
    # (na, nb, C, d): exchange the inner axis first, then the outer
    x = x.reshape(na, nb * c, d)
    x = jax.lax.all_to_all(x, a, split_axis=0, concat_axis=0, tiled=True)
    x = x.reshape(na, nb, c, d).swapaxes(0, 1).reshape(nb, na * c, d)
    x = jax.lax.all_to_all(x, b, split_axis=0, concat_axis=0, tiled=True)
    x = x.reshape(nb, na, c, d).swapaxes(0, 1).reshape(p, c, d)
    return x


def _expert_ffn(recv, wu, wg, wd, act_kind, a_fmt, e_loc, capacity):
    """recv: (P, E_loc*C, d): for each source rank, the C slots of each of
    our E_loc experts. Regroup to (E_loc, P*C, d) for batched expert FFNs."""
    p = recv.shape[0]
    d = recv.shape[-1]
    t = recv.reshape(p, e_loc, capacity, d).swapaxes(0, 1).reshape(e_loc, p * capacity, d)
    tq = quant_act(t, a_fmt)
    up = jnp.einsum("etd,efd->etf", tq, wu, preferred_element_type=jnp.float32).astype(t.dtype)
    if wg is not None:
        g = jnp.einsum("etd,efd->etf", tq, wg, preferred_element_type=jnp.float32).astype(t.dtype)
        h = activation(g, act_kind) * up
    else:
        h = activation(up, act_kind)
    hq = quant_act(h, a_fmt)
    out = jnp.einsum("etf,edf->etd", hq, wd, preferred_element_type=jnp.float32).astype(t.dtype)
    # inverse regroup: (E_loc, P*C, d) -> (P, E_loc*C, d)
    out = out.reshape(e_loc, p, capacity, d).swapaxes(0, 1).reshape(p, e_loc * capacity, d)
    return out


def _ep_axes(mesh, n_experts: int):
    """EP axes for the expert stack, mirroring the placement rule in
    launch.sharding.serve_rules: the whole mesh when the expert count
    divides it, else the ('data', 'model') subset, else None (no EP)."""
    total = 1
    for a in mesh.shape:
        total *= mesh.shape[a]
    if n_experts % total == 0:
        return tuple(mesh.shape.keys())
    dm = tuple(a for a in ("data", "model") if a in mesh.shape)
    size = 1
    for a in dm:
        size *= mesh.shape[a]
    if dm and n_experts % size == 0:
        return dm
    return None


def moe_decode_ep(p, x, cfg, mesh, a_fmt: Optional[str] = None,
                  group_size: int = 1024):
    """Expert-parallel MoE for the *paged decode/prefill* path (serving on
    a mesh). x: (B, S, d) replicated -> (out (B, S, d), aux scalar).

    Routing, capacity math and the dispatch/combine einsums are the exact
    einsum-path code from models/moe.moe_layer — replicated on every rank,
    so token->expert assignment is identical to the single-device engine by
    construction. Only the three expert FFN GEMMs run inside a shard_map
    over the expert stack (the layout serve_rules already placed the W4A8
    expert weights in: dim0 over the EP axes, fully local — no weight
    gather). The combine einsum contracts the expert dim *outside* the
    shard_map, so GSPMD inserts the one all-reduce this layer needs — the
    same collective class as the TP MLP.

    Unlike moe_layer_a2a this has no sequence-divisibility constraint
    (decode steps are (B, 1, d)): tokens stay replicated, experts move
    nothing. Weights whose leading dim is not the expert count (e.g. a
    shared LoRC factor) force the replicated fallback."""
    m = cfg.moe
    e = m.n_experts
    axes = _ep_axes(mesh, e)
    stacked = {k: p[k] for k in ("wu", "wg", "wd") if k in p}
    if axes is None or any(
            getattr(l, "ndim", 0) < 1 or l.shape[0] != e
            for l in jax.tree.leaves(stacked)):
        from .moe import moe_layer

        return moe_layer(p, x, cfg, a_fmt=a_fmt, group_size=group_size)

    # -- replicated dispatch: verbatim moe_layer math ----------------------
    b, s, d = x.shape
    n = b * s
    g = max(n // group_size, 1)
    sg = -(-n // g)
    pad = g * sg - n
    capacity = max(int(sg * m.top_k / e * m.capacity_factor), 1)

    xf = x.reshape(n, d)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    xf = xf.reshape(g, sg, d)
    logits = linear(p["router"], xf.astype(jnp.float32))  # router in f32
    dispatch, combine, probs = _dispatch_masks(logits, m.top_k, capacity)
    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(jnp.float32)

    xq = quant_act(xf, a_fmt)
    ex_in = jnp.einsum("gsec,gsd->gecd", dispatch, xq)
    xe = jnp.moveaxis(ex_in, 1, 0).reshape(e, g * capacity, d)

    # -- expert FFNs: local shard of the expert stack ----------------------
    def ffn(xe_l, w):
        up = batched_linear(w["wu"], xe_l)
        if "wg" in w:
            h = activation(batched_linear(w["wg"], xe_l), cfg.act_kind) * up
        else:
            h = activation(up, cfg.act_kind)
        hq = quant_act(h, a_fmt)
        return batched_linear(w["wd"], hq)

    espec = jax.tree.map(
        lambda l: P(axes, *([None] * (l.ndim - 1))), stacked)
    eo = shard_map(ffn, mesh=mesh,
                   in_specs=(P(axes, None, None), espec),
                   out_specs=P(axes, None, None),
                   check_rep=False)(xe, stacked)

    ex_out = jnp.moveaxis(eo.reshape(e, g, capacity, d), 0, 1)
    out = jnp.einsum("gsec,gecd->gsd", combine, ex_out.astype(jnp.float32))
    out = out.reshape(g * sg, d)
    if pad:
        out = out[:n]
    out = out.reshape(b, s, d).astype(x.dtype)

    if m.n_shared_experts:
        out = out + mlp(p["shared"], x, cfg, a_fmt=a_fmt)

    frac_tokens = jnp.mean(
        jnp.sum(dispatch, axis=-1).astype(jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out, aux


def moe_layer_a2a(p, x, cfg, mesh, a_fmt: Optional[str] = None):
    """x: (B, S, d) with the residual in SP layout (batch over dp, seq over
    'model'). Returns (out, aux). Requires E % mesh_size == 0."""
    m = cfg.moe
    b, s, d = x.shape
    e = m.n_experts
    dp_only = tuple(a for a in ("pod", "data") if a in mesh.shape)

    def _size(ax):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n

    # widest EP degree that divides the expert count
    axes = None
    for cand in (("data", "model"), ("model",)):
        if all(a in mesh.shape for a in cand) and e % _size(cand) == 0:
            axes = cand
            break
    if axes is None:
        raise ValueError(f"E={e} not divisible by any mesh-axis product")
    psize = _size(axes)
    e_loc = e // psize

    msize = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1)
    n_loc = (b // dsize) * (s // msize) if s % msize == 0 else None
    assert n_loc, "seq must divide the model axis for a2a MoE"
    capacity = max(int(n_loc * m.top_k / e * m.capacity_factor), 1)

    router_w = p["router"]
    wu, wd = p["wu"], p["wd"]
    wg = p.get("wg")

    def body(xb, rw, wu_l, wg_l, wd_l):
        # xb: (B_loc, S_loc, d) — this rank's residual shard
        bl, sl, _ = xb.shape
        xf = xb.reshape(bl * sl, d)
        logits = (xf.astype(jnp.float32) @ rw.astype(jnp.float32).T)
        send, sorted_t, slot, weight = _dispatch_local(
            quant_act(xf, a_fmt), logits, m.top_k, capacity, e
        )
        # (E, C, d) -> (P, E_loc*C, d): chunk p holds the slots of the
        # experts owned by rank p (expert dim is rank-major sharded)
        send2 = send.reshape(psize, e_loc * capacity, d)
        recv = _a2a2(send2, axes)  # (P, E_loc*C, d): sources x our experts
        out_recv = _expert_ffn(recv, wu_l, wg_l, wd_l, cfg.act_kind, a_fmt,
                               e_loc, capacity)
        back = _a2a2(out_recv, axes).reshape(e * capacity, d)
        gathered = back[jnp.clip(slot, 0, e * capacity - 1)]
        yf = jnp.zeros((bl * sl, d), jnp.float32)
        yf = yf.at[sorted_t].add(gathered.astype(jnp.float32) * weight[:, None])
        # aux load-balance stats (local)
        frac = jnp.mean(jax.nn.one_hot(jnp.argmax(logits, -1), e), axis=0)
        aux = e * jnp.sum(frac * jnp.mean(jax.nn.softmax(logits, -1), axis=0))
        aux = jax.lax.pmean(aux, axes)
        return yf.reshape(bl, sl, d).astype(xb.dtype), aux

    expert_spec = P(axes, None, None)
    if wg is not None:
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(P(dp_only, "model", None), P(None, None), expert_spec,
                      expert_spec, expert_spec),
            out_specs=(P(dp_only, "model", None), P()),
            check_rep=False,
        )
        out, aux = fn(x, router_w, wu, wg, wd)
    else:
        fn = shard_map(
            lambda xb, rw, a, c: body(xb, rw, a, None, c), mesh=mesh,
            in_specs=(P(dp_only, "model", None), P(None, None), expert_spec,
                      expert_spec),
            out_specs=(P(dp_only, "model", None), P()),
            check_rep=False,
        )
        out, aux = fn(x, router_w, wu, wd)

    if m.n_shared_experts:
        out = out + mlp(p["shared"], x, cfg, a_fmt=a_fmt)
    return out, aux
