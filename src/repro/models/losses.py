"""Loss computation — sequence-chunked cross entropy.

Materializing (B, S, vocab) f32 logits at vocab=256k would cost tens of GB
per device; instead the head matmul + log-softmax run inside a lax.scan over
sequence chunks, so the live logits buffer is (B, chunk, vocab/TP).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["chunked_xent", "mtp_loss"]


def chunked_xent(hidden, head_w, labels, mask=None, chunk: int = 512):
    """hidden: (B, S, d); head_w: (V, d); labels: (B, S) int32.

    Returns (mean_nll, n_tokens). mask: (B, S) float/bool or None.
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(
            mask if mask is not None else jnp.ones((b, s), jnp.float32),
            ((0, 0), (0, pad)),
        )
    elif mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    mask = mask.astype(jnp.float32)

    hs = hidden.reshape(b, nc, chunk, d)
    ls = labels.reshape(b, nc, chunk)
    ms = mask.reshape(b, nc, chunk)

    def step(acc, ci):
        nll_sum, tok_sum = acc
        h = hs[:, ci]  # (B, c, d)
        from .layers import accum_dtype

        logits = jax.lax.dot_general(
            h, head_w, (((2,), (1,)), ((), ())), preferred_element_type=accum_dtype()
        ).astype(jnp.float32)  # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[:, ci][..., None], axis=-1)[..., 0]
        nll = (lse - gold) * ms[:, ci]
        return (nll_sum + jnp.sum(nll), tok_sum + jnp.sum(ms[:, ci])), None

    (nll_sum, tok_sum), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), jnp.arange(nc)
    )
    return nll_sum / jnp.maximum(tok_sum, 1.0), tok_sum


def mtp_loss(params, cfg, hidden, tokens, labels, seg, block_apply_fn, head_w, chunk=512):
    """DeepSeek-V3-style Multi-Token Prediction (depth 1): combine the main
    hidden state with the embedding of the next token, run one extra block,
    predict token t+2. Returns the mean extra nll (caller weights it)."""
    p = params["mtp"]
    b, s = tokens.shape
    # shift: combine h_t with embed(token_{t+1}) to predict label_{t+1} (=t+2 token)
    nxt = jnp.take(params["embed"], tokens[:, 1:], axis=0)  # (B, S-1, d)
    h_in = jnp.concatenate([hidden[:, :-1], nxt.astype(hidden.dtype)], axis=-1)
    # pad back to the full sequence length: keeps every (seq % mesh-axis)
    # divisibility property of the main path (a2a MoE, SP residual)
    h_in = jnp.pad(h_in, ((0, 0), (0, 1), (0, 0)))
    from .layers import linear, norm

    h_in = linear(p["proj"], h_in)
    positions = jnp.arange(s)
    h_out, _, _ = block_apply_fn(p["block"], h_in, cfg, seg, positions)
    h_out = norm(p["ln"], h_out, cfg.norm_kind, cfg.norm_eps)
    loss, _ = chunked_xent(h_out[:, : s - 1], head_w, labels[:, 1:], chunk=chunk)
    return loss
