"""Mixture-of-Experts layer (OLMoE 64e/top8, DeepSeek-V3 256e/top8+shared).

SPMD design (TPU-native, GSPMD-friendly — see DESIGN.md §4):
  * tokens stay sharded over the 'data' axis; experts shard over 'model'
    (expert parallelism). Activations entering the layer are replicated
    across 'model' (standard TP residual stream), so every model rank can
    locally build the dispatch for *its* experts — no token-redistribution
    all-to-all. The only collective is the final partial-sum all-reduce over
    'model' of the combined outputs, the same volume class as a TP MLP.
  * dispatch is the capacity-bounded one-hot einsum (t5x/flaxformer style):
    tokens are processed in fixed-size groups; each group dispatches at most
    C = group_size * top_k / E * capacity_factor tokens per expert; overflow
    tokens are dropped (their residual passes through). Group size bounds
    the dispatch-mask memory to (group, E, C) per step.
  * router runs in f32 (softmax over experts), jitter optional.

Weights are stored stacked: wg/wu (E, d_ff, d), wd (E, d, d_ff) — the
quantizable unit for the paper's W4A8 path is the (d_ff, d) slice per
expert (FGQ groups along d).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import ParamDef, activation, batched_linear, linear, mlp, mlp_params, quant_act

__all__ = ["moe_params", "moe_layer"]


def moe_params(cfg):
    d, dt = cfg.d_model, cfg.param_dtype
    m = cfg.moe
    e, ff = m.n_experts, m.d_ff
    p = {
        "router": ParamDef((e, d), ("expert", "embed"), dt, "normal", 1.0),
        "wu": ParamDef((e, ff, d), ("expert", "ffn", "embed"), dt),
        "wd": ParamDef((e, d, ff), ("expert", "embed", "ffn"), dt),
    }
    if cfg.mlp_gated:
        p["wg"] = ParamDef((e, ff, d), ("expert", "ffn", "embed"), dt)
    if m.n_shared_experts:
        shared_ff = (m.shared_d_ff or ff) * m.n_shared_experts
        p["shared"] = mlp_params(cfg, d_ff=shared_ff)
    return p


def _dispatch_masks(logits, top_k: int, capacity: int):
    """logits: (G, S, E) f32 -> (dispatch (G,S,E,C) bool, combine (G,S,E,C) f32).

    Position-in-expert is priority-ordered by token position (drop-late).
    """
    g, s, e = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, top_k)  # (G, S, K)
    # normalize the chosen probabilities (deepseek/olmoe convention)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # expert one-hot per k-slot: (G, S, K, E)
    oh = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)
    # priority: earlier tokens first, k-slots in order. Flatten (S, K).
    ohf = oh.reshape(g, s * top_k, e)
    pos = jnp.cumsum(ohf, axis=1) - ohf  # position of each assignment in its expert
    keep = pos < capacity
    posc = jnp.where(keep, pos, 0).astype(jnp.int32)
    pos_oh = jax.nn.one_hot(posc, capacity, dtype=jnp.float32) * keep[..., None]
    # (G, S*K, E, C) -> fold k back, combine weights
    disp = (ohf[..., None] * pos_oh).reshape(g, s, top_k, e, capacity)
    comb = disp * top_p[..., None, None]
    dispatch = jnp.sum(disp, axis=2)  # (G, S, E, C)
    combine = jnp.sum(comb, axis=2)
    return dispatch, combine, probs


def moe_layer(p, x, cfg, a_fmt: Optional[str] = None, group_size: int = 1024):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    g = max(n // group_size, 1)
    sg = -(-n // g)
    pad = g * sg - n  # MTP paths feed S-1 tokens; pad to a full grid
    e = m.n_experts
    capacity = max(int(sg * m.top_k / e * m.capacity_factor), 1)

    xf = x.reshape(n, d)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    xf = xf.reshape(g, sg, d)
    logits = linear(p["router"], xf.astype(jnp.float32))  # router in f32
    dispatch, combine, probs = _dispatch_masks(logits, m.top_k, capacity)
    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(jnp.float32)

    # expert inputs: (G, E, C, d) — E-sharded over 'model' via annotation
    xq = quant_act(xf, a_fmt)
    ex_in = jnp.einsum("gsec,gsd->gecd", dispatch, xq)

    # expert-major layout (E, G*C, d): the quantizable unit per expert is a
    # plain GEMM, so packed (W4A8) expert stacks run the fused batched
    # kernel directly — no dense dequantization on the pallas backend
    xe = jnp.moveaxis(ex_in, 1, 0).reshape(e, g * capacity, d)
    up = batched_linear(p["wu"], xe)  # (E, G*C, ff)
    if "wg" in p:
        h = activation(batched_linear(p["wg"], xe), cfg.act_kind) * up
    else:
        h = activation(up, cfg.act_kind)
    hq = quant_act(h, a_fmt)
    eo = batched_linear(p["wd"], hq)  # (E, G*C, d)
    ex_out = jnp.moveaxis(eo.reshape(e, g, capacity, d), 0, 1)

    out = jnp.einsum("gsec,gecd->gsd", combine, ex_out.astype(jnp.float32))
    out = out.reshape(g * sg, d)
    if pad:
        out = out[:n]
    out = out.reshape(b, s, d).astype(x.dtype)

    if m.n_shared_experts:
        out = out + mlp(p["shared"], x, cfg, a_fmt=a_fmt)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    frac_tokens = jnp.mean(jnp.sum(dispatch, axis=-1).astype(jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out, aux
