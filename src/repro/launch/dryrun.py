import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
)
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init). Do not move them.

import argparse  # noqa: E402
import gc  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import all_arch_names, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import roofline_terms  # noqa: E402
from repro.launch.shapes import SHAPES, shape_skip_reason  # noqa: E402
from repro.launch.steps import lower_cell  # noqa: E402

RESULTS_PATH = os.environ.get("DRYRUN_RESULTS", "dryrun_results.json")


def seq_flops_per_token(cfg, seq_or_cache: int) -> float:
    """Attention flops per token against a context of length L (causal avg
    for train/prefill handled by caller)."""
    if cfg.attn_kind == "mla":
        m = cfg.mla
        return 2 * cfg.n_heads * (m.qk_nope_dim + m.qk_rope_dim + m.v_head_dim) * seq_or_cache
    if cfg.attn_kind == "gqa":
        return 2 * cfg.n_heads * cfg.resolved_head_dim * 2 * seq_or_cache
    return 0.0


def model_flops(cfg, shape) -> float:
    """Global useful FLOPs for the cell: 6·N·D train / 2·N·D inference
    (N = active params), plus attention context terms."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        base = 6.0 * n_active * tokens
        attn = 3.0 * tokens * seq_flops_per_token(cfg, shape.seq // 2)
    elif shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        base = 2.0 * n_active * tokens
        attn = tokens * seq_flops_per_token(cfg, shape.seq // 2)
    else:  # decode: one token per sequence
        tokens = shape.batch
        base = 2.0 * n_active * tokens
        attn = tokens * seq_flops_per_token(cfg, shape.seq)
    return base + attn


def _kernel_adjust(terms, cfg, shape, total_dev):
    """Serving cells lower the jnp REFERENCE W4A8 path, which materializes a
    bf16 dequant of every weight (2 B/param write + 2 B/param read per use).
    The Pallas kernel instead streams packed FP4 codes + scales from HBM
    (0.5625 B/param) and decodes in VMEM. Adjust the memory term by the
    difference; both numbers are reported (§Roofline)."""
    import jax as _jax
    import numpy as _np

    from repro.core.policy import QuantPolicy
    from repro.core.ptq import is_quantizable
    from repro.models import build_def
    from repro.models.params import ParamDef

    defs = build_def(cfg)
    flat, _ = _jax.tree.flatten_with_path(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    q_params = 0
    for path, d in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if is_quantizable(d, pstr):
            q_params += int(_np.prod(d.shape))
    # per-device: weights are sharded across the whole mesh for serving
    q_dev = q_params / total_dev
    ref_traffic = 4.0 * q_dev  # bf16 dequant write + read
    kernel_traffic = 0.5625 * q_dev  # packed codes + per-group scales
    from .roofline import HW

    adj = max(ref_traffic - kernel_traffic, 0.0) / HW["hbm_bw"]
    terms["memory_s_ref"] = terms["memory_s"]
    terms["memory_s"] = max(terms["memory_s"] - adj, terms["compute_s"] * 0.0)
    terms["kernel_weight_adjust_s"] = adj
    bound = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["dominant"] = max(
        [("compute", terms["compute_s"]), ("memory", terms["memory_s"]),
         ("collective", terms["collective_s"])], key=lambda kv: kv[1])[0]
    if "model_flops" in terms:
        ideal = (terms["model_flops"] / total_dev) / HW["peak_flops"]
        terms["roofline_fraction"] = ideal / max(bound, 1e-30)


def _flash_adjust(terms, cfg, shape, mesh):
    """OPT-IN (REPRO_FLASH_ADJUST=1, used for §Perf optimized numbers):
    replace the jnp attention's measured softmax-materialization traffic by
    the flash-attention kernel's (kernels/flash_attn.py — validated in
    interpret mode). The jnp path materializes the (S, S)-class f32 scores
    ~5x per attention (dot write, mask add, sub-exp, divide, convert; each
    read+write); flash keeps the tile in VMEM and writes only the (S, dv)
    output. We subtract 4 of ~5 score passes (conservative: TPU fusion
    would already merge some)."""
    if cfg.attn_kind not in ("gqa", "mla") or shape.kind == "decode":
        return
    msize = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    if shape.kind == "train":
        reps = 3.0  # fwd + remat-fwd + bwd
        b_loc = max(shape.batch // dsize, 1)
        if cfg.param_count() >= 100e9:
            b_loc = max(b_loc // 4, 1)  # grad-accum microbatching
            reps *= 4
    else:
        reps = 1.0
        b_loc = max(shape.batch // dsize, 1)
    h_loc = max(cfg.n_heads // msize, 1)
    s = shape.seq
    enc = cfg.encoder_layers or 0
    layers = cfg.n_layers + enc
    score_bytes = b_loc * h_loc * float(s) * s * 4.0
    saved = 4 * 2 * score_bytes * layers * reps / (1 if shape.kind == "train" else 1)
    from .roofline import HW

    adj = saved / HW["hbm_bw"]
    terms["memory_s_jnp"] = terms["memory_s"]
    terms["memory_s"] = max(terms["memory_s"] - adj, terms["compute_s"])
    terms["flash_adjust_s"] = adj
    bound = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["dominant"] = max(
        [("compute", terms["compute_s"]), ("memory", terms["memory_s"]),
         ("collective", terms["collective_s"])], key=lambda kv: kv[1])[0]
    if "model_flops" in terms:
        total_dev = int(np.prod(list(mesh.shape.values())))
        ideal = (terms["model_flops"] / total_dev) / HW["peak_flops"]
        terms["roofline_fraction"] = ideal / max(bound, 1e-30)


def run_cell(arch: str, shape_name: str, mesh, multi_pod: bool, verbose: bool = True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = shape_skip_reason(cfg, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "reason": skip}

    t0 = time.time()
    lowered, meta = lower_cell(cfg, shape, mesh)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    total_dev = mesh.devices.size

    terms = roofline_terms(cost, hlo, total_dev, model_flops(cfg, shape))
    if shape.kind in ("prefill", "decode"):
        _kernel_adjust(terms, cfg, shape, total_dev)
    if os.environ.get("REPRO_FLASH_ADJUST") and shape.kind in ("train", "prefill"):
        _flash_adjust(terms, cfg, shape, mesh)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "mode": meta["mode"],
        "profile": {k: str(v) for k, v in meta["profile"].items()},
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "total_bytes": mem.argument_size_in_bytes + mem.temp_size_in_bytes,
        },
        "roofline": {
            k: v for k, v in terms.items() if k != "collective"
        },
        "collective": terms["collective"],
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {rec['mesh']}] OK "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
              f"arg {mem.argument_size_in_bytes/2**30:.2f} GiB "
              f"temp {mem.temp_size_in_bytes/2**30:.2f} GiB | "
              f"compute {terms['compute_s']*1e3:.2f} ms "
              f"memory {terms['memory_s']*1e3:.2f} ms "
              f"collective {terms['collective_s']*1e3:.2f} ms "
              f"-> {terms['dominant']}-bound, "
              f"roofline {terms.get('roofline_fraction', 0):.2%}")
        print(f"  memory_analysis: {mem}")
    del compiled, lowered
    gc.collect()
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=RESULTS_PATH)
    args = ap.parse_args()

    archs = all_arch_names() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for mesh_kind in meshes:
        mesh = make_production_mesh(multi_pod=mesh_kind == "multi")
        for arch in archs:
            for shape_name in shapes:
                key = (arch, shape_name, mesh_kind)
                if any((r["arch"], r["shape"], r.get("mesh", "single")) == key
                       and r["status"] in ("ok", "skipped") for r in results):
                    print(f"[{arch} x {shape_name} x {mesh_kind}] cached, skipping")
                    continue
                try:
                    rec = run_cell(arch, shape_name, mesh, mesh_kind == "multi")
                except Exception as e:  # noqa: BLE001 — record and continue
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                    print(f"[{arch} x {shape_name} x {mesh_kind}] FAILED: {rec['error']}")
                results = [r for r in results
                           if (r["arch"], r["shape"], r.get("mesh", "single")) != key]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                gc.collect()

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"-> {args.out}")
    sys.exit(1 if n_err else 0)


if __name__ == "__main__":
    main()
