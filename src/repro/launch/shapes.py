"""Assigned input shapes and their ShapeDtypeStruct stand-ins.

  train_4k      seq 4096,   global_batch 256   -> train_step
  prefill_32k   seq 32768,  global_batch 32    -> prefill_step
  decode_32k    seq 32768,  global_batch 128   -> serve_step (1 new token,
                                                  KV cache of seq_len)
  long_500k     seq 524288, global_batch 1     -> serve_step; only for
                                                  sub-quadratic archs

Skips (DESIGN.md §5/§6): long_500k is skipped for pure full-attention archs
(whisper, minitron, nemotron, minicpm3, olmo, deepseek, olmoe, llava); runs
for xlstm-125m (ssm) and zamba2-1.2b (hybrid). No encoder-only archs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

__all__ = ["SHAPES", "ShapeSpec", "cell_supported", "batch_specs", "shape_skip_reason"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_skip_reason(cfg, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and not cfg.is_sub_quadratic:
        return "full-attention arch: long_500k needs sub-quadratic attention (DESIGN.md §5)"
    return None


def cell_supported(cfg, shape_name: str) -> bool:
    return shape_skip_reason(cfg, shape_name) is None


def batch_specs(cfg, shape: ShapeSpec):
    """ShapeDtypeStructs for the step's data inputs (weak-type-correct,
    shardable, no allocation)."""
    sds = jax.ShapeDtypeStruct
    b = shape.batch
    if shape.kind == "train":
        s = shape.seq
        out = {}
        n_prefix = 0
        if cfg.frontend == "vision_patches":
            n_prefix = cfg.n_patches
            out["patches"] = sds((b, n_prefix, 1024), jnp.float32)
        if cfg.encoder_layers:
            out["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), jnp.float32)
        out["tokens"] = sds((b, s - n_prefix), jnp.int32)
        out["labels"] = sds((b, s - n_prefix), jnp.int32)
        return out
    if shape.kind == "prefill":
        s = shape.seq
        out = {}
        n_prefix = 0
        if cfg.frontend == "vision_patches":
            n_prefix = cfg.n_patches
            out["patches"] = sds((b, n_prefix, 1024), jnp.float32)
        if cfg.encoder_layers:
            out["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), jnp.float32)
        out["tokens"] = sds((b, s - n_prefix), jnp.int32)
        return out
    # decode: one new token; the KV/SSM cache of size shape.seq is a
    # separate input built by cache_specs()
    return {"tokens": sds((b, 1), jnp.int32)}
