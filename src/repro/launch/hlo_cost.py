"""Trip-count-aware cost model over compiled HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts each ``while`` body ONCE
— a scan over 96 layers reports 1/96th of the real FLOPs (verified
empirically; see EXPERIMENTS.md §Dry-run). Since every model here scans its
layer stack (and chunked attention scans q/kv blocks), we walk the
post-optimization HLO text ourselves:

  * computations are parsed into (name -> [ops]) with a per-computation
    symbol table of operand shapes;
  * ``while`` ops multiply their body+condition cost by the trip count
    (read from the ``constant(N)`` in the condition computation — lax.scan
    lowers to exactly this form);
  * ``dot``: flops = 2 * prod(result dims) * prod(contracting dims);
  * bytes use a write-centric traffic model: 2 x result bytes per
    materializing op (one write + one later read), + dot/reduce operand
    reads, + 2 x slice/update sizes for (dynamic-)slice/update ops. Counting
    full fusion-operand sizes would wildly over-count scans, where the
    stacked (n_layers, ...) weight arrays appear as loop-body fusion
    operands but each iteration only touches one layer's slice;
  * collectives are recorded with their enclosing trip-count multiplier —
    a per-layer all-gather inside the scan counts layers-many times;
  * ``conditional`` takes the max across branches (upper bound; noted).

CPU f32-dot correction (``bf16_model=True``): this CPU backend's DotThunk
supports neither BF16xBF16=F32 nor =BF16, so XLA rewrites EVERY bf16 matmul
to convert-to-f32 + f32 dot. Model code here keeps all matmul inputs and
outputs bf16 by construction, so any f32 dot operand/result — and any f32
collective (GSPMD places weight/activation gathers on the converted-f32
side) — is a CPU lowering artifact that a TPU build would carry in bf16.
With the flag on, those count at 2 bytes/element. Raw (uncorrected) numbers
are reported alongside in §Roofline. Known residual error: legitimately-f32
collectives (logsumexp partials, scalar aux) are also halved — they are
<1 percent of traffic in every measured cell.

All numbers are PER-DEVICE (the module is post-SPMD-partitioning).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fnuz|f8e4m3fn|f8e4m3|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OP_RE = re.compile(r"\)?\s*([a-z][a-z0-9\-]*)\(")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\(((?:%[\w.\-]+(?:,\s*)?)+)\)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_PAIR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_ELEMENTWISE_SKIP = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "copy", "copy-start", "copy-done", "reshape", "transpose", "broadcast",
    "iota", "after-all", "partition-id", "replica-id", "custom-call",
    "infeed", "outfeed", "rng-get-and-update-state",
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                    "collective-permute")


def _shapes_of(typestr: str) -> List[Tuple[str, List[int]]]:
    return [(m.group(1), [int(x) for x in m.group(2).split(",") if x])
            for m in _SHAPE_RE.finditer(typestr)]


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _nelems(shapes) -> int:
    total = 0
    for _dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    line: str
    result_shapes: list
    operands: list


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: list = dataclasses.field(default_factory=list)

    @property
    def collective_bytes(self) -> float:
        return sum(c["bytes"] * c["count"] for c in self.collectives)

    @property
    def collective_traffic(self) -> float:
        return sum(c["traffic"] * c["count"] for c in self.collectives)


def _parse_computations(text: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    cur: Optional[str] = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        ls = line.strip()
        if not ls or ls.startswith("//") or ls.startswith("HloModule"):
            continue
        hdr = _COMP_HDR_RE.match(ls)
        if hdr and ("->" in ls):
            cur = hdr.group(1)
            comps[cur] = []
            if ls.startswith("ENTRY"):
                entry = cur
            continue
        if ls == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(ls)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # opcode: first `word(` after the type expression. Find all; take
        # the first that is a known op-looking token following the shapes.
        # Strategy: strip the leading type expression (up to the first
        # space-delimited token containing '[' closing), then match.
        opm = None
        # find opcode as the token right before the first '(' that is
        # preceded by space and not part of a shape
        paren_ops = re.findall(r"([a-z][a-z0-9\-]*)\(", rhs)
        opcode = None
        for cand in paren_ops:
            if cand not in ("", ):
                opcode = cand
                break
        if opcode is None:
            continue
        result_shapes = _shapes_of(rhs.split(opcode + "(", 1)[0])
        operands = re.findall(r"%([\w.\-]+)", rhs.split(opcode + "(", 1)[1].split(")", 1)[0]) if opcode + "(" in rhs else []
        comps[cur].append(_Op(name, opcode, ls, result_shapes, operands))
    comps["__entry__"] = comps.get(entry, [])
    if entry:
        comps["__entry_name__"] = entry  # type: ignore[assignment]
    return comps


def _symbol_shapes(ops: List[_Op]) -> Dict[str, list]:
    table = {}
    for op in ops:
        table[op.name] = op.result_shapes
    return table


def _trip_count(comps, cond_name: str) -> int:
    ops = comps.get(cond_name, [])
    consts = []
    for op in ops:
        for m in _CONST_RE.finditer(op.line):
            consts.append(int(m.group(1)))
    # also look into fusions called from the condition
    for op in ops:
        cm = _CALLS_RE.search(op.line)
        if cm and cm.group(1) in comps:
            for op2 in comps[cm.group(1)]:
                for m in _CONST_RE.finditer(op2.line):
                    consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_PAIR_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return total_devices


def _dot_flops(op: _Op, table) -> float:
    res = _nelems(op.result_shapes)
    cm = _CONTRACT_RE.search(op.line)
    contract = 1
    if cm and op.operands:
        lhs_shape = table.get(op.operands[0])
        if lhs_shape:
            dims = lhs_shape[0][1]
            for idx in (int(x) for x in cm.group(1).split(",") if x):
                if idx < len(dims):
                    contract *= dims[idx]
    return 2.0 * res * contract


def _coll_traffic(kind: str, nbytes: int, n: int) -> float:
    if kind == "all-reduce":
        return 2 * (n - 1) / max(n, 1) * nbytes
    if kind == "all-gather":
        return (n - 1) / max(n, 1) * nbytes
    if kind == "reduce-scatter":
        return (n - 1) / max(n, 1) * nbytes * n
    if kind == "all-to-all":
        return (n - 1) / max(n, 1) * nbytes
    return float(nbytes)  # collective-permute


def _f32_half(shapes, corrected: bool) -> float:
    """Bytes of ``shapes`` with f32 counted at 2 B/elem when corrected."""
    if not corrected:
        return _nbytes(shapes)
    total = 0.0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        bpe = _DTYPE_BYTES.get(dt, 4)
        if dt == "f32":
            bpe = 2
        total += n * bpe
    return total


def _comp_cost(comps, name: str, total_devices: int, memo: dict,
               mult: float = 1.0, bf16_model: bool = True) -> HloCost:
    """Cost of one computation, WITHOUT the outer multiplier applied to the
    returned aggregate (caller scales); collectives carry their own count."""
    if name in memo:
        base = memo[name]
    else:
        ops = comps.get(name, [])
        table = _symbol_shapes(ops)
        base = HloCost()
        for op in ops:
            oc = op.opcode
            if oc == "while":
                cond = _COND_RE.search(op.line)
                body = re.search(r"body=%?([\w.\-]+)", op.line)
                trips = _trip_count(comps, cond.group(1)) if cond else 1
                if body:
                    inner = _comp_cost(comps, body.group(1), total_devices, memo, bf16_model=bf16_model)
                    base.flops += trips * inner.flops
                    base.bytes += trips * inner.bytes
                    for c in inner.collectives:
                        base.collectives.append(dict(c, count=c["count"] * trips))
                continue
            if oc == "conditional":
                bm = _BRANCHES_RE.search(op.line)
                branches = []
                if bm:
                    branches = [b.strip().lstrip("%") for b in bm.group(1).split(",")]
                else:
                    branches = re.findall(r"(?:true_computation|false_computation)=%?([\w.\-]+)", op.line)
                if branches:
                    costs = [_comp_cost(comps, b, total_devices, memo, bf16_model=bf16_model) for b in branches]
                    worst = max(costs, key=lambda c: c.flops + c.bytes)
                    base.flops += worst.flops
                    base.bytes += worst.bytes
                    base.collectives.extend(worst.collectives)
                continue
            if oc in ("fusion", "call", "map", "reduce", "reduce-window", "sort", "scatter", "select-and-scatter"):
                if bf16_model and op.name.startswith(("wrapped_convert", "convert_bitcast")):
                    # standalone input-convert fusions only exist because the
                    # CPU DotThunk can't consume bf16; TPU reads bf16 directly
                    # (the dot's operand pass is charged at the dot).
                    res_bytes = 0
                elif bf16_model and op.name.startswith("copy_"):
                    # functional cache copies: elided on TPU by buffer
                    # donation/aliasing (donate_argnums is set; the CPU
                    # backend ignores donation and keeps the copies).
                    res_bytes = 0
                elif "dynamic-update-slice" in op.name:
                    # in-place update traffic = the slice payloads only.
                    # Full buffers (the aliased result, stacked scan buffers
                    # read via fused dynamic-slice) are NOT streamed per
                    # step on TPU. Heuristic: operands <= 4 MiB are
                    # payloads (same threshold as the dot VMEM-residency
                    # rule); larger ones are aliased/sliced stacked buffers
                    # whose per-step traffic is slice-sized. Known
                    # under-count: >4 MiB one-shot updates (prefill cache
                    # writes) — bounded by cache-size/step, negligible vs
                    # the prefill terms.
                    sizes = [_nbytes(table.get(o, [])) for o in op.operands]
                    res_bytes = sum(b for b in sizes if b <= 4 * 2**20)
                elif bf16_model and "convert" in op.name:
                    res_bytes = _f32_half(op.result_shapes, True)
                else:
                    res_bytes = _nbytes(op.result_shapes)
                base.bytes += 2 * res_bytes  # write + one later read
                cm = _CALLS_RE.search(op.line)
                if cm and cm.group(1) in comps:
                    inner = _comp_cost(comps, cm.group(1), total_devices, memo, bf16_model=bf16_model)
                    # inner flops count; inner bytes DON'T (fusion), except
                    # for 'call' which is a real boundary
                    base.flops += inner.flops
                    if oc == "call":
                        base.bytes += inner.bytes
                    for c in inner.collectives:
                        base.collectives.append(dict(c))
                elif oc in ("reduce", "reduce-window"):
                    base.flops += sum(_nelems(table.get(o, [])) for o in op.operands)
                    base.bytes += sum(_nbytes(table.get(o, [])) for o in op.operands)
                continue
            coll = next((k for k in COLLECTIVE_KINDS if oc == k or oc == k + "-start"), None)
            if coll:
                nb = _f32_half(op.result_shapes, bf16_model)
                if coll == "reduce-scatter":
                    nb = sum(_f32_half(table.get(o, []), bf16_model) for o in op.operands) or nb
                    traffic = (max(_group_size(op.line, total_devices), 1) - 1) / max(
                        _group_size(op.line, total_devices), 1) * nb
                    base.collectives.append({"kind": coll, "bytes": nb, "count": 1,
                                             "group": _group_size(op.line, total_devices),
                                             "traffic": traffic})
                else:
                    n = _group_size(op.line, total_devices)
                    base.collectives.append({"kind": coll, "bytes": nb, "count": 1,
                                             "group": n,
                                             "traffic": _coll_traffic(coll, nb, n)})
                base.bytes += 2 * nb
                continue
            if oc in ("dot", "dot-general"):
                base.flops += _dot_flops(op, table)
                # VMEM-residency assumption: operands under 4 MiB of an
                # in-loop dot stay resident on TPU (128 MiB VMEM) instead of
                # being re-read from HBM every trip — without this, a
                # recurrent cell (sLSTM: 4096 sequential steps) charges its
                # 2 MiB weights per step and reports 100x the real traffic.
                opnd_bytes = sum(
                    b for b in (
                        _f32_half(table.get(o, []), bf16_model) for o in op.operands
                    ) if b >= 4 * 2**20
                )
                base.bytes += opnd_bytes + _f32_half(op.result_shapes, bf16_model)
                continue
            if oc == "convolution":
                # flops ~ 2 * result elems * (kernel elems per output)
                base.flops += 2.0 * _nelems(op.result_shapes) * max(
                    (_nelems(table.get(op.operands[1], [])) // max(_nelems(op.result_shapes), 1)), 1
                )
                base.bytes += sum(_nbytes(table.get(o, [])) for o in op.operands) + _nbytes(op.result_shapes)
                continue
            if oc in ("dynamic-slice", "gather"):
                base.bytes += 2 * _nbytes(op.result_shapes)
                continue
            if oc in ("dynamic-update-slice",):
                upd = _nbytes(table.get(op.operands[1], [])) if len(op.operands) > 1 else 0
                base.bytes += 2 * upd
                continue
            if oc in _ELEMENTWISE_SKIP:
                continue
            # generic elementwise / compare / select / convert / exp ...
            ne = _nelems(op.result_shapes)
            base.flops += ne
            base.bytes += ne and 0  # inside top-level: usually fused; don't double count
        memo[name] = base
    return base


def analyze_hlo(hlo_text: str, total_devices: int, bf16_model: bool = True) -> HloCost:
    comps = _parse_computations(hlo_text)
    entry_name = comps.get("__entry_name__")
    memo: dict = {}
    if not isinstance(entry_name, str):
        # fall back: cost every computation once (upper-ish bound)
        entry_name = None
        for k in comps:
            if k.startswith("main"):
                entry_name = k
                break
    base = _comp_cost(comps, entry_name or "__entry__", total_devices, memo,
                      bf16_model=bf16_model)
    return base
