"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips across DCN.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax import; smoke
tests and benches must keep seeing 1 device).
"""
from __future__ import annotations

import jax

__all__ = ["make_mesh", "make_production_mesh", "dp_axes", "axis_size",
           "MESH_AXES"]

MESH_AXES = {"single": ("data", "model"), "multi": ("pod", "data", "model")}


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where the installed jax has them
    (>= 0.5); on older jax (0.4.x) axis types don't exist and every axis is
    implicitly Auto, so the plain call is equivalent. All mesh construction
    (tests included) goes through here so the repo runs on both pins."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """Axes that carry the batch (pod composes with data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def axis_size(name):
    """Mapped-axis size inside shard_map bodies. jax >= 0.5 has
    lax.axis_size; the 0.4.x spelling is psum(1, axis), folded to a static
    int at trace time. The compat shim lives here with make_mesh so a jax
    pin bump touches one module."""
    size = getattr(jax.lax, "axis_size", None)
    if size is not None:
        return size(name)
    return jax.lax.psum(1, name)
