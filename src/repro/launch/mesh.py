"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips across DCN.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax import; smoke
tests and benches must keep seeing 1 device).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "dp_axes", "MESH_AXES"]

MESH_AXES = {"single": ("data", "model"), "multi": ("pod", "data", "model")}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def dp_axes(mesh) -> tuple:
    """Axes that carry the batch (pod composes with data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
