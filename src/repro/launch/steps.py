"""Step builders: train_step / prefill_step / serve_step with full sharding
specifications. The dry-run lowers exactly these functions; the CPU training
examples run them on a 1-device mesh.
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import models
from repro.core.policy import QuantPolicy
from repro.core.ptq import quantized_shape_tree
from repro.models.layers import set_accum_dtype, set_residual_sharding
from repro.models.moe_a2a import set_moe_impl
from repro.models.params import ParamDef, pspec_tree
from repro.optimizer import AdamWConfig, OptState, adamw_init, adamw_update

from .mesh import dp_axes
from .sharding import (
    batch_pspecs,
    cache_pspecs,
    profile_for,
    residual_spec,
    serve_rules,
    train_rules,
)
from .shapes import ShapeSpec, batch_specs

__all__ = [
    "TrainState",
    "make_train_step",
    "make_prefill_step",
    "make_serve_step",
    "train_state_shapes",
    "train_state_pspecs",
    "lower_cell",
]


class TrainState(NamedTuple):
    params: object
    opt: OptState


def train_state_shapes(cfg, opt_cfg: AdamWConfig):
    pshapes = models.param_shapes(cfg)
    mdt = jnp.dtype("bfloat16" if opt_cfg.moment_dtype == "fp8_sim" else opt_cfg.moment_dtype)
    mshape = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, mdt), pshapes)
    return TrainState(
        params=pshapes,
        opt=OptState(mu=mshape, nu=mshape, step=jax.ShapeDtypeStruct((), jnp.int32)),
    )


def train_state_pspecs(cfg, mesh, zero3: bool, moe_a2a: bool = False,
                       pure_dp: bool = False):
    prules, mrules = train_rules(cfg, mesh, zero3, moe_a2a=moe_a2a, pure_dp=pure_dp)
    defs = models.build_def(cfg)
    pspec = pspec_tree(defs, prules, mesh)
    mspec = pspec_tree(defs, mrules, mesh)
    return TrainState(
        params=pspec, opt=OptState(mu=mspec, nu=mspec, step=P())
    )


def make_train_step(cfg, opt_cfg: AdamWConfig, accum_steps: int = 1,
                    a_fmt: Optional[str] = None, grad_compress=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``grad_compress``: optional (compress, decompress) pair from
    runtime.compress — applied to the gradient pytree before the optimizer
    (the DP all-reduce then moves the compressed representation).
    """

    def loss_of(params, batch):
        loss, metrics = models.loss_fn(
            params, cfg, batch, a_fmt=a_fmt, remat=True,
            mtp_weight=0.3 if cfg.mtp_depth else 0.0,
        )
        return loss, metrics

    def one_grad(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(state: TrainState, batch):
        if accum_steps == 1:
            loss, metrics, grads = one_grad(state.params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:]),
                batch,
            )

            def acc_fn(carry, mb):
                loss, metrics, grads = one_grad(state.params, mb)
                acc_loss, acc_grads = carry
                return (acc_loss + loss / accum_steps,
                        jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / accum_steps,
                                     acc_grads, grads)), metrics

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (loss, grads), metrics = jax.lax.scan(
                acc_fn, (jnp.zeros((), jnp.float32), zero_g), micro
            )
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        if grad_compress is not None:
            compress, decompress = grad_compress
            grads = decompress(compress(grads))
        new_params, new_opt, om = adamw_update(state.params, grads, state.opt, opt_cfg)
        metrics = dict(metrics, **om, loss=loss)
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_prefill_step(cfg, max_seq: int, a_fmt: Optional[str] = None):
    """prefill_step(params, batch) -> (last_logits, caches)."""

    def prefill_step(params, batch):
        return models.prefill(params, cfg, batch, max_seq, a_fmt=a_fmt)

    return prefill_step


def make_serve_step(cfg, a_fmt: Optional[str] = "fp8_e4m3"):
    """serve_step(params, caches, tokens, cache_index) -> (logits, caches).
    ``params`` is the quantized serving checkpoint (PackedLinear leaves)."""

    def serve_step(params, caches, tokens, cache_index):
        return models.decode_step(params, cfg, tokens, caches, cache_index, a_fmt=a_fmt)

    return serve_step


# ---------------------------------------------------------------------------
# Cell lowering — the dry-run entry: (arch x shape x mesh) -> compiled
# ---------------------------------------------------------------------------
def _ns(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree (None specs -> replicated)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if isinstance(s, P) else P()),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def lower_cell(cfg, shape: ShapeSpec, mesh, policy: Optional[QuantPolicy] = None,
               opt_cfg: Optional[AdamWConfig] = None, seq_shard: Optional[bool] = None):
    """Lower (no execution) one cell. Returns (lowered, meta dict)."""
    prof = profile_for(cfg, mesh, shape.kind)
    policy = policy or QuantPolicy(w_fmt="fp4_e2m1", a_fmt="fp8_e4m3",
                                   scale_mode="m2", lorc_rank=8)
    bshapes = batch_specs(cfg, shape)
    bspecs = batch_pspecs(bshapes, mesh, dp=prof.get("dp"))
    defs = models.build_def(cfg)

    set_accum_dtype(jnp.bfloat16)  # TPU-mirroring lowering; see models.layers
    # all-to-all expert parallelism for MoE training (EXPERIMENTS.md §Perf):
    # tokens move instead of weights; requires E divisible by an axis product
    if (shape.kind == "train" and cfg.moe is not None
            and os.environ.get("REPRO_MOE_IMPL", "einsum") == "a2a"):
        try:
            total = int(np.prod(list(mesh.shape.values())))
            if cfg.moe.n_experts % total == 0 or cfg.moe.n_experts % mesh.shape.get("model", 1) == 0:
                set_moe_impl("a2a", mesh)
        except Exception:  # noqa: BLE001
            set_moe_impl("einsum", None)
    use_seq_shard = prof["seq_shard"] if seq_shard is None else seq_shard
    if use_seq_shard:
        set_residual_sharding(
            NamedSharding(mesh, residual_spec(mesh)),
            heads_sharding=NamedSharding(mesh, P(dp_axes(mesh), None, "model", None)),
        )
    else:
        set_residual_sharding(None)

    try:
        if shape.kind == "train":
            from repro.models.moe_a2a import get_moe_impl

            moe_a2a = get_moe_impl()[0] == "a2a" and cfg.moe is not None
            zero3 = prof["zero3"]
            # (measured & REFUTED, §Perf iteration 4: dropping ZeRO-3 on the
            # non-expert remainder under a2a saved only ~1% collective while
            # growing resident params by 3 GiB — keep ZeRO-3.)
            opt_cfg = opt_cfg or AdamWConfig(moment_dtype=prof["moment_dtype"])
            step = make_train_step(cfg, opt_cfg, accum_steps=prof["accum_steps"])
            state_shapes = train_state_shapes(cfg, opt_cfg)
            state_specs = train_state_pspecs(cfg, mesh, zero3, moe_a2a=moe_a2a,
                                             pure_dp=prof.get("pure_dp", False))
            fn = jax.jit(step,
                         in_shardings=(_ns(mesh, state_specs), _ns(mesh, bspecs)),
                         out_shardings=(_ns(mesh, state_specs), None),
                         donate_argnums=(0,))
            lowered = fn.lower(state_shapes, bshapes)
            return lowered, {"profile": prof, "mode": "train"}

        if shape.kind == "prefill":
            # serving path: quantized weights (the paper's W4A8 deployment)
            srules = serve_rules(cfg, mesh)
            qshapes = quantized_shape_tree(defs, policy)
            qspecs = _packed_pspecs(defs, policy, srules, mesh)
            step = make_prefill_step(cfg, max_seq=shape.seq, a_fmt=policy.a_fmt)
            cshape = jax.eval_shape(
                lambda: models.init_cache(cfg, shape.batch, shape.seq)
            )
            cspecs = cache_pspecs(cshape, mesh)
            fn = jax.jit(step,
                         in_shardings=(_ns(mesh, qspecs), _ns(mesh, bspecs)),
                         out_shardings=(None, _ns(mesh, cspecs)))
            lowered = fn.lower(qshapes, bshapes)
            return lowered, {"profile": prof, "mode": "prefill"}

        # decode
        srules = serve_rules(cfg, mesh)
        qshapes = quantized_shape_tree(defs, policy)
        qspecs = _packed_pspecs(defs, policy, srules, mesh)
        cshape = jax.eval_shape(lambda: models.init_cache(cfg, shape.batch, shape.seq))
        cspecs = cache_pspecs(cshape, mesh)
        step = make_serve_step(cfg, a_fmt=policy.a_fmt)
        fn = jax.jit(step,
                     in_shardings=(_ns(mesh, qspecs), _ns(mesh, cspecs),
                                   _ns(mesh, bspecs["tokens"]), None),
                     out_shardings=(None, _ns(mesh, cspecs)),
                     donate_argnums=(1,))
        idx = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = fn.lower(qshapes, cshape, bshapes["tokens"], idx)
        return lowered, {"profile": prof, "mode": "decode"}
    finally:
        set_residual_sharding(None)
        set_accum_dtype(None)
        set_moe_impl("einsum", None)


def _packed_pspecs(defs, policy: QuantPolicy, rules, mesh):
    """PartitionSpec tree matching quantized_shape_tree's structure."""
    from repro.core.ptq import is_quantizable, packed_def
    from repro.core.ptq import _map_with_defs
    from repro.models.params import pspec_leaf

    def visit(path, d, _):
        if is_quantizable(d, path) and str(policy.w_fmt).startswith("fp4"):
            pd = packed_def(d, policy)
            # codes/scale/lorc inherit the (out, in) logical axes of the def
            lead_axes = d.axes[:-2]
            out_ax, in_ax = d.axes[-2], d.axes[-1]

            def sized(shape, axes):
                return pspec_leaf(ParamDef(shape, axes, d.dtype), rules, mesh)

            return dataclasses.replace(
                pd,
                codes=sized(pd.codes.shape, lead_axes + (out_ax, None)),
                scale=sized(pd.scale.shape, lead_axes + (out_ax, None)),
                s_max=None if pd.s_max is None else sized(pd.s_max.shape, lead_axes + (out_ax, None)),
                shifts=None if pd.shifts is None else sized(pd.shifts.shape, lead_axes + (out_ax, None)),
                lorc_a=None if pd.lorc_a is None else sized(pd.lorc_a.shape, lead_axes + (out_ax, None)),
                lorc_b=None if pd.lorc_b is None else sized(pd.lorc_b.shape, lead_axes + (None, in_ax)),
            )
        return pspec_leaf(d, rules, mesh)

    return _map_with_defs(visit, jax.tree.map(lambda d: d, defs, is_leaf=lambda x: isinstance(x, ParamDef)), defs)
