"""Sharding profiles: logical->physical rules per (arch x mode), cache
partition specs, activation (sequence-parallel) constraints.

Profiles (selected by parameter count / family — DESIGN.md §4):
  * TP        — params over 'model' (heads/ffn/vocab/experts), replicated
                elsewhere. Default for < 16B params.
  * ZERO3     — TP + the 'embed' dim of params/moments over ('pod','data'):
                fully-sharded at rest, layer-gathered inside the scan by
                GSPMD. Required for 340B/671B to fit 16 GB/chip.
  * SERVE_EP  — serving deepseek-scale MoE: experts over ('data','model')
                (= EP 256, one expert per chip), everything else TP.

Sequence parallelism (Megatron SP): the residual stream between blocks is
sharded over 'model' along the sequence dim via a with_sharding_constraint
hook (models/layers.set_residual_sharding). GSPMD inserts the all-gather
before qkv/up projections and the reduce-scatter after wo/down — the
standard TP+SP collective schedule.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.params import DEFAULT_RULES, pspec_tree
from .mesh import dp_axes

__all__ = [
    "profile_for",
    "train_rules",
    "serve_rules",
    "batch_pspecs",
    "cache_pspecs",
    "residual_spec",
    "serve_pool_pspecs",
    "serve_param_shardings",
]

BIG_PARAMS = 16e9  # above this, ZeRO-3 param sharding
HUGE_PARAMS = 100e9  # above this, grad accumulation + fp8-sim moments
SMALL_PARAMS = 2e9  # below this, train pure-DP over the whole mesh


def non_expert_params(cfg) -> int:
    if cfg.moe is None:
        return cfg.param_count()
    mult = 3 if cfg.mlp_gated else 2
    routed = (cfg.n_layers - cfg.moe.n_dense_layers) * cfg.moe.n_experts *         mult * cfg.d_model * cfg.moe.d_ff
    return cfg.param_count() - int(routed)


def profile_for(cfg, mesh, mode: str) -> dict:
    n = cfg.param_count()
    dp = dp_axes(mesh)
    # §Perf hillclimb: models under ~2B replicate comfortably — pure DP over
    # ALL mesh axes (model axis joins the batch) removes every TP/SP
    # collective; the only traffic left is the once-per-step grad reduction.
    pure_dp = mode == "train" and n < SMALL_PARAMS
    prof = {
        "dp": tuple(mesh.shape.keys()) if pure_dp else dp,
        "pure_dp": pure_dp,
        "seq_shard": mode == "train" and not pure_dp,
        "accum_steps": int(os.environ.get("REPRO_ACCUM", "4" if n >= HUGE_PARAMS else "1")) if mode == "train" else 1,
        "moment_dtype": "fp8_sim" if n >= HUGE_PARAMS else "float32",
        "zero3": n >= BIG_PARAMS,
    }
    return prof


def train_rules(cfg, mesh, zero3: bool, moe_a2a: bool = False,
                pure_dp: bool = False) -> tuple:
    """(param_rules, moment_rules).

    moe_a2a: expert weights live in the all-to-all EP layout — expert dim
    sharded over the WHOLE mesh (weights fully local to their rank; no
    ZeRO gather, no resharding at the shard_map boundary or the optimizer).
    """
    dp = dp_axes(mesh)
    if pure_dp:
        allax = tuple(mesh.shape.keys())
        prules = {k: None for k in DEFAULT_RULES}
        mrules = dict(prules, embed=allax, ffn=None, vocab=None, heads=None)
        return prules, mrules
    base = dict(DEFAULT_RULES)
    zero = dict(DEFAULT_RULES, embed=dp if len(dp) > 1 else dp[0])
    prules = dict(zero if zero3 else base)
    mrules = dict(zero)
    if moe_a2a and cfg.moe is not None:
        import numpy as _np

        for cand in (("data", "model"), ("model",)):
            if all(a in mesh.shape for a in cand) and cfg.moe.n_experts % int(
                _np.prod([mesh.shape[a] for a in cand])
            ) == 0:
                prules["expert"] = cand
                mrules["expert"] = cand
                break
    return prules, mrules


def serve_rules(cfg, mesh) -> dict:
    rules = dict(DEFAULT_RULES)
    if cfg.moe is not None:
        total = int(np.prod([mesh.shape[a] for a in mesh.shape]))
        if cfg.moe.n_experts % total == 0:
            rules["expert"] = tuple(mesh.shape.keys())  # EP across the whole mesh
        else:
            dm = tuple(a for a in ("data", "model") if a in mesh.shape)
            if cfg.moe.n_experts % int(np.prod([mesh.shape[a] for a in dm])) == 0:
                rules["expert"] = dm
    return rules


def batch_pspecs(batch_shapes, mesh, dp=None):
    """Tokens/labels/frames: batch over (pod, data) — or all axes (pure DP)."""
    dp = dp if dp is not None else dp_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in dp]))

    def spec(sds):
        if sds.shape[0] % dsize == 0:
            return P(dp, *([None] * (len(sds.shape) - 1)))
        return P(*([None] * len(sds.shape)))

    return jax.tree.map(spec, batch_shapes)


def residual_spec(mesh) -> P:
    """(B, S, d) residual: batch over dp, seq over model (Megatron SP)."""
    return P(dp_axes(mesh), "model", None)


# ---------------------------------------------------------------------------
# Cache partition specs (decode/prefill)
# ---------------------------------------------------------------------------
def _cache_leaf_spec(shape, mesh) -> P:
    """Heuristic per cache leaf. Layout conventions (models/*):
    dim0 = stacked layers/invocations (never sharded), dim1 = batch.
    Sequence dims are large (>= 4096); head dims divisible by 'model' shard.
    """
    dp = dp_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in dp]))
    msize = mesh.shape.get("model", 1)
    nd = len(shape)
    spec = [None] * nd
    data_used = model_used = False
    if nd >= 2 and dp and shape[1] % dsize == 0 and shape[1] > 1:
        # a single dp axis goes in bare (P("data") == P(("data",)) for jax,
        # but downstream spec introspection compares entries to axis names);
        # an empty dp (model-only mesh) leaves the batch dim replicated
        spec[1] = dp if len(dp) > 1 else dp[0]
        data_used = True
    # kv-head dim for 5D (L, B, S, KV, hd)
    if nd == 5 and shape[3] % msize == 0:
        spec[3] = "model"
        model_used = True
    # ssm state (L, B, H, n, p): shard heads over model
    if nd == 5 and not model_used and shape[2] % msize == 0 and shape[2] >= msize:
        # only if dim2 is a head dim (heuristic: small-ish, not a sequence)
        if shape[2] <= 1024:
            spec[2] = "model"
            model_used = True
    # sequence dim (large): give it whatever axes remain
    seq_dim = None
    for i in range(1, nd):
        if spec[i] is None and shape[i] >= 4096:
            seq_dim = i
            break
    if seq_dim is not None:
        remaining = []
        if not data_used:
            remaining.extend(dp)
        if not model_used:
            remaining.append("model")
        if remaining:
            rsize = int(np.prod([mesh.shape[a] for a in remaining]))
            if shape[seq_dim] % rsize == 0:
                spec[seq_dim] = tuple(remaining) if len(remaining) > 1 else remaining[0]
    return P(*spec)


def cache_pspecs(cache_shape_tree, mesh):
    return jax.tree.map(lambda s: _cache_leaf_spec(s.shape, mesh), cache_shape_tree)


# ---------------------------------------------------------------------------
# Serving pool / param layouts (paged engine on a mesh)
# ---------------------------------------------------------------------------
def _pool_leaf_spec(name: str, shape, mesh) -> P:
    """Per-mesh-axis layout for one paged-pool leaf (runtime/kv_cache.py
    layout conventions):

      * GQA/cross K/V stores ``(L, P+1, page, KV, hd)`` — the KV-head dim
        (index 3) shards along 'model' when divisible; layers, page ids and
        the in-page token dim stay replicated (page identity is host-global).
      * per-(page, head) ``*_shift`` scales ``(L, P+1, KV)`` co-shard their
        head dim with the codes; per-page ``*_smax`` ``(L, P+1)`` replicate
        (one scalar per page, shared by every head shard).
      * MLA latent stores ``(L, P+1, r)``-shaped leaves have no head axis —
        they replicate (the absorbed heads shard on the query side), and
        their single-"head" shifts ``(L, P+1, 1)`` fall out replicated via
        the same divisibility test.
      * frozen ``*_fz`` leaves mirror the active layout (same head dim
        index), zero-size format markers and recurrent slabs replicate.
    """
    msize = mesh.shape.get("model", 1)
    nd = len(shape)
    if msize <= 1 or 0 in shape:
        return P(*([None] * nd))
    if nd == 5 and shape[3] % msize == 0:  # (L, pages, page, KV, hd) codes
        return P(None, None, None, "model", None)
    if nd == 3 and name.endswith("_shift") and shape[2] % msize == 0:
        return P(None, None, "model")  # co-sharded with the code head dim
    return P(*([None] * nd))


def serve_pool_pspecs(pool, mesh):
    """PartitionSpec per paged-pool leaf, keyed by leaf name + shape (only
    ``mesh.shape`` is read, so a stub mesh works for spec-shape tests)."""
    return {name: _pool_leaf_spec(name, leaf.shape, mesh)
            for name, leaf in pool.items()}


def _key_str(k) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def serve_param_shardings(cfg, params, mesh):
    """NamedSharding tree for a *serving* param tree (dense or W4A8-packed)
    under ``serve_rules``. The logical->axis specs come from the model's
    ParamDef tree; packed ``PackedLinear`` leaves (codes/scales/s_max/
    shifts/lorc_a, whose dim0 is the def leaf's dim0) inherit the def
    spec's dim0 entry. Anything unmatched or non-divisible replicates —
    placement is an optimization, GSPMD owns correctness."""
    from repro.models.api import build_def

    spec_tree = pspec_tree(build_def(cfg), serve_rules(cfg, mesh), mesh)
    flat_specs, _ = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    by_path = {tuple(_key_str(k) for k in path): spec
               for path, spec in flat_specs}
    replicated = NamedSharding(mesh, P())

    def leaf_sharding(path, leaf):
        keys = tuple(_key_str(k) for k in path)
        spec = by_path.get(keys)
        if spec is None and len(keys) > 1:
            # PackedLinear field under a def leaf: apply the def dim0 axis
            # to the field's dim0 (out-features / expert stack), except the
            # 2-D lorc_b whose dim0 is the LoRC rank, not the def dim0
            base = by_path.get(keys[:-1])
            field = keys[-1]
            if base is not None and getattr(leaf, "ndim", 0) >= 1 and not (
                    field == "lorc_b" and leaf.ndim == 2):
                ax = base[0] if len(base) else None
                if ax is not None:
                    asize = int(np.prod([mesh.shape[a] for a in
                                         ((ax,) if isinstance(ax, str)
                                          else tuple(ax))]))
                    if asize and leaf.shape[0] % asize == 0:
                        spec = P(ax, *([None] * (leaf.ndim - 1)))
        if spec is None:
            return replicated
        if len(spec) > getattr(leaf, "ndim", 0):
            return replicated  # shape drifted from the def tree: replicate
        return NamedSharding(mesh, spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_sharding(path, leaf) for path, leaf in flat])
