"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

Conventions (validated empirically, EXPERIMENTS.md §Dry-run):
  * compiled.cost_analysis() reports PER-DEVICE flops / bytes of the
    SPMD-partitioned module, so
        compute term    = flops / PEAK_FLOPS
        memory term     = bytes accessed / HBM_BW
  * collective bytes are parsed from compiled.as_text(): for each collective
    op we take the RESULT shape bytes (per-device) and convert to per-link
    traffic with the standard ring models:
        all-reduce      2 (n-1)/n x bytes
        all-gather        (n-1)/n x bytes      (result = gathered)
        reduce-scatter    (n-1)/n x input bytes (= result x n)
        all-to-all        (n-1)/n x bytes
        collective-permute          1 x bytes
    collective term = traffic / ICI_BW.
"""
from __future__ import annotations

import re
from typing import Dict, List

import numpy as np

__all__ = ["HW", "collective_bytes", "roofline_terms", "parse_collectives"]

HW = {
    "peak_flops": 197e12,  # bf16 per chip
    "hbm_bw": 819e9,  # bytes/s
    "ici_bw": 50e9,  # bytes/s per link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w[\w.-]*)\s*=\s*(?:\([^)]*\)|[\w\[\],{}()\s]*?)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3\w*|f8e5m2\w*|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(line: str) -> int:
    """Sum result-shape bytes on an HLO line (handles tuple results)."""
    # result shapes appear before the op name, after '='
    lhs = line.split("=", 1)[1]
    opidx = min(
        [lhs.find(op) for op in
         ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
         if lhs.find(op) >= 0]
        or [len(lhs)]
    )
    total = 0
    for m in _SHAPE_RE.finditer(lhs[:opidx]):
        dt = m.group(1)
        base = next((v for k, v in _DTYPE_BYTES.items() if dt.startswith(k)), 4)
        dims = m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * base
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return total_devices


def parse_collectives(hlo_text: str, total_devices: int) -> List[Dict]:
    out = []
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("//") or "= " not in ls:
            continue
        kinds = [k for k in ("all-reduce-start", "all-reduce", "all-gather-start",
                             "all-gather", "reduce-scatter", "all-to-all",
                             "collective-permute-start", "collective-permute")
                 if f" {k}(" in ls or f"{k}(" in ls]
        if not kinds:
            continue
        kind = kinds[0].replace("-start", "")
        if "-done" in ls:
            continue
        b = _shape_bytes(ls)
        n = _group_size(ls, total_devices)
        if kind == "all-reduce":
            traffic = 2 * (n - 1) / max(n, 1) * b
        elif kind == "all-gather":
            traffic = (n - 1) / max(n, 1) * b
        elif kind == "reduce-scatter":
            traffic = (n - 1) / max(n, 1) * b * n
        elif kind == "all-to-all":
            traffic = (n - 1) / max(n, 1) * b
        else:  # collective-permute
            traffic = b
        out.append({"kind": kind, "bytes": b, "group": n, "traffic": traffic})
    return out


def collective_bytes(hlo_text: str, total_devices: int) -> Dict[str, float]:
    colls = parse_collectives(hlo_text, total_devices)
    per_kind: Dict[str, float] = {}
    for c in colls:
        per_kind[c["kind"]] = per_kind.get(c["kind"], 0.0) + c["bytes"]
    return {
        "ops": len(colls),
        "bytes": sum(c["bytes"] for c in colls),
        "traffic": sum(c["traffic"] for c in colls),
        "per_kind": per_kind,
    }


def roofline_terms(cost: Dict, hlo_text: str, total_devices: int,
                   model_flops: float = 0.0) -> Dict:
    """Three-term roofline from the compiled HLO.

    Primary source is the trip-count-aware HLO walk (launch/hlo_cost.py);
    XLA's own cost_analysis() numbers (which count while bodies once) are
    reported alongside as `xla_*` for reference.
    """
    from .hlo_cost import analyze_hlo

    hc = analyze_hlo(hlo_text, total_devices, bf16_model=True)
    hc_raw = analyze_hlo(hlo_text, total_devices, bf16_model=False)
    flops = hc.flops
    byts = hc.bytes
    traffic = hc.collective_traffic
    per_kind: Dict[str, float] = {}
    n_ops = 0
    for c in hc.collectives:
        per_kind[c["kind"]] = per_kind.get(c["kind"], 0.0) + c["bytes"] * c["count"]
        n_ops += c["count"]
    coll = {"ops": n_ops, "bytes": hc.collective_bytes, "traffic": traffic,
            "per_kind": per_kind}

    t_compute = flops / HW["peak_flops"]
    t_memory = byts / HW["hbm_bw"]
    t_coll = traffic / HW["ici_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    out = {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "device_flops": flops,
        "device_bytes": byts,
        "raw_bytes": hc_raw.bytes,
        "raw_collective_traffic": hc_raw.collective_traffic,
        "xla_flops": float(cost.get("flops", 0.0)),
        "xla_bytes": float(cost.get("bytes accessed", 0.0)),
        "collective": coll,
    }
    if model_flops:
        # model_flops is GLOBAL useful flops; device_flops is per-device
        out["model_flops"] = model_flops
        out["useful_ratio"] = model_flops / max(flops * total_devices, 1.0)
        bound = max(t_compute, t_memory, t_coll)
        ideal = (model_flops / total_devices) / HW["peak_flops"]
        out["roofline_fraction"] = ideal / max(bound, 1e-30)
    return out
