"""Sharded, step-atomic, elastic checkpointing.

Design for 1000+ node fleets (DESIGN.md §4):
  * step-atomic: writes go to ``step_<N>.tmp/`` and are renamed to
    ``step_<N>/`` only after every shard + the manifest are fsynced — a
    crash mid-save never corrupts the restore point;
  * sharded: each host writes only its addressable shards (here: the
    process-local slices of every array). Files are npz per host;
  * topology-independent (elastic): the manifest stores the LOGICAL tree +
    global shapes, not the mesh. Restore re-shards onto whatever mesh the
    new job brings up — a 512-chip checkpoint restores onto 256 chips (or
    one CPU) unchanged;
  * retention: keep_last N checkpoints, best-effort async cleanup;
  * fault handling: restore() scans for the newest COMPLETE step directory
    and ignores torn ones.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]

_MANIFEST = "manifest.json"


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: Any, process_index: int = 0,
         n_processes: int = 1) -> str:
    """Write one checkpoint step atomically. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp{process_index}"
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = _flatten(tree)
    arrays = {}
    dtypes = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtypes.append(str(arr.dtype))
        if arr.dtype == ml_dtypes.bfloat16:  # npz has no bf16: store bits
            arr = arr.view(np.uint16)
        arrays[f"leaf_{i}"] = arr
    arrays["__dtypes__"] = np.array(dtypes)
    shard_file = os.path.join(tmp, f"shard_{process_index}.npz")
    np.savez(shard_file, **arrays)

    if process_index == 0:
        manifest = {
            "step": step,
            "n_processes": n_processes,
            "treedef": str(treedef),
            "leaves": [
                {"shape": list(np.shape(np.asarray(jax.device_get(l)))),
                 "dtype": str(np.asarray(jax.device_get(l)).dtype)}
                for l in leaves
            ],
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
    # atomic publish (single-host path: one rename)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    """Newest COMPLETE checkpoint step (manifest present), else None."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp0"):
            path = os.path.join(directory, name, _MANIFEST)
            if os.path.exists(path):
                try:
                    steps.append(int(name.split("_")[1].split(".")[0]))
                except ValueError:
                    continue
    return max(steps) if steps else None


def restore(directory: str, tree_like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``tree_like``; re-shard elastically.

    ``shardings``: optional matching tree of NamedSharding — arrays are
    device_put onto it (the ELASTIC path: the saved mesh is irrelevant)."""
    step = latest_step(directory) if step is None else step
    assert step is not None, f"no complete checkpoint under {directory}"
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "shard_0.npz"))
    saved_dtypes = [str(d) for d in data["__dtypes__"]] if "__dtypes__" in data else None
    leaves, treedef = _flatten(tree_like)
    out = []
    for i, leaf in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        if saved_dtypes and saved_dtypes[i] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        tgt_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        a = jnp.asarray(arr).astype(tgt_dtype)
        out.append(a)
    restored = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        restored = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            restored, shardings,
        )
    return restored


class CheckpointManager:
    """Retention + resume orchestration for the training loop."""

    def __init__(self, directory: str, keep_last: int = 3, every: int = 100):
        self.directory = directory
        self.keep_last = keep_last
        self.every = every

    def maybe_save(self, step: int, tree: Any) -> Optional[str]:
        if step % self.every:
            return None
        path = save(self.directory, step, tree)
        self._cleanup()
        return path

    def _cleanup(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and "." not in n
        )
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def resume_or(self, init_tree: Any, shardings: Any = None):
        """(tree, start_step) — restored if a checkpoint exists, else init."""
        step = latest_step(self.directory)
        if step is None:
            return init_tree, 0
        return restore(self.directory, init_tree, step, shardings), step
