"""AdamW with configurable moment dtype.

At 340B/671B scale, f32 moments + f32 master weights cost 12 bytes/param —
over the 16 GB/chip budget even fully sharded on 512 chips. ``moment_dtype``
lets the launcher drop moments to bf16 (4 bytes/param total) for the largest
archs; ``fp8_sim`` additionally runs the moments through the paper's own
E4M3 grid (quantized optimizer state — the core FP machinery reused beyond
the paper). Updates always compute in f32.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.quantize import fake_quantize_act

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # 'float32' | 'bfloat16' | 'fp8_sim'
    warmup: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    mu: object  # pytree like params
    nu: object
    step: jnp.ndarray


def _store(x, dtype: str):
    if dtype == "fp8_sim":
        return fake_quantize_act(x, "fp8_e4m3").astype(jnp.bfloat16)
    return x.astype(jnp.dtype(dtype if dtype != "fp8_sim" else "bfloat16"))


def adamw_init(params, cfg: AdamWConfig) -> OptState:
    dt = "bfloat16" if cfg.moment_dtype == "fp8_sim" else cfg.moment_dtype
    zeros = lambda p: jnp.zeros(p.shape, jnp.dtype(dt))
    return OptState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def lr_schedule(step, cfg: AdamWConfig):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup) / jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_update(params, grads, state: OptState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = lr_schedule(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        if p.ndim >= 2:  # decoupled decay on matrices only
            p32 = p32 * (1 - lr * cfg.weight_decay)
        p_new = (p32 - lr * delta).astype(p.dtype)
        return p_new, _store(m32, cfg.moment_dtype), _store(v32, cfg.moment_dtype)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(new_mu, new_nu, step), {"lr": lr, "grad_norm": gnorm}
