from .adamw import AdamWConfig, OptState, adamw_init, adamw_update, clip_by_global_norm, lr_schedule

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update", "clip_by_global_norm", "lr_schedule"]
