"""Synthetic LM data pipeline — deterministic, stateless-resumable,
host-sharded.

Real text corpora are unavailable offline; the pipeline synthesizes token
streams from a seeded Markov-ish generator with heavy-tailed unigram
statistics (Zipfian) so that models actually have structure to learn (the
e2e example trains to a visibly decreasing loss and PTQ perplexities are
meaningful, mirroring the paper's C4 calibration role).

Key properties for fleet-scale training:
  * stateless resume: batch t is a pure function of (seed, step, host) — a
    restarted job continues exactly where it left off with no data-state
    checkpointing;
  * host sharding: each host materializes only its slice of the global
    batch (process_index-parameterized);
  * straggler hook: `with_backup_hosts` marks batches with a redundancy
    group so a slow host's shard can be recomputed by its backup (the
    dispatch logic runtime/straggler.py consumes this).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "calibration_stream"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_index: int = 0
    zipf_a: float = 1.2  # unigram skew
    order: int = 2  # markov order for local structure
    grammar_p: float = 0.9  # fraction of tokens drawn from the sparse grammar


class SyntheticLM:
    """Deterministic synthetic token stream with learnable structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        v = cfg.vocab_size
        # Zipfian unigram distribution
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self.unigram = (probs / probs.sum()).astype(np.float64)
        # a sparse "grammar": each context hash prefers a small successor
        # set. The grammar is the LANGUAGE and must be identical for every
        # stream (train/calibration/eval draw different SAMPLES of the same
        # language) — so it is seeded independently of cfg.seed.
        g_rng = np.random.default_rng(20230707)
        self.n_ctx = 512
        self.succ = g_rng.integers(0, v, size=(self.n_ctx, 8))

    @property
    def host_batch(self) -> int:
        assert self.cfg.global_batch % self.cfg.n_hosts == 0
        return self.cfg.global_batch // self.cfg.n_hosts

    def batch(self, step: int) -> dict:
        """Pure function of (seed, step, host): {'tokens', 'labels'}."""
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.host_index])
        )
        b, s = self.host_batch, c.seq_len
        toks = np.empty((b, s + 1), dtype=np.int64)
        toks[:, 0] = rng.choice(c.vocab_size, size=b, p=self.unigram)
        ctx = toks[:, 0].copy()
        for t in range(1, s + 1):
            h = (ctx * 1000003 + t // 7) % self.n_ctx
            use_grammar = rng.random(b) < c.grammar_p
            pick = self.succ[h, rng.integers(0, 8, size=b)]
            rand = rng.choice(c.vocab_size, size=b, p=self.unigram)
            toks[:, t] = np.where(use_grammar, pick, rand)
            ctx = (ctx * 31 + toks[:, t]) % (1 << 30)
        return {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }

    def stream(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


def calibration_stream(cfg, n_batches: int, batch: int, seq: int, seed: int = 1234):
    """The paper's calibration set analogue: n sentences x seq tokens
    (paper: 128 x 2048 from C4). Returns a list of token batches."""
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
                    seed=seed)
    src = SyntheticLM(dc)
    return [src.batch(i) for i in range(n_batches)]
