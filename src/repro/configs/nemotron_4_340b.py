"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — squared-ReLU, LayerNorm. [arXiv:2402.16819; unverified]"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    attn_kind="gqa",
    norm_kind="layernorm",
    act_kind="relu2",
    mlp_gated=False,
    rope_theta=10000.0,
    source="[arXiv:2402.16819; unverified]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=96, n_heads=4, n_kv_heads=2, d_ff=384,
    vocab_size=256, attn_chunk=32,
)
