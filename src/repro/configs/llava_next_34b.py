"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling frontend STUB (input_specs feeds patch
embeddings at the vision dim 1024). [hf:llava-hf/llava-v1.6; unverified]"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    attn_kind="gqa",
    norm_kind="rmsnorm",
    act_kind="silu",
    mlp_gated=True,
    frontend="vision_patches",
    n_patches=576,         # one 24x24 CLIP tile (anyres stub)
    source="[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab_size=256, n_patches=8, attn_chunk=32,
)
