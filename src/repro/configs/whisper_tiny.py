"""whisper-tiny [audio]: 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.
Enc-dec; conv frontend STUB (input_specs feeds frame embeddings).
[arXiv:2212.04356; unverified]"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,            # decoder layers
    encoder_layers=4,
    encoder_seq=1500,      # 30 s of audio at 50 Hz post-conv
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    attn_kind="gqa",
    norm_kind="layernorm",
    act_kind="gelu",
    mlp_gated=False,
    use_bias=True,
    pos_embedding="learned",
    tie_embeddings=True,
    max_position=65536,    # decode_32k needs learned positions up to 32k
    frontend="audio_frames",
    source="[arXiv:2212.04356; unverified]",
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=2, encoder_layers=2, encoder_seq=32, d_model=64, n_heads=2,
    n_kv_heads=2, d_ff=128, vocab_size=256, max_position=128, attn_chunk=32,
)
