"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H d_ff=8192 vocab=32000,
ssm_state=64 — Mamba2 backbone + ONE shared attention+MLP block applied
every 6 layers (weight sharing; per-invocation KV caches).
[arXiv:2411.15242; hf]"""
import dataclasses

from repro.models.config import ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    attn_kind="gqa",
    ssm=SSMSpec(kind="mamba2", d_state=64, expand=2, head_dim=64, d_conv=4,
                chunk=256, attn_every=6),
    norm_kind="rmsnorm",
    act_kind="gelu",
    mlp_gated=False,
    tie_embeddings=True,
    source="[arXiv:2411.15242; hf]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, attn_chunk=32,
    ssm=SSMSpec(kind="mamba2", d_state=16, expand=2, head_dim=16, d_conv=4,
                chunk=32, attn_every=2),
)
