"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff(moe)=2048
vocab=129280 — MLA (q_lora 1536, kv_lora 512, nope 128, rope 64, v 128),
1 shared + 256 routed experts top-8, 3 leading dense layers (d_ff 18432),
MTP depth 1. [arXiv:2412.19437; hf]"""
import dataclasses

from repro.models.config import ArchConfig, MLASpec, MoESpec

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,            # dense-layer FFN width
    vocab_size=129280,
    attn_kind="mla",
    mla=MLASpec(
        q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128,
    ),
    moe=MoESpec(
        n_experts=256, top_k=8, d_ff=2048, n_shared_experts=1,
        shared_d_ff=2048, capacity_factor=1.25, n_dense_layers=3,
        dense_d_ff=18432,
    ),
    norm_kind="rmsnorm",
    act_kind="silu",
    mlp_gated=True,
    mtp_depth=1,
    source="[arXiv:2412.19437; hf]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, attn_chunk=32,
    mla=MLASpec(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=8, qk_rope_dim=8,
                v_head_dim=8),
    moe=MoESpec(n_experts=8, top_k=2, d_ff=32, n_shared_experts=1,
                shared_d_ff=32, capacity_factor=1.25, n_dense_layers=1,
                dense_d_ff=128),
)
