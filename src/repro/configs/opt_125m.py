"""opt-125m — the paper's own experimental family (OPT), small config used
by the end-to-end train->PTQ example and the paper-table benchmarks.
12L d_model=768 12H d_ff=3072 vocab=50272, ReLU MLP, LayerNorm, learned pos.
[arXiv:2205.01068; hf]"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="opt-125m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=50272,
    attn_kind="gqa",
    norm_kind="layernorm",
    act_kind="relu",       # OPT uses plain ReLU (drives the paper's fc2 skew)
    mlp_gated=False,
    use_bias=True,
    pos_embedding="learned",
    tie_embeddings=True,
    max_position=4096,
    source="[arXiv:2205.01068; hf]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=512, attn_chunk=32,
)
