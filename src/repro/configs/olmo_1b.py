"""olmo-1b [dense]: 16L d_model=2048 16H d_ff=8192 vocab=50304 —
non-parametric LayerNorm, SwiGLU, rope. [arXiv:2402.00838; hf]"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    attn_kind="gqa",
    norm_kind="nonparam_ln",
    act_kind="silu",
    mlp_gated=True,
    tie_embeddings=True,
    source="[arXiv:2402.00838; hf]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=256, attn_chunk=32,
)
