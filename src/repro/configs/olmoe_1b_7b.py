"""olmoe-1b-7b [moe]: 16L d_model=2048 16H d_ff(expert)=1024 vocab=50304 —
64 experts top-8, SwiGLU, rmsnorm. [arXiv:2409.02060; hf]"""
import dataclasses

from repro.models.config import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    attn_kind="gqa",
    moe=MoESpec(n_experts=64, top_k=8, d_ff=1024, capacity_factor=1.25),
    norm_kind="rmsnorm",
    act_kind="silu",
    mlp_gated=True,
    source="[arXiv:2409.02060; hf]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
    vocab_size=256, attn_chunk=32,
    moe=MoESpec(n_experts=8, top_k=2, d_ff=32, capacity_factor=1.25),
)
