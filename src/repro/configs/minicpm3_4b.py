"""minicpm3-4b [dense]: 62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA
(q_lora 768, kv_lora 256, qk_nope 64, qk_rope 32, v 64).
[hf:openbmb/MiniCPM3-4B; hf]"""
import dataclasses

from repro.models.config import ArchConfig, MLASpec

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attn_kind="mla",
    mla=MLASpec(
        q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64, qk_rope_dim=32,
        v_head_dim=64,
    ),
    norm_kind="rmsnorm",
    act_kind="silu",
    mlp_gated=True,
    source="[hf:openbmb/MiniCPM3-4B; hf]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, attn_chunk=32,
    mla=MLASpec(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=8, qk_rope_dim=8,
                v_head_dim=8),
)
