"""Architecture registry: one module per assigned arch, exact published
configs + reduced smoke variants. ``get_config(name)`` / ``get_smoke(name)``.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "whisper_tiny",
    "minitron_8b",
    "nemotron_4_340b",
    "minicpm3_4b",
    "olmo_1b",
    "xlstm_125m",
    "deepseek_v3_671b",
    "olmoe_1b_7b",
    "llava_next_34b",
    "zamba2_1p2b",
    # the paper's own experimental family (OPT-style, used by examples)
    "opt_125m",
]

_ALIASES = {
    "whisper-tiny": "whisper_tiny",
    "minitron-8b": "minitron_8b",
    "nemotron-4-340b": "nemotron_4_340b",
    "minicpm3-4b": "minicpm3_4b",
    "olmo-1b": "olmo_1b",
    "xlstm-125m": "xlstm_125m",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llava-next-34b": "llava_next_34b",
    "zamba2-1.2b": "zamba2_1p2b",
    "opt-125m": "opt_125m",
}


def _module(name: str):
    key = _ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{key}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).SMOKE


def all_arch_names():
    return [a for a in ARCHS if a != "opt_125m"]
