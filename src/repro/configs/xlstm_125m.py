"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — alternating
sLSTM + mLSTM blocks (projections internal to the blocks).
[arXiv:2405.04517; unverified]"""
import dataclasses

from repro.models.config import ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    attn_kind="none",
    ssm=SSMSpec(kind="xlstm"),
    norm_kind="rmsnorm",
    tie_embeddings=True,
    pos_embedding="none",
    source="[arXiv:2405.04517; unverified]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=2, vocab_size=256,
)
