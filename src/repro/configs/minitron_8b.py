"""minitron-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 — pruned nemotron (squared-ReLU, LayerNorm).
[arXiv:2407.14679; hf]"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    attn_kind="gqa",
    norm_kind="layernorm",
    act_kind="relu2",
    mlp_gated=False,
    rope_theta=10000.0,
    source="[arXiv:2407.14679; hf]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab_size=256, attn_chunk=32,
)
