"""Pallas TPU kernel: fused token-wise FP8 (E4M3) activation quantization.

One VMEM pass per row-block: per-token absmax -> scale = absmax / fmt.max
-> RNE rounding onto the saturating ExMy grid. The grid math lives in
kernels.common (shared with the fused single-pass GEMM, which runs the same
quantization *inside* its M-tile) and matches core.formats.quantize_to_grid
exactly.

This standalone kernel remains for call-sites that need the quantized
activations themselves (calibration capture, compression); the serving GEMM
no longer round-trips through it — see w4a8_fused.py.

Target layout: rows are tokens, the full feature row lives in one block
(feature dims here are <= 73728 -> <= 288 KiB f32 per 8-row block, well
inside VMEM).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import FORMATS

from .common import quantize_rows as _quantize_rows

__all__ = ["act_quant_pallas"]


def _kernel(x_ref, q_ref, s_ref, *, fmt):
    x = x_ref[...].astype(jnp.float32)
    q, scale = _quantize_rows(x, fmt)
    q_ref[...] = q.astype(q_ref.dtype)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("fmt_name", "block_rows", "interpret"))
def act_quant_pallas(x, fmt_name: str = "fp8_e4m3", block_rows: int = 8,
                     interpret: Optional[bool] = None):
    """x: (..., d) -> (values_on_grid f32, scale (..., 1) f32).

    Semantics identical to kernels.ref.act_quant_ref (asserted by the
    sweep tests). ``interpret=None`` resolves from the runtime: compiled on
    TPU, interpreter elsewhere (kernels.ops.interpret_mode)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fmt = FORMATS[fmt_name]
    lead = x.shape[:-1]
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    t = x2.shape[0]
    bt = min(block_rows, t)
    while t % bt:
        bt -= 1

    q, s = pl.pallas_call(
        functools.partial(_kernel, fmt=fmt),
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), jnp.float32),
            jax.ShapeDtypeStruct((t, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2)
    return q.reshape(*lead, d), s.reshape(*lead, 1)
