"""Pallas TPU kernel: fused token-wise FP8 (E4M3) activation quantization.

One VMEM pass per row-block: per-token absmax -> scale = absmax / fmt.max
-> RNE rounding onto the saturating ExMy grid. The grid math matches
core.formats.quantize_to_grid exactly (same pow2-by-bit-pattern idiom — an
integer VPU op on TPU, no transcendentals except log2 for the exponent).

Target layout: rows are tokens, the full feature row lives in one block
(feature dims here are <= 73728 -> <= 288 KiB f32 per 8-row block, well
inside VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import FORMATS

__all__ = ["act_quant_pallas"]


def _pow2i(k):
    k = jnp.clip(k.astype(jnp.int32), -126, 127)
    bits = (k + 127).astype(jnp.uint32) << 23
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def _quantize_rows(x, fmt):
    """x: (bt, d) f32 -> (values_on_grid, scale (bt, 1)).

    Constants are pinned to f32 — pallas interpret mode otherwise evaluates
    weak Python-float scalars at f64, perturbing the scale by one ulp vs the
    reference and shifting grid-tie roundings."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax * jnp.float32(1.0 / fmt.max_value), jnp.float32(1e-12))
    xs = x / scale
    ax = jnp.abs(xs)
    safe = jnp.maximum(ax, 1e-38)
    e = jnp.clip(jnp.floor(jnp.log2(safe)), fmt.min_exp, fmt.max_exp)
    step = _pow2i(e - fmt.man_bits)
    q = jnp.round(xs / step) * step
    q = jnp.clip(q, -fmt.max_value, fmt.max_value)
    q = jnp.where(ax == 0, jnp.zeros_like(q), q)
    return q, scale


def _kernel(x_ref, q_ref, s_ref, *, fmt):
    x = x_ref[...].astype(jnp.float32)
    q, scale = _quantize_rows(x, fmt)
    q_ref[...] = q.astype(q_ref.dtype)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("fmt_name", "block_rows", "interpret"))
def act_quant_pallas(x, fmt_name: str = "fp8_e4m3", block_rows: int = 8,
                     interpret: bool = True):
    """x: (..., d) -> (values_on_grid f32, scale (..., 1) f32).

    Semantics identical to kernels.ref.act_quant_ref (asserted by the
    sweep tests)."""
    fmt = FORMATS[fmt_name]
    lead = x.shape[:-1]
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    t = x2.shape[0]
    bt = min(block_rows, t)
    while t % bt:
        bt -= 1

    q, s = pl.pallas_call(
        functools.partial(_kernel, fmt=fmt),
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), jnp.float32),
            jax.ShapeDtypeStruct((t, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2)
    return q.reshape(*lead, d), s.reshape(*lead, 1)
