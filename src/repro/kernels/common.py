"""Shared in-kernel math for the W4A8 Pallas kernels.

Everything here runs *inside* kernel bodies (interpret or compiled), so it is
restricted to ops the TPU VPU lowers cheaply: integer bit twiddling, the
pow2-by-bit-pattern idiom, and jnp elementwise math. The same functions are
used by the split kernels (act_quant, w4a8_matmul) and the fused pipeline
(w4a8_fused), so the quantization semantics are defined once.

Numerical contract: identical to core.formats (quantize_to_grid / fp_decode)
— asserted bit-for-bit by tests/test_kernels.py and tests/test_w4a8_fused.py.
Constants are pinned to f32 because pallas interpret mode otherwise evaluates
weak Python-float scalars at f64, perturbing scales by one ulp vs the
reference and shifting grid-tie roundings.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

# pow2i and unpack_nibbles are the exact functions from core.formats — both
# are pure integer bit twiddling with no captured constants, so they are
# kernel-body-safe as-is; re-exported here so every kernel pulls its in-VMEM
# math from one module.
from repro.core.formats import FORMATS, pow2i, unpack_nibbles

__all__ = [
    "pow2i",
    "decode_e2m1",
    "decode_e3m0",
    "decode_fp8",
    "DECODERS",
    "unpack_nibbles",
    "token_scale",
    "round_to_grid",
    "quantize_rows",
    "PageFormat",
    "page_format",
    "PAGE_FORMAT_NAMES",
]


def decode_e2m1(code):
    """uint4 code (as wider int) -> f32 value. Closed form for E2M1
    {0, .5, 1, 1.5, 2, 3, 4, 6}: sub-normal (exp==0) value is 0.5*man."""
    code = code.astype(jnp.int32)
    sign = (code >> 3) & 1
    exp = (code >> 1) & 3
    man = code & 1
    frac = 1.0 + 0.5 * man.astype(jnp.float32)
    val = pow2i(exp - 1) * frac
    val = jnp.where(exp == 0, 0.5 * man.astype(jnp.float32), val)
    return jnp.where(sign == 1, -val, val)


def decode_e3m0(code):
    """E3M0 bias 3: pure powers of two, exp field 1..7 -> 2^-2..2^4."""
    code = code.astype(jnp.int32)
    sign = (code >> 3) & 1
    exp = code & 7
    val = jnp.where(exp == 0, 0.0, pow2i(exp - 3))
    return jnp.where(sign == 1, -val, val)


DECODERS = {"fp4_e2m1": decode_e2m1, "fp4_e3m0": decode_e3m0}


@dataclasses.dataclass(frozen=True)
class PageFormat:
    """The frozen spec of one KV page payload — how a page's bytes decode.

    Replaces the ``kv_fmt: Optional[str]`` static string that used to be
    threaded through the paged decode-attention kernels. A PageFormat is
    hashable (a valid jit static argument) and carries everything a kernel
    body or oracle needs to consume the page: the grid (``fmt``), the storage
    width (``bytes_per_code`` — FP4 packs two codes per byte), and the
    scale-apply mode (``exp_add``: per-head M2 shift applied as an exponent
    add inside ``decode_fp8``; ``none``: bf16 passthrough, no scales).

    Construct through :func:`page_format` — direct construction skips the
    allowed-set validation.
    """

    name: Optional[str]  # FORMATS key, or None = bf16 passthrough
    packed: bool = False  # two codes per byte (4-bit formats)
    scale_apply: str = "none"  # "exp_add" | "none"

    @property
    def quantized(self) -> bool:
        return self.name is not None

    @property
    def fmt(self):
        """The core.formats.FloatFormat grid (None for bf16)."""
        return FORMATS[self.name] if self.name is not None else None

    @property
    def bytes_per_code(self) -> float:
        return 0.5 if self.packed else (1.0 if self.quantized else 2.0)

    def width(self, d: int) -> int:
        """Stored last-dim width (in array elements) for ``d`` logical codes."""
        return (d + 1) // 2 if self.packed else d

    def decode(self, raw, shift, d: int):
        """Page bytes -> f32 values (the residual s_max multiply is the
        caller's, once per page). ``raw``: (..., width(d)) uint8 codes or
        bf16 values; ``shift`` broadcasts against the decoded codes. Static
        ``d`` recovers the logical width after a packed nibble unpack (odd
        head dims store one pad nibble)."""
        if not self.quantized:
            return raw
        codes = raw
        if self.packed:
            codes = unpack_nibbles(codes)[..., :d]
        return decode_fp8(codes, self.fmt, shift)


_PAGE_FORMATS = {
    None: PageFormat(None),
    "fp8_e4m3": PageFormat("fp8_e4m3", packed=False, scale_apply="exp_add"),
    "fp4_e2m1": PageFormat("fp4_e2m1", packed=True, scale_apply="exp_add"),
}

PAGE_FORMAT_NAMES = tuple(sorted(k for k in _PAGE_FORMATS if k is not None))


def page_format(spec) -> PageFormat:
    """Coerce a format name (or None, or an existing PageFormat) to the
    registered PageFormat — failing FAST, at dispatch time, with the allowed
    set in the message. Before this registry an unknown ``kv_fmt`` string
    sailed into the jitted kernel body and surfaced as an opaque ``KeyError``
    mid-trace."""
    if isinstance(spec, PageFormat):
        if spec.name in _PAGE_FORMATS:
            return spec
        raise ValueError(
            f"unknown KV page format {spec.name!r}: expected one of "
            f"{PAGE_FORMAT_NAMES} or None (bf16)")
    try:
        return _PAGE_FORMATS[spec]
    except KeyError:
        raise ValueError(
            f"unknown KV page format {spec!r}: expected one of "
            f"{PAGE_FORMAT_NAMES} or None (bf16)") from None


def decode_fp8(code, fmt, exp_shift=0):
    """uint8 ExMy code -> f32 value, with an M2-style scale applied as an
    EXPONENT ADD: value * 2^-k is pow2i(e - k), an integer add on the bit
    pattern instead of a multiply + scale-table gather in the hot loop.

    Same numeric contract as core.formats.fp_decode (subnormals exact, no
    inf/nan codes) — the paged-KV decode-attention kernel and its jnp oracle
    both dequantize through this one function. ``exp_shift`` broadcasts
    against ``code`` (per-(page, head) shifts from constrain_scales_m2); the
    residual full-precision s_max multiply happens once per page outside.
    """
    code = code.astype(jnp.int32)
    man_mask = 2**fmt.man_bits - 1
    exp_mask = 2**fmt.exp_bits - 1
    man = code & man_mask
    exp_field = (code >> fmt.man_bits) & exp_mask
    sign = (code >> (fmt.exp_bits + fmt.man_bits)) & 1
    is_sub = exp_field == 0
    e = jnp.where(is_sub, fmt.min_exp, exp_field - fmt.bias) - exp_shift
    frac = jnp.where(
        is_sub,
        man.astype(jnp.float32) * jnp.float32(2.0**-fmt.man_bits),
        1.0 + man.astype(jnp.float32) * jnp.float32(2.0**-fmt.man_bits),
    )
    val = pow2i(e) * frac
    return jnp.where(sign == 1, -val, val)


def token_scale(x, fmt):
    """Per-row (token) FP8 scale: absmax / fmt.max, floored away from zero.
    x: (..., d) f32 -> (..., 1) f32."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    return jnp.maximum(absmax * jnp.float32(1.0 / fmt.max_value), jnp.float32(1e-12))


def round_to_grid(xs, fmt):
    """RNE-round pre-scaled values onto the saturating ExMy grid (f32 in/out).

    Same math as core.formats.quantize_to_grid: step at |x| in [2^e, 2^(e+1))
    is 2^(e - man_bits); below the smallest normal, the subnormal step.
    """
    ax = jnp.abs(xs)
    safe = jnp.maximum(ax, jnp.float32(1e-38))
    e = jnp.clip(jnp.floor(jnp.log2(safe)), fmt.min_exp, fmt.max_exp)
    step = pow2i(e - fmt.man_bits)
    q = jnp.round(xs / step) * step
    q = jnp.clip(q, -fmt.max_value, fmt.max_value)
    return jnp.where(ax == 0, jnp.zeros_like(q), q)


def quantize_rows(x, fmt):
    """x: (bt, d) f32 -> (values_on_grid, scale (bt, 1)). The act_quant
    kernel body; also the first stage of the fused pipeline's M-tile."""
    scale = token_scale(x, fmt)
    return round_to_grid(x / scale, fmt), scale
