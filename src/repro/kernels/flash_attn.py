"""Pallas TPU kernel: flash attention (forward), online softmax in VMEM.

The dry-run shows attention softmax materialization dominating the memory
term of large train/prefill cells (EXPERIMENTS.md §Perf: deepseek train —
~7.5 TB/device of (S, S)-class f32 traffic across mask-add / sub-exp /
divide / convert passes). This kernel keeps the (q_block, kv_block) score
tile in VMEM, carries (m, l, acc) accumulators across kv blocks, and writes
ONLY the (S, d) output — the standard flash-attention dataflow mapped to
the TPU: MXU for the two tile matmuls, VPU for the online-softmax updates,
one HBM pass over q/k/v and one output write.

Forward-only: the training path's backward uses XLA autodiff over the
q-chunked jnp attention (models/attention.py); serving (prefill) is where
this kernel slots in. Validated against the jnp oracle in interpret mode
(tests/test_kernels.py) over shape/dtype sweeps.

Grid: (n_q_blocks,) with the kv loop INSIDE the kernel body (fori_loop) so
the accumulators live in registers/VMEM for the whole row block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas", "flash_attention_ref"]

_NEG_INF = -1e30


def flash_attention_ref(q, k, v, causal: bool = True):
    """Oracle: plain softmax attention. q/k/v: (B, S|T, H, hd)."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    scale = 1.0 / float(hd) ** 0.5
    sc = jnp.einsum("bqhd,bthd->bhqt", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    if causal:
        msk = jnp.where(jnp.arange(t)[None] > jnp.arange(s)[:, None], _NEG_INF, 0.0)
        sc = sc + msk[None, None]
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhqt,bthd->bqhd", p, v.astype(jnp.float32))
    return o.astype(v.dtype)


def _kernel(q_ref, k_ref, v_ref, o_ref, *, causal, block_q, block_k, t):
    """One (batch*head, q-block) program: loop kv blocks inside."""
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)  # (block_q, hd); leading dim 1 = bh block
    scale = 1.0 / float(q.shape[-1]) ** 0.5
    q = q * scale
    nk = t // block_k
    # whole-block VMEM reads once; the kv loop slices the loaded values
    # (pl.load with a scalar leading index trips the interpret-mode
    # discharge rule on this jax version)
    k_all = k_ref[0]
    v_all = v_ref[0]

    def body(ki, carry):
        m_run, l_run, acc = carry
        k_blk = jax.lax.dynamic_slice(
            k_all, (ki * block_k, 0), (block_k, k_all.shape[-1]))
        v_blk = jax.lax.dynamic_slice(
            v_all, (ki * block_k, 0), (block_k, v_all.shape[-1]))
        s_blk = jax.lax.dot_general(
            q, k_blk.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (block_q, block_k)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s_blk.shape, 0)
            k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s_blk.shape, 1)
            s_blk = jnp.where(k_pos > q_pos, _NEG_INF, s_blk)
        m_new = jnp.maximum(m_run, jnp.max(s_blk, axis=-1))
        p = jnp.exp(s_blk - m_new[:, None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p.astype(v_blk.dtype), v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    a0 = jnp.zeros((block_q, v_ref.shape[-1]), jnp.float32)
    m_f, l_f, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l_f[:, None], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, causal: bool = True, block_q: int = 128,
                           block_k: int = 128, interpret: bool = True):
    """q: (B, S, H, hd); k/v: (B, T, H, hd|dv). Returns (B, S, H, dv)."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    dv = v.shape[-1]
    block_q = min(block_q, s)
    while s % block_q:
        block_q -= 1
    block_k = min(block_k, t)
    while t % block_k:
        block_k -= 1

    # fold (B, H) into the grid's leading axis; layout (BH, S, hd)
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, s, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * h, t, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * h, t, dv)

    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, block_q=block_q,
                          block_k=block_k, t=t),
        grid=(b * h, s // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, t, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, t, dv), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dv), v.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return jnp.moveaxis(out.reshape(b, h, s, dv), 1, 2)
