"""Pure-jnp oracles for the Pallas kernels.

These define the exact semantics the TPU kernels must match bit-for-bit
(tests/test_kernels_* sweep shapes/dtypes and assert_allclose against these).
They are also the CPU fallback execution path for serving simulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import FORMATS, fp_decode, pow2i, quantize_to_grid, unpack_nibbles
from repro.core.quantize import quantize_act_tokenwise

__all__ = ["act_quant_ref", "dequant_packed_ref", "w4a8_matmul_ref",
           "w4a8_batched_matmul_ref"]


def act_quant_ref(x, fmt_name: str = "fp8_e4m3"):
    """Token-wise FP8 quantization: returns (values_on_grid, scale).
    x: (..., d). scale: (..., 1) f32; values f32 on the E4M3 grid."""
    return quantize_act_tokenwise(x, fmt_name)


def dequant_packed_ref(codes, scale, fmt_name: str = "fp4_e2m1", group_size: int = 256):
    """codes: (..., out, in/2) packed nibbles; scale: (..., out, n_groups).
    Returns (..., out, in) BF16 dequantized weights — bf16 is what the TPU
    kernel materializes in VMEM (decode product is exact in bf16 for E2M1's
    1-mantissa-bit grid times a pow-2-constrained scale)."""
    fmt = FORMATS[fmt_name]
    q = fp_decode(unpack_nibbles(codes), fmt)  # (..., out, in) f32
    out_f, in_f = q.shape[-2], q.shape[-1]
    n_groups = scale.shape[-1]
    gs = in_f // n_groups
    qg = q.reshape(*q.shape[:-1], n_groups, gs)
    w = (qg * scale[..., None].astype(jnp.float32)).reshape(*q.shape[:-2], out_f, in_f)
    return w.astype(jnp.bfloat16)


def w4a8_matmul_ref(x, codes, scale, lorc_a=None, lorc_b=None,
                    w_fmt: str = "fp4_e2m1", a_fmt: str = "fp8_e4m3",
                    group_size: int = 256):
    """The W4A8 GEMM semantics: token-wise-FP8 activations x packed-FP4
    weights (+ optional LoRC low-rank side path).

    x: (..., in) float; codes: (out, in/2) uint8; scale: (out, G) f32.
    Returns (..., out) in x.dtype.
    """
    from repro.models.layers import accum_dtype

    if a_fmt:
        qx, sx = quantize_act_tokenwise(x, a_fmt)
        xq = (qx * sx).astype(jnp.bfloat16)  # values on grid * scale
    else:
        xq = x.astype(jnp.bfloat16)
    w = dequant_packed_ref(codes, scale, w_fmt, group_size)  # (out, in) bf16
    y = jax.lax.dot_general(xq, w, (((xq.ndim - 1,), (1,)), ((), ())),
                            preferred_element_type=accum_dtype())
    if lorc_a is not None:
        y = y + jax.lax.dot_general(
            jax.lax.dot_general(xq, lorc_b, (((xq.ndim - 1,), (1,)), ((), ())),
                                preferred_element_type=accum_dtype()).astype(jnp.bfloat16),
            lorc_a, (((xq.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=accum_dtype()).astype(y.dtype)
    return y.astype(x.dtype)


def w4a8_batched_matmul_ref(x, codes, scale, lorc_a=None, lorc_b=None,
                            w_fmt: str = "fp4_e2m1", a_fmt=None,
                            group_size: int = 256, transpose_w: bool = False):
    """Oracle for the batched fused kernel (MoE expert stacks, MLA absorbed
    heads). x: (E, M, D); codes: (E, N, In/2); scale: (E, N, G).

    normal: D == In, y[e] = x[e] @ W[e]^T -> (E, M, N);
    transposed: D == N, y[e] = x[e] @ W[e] -> (E, M, In) (the MLA absorbed q
    path contracts the packed weight's out rows).
    LoRC is the same low-rank *side path* as the fused epilogue. Returns f32.
    """
    if a_fmt:
        qx, sx = quantize_act_tokenwise(x, a_fmt)
        xq = (qx * sx).astype(jnp.bfloat16)
    else:
        xq = x.astype(jnp.bfloat16)
    w = dequant_packed_ref(codes, scale, w_fmt, group_size)  # (E, N, In) bf16
    if transpose_w:
        y = jnp.einsum("emn,eni->emi", xq, w, preferred_element_type=jnp.float32)
        if lorc_a is not None:
            xr = jnp.einsum("emn,enr->emr", xq, lorc_a.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32).astype(jnp.bfloat16)
            y = y + jnp.einsum("emr,eri->emi", xr, lorc_b.astype(jnp.bfloat16),
                               preferred_element_type=jnp.float32)
    else:
        y = jnp.einsum("emk,enk->emn", xq, w, preferred_element_type=jnp.float32)
        if lorc_a is not None:
            xr = jnp.einsum("emk,erk->emr", xq, lorc_b.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32).astype(jnp.bfloat16)
            y = y + jnp.einsum("emr,enr->emn", xr, lorc_a.astype(jnp.bfloat16),
                               preferred_element_type=jnp.float32)
    return y
