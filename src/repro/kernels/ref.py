"""Pure-jnp oracles for the Pallas kernels.

These define the exact semantics the TPU kernels must match bit-for-bit
(tests/test_kernels_* sweep shapes/dtypes and assert_allclose against these).
They are also the CPU fallback execution path for serving simulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import FORMATS, fp_decode, pow2i, quantize_to_grid, unpack_nibbles
from repro.core.quantize import quantize_act_tokenwise
from .common import decode_fp8, page_format

__all__ = ["act_quant_ref", "dequant_packed_ref", "w4a8_matmul_ref",
           "w4a8_batched_matmul_ref", "paged_decode_attn_ref",
           "paged_mla_decode_attn_ref"]


def act_quant_ref(x, fmt_name: str = "fp8_e4m3"):
    """Token-wise FP8 quantization: returns (values_on_grid, scale).
    x: (..., d). scale: (..., 1) f32; values f32 on the E4M3 grid."""
    return quantize_act_tokenwise(x, fmt_name)


def dequant_packed_ref(codes, scale, fmt_name: str = "fp4_e2m1", group_size: int = 256):
    """codes: (..., out, in/2) packed nibbles; scale: (..., out, n_groups).
    Returns (..., out, in) BF16 dequantized weights — bf16 is what the TPU
    kernel materializes in VMEM (decode product is exact in bf16 for E2M1's
    1-mantissa-bit grid times a pow-2-constrained scale)."""
    fmt = FORMATS[fmt_name]
    q = fp_decode(unpack_nibbles(codes), fmt)  # (..., out, in) f32
    out_f, in_f = q.shape[-2], q.shape[-1]
    n_groups = scale.shape[-1]
    gs = in_f // n_groups
    qg = q.reshape(*q.shape[:-1], n_groups, gs)
    w = (qg * scale[..., None].astype(jnp.float32)).reshape(*q.shape[:-2], out_f, in_f)
    return w.astype(jnp.bfloat16)


def w4a8_matmul_ref(x, codes, scale, lorc_a=None, lorc_b=None,
                    w_fmt: str = "fp4_e2m1", a_fmt: str = "fp8_e4m3",
                    group_size: int = 256):
    """The W4A8 GEMM semantics: token-wise-FP8 activations x packed-FP4
    weights (+ optional LoRC low-rank side path).

    x: (..., in) float; codes: (out, in/2) uint8; scale: (out, G) f32.
    Returns (..., out) in x.dtype.
    """
    from repro.models.layers import accum_dtype

    if a_fmt:
        qx, sx = quantize_act_tokenwise(x, a_fmt)
        xq = (qx * sx).astype(jnp.bfloat16)  # values on grid * scale
    else:
        xq = x.astype(jnp.bfloat16)
    w = dequant_packed_ref(codes, scale, w_fmt, group_size)  # (out, in) bf16
    y = jax.lax.dot_general(xq, w, (((xq.ndim - 1,), (1,)), ((), ())),
                            preferred_element_type=accum_dtype())
    if lorc_a is not None:
        y = y + jax.lax.dot_general(
            jax.lax.dot_general(xq, lorc_b, (((xq.ndim - 1,), (1,)), ((), ())),
                                preferred_element_type=accum_dtype()).astype(jnp.bfloat16),
            lorc_a, (((xq.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=accum_dtype()).astype(y.dtype)
    return y.astype(x.dtype)


def w4a8_batched_matmul_ref(x, codes, scale, lorc_a=None, lorc_b=None,
                            w_fmt: str = "fp4_e2m1", a_fmt=None,
                            group_size: int = 256, transpose_w: bool = False):
    """Oracle for the batched fused kernel (MoE expert stacks, MLA absorbed
    heads). x: (E, M, D); codes: (E, N, In/2); scale: (E, N, G).

    normal: D == In, y[e] = x[e] @ W[e]^T -> (E, M, N);
    transposed: D == N, y[e] = x[e] @ W[e] -> (E, M, In) (the MLA absorbed q
    path contracts the packed weight's out rows).
    LoRC is the same low-rank *side path* as the fused epilogue. Returns f32.
    """
    if a_fmt:
        qx, sx = quantize_act_tokenwise(x, a_fmt)
        xq = (qx * sx).astype(jnp.bfloat16)
    else:
        xq = x.astype(jnp.bfloat16)
    w = dequant_packed_ref(codes, scale, w_fmt, group_size)  # (E, N, In) bf16
    if transpose_w:
        y = jnp.einsum("emn,eni->emi", xq, w, preferred_element_type=jnp.float32)
        if lorc_a is not None:
            xr = jnp.einsum("emn,enr->emr", xq, lorc_a.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32).astype(jnp.bfloat16)
            y = y + jnp.einsum("emr,eri->emi", xr, lorc_b.astype(jnp.bfloat16),
                               preferred_element_type=jnp.float32)
    else:
        y = jnp.einsum("emk,enk->emn", xq, w, preferred_element_type=jnp.float32)
        if lorc_a is not None:
            xr = jnp.einsum("emk,erk->emr", xq, lorc_b.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32).astype(jnp.bfloat16)
            y = y + jnp.einsum("emr,enr->emn", xr, lorc_a.astype(jnp.bfloat16),
                               preferred_element_type=jnp.float32)
    return y


def paged_decode_attn_ref(q, k_pages, v_pages, k_smax, k_shift, v_smax,
                          v_shift, page_table, kv_lens, fmt=None,
                          window: int = 0, frozen=None,
                          k_fz=None, v_fz=None, k_fz_smax=None,
                          k_fz_shift=None, v_fz_smax=None, v_fz_shift=None):
    """Oracle for the paged decode-attention kernel.

    q: (B, H, hd); k_pages/v_pages: (P+1, page, KV, hd) uint8 codes
    (``fmt`` quantized) or bf16 values (``fmt`` None); k/v_smax: (P+1,) f32
    per-page full-precision scales; k/v_shift: (P+1, KV) int32 M2 exponent
    shifts; page_table: (B, PP) int32; kv_lens: (B,) valid token counts.
    ``fmt``/``frozen`` take a PageFormat or format name (coerced via
    ``page_format``); with ``frozen`` set the ``*_fz`` operands carry the
    packed FP4 region and table entries >= P+1 are frozen logical ids —
    gathered with clamped indices and selected per page by id class,
    exactly the kernel's dataflow. Returns (B, H, dv) f32 — the
    gathered-page, dequantized softmax attention with per-row length masks
    (GQA repetition internal).

    Shape-polymorphic in H and KV (only g = H/KV is load-bearing), so the
    serving mesh's shard_map wrapper runs this same oracle per model-axis
    shard on its contiguous head block — H/m query heads against KV/m
    kv heads with the co-sharded ``*_shift`` rows — with no sharded
    variant needed.
    """
    fmt = page_format(fmt)
    frozen = page_format(frozen) if frozen is not None else None
    b, h, hd = q.shape
    base, page, kv, _ = k_pages.shape
    dv = v_pages.shape[-1]
    pp = page_table.shape[1]
    g = h // kv

    def dq(pages, smax, shift, fpages, fsmax, fshift):
        apt = (jnp.minimum(page_table, base - 1) if frozen is not None
               else page_table)
        gathered = pages[apt]  # (B, PP, page, KV, d)
        if not fmt.quantized:
            return gathered.astype(jnp.float32).reshape(b, pp * page, kv, -1)
        d = pages.shape[-1] * (2 if fmt.packed else 1)
        vals = fmt.decode(gathered, shift[apt][:, :, None, :, None], d)
        vals = vals * smax[apt][:, :, None, None, None]
        if frozen is not None:
            fpt = jnp.clip(page_table - base, 0, fpages.shape[0] - 1)
            fvals = frozen.decode(fpages[fpt],
                                  fshift[fpt][:, :, None, :, None],
                                  pages.shape[-1])
            fvals = fvals * fsmax[fpt][:, :, None, None, None]
            mask = (page_table >= base)[:, :, None, None, None]
            vals = jnp.where(mask, fvals, vals)
        return vals.reshape(b, pp * page, kv, -1)

    kf = dq(k_pages, k_smax, k_shift, k_fz, k_fz_smax, k_fz_shift)
    vf = dq(v_pages, v_smax, v_shift, v_fz, v_fz_smax, v_fz_shift)
    qg = q.reshape(b, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, kf) * (1.0 / float(hd) ** 0.5)
    t = pp * page
    pos = jnp.arange(t)[None, None, None, :]
    valid = pos < kv_lens[:, None, None, None]
    if window:  # sliding window: the query sits at position kv_len - 1
        valid &= pos > (kv_lens - 1 - window)[:, None, None, None]
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid, p, 0.0)
    o = jnp.einsum("bkgt,btkd->bkgd", p, vf)
    return o.reshape(b, h, dv)


def paged_mla_decode_attn_ref(q_lat, q_rope, ckv_pages, krope_pages,
                              ckv_smax, ckv_shift, krope_smax, krope_shift,
                              page_table, kv_lens, scale, fmt=None,
                              frozen=None, ckv_fz=None, krope_fz=None,
                              ckv_fz_smax=None, ckv_fz_shift=None,
                              krope_fz_smax=None, krope_fz_shift=None):
    """Oracle for the MLA latent decode kernel.

    q_lat: (B, H, r) absorbed queries; q_rope: (B, H, dr); ckv_pages:
    (P+1, page, r) / krope_pages: (P+1, page, dr) uint8 codes (``fmt``
    quantized) or bf16; c/r smax: (P+1,) f32; c/r shift: (P+1, 1)
    int32 (the latent has a single scale "head"); page_table: (B, PP);
    kv_lens: (B,). ``fmt``/``frozen`` as in ``paged_decode_attn_ref``; the
    ``*_fz`` operands carry the packed FP4 latent region. Scores are the
    k = concat(ckv, krope) contraction, v is the ckv view. Returns the
    latent context (B, H, r) f32.
    """
    fmt = page_format(fmt)
    frozen = page_format(frozen) if frozen is not None else None
    b, h, r = q_lat.shape
    base, page, _ = ckv_pages.shape
    pp = page_table.shape[1]

    def dq(pages, smax, shift, fpages, fsmax, fshift):
        apt = (jnp.minimum(page_table, base - 1) if frozen is not None
               else page_table)
        gathered = pages[apt]  # (B, PP, page, d)
        if not fmt.quantized:
            return gathered.astype(jnp.float32).reshape(b, pp * page, -1)
        d = pages.shape[-1] * (2 if fmt.packed else 1)
        vals = fmt.decode(gathered, shift[apt][..., None], d)
        vals = vals * smax[apt][:, :, None, None]
        if frozen is not None:
            fpt = jnp.clip(page_table - base, 0, fpages.shape[0] - 1)
            fvals = frozen.decode(fpages[fpt], fshift[fpt][..., None],
                                  pages.shape[-1])
            fvals = fvals * fsmax[fpt][:, :, None, None]
            mask = (page_table >= base)[:, :, None, None]
            vals = jnp.where(mask, fvals, vals)
        return vals.reshape(b, pp * page, -1)

    ckv = dq(ckv_pages, ckv_smax, ckv_shift, ckv_fz, ckv_fz_smax,
             ckv_fz_shift)
    kr = dq(krope_pages, krope_smax, krope_shift, krope_fz, krope_fz_smax,
            krope_fz_shift)
    s = (jnp.einsum("bhr,btr->bht", q_lat.astype(jnp.float32), ckv)
         + jnp.einsum("bhd,btd->bht", q_rope.astype(jnp.float32), kr)) * scale
    t = pp * page
    valid = jnp.arange(t)[None, None, :] < kv_lens[:, None, None]
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid, p, 0.0)
    return jnp.einsum("bht,btr->bhr", p, ckv)
