"""jit'd wrappers over the Pallas kernels with jnp-reference fallback.

Backend selection:
  * 'ref'      — pure-jnp oracle semantics (default off-TPU; also what the
                 dry-run lowers, so rooflines see realistic HLO).
  * 'pallas'   — pl.pallas_call TPU kernels, compiled on real TPU. Off-TPU
                 the kernels transparently run in interpreter mode (there is
                 no hardware to compile for), so 'pallas' is always safe to
                 select.
  * 'pallas_interpret' — force interpreter mode even on TPU (debugging).
Set via set_backend() or REPRO_KERNEL_BACKEND env var.

Under the pallas backend the hot path is the *fused single-pass* kernel
(kernels/w4a8_fused.py): FP8 activation quantization happens inside the GEMM
M-tile and the LoRC correction is a fused epilogue — nothing round-trips
through HBM between quantize, decode, matmul, and correct. Block sizes come
from the autotuner cache (kernels/autotune.py), with a shape heuristic on
cache miss. Stacked weights (MoE experts, MLA absorbed heads) go through
w4a8_matmul_batched instead of densifying via dequant_packed.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import ref as _ref

_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "ref")

# Mesh the paged decode-attention wrappers shard over (None = single-device,
# today's exact dataflow). Scoped by the serving engine around every trace —
# a module global like _BACKEND, read at trace time.
_DECODE_MESH = [None]


def set_backend(name: str):
    global _BACKEND
    assert name in ("ref", "pallas", "pallas_interpret")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def set_decode_mesh(mesh):
    """Install (or clear, with None) the mesh the paged decode-attention
    entry points shard_map over. Head-sharded decode is only taken when the
    KV-head dim divides the 'model' axis; otherwise the call falls through
    to the unsharded dataflow and GSPMD handles placement."""
    _DECODE_MESH[0] = mesh


def get_decode_mesh():
    return _DECODE_MESH[0]


def interpret_mode() -> bool:
    """True when pallas kernels must run under the interpreter: either the
    explicit 'pallas_interpret' backend, or no TPU to compile for."""
    return _BACKEND == "pallas_interpret" or jax.default_backend() != "tpu"


def _block_sizes(kind: str, w, m: int, n: int, k: int, batch: int = 1,
                 transpose_w: bool = False):
    from .autotune import best_block_sizes

    return best_block_sizes(
        kind, batch=batch, m=m, n=n, k=k, w_fmt=w.w_fmt, a_fmt=w.a_fmt,
        group_size=w.group_size, m2=w.shifts is not None,
        lorc_rank=0 if w.lorc_a is None else int(w.lorc_a.shape[-1]),
        transpose_w=transpose_w,
    )


def act_quant(x, fmt_name: str = "fp8_e4m3"):
    """Token-wise FP8 quantization -> (values_on_grid, scale)."""
    if _BACKEND.startswith("pallas"):
        from .act_quant import act_quant_pallas

        return act_quant_pallas(x, fmt_name, interpret=interpret_mode())
    return _ref.act_quant_ref(x, fmt_name)


def w4a8_matmul(x, w):
    """x: (..., in); w: PackedLinear (2D codes after any scan slicing).

    Pallas backend: ONE fused kernel — in-kernel FP8 act-quant, packed-FP4
    decode, f32 accumulation, LoRC epilogue — a single HBM write."""
    assert w.codes.ndim == 2, "stacked PackedLinear must go through w4a8_matmul_batched"
    if _BACKEND.startswith("pallas"):
        from .w4a8_fused import w4a8_fused_matmul_pallas

        lead = x.shape[:-1]
        k = x.shape[-1]
        x2 = x.reshape(-1, k)
        bm, bn = _block_sizes("fused", w, x2.shape[0], w.out_features, k)
        y = w4a8_fused_matmul_pallas(
            x2, w.codes, w.scale, w.s_max, w.shifts, w.lorc_a, w.lorc_b,
            w_fmt=w.w_fmt, a_fmt=w.a_fmt, group_size=w.group_size,
            bm=bm, bn=bn, interpret=interpret_mode(),
        )
        return y.reshape(*lead, -1).astype(x.dtype)
    return _ref.w4a8_matmul_ref(
        x, w.codes, w.scale, w.lorc_a, w.lorc_b,
        w_fmt=w.w_fmt, a_fmt=w.a_fmt, group_size=w.group_size,
    )


def w4a8_matmul_batched(x, w, transpose_w: bool = False,
                        quantize_acts: bool = True):
    """Stacked-weight GEMM straight off the packed codes (no densify).

    x: (E, M, D); w: batched PackedLinear (codes (E, out, in/2)).
    normal: D == in_features -> (E, M, out); transposed: D == out (contract
    the weight's out rows — MLA absorbed q path) -> (E, M, in).
    ``quantize_acts=False`` skips the FP8 activation quantization (latent
    absorbed paths feed already-attenuated activations). Returns f32.
    """
    assert w.codes.ndim == 3, "2-D PackedLinear goes through w4a8_matmul"
    a_fmt = w.a_fmt if quantize_acts else None
    if _BACKEND.startswith("pallas"):
        from .w4a8_fused import w4a8_fused_batched_pallas

        e, m, _ = x.shape
        bm, bn = _block_sizes("fused_batched", w, m, w.codes.shape[1],
                              x.shape[-1], batch=e, transpose_w=transpose_w)
        return w4a8_fused_batched_pallas(
            x, w.codes, w.scale, w.s_max, w.shifts, w.lorc_a, w.lorc_b,
            w_fmt=w.w_fmt, a_fmt=a_fmt, group_size=w.group_size,
            bm=bm, bn=bn, transpose_w=transpose_w, interpret=interpret_mode(),
        )
    return _ref.w4a8_batched_matmul_ref(
        x, w.codes, w.scale, w.lorc_a, w.lorc_b,
        w_fmt=w.w_fmt, a_fmt=a_fmt, group_size=w.group_size,
        transpose_w=transpose_w,
    )


def _layer_formats(pool_layer, key: str):
    """Derive the (active, frozen) PageFormats from a pool slice's leaves —
    dtype picks quantized vs bf16, the zero-size ``_fp4`` marker picks
    packed FP4 over FP8, and a ``<key>_fz`` leaf announces the dedicated
    packed frozen region (mirrors runtime.kv_cache.pool_format /
    frozen_format without importing the runtime layer)."""
    from .common import page_format

    leaf = pool_layer[key]
    if leaf.dtype != jnp.uint8:
        name = None
    else:
        name = "fp4_e2m1" if "_fp4" in pool_layer else "fp8_e4m3"
    frozen = ("fp4_e2m1" if key + "_fz" in pool_layer else None)
    return page_format(name), page_format(frozen) if frozen else None


def _fz_operands(pool_layer, names):
    """The frozen-region operand dict for the kernel/oracle call: the
    ``*_fz`` leaves when present, else all-None (the wrappers skip the
    frozen operand block entirely)."""
    out = {}
    for name in names:
        for suffix in ("_fz", "_fz_smax", "_fz_shift"):
            out[name + suffix] = pool_layer.get(name + suffix)
    return out


def _pool_shard_spec(name: str, leaf, msize: int):
    """PartitionSpec for one per-layer pool-slice leaf under head-sharded
    decode: 4-D ``(pages, page, KV, hd)`` code stores shard their head dim,
    per-(page, head) ``*_shift`` scales co-shard with them, and everything
    else (per-page smax, MLA latents, zero-size format markers) replicates.
    """
    from jax.sharding import PartitionSpec as P

    if leaf.ndim == 4 and leaf.size and leaf.shape[2] % msize == 0:
        return P(None, None, "model", None)
    if leaf.ndim == 2 and name.endswith("_shift") and \
            leaf.shape[1] % msize == 0:
        return P(None, "model")
    return P()


def paged_decode_attn(q, pool_layer, page_table, kv_lens, window: int = 0):
    """Paged decode attention over one layer's quantized KV pool slice.

    q: (B, H, hd) single-token queries; pool_layer: one layer of a
    runtime.kv_cache GQA pool ({'k', 'v'} + fp8 scale leaves, plus the
    packed ``*_fz`` frozen-region leaves in a mixed-precision pool);
    page_table: (B, PP) int32 — entries >= P+1 are frozen logical ids;
    kv_lens: (B,) int32 valid token counts; ``window``: sliding-window size
    (0 = full history). Returns (B, H, dv) f32.

    With a decode mesh installed (``set_decode_mesh``) and the KV-head dim
    divisible by the 'model' axis, the whole dataflow runs under
    ``shard_map`` with pages/scales head-sharded: each shard attends its
    own KV-head group against its slice of every page — queries arrive
    head-sharded, no collectives, outputs stay head-sharded. Non-divisible
    head counts fall through to the unsharded call (GSPMD places it).
    """
    mesh = _DECODE_MESH[0]
    if mesh is not None:
        msize = mesh.shape.get("model", 1)
        kvh, h = pool_layer["k"].shape[2], q.shape[1]
        if msize > 1 and kvh % msize == 0 and h % msize == 0:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            specs = {n: _pool_shard_spec(n, l, msize)
                     for n, l in pool_layer.items()}
            hspec = P(None, "model", None)
            fn = shard_map(
                lambda qv, pl, pt, kl: _paged_decode_attn_impl(
                    qv, pl, pt, kl, window=window),
                mesh=mesh, in_specs=(hspec, specs, P(), P()),
                out_specs=hspec, check_rep=False)
            return fn(q, pool_layer, page_table, kv_lens)
    return _paged_decode_attn_impl(q, pool_layer, page_table, kv_lens,
                                   window=window)


def _paged_decode_attn_impl(q, pool_layer, page_table, kv_lens,
                            window: int = 0):
    """Single-shard paged decode attention (backend dispatch unchanged —
    this is exactly the pre-mesh dataflow; under shard_map every shape
    below is the per-shard local shape)."""
    kp, vp = pool_layer["k"], pool_layer["v"]
    fmt, frozen = _layer_formats(pool_layer, "k")
    if fmt.quantized:
        ksm, ksh = pool_layer["k_smax"], pool_layer["k_shift"]
        vsm, vsh = pool_layer["v_smax"], pool_layer["v_shift"]
    else:  # dummies keep the kernel operand list static across formats
        ksm = vsm = jnp.zeros((1,), jnp.float32)
        ksh = vsh = jnp.zeros((1, 1), jnp.int32)
    fz = _fz_operands(pool_layer, ("k", "v"))
    if _BACKEND.startswith("pallas"):
        from .autotune import best_block_sizes
        from .decode_attn import paged_decode_attn_pallas

        b, h, hd = q.shape
        page, kv = kp.shape[1], kp.shape[2]
        bq, _ = best_block_sizes(
            "decode_attn", batch=b, m=h // kv, n=page, k=hd,
            w_fmt=fmt.name or "bf16", a_fmt=None, group_size=page, m2=True,
            lorc_rank=0,
        )
        return paged_decode_attn_pallas(
            q, kp, vp, ksm, ksh, vsm, vsh, page_table, kv_lens,
            fmt=fmt, frozen=frozen, bq=bq, window=window,
            interpret=interpret_mode(), **fz,
        )
    return _ref.paged_decode_attn_ref(
        q, kp, vp, ksm, ksh, vsm, vsh, page_table, kv_lens, fmt=fmt,
        window=window, frozen=frozen, **fz,
    )


def paged_mla_decode_attn(q_lat, q_rope, pool_layer, page_table, kv_lens,
                          scale: float):
    """MLA absorbed decode over one layer's latent page pool slice.

    q_lat: (B, H, r) queries absorbed into the latent space; q_rope:
    (B, H, dr); pool_layer: one layer of a runtime.kv_cache MLA pool
    ({'ckv', 'krope'} + fp8 scale leaves); page_table: (B, PP) int32;
    kv_lens: (B,) int32; ``scale``: softmax scale (1/sqrt(nope + rope
    dims)). Returns the latent context (B, H, r) f32 — KV is one head,
    k = concat(ckv, krope), v = the ckv view.

    Pallas backend: the latent flash-decoding kernel gathers pages through
    the scalar-prefetched page table and dequantizes FP8 in VMEM. Ref: the
    gathered-page jnp oracle.

    With a decode mesh installed, the absorbed query heads shard along
    'model' while the latent pool (no head axis) replicates — each shard
    runs its head group against the full latent pages, so the contraction
    is local and the (B, H, r) context comes back head-sharded.
    """
    mesh = _DECODE_MESH[0]
    if mesh is not None:
        msize = mesh.shape.get("model", 1)
        if msize > 1 and q_lat.shape[1] % msize == 0:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            specs = {n: P() for n in pool_layer}  # latents: no head axis
            hspec = P(None, "model", None)
            fn = shard_map(
                lambda ql, qr, pl, pt, kl: _paged_mla_decode_attn_impl(
                    ql, qr, pl, pt, kl, scale=scale),
                mesh=mesh, in_specs=(hspec, hspec, specs, P(), P()),
                out_specs=hspec, check_rep=False)
            return fn(q_lat, q_rope, pool_layer, page_table, kv_lens)
    return _paged_mla_decode_attn_impl(q_lat, q_rope, pool_layer, page_table,
                                       kv_lens, scale=scale)


def _paged_mla_decode_attn_impl(q_lat, q_rope, pool_layer, page_table,
                                kv_lens, scale: float):
    """Single-shard MLA absorbed decode (the pre-mesh dataflow; under
    shard_map the head dim below is the per-shard local head count)."""
    cp, rp = pool_layer["ckv"], pool_layer["krope"]
    fmt, frozen = _layer_formats(pool_layer, "ckv")
    if fmt.quantized:
        csm, csh = pool_layer["ckv_smax"], pool_layer["ckv_shift"]
        rsm, rsh = pool_layer["krope_smax"], pool_layer["krope_shift"]
    else:  # dummies keep the kernel operand list static across formats
        csm = rsm = jnp.zeros((1,), jnp.float32)
        csh = rsh = jnp.zeros((1, 1), jnp.int32)
    fz = _fz_operands(pool_layer, ("ckv", "krope"))
    if _BACKEND.startswith("pallas"):
        from .autotune import best_block_sizes
        from .decode_attn import paged_mla_decode_attn_pallas

        b, h, r = q_lat.shape
        page = cp.shape[1]
        # same autotune kind as GQA decode: bm is the query-head block,
        # bn the page size; the latent contraction dim is r + dr
        bq, _ = best_block_sizes(
            "decode_attn", batch=b, m=h, n=page, k=r + q_rope.shape[-1],
            w_fmt=fmt.name or "bf16", a_fmt=None, group_size=page, m2=True,
            lorc_rank=0,
        )
        return paged_mla_decode_attn_pallas(
            q_lat, q_rope, cp, rp, csm, csh, rsm, rsh, page_table, kv_lens,
            scale, fmt=fmt, frozen=frozen, bq=bq,
            interpret=interpret_mode(), **fz,
        )
    return _ref.paged_mla_decode_attn_ref(
        q_lat, q_rope, cp, rp, csm, csh, rsm, rsh, page_table, kv_lens,
        scale, fmt=fmt, frozen=frozen, **fz,
    )


def dequant_packed(w):
    """PackedLinear -> dense f32 weights. Ref-backend fallback for einsum
    call-sites; the pallas backend routes those through w4a8_matmul_batched
    instead (asserted by tests/test_w4a8_fused.py)."""
    out = _ref.dequant_packed_ref(w.codes, w.scale, w.w_fmt, w.group_size)
    if w.lorc_a is not None:
        out = out + jnp.einsum(
            "...or,...ri->...oi", w.lorc_a.astype(jnp.float32), w.lorc_b.astype(jnp.float32)
        )
    return out
