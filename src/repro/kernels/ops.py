"""jit'd wrappers over the Pallas kernels with jnp-reference fallback.

Backend selection:
  * 'ref'      — pure-jnp oracle semantics (default off-TPU; also what the
                 dry-run lowers, so rooflines see realistic HLO).
  * 'pallas'   — pl.pallas_call TPU kernels (interpret=True on CPU for
                 tests; compiled on real TPU).
Set via set_backend() or REPRO_KERNEL_BACKEND env var.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref as _ref

_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "ref")


def set_backend(name: str):
    global _BACKEND
    assert name in ("ref", "pallas", "pallas_interpret")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def act_quant(x, fmt_name: str = "fp8_e4m3"):
    """Token-wise FP8 quantization -> (values_on_grid, scale)."""
    if _BACKEND.startswith("pallas"):
        from .act_quant import act_quant_pallas

        return act_quant_pallas(x, fmt_name, interpret=_BACKEND == "pallas_interpret")
    return _ref.act_quant_ref(x, fmt_name)


def w4a8_matmul(x, w):
    """x: (..., in); w: PackedLinear (2D codes after any scan slicing)."""
    assert w.codes.ndim == 2, "batched PackedLinear must go through dequant_packed"
    if _BACKEND.startswith("pallas"):
        from .act_quant import act_quant_pallas
        from .w4a8_matmul import w4a8_matmul_pallas

        interp = _BACKEND in ("pallas", "pallas_interpret")  # CPU: always interpret
        lead = x.shape[:-1]
        k = x.shape[-1]
        x2 = x.reshape(-1, k)
        if w.a_fmt:
            qv, sc = act_quant_pallas(x2, w.a_fmt, interpret=interp)
            xq = (qv * sc).astype(jnp.bfloat16)
        else:
            xq = x2.astype(jnp.bfloat16)
        y = w4a8_matmul_pallas(
            xq, w.codes, w.scale, s_max=w.s_max, shifts=w.shifts,
            w_fmt=w.w_fmt, group_size=w.group_size, interpret=interp,
        )
        if w.lorc_a is not None:
            y = y + (xq @ w.lorc_b.T.astype(jnp.bfloat16)).astype(jnp.bfloat16) @ w.lorc_a.T.astype(jnp.bfloat16)
        return y.reshape(*lead, -1).astype(x.dtype)
    return _ref.w4a8_matmul_ref(
        x, w.codes, w.scale, w.lorc_a, w.lorc_b,
        w_fmt=w.w_fmt, a_fmt=w.a_fmt, group_size=w.group_size,
    )


def dequant_packed(w):
    """PackedLinear -> dense f32 weights (used by einsum paths: MoE experts,
    MLA absorbed projections)."""
    out = _ref.dequant_packed_ref(w.codes, w.scale, w.w_fmt, w.group_size)
    if w.lorc_a is not None:
        out = out + jnp.einsum(
            "...or,...ri->...oi", w.lorc_a.astype(jnp.float32), w.lorc_b.astype(jnp.float32)
        )
    return out
