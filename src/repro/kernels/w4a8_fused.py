"""Pallas TPU kernel: fused single-pass W4A8 GEMM pipeline.

The split deployment path costs three HBM round-trips: act_quant writes the
FP8-grid activations, the GEMM reads them back, and the LoRC correction runs
as two extra bf16 matmuls over the same activations. This kernel does the
whole quantize -> decode -> matmul -> correct chain in one pl.pallas_call:

  1. *in-kernel activation quantization*: the full K row of the M-tile is
     resident in VMEM (same layout contract as act_quant — feature dims fit
     one block), so when the first N-tile visits an M-tile the per-token
     absmax/scale is computed and the whole row is RNE-rounded onto the FP8
     grid into a bf16 VMEM scratch; later N-tiles of the same M-tile reuse
     the scratch. Nothing is materialized to HBM.
  2. packed E2M1/E3M0 nibbles are decoded in VMEM per (BN, BK=group) slice
     (copy-free bitwise unpack) and the per-(row, group) scale folds into
     the slice (M2: 2^-k from the exponent bit pattern + one per-row s_max
     multiply after the loop).
  3. the K loop lives *inside* the kernel (flash-attention style): a f32
     accumulator carried across the K steps in VMEM/registers, one single
     HBM write of the finished tile.
  4. *fused LoRC epilogue*: the rank-r correction (x @ B^T) @ A^T is applied
     to the accumulator before that single write.

A leading batch grid axis makes the same kernel serve stacked weights: MoE
expert stacks (E, out, in) and MLA per-head absorbed projections call it
directly instead of densifying through dequant_packed. Two orientations:

  * normal:     y[e] = x[e] @ W[e]^T — contraction over in-features (K),
                group scales along the contraction dim (the 2-D serving GEMM
                is this with E == 1);
  * transposed: y[e] = x[e] @ W[e]   — contraction over the weight's out
                rows (the MLA absorbed q path contracts wk_b's out dim);
                group scales then lie along the *output* dim, so the output
                tile is one scale group wide and s_max folds into the
                weight slice inside the loop.

Grid: (E, M/BM, N/BN) — output-tile programs, K internal. Block sizes come
from kernels.autotune; both are clamped to divisors of their dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import FORMATS

from .common import DECODERS, pow2i, round_to_grid, token_scale, unpack_nibbles

__all__ = ["w4a8_fused_matmul_pallas", "w4a8_fused_batched_pallas", "clamp_block"]


def clamp_block(dim: int, blk: int) -> int:
    """Largest divisor of ``dim`` that is <= blk (the kernels' tiling rule)."""
    blk = max(1, min(blk, dim))
    while dim % blk:
        blk -= 1
    return blk


def _kernel(refs, *, w_fmt, a_fmt, m2, lorc, gs, bm, bn, nsteps, transpose):
    """One (BM, BN) output tile; the K/contraction loop runs inside.

    ``refs`` is the positional (inputs..., output, scratch...) list; which
    optional refs are present is decided by the static flags.
    """
    refs = list(refs)
    x_ref = refs.pop(0)          # (1, BM, D) raw activations, full row
    codes_ref = refs.pop(0)      # (1, BN, K/2) | (1, O, gs/2)
    scale_ref = refs.pop(0)      # (1, BN, G)   | (1, O, 1)   (shifts when m2)
    smax_ref = refs.pop(0) if m2 else None
    a_ref = refs.pop(0) if lorc else None
    b_ref = refs.pop(0) if lorc else None
    o_ref = refs.pop(0)
    xq_scr = refs.pop(0) if a_fmt else None  # (BM, D) bf16 quantized slab
    lr_scr = refs.pop(0) if lorc else None   # (BM, r) f32 LoRC projection
    assert not refs
    decode = DECODERS[w_fmt]

    # ---- in-kernel FP8 quantization, once per M-tile -----------------------
    if a_fmt:
        fmt = FORMATS[a_fmt]

        @pl.when(pl.program_id(2) == 0)
        def _quantize_slab():
            xf = x_ref[0].astype(jnp.float32)
            sc = token_scale(xf, fmt)
            xq_scr[...] = (round_to_grid(xf / sc, fmt) * sc).astype(jnp.bfloat16)

        xq = xq_scr[...]
    else:
        xq = x_ref[0].astype(jnp.bfloat16)

    # ---- LoRC skinny projection, once per M-tile ---------------------------
    # xr depends only on the M-tile and the output-tile-invariant factor
    # (B^T in normal orientation, A in transposed), so it is computed by the
    # first output-tile program and reused from scratch by the rest.
    if lorc:

        @pl.when(pl.program_id(2) == 0)
        def _lorc_project():
            fac = a_ref[0] if transpose else b_ref[0]
            cdim = (0,) if transpose else (1,)
            lr_scr[...] = jax.lax.dot_general(
                xq, fac.astype(jnp.bfloat16), (((1,), cdim), ((), ())),
                preferred_element_type=jnp.float32)

    # ---- K loop: decode + scale a weight slice, accumulate in f32 ---------
    # whole-block VMEM reads once; the loop slices the loaded values
    half = gs // 2
    codes_all = codes_ref[0]
    scale_all = scale_ref[0]
    smax_all = smax_ref[0] if m2 else None

    def body(s, acc):
        if transpose:
            cod = jax.lax.dynamic_slice(codes_all, (s * bn, 0), (bn, half))
            gsc = jax.lax.dynamic_slice(scale_all, (s * bn, 0), (bn, 1))
            if m2:
                sm = jax.lax.dynamic_slice(smax_all, (s * bn, 0), (bn, 1))
                gsc = pow2i(-gsc.astype(jnp.int32)) * sm
            xs = jax.lax.dynamic_slice(xq, (0, s * bn), (bm, bn))
            dims = (((1,), (0,)), ((), ()))
        else:
            cod = jax.lax.dynamic_slice(codes_all, (0, s * half), (bn, half))
            gsc = jax.lax.dynamic_slice(scale_all, (0, s), (bn, 1))
            if m2:
                gsc = pow2i(-gsc.astype(jnp.int32))
            xs = jax.lax.dynamic_slice(xq, (0, s * gs), (bm, gs))
            dims = (((1,), (1,)), ((), ()))
        w = (decode(unpack_nibbles(cod)) * gsc).astype(jnp.bfloat16)
        return acc + jax.lax.dot_general(xs, w, dims,
                                         preferred_element_type=jnp.float32)

    out_cols = gs if transpose else bn
    # unrolled: nsteps is static, so the slices become static and XLA can
    # fold the decode chain per step instead of carrying a dynamic loop
    acc = jax.lax.fori_loop(
        0, nsteps, body, jnp.zeros((bm, out_cols), jnp.float32),
        unroll=True)

    if m2 and not transpose:
        acc = acc * smax_ref[0].reshape(1, -1)  # per-row s_max, once

    # ---- fused LoRC epilogue before the single HBM write -------------------
    if lorc:
        xr = lr_scr[...].astype(jnp.bfloat16)  # (BM, r) from the projection
        if transpose:
            corr = jax.lax.dot_general(
                xr, b_ref[0].astype(jnp.bfloat16), (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        else:
            corr = jax.lax.dot_general(
                xr, a_ref[0].astype(jnp.bfloat16), (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
        acc = acc + corr

    o_ref[0] = acc


@functools.partial(
    jax.jit,
    static_argnames=("w_fmt", "a_fmt", "group_size", "bm", "bn", "transpose_w",
                     "interpret"),
)
def w4a8_fused_batched_pallas(
    x,
    codes,
    scale,
    s_max=None,
    shifts=None,
    lorc_a=None,
    lorc_b=None,
    *,
    w_fmt: str = "fp4_e2m1",
    a_fmt=None,
    group_size: int = 256,
    bm: int = 128,
    bn: int = 128,
    transpose_w: bool = False,
    interpret=None,
):
    """Batched fused W4A8 GEMM over stacked packed weights.

    x: (E, M, D) float — raw (unquantized) activations; quantized in-kernel
       when ``a_fmt`` is set.
    codes: (E, N, In/2) uint8; scale: (E, N, n_groups) f32.
    normal (transpose_w=False): D == In, returns (E, M, N) f32.
    transposed: D == N (contract the weight's out rows), returns (E, M, In).
    Optional M2 decomposition (s_max (E, N, 1), shifts (E, N, n_groups)) and
    LoRC factors (lorc_a (E, N, r), lorc_b (E, r, In)).
    ``interpret=None`` resolves from the runtime: compiled on TPU,
    interpreter elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    ne, m, d = x.shape
    n_rows, half = codes.shape[1], codes.shape[2]
    in_f = half * 2
    gs = group_size
    assert scale.shape[-1] * gs == in_f, (scale.shape, gs, in_f)
    m2 = shifts is not None
    lorc = lorc_a is not None and lorc_a.shape[-1] > 0
    r = lorc_a.shape[-1] if lorc else 0

    bm = clamp_block(m, bm)
    bn = clamp_block(n_rows, bn)
    if transpose_w:
        assert d == n_rows, (d, n_rows)
        nsteps = n_rows // bn
        grid = (ne, m // bm, in_f // gs)
        n_out, bn_out = in_f, gs
        codes_spec = pl.BlockSpec((1, n_rows, gs // 2), lambda e, i, j: (e, 0, j))
        scale_spec = pl.BlockSpec((1, n_rows, 1), lambda e, i, j: (e, 0, j))
        smax_spec = pl.BlockSpec((1, n_rows, 1), lambda e, i, j: (e, 0, 0))
        a_spec = pl.BlockSpec((1, n_rows, r), lambda e, i, j: (e, 0, 0))
        b_spec = pl.BlockSpec((1, r, gs), lambda e, i, j: (e, 0, j))
    else:
        assert d == in_f, (d, in_f)
        assert d % gs == 0, (d, gs)
        nsteps = d // gs
        grid = (ne, m // bm, n_rows // bn)
        n_out, bn_out = n_rows, bn
        codes_spec = pl.BlockSpec((1, bn, half), lambda e, i, j: (e, j, 0))
        scale_spec = pl.BlockSpec((1, bn, nsteps), lambda e, i, j: (e, j, 0))
        smax_spec = pl.BlockSpec((1, bn, 1), lambda e, i, j: (e, j, 0))
        a_spec = pl.BlockSpec((1, bn, r), lambda e, i, j: (e, j, 0))
        b_spec = pl.BlockSpec((1, r, d), lambda e, i, j: (e, 0, 0))

    args = [x, codes, shifts.astype(jnp.int32) if m2 else scale]
    in_specs = [
        pl.BlockSpec((1, bm, d), lambda e, i, j: (e, i, 0)),  # full-row slab
        codes_spec,
        scale_spec,
    ]
    if m2:
        args.append(s_max.reshape(ne, n_rows, 1))
        in_specs.append(smax_spec)
    if lorc:
        args += [lorc_a, lorc_b]
        in_specs += [a_spec, b_spec]

    scratch_shapes = []
    if a_fmt:
        scratch_shapes.append(pltpu.VMEM((bm, d), jnp.bfloat16))
    if lorc:
        scratch_shapes.append(pltpu.VMEM((bm, r), jnp.float32))

    kernel = functools.partial(
        _kernel, w_fmt=w_fmt, a_fmt=a_fmt, m2=m2, lorc=lorc, gs=gs, bm=bm,
        bn=bn, nsteps=nsteps, transpose=transpose_w,
    )
    out = pl.pallas_call(
        lambda *refs: kernel(refs),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn_out), lambda e, i, j: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((ne, m, n_out), jnp.float32),
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(*args)
    return out


@functools.partial(
    jax.jit,
    static_argnames=("w_fmt", "a_fmt", "group_size", "bm", "bn", "interpret"),
)
def w4a8_fused_matmul_pallas(
    x,
    codes,
    scale,
    s_max=None,
    shifts=None,
    lorc_a=None,
    lorc_b=None,
    *,
    w_fmt: str = "fp4_e2m1",
    a_fmt="fp8_e4m3",
    group_size: int = 256,
    bm: int = 128,
    bn: int = 128,
    interpret=None,
):
    """2-D fused deployment GEMM: y[m, n] = sum_k q8(x)[m, k] * deq(w)[n, k]
    [+ LoRC]. x: (M, K) raw activations; codes: (N, K/2). Returns (M, N) f32.
    This is the batched kernel with a unit leading axis."""
    none = lambda v: None if v is None else v[None]
    out = w4a8_fused_batched_pallas(
        x[None], codes[None], scale[None], none(s_max), none(shifts),
        none(lorc_a), none(lorc_b), w_fmt=w_fmt, a_fmt=a_fmt,
        group_size=group_size, bm=bm, bn=bn, transpose_w=False,
        interpret=interpret,
    )
    return out[0]
