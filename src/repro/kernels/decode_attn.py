"""Pallas TPU kernel: paged FP8 decode attention (flash-decoding dataflow).

Decode's dominant memory term is the KV-cache read; this kernel reads the
cache in its *deployed* form — packed FP8 E4M3 pages with per-(page, head)
M2 scales — and never materializes a dequantized cache in HBM:

  * the page table and per-row true lengths ride in as scalar-prefetch
    operands (SMEM); each grid step's BlockSpec index_map *gathers* its page
    straight from the pool via ``page_table[b, j]`` — the DMA engine fetches
    exactly the pages a row owns, in page-table order,
  * FP8 codes are dequantized in VMEM with the exponent-add scale apply
    (kernels.common.decode_fp8: per-head shift k is an integer add on the
    exponent; the full-precision s_max multiplies once per page),
  * online softmax (m, l, acc) accumulators live in VMEM scratch across the
    page loop (innermost grid dim), standard flash-decoding.

Grid: (B, KV_heads, pages_per_slot). The g = H/KV query heads of a KV group
are processed together as the row block (padded to ``bq`` for VPU/MXU
tiling — the autotuner's block size for this kernel). Rows past a slot's
true length are masked by position, so per-slot lengths need no host-side
synchronization (this is what retires the engine's max-length hack).

The jnp oracle is kernels.ref.paged_decode_attn_ref; interpret-mode parity
is asserted by tests/test_kv_cache.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import FORMATS
from .common import decode_fp8

__all__ = ["paged_decode_attn_pallas", "paged_mla_decode_attn_pallas"]

_NEG_INF = -1e30


def _kernel(pt_ref, len_ref, ksm_ref, ksh_ref, vsm_ref, vsh_ref,
            q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, page, pp, scale, kv_fmt, window):
    b, h, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
    if kv_fmt is not None:
        fmt = FORMATS[kv_fmt]
        pid = pt_ref[b, j]
        # exponent-add scale apply: integer add of -k on the code exponent,
        # then one full-precision s_max multiply per (page, head)
        k = decode_fp8(k_ref[0, :, 0], fmt, ksh_ref[pid, h]) * ksm_ref[pid]
        v = decode_fp8(v_ref[0, :, 0], fmt, vsh_ref[pid, h]) * vsm_ref[pid]
    else:
        k = k_ref[0, :, 0].astype(jnp.float32)  # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < len_ref[b]
    if window:  # sliding window: the query sits at position kv_len - 1
        valid &= pos > len_ref[b] - 1 - window
    s = jnp.where(valid, s, _NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    # fully-masked pages leave m at -inf; exp(s - m) would be exp(0) = 1
    # for every masked lane, so the mask must hit p, not just s
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == pp - 1)
    def _done():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("kv_fmt", "bq", "window",
                                             "interpret"))
def paged_decode_attn_pallas(q, k_pages, v_pages, k_smax, k_shift, v_smax,
                             v_shift, page_table, kv_lens,
                             kv_fmt=None, bq: int = 8, window: int = 0,
                             interpret: bool = True):
    """q: (B, H, hd) single-token queries; k_pages/v_pages: (P+1, page, KV,
    hd) uint8 codes (fp8) or bf16 values; k/v_smax: (P+1,) f32; k/v_shift:
    (P+1, KV) int32 (pass zeros-shaped dummies when ``kv_fmt`` is None);
    page_table: (B, PP) int32; kv_lens: (B,) valid token counts; ``window``:
    sliding-window size (0 = full history). Returns (B, H, dv) f32. GQA
    head repetition is internal (grid over KV heads, g query heads per
    block, padded to ``bq``).
    """
    b, h, hd = q.shape
    p1, page, kv, _ = k_pages.shape
    dv = v_pages.shape[-1]
    pp = page_table.shape[1]
    g = h // kv
    bq = max(bq, g)
    qg = q.reshape(b, kv, g, hd)
    if bq != g:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, bq - g), (0, 0)))

    def page_map(bi, hi, ji, pt, ln, *_s):
        return (pt[bi, ji], 0, hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(b, kv, pp),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bi, hi, ji, *_s: (bi, hi, 0, 0)),
            pl.BlockSpec((1, page, 1, hd), page_map),
            pl.BlockSpec((1, page, 1, dv), page_map),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv),
                               lambda bi, hi, ji, *_s: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, page=page, pp=pp,
                          scale=1.0 / float(hd) ** 0.5, kv_fmt=kv_fmt,
                          window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, bq, dv), jnp.float32),
        interpret=interpret,
    )(page_table, kv_lens, k_smax, k_shift, v_smax, v_shift, qg,
      k_pages, v_pages)
    return out[:, :, :g].reshape(b, h, dv)


# ---------------------------------------------------------------------------
# MLA latent decode: KV = 1 head, k = concat(ckv, krope), v = ckv view
# ---------------------------------------------------------------------------
def _mla_kernel(pt_ref, len_ref, csm_ref, csh_ref, rsm_ref, rsh_ref,
                ql_ref, qr_ref, ckv_ref, kr_ref, o_ref, m_ref, l_ref, acc_ref,
                *, page, pp, scale, kv_fmt):
    b, j = pl.program_id(0), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ql = ql_ref[0, 0].astype(jnp.float32)  # (bq, r)
    qr = qr_ref[0, 0].astype(jnp.float32)  # (bq, dr)
    if kv_fmt is not None:
        fmt = FORMATS[kv_fmt]
        pid = pt_ref[b, j]
        # the latent has no head axis: one M2 shift per page (head index 0),
        # applied as the same exponent add + one s_max multiply per page
        ckv = decode_fp8(ckv_ref[0], fmt, csh_ref[pid, 0]) * csm_ref[pid]
        kr = decode_fp8(kr_ref[0], fmt, rsh_ref[pid, 0]) * rsm_ref[pid]
    else:
        ckv = ckv_ref[0].astype(jnp.float32)  # (page, r)
        kr = kr_ref[0].astype(jnp.float32)  # (page, dr)

    # scores against k = concat(ckv, krope) without materializing the
    # concat: contract the latent and rope halves separately and add
    s = (jax.lax.dot_general(ql, ckv, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)) * scale
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < len_ref[b]
    s = jnp.where(valid, s, _NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    # v is the ckv view: the attention-weighted latent IS the context
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, ckv, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == pp - 1)
    def _done():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("scale", "kv_fmt", "bq",
                                             "interpret"))
def paged_mla_decode_attn_pallas(q_lat, q_rope, ckv_pages, krope_pages,
                                 ckv_smax, ckv_shift, krope_smax, krope_shift,
                                 page_table, kv_lens, scale,
                                 kv_fmt=None, bq: int = 8,
                                 interpret: bool = True):
    """MLA absorbed decode over latent pages (flash-decoding dataflow).

    q_lat: (B, H, r) queries absorbed into the latent space; q_rope:
    (B, H, dr) rope-space queries; ckv_pages: (P+1, page, r) and
    krope_pages: (P+1, page, dr) uint8 FP8 codes (``kv_fmt`` set) or bf16;
    c/r smax: (P+1,) f32; c/r shift: (P+1, 1) int32 (single scale "head");
    page_table: (B, PP) int32; kv_lens: (B,); ``scale``: softmax scale
    (1/sqrt(qk_nope + qk_rope)). Returns the latent context (B, H, r) f32 —
    the caller applies the absorbed v_up projection.

    KV is a single head: every query head scores the same k =
    concat(ckv, krope) page block and v is the ckv view, so the grid is
    (B, ceil(H / bq), pages) with the page loop innermost and the latent
    never gathered into HBM.
    """
    b, h, r = q_lat.shape
    dr = q_rope.shape[-1]
    p1, page, _ = ckv_pages.shape
    pp = page_table.shape[1]
    hb = -(-h // bq)
    pad = hb * bq - h
    if pad:
        q_lat = jnp.pad(q_lat, ((0, 0), (0, pad), (0, 0)))
        q_rope = jnp.pad(q_rope, ((0, 0), (0, pad), (0, 0)))
    ql = q_lat.reshape(b, hb, bq, r)
    qr = q_rope.reshape(b, hb, bq, dr)

    def page_map(bi, hi, ji, pt, ln, *_s):
        return (pt[bi, ji], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(b, hb, pp),
        in_specs=[
            pl.BlockSpec((1, 1, bq, r), lambda bi, hi, ji, *_s: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, bq, dr), lambda bi, hi, ji, *_s: (bi, hi, 0, 0)),
            pl.BlockSpec((1, page, r), page_map),
            pl.BlockSpec((1, page, dr), page_map),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, r),
                               lambda bi, hi, ji, *_s: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, r), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_mla_kernel, page=page, pp=pp, scale=scale,
                          kv_fmt=kv_fmt),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hb, bq, r), jnp.float32),
        interpret=interpret,
    )(page_table, kv_lens, ckv_smax, ckv_shift, krope_smax, krope_shift,
      ql, qr, ckv_pages, krope_pages)
    return out.reshape(b, hb * bq, r)[:, :h]
