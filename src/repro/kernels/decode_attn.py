"""Pallas TPU kernel: paged FP8/FP4 decode attention (flash-decoding dataflow).

Decode's dominant memory term is the KV-cache read; this kernel reads the
cache in its *deployed* form — packed FP8 E4M3 pages with per-(page, head)
M2 scales, plus (mixed-precision pools) a packed FP4 E2M1 frozen region —
and never materializes a dequantized cache in HBM:

  * the page table and per-row true lengths ride in as scalar-prefetch
    operands (SMEM); each grid step's BlockSpec index_map *gathers* its page
    straight from the pool via ``page_table[b, j]`` — the DMA engine fetches
    exactly the pages a row owns, in page-table order,
  * codes are dequantized in VMEM with the exponent-add scale apply
    (kernels.common.decode_fp8: per-head shift k is an integer add on the
    exponent; the full-precision s_max multiplies once per page),
  * online softmax (m, l, acc) accumulators live in VMEM scratch across the
    page loop (innermost grid dim), standard flash-decoding.

Formats ride in as one frozen ``PageFormat`` static per page class
(``fmt`` for the active store, ``frozen`` for the packed FP4 region) —
coerced through :func:`kernels.common.page_format`, which fails fast with
the allowed set instead of letting an unknown string surface as an opaque
``KeyError`` mid-trace. With ``frozen`` set, the per-page format select is
driven by the scalar-prefetched page table itself: logical ids >= the
active row count address the frozen store, so the index maps gather *both*
candidate pages with clamped indices and the kernel body selects the
decoded block by id class — no extra mask operand, no divergent grid.

Grid: (B, KV_heads, pages_per_slot). The g = H/KV query heads of a KV group
are processed together as the row block (padded to ``bq`` for VPU/MXU
tiling — the autotuner's block size for this kernel). Rows past a slot's
true length are masked by position, so per-slot lengths need no host-side
synchronization (this is what retires the engine's max-length hack).

On a serving mesh the kernel is *oblivious* to tensor parallelism: the
engine's shard_map wrapper (kernels.ops.paged_decode_attn) hands each
model-axis shard a contiguous KV-head block of the pool (codes and their
per-(page, head) shift scales co-sharded on the head dim; per-page s_max
replicated) plus the matching contiguous q-head block — GQA's g = H/KV
grouping is preserved locally because both head counts divide the axis —
so the kernel body, grid and index maps are identical per shard, just with
KV/m heads. MLA's latent pages have no head axis and stay replicated; its
wrapper shards the absorbed q heads only.

The jnp oracle is kernels.ref.paged_decode_attn_ref; interpret-mode parity
is asserted by tests/test_kv_cache.py (FP8 tier) and tests/test_fp4_cache.py
(packed FP4 tier).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import page_format

__all__ = ["paged_decode_attn_pallas", "paged_mla_decode_attn_pallas"]

_NEG_INF = -1e30


def _kernel(*refs, page, pp, scale, fmt, frozen, base, nfz, hd, dv, window):
    if frozen is not None:
        (pt_ref, len_ref, ksm_ref, ksh_ref, vsm_ref, vsh_ref,
         kfsm_ref, kfsh_ref, vfsm_ref, vfsh_ref,
         q_ref, k_ref, v_ref, kf_ref, vf_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (pt_ref, len_ref, ksm_ref, ksh_ref, vsm_ref, vsh_ref,
         q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref) = refs
    b, h, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
    if fmt.quantized:
        pid = pt_ref[b, j]
        # exponent-add scale apply: integer add of -k on the code exponent,
        # then one full-precision s_max multiply per (page, head)
        apid = jnp.minimum(pid, base - 1) if frozen is not None else pid
        k = fmt.decode(k_ref[0, :, 0], ksh_ref[apid, h], hd) * ksm_ref[apid]
        v = fmt.decode(v_ref[0, :, 0], vsh_ref[apid, h], dv) * vsm_ref[apid]
        if frozen is not None:
            # per-page format select off the prefetched table: logical ids
            # >= base address the packed FP4 frozen region. Both candidate
            # blocks were DMA'd via clamped index maps; pick by id class.
            fpid = jnp.clip(pid - base, 0, nfz)
            is_fz = pid >= base
            kf = frozen.decode(kf_ref[0, :, 0], kfsh_ref[fpid, h], hd) \
                * kfsm_ref[fpid]
            vf = frozen.decode(vf_ref[0, :, 0], vfsh_ref[fpid, h], dv) \
                * vfsm_ref[fpid]
            k = jnp.where(is_fz, kf, k)
            v = jnp.where(is_fz, vf, v)
    else:
        k = k_ref[0, :, 0].astype(jnp.float32)  # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < len_ref[b]
    if window:  # sliding window: the query sits at position kv_len - 1
        valid &= pos > len_ref[b] - 1 - window
    s = jnp.where(valid, s, _NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    # fully-masked pages leave m at -inf; exp(s - m) would be exp(0) = 1
    # for every masked lane, so the mask must hit p, not just s
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == pp - 1)
    def _done():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("fmt", "frozen", "bq", "window",
                                             "interpret"))
def paged_decode_attn_pallas(q, k_pages, v_pages, k_smax, k_shift, v_smax,
                             v_shift, page_table, kv_lens,
                             fmt=None, frozen=None,
                             k_fz=None, v_fz=None, k_fz_smax=None,
                             k_fz_shift=None, v_fz_smax=None, v_fz_shift=None,
                             bq: int = 8, window: int = 0,
                             interpret: bool = True):
    """q: (B, H, hd) single-token queries; k_pages/v_pages: (P+1, page, KV,
    hd) uint8 codes (``fmt`` quantized) or bf16 values; k/v_smax: (P+1,) f32;
    k/v_shift: (P+1, KV) int32 (pass zeros-shaped dummies for bf16);
    page_table: (B, PP) int32; kv_lens: (B,) valid token counts; ``window``:
    sliding-window size (0 = full history). ``fmt``/``frozen`` accept a
    PageFormat or a format name (coerced via ``page_format`` — unknown names
    fail fast with the allowed set). With ``frozen`` set the ``*_fz``
    operands carry the packed FP4 region ((F+1, page, KV, ceil(hd/2)) codes
    + its own scales; row F is the dummy clamped gathers land on) and table
    entries >= P+1 select it per page. Returns (B, H, dv) f32. GQA head
    repetition is internal (grid over KV heads, g query heads per block,
    padded to ``bq``).
    """
    fmt = page_format(fmt)
    frozen = page_format(frozen) if frozen is not None else None
    assert frozen is None or (fmt.quantized and frozen.quantized), \
        "a frozen region requires quantized active pages"
    b, h, hd = q.shape
    p1, page, kv, _ = k_pages.shape
    dv = v_pages.shape[-1]
    pp = page_table.shape[1]
    g = h // kv
    bq = max(bq, g)
    qg = q.reshape(b, kv, g, hd)
    if bq != g:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, bq - g), (0, 0)))

    nfz = 0 if k_fz is None else k_fz.shape[0] - 1

    def page_map(bi, hi, ji, pt, ln, *_s):
        pid = pt[bi, ji]
        if frozen is not None:  # frozen ids clamp to the null page
            pid = jnp.minimum(pid, p1 - 1)
        return (pid, 0, hi, 0)

    def fz_page_map(bi, hi, ji, pt, ln, *_s):
        return (jnp.clip(pt[bi, ji] - p1, 0, nfz), 0, hi, 0)

    def q_map(bi, hi, ji, *_s):
        return (bi, hi, 0, 0)

    scalars = [page_table, kv_lens, k_smax, k_shift, v_smax, v_shift]
    tensors = [qg, k_pages, v_pages]
    in_specs = [
        pl.BlockSpec((1, 1, bq, hd), q_map),
        pl.BlockSpec((1, page, 1, hd), page_map),
        pl.BlockSpec((1, page, 1, dv), page_map),
    ]
    if frozen is not None:
        scalars += [k_fz_smax, k_fz_shift, v_fz_smax, v_fz_shift]
        tensors += [k_fz, v_fz]
        in_specs += [
            pl.BlockSpec((1, page, 1, k_fz.shape[-1]), fz_page_map),
            pl.BlockSpec((1, page, 1, v_fz.shape[-1]), fz_page_map),
        ]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalars),
        grid=(b, kv, pp),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, bq, dv), q_map),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, page=page, pp=pp,
                          scale=1.0 / float(hd) ** 0.5, fmt=fmt,
                          frozen=frozen, base=p1, nfz=nfz, hd=hd, dv=dv,
                          window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, bq, dv), jnp.float32),
        interpret=interpret,
    )(*scalars, *tensors)
    return out[:, :, :g].reshape(b, h, dv)


# ---------------------------------------------------------------------------
# MLA latent decode: KV = 1 head, k = concat(ckv, krope), v = ckv view
# ---------------------------------------------------------------------------
def _mla_kernel(*refs, page, pp, scale, fmt, frozen, base, nfz, r, dr):
    if frozen is not None:
        (pt_ref, len_ref, csm_ref, csh_ref, rsm_ref, rsh_ref,
         cfsm_ref, cfsh_ref, rfsm_ref, rfsh_ref,
         ql_ref, qr_ref, ckv_ref, kr_ref, cf_ref, rf_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (pt_ref, len_ref, csm_ref, csh_ref, rsm_ref, rsh_ref,
         ql_ref, qr_ref, ckv_ref, kr_ref, o_ref, m_ref, l_ref,
         acc_ref) = refs
    b, j = pl.program_id(0), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ql = ql_ref[0, 0].astype(jnp.float32)  # (bq, r)
    qr = qr_ref[0, 0].astype(jnp.float32)  # (bq, dr)
    if fmt.quantized:
        pid = pt_ref[b, j]
        # the latent has no head axis: one M2 shift per page (head index 0),
        # applied as the same exponent add + one s_max multiply per page
        apid = jnp.minimum(pid, base - 1) if frozen is not None else pid
        ckv = fmt.decode(ckv_ref[0], csh_ref[apid, 0], r) * csm_ref[apid]
        kr = fmt.decode(kr_ref[0], rsh_ref[apid, 0], dr) * rsm_ref[apid]
        if frozen is not None:
            fpid = jnp.clip(pid - base, 0, nfz)
            is_fz = pid >= base
            cf = frozen.decode(cf_ref[0], cfsh_ref[fpid, 0], r) \
                * cfsm_ref[fpid]
            rf = frozen.decode(rf_ref[0], rfsh_ref[fpid, 0], dr) \
                * rfsm_ref[fpid]
            ckv = jnp.where(is_fz, cf, ckv)
            kr = jnp.where(is_fz, rf, kr)
    else:
        ckv = ckv_ref[0].astype(jnp.float32)  # (page, r)
        kr = kr_ref[0].astype(jnp.float32)  # (page, dr)

    # scores against k = concat(ckv, krope) without materializing the
    # concat: contract the latent and rope halves separately and add
    s = (jax.lax.dot_general(ql, ckv, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)) * scale
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < len_ref[b]
    s = jnp.where(valid, s, _NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    # v is the ckv view: the attention-weighted latent IS the context
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, ckv, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == pp - 1)
    def _done():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("scale", "fmt", "frozen", "bq",
                                             "interpret"))
def paged_mla_decode_attn_pallas(q_lat, q_rope, ckv_pages, krope_pages,
                                 ckv_smax, ckv_shift, krope_smax, krope_shift,
                                 page_table, kv_lens, scale,
                                 fmt=None, frozen=None,
                                 ckv_fz=None, krope_fz=None, ckv_fz_smax=None,
                                 ckv_fz_shift=None, krope_fz_smax=None,
                                 krope_fz_shift=None,
                                 bq: int = 8, interpret: bool = True):
    """MLA absorbed decode over latent pages (flash-decoding dataflow).

    q_lat: (B, H, r) queries absorbed into the latent space; q_rope:
    (B, H, dr) rope-space queries; ckv_pages: (P+1, page, r) and
    krope_pages: (P+1, page, dr) uint8 codes (``fmt`` quantized) or bf16;
    c/r smax: (P+1,) f32; c/r shift: (P+1, 1) int32 (single scale "head");
    page_table: (B, PP) int32; kv_lens: (B,); ``scale``: softmax scale
    (1/sqrt(qk_nope + qk_rope)). ``fmt``/``frozen`` are PageFormats (or
    names — ``page_format`` coercion fails fast on unknowns); with
    ``frozen`` set the ``*_fz`` operands carry the packed FP4 latent region
    ((F+1, page, ceil(d/2)) codes + scales) and table entries >= P+1 select
    it per page. Returns the latent context (B, H, r) f32 — the caller
    applies the absorbed v_up projection.

    KV is a single head: every query head scores the same k =
    concat(ckv, krope) page block and v is the ckv view, so the grid is
    (B, ceil(H / bq), pages) with the page loop innermost and the latent
    never gathered into HBM.
    """
    fmt = page_format(fmt)
    frozen = page_format(frozen) if frozen is not None else None
    assert frozen is None or (fmt.quantized and frozen.quantized), \
        "a frozen region requires quantized active pages"
    b, h, r = q_lat.shape
    dr = q_rope.shape[-1]
    p1, page, _ = ckv_pages.shape
    pp = page_table.shape[1]
    hb = -(-h // bq)
    pad = hb * bq - h
    if pad:
        q_lat = jnp.pad(q_lat, ((0, 0), (0, pad), (0, 0)))
        q_rope = jnp.pad(q_rope, ((0, 0), (0, pad), (0, 0)))
    ql = q_lat.reshape(b, hb, bq, r)
    qr = q_rope.reshape(b, hb, bq, dr)

    nfz = 0 if ckv_fz is None else ckv_fz.shape[0] - 1

    def page_map(bi, hi, ji, pt, ln, *_s):
        pid = pt[bi, ji]
        if frozen is not None:  # frozen ids clamp to the null page
            pid = jnp.minimum(pid, p1 - 1)
        return (pid, 0, 0)

    def fz_page_map(bi, hi, ji, pt, ln, *_s):
        return (jnp.clip(pt[bi, ji] - p1, 0, nfz), 0, 0)

    def q_map(bi, hi, ji, *_s):
        return (bi, hi, 0, 0)

    scalars = [page_table, kv_lens, ckv_smax, ckv_shift, krope_smax,
               krope_shift]
    tensors = [ql, qr, ckv_pages, krope_pages]
    in_specs = [
        pl.BlockSpec((1, 1, bq, r), q_map),
        pl.BlockSpec((1, 1, bq, dr), q_map),
        pl.BlockSpec((1, page, r), page_map),
        pl.BlockSpec((1, page, dr), page_map),
    ]
    if frozen is not None:
        scalars += [ckv_fz_smax, ckv_fz_shift, krope_fz_smax, krope_fz_shift]
        tensors += [ckv_fz, krope_fz]
        in_specs += [
            pl.BlockSpec((1, page, ckv_fz.shape[-1]), fz_page_map),
            pl.BlockSpec((1, page, krope_fz.shape[-1]), fz_page_map),
        ]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalars),
        grid=(b, hb, pp),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, bq, r), q_map),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, r), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_mla_kernel, page=page, pp=pp, scale=scale,
                          fmt=fmt, frozen=frozen, base=p1, nfz=nfz,
                          r=r, dr=dr),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hb, bq, r), jnp.float32),
        interpret=interpret,
    )(*scalars, *tensors)
    return out.reshape(b, hb * bq, r)[:, :h]
