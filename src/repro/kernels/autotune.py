"""Block-size autotuner for the fused W4A8 kernels.

Two halves:

  * ``autotune_gemm(build, key, ...)`` — the *offline* timed sweep: given a
    factory that builds a zero-arg kernel call for a (bm, bn) candidate, time
    every candidate on concrete inputs and persist the winner in a JSON
    cache. Run from the benchmark harness (or any warmup script with real
    tensors); it cannot run at dispatch time because the ops layer is called
    under jit traces where inputs are abstract.
  * ``best_block_sizes(...)`` — the *dispatch-time* lookup: pure cache read
    keyed on the GEMM signature, falling back to a shape heuristic on a miss
    (the kernels clamp blocks to divisors, so the heuristic is always legal).

Cache keys: kind | backend | E | M | N | K | w_fmt | a_fmt | group | m2 |
lorc_rank | transpose — everything that changes the kernel's tiling
economics. The cache file (REPRO_AUTOTUNE_CACHE, default
~/.cache/repro/w4a8_autotune.json) is invalidated simply by deleting it; a
schema version inside the file guards stale layouts across refactors.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Iterable, Optional, Tuple

import jax

__all__ = [
    "cache_path",
    "clear_cache",
    "cache_key",
    "best_block_sizes",
    "autotune_gemm",
    "DEFAULT_CANDIDATES",
]

SCHEMA = 1

DEFAULT_CANDIDATES: Tuple[Tuple[int, int], ...] = (
    (128, 128), (256, 128), (128, 256), (256, 256), (128, 512), (256, 512),
    (64, 128), (128, 64), (64, 64), (32, 128), (16, 128), (8, 128), (8, 256),
)

_MEM: Optional[Dict[str, list]] = None  # in-process mirror of the JSON file


def cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "w4a8_autotune.json")


def _load() -> Dict[str, list]:
    global _MEM
    if _MEM is not None:
        return _MEM
    _MEM = {}
    try:
        with open(cache_path()) as f:
            data = json.load(f)
        if isinstance(data, dict) and data.get("__schema__") == SCHEMA:
            _MEM = {k: v for k, v in data.items() if not k.startswith("__")}
    except (OSError, ValueError):
        pass
    return _MEM


def _save() -> None:
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        payload = {"__schema__": SCHEMA}
        payload.update(_load())
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
    except OSError:
        pass  # read-only FS: the in-process cache still serves this run


def clear_cache() -> None:
    global _MEM
    _MEM = None
    try:
        os.remove(cache_path())
    except OSError:
        pass


def cache_key(
    kind: str,
    *,
    batch: int,
    m: int,
    n: int,
    k: int,
    w_fmt: str,
    a_fmt: Optional[str],
    group_size: int,
    m2: bool,
    lorc_rank: int,
    transpose_w: bool = False,
    backend: Optional[str] = None,
) -> str:
    backend = backend or jax.default_backend()
    return "|".join(str(v) for v in (
        kind, backend, batch, m, n, k, w_fmt, a_fmt or "none", group_size,
        int(m2), lorc_rank, int(transpose_w),
    ))


def _heuristic(kind: str, m: int, n: int) -> Tuple[int, int]:
    """Cache-miss default: full MXU tiles, shrunk for skinny decode batches
    (tiny M wastes no VMEM on a tall block; the kernel clamps to divisors).
    For the paged decode-attention kernel (kind 'decode_attn') bm is the
    query-group row block: the g = H/KV heads padded up to a sublane
    multiple; bn is the page size (the kv block is a whole page)."""
    if kind == "decode_attn":
        return max(8, -(-m // 8) * 8), n
    bm = 128 if m >= 128 else max(8, m)
    bn = 128
    return bm, bn


def best_block_sizes(kind: str = "fused", **sig) -> Tuple[int, int]:
    """Dispatch-time (bm, bn) choice. Safe under jit traces: pure lookup on
    static shapes, no timing, no device work."""
    key = cache_key(kind, **sig)
    hit = _load().get(key)
    if hit:
        return int(hit[0]), int(hit[1])
    return _heuristic(kind, sig["m"], sig["n"])


def autotune_gemm(
    build: Callable[[int, int], Callable[[], object]],
    key: str,
    candidates: Iterable[Tuple[int, int]] = DEFAULT_CANDIDATES,
    reps: int = 3,
    dims: Optional[Tuple[int, int]] = None,
) -> Tuple[int, int]:
    """Timed sweep: ``build(bm, bn)`` returns a zero-arg callable running the
    kernel on concrete inputs. The winner is persisted under ``key`` and
    returned. Candidates that fail to build/run are skipped.

    ``dims=(m, n)`` maps candidates through the kernels' divisor clamp
    first and dedupes — e.g. for a decode batch m=8 every bm >= 8 collapses
    to the same effective tiling, which would otherwise be compiled and
    timed once per raw candidate; the cached winner is then the *effective*
    pair, so dispatch reuses one jit variant."""
    mem = _load()
    if key in mem:
        return int(mem[key][0]), int(mem[key][1])
    if dims is not None:
        from .w4a8_fused import clamp_block

        m, n = dims
        seen = set()
        candidates = [c for c in
                      ((clamp_block(m, bm), clamp_block(n, bn))
                       for bm, bn in candidates)
                      if not (c in seen or seen.add(c))]
    # build + warm every runnable candidate first, then time them in
    # interleaved rounds taking per-candidate minima — sequential
    # median-per-candidate lets machine-load drift crown whichever candidate
    # happened to run during a quiet phase
    calls = {}
    last_exc: Optional[Exception] = None
    for bm, bn in candidates:
        try:
            call = build(bm, bn)
            jax.block_until_ready(call())  # compile + warm
            calls[(bm, bn)] = call
        except Exception as exc:  # noqa: BLE001 — illegal tiling for this shape
            last_exc = exc
            continue
    if not calls:
        # every candidate failed: that's a kernel bug, not a tiling issue —
        # surface the real traceback instead of burying it
        raise ValueError(f"no candidate block size ran for {key}") from last_exc
    times = {c: float("inf") for c in calls}
    for _ in range(reps):
        for c, call in calls.items():
            t0 = time.perf_counter()
            jax.block_until_ready(call())
            times[c] = min(times[c], time.perf_counter() - t0)
    best = min(times, key=times.get)
    mem[key] = [best[0], best[1], times[best] * 1e6]  # us, for the curious
    _save()
    return best
