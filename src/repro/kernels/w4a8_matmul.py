"""Pallas TPU kernel: split W4A8 GEMM — packed-FP4 weights x *pre-quantized*
FP8 activations, decoded in VMEM.

This is the original two-pass deployment kernel (act_quant writes the FP8
activations to HBM, this GEMM reads them back). It is kept as the baseline
the fused single-pass kernel (w4a8_fused.py) is benchmarked against, and as
the building block for callers that already hold quantized activations.
The decode / scale semantics are shared with the fused kernel via
kernels.common (DESIGN.md §2):

  * weights live in HBM as packed E2M1 nibbles (2/byte) + per-(row, group)
    scales — the HBM read per weight is 4 bits, which is the whole point on
    a bandwidth-bound decode step;
  * each (BM, BN, BK=group) tile is decoded to bf16 *in VMEM*: copy-free
    bitwise nibble unpack + a closed-form E2M1 decode (4 VPU ops), then an
    MXU bf16 matmul with f32 accumulation;
  * scales: the per-group multiply folds into the tile's partial sum. With
    M2 (pow-2 constrained) scales the multiplier is 2^-k built directly from
    the exponent bit pattern (integer VPU op — the TPU equivalent of the
    paper's "bit shift" cast) and one final per-row s_max multiply;
  * activations arrive already token-wise FP8-quantized (values on the E4M3
    grid times their scale, stored bf16) from the act_quant kernel.

Grid: (M/BM, N/BN, K/BK), K innermost; out tile (BM, BN) f32 accumulates
across the K steps and is written once (revisiting semantics).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DECODERS, decode_e2m1, decode_e3m0, pow2i as _pow2i, unpack_nibbles as _unpack

__all__ = ["w4a8_matmul_pallas", "decode_e2m1", "decode_e3m0"]


def _kernel(x_ref, codes_ref, scale_ref, o_ref, *, w_fmt, nsteps, m2, smax_ref=None):
    """One (BM, BN) tile accumulating over the K grid dimension.

    x_ref: (BM, BK) bf16 — FP8-grid activation values (x scale)
    codes_ref: (BN, BK/2) uint8; scale_ref: (BN, 1) f32 (or shifts when m2)
    o_ref: (BM, BN) f32 accumulator
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    decode = DECODERS[w_fmt]
    w_q = decode(_unpack(codes_ref[...]))  # (BN, BK) f32 on-grid
    if m2:
        # pow-2 group scale: multiplier from exponent bits (the bit-shift)
        gscale = _pow2i(-scale_ref[...].astype(jnp.int32))  # (BN, 1)
    else:
        gscale = scale_ref[...]  # (BN, 1) f32
    w = (w_q * gscale).astype(jnp.bfloat16)
    x = x_ref[...].astype(jnp.bfloat16)
    part = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] += part

    if m2:

        @pl.when(k_step == nsteps - 1)
        def _finalize():
            o_ref[...] = o_ref[...] * smax_ref[...].reshape(1, -1)


@functools.partial(
    jax.jit,
    static_argnames=("w_fmt", "group_size", "bm", "bn", "interpret"),
)
def w4a8_matmul_pallas(
    x_q,
    codes,
    scale,
    s_max=None,
    shifts=None,
    w_fmt: str = "fp4_e2m1",
    group_size: int = 256,
    bm: int = 128,
    bn: int = 128,
    interpret: Optional[bool] = None,
):
    """y[m, n] = sum_k x_q[m, k] * dequant(codes, scale)[n, k].

    x_q: (M, K) bf16/f32 — already FP8-quantized activation values x scale.
    codes: (N, K/2) uint8; scale: (N, G) f32; optional M2 (s_max, shifts).
    Returns (M, N) f32. Shapes must tile: M % bm == 0 is relaxed by clamping
    bm to a divisor; K % group_size == 0 required (FGQ invariant).
    ``interpret=None`` resolves from the runtime: compiled on TPU,
    interpreter elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, k = x_q.shape
    n = codes.shape[0]
    bk = group_size
    assert k % bk == 0, (k, bk)
    bm = min(bm, m)
    while m % bm:
        bm -= 1
    bn = min(bn, n)
    while n % bn:
        bn -= 1
    nsteps = k // bk
    m2 = shifts is not None

    scale_in = shifts.astype(jnp.int32) if m2 else scale
    args = [x_q.astype(jnp.bfloat16), codes, scale_in]
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
        pl.BlockSpec((bn, bk // 2), lambda i, j, s: (j, s)),
        pl.BlockSpec((bn, 1), lambda i, j, s: (j, s)),
    ]
    if m2:
        args.append(s_max.reshape(n, 1))
        in_specs.append(pl.BlockSpec((bn, 1), lambda i, j, s: (j, 0)))

    kernel = functools.partial(_kernel, w_fmt=w_fmt, nsteps=nsteps, m2=m2)
    if m2:
        def kernel(x_ref, c_ref, s_ref, sm_ref, o_ref):  # noqa: F811
            _kernel(x_ref, c_ref, s_ref, o_ref, w_fmt=w_fmt, nsteps=nsteps,
                    m2=True, smax_ref=sm_ref)

    out = pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nsteps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(*args)
    return out
